"""Mirror of rust/src/clustering/incremental.rs — the persistent,
incrementally-maintained DBSCAN engine — plus the drift-schedule
validation harness behind the Rust proptest
`prop_incremental_dbscan_matches_full_recluster_under_drift`.

The engine keeps the uniform grid (cell size = eps), the point set, and
the standing cluster labels alive across updates; `update` reclusters
only the cell-connected components a batch of changes touched and
splices fresh labels in. This script asserts, over hundreds of seeded
multi-round insert/move/remove schedules, that the spliced standing
labels stay **partition-identical** (identical NOISE sets + a bijection
between label values) to a from-scratch `dbscan_grid` pass at the same
frozen eps — the exactness claim the Rust module docs make from the
cell-component independence argument (cells of size eps: points >= 2
cells apart on any axis are > eps apart, so density-reachability never
crosses non-adjacent occupied-cell components).

Neighbour visit order inside one expansion is irrelevant to the
resulting partition (a cluster's expansion labels exactly the
density-reachable closure of its seed, and seeds are scanned in
ascending id order on both sides), so the Python dict/set iteration
order standing in for Rust's HashMap/HashSet is not a fidelity gap.

Zero dependencies beyond the standard library. Run:

    python3 python/mirror/incremental.py
"""

import itertools
import math

from core import NOISE, Rng, cell_key, dbscan_grid, dist2, expand

CASES = 300
SEED = 0x1DB5_CA4D_12F7_5EED


class IncrementalDbscan:
    """Persistent grid + standing labels (incremental.rs)."""

    def __init__(self, eps, min_pts):
        self.eps = eps
        self.eps2 = eps * eps
        self.min_pts = min_pts
        self.dim = None
        self.cells = {}   # cell key tuple -> set of ids
        self.pts = {}     # id -> (point tuple, cell key tuple)
        self.labels = {}  # id -> standing label (NOISE for outliers)
        self.next_cluster = 0

    @classmethod
    def new(cls, eps, min_pts):
        if not (math.isfinite(eps) and eps > 0.0):
            return None
        return cls(eps, min_pts)

    def __len__(self):
        return len(self.pts)

    def label(self, pid):
        return self.labels.get(pid)

    def labels_for(self, ids):
        return [self.labels[i] for i in ids]

    def _block(self, center):
        for offs in itertools.product((-1, 0, 1), repeat=len(center)):
            yield tuple(c + o for c, o in zip(center, offs))

    def update(self, changes):
        """Apply (id, point-or-None) changes; recluster touched
        cell-components. Returns (reclustered, components, relabeled)
        or None (state unchanged) on an unplaceable point."""
        # Validate every change before mutating anything.
        dim = self.dim
        keyed = []
        for pid, p in changes:
            if p is None:
                keyed.append((pid, None))
                continue
            if dim is not None and dim != len(p):
                return None
            if dim is None:
                dim = len(p)
            key = cell_key(p, self.eps)
            if key is None:
                return None
            keyed.append((pid, (tuple(p), key)))

        # Apply grid mutations, collecting every cell a changed point
        # left or entered as a BFS seed.
        seeds = set()
        for pid, upsert in keyed:
            old = self.pts.get(pid)
            if old is not None:
                old_key = old[1]
                members = self.cells.get(old_key)
                if members is not None:
                    members.discard(pid)
                    if not members:
                        del self.cells[old_key]
                seeds.add(old_key)
            if upsert is not None:
                p, key = upsert
                seeds.add(key)
                self.cells.setdefault(key, set()).add(pid)
                self.pts[pid] = (p, key)
            else:
                self.pts.pop(pid, None)
                self.labels.pop(pid, None)
        self.dim = dim

        # Close over the touched cell-components.
        visited = set()
        frontier = []
        components = 0
        for seed in sorted(seeds):
            started = False
            for cell in self._block(seed):
                if cell in self.cells and cell not in visited:
                    visited.add(cell)
                    frontier.append(cell)
                    started = True
            if not started:
                continue
            components += 1
            while frontier:
                cell = frontier.pop()
                for nb in self._block(cell):
                    if nb in self.cells and nb not in visited:
                        visited.add(nb)
                        frontier.append(nb)

        # Gather members ascending by id — from-scratch seed order.
        ids = sorted(i for c in visited for i in self.cells[c])
        index = {pid: i for i, pid in enumerate(ids)}

        def neighbours(i):
            p, key = self.pts[ids[i]]
            out = []
            for cell in self._block(key):
                for j in self.cells.get(cell, ()):
                    if dist2(p, self.pts[j][0]) <= self.eps2:
                        out.append(index[j])
            return out

        local, _ = expand(len(ids), self.min_pts, neighbours)

        # Splice: fresh ids for the non-noise local clusters.
        base = self.next_cluster
        max_local = max(local, default=NOISE)
        self.next_cluster += max_local + 1
        relabeled = []
        for i, pid in enumerate(ids):
            label = NOISE if local[i] == NOISE else base + local[i]
            self.labels[pid] = label
            relabeled.append((pid, label))
        return (len(relabeled), components, relabeled)


# --------------------------------------------------------------- validation

def assert_partition_eq(ids, got, want, what):
    assert len(got) == len(want), f"{what}: length {len(got)} vs {len(want)}"
    fwd, rev = {}, {}
    for pid, g, w in zip(ids, got, want):
        assert (g == NOISE) == (w == NOISE), \
            f"{what}: id {pid} noise mismatch ({g} vs {w})"
        if g == NOISE:
            continue
        assert fwd.setdefault(g, w) == w, f"{what}: id {pid} fwd"
        assert rev.setdefault(w, g) == g, f"{what}: id {pid} rev"


def engine_matches_oracle(engine, live, what):
    ids = sorted(live)
    points = [live[i] for i in ids]
    want = dbscan_grid(points, engine.eps, engine.min_pts)
    got = engine.labels_for(ids)
    assert_partition_eq(ids, got, want, what)
    assert len(engine) == len(ids), f"{what}: engine size"


def drift_case(case):
    """One seeded multi-round insert/move/remove schedule, mirroring
    tests/proptests.rs::prop_incremental_dbscan_matches_full_recluster
    _under_drift (3 feature-shaped blobs, departures / EMA-style moves /
    arrivals, oracle check after every round)."""
    rng = Rng(SEED ^ case)
    n = 4 + rng.below(41)
    min_pts = 2 + rng.below(3)
    eps = rng.range_f64(0.2, 8.0)

    live = {}
    for pid in range(n):
        c = rng.below(3)
        center = c * 40.0
        live[pid] = (
            center + rng.range_f64(-1.5, 1.5),
            center + rng.range_f64(-1.5, 1.5),
        )
    next_id = n

    engine = IncrementalDbscan.new(eps, min_pts)
    assert engine is not None
    res = engine.update(sorted(live.items()))
    assert res is not None and res[0] == n, f"case {case}: bulk build"
    engine_matches_oracle(engine, live, f"case {case} bulk")

    rounds = 1 + rng.below(7)
    for rnd in range(rounds):
        changes = {}
        for pid in list(live):
            if rng.bernoulli(0.15):
                changes[pid] = None
            elif rng.bernoulli(0.4):
                old = live[pid]
                s = rng.range_f64(0.7, 1.4)
                changes[pid] = (old[0] * s, old[1] * s)
        for _ in range(rng.below(5)):
            c = rng.below(3)
            center = c * 40.0
            changes[next_id] = (
                center + rng.range_f64(-1.5, 1.5),
                center + rng.range_f64(-1.5, 1.5),
            )
            next_id += 1
        batch = sorted(changes.items())
        res = engine.update(batch)
        assert res is not None, f"case {case} round {rnd}: refused"
        for pid, p in batch:
            if p is None:
                live.pop(pid, None)
            else:
                live[pid] = p
        engine_matches_oracle(engine, live, f"case {case} round {rnd}")


def refusal_and_locality_checks():
    # Refusals leave standing state intact (unit-test mirror).
    e = IncrementalDbscan.new(0.5, 2)
    e.update([(0, (0.0,)), (1, (0.1,))])
    before = (e.label(0), e.label(1), len(e))
    assert e.update([(2, (float("nan"),))]) is None
    assert e.update([(2, (0.0, 0.0))]) is None, "dim mismatch"
    assert (e.label(0), e.label(1), len(e)) == before
    assert e.update([])[0] == 0, "noop update is an empty splice"
    assert IncrementalDbscan.new(0.0, 2) is None
    assert IncrementalDbscan.new(-1.0, 2) is None
    assert IncrementalDbscan.new(float("nan"), 2) is None

    # Removal from one far blob never relabels the other.
    e = IncrementalDbscan.new(0.5, 2)
    pts = {i: (i * 0.3,) for i in range(4)}
    pts.update({i: (100.0 + i * 0.3,) for i in range(4, 8)})
    e.update(sorted(pts.items()))
    right_before = e.label(5)
    reclustered, _, _ = e.update([(0, None)])
    assert reclustered <= 3, f"locality: {reclustered} reclustered"
    assert e.label(5) == right_before, "untouched component keeps labels"
    del pts[0]
    engine_matches_oracle(e, pts, "after removal")


def main():
    refusal_and_locality_checks()
    for case in range(CASES):
        drift_case(case)
    print(f"incremental mirror OK: {CASES} drift schedules partition-"
          f"identical to from-scratch dbscan_grid (+ refusal/locality checks)")


if __name__ == "__main__":
    main()
