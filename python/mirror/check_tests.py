"""Mirror the new Rust unit/scale tests to confirm their assertions hold."""
import math
from core import (Rng, cluster_clients, dbscan_grid, HistoryStore, NewHistory,
                  stratified_cohort, fedlesscan_select, COHORT_MAX)

# 1. subsampled_eps_estimate_still_separates_blobs (clustering/mod.rs)
EPS_SAMPLE_MAX = 512
n = EPS_SAMPLE_MAX + 200
pts = []
for i in range(n):
    c = 0.0 if i % 2 == 0 else 50.0
    a = i * 0.37
    pts.append([c + 0.3 * math.sin(a), 0.3 * math.cos(a)])
la, ka = cluster_clients(pts, 2, dbscan_grid)
lb, kb = cluster_clients(pts, 2, dbscan_grid)
assert la == lb and ka == kb
print("subsample blobs: k =", ka, "| la[0]!=la[1]:", la[0] != la[1],
      "| la[0]==la[2]:", la[0] == la[2], "| la[1]==la[3]:", la[1] == la[3])
assert ka == 2 and la[0] != la[1] and la[0] == la[2] and la[1] == la[3]

# 2. stratified_cohort_spans_the_behaviour_range (fedlesscan.rs)
n = 4000
hist = HistoryStore(NewHistory)
for c in range(n):
    hist.record_invocation(c)
    t = 5.0 if c % 2 == 0 else 80.0
    hist.record_success(c, 0, t + (c % 17) * 0.1)
rng = Rng(21)
take = 512
cohort = stratified_cohort(list(range(n)), hist, take, rng)
assert len(cohort) == take, len(cohort)
assert len(set(cohort)) == take
fast = sum(1 for c in cohort if c % 2 == 0)
slow = take - fast
print("stratified cohort: fast", fast, "slow", slow)
assert fast > take // 4 and slow > take // 4

# 3. large_fleet_selection_is_bounded_and_deterministic (fedlesscan.rs)
n = COHORT_MAX * 3
hist = HistoryStore(NewHistory)
for c in range(n):
    hist.record_invocation(c)
    hist.record_success(c, 0, 5.0 + (c % 97))
def run(seed):
    rng = Rng(seed)
    return fedlesscan_select(list(range(n)), hist, 3, 20, 48, rng, True)
a = run(7); b = run(7); c8 = run(8)
assert a == b
assert len(a) == 48 and len(set(a)) == 48
print("large fleet: deterministic ok; a != run(8):", a != c8)
assert a != c8

# 4. scale.rs fleet_history 50k selection (downscaled mirror at 20k for time)
n = 20000
hist = HistoryStore(NewHistory)
for c in range(n):
    m = c % 10
    if m in (0, 1):
        pass
    elif m == 2:
        hist.record_invocation(c)
        hist.record_failure(c, 3)
    else:
        hist.record_invocation(c)
        hist.record_success(c, 0, 5.0 + (c % 211) * 0.4)
        hist.record_invocation(c)
        hist.record_success(c, 1, 5.0 + ((c * 7) % 211) * 0.4)
        if c % 13 == 0:
            hist.record_invocation(c)
            hist.record_failure(c, 2)
            hist.tick_cooldowns([])
k = 256
rng1 = Rng(99); rng2 = Rng(99)
s1 = fedlesscan_select(list(range(n)), hist, 5, 40, k, rng1, True)
s2 = fedlesscan_select(list(range(n)), hist, 5, 40, k, rng2, True)
assert s1 == s2
assert len(s1) == k, len(s1)
assert len(set(s1)) == k
print("fleet selection(20k mirror): k =", len(s1), "distinct ok")
print("ALL TEST EXPECTATIONS HOLD")
