"""Bit-exact Python mirror of the kernel-plane restructuring claims.

``rust/src/runtime/kernel.rs`` promises that the AVX2 kernels are
f32-bit-identical to the scalar seed loops. The vector instructions
themselves cannot run here, but every claim rests on *restructurings*
that are kernel-independent and checkable in pure Python with f32
emulation (every op computed in double, rounded back to f32 via a
struct round-trip — exact for single +, -, *, / of f32 operands):

1. `matmul_a_bt` replaces the seed's per-element dot-product fold
   (`Iterator::sum`) with a pre-transpose + j-inner matmul. Claim: the
   per-element accumulation order is unchanged, so results are
   bit-identical.
2. The fused bias+ReLU epilogue (`matmul_bias_relu`) performs the same
   op sequence as the unfused matmul → +bias → max(0) chain.
3. Ragged eval splits: per-row forward math is batch-independent and
   loss/correct accumulate in global row order, so any `eval_batch`
   split of the eval set is bit-identical (the pre-fix code dropped
   the ragged tail entirely).
4. The AVX2 int8 encode emulates `f32::round` (half AWAY from zero)
   via truncate + fractional-part compare. Claim: `t = trunc(x);
   frac = x - t; r = t + (|frac| >= 0.5 ? copysign(1, x) : 0)` equals
   `f32::round` for all |x| < 2^23, where the naive `trunc(x + 0.5)`
   trick does not (it fails at 0.49999997f32, whose +0.5 rounds up to
   1.0) and `_mm256_round_ps`-to-nearest does not (halves to even).

Run directly: ``python3 kernelplane.py`` — prints a pass line.
"""

import math

from quantplane import f32, f32_bits, rust_round_f32


# --- scalar kernel mirrors (rust/src/runtime/kernel.rs mod scalar) -----


def matmul(a, b, m, k, n):
    """out[m,n] = a[m,k] @ b[k,n], j-inner accumulation (seed loop order)."""
    out = [0.0] * (m * n)
    for i in range(m):
        for l in range(k):
            aik = a[i * k + l]
            for j in range(n):
                out[i * n + j] = f32(out[i * n + j] + f32(aik * b[l * n + j]))
    return out


def matmul_a_bt_dot(a, b, m, n, k):
    """Seed form of `a[m,n] @ b[k,n]ᵀ`: per-element dot-product fold
    (`Iterator::sum` = sequential += from 0.0)."""
    out = [0.0] * (m * k)
    for i in range(m):
        for j in range(k):
            acc = 0.0
            for l in range(n):
                acc = f32(acc + f32(a[i * n + l] * b[j * n + l]))
            out[i * k + j] = acc
    return out


def matmul_a_bt_restructured(a, b, m, n, k):
    """Kernel form: pre-transpose b into bt[n,k], then j-inner matmul."""
    bt = [0.0] * (n * k)
    for i in range(k):
        for j in range(n):
            bt[j * k + i] = b[i * n + j]  # moves are rounding-free
    return matmul(a, bt, m, n, k)


def matmul_bias_relu_fused(a, b, bias, m, k, n):
    z = matmul(a, b, m, k, n)
    act = [0.0] * (m * n)
    for i in range(m):
        for j in range(n):
            z[i * n + j] = f32(z[i * n + j] + bias[j])
            act[i * n + j] = max(z[i * n + j], 0.0)
    return z, act


def matmul_bias_relu_unfused(a, b, bias, m, k, n):
    z = matmul(a, b, m, k, n)
    z = [f32(z[i * n + j] + bias[j]) for i in range(m) for j in range(n)]
    act = [max(v, 0.0) for v in z]
    return z, act


def avx2_round_emulation(x):
    """The vector encode's round: trunc + |frac| >= 0.5 + copysign(1, x).
    trunc and x - trunc(x) are exact f32 ops for |x| < 2^23."""
    t = float(math.trunc(x))
    frac = f32(x - t)
    if abs(frac) >= 0.5:
        return t + math.copysign(1.0, x)
    return t


def naive_round(x):
    """The tempting-but-wrong trunc(x + 0.5) trick (for the negative
    demo below — NOT what the kernel does)."""
    return float(math.trunc(f32(x + math.copysign(0.5, x))))


# --- eval-loop mirror (native.rs evaluate, post-ragged-fix) ------------


def eval_split(z_rows, y, eval_batch):
    """Loss/correct over per-row logits, accumulated in `eval_batch`
    groups exactly as native.rs does (batch boundaries only gate when
    the forward pass runs; the sums walk rows in global order)."""
    loss_sum, correct = 0.0, 0.0
    off = 0
    while off < len(y):
        rows = min(eval_batch, len(y) - off)
        for r in range(off, off + rows):
            zr, yi = z_rows[r], y[r]
            zmax = max(zr)
            denom = f32(sum_f32(f32(math.exp(f32(z - zmax))) for z in zr))
            loss_sum = f32(
                loss_sum + f32(-(f32(f32(zr[yi] - zmax) - f32(math.log(denom)))))
            )
            best = 0
            for i, z in enumerate(zr):
                if z > zr[best]:
                    best = i
            if best == yi:
                correct = f32(correct + 1.0)
        off += rows
    return loss_sum, correct


def sum_f32(it):
    acc = 0.0
    for v in it:
        acc = f32(acc + v)
    return acc


def ramp(n, phase):
    return [f32(((i * 7 + phase * 13) % 23 - 11.0) * 0.037) for i in range(n)]


if __name__ == "__main__":
    # 1. a @ bᵀ restructure: dot fold == transpose + j-inner, bit for bit,
    #    across ragged shapes (incl. lane tails at every n % 8 residue).
    for m, n, k in [(1, 1, 1), (3, 10, 7), (5, 32, 10), (4, 17, 9), (2, 8, 8)]:
        a = ramp(m * n, 1)
        b = ramp(k * n, 2)
        ref = matmul_a_bt_dot(a, b, m, n, k)
        got = matmul_a_bt_restructured(a, b, m, n, k)
        assert [f32_bits(v) for v in ref] == [f32_bits(v) for v in got], (m, n, k)

    # 2. fused bias+ReLU epilogue == unfused chain, bit for bit.
    for m, k, n in [(1, 1, 1), (4, 9, 11), (6, 13, 8), (3, 784 % 50, 10)]:
        a, b, bias = ramp(m * k, 3), ramp(k * n, 4), ramp(n, 5)
        zf, af = matmul_bias_relu_fused(a, b, bias, m, k, n)
        zu, au = matmul_bias_relu_unfused(a, b, bias, m, k, n)
        assert [f32_bits(v) for v in zf] == [f32_bits(v) for v in zu], (m, k, n)
        assert [f32_bits(v) for v in af] == [f32_bits(v) for v in au], (m, k, n)

    # 3. ragged eval split invariance: 10 rows under every batch split
    #    (ragged tails at 3, 4, 8) match the single-batch sums exactly.
    c = 6
    z_rows = [ramp(c, 20 + r) for r in range(10)]
    y = [(r * 5) % c for r in range(10)]
    base = eval_split(z_rows, y, 10)
    for eb in (1, 2, 3, 4, 7, 8, 128):
        got = eval_split(z_rows, y, eb)
        assert f32_bits(base[0]) == f32_bits(got[0]), eb
        assert base[1] == got[1], eb

    # 4. the encode's round emulation == f32::round on every adversarial
    #    case, and the naive trunc(x + 0.5) trick provably differs.
    tricky = [
        0.5, -0.5, 1.5, -1.5, 2.5, -2.5, 126.5, -126.5,
        f32(0.49999997), f32(-0.49999997), 130.0, -130.0,
        0.0, f32(1.0e-8), f32(3.49), -f32(3.51),
    ]
    sweep = [f32((i - 600) * 0.211) for i in range(1200)]
    for v in tricky + sweep:
        assert avx2_round_emulation(v) == rust_round_f32(v), v
    # half-to-even (_mm256_round_ps nearest) and the naive trick both
    # diverge from f32::round — the emulation is load-bearing:
    assert rust_round_f32(2.5) == 3 and round(2.5) == 2
    bad = f32(0.49999997)
    assert naive_round(bad) == 1.0 and rust_round_f32(bad) == 0

    print("kernelplane mirror self-checks pass")
