"""Bit-exact Python mirror of the continuous-mode coordinator path.

Mirrors, op for op:

* ``util/rng.rs``      — Box–Muller ``normal()`` (with the cached spare
                         deviate) and ``lognormal()`` on top of the
                         ``core.Rng`` xoshiro256** mirror;
* ``faas/mod.rs``      — ``SimulatedGcf``: cold/warm decision, the
                         pinned RNG draw order (startup → crash →
                         speed → jitter, with the ``||`` short-circuit
                         skipping the transient draw on forced crashes),
                         and the pure timeline materialization;
* ``cost/mod.rs``      — GCF pricing at 100 ms granularity;
* ``coordinator/mod.rs`` ``drive_continuous``/``dispatch_continuous`` —
                         the generation-keyed Eq. 3 fold/expire logic,
                         metric windows, in-flight ledger, cooldown tick
                         cadence, and the budgeted replacement dispatch.

The driver never models parameter values: with the test suites'
``MockBackend`` the virtual timeline, history evolution, selection and
cost are independent of the trained floats, which is exactly the state
``tests/continuous_golden.rs`` pins. Run ``gen_continuous_golden.py`` to
(re)generate the pinned constants.
"""

import heapq
import math

from core import GaussRng, HistoryStore, NewHistory, fedlesscan_select, rust_round

# seed mixers (faas/mod.rs, coordinator/mod.rs)
FAAS_SEED_MIX = 0xFAA5_0001
COORD_SEED_MIX = 0xC00D_1234_5678_9ABC

# FaasConfig::default()
COLD_START_MEDIAN_S = 4.0
COLD_START_SIGMA = 0.5
WARM_OVERHEAD_S = 0.15
IDLE_TIMEOUT_S = 300.0
CLIENT_SPEED_SIGMA = 0.25
INVOCATION_JITTER_SIGMA = 0.10
TRANSIENT_FAILURE_RATE = 0.02
MEMORY_MB = 2048
NETWORK_MBPS = 40.0
FUNCTION_TIMEOUT_S = 540.0

# GcfPricing::default(); 2048 MB -> 2.0 GB, 2.4 GHz tier
PER_INVOCATION = 0.40 / 1e6
PER_GB_SECOND = 0.000_002_5
PER_GHZ_SECOND = 0.000_010_0
GRANULARITY_S = 0.1


def invocation_cost(duration_s, memory_mb=MEMORY_MB, margins=None):
    """cost/mod.rs invocation_cost, same op order."""
    if margins is not None:
        # ceil-boundary audit: a last-ulp drift in a transcendental-
        # derived duration must not flip the billing quantum
        q = duration_s / GRANULARITY_S
        margins.append(("bill_ceil", abs(q - round(q))))
    billed = math.ceil(duration_s / GRANULARITY_S) * GRANULARITY_S
    gb = memory_mb / 1024.0
    ghz = 2.4  # ghz_for_memory_mb(2048)
    return PER_INVOCATION + billed * gb * PER_GB_SECOND + billed * ghz * PER_GHZ_SECOND


class Faas:
    """SimulatedGcf: decide (all RNG) + materialize (no RNG)."""

    def __init__(self, seed):
        self.rng = GaussRng(seed ^ FAAS_SEED_MIX)
        self.warm = {}  # client -> last_used_at
        self.speed = {}  # client -> cached speed factor
        self.margins = []  # (kind, |lhs - rhs|) float-boundary audit trail

    def invoke(self, client, now_s, compute_s, payload_mb, deadline_s, forced):
        # ---- decide: pinned draw order --------------------------------
        if client in self.warm:
            gap = now_s - self.warm[client]
            cold = not (0.0 <= gap <= IDLE_TIMEOUT_S)
            self.margins.append(("warm_gap_lo", abs(gap)))
            self.margins.append(("warm_gap_hi", abs(gap - IDLE_TIMEOUT_S)))
        else:
            cold = True
        if cold:
            startup = self.rng.lognormal(
                math.log(COLD_START_MEDIAN_S), max(COLD_START_SIGMA, 1e-9)
            )
        else:
            startup = WARM_OVERHEAD_S
        # Rust `||` short-circuits: a forced crash skips the transient draw
        crashed = forced == "crash" or self.rng.bernoulli(TRANSIENT_FAILURE_RATE)
        if crashed:
            perf = None
        else:
            if client not in self.speed:
                self.speed[client] = self.rng.lognormal(
                    0.0, max(CLIENT_SPEED_SIGMA, 1e-9)
                )
            jitter = self.rng.lognormal(0.0, max(INVOCATION_JITTER_SIGMA, 1e-9))
            perf = (self.speed[client], jitter)

        # ---- materialize ----------------------------------------------
        if perf is None:
            end = max(deadline_s, now_s)
            self.warm.pop(client, None)
            return {
                "finished_at": end,
                "billed_s": end - now_s,
                "training_time_s": 0.0,
                "outcome": "crash",
            }
        speed, jitter = perf
        train_s = compute_s * speed * jitter + 2.0 * payload_mb / max(
            NETWORK_MBPS, 1e-9
        )
        if forced == "slow":
            past_deadline = max(deadline_s - now_s - startup, 0.0) * 1.25 + 1.0
            train_s = max(train_s, past_deadline)
        total = startup + train_s
        self.margins.append(("fn_timeout", abs(total - FUNCTION_TIMEOUT_S)))
        if total > FUNCTION_TIMEOUT_S:
            end = now_s + FUNCTION_TIMEOUT_S
            self.warm.pop(client, None)
            return {
                "finished_at": end,
                "billed_s": FUNCTION_TIMEOUT_S,
                "training_time_s": 0.0,
                "outcome": "crash",
            }
        finished_at = now_s + total
        prev = self.warm.get(client)
        self.warm[client] = finished_at if prev is None else max(prev, finished_at)
        self.margins.append(("deadline", abs(finished_at - deadline_s)))
        return {
            "finished_at": finished_at,
            "billed_s": total,
            "training_time_s": train_s,
            "outcome": "ontime" if finished_at <= deadline_s else "late",
        }


def weight_component(produced_round, cardinality, t, tau):
    """paramsvr weight_component (u32 saturating_sub on non-negatives)."""
    if max(t - produced_round, 0) >= tau:
        return None
    damp = min(produced_round / float(max(t, 1)), 1.0)
    return damp * float(cardinality)


def run_continuous(
    seed=42,
    n_clients=12,
    k=3,
    rounds=4,
    inflight_cohorts=2,
    straggler_frac=0.25,
    straggler_slow_frac=0.5,
    base_train_s=25.0,
    window_s=60.0,
    param_count=8,
    tau=2,
):
    """drive_continuous + dispatch_continuous for the Fedlesscan strategy
    (work_fraction 1.0, StalenessAware tau, default ema_alpha/min_pts).

    Returns a dict of everything tests/continuous_golden.rs pins, plus
    the float-boundary margins for the cross-libm safety audit.
    """
    budget = rounds * k
    target = k * max(inflight_cohorts, 1)
    payload_mb = (param_count * 4) / 1e6
    tau_gen = max(tau * k, 1)  # StalenessAware rescale (one round ~ k folds)
    alpha0 = 0.5  # cfg.async_alpha default (preset)

    rng = GaussRng(seed ^ COORD_SEED_MIX)
    faas = Faas(seed)
    hist = HistoryStore(NewHistory)
    all_clients = list(range(n_clients))

    # §VI-A4 forced straggler set, fixed up front (Controller::new)
    forced = {}
    if straggler_frac > 0.0:
        ids = list(range(n_clients))
        rng.shuffle(ids)
        n_strag = rust_round(n_clients * straggler_frac)
        for c in ids[:n_strag]:
            forced[c] = "slow" if rng.bernoulli(straggler_slow_frac) else "crash"

    events = []  # heap of (at_s, seq, client, outcome); seq pins ties
    pending = {}  # seq -> (departed_gen, training_time_s)
    in_flight = {}  # client -> finished_at
    invocations = {}
    state = {"seq": 0, "dispatched": 0}
    generation = 0
    total_cost = 0.0
    window_margins = []

    def expire(now_s):
        for c in [c for c, t in in_flight.items() if not t > now_s]:
            del in_flight[c]

    def dispatch(want, now_s):
        want = min(want, budget - state["dispatched"])
        if want == 0:
            return (0, 0)
        pseudo_round = state["dispatched"] // k
        selected = fedlesscan_select(
            all_clients, hist, pseudo_round, rounds, want, rng, new_path=True
        )
        expire(now_s)
        invoked = [c for c in selected if c not in in_flight]
        skipped = [c for c in selected if c in in_flight]
        gen_now = generation
        n_invoked = 0
        for client in invoked:
            if state["dispatched"] >= budget:
                break
            hist.record_invocation(client)
            invocations[client] = invocations.get(client, 0) + 1
            # work_fraction is 1.0 for FedLesScan (no RNG draw)
            compute_s = base_train_s * 1.0
            deadline = now_s + window_s
            inv = faas.invoke(
                client, now_s, compute_s, payload_mb, deadline, forced.get(client)
            )
            nonlocal total_cost
            total_cost += invocation_cost(inv["billed_s"], margins=faas.margins)
            in_flight[client] = inv["finished_at"]
            seq = state["seq"]
            state["seq"] += 1
            state["dispatched"] += 1
            n_invoked += 1
            pending[seq] = (gen_now, inv["training_time_s"])
            heapq.heappush(
                events, (inv["finished_at"], seq, client, inv["outcome"])
            )
        return (n_invoked, len(skipped))

    def new_window(idx, start_s):
        return {
            "window": idx,
            "start_s": start_s,
            "end_s": start_s + window_s,
            "dispatched": 0,
            "completions": 0,
            "folds": 0,
            "crashes": 0,
            "expired": 0,
            "in_flight_peak": 0,
        }

    windows = []
    win = new_window(0, 0.0)
    failed_since_tick = []
    completions = folds = crashes = expired = late = in_flight_skipped = 0
    now_s = 0.0

    inv0, skip0 = dispatch(target, now_s)
    win["dispatched"] += inv0
    in_flight_skipped += skip0
    win["in_flight_peak"] = max(win["in_flight_peak"], len(pending))

    while events:
        at_s, seq, client, outcome = heapq.heappop(events)
        now_s = at_s
        while now_s >= win["end_s"]:
            window_margins.append(abs(now_s - win["end_s"]))
            windows.append(win)
            start = win["end_s"]
            win = new_window(len(windows), start)
            win["in_flight_peak"] = len(pending)
        window_margins.append(abs(now_s - win["end_s"]))
        departed_gen, training_time_s = pending.pop(seq)
        expire(now_s)
        pseudo_round = completions // k
        win["completions"] += 1
        if outcome == "crash":
            crashes += 1
            win["crashes"] += 1
            hist.record_failure(client, pseudo_round)
            failed_since_tick.append(client)
        else:
            if outcome == "late":
                late += 1
            gen_now = generation
            damp = weight_component(departed_gen + 1, 1, gen_now + 1, tau_gen)
            if damp is None:
                expired += 1
                win["expired"] += 1
                hist.record_failure(client, pseudo_round)
                failed_since_tick.append(client)
            else:
                # the fold itself only moves parameters; the golden pins
                # its bookkeeping (generation bump + history success)
                generation = gen_now + 1
                folds += 1
                win["folds"] += 1
                hist.record_success(client, pseudo_round, training_time_s)
        completions += 1
        if completions % k == 0:
            hist.tick_cooldowns(failed_since_tick)
            failed_since_tick = []
        free = target - len(pending)
        if free > 0:
            inv_d, skip_d = dispatch(free, now_s)
            win["dispatched"] += inv_d
            in_flight_skipped += skip_d
        win["in_flight_peak"] = max(win["in_flight_peak"], len(pending))
    windows.append(win)
    if failed_since_tick:
        hist.tick_cooldowns(failed_since_tick)

    return {
        "seed": seed,
        "windows": windows,
        "duration_s": now_s,
        "dispatched": state["dispatched"],
        "completions": completions,
        "folds": folds,
        "crashes": crashes,
        "expired": expired,
        "late": late,
        "in_flight_skipped": in_flight_skipped,
        "final_generation": generation,
        "total_cost": total_cost,
        "invocations": dict(sorted(invocations.items())),
        "faas_margins": faas.margins,
        "window_margins": window_margins,
    }
