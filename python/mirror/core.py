"""Bit-exact Python mirror of the Rust behaviour-plane path.

Python floats are IEEE-754 doubles, so every f64 op here reproduces the
Rust arithmetic exactly as long as operation order matches. u64 ops are
masked. Used to (a) validate grid-DBSCAN == naive == seed-naive labels,
(b) validate old (unbounded-history) select == new (bounded-history)
select, and (c) generate the pinned goldens for tests/goldens.rs.
"""

import itertools
import math

MASK = (1 << 64) - 1


def rotl(x, k):
    return ((x << k) | (x >> (64 - k))) & MASK


class Rng:
    """xoshiro256** seeded via SplitMix64 (util/rng.rs)."""

    def __init__(self, seed):
        sm = seed & MASK
        s = []
        for _ in range(4):
            sm = (sm + 0x9E3779B97F4A7C15) & MASK
            z = sm
            z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
            z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
            s.append(z ^ (z >> 31))
        self.s = s

    def next_u64(self):
        s = self.s
        result = (rotl((s[1] * 5) & MASK, 7) * 9) & MASK
        t = (s[1] << 17) & MASK
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = rotl(s[3], 45)
        return result

    def f64(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def below(self, n):
        assert n > 0
        return int(self.f64() * float(n)) % n

    def range_f64(self, lo, hi):
        return lo + self.f64() * (hi - lo)

    def bernoulli(self, p):
        return self.f64() < p

    def shuffle(self, xs):
        for i in range(len(xs) - 1, 0, -1):
            j = self.below(i + 1)
            xs[i], xs[j] = xs[j], xs[i]

    def sample(self, xs, k):
        pool = list(xs)
        self.shuffle(pool)
        return pool[:k]

    def sample_indices(self, n, k):
        if k >= n:
            pool = list(range(n))
            self.shuffle(pool)
            return pool
        swapped = {}
        out = []
        for i in range(k):
            j = i + self.below(n - i)
            vj = swapped.get(j, j)
            vi = swapped.get(i, i)
            swapped[j] = vi
            out.append(vj)
        return out


class GaussRng(Rng):
    """Rng + the Box–Muller `normal()`/`lognormal()` pair (util/rng.rs),
    including the cached spare deviate — the cache is part of the RNG
    stream contract: every second `normal()` consumes no uniforms."""

    def __init__(self, seed):
        super().__init__(seed)
        self.spare_normal = None

    def normal(self):
        if self.spare_normal is not None:
            z = self.spare_normal
            self.spare_normal = None
            return z
        u1 = max(self.f64(), 1e-300)
        u2 = self.f64()
        r = math.sqrt(-2.0 * math.log(u1))
        theta = 2.0 * math.pi * u2
        self.spare_normal = r * math.sin(theta)
        return r * math.cos(theta)

    def lognormal(self, mu, sigma):
        return math.exp(mu + sigma * self.normal())


def rust_round(x):
    """f64::round — half away from zero (non-negative domain here)."""
    assert x >= 0.0
    f = math.floor(x)
    r = x - f
    if r > 0.5:
        return f + 1
    if r < 0.5:
        return f
    return f + 1


# ---------------------------------------------------------------- features

def ema(values, alpha):
    if not values:
        return 0.0
    acc = values[0]
    for x in values[1:]:
        acc = alpha * x + (1.0 - alpha) * acc
    return acc


def missed_round_ema(missed, current_round, alpha):
    if current_round == 0:
        return 0.0
    ratios = [r / float(current_round) for r in missed]
    return ema(ratios, alpha)


# ---------------------------------------------------------------- history

HISTORY_WINDOW = 64
HISTORY_EMA_ALPHA = 0.5


class OldHistory:
    """Seed ClientHistory: unbounded vectors."""

    def __init__(self):
        self.training_times = []
        self.missed_rounds = []
        self.cooldown = 0
        self.invocations = 0
        self.successes = 0

    def is_rookie(self):
        return self.invocations == 0

    def is_straggler(self):
        return self.cooldown > 0

    def t_feature(self, alpha):
        return ema(self.training_times, alpha)

    def m_feature(self, rnd, alpha):
        return missed_round_ema(self.missed_rounds, rnd, alpha)


class NewHistory:
    """Bounded ClientHistory: incremental EMA + recency windows."""

    def __init__(self):
        self.t_ema = 0.0
        self.t_sum = 0.0
        self.times_count = 0
        self.recent_times = []
        self.missed_recent = []
        self.missed_evicted = 0
        self.cooldown = 0
        self.invocations = 0
        self.successes = 0

    def is_rookie(self):
        return self.invocations == 0

    def is_straggler(self):
        return self.cooldown > 0

    def note_time(self, t):
        if self.times_count == 0:
            self.t_ema = t
        else:
            self.t_ema = HISTORY_EMA_ALPHA * t + (1.0 - HISTORY_EMA_ALPHA) * self.t_ema
        self.t_sum += t
        self.times_count += 1
        if len(self.recent_times) == HISTORY_WINDOW:
            self.recent_times.pop(0)
        self.recent_times.append(t)

    def note_miss(self, rnd):
        if rnd in self.missed_recent:
            return
        if len(self.missed_recent) == HISTORY_WINDOW:
            self.missed_recent.pop(0)
            self.missed_evicted += 1
        self.missed_recent.append(rnd)

    def unmiss(self, rnd):
        self.missed_recent = [r for r in self.missed_recent if r != rnd]

    def t_feature(self, alpha):
        if alpha == HISTORY_EMA_ALPHA:
            return self.t_ema
        return ema(self.recent_times, alpha)

    def m_feature(self, rnd, alpha):
        return missed_round_ema(self.missed_recent, rnd, alpha)


class HistoryStore:
    def __init__(self, cls):
        self.cls = cls
        self.map = {}

    def entry(self, cid):
        if cid not in self.map:
            self.map[cid] = self.cls()
        return self.map[cid]

    def view(self, cid):
        return self.map.get(cid) or self.cls()

    def record_invocation(self, cid):
        self.entry(cid).invocations += 1

    def record_success(self, cid, rnd, t):
        h = self.entry(cid)
        h.cooldown = 0
        h.successes += 1
        if self.cls is OldHistory:
            h.training_times.append(t)
            h.missed_rounds = [r for r in h.missed_rounds if r != rnd]
        else:
            h.note_time(t)
            h.unmiss(rnd)

    def record_failure(self, cid, rnd):
        h = self.entry(cid)
        if self.cls is OldHistory:
            if rnd not in h.missed_rounds:
                h.missed_rounds.append(rnd)
        else:
            h.note_miss(rnd)
        h.cooldown = 1 if h.cooldown == 0 else h.cooldown * 2

    def record_late_completion(self, cid, rnd, t):
        h = self.entry(cid)
        if self.cls is OldHistory:
            h.missed_rounds = [r for r in h.missed_rounds if r != rnd]
            h.training_times.append(t)
        else:
            h.unmiss(rnd)
            h.note_time(t)

    def tick_cooldowns(self, failed):
        fs = set(failed)
        for cid, h in self.map.items():
            if h.cooldown > 0 and cid not in fs:
                h.cooldown -= 1


# ---------------------------------------------------------------- clustering

NOISE = -1
UNVISITED = -2


def dist2(a, b):
    s = 0.0
    for x, y in zip(a, b):
        s += (x - y) * (x - y)
    return s


def dbscan_seed(points, eps, min_pts):
    """The seed implementation, duplicated frontier and all."""
    n = len(points)
    eps2 = eps * eps
    labels = [UNVISITED] * n
    cluster = 0

    def neighbours(i):
        return [j for j in range(n) if dist2(points[i], points[j]) <= eps2]

    for i in range(n):
        if labels[i] != UNVISITED:
            continue
        nb = neighbours(i)
        if len(nb) < min_pts:
            labels[i] = NOISE
            continue
        labels[i] = cluster
        frontier = list(nb)
        while frontier:
            j = frontier.pop()
            if labels[j] == NOISE:
                labels[j] = cluster
            if labels[j] != UNVISITED:
                continue
            labels[j] = cluster
            nb_j = neighbours(j)
            if len(nb_j) >= min_pts:
                frontier.extend(nb_j)
        cluster += 1
    return labels


def expand(n, min_pts, neighbours):
    """New shared expansion (deduped frontier). Returns (labels, peak)."""
    labels = [UNVISITED] * n
    queued = [False] * n
    cluster = 0
    peak = 0
    frontier = []

    def enqueue(nb):
        nonlocal peak
        for j in nb:
            if not queued[j] and (labels[j] == UNVISITED or labels[j] == NOISE):
                queued[j] = True
                frontier.append(j)
        peak = max(peak, len(frontier))

    for i in range(n):
        if labels[i] != UNVISITED:
            continue
        nb = neighbours(i)
        if len(nb) < min_pts:
            labels[i] = NOISE
            continue
        labels[i] = cluster
        enqueue(nb)
        while frontier:
            j = frontier.pop()
            if labels[j] == NOISE:
                labels[j] = cluster
                continue
            assert labels[j] == UNVISITED
            labels[j] = cluster
            nb_j = neighbours(j)
            if len(nb_j) >= min_pts:
                enqueue(nb_j)
        cluster += 1
    return labels, peak


def dbscan_naive_new(points, eps, min_pts):
    n = len(points)
    eps2 = eps * eps
    return expand(
        n, min_pts,
        lambda i: [j for j in range(n) if dist2(points[i], points[j]) <= eps2],
    )[0]


MAX_CELL = 1.0e12


def cell_key(p, eps):
    key = []
    for x in p:
        q = x / eps
        if not math.isfinite(q):
            return None
        c = math.floor(q)
        if abs(c) > MAX_CELL:
            return None
        key.append(int(c))
    return tuple(key)


def grid_build(points, eps):
    if not (math.isfinite(eps) and eps > 0.0):
        return None
    dim = len(points[0]) if points else 0
    if any(len(p) != dim for p in points):
        return None
    cells = {}
    for i, p in enumerate(points):
        k = cell_key(p, eps)
        if k is None:
            return None
        cells.setdefault(k, []).append(i)
    return cells


def grid_neighbours(points, cells, eps, i):
    # Visit order differs from the Rust odometer but the result is the
    # same sorted set: cells partition the points, so no duplicates.
    p = points[i]
    eps2 = eps * eps
    center = cell_key(p, eps)
    out = []
    for offs in itertools.product((-1, 0, 1), repeat=len(center)):
        key = tuple(c + o for c, o in zip(center, offs))
        for j in cells.get(key, ()):
            if dist2(p, points[j]) <= eps2:
                out.append(j)
    out.sort()
    return out


def dbscan_grid(points, eps, min_pts):
    cells = grid_build(points, eps)
    if cells is None:
        return dbscan_naive_new(points, eps, min_pts)
    return expand(
        len(points), min_pts,
        lambda i: grid_neighbours(points, cells, eps, i),
    )[0]


def relabel_outliers(labels):
    mx = max(labels) if labels else NOISE
    noise_id = mx + 1
    any_noise = False
    for i, l in enumerate(labels):
        if l == NOISE:
            labels[i] = noise_id
            any_noise = True
    return (mx + 1) + (1 if any_noise else 0)


def calinski_harabasz(points, labels, k):
    n = len(points)
    if k < 2 or k >= n:
        return float("-inf")
    dim = len(points[0])
    g = [0.0] * dim
    for p in points:
        for d in range(dim):
            g[d] += p[d]
    for d in range(dim):
        g[d] /= float(n)
    cent = [[0.0] * dim for _ in range(k)]
    sizes = [0] * k
    for p, l in zip(points, labels):
        sizes[l] += 1
        for d in range(dim):
            cent[l][d] += p[d]
    for c, s in zip(cent, sizes):
        if s > 0:
            for d in range(dim):
                c[d] /= float(s)
    ssb = 0.0
    for c, s in zip(cent, sizes):
        d2 = 0.0
        for a, b in zip(c, g):
            d2 += (a - b) * (a - b)
        ssb += float(s) * d2
    ssw = 0.0
    for p, l in zip(points, labels):
        c = cent[l]
        t = 0.0
        for a, b in zip(p, c):
            t += (a - b) * (a - b)
        ssw += t
    if ssw <= 2.220446049250313e-16:  # f64::EPSILON
        return float("inf") if ssb > 0.0 else 0.0
    return (ssb / (k - 1.0)) / (ssw / (n - float(k)))


EPS_SAMPLE_MAX = 512
EPS_SAMPLE_SEED = 0x5EED_CA11_AB5A_7E57


def cluster_clients(points, min_pts, dbscan_fn):
    n = len(points)
    if n == 0:
        return [], 0
    if n == 1:
        return [0], 1
    if n <= EPS_SAMPLE_MAX:
        sample = list(range(n))
    else:
        rng = Rng(EPS_SAMPLE_SEED ^ n)
        picked = rng.sample_indices(n, EPS_SAMPLE_MAX)
        picked.sort()
        sample = picked
    m = len(sample)
    dists = []
    for i in range(m):
        for j in range(i + 1, m):
            dists.append(math.sqrt(dist2(points[sample[i]], points[sample[j]])))
    dists.sort()

    def quantile(q):
        idx = rust_round((len(dists) - 1) * q)
        return dists[idx]

    candidates = [quantile(q) for q in (0.05, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.75)]
    candidates = [e for e in candidates if e > 0.0]
    # Vec::dedup — consecutive duplicates only
    deduped = []
    for e in candidates:
        if not deduped or deduped[-1] != e:
            deduped.append(e)
    candidates = deduped
    if not candidates:
        return [0] * n, 1

    best = None
    for eps in candidates:
        labels = dbscan_fn(points, eps, min_pts)
        k = relabel_outliers(labels)
        if k < 2 or k >= n:
            continue
        score = calinski_harabasz(points, labels, k)
        if best is None or score > best[0]:
            best = (score, labels, k)
    if best is None:
        return [0] * n, 1
    return best[1], best[2]


# ---------------------------------------------------------------- selection

COHORT_MAX = 1024
COHORT_STRATA = 16


SAMPLE_SWITCH_MIN = 1024  # strategy/mod.rs: sparse-sampler threshold


def random_sample(clients, k, rng):
    if len(clients) > SAMPLE_SWITCH_MIN:
        return [clients[i] for i in rng.sample_indices(len(clients), k)]
    return rng.sample(clients, k)


def tier_partition(all_clients, hist):
    rookies, participants, stragglers = [], [], []
    for c in all_clients:
        h = hist.view(c)
        if h.is_rookie():
            rookies.append(c)
        elif h.is_straggler():
            stragglers.append(c)
        else:
            participants.append(c)
    return rookies, participants, stragglers


def sample_clustered(participants, total_ema, labels, n_clusters, take, hist,
                     rnd, max_rounds, rng):
    if n_clusters == 0:
        return random_sample(participants, take, rng)
    cluster_sum = [0.0] * n_clusters
    cluster_cnt = [0] * n_clusters
    for i, l in enumerate(labels):
        cluster_sum[l] += total_ema[i]
        cluster_cnt[l] += 1
    order = sorted(
        range(n_clusters),
        key=lambda x: cluster_sum[x] / float(max(cluster_cnt[x], 1)),
    )
    members = [[] for _ in range(n_clusters)]
    for i, l in enumerate(labels):
        members[l].append(participants[i])
    for m in members:
        m.sort(key=lambda c: (hist.view(c).invocations, c))
    progress = 0.0 if max_rounds == 0 else rnd / float(max_rounds)
    start = min(int(progress * float(n_clusters)), n_clusters - 1)
    picked = []
    for step in range(n_clusters):
        cl = order[(start + step) % n_clusters]
        for c in members[cl]:
            picked.append(c)
            if len(picked) == take:
                return picked
    return picked


def stratified_cohort(participants, hist, take, rng):
    assert take < len(participants)
    keys = [hist.view(c).t_ema for c in participants]
    lo = float("inf")
    hi = float("-inf")
    for x in keys:
        lo = min(lo, x)
        hi = max(hi, x)
    if not hi > lo:
        return random_sample(participants, take, rng)
    buckets = [[] for _ in range(COHORT_STRATA)]
    for c, x in zip(participants, keys):
        b = min(int((x - lo) / (hi - lo) * float(COHORT_STRATA)), COHORT_STRATA - 1)
        buckets[b].append(c)
    n = len(participants)
    quota = [len(b) * take // n for b in buckets]
    rem = sorted(
        [((len(b) * take) % n, i) for i, b in enumerate(buckets)],
        key=lambda t: (-t[0], t[1]),
    )
    short = take - sum(quota)
    for _, i in rem:
        if short == 0:
            break
        if quota[i] < len(buckets[i]):
            quota[i] += 1
            short -= 1
    while short > 0:
        progressed = False
        for i in range(COHORT_STRATA):
            if short > 0 and quota[i] < len(buckets[i]):
                quota[i] += 1
                short -= 1
                progressed = True
        if not progressed:
            break
    cohort = []
    for bucket, q in zip(buckets, quota):
        if q > 0:
            cohort.extend(random_sample(bucket, q, rng))
    return cohort


def fedlesscan_select(all_clients, hist, rnd, max_rounds, k, rng,
                      new_path, alpha=0.5, min_pts=2):
    rookies, participants, stragglers = tier_partition(all_clients, hist)
    if len(rookies) >= k:
        return random_sample(rookies, k, rng)
    selected = list(rookies)
    need = k - len(selected)
    n_cluster = min(need, len(participants))
    n_straggler = min(need - n_cluster, len(stragglers))
    straggler_picks = random_sample(stragglers, n_straggler, rng)
    if n_cluster > 0:
        if new_path:
            cohort_cap = max(COHORT_MAX, n_cluster * 4)
            if len(participants) > cohort_cap:
                cohort = stratified_cohort(participants, hist, cohort_cap, rng)
            else:
                cohort = participants
            dbscan_fn = dbscan_grid
        else:
            cohort = participants
            dbscan_fn = dbscan_seed
        feats = []
        for c in cohort:
            h = hist.view(c)
            feats.append((h.t_feature(alpha), h.m_feature(max(rnd, 1), alpha)))
        max_t = 0.0
        for t, _ in feats:
            max_t = max(max_t, t)
        max_t = max(max_t, 1e-9)
        points = [[t, m * max_t] for t, m in feats]
        labels, n_clusters = cluster_clients(points, min_pts, dbscan_fn)
        total_ema = [t + m * max_t for t, m in feats]
        selected.extend(
            sample_clustered(cohort, total_ema, labels, n_clusters, n_cluster,
                             hist, rnd, max_rounds, rng)
        )
    selected.extend(straggler_picks)
    return selected[:k]
