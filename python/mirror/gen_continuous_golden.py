"""Generate the pinned constants for rust/tests/continuous_golden.rs.

Runs the continuous-mode mirror (continuous.py) on the golden config —
mnist preset shrunk to 12 clients / k=3 / 4-round budget / 2 cohorts,
Straggler(25), Fedlesscan defaults, seed 42 — twice, asserting replay
determinism, then audits every float comparison the timeline made for
cross-libm safety and prints the Rust assertions.

Float-boundary audit: the only libm-dependent ops in the timeline are
exp/ln/sin/cos inside the log-normal draws (sqrt is correctly rounded
everywhere). Any comparison whose sides could differ by an ulp across
libms must clear a 1e-6 margin. Margins that are *exactly* 0.0 are safe
by construction, not luck: they arise from identities whose two sides
are the same arithmetic on the same floats (a crash billed to
`deadline = now + window_s` landing on a window boundary that is the
same `start + window_s` chain, or a warm-pool gap of `t - t`), so they
compare equal bit-for-bit on every platform. If a *nonzero* margin ever
falls under 1e-6, bump the golden seed and regenerate.

Usage: cd python/mirror && python3 gen_continuous_golden.py
"""

from continuous import run_continuous

MARGIN = 1e-6


def main():
    a = run_continuous(seed=42)
    b = run_continuous(seed=42)
    for key in (
        "dispatched",
        "completions",
        "folds",
        "crashes",
        "expired",
        "late",
        "in_flight_skipped",
        "final_generation",
        "duration_s",
        "total_cost",
        "windows",
        "invocations",
    ):
        assert a[key] == b[key], f"replay drift in {key}"

    worst = {}
    for kind, m in a["faas_margins"] + [("window", m) for m in a["window_margins"]]:
        if m == 0.0:
            continue  # exact identity — bit-equal on every platform
        assert m > MARGIN, f"float boundary too close: {kind} margin {m}"
        worst[kind] = min(worst.get(kind, float("inf")), m)
    print("# float-boundary audit (worst nonzero margin per comparison):")
    for kind, m in sorted(worst.items()):
        print(f"#   {kind}: {m:.6g}")
    zeros = sum(
        1 for _, m in a["faas_margins"] if m == 0.0
    ) + sum(1 for m in a["window_margins"] if m == 0.0)
    print(f"#   exact-identity hits (safe by construction): {zeros}")

    print()
    print("// ---- paste into rust/tests/continuous_golden.rs ----")
    print(f"assert_eq!(r.dispatched, {a['dispatched']});")
    print(f"assert_eq!(r.completions, {a['completions']});")
    print(f"assert_eq!(r.folds, {a['folds']});")
    print(f"assert_eq!(r.crashes, {a['crashes']});")
    print(f"assert_eq!(r.expired, {a['expired']});")
    print(f"assert_eq!(r.late, {a['late']});")
    print(f"assert_eq!(r.in_flight_skipped, {a['in_flight_skipped']});")
    print(f"assert_eq!(r.final_generation, {a['final_generation']});")
    print(f"assert!((r.duration_s - {a['duration_s']!r}).abs() < 1e-6);")
    print(f"assert!((r.total_cost - {a['total_cost']!r}).abs() < 1e-9);")
    print(f"assert_eq!(r.windows.len(), {len(a['windows'])});")
    rows = ", ".join(
        "({}, {}, {}, {}, {}, {})".format(
            w["dispatched"],
            w["completions"],
            w["folds"],
            w["crashes"],
            w["expired"],
            w["in_flight_peak"],
        )
        for w in a["windows"]
    )
    print(f"let want = [{rows}];")
    total_inv = sum(a["invocations"].values())
    print(f"// per-client invocation counts sum: {total_inv}")
    print(f"// updates/s = {a['folds'] / a['duration_s']!r}")
    print(f"// effective update ratio = {a['folds'] / a['completions']!r}")


if __name__ == "__main__":
    main()
