"""Validation battery over the mirror:

1. seed-naive vs deduped-naive vs grid DBSCAN: identical labels on
   random clouds (incl. degenerate cases) — supports the Rust claim that
   the refactor is label-preserving, not just partition-equivalent.
2. old (unbounded) vs new (bounded) FedLesScan selection: identical
   selections + identical RNG stream consumption over random multi-round
   drives at paper scale.
3. frontier peak stays <= n on dense blobs (the regression claim).
"""

import core
from core import (Rng, dbscan_seed, dbscan_naive_new, dbscan_grid, expand,
                  dist2, HistoryStore, OldHistory, NewHistory,
                  fedlesscan_select, tier_partition)

fails = 0


def check(cond, msg):
    global fails
    if not cond:
        fails += 1
        print("FAIL:", msg)


# ---- 1. DBSCAN triple equivalence ------------------------------------
CASES = 300
for case in range(CASES):
    rng = Rng(case ^ 0x5A5A)
    n = 1 + rng.below(80)
    dim = 1 + rng.below(3)
    style = rng.below(4)
    pts = []
    for i in range(n):
        if style == 0:  # uniform cloud
            pts.append([rng.range_f64(-10.0, 10.0) for _ in range(dim)])
        elif style == 1:  # blobs
            c = float(rng.below(4)) * 8.0
            pts.append([c + rng.range_f64(-0.7, 0.7) for _ in range(dim)])
        elif style == 2:  # all identical
            pts.append([3.25] * dim)
        else:  # exact grid-boundary lattice: multiples of eps
            pts.append([float(rng.below(6)) * 0.5 for _ in range(dim)])
    eps = [0.5, 0.25, 1.0, 5.0, 100.0][rng.below(5)]  # incl. eps spanning many cells
    min_pts = 1 + rng.below(4)
    a = dbscan_seed(pts, eps, min_pts)
    b = dbscan_naive_new(pts, eps, min_pts)
    c = dbscan_grid(pts, eps, min_pts)
    check(a == b, f"case {case}: seed vs dedup mismatch {a} {b}")
    check(a == c, f"case {case}: seed vs grid mismatch n={n} eps={eps} mp={min_pts}")
print(f"dbscan triple equivalence: {CASES} cases done")

# dense blob frontier bound
n = 400
pts = [[0.01 * __import__('math').sin(i * 0.618),
        0.01 * __import__('math').cos(i * 0.618)] for i in range(n)]
labels, peak = expand(
    n, 2, lambda i: [j for j in range(n) if dist2(pts[i], pts[j]) <= 1.0])
check(all(l == 0 for l in labels), "dense blob: one cluster")
check(peak <= n, f"dense blob: peak {peak} > n")
seed_labels = dbscan_seed(pts, 1.0, 2)
check(labels == seed_labels, "dense blob: dedup changed labels")
print(f"dense blob: peak frontier {peak} (n={n})")

# ---- 2. old vs new selection equivalence ------------------------------
DRIVES = 60
for case in range(DRIVES):
    drive_rng = Rng(case ^ 0xD21)
    n = 10 + drive_rng.below(80)
    k = 1 + drive_rng.below(max(n // 2, 1))
    max_rounds = 20
    rounds = 12
    old = HistoryStore(OldHistory)
    new = HistoryStore(NewHistory)
    rng_old = Rng(1000 + case)
    rng_new = Rng(1000 + case)
    clients = list(range(n))
    prev_failed = []
    for r in range(rounds):
        sel_old = fedlesscan_select(clients, old, r, max_rounds, k, rng_old, False)
        sel_new = fedlesscan_select(clients, new, r, max_rounds, k, rng_new, True)
        check(sel_old == sel_new,
              f"drive {case} round {r}: {sel_old} vs {sel_new}")
        check(rng_old.s == rng_new.s,
              f"drive {case} round {r}: RNG streams diverged")
        # late completions correct half of last round's failures
        for c in prev_failed:
            if (c + r) % 2 == 0:
                t = 60.0 + float(c)
                old.record_late_completion(c, r - 1, t)
                new.record_late_completion(c, r - 1, t)
        failed = []
        for c in sel_old:
            old.record_invocation(c)
            new.record_invocation(c)
            if (c * 7 + r) % 5 == 0:
                old.record_failure(c, r)
                new.record_failure(c, r)
                failed.append(c)
            else:
                t = 5.0 + float((c * 13 + r * 3) % 40) * 1.5
                old.record_success(c, r, t)
                new.record_success(c, r, t)
        old.tick_cooldowns(failed)
        new.tick_cooldowns(failed)
        prev_failed = failed
    ro, po, so = tier_partition(clients, old)
    rn, pn, sn = tier_partition(clients, new)
    check((ro, po, so) == (rn, pn, sn), f"drive {case}: tier mismatch")
print(f"old-vs-new selection: {DRIVES} drives x 12 rounds identical")

print("FAILURES:", fails)
