"""Bit-exact Python mirror of the quantized parameter plane.

Mirrors, op for op, ``rust/src/params/shard.rs`` (``ShardLayout``) and
``rust/src/params/quant.rs`` (dense/top-k int8 quantization and the
error-feedback residual). Python floats are IEEE-754 doubles; every f32
op is emulated by computing in double and rounding the result back to
f32 via a struct round-trip — exact for +, -, *, / of f32 operands
(single ops evaluated in double then rounded are correctly rounded).
``f32::round`` is half-AWAY-from-zero, not Python's banker's rounding,
so it is emulated explicitly.

Run ``gen_params_golden.py`` to (re)generate the pinned constants in
``rust/tests/quant_golden.rs``.
"""

import math
import struct

QMAX = 127.0


def f32(x):
    """Round an f64 to the nearest f32 (returned as Python float)."""
    return struct.unpack("<f", struct.pack("<f", x))[0]


def f32_bits(x):
    return struct.unpack("<I", struct.pack("<f", x))[0]


def rust_round_f32(q):
    """f32::round — half away from zero (q is f32-valued, |q| < 2**52)."""
    if q >= 0.0:
        return math.floor(q + 0.5)
    return math.ceil(q - 0.5)


class ShardLayout:
    """params/shard.rs ShardLayout: balanced chunk boundaries."""

    def __init__(self, length, shards):
        self.len = length
        self.shards_n = max(1, min(shards, max(length, 1)))

    def range(self, i):
        base = self.len // self.shards_n
        extra = self.len % self.shards_n
        start = i * base + min(i, extra)
        size = base + (1 if i < extra else 0)
        return range(start, start + size)

    def ranges(self):
        return (self.range(i) for i in range(self.shards_n))

    def shard_of(self, elem):
        base = self.len // self.shards_n
        extra = self.len % self.shards_n
        boundary = extra * (base + 1)
        if elem < boundary:
            return elem // (base + 1)
        return extra + (elem - boundary) // base


def shard_scale(values):
    m = 0.0
    for v in values:
        m = max(m, abs(v))  # f32 abs/max are exact
    if m == 0.0:
        return 0.0
    return f32(m / QMAX)


def encode_one(v, scale):
    if scale == 0.0:
        return 0
    q = f32(v / scale)
    c = rust_round_f32(q)
    return int(max(-127, min(127, c)))


def quantize(values, layout):
    assert len(values) == layout.len
    scales, data = [], []
    for r in layout.ranges():
        shard = values[r.start : r.stop]
        scale = shard_scale(shard)
        scales.append(scale)
        data.extend(encode_one(v, scale) for v in shard)
    return {"len": len(values), "scales": scales, "data": data, "indices": None}


def topk_keep(shard_len, frac):
    return max(1, min(math.ceil(shard_len * frac), max(shard_len, 1)))


def quantize_topk(values, layout, frac):
    assert len(values) == layout.len and 0.0 < frac <= 1.0
    scales, data, indices = [], [], []
    for r in layout.ranges():
        shard = values[r.start : r.stop]
        keep = topk_keep(len(shard), frac)
        order = sorted(range(len(shard)), key=lambda a: (-abs(shard[a]), a))
        kept = sorted(order[:keep])
        scale = shard_scale(shard)
        scales.append(scale)
        for local in kept:
            indices.append(r.start + local)
            data.append(encode_one(shard[local], scale))
    return {"len": len(values), "scales": scales, "data": data, "indices": indices}


def dequantize(q, layout):
    out = [0.0] * q["len"]
    if q["indices"] is None:
        pos = 0
        for i, r in enumerate(layout.ranges()):
            scale = q["scales"][i]
            for e in r:
                out[e] = f32(float(q["data"][pos]) * scale)
                pos += 1
    else:
        for ix, c in zip(q["indices"], q["data"]):
            out[ix] = f32(float(c) * q["scales"][layout.shard_of(ix)])
    return out


def wire_bytes(q):
    return (
        len(q["data"])
        + len(q["scales"]) * 4
        + (0 if q["indices"] is None else len(q["indices"]) * 4)
    )


class ErrorFeedback:
    def __init__(self, length):
        self.residual = [0.0] * length

    def encode(self, update, layout, topk=None):
        compensated = [f32(u + e) for u, e in zip(update, self.residual)]
        if topk is None:
            q = quantize(compensated, layout)
        else:
            q = quantize_topk(compensated, layout, topk)
        dq = dequantize(q, layout)
        self.residual = [f32(v - d) for v, d in zip(compensated, dq)]
        return q


if __name__ == "__main__":
    # self-check: roundtrip error bound + EF telescoping on a ramp
    p = 1031
    v = [f32(((i % 31) - 15.0) * 0.013) for i in range(p)]
    for shards in (1, 4, 17):
        layout = ShardLayout(p, shards)
        q = quantize(v, layout)
        dq = dequantize(q, layout)
        for i in range(p):
            bound = q["scales"][layout.shard_of(i)] * 0.5 * 1.0001 + 1.2e-7
            assert abs(v[i] - dq[i]) <= bound, (shards, i)
    layout = ShardLayout(64, 4)
    vv = [0.0] * 64
    vv[0], vv[1] = 1.0, 0.002
    ef = ErrorFeedback(64)
    transmitted = 0.0
    for _ in range(8):
        transmitted += dequantize(ef.encode(vv, layout), layout)[1]
    assert abs(transmitted - 8 * 0.002) <= 0.5 / QMAX + 1e-6, transmitted
    print("quantplane mirror self-checks pass")
