"""Generate the pinned selection goldens for tests/goldens.rs.

Computed with the OLD (pre-refactor, unbounded-history, seed-DBSCAN)
semantics and cross-checked equal under the NEW path — so the Rust test
pins refactor-is-behaviour-preserving, not implementation echo."""

import core
from core import (Rng, HistoryStore, OldHistory, NewHistory,
                  fedlesscan_select, tier_partition)

PRESETS = [
    # (label, n, k, max_rounds, drive_rounds, seed)
    ("mnist_shape", 60, 12, 20, 10, 42),
    ("femnist_shape", 50, 10, 15, 8, 1337),
    ("speech_shape", 60, 15, 20, 10, 7),
]


def drive(n, k, max_rounds, rounds, seed, cls, new_path):
    hist = HistoryStore(cls)
    rng = Rng(seed)
    clients = list(range(n))
    sels = []
    prev_failed = []
    for r in range(rounds):
        sel = fedlesscan_select(clients, hist, r, max_rounds, k, rng, new_path)
        sels.append(sel)
        for c in prev_failed:
            if (c + r) % 2 == 0:
                hist.record_late_completion(c, r - 1, 60.0 + float(c))
        failed = []
        for c in sel:
            hist.record_invocation(c)
            if (c * 7 + r) % 5 == 0:
                hist.record_failure(c, r)
                failed.append(c)
            else:
                hist.record_success(c, r, 5.0 + float((c * 13 + r * 3) % 40) * 1.5)
        hist.tick_cooldowns(failed)
        prev_failed = failed
    tiers = tier_partition(clients, hist)
    return sels, tiers


def fmt(v):
    return "&[" + ", ".join(str(x) for x in v) + "]"


for label, n, k, max_rounds, rounds, seed in PRESETS:
    old_sels, old_tiers = drive(n, k, max_rounds, rounds, seed, OldHistory, False)
    new_sels, new_tiers = drive(n, k, max_rounds, rounds, seed, NewHistory, True)
    assert old_sels == new_sels, f"{label}: selection drifted under the new path"
    assert old_tiers == new_tiers, f"{label}: tiers drifted under the new path"
    print(f"// {label}: n={n} k={k} max_rounds={max_rounds} seed={seed}")
    print(f"const {label.upper()}_SELECTIONS: &[&[ClientId]] = &[")
    for sel in old_sels:
        print(f"    {fmt(sel)},")
    print("];")
    r, p, s = old_tiers
    print(f"const {label.upper()}_ROOKIES: &[ClientId] = {fmt(r)};")
    print(f"const {label.upper()}_PARTICIPANTS: &[ClientId] = {fmt(p)};")
    print(f"const {label.upper()}_STRAGGLERS: &[ClientId] = {fmt(s)};")
    print()
print("// all presets: old path == new path verified")
