"""L2 correctness: model zoo shapes, training dynamics, FedProx semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import build_bundle
from compile.scales import MODELS, get_scale

SMOKE = {name: build_bundle(name, "smoke") for name in MODELS}


def _learnable_batch(bundle, n, seed=0):
    """Synthetic class-separable data matching the model's input spec."""
    ms = bundle.ms
    key = jax.random.key(seed)
    ky, kx = jax.random.split(key)
    y = jax.random.randint(ky, (n,), 0, ms.num_classes, jnp.int32)
    if ms.input_dtype == "i32":
        # token sequences whose last token leaks the label
        x = jax.random.randint(kx, (n, *ms.input_shape), 0, ms.num_classes, jnp.int32)
        x = x.at[:, -1].set(y)
    else:
        base = jax.random.normal(kx, (ms.num_classes, *ms.input_shape)) * 2.0
        noise = jax.random.normal(jax.random.fold_in(kx, 1), (n, *ms.input_shape))
        x = base[y] + 0.3 * noise
    return x, y


@pytest.mark.parametrize("name", MODELS)
def test_param_count_matches_init_bin_len(name):
    b = SMOKE[name]
    assert b.init_flat.shape == (b.param_count,)
    assert b.init_flat.dtype == jnp.float32
    assert bool(jnp.all(jnp.isfinite(b.init_flat)))


@pytest.mark.parametrize("name", MODELS)
def test_logits_shape(name):
    b = SMOKE[name]
    x, _ = _learnable_batch(b, 4)
    logits = b.arch.apply(b.unravel(b.init_flat), x, key=jax.random.key(0), train=True)
    assert logits.shape == (4, b.ms.num_classes)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("name", MODELS)
def test_train_round_decreases_loss(name):
    """Two consecutive local rounds on separable data must reduce loss."""
    b = SMOKE[name]
    ms = b.ms
    x, y = _learnable_batch(b, ms.shard_size, seed=3)
    p = b.init_flat
    m = v = jnp.zeros_like(p)
    t = jnp.float32(0)
    full = jnp.int32(ms.steps_per_round)
    train = jax.jit(b.train)
    p1, m1, v1, t1, loss1 = train(p, m, v, t, x, y, jnp.int32(1), full)
    p2, _, _, t2, loss2 = train(p1, m1, v1, t1, x, y, jnp.int32(2), full)
    assert float(loss2) < float(loss1)
    assert float(t1) == ms.steps_per_round
    assert float(t2) == 2 * ms.steps_per_round
    assert not np.allclose(np.asarray(p1), np.asarray(p))


@pytest.mark.parametrize("name", ["mnist", "shakespeare"])
def test_num_steps_zero_is_identity(name):
    """Partial-work mask: num_steps=0 must leave params/opt-state unchanged."""
    b = SMOKE[name]
    ms = b.ms
    x, y = _learnable_batch(b, ms.shard_size)
    p = b.init_flat
    m = v = jnp.zeros_like(p)
    p1, m1, v1, t1, loss = jax.jit(b.train)(
        p, m, v, jnp.float32(0), x, y, jnp.int32(0), jnp.int32(0)
    )
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p))
    np.testing.assert_array_equal(np.asarray(m1), 0)
    assert float(t1) == 0.0


def test_partial_work_fewer_steps_changes_less():
    b = SMOKE["mnist"]
    ms = b.ms
    x, y = _learnable_batch(b, ms.shard_size)
    p = b.init_flat
    z = jnp.zeros_like(p)
    run = lambda k: jax.jit(b.train)(
        p, z, z, jnp.float32(0), x, y, jnp.int32(5), jnp.int32(k)
    )
    p_small, *_ = run(1)
    p_full, *_, tfull, _ = run(ms.steps_per_round)
    d_small = float(jnp.linalg.norm(p_small - p))
    d_full = float(jnp.linalg.norm(p_full - p))
    assert 0 < d_small < d_full


def test_prox_pulls_toward_global():
    """FedProx gradient includes mu(w - w_g): with a huge mu the drift from
    the global point must be smaller than plain training's drift."""
    b = build_bundle("mnist", "smoke")
    ms = b.ms
    x, y = _learnable_batch(b, ms.shard_size)
    p = b.init_flat
    z = jnp.zeros_like(p)
    full = jnp.int32(ms.steps_per_round)
    p_plain, *_ = jax.jit(b.train)(p, z, z, jnp.float32(0), x, y, jnp.int32(7), full)
    p_prox, *_ = jax.jit(b.train_prox)(
        p, z, z, jnp.float32(0), x, y, jnp.int32(7), full, p
    )
    drift_plain = float(jnp.linalg.norm(p_plain - p))
    drift_prox = float(jnp.linalg.norm(p_prox - p))
    assert drift_prox < drift_plain


@pytest.mark.parametrize("name", MODELS)
def test_eval_counts_are_bounded(name):
    b = SMOKE[name]
    x, y = _learnable_batch(b, b.ms.eval_size)
    loss_sum, correct = jax.jit(b.eval)(b.init_flat, x, y)
    assert 0.0 <= float(correct) <= b.ms.eval_size
    assert float(loss_sum) > 0.0


def test_eval_improves_after_training():
    b = SMOKE["mnist"]
    ms = b.ms
    x, y = _learnable_batch(b, ms.shard_size, seed=5)
    ex, ey = _learnable_batch(b, ms.eval_size, seed=6)
    p = b.init_flat
    z = jnp.zeros_like(p)
    _, c0 = jax.jit(b.eval)(p, ex, ey)
    train = jax.jit(b.train)
    m = v = z
    t = jnp.float32(0)
    for r in range(4):
        p, m, v, t, _ = train(p, m, v, t, x, y, jnp.int32(r), jnp.int32(ms.steps_per_round))
    _, c1 = jax.jit(b.eval)(p, ex, ey)
    assert float(c1) > float(c0)


def test_train_deterministic_given_seed():
    b = SMOKE["speech"]  # has dropout -> exercises the rng path
    ms = b.ms
    x, y = _learnable_batch(b, ms.shard_size)
    p = b.init_flat
    z = jnp.zeros_like(p)
    args = (p, z, z, jnp.float32(0), x, y, jnp.int32(42), jnp.int32(ms.steps_per_round))
    p1, *_ = jax.jit(b.train)(*args)
    p2, *_ = jax.jit(b.train)(*args)
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))


def test_scale_presets_are_consistent():
    for name in MODELS:
        for scale in ("smoke", "default", "paper"):
            ms = get_scale(name, scale)
            assert ms.steps_per_round >= 1
            assert ms.eval_size % ms.eval_batch == 0
            assert ms.k_max >= 2
