"""AOT pipeline: HLO text emission, manifest schema, init binary."""

import json
import struct
from pathlib import Path

import pytest

from compile import aot
from compile.model import build_bundle

MODEL = "mnist"


@pytest.fixture(scope="module")
def exported(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = aot.export_model(MODEL, "smoke", out, quiet=True)
    return out, manifest


def test_hlo_files_are_text_modules(exported):
    out, manifest = exported
    for ep in manifest["entrypoints"].values():
        text = (out / ep["file"]).read_text()
        assert text.startswith("HloModule"), ep["file"]
        assert "ENTRY" in text
        # interchange must be text, never a serialized proto
        assert "\x00" not in text


def test_manifest_schema(exported):
    _, m = exported
    for key in (
        "name", "scale", "param_count", "num_classes", "input_shape",
        "input_dtype", "shard_size", "batch_size", "local_epochs",
        "steps_per_round", "optimizer", "lr", "prox_mu", "eval_size",
        "eval_batch", "k_max", "entrypoints", "init_file", "init_sha256",
        "flops_per_round",
    ):
        assert key in m, key
    assert m["steps_per_round"] == (
        m["shard_size"] // m["batch_size"] * m["local_epochs"]
    )
    for name, io in aot.ENTRYPOINT_IO.items():
        ep = m["entrypoints"][name]
        assert ep["inputs"] == io[0]
        assert ep["outputs"] == io[1]


def test_init_bin_is_p_f32_le(exported):
    out, m = exported
    raw = (out / m["init_file"]).read_bytes()
    assert len(raw) == 4 * m["param_count"]
    # first element round-trips as little-endian f32 and matches the bundle
    bundle = build_bundle(MODEL, "smoke", init_seed=m["init_seed"])
    first = struct.unpack("<f", raw[:4])[0]
    assert abs(first - float(bundle.init_flat[0])) < 1e-7


def test_entry_parameter_count_matches_manifest(exported):
    """The HLO entry computation must declare exactly the manifest inputs."""
    out, m = exported
    for name, ep in m["entrypoints"].items():
        text = (out / ep["file"]).read_text()
        entry = text.split("ENTRY")[1]
        n_params = entry.count(" parameter(")
        assert n_params == len(ep["inputs"]), name


def test_index_written(tmp_path):
    aot.main(["--out-dir", str(tmp_path), "--scale", "smoke",
              "--models", "mnist", "--quiet"])
    idx = json.loads((tmp_path / "index.json").read_text())
    assert idx["models"] == ["mnist"]
    assert (tmp_path / idx["manifests"]["mnist"]).exists()
