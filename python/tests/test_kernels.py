"""L1 correctness: Pallas kernels vs the pure-jnp oracles in kernels.ref.

This is the core correctness signal for the kernel layer: hypothesis
sweeps shapes/dtypes/tile sizes and asserts allclose against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import compile.kernels.aggregate as agg_mod
import compile.kernels.dense as dense_mod
from compile.kernels import ref

jax.config.update("jax_enable_x64", False)

SET = dict(max_examples=25, deadline=None)


def _rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.key(key), shape, jnp.float32).astype(dtype)


# ---------------------------------------------------------------------------
# pl_matmul
# ---------------------------------------------------------------------------


@settings(**SET)
@given(
    m=st.integers(1, 70),
    k=st.integers(1, 48),
    n=st.integers(1, 70),
    bm=st.sampled_from([8, 16, 128]),
    bn=st.sampled_from([8, 16, 128]),
    seed=st.integers(0, 2**16),
)
def test_matmul_matches_ref(m, k, n, bm, bn, seed):
    a = _rand(seed, (m, k))
    b = _rand(seed + 1, (k, n))
    got = dense_mod.pl_matmul(a, b, bm=bm, bn=bn)
    want = ref.matmul_ref(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_dtypes(dtype):
    a = _rand(0, (33, 17), dtype)
    b = _rand(1, (17, 65), dtype)
    got = dense_mod.pl_matmul(a, b, bm=16, bn=16)
    want = ref.matmul_ref(a, b)
    assert got.dtype == dtype
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32), rtol=2e-2, atol=2e-2
    )


def test_matmul_rejects_bad_shapes():
    with pytest.raises(ValueError):
        dense_mod.pl_matmul(jnp.zeros((2, 3)), jnp.zeros((4, 5)))
    with pytest.raises(ValueError):
        dense_mod.pl_matmul(jnp.zeros((2, 3, 4)), jnp.zeros((4, 5)))


def test_matmul_exact_tile_boundary():
    # No padding path: m, n exact multiples of the tiles.
    a = _rand(3, (32, 8))
    b = _rand(4, (8, 48))
    got = dense_mod.pl_matmul(a, b, bm=16, bn=16)
    np.testing.assert_allclose(got, ref.matmul_ref(a, b), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# dense + custom VJP
# ---------------------------------------------------------------------------


@settings(**SET)
@given(
    bsz=st.integers(1, 16),
    nin=st.integers(1, 32),
    nout=st.integers(1, 32),
    seed=st.integers(0, 2**16),
)
def test_dense_forward_matches_ref(bsz, nin, nout, seed):
    x = _rand(seed, (bsz, nin))
    w = _rand(seed + 1, (nin, nout))
    b = _rand(seed + 2, (nout,))
    np.testing.assert_allclose(
        dense_mod.dense(x, w, b), ref.dense_ref(x, w, b), rtol=1e-5, atol=1e-5
    )


def test_dense_grads_match_jnp():
    """The custom VJP (Pallas bwd matmuls) must equal autodiff of the oracle."""
    x = _rand(10, (7, 13))
    w = _rand(11, (13, 5))
    b = _rand(12, (5,))

    def loss_pallas(x, w, b):
        return jnp.sum(jnp.tanh(dense_mod.dense(x, w, b)) ** 2)

    def loss_ref(x, w, b):
        return jnp.sum(jnp.tanh(ref.dense_ref(x, w, b)) ** 2)

    g1 = jax.grad(loss_pallas, argnums=(0, 1, 2))(x, w, b)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(x, w, b)
    for a, e in zip(g1, g2):
        np.testing.assert_allclose(a, e, rtol=1e-5, atol=1e-5)


def test_dense_grad_under_jit_and_scan():
    """Same composition the AOT train round uses: grad inside scan inside jit."""
    x = _rand(20, (4, 6))
    w = _rand(21, (6, 3))
    b = jnp.zeros((3,))

    def step(carry, _):
        w, b = carry
        g_w, g_b = jax.grad(
            lambda w, b: jnp.mean(dense_mod.dense(x, w, b) ** 2), argnums=(0, 1)
        )(w, b)
        return (w - 0.1 * g_w, b - 0.1 * g_b), jnp.mean(dense_mod.dense(x, w, b) ** 2)

    (_, _), losses = jax.jit(
        lambda w, b: jax.lax.scan(step, (w, b), None, length=5)
    )(w, b)
    assert losses[-1] < losses[0]


# ---------------------------------------------------------------------------
# aggregate
# ---------------------------------------------------------------------------


@settings(**SET)
@given(
    k=st.integers(1, 24),
    p=st.integers(1, 5000),
    bp=st.sampled_from([64, 1024, 2048]),
    seed=st.integers(0, 2**16),
)
def test_aggregate_matches_ref(k, p, bp, seed):
    u = _rand(seed, (k, p))
    w = jax.random.uniform(jax.random.key(seed + 9), (k,))
    got = agg_mod.aggregate(u, w, bp=bp)
    want = ref.aggregate_ref(u, w)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_aggregate_zero_weight_rows_are_exact_padding():
    """Rounds with fewer than k_max updates pad with zero weights: exact."""
    u = _rand(1, (8, 257))
    w = jnp.array([0.3, 0.7, 0, 0, 0, 0, 0, 0], jnp.float32)
    got = agg_mod.aggregate(u, w)
    want = ref.aggregate_ref(u[:2], w[:2])
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_aggregate_fedavg_weights_recover_mean():
    """With t_k == t, Eq. 3 reduces to FedAvg: n_k/n weighted mean."""
    u = _rand(2, (4, 100))
    cards = jnp.array([10.0, 30.0, 40.0, 20.0])
    w = cards / cards.sum()
    got = agg_mod.aggregate(u, w)
    want = jnp.einsum("k,kp->p", w, u)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_aggregate_validates_shapes():
    with pytest.raises(ValueError):
        agg_mod.aggregate(jnp.zeros((3, 4)), jnp.zeros((5,)))
    with pytest.raises(ValueError):
        agg_mod.aggregate(jnp.zeros((3,)), jnp.zeros((3,)))


# ---------------------------------------------------------------------------
# staleness weights reference (cross-checked against the Rust impl too)
# ---------------------------------------------------------------------------


def test_staleness_weights_tau_cutoff():
    rounds = jnp.array([10.0, 9.0, 8.0, 7.0])
    cards = jnp.array([100.0, 100.0, 100.0, 100.0])
    w = ref.staleness_weights_ref(rounds, cards, current_round=10, tau=2)
    # ages 0,1 kept; ages 2,3 discarded
    assert w[2] == 0.0 and w[3] == 0.0
    assert w[0] > w[1] > 0.0


def test_staleness_weights_same_round_is_fedavg():
    rounds = jnp.array([5.0, 5.0, 5.0])
    cards = jnp.array([10.0, 20.0, 70.0])
    w = ref.staleness_weights_ref(rounds, cards, current_round=5, tau=2)
    np.testing.assert_allclose(w, cards / cards.sum(), rtol=1e-6)


# ---------------------------------------------------------------------------
# VMEM estimators (perf bookkeeping)
# ---------------------------------------------------------------------------


def test_vmem_budgets():
    # Paper-scale tiles must fit the ~16 MiB/core VMEM budget.
    assert dense_mod.vmem_bytes(128, 128, 4096) <= 16 * 2**20
    assert agg_mod.vmem_bytes(256, 2048) <= 16 * 2**20
