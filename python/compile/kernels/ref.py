"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: every Pallas kernel must match its
oracle to float tolerance across the hypothesis shape/dtype sweeps in
``python/tests/test_kernels.py``. Keep these boring — no tiling, no
padding, just the mathematical definition.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """``a [M, K] @ b [K, N] -> [M, N]`` with f32 accumulation."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(a.dtype)


def dense_ref(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """``x @ w + b``."""
    return matmul_ref(x, w) + b


def aggregate_ref(updates: jax.Array, weights: jax.Array) -> jax.Array:
    """Staleness-weighted aggregation oracle (paper Eq. 3 inner sum).

    ``sum_k weights[k] * updates[k, :]`` in f32.
    """
    return jnp.einsum(
        "k,kp->p",
        weights.astype(jnp.float32),
        updates.astype(jnp.float32),
    )


def staleness_weights_ref(
    rounds: jax.Array, cards: jax.Array, current_round: int, tau: int
) -> jax.Array:
    """Reference for the Eq. 3 scalar weights (also implemented in Rust).

    weight_k = (t_k / t) * (n_k / n) over the non-expired updates,
    where updates with ``t - t_k >= tau`` are discarded and n sums the
    cardinality of the *included* updates only.
    """
    t = jnp.asarray(current_round, jnp.float32)
    keep = (t - rounds.astype(jnp.float32)) < tau
    cards_f = jnp.where(keep, cards.astype(jnp.float32), 0.0)
    n = jnp.maximum(cards_f.sum(), 1e-12)
    damp = jnp.where(keep, rounds.astype(jnp.float32) / jnp.maximum(t, 1.0), 0.0)
    return damp * cards_f / n
