"""L1 Pallas kernel: staleness-weighted model aggregation (paper Eq. 3).

The FedLesScan aggregator combines K client updates into the next global
model:

    w_{t+1} = sum_k  (t_k / t) * (n_k / n) * w^k_{t_k}

The Rust coordinator computes the scalar weight per update (staleness
dampening * cardinality share, with the tau cutoff applied before the
call) and invokes this kernel with the stacked updates ``[K, P]`` and the
weight vector ``[K]``. K is fixed at AOT time to ``k_max`` (the configured
clients-per-round plus the staleness buffer headroom); rounds with fewer
updates pad with zero rows / zero weights, which is exact.

Kernel structure (TPU mapping):
  * grid over the parameter axis P in ``BP``-wide tiles (lane-aligned),
  * each grid step loads a ``(K, BP)`` tile of updates plus the full
    ``(K,)`` weight vector into VMEM and contracts over K on the MXU/VPU
    (``w [1,K] @ u [K,BP]``),
  * P is padded to a multiple of BP by the wrapper and sliced back.

VMEM per step: K*BP*4 + K*4 + BP*4 bytes — for K=256, BP=2048 that is
~2.1 MB, far under budget; BP can be raised to trade grid steps for
bandwidth (see DESIGN.md §Perf).

Runs interpret=True on this CPU image (see kernels.dense docstring).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BP = 2048

# Per-step VMEM budget used by auto tile sizing (half the ~16 MiB/core
# budget, leaving headroom for the weights vector and the output tile).
VMEM_BUDGET_BYTES = 8 * 2**20

INTERPRET = True


def auto_bp(k: int, p: int) -> int:
    """Pick the widest lane tile that keeps the double-buffered update
    tile under the VMEM budget: fewer grid steps amortize per-step
    overhead (a measured 4x end-to-end win on the CPU interpret path —
    see EXPERIMENTS.md §Perf) and on TPU reduce DMA issue count.
    """
    cap = max(512, VMEM_BUDGET_BYTES // (8 * max(k, 1)))
    # round down to a power of two for lane alignment
    bp = 1 << (cap.bit_length() - 1)
    return max(512, min(bp, max(p, 1)))


def _agg_kernel(u_ref, w_ref, o_ref):
    # (1, K) @ (K, BP) -> (1, BP): contraction over clients on the MXU.
    w = w_ref[...].reshape(1, -1)
    o_ref[...] = jnp.dot(
        w, u_ref[...], preferred_element_type=jnp.float32
    )[0].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bp",))
def aggregate(
    updates: jax.Array, weights: jax.Array, *, bp: int | None = None
) -> jax.Array:
    """Weighted sum of client updates: ``[K, P], [K] -> [P]``.

    The caller owns the weight semantics (Eq. 3 staleness dampening and
    cardinality shares, or plain FedAvg n_k/n weights). ``bp`` defaults
    to the widest VMEM-safe lane tile (see ``auto_bp``).
    """
    if updates.ndim != 2:
        raise ValueError(f"updates must be [K, P], got {updates.shape}")
    if weights.shape != (updates.shape[0],):
        raise ValueError(
            f"weights {weights.shape} does not match K={updates.shape[0]}"
        )
    k, p = updates.shape
    if bp is None:
        bp = auto_bp(k, p)
    bp = min(bp, max(p, 1))
    rem = (-p) % bp
    u = jnp.pad(updates, ((0, 0), (0, rem))) if rem else updates
    pp = u.shape[1]
    out = pl.pallas_call(
        _agg_kernel,
        grid=(pp // bp,),
        in_specs=[
            pl.BlockSpec((k, bp), lambda j: (0, j)),
            pl.BlockSpec((k,), lambda j: (0,)),
        ],
        out_specs=pl.BlockSpec((bp,), lambda j: (j,)),
        out_shape=jax.ShapeDtypeStruct((pp,), jnp.float32),
        interpret=INTERPRET,
    )(u.astype(jnp.float32), weights.astype(jnp.float32))
    return out[:p]


def vmem_bytes(k: int, bp: int, itemsize: int = 4) -> int:
    """Estimated per-step VMEM working set (double-buffered update tile)."""
    return itemsize * (2 * k * bp + k + bp)
