"""L1 Pallas kernels: tiled matmul and a fused dense layer.

The dense (fully-connected) layers are the compute hot-spot of every model
architecture in the paper (§VI-A2): the LEAF CNNs end in large FC layers,
the Shakespeare LSTM is four fused gate matmuls per step, and the
char-transformer is matmul-dominated. We implement the matmul as a Pallas
kernel tiled for the TPU memory hierarchy:

  * the M and N axes are blocked (``BM`` x ``BN`` tiles, MXU-shaped by
    default) and mapped onto the grid,
  * the K (contraction) axis is kept resident in VMEM per tile — for the
    layer sizes used by the paper's models (K <= 4096) an ``(BM, K)`` +
    ``(K, BN)`` working set fits comfortably in the ~16 MB VMEM budget,
  * accumulation happens in f32 via ``preferred_element_type`` so bf16
    inputs still use the MXU with full-precision accumulation.

``pallas_call`` has no automatic-differentiation rule, so the public
``dense`` op carries a ``custom_vjp`` whose backward pass re-uses the same
Pallas matmul kernel for dX = g @ W^T and dW = X^T @ g. This keeps the
Pallas kernel on the hot path of both the forward *and* backward pass of
client-side training.

NOTE: on this (CPU-only) image the kernels run with ``interpret=True`` —
real TPU lowering emits a Mosaic custom-call the CPU PJRT client cannot
execute. The tiling structure is still what a TPU would get; estimated
VMEM/MXU numbers are recorded in DESIGN.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes. 128x128 matches the MXU systolic array; on the
# interpret path they only control the grid decomposition.
DEFAULT_BM = 128
DEFAULT_BN = 128

# All kernels in this repository run in interpret mode (see module
# docstring). Kept as a module flag so tests can assert on it.
INTERPRET = True


def _matmul_kernel(a_ref, b_ref, o_ref):
    """One (BM, BN) output tile: full-K contraction resident in VMEM."""
    o_ref[...] = jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def pl_matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
) -> jax.Array:
    """Tiled Pallas matmul: ``a [M, K] @ b [K, N] -> [M, N]``.

    M and N are padded up to the tile sizes and the result is sliced back,
    so arbitrary shapes are accepted. K is never blocked (see module
    docstring for the VMEM argument).
    """
    if a.ndim != 2 or b.ndim != 2:
        raise ValueError(f"pl_matmul expects 2-D operands, got {a.shape} @ {b.shape}")
    if a.shape[1] != b.shape[0]:
        raise ValueError(f"contraction mismatch: {a.shape} @ {b.shape}")
    m, k = a.shape
    n = b.shape[1]
    bm = min(bm, max(m, 1))
    bn = min(bn, max(n, 1))
    a_p = _pad_to(a, 0, bm)
    b_p = _pad_to(b, 1, bn)
    mp, np_ = a_p.shape[0], b_p.shape[1]
    grid = (mp // bm, np_ // bn)
    out = pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=INTERPRET,
    )(a_p, b_p)
    return out[:m, :n].astype(a.dtype)


# ---------------------------------------------------------------------------
# dense: y = x @ w + b with a custom VJP that keeps Pallas on the bwd path.
# ---------------------------------------------------------------------------


@jax.custom_vjp
def dense(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Fused dense layer ``x [B, I] @ w [I, O] + b [O]`` via the Pallas matmul."""
    return pl_matmul(x, w) + b


def _dense_fwd(x, w, b):
    return dense(x, w, b), (x, w)


def _dense_bwd(res, g):
    x, w = res
    dx = pl_matmul(g, w.T)
    dw = pl_matmul(x.T, g)
    db = jnp.sum(g, axis=0)
    return dx, dw, db


dense.defvjp(_dense_fwd, _dense_bwd)


def vmem_bytes(bm: int, bn: int, k: int, itemsize: int = 4) -> int:
    """Estimated per-core VMEM working set of one grid step.

    a-tile (bm, k) + b-tile (k, bn) + out-tile (bm, bn), double-buffered
    inputs (the Mosaic pipeline overlaps the next tile's DMA).
    """
    return itemsize * (2 * (bm * k + k * bn) + bm * bn)
