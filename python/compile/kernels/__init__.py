"""L1 Pallas kernels for the FedLesScan reproduction.

``dense``      — tiled matmul / fused dense layer used by all L2 models.
``aggregate``  — staleness-weighted model aggregation (paper Eq. 3).
``ref``        — pure-jnp correctness oracles for both.

Import the submodules (``from compile.kernels import dense``) or the ops
directly (``from compile.kernels.dense import dense``). The package itself
deliberately re-exports nothing: a function re-export named like its own
submodule would shadow it on ``import compile.kernels.dense``.
"""
