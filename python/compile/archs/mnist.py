"""LEAF MNIST CNN (paper §VI-A2).

2x [conv 5x5 -> 2x2 max-pool], fully-connected hidden layer, 10-way output.
Channel/hidden widths come from the scale preset (paper: 32/64/512).
"""

from __future__ import annotations

import jax

from compile.archs.common import (
    Arch,
    apply_conv,
    apply_dense,
    conv_init,
    dense_init,
    max_pool,
)
from compile.scales import ModelScale


def build(ms: ModelScale) -> Arch:
    c1, c2, fc = ms.arch["c1"], ms.arch["c2"], ms.arch["fc"]
    h, w, cin = ms.input_shape
    # Two SAME convs + two 2x2 pools: spatial /4.
    flat_dim = (h // 4) * (w // 4) * c2

    def init(key):
        k1, k2, k3, k4 = jax.random.split(key, 4)
        return {
            "conv1": conv_init(k1, 5, 5, cin, c1),
            "conv2": conv_init(k2, 5, 5, c1, c2),
            "fc": dense_init(k3, flat_dim, fc),
            "out": dense_init(k4, fc, ms.num_classes),
        }

    def apply(params, x, *, key=None, train=False):
        del key, train  # no stochastic layers in this arch
        y = jax.nn.relu(apply_conv(params["conv1"], x))
        y = max_pool(y)
        y = jax.nn.relu(apply_conv(params["conv2"], y))
        y = max_pool(y)
        y = y.reshape(y.shape[0], -1)
        y = jax.nn.relu(apply_dense(params["fc"], y))
        return apply_dense(params["out"], y)

    return Arch(ms.name, ms.num_classes, init, apply)
