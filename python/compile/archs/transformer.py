"""Decoder-only char transformer (the end-to-end driver model).

Not a paper architecture — the system-prompt e2e requirement: prove the
full stack composes on a modern training workload. Pre-LN decoder blocks;
QKV/O/FFN projections route through the Pallas dense kernel (reshaped to
2-D so the tiled matmul applies); attention score/value contractions stay
in einsum where XLA fuses the softmax chain.

Predicts the next character from the previous ``seq_len`` (same external
interface as the Shakespeare LSTM, so the whole federated pipeline is
architecture-agnostic).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.archs.common import Arch, apply_dense, dense_init, embed_init
from compile.scales import ModelScale


def _dense3(p: dict, x: jax.Array) -> jax.Array:
    """Apply the Pallas dense layer to a [B, T, D] tensor."""
    b, t, d = x.shape
    return apply_dense(p, x.reshape(b * t, d)).reshape(b, t, -1)


def _layer_norm(g: jax.Array, b: jax.Array, x: jax.Array) -> jax.Array:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return g * (x - mu) * jax.lax.rsqrt(var + 1e-5) + b


def build(ms: ModelScale) -> Arch:
    d_model = ms.arch["d_model"]
    n_layers = ms.arch["layers"]
    n_heads = ms.arch["heads"]
    d_ff = ms.arch["d_ff"]
    vocab = ms.num_classes
    seq = ms.seq_len
    d_head = d_model // n_heads
    if d_head * n_heads != d_model:
        raise ValueError("heads must divide d_model")

    def init(key):
        keys = jax.random.split(key, 2 + n_layers)
        params = {
            "embed": embed_init(keys[0], vocab, d_model),
            "pos": embed_init(keys[1], seq, d_model),
        }
        for li in range(n_layers):
            ks = jax.random.split(keys[2 + li], 6)
            params[f"blk{li}"] = {
                "qkv": dense_init(ks[0], d_model, 3 * d_model),
                "o": dense_init(ks[1], d_model, d_model),
                "ff1": dense_init(ks[2], d_model, d_ff),
                "ff2": dense_init(ks[3], d_ff, d_model),
                "ln1g": jnp.ones((d_model,)), "ln1b": jnp.zeros((d_model,)),
                "ln2g": jnp.ones((d_model,)), "ln2b": jnp.zeros((d_model,)),
            }
        params["lnfg"] = jnp.ones((d_model,))
        params["lnfb"] = jnp.zeros((d_model,))
        params["out"] = dense_init(jax.random.fold_in(keys[-1], 7), d_model, vocab)
        return params

    causal = jnp.tril(jnp.ones((seq, seq), bool))

    def attention(blk, x):
        b, t, _ = x.shape
        qkv = _dense3(blk["qkv"], x)  # [B, T, 3D]
        q, k, v = jnp.split(qkv, 3, axis=-1)

        def heads(z):  # [B, T, D] -> [B, H, T, dh]
            return z.reshape(b, t, n_heads, d_head).transpose(0, 2, 1, 3)

        q, k, v = heads(q), heads(k), heads(v)
        scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(d_head))
        scores = jnp.where(causal[:t, :t], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("bhqk,bhkd->bhqd", probs, v)
        ctx = ctx.transpose(0, 2, 1, 3).reshape(b, t, d_model)
        return _dense3(blk["o"], ctx)

    def apply(params, x, *, key=None, train=False):
        del key, train
        b, t = x.shape
        y = params["embed"][x] + params["pos"][:t]
        for li in range(n_layers):
            blk = params[f"blk{li}"]
            y = y + attention(blk, _layer_norm(blk["ln1g"], blk["ln1b"], y))
            h = _dense3(blk["ff1"], _layer_norm(blk["ln2g"], blk["ln2b"], y))
            y = y + _dense3(blk["ff2"], jax.nn.gelu(h))
        y = _layer_norm(params["lnfg"], params["lnfb"], y)
        return apply_dense(params["out"], y[:, -1, :])

    return Arch(ms.name, ms.num_classes, init, apply)
