"""L2 model zoo registry: the four paper architectures + the e2e transformer."""

from __future__ import annotations

from compile.archs import femnist, mnist, shakespeare, speech, transformer
from compile.archs.common import Arch
from compile.scales import ModelScale

_BUILDERS = {
    "mnist": mnist.build,
    "femnist": femnist.build,
    "shakespeare": shakespeare.build,
    "speech": speech.build,
    "transformer": transformer.build,
}


def build_arch(ms: ModelScale) -> Arch:
    """Instantiate the architecture for a scale preset."""
    return _BUILDERS[ms.name](ms)


__all__ = ["Arch", "build_arch"]
