"""LEAF FEMNIST CNN (paper §VI-A2).

Identical topology to the MNIST CNN but a 62-way output and a wider hidden
layer (paper: 2048). The shared structure is deliberate — it mirrors LEAF.
"""

from __future__ import annotations

from compile.archs import mnist
from compile.archs.common import Arch
from compile.scales import ModelScale


def build(ms: ModelScale) -> Arch:
    arch = mnist.build(ms)
    return Arch(ms.name, ms.num_classes, arch.init, arch.apply)
