"""LEAF Shakespeare character LSTM (paper §VI-A2).

Embedding (dim 8) -> stacked LSTM layers (paper: 2x256) -> 82-way output
predicting the next character from the previous ``seq_len``. The LSTM gate
matmuls go through the Pallas dense kernel: each step computes
``[x_t, h] @ W_gates [I+H, 4H]`` — the model's compute hot-spot.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.archs.common import Arch, apply_dense, dense_init, embed_init
from compile.scales import ModelScale


def _lstm_layer(p: dict, xs: jax.Array) -> jax.Array:
    """Run one LSTM layer over ``xs [B, T, I]``; returns hidden seq [B, T, H]."""
    batch = xs.shape[0]
    hidden = p["w"].shape[1] // 4

    def step(carry, x_t):
        h, c = carry
        gates = apply_dense(p, jnp.concatenate([x_t, h], axis=-1))
        i, f, g, o = jnp.split(gates, 4, axis=-1)
        c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (h, c), h

    h0 = jnp.zeros((batch, hidden), jnp.float32)
    (_, _), hs = jax.lax.scan(step, (h0, h0), jnp.swapaxes(xs, 0, 1))
    return jnp.swapaxes(hs, 0, 1)


def build(ms: ModelScale) -> Arch:
    embed, hidden, layers = ms.arch["embed"], ms.arch["hidden"], ms.arch["layers"]
    vocab = ms.num_classes

    def init(key):
        keys = jax.random.split(key, layers + 2)
        params = {"embed": embed_init(keys[0], vocab, embed)}
        dim = embed
        for li in range(layers):
            # One fused gate matrix per layer: [I+H, 4H] (i, f, g, o).
            params[f"lstm{li}"] = dense_init(keys[1 + li], dim + hidden, 4 * hidden)
            dim = hidden
        params["out"] = dense_init(keys[-1], hidden, vocab)
        return params

    def apply(params, x, *, key=None, train=False):
        del key, train
        y = params["embed"][x]  # [B, T, E]
        for li in range(layers):
            y = _lstm_layer(params[f"lstm{li}"], y)
        return apply_dense(params["out"], y[:, -1, :])

    return Arch(ms.name, ms.num_classes, init, apply)
