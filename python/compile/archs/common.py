"""Shared building blocks for the L2 model zoo.

Every architecture is expressed as an ``Arch``: an ``init`` producing a
parameter pytree and an ``apply`` mapping ``(params, x)`` to logits. Dense
layers route through the L1 Pallas kernel (``kernels.dense``); convolutions
and element-wise ops stay in XLA-native jnp/lax, which is where they fuse
best.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

from compile.kernels.dense import dense


@dataclass(frozen=True)
class Arch:
    """One model architecture bound to a concrete scale."""

    name: str
    num_classes: int
    init: Callable  # (key) -> params pytree
    apply: Callable  # (params, x, *, key, train) -> logits [B, C]


# ---------------------------------------------------------------------------
# parameter initializers
# ---------------------------------------------------------------------------


def dense_init(key, n_in: int, n_out: int) -> dict:
    """Glorot-uniform dense parameters (matches LEAF's TF defaults)."""
    lim = jnp.sqrt(6.0 / (n_in + n_out))
    w = jax.random.uniform(key, (n_in, n_out), jnp.float32, -lim, lim)
    return {"w": w, "b": jnp.zeros((n_out,), jnp.float32)}


def conv_init(key, kh: int, kw: int, c_in: int, c_out: int) -> dict:
    """Glorot-uniform conv parameters, HWIO layout."""
    fan_in = kh * kw * c_in
    fan_out = kh * kw * c_out
    lim = jnp.sqrt(6.0 / (fan_in + fan_out))
    w = jax.random.uniform(key, (kh, kw, c_in, c_out), jnp.float32, -lim, lim)
    return {"w": w, "b": jnp.zeros((c_out,), jnp.float32)}


def embed_init(key, vocab: int, dim: int) -> jax.Array:
    return jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02


# ---------------------------------------------------------------------------
# layer applications
# ---------------------------------------------------------------------------


def apply_dense(p: dict, x: jax.Array) -> jax.Array:
    """Dense layer via the Pallas matmul kernel (fwd *and* bwd)."""
    return dense(x, p["w"], p["b"])


def apply_conv(p: dict, x: jax.Array, *, padding: str = "SAME") -> jax.Array:
    """NHWC conv with HWIO weights, stride 1."""
    y = lax.conv_general_dilated(
        x, p["w"], window_strides=(1, 1), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y + p["b"]


def max_pool(x: jax.Array, window: int = 2) -> jax.Array:
    return lax.reduce_window(
        x, -jnp.inf, lax.max,
        (1, window, window, 1), (1, window, window, 1), "VALID",
    )


def avg_pool(x: jax.Array, window: int = 2) -> jax.Array:
    summed = lax.reduce_window(
        x, 0.0, lax.add,
        (1, window, window, 1), (1, window, window, 1), "VALID",
    )
    return summed / float(window * window)


def dropout(key, x: jax.Array, rate: float, train: bool) -> jax.Array:
    """Inverted dropout; identity when not training (eval artifacts)."""
    if not train or rate <= 0.0:
        return x
    keep = 1.0 - rate
    mask = jax.random.bernoulli(key, keep, x.shape)
    return jnp.where(mask, x / keep, 0.0)


def softmax_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean softmax cross-entropy over the batch; labels are int class ids."""
    logz = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logz, labels[:, None].astype(jnp.int32), axis=-1)
    return jnp.mean(nll)


def accuracy_counts(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Number of correct argmax predictions in the batch (f32 scalar)."""
    pred = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jnp.sum((pred == labels.astype(jnp.int32)).astype(jnp.float32))
