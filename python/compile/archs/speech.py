"""Google Speech Commands CNN (paper §VI-A2).

Two identical blocks of [conv 3x3, conv 3x3, 2x2 max-pool, dropout 0.25],
then average pooling and a 35-way output layer. Input is a fixed 32x32x1
spectrogram-like map (DESIGN.md substitutions).
"""

from __future__ import annotations

import jax

from compile.archs.common import (
    Arch,
    apply_conv,
    apply_dense,
    avg_pool,
    conv_init,
    dense_init,
    dropout,
    max_pool,
)
from compile.scales import ModelScale


def build(ms: ModelScale) -> Arch:
    c1, c2 = ms.arch["c1"], ms.arch["c2"]
    rate = ms.arch["dropout"]
    h, w, cin = ms.input_shape
    # Two pool-2 blocks then one avg-pool-2: spatial /8.
    flat_dim = (h // 8) * (w // 8) * c2

    def init(key):
        k1, k2, k3, k4, k5 = jax.random.split(key, 5)
        return {
            "b1c1": conv_init(k1, 3, 3, cin, c1),
            "b1c2": conv_init(k2, 3, 3, c1, c1),
            "b2c1": conv_init(k3, 3, 3, c1, c2),
            "b2c2": conv_init(k4, 3, 3, c2, c2),
            "out": dense_init(k5, flat_dim, ms.num_classes),
        }

    def apply(params, x, *, key=None, train=False):
        if train and key is None:
            raise ValueError("speech arch needs a dropout key when train=True")
        k1 = k2 = None
        if train:
            k1, k2 = jax.random.split(key)
        y = jax.nn.relu(apply_conv(params["b1c1"], x))
        y = jax.nn.relu(apply_conv(params["b1c2"], y))
        y = max_pool(y)
        y = dropout(k1, y, rate, train)
        y = jax.nn.relu(apply_conv(params["b2c1"], y))
        y = jax.nn.relu(apply_conv(params["b2c2"], y))
        y = max_pool(y)
        y = dropout(k2, y, rate, train)
        y = avg_pool(y)
        y = y.reshape(y.shape[0], -1)
        return apply_dense(params["out"], y)

    return Arch(ms.name, ms.num_classes, init, apply)
