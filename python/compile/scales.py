"""Scale presets for the model zoo.

The paper trains on real GCF with up to 200 concurrent clients (§VI-A3);
this reproduction runs the full stack on a CPU PJRT client, so every model
family exposes three scales:

  * ``smoke``   — seconds-fast shapes for CI and property tests,
  * ``default`` — the shapes used by the checked-in experiment runs in
                  EXPERIMENTS.md; small enough for a CPU matrix sweep but
                  structurally identical to the paper models,
  * ``paper``   — the exact LEAF / paper §VI-A2 architectures and Table I
                  hyperparameters (shard sizes per §VI-A1).

Hyperparameters that the paper fixes (Table I) keep their values across
scales: local epochs, batch size, learning rate, optimizer. Only model
width / shard size / sequence length shrink below ``paper``.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional


@dataclass(frozen=True)
class ModelScale:
    """Everything the AOT pipeline needs to build one model variant."""

    name: str
    scale: str
    # --- data shape ---
    input_shape: tuple  # per-sample shape, e.g. (28, 28, 1) or (seq_len,)
    input_dtype: str  # "f32" | "i32"
    num_classes: int
    shard_size: int  # fixed per-client local dataset cardinality N
    eval_size: int  # central eval set size M
    eval_batch: int  # EB, must divide eval_size
    # --- Table I hyperparameters ---
    local_epochs: int
    batch_size: int
    lr: float
    optimizer: str  # "adam" | "sgd"
    prox_mu: float  # FedProx proximal coefficient
    # --- aggregation ---
    k_max: int  # max stacked updates per aggregate call
    # --- architecture hyperparameters (per family) ---
    arch: dict = field(default_factory=dict)
    seq_len: Optional[int] = None

    def __post_init__(self):
        if self.eval_size % self.eval_batch != 0:
            raise ValueError(f"{self.name}/{self.scale}: eval_batch must divide eval_size")
        if self.shard_size % self.batch_size != 0:
            raise ValueError(f"{self.name}/{self.scale}: batch_size must divide shard_size")

    @property
    def steps_per_epoch(self) -> int:
        return self.shard_size // self.batch_size

    @property
    def steps_per_round(self) -> int:
        return self.steps_per_epoch * self.local_epochs


def _mnist(scale: str) -> ModelScale:
    arch = {
        "smoke": dict(c1=4, c2=8, fc=32),
        "default": dict(c1=8, c2=16, fc=64),
        "paper": dict(c1=32, c2=64, fc=512),  # LEAF MNIST CNN (§VI-A2)
    }[scale]
    shard = {"smoke": 20, "default": 50, "paper": 200}[scale]  # paper: 300x200 shards
    return ModelScale(
        name="mnist", scale=scale,
        input_shape=(28, 28, 1), input_dtype="f32", num_classes=10,
        shard_size=shard, eval_size={"smoke": 128, "default": 512, "paper": 2048}[scale],
        eval_batch=128,
        local_epochs=5, batch_size=10, lr=1e-3, optimizer="adam", prox_mu=0.01,
        k_max={"smoke": 8, "default": 32, "paper": 256}[scale],
        arch=arch,
    )


def _femnist(scale: str) -> ModelScale:
    arch = {
        "smoke": dict(c1=4, c2=8, fc=32),
        "default": dict(c1=8, c2=16, fc=128),
        "paper": dict(c1=32, c2=64, fc=2048),  # LEAF FEMNIST CNN (§VI-A2)
    }[scale]
    shard = {"smoke": 20, "default": 50, "paper": 226}[scale]  # paper: avg 226/client
    # 226 % 10 != 0 -> paper shard rounded to 230 to keep full batches.
    if scale == "paper":
        shard = 230
    return ModelScale(
        name="femnist", scale=scale,
        input_shape=(28, 28, 1), input_dtype="f32", num_classes=62,
        shard_size=shard, eval_size={"smoke": 128, "default": 512, "paper": 2048}[scale],
        eval_batch=128,
        local_epochs=5, batch_size=10, lr=1e-3, optimizer="adam", prox_mu=0.01,
        k_max={"smoke": 8, "default": 32, "paper": 256}[scale],
        arch=arch,
    )


def _shakespeare(scale: str) -> ModelScale:
    arch = {
        "smoke": dict(embed=8, hidden=16, layers=1),
        "default": dict(embed=8, hidden=32, layers=2),
        "paper": dict(embed=8, hidden=256, layers=2),  # LEAF LSTM (§VI-A2)
    }[scale]
    seq = {"smoke": 10, "default": 20, "paper": 80}[scale]
    return ModelScale(
        name="shakespeare", scale=scale,
        input_shape=(seq,), input_dtype="i32", num_classes=82,
        shard_size={"smoke": 32, "default": 64, "paper": 3744}[scale],  # avg 3743/client
        eval_size={"smoke": 128, "default": 512, "paper": 2048}[scale], eval_batch=128,
        local_epochs=1, batch_size=32, lr=0.8, optimizer="sgd", prox_mu=0.001,
        k_max={"smoke": 8, "default": 32, "paper": 128}[scale],
        arch=arch, seq_len=seq,
    )


def _speech(scale: str) -> ModelScale:
    # The paper trains on 1-second audio; we use a fixed 32x32x1
    # spectrogram-like input (see DESIGN.md substitutions).
    arch = {
        "smoke": dict(c1=4, c2=8, dropout=0.25),
        "default": dict(c1=16, c2=32, dropout=0.25),
        "paper": dict(c1=32, c2=64, dropout=0.25),  # §VI-A2 two-block CNN
    }[scale]
    return ModelScale(
        name="speech", scale=scale,
        input_shape=(32, 32, 1), input_dtype="f32", num_classes=35,
        shard_size={"smoke": 20, "default": 40, "paper": 160}[scale],  # ~4 FedScale clients
        eval_size={"smoke": 128, "default": 512, "paper": 2048}[scale], eval_batch=128,
        local_epochs=5, batch_size=5, lr=1e-3, optimizer="adam", prox_mu=0.01,
        k_max={"smoke": 8, "default": 32, "paper": 256}[scale],
        arch=arch,
    )


def _transformer(scale: str) -> ModelScale:
    # Not in the paper — our end-to-end driver (examples/e2e_train) trains a
    # federated char-transformer to prove all layers compose on a modern
    # workload. ``paper`` here means the largest CPU-feasible e2e config.
    arch = {
        "smoke": dict(d_model=32, layers=1, heads=2, d_ff=64),
        "default": dict(d_model=64, layers=2, heads=4, d_ff=256),
        "paper": dict(d_model=256, layers=6, heads=8, d_ff=1024),
    }[scale]
    seq = {"smoke": 16, "default": 32, "paper": 64}[scale]
    return ModelScale(
        name="transformer", scale=scale,
        input_shape=(seq,), input_dtype="i32", num_classes=96,
        shard_size={"smoke": 32, "default": 64, "paper": 256}[scale],
        eval_size={"smoke": 128, "default": 512, "paper": 1024}[scale], eval_batch=128,
        local_epochs=1, batch_size=16, lr=3e-4, optimizer="adam", prox_mu=0.01,
        k_max={"smoke": 8, "default": 32, "paper": 64}[scale],
        arch=arch, seq_len=seq,
    )


_FAMILIES = {
    "mnist": _mnist,
    "femnist": _femnist,
    "shakespeare": _shakespeare,
    "speech": _speech,
    "transformer": _transformer,
}

SCALES = ("smoke", "default", "paper")
MODELS = tuple(_FAMILIES)


def get_scale(name: str, scale: str = "default") -> ModelScale:
    if name not in _FAMILIES:
        raise KeyError(f"unknown model {name!r}; have {MODELS}")
    if scale not in SCALES:
        raise KeyError(f"unknown scale {scale!r}; have {SCALES}")
    return _FAMILIES[name](scale)
