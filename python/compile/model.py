"""L2: the federated client/aggregator compute graphs.

For each model family+scale this module builds the four jittable functions
that get AOT-lowered to HLO text (DESIGN.md §1 flat-parameter convention):

  train       one *entire local round* (epochs x shard/batch optimizer
              steps via ``lax.scan``) in a single call — the Rust hot loop
              makes exactly one PJRT ``execute`` per client invocation.
  train_prox  same, plus the FedProx proximal term mu/2 ||w - w_g||^2.
              Both variants accept ``num_steps`` for FedProx's
              partial-work toleration (§III-B): steps past the cutoff are
              masked to no-ops.
  eval        central federated evaluation over a fixed test set.
  aggregate   the L1 Pallas staleness-weighted aggregation kernel.

Everything is shape-static: shard size, batch size, epochs, eval size and
k_max come from the scale preset, so one lowered module serves every
client of a deployment.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from compile.archs import build_arch
from compile.archs.common import Arch, accuracy_counts, softmax_xent
from compile.kernels.aggregate import aggregate as pl_aggregate
from compile.optim import make_step
from compile.scales import ModelScale, get_scale

_DTYPES = {"f32": jnp.float32, "i32": jnp.int32}


@dataclass(frozen=True)
class ModelBundle:
    """Everything the AOT driver needs for one model variant."""

    ms: ModelScale
    arch: Arch
    param_count: int
    init_flat: jax.Array  # seed-0 initial flat parameters
    unravel: Callable
    train: Callable  # (params, m, v, t, x, y, seed, num_steps) -> 5-tuple
    train_prox: Callable  # ... + global_params
    eval: Callable  # (params, x, y) -> (loss_sum, correct)
    aggregate: Callable  # (updates [K,P], weights [K]) -> (agg [P],)

    def example_args(self, fn: str):
        """Zero-filled example arguments with the exact lowering shapes."""
        ms = self.ms
        p = self.param_count
        xdt = _DTYPES[ms.input_dtype]
        fl = lambda *s: jnp.zeros(s, jnp.float32)
        il = lambda *s: jnp.zeros(s, jnp.int32)
        xs = (ms.shard_size, *ms.input_shape)
        if fn == "train":
            return (fl(p), fl(p), fl(p), fl(), jnp.zeros(xs, xdt),
                    il(ms.shard_size), il(), il())
        if fn == "train_prox":
            return (fl(p), fl(p), fl(p), fl(), jnp.zeros(xs, xdt),
                    il(ms.shard_size), il(), il(), fl(p))
        if fn == "eval":
            return (fl(p), jnp.zeros((ms.eval_size, *ms.input_shape), xdt),
                    il(ms.eval_size))
        if fn == "aggregate":
            return (fl(ms.k_max, p), fl(ms.k_max))
        raise KeyError(fn)


def _build_train(ms: ModelScale, arch: Arch, unravel, prox: bool):
    """The full-local-round function (Algorithm 1 Client_Update compute)."""
    n, b = ms.shard_size, ms.batch_size
    steps = ms.steps_per_epoch
    total_steps = ms.steps_per_round
    opt_step = make_step(ms.optimizer, ms.lr)
    mu = ms.prox_mu

    def loss_fn(flat, xb, yb, dkey):
        logits = arch.apply(unravel(flat), xb, key=dkey, train=True)
        return softmax_xent(logits, yb)

    grad_fn = jax.value_and_grad(loss_fn)

    def train(params, m, v, t, x, y, seed, num_steps, global_params=None):
        key = jax.random.key(seed.astype(jnp.uint32))
        kperm, kdrop = jax.random.split(key)

        # Per-epoch shuffles, materialized as one [E*steps, B] index table.
        def epoch_idx(k):
            return jax.random.permutation(k, n)[: steps * b].reshape(steps, b)

        idxs = jax.vmap(epoch_idx)(jax.random.split(kperm, ms.local_epochs))
        idxs = idxs.reshape(total_steps, b)

        def body(carry, sx):
            flat, m, v, t, loss_acc = carry
            idx, i = sx
            active = i < num_steps
            xb = jnp.take(x, idx, axis=0)
            yb = jnp.take(y, idx, axis=0)
            loss, g = grad_fn(flat, xb, yb, jax.random.fold_in(kdrop, i))
            if prox:
                g = g + mu * (flat - global_params)
            nflat, nm, nv, nt = opt_step(flat, g, m, v, t)
            sel = lambda a, old: jnp.where(active, a, old)
            carry = (
                sel(nflat, flat), sel(nm, m), sel(nv, v), sel(nt, t),
                loss_acc + jnp.where(active, loss, 0.0),
            )
            return carry, None

        init = (params, m, v, t, jnp.float32(0.0))
        xs = (idxs, jnp.arange(total_steps, dtype=jnp.int32))
        (params, m, v, t, loss_sum), _ = jax.lax.scan(body, init, xs)
        denom = jnp.maximum(num_steps.astype(jnp.float32), 1.0)
        denom = jnp.minimum(denom, float(total_steps))
        return params, m, v, t, loss_sum / denom

    if prox:
        def train_prox(params, m, v, t, x, y, seed, num_steps, global_params):
            return train(params, m, v, t, x, y, seed, num_steps, global_params)
        return train_prox
    return lambda params, m, v, t, x, y, seed, num_steps: train(
        params, m, v, t, x, y, seed, num_steps
    )


def _build_eval(ms: ModelScale, arch: Arch, unravel):
    """Central evaluation: scan over fixed-size eval batches."""
    eb = ms.eval_batch
    nb = ms.eval_size // eb

    def eval_fn(params, x, y):
        flatp = unravel(params)

        def body(carry, i):
            loss_sum, correct = carry
            xb = jax.lax.dynamic_slice_in_dim(x, i * eb, eb, axis=0)
            yb = jax.lax.dynamic_slice_in_dim(y, i * eb, eb, axis=0)
            logits = arch.apply(flatp, xb, key=None, train=False)
            loss_sum = loss_sum + softmax_xent(logits, yb) * eb
            correct = correct + accuracy_counts(logits, yb)
            return (loss_sum, correct), None

        (loss_sum, correct), _ = jax.lax.scan(
            body, (jnp.float32(0.0), jnp.float32(0.0)),
            jnp.arange(nb, dtype=jnp.int32),
        )
        return loss_sum, correct

    return eval_fn


def build_bundle(name: str, scale: str = "default", init_seed: int = 0) -> ModelBundle:
    """Construct the four compute graphs for one (model, scale)."""
    ms = get_scale(name, scale)
    arch = build_arch(ms)
    params0 = arch.init(jax.random.key(init_seed))
    flat0, unravel = ravel_pytree(params0)
    flat0 = flat0.astype(jnp.float32)
    p = int(flat0.size)

    train = _build_train(ms, arch, unravel, prox=False)
    train_prox = _build_train(ms, arch, unravel, prox=True)
    eval_fn = _build_eval(ms, arch, unravel)

    def aggregate(updates, weights):
        return (pl_aggregate(updates, weights),)

    return ModelBundle(
        ms=ms, arch=arch, param_count=p, init_flat=flat0, unravel=unravel,
        train=train, train_prox=train_prox, eval=eval_fn, aggregate=aggregate,
    )
