"""Flat-vector optimizers for the AOT training round.

All optimizer state crosses the Rust<->HLO boundary as flat f32 vectors
(DESIGN.md §1), so the optimizers operate directly on the raveled
parameter vector. SGD carries the (m, v) slots untouched so every model
family exposes the *same* train entrypoint signature regardless of
optimizer — the Rust runtime stays generic.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
State = Tuple[Array, Array, Array, Array]  # (flat, m, v, t)


def adam_step(
    flat: Array, g: Array, m: Array, v: Array, t: Array,
    lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
) -> State:
    """One Adam step with bias correction; ``t`` is the f32 step counter."""
    t = t + 1.0
    m = b1 * m + (1.0 - b1) * g
    v = b2 * v + (1.0 - b2) * (g * g)
    mhat = m / (1.0 - jnp.power(b1, t))
    vhat = v / (1.0 - jnp.power(b2, t))
    flat = flat - lr * mhat / (jnp.sqrt(vhat) + eps)
    return flat, m, v, t


def sgd_step(
    flat: Array, g: Array, m: Array, v: Array, t: Array, lr: float
) -> State:
    """Plain SGD (paper uses lr=0.8 for Shakespeare); m/v pass through."""
    return flat - lr * g, m, v, t + 1.0


def make_step(optimizer: str, lr: float):
    """Return ``(flat, g, m, v, t) -> (flat, m, v, t)`` for the config."""
    if optimizer == "adam":
        return lambda flat, g, m, v, t: adam_step(flat, g, m, v, t, lr)
    if optimizer == "sgd":
        return lambda flat, g, m, v, t: sgd_step(flat, g, m, v, t, lr)
    raise ValueError(f"unknown optimizer {optimizer!r}")
