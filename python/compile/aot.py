"""AOT driver: lower the L2 compute graphs to HLO text artifacts.

For every (model, scale) this emits into the artifacts directory:

  <model>.train.hlo.txt        full local training round
  <model>.train_prox.hlo.txt   FedProx variant
  <model>.eval.hlo.txt         central evaluation
  <model>.aggregate.hlo.txt    Pallas staleness-weighted aggregation
  <model>.init.bin             seed-0 initial flat parameters (f32 LE)
  <model>.manifest.json        shapes, dtypes, hyperparameters, file map
  index.json                   list of built manifests

Interchange format is **HLO text**, not a serialized HloModuleProto: the
``xla`` crate links xla_extension 0.5.1, which rejects the 64-bit
instruction ids jax >= 0.5 writes into protos (``proto.id() <= INT_MAX``).
The text parser reassigns ids, so text round-trips cleanly — see
/opt/xla-example/README.md.

Python runs exactly once (``make artifacts``); the Rust binary is
self-contained afterwards.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import sys
import time
from pathlib import Path

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from compile.model import ModelBundle, build_bundle
from compile.scales import MODELS, SCALES

# Input/output name lists per entrypoint; the Rust runtime relies on this
# ordering (it matches the positional args of the lowered functions).
ENTRYPOINT_IO = {
    "train": (
        ["params", "m", "v", "t", "x", "y", "seed", "num_steps"],
        ["params", "m", "v", "t", "loss"],
    ),
    "train_prox": (
        ["params", "m", "v", "t", "x", "y", "seed", "num_steps", "global"],
        ["params", "m", "v", "t", "loss"],
    ),
    "eval": (["params", "x", "y"], ["loss_sum", "correct"]),
    "aggregate": (["updates", "weights"], ["agg"]),
}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _tuple_wrap(fn):
    """Ensure the lowered root is a tuple even for multi-output fns."""

    def wrapped(*args):
        out = fn(*args)
        return out if isinstance(out, tuple) else (out,)

    return wrapped


def export_model(
    name: str, scale: str, out_dir: Path, *, init_seed: int = 0, quiet: bool = False
) -> dict:
    """Lower one model's four entrypoints and write all artifacts."""
    t0 = time.time()
    bundle: ModelBundle = build_bundle(name, scale, init_seed=init_seed)
    ms = bundle.ms
    out_dir.mkdir(parents=True, exist_ok=True)

    files = {}
    for fn_name in ("train", "train_prox", "eval", "aggregate"):
        fn = getattr(bundle, fn_name)
        args = bundle.example_args(fn_name)
        lowered = jax.jit(_tuple_wrap(fn)).lower(*args)
        text = to_hlo_text(lowered)
        fname = f"{name}.{fn_name}.hlo.txt"
        (out_dir / fname).write_text(text)
        inputs, outputs = ENTRYPOINT_IO[fn_name]
        files[fn_name] = {"file": fname, "inputs": inputs, "outputs": outputs}
        if not quiet:
            print(f"  {fname}: {len(text) / 1024:.0f} KiB")

    init_bytes = np.asarray(bundle.init_flat, dtype="<f4").tobytes()
    init_file = f"{name}.init.bin"
    (out_dir / init_file).write_bytes(init_bytes)

    manifest = {
        "name": name,
        "scale": scale,
        "param_count": bundle.param_count,
        "num_classes": ms.num_classes,
        "input_shape": list(ms.input_shape),
        "input_dtype": ms.input_dtype,
        "shard_size": ms.shard_size,
        "batch_size": ms.batch_size,
        "local_epochs": ms.local_epochs,
        "steps_per_round": ms.steps_per_round,
        "optimizer": ms.optimizer,
        "lr": ms.lr,
        "prox_mu": ms.prox_mu,
        "eval_size": ms.eval_size,
        "eval_batch": ms.eval_batch,
        "k_max": ms.k_max,
        "seq_len": ms.seq_len,
        # rough fwd+bwd flop estimate per local round, for the cost model
        "flops_per_round": 6 * bundle.param_count * ms.batch_size * ms.steps_per_round,
        "entrypoints": files,
        "init_file": init_file,
        "init_sha256": hashlib.sha256(init_bytes).hexdigest(),
        "init_seed": init_seed,
    }
    mf = out_dir / f"{name}.manifest.json"
    mf.write_text(json.dumps(manifest, indent=2))
    if not quiet:
        print(
            f"  {name}/{scale}: P={bundle.param_count} "
            f"({time.time() - t0:.1f}s)"
        )
    return manifest


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--scale", default="default", choices=SCALES)
    ap.add_argument(
        "--models", default="all",
        help=f"comma list from {MODELS} or 'all'",
    )
    ap.add_argument("--init-seed", type=int, default=0)
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    names = list(MODELS) if args.models == "all" else args.models.split(",")
    for n in names:
        if n not in MODELS:
            ap.error(f"unknown model {n!r}; have {MODELS}")
    out_dir = Path(args.out_dir)
    manifests = []
    for n in names:
        print(f"[aot] exporting {n} @ {args.scale} ...")
        manifests.append(export_model(n, args.scale, out_dir, init_seed=args.init_seed,
                                      quiet=args.quiet))
    index = {
        "scale": args.scale,
        "models": [m["name"] for m in manifests],
        "manifests": {m["name"]: f"{m['name']}.manifest.json" for m in manifests},
    }
    (out_dir / "index.json").write_text(json.dumps(index, indent=2))
    print(f"[aot] wrote {len(manifests)} model(s) to {out_dir}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
