//! Micro benchmarks for the L3 coordinator hot paths (DESIGN.md §4):
//! client selection (clustering + ε grid search), behaviour features,
//! staleness weights, dataset synthesis, JSON, and the native-backend
//! aggregation kernel across K and P.
//!
//!   cargo bench --bench micro
//!
//! Uses the built-in harness (util::bench); criterion is unavailable in
//! this offline environment.

use fedless::clientdb::HistoryStore;
use fedless::clustering::{cluster_clients, dbscan, dbscan_naive, DbscanParams};
use fedless::data::{Partition, SynthDataset};
use fedless::params::fold_weighted_into;
use fedless::paramsvr::{staleness_weights, WeightedUpdate};
use fedless::runtime::{Backend, NativeBackend};
use fedless::strategy::{ema, FedLesScan, SelectionContext, Strategy};
use fedless::util::bench::bench;
use fedless::util::{Json, Rng};

fn history_with(n: usize, rng: &mut Rng) -> HistoryStore {
    let mut h = HistoryStore::new();
    for c in 0..n {
        for r in 0..10u32 {
            h.record_invocation(c);
            if rng.bernoulli(0.8) {
                h.record_success(c, r, rng.range_f64(5.0, 90.0));
            } else {
                h.record_failure(c, r);
            }
        }
    }
    h
}

fn main() {
    println!("== micro benches (L3 coordinator) ==");
    let mut rng = Rng::seed_from_u64(1);

    // --- FedLesScan selection at paper scale (TAB2 selection cost) -----
    for &n in &[60usize, 200, 542] {
        let hist = history_with(n, &mut rng);
        let clients: Vec<usize> = (0..n).collect();
        let mut strat = FedLesScan::default();
        let k = (n / 3).max(4);
        let mut r = Rng::seed_from_u64(2);
        bench(&format!("select/fedlesscan n={n} k={k}"), 3, 30, || {
            let ctx = SelectionContext {
                round: 5,
                max_rounds: 20,
                clients_per_round: k,
                all_clients: &clients,
                history: &hist,
            };
            strat.select(&ctx, &mut r)
        });
    }

    // --- DBSCAN + CH grid search alone ---------------------------------
    for &n in &[50usize, 200, 500] {
        let pts: Vec<Vec<f64>> = (0..n)
            .map(|i| {
                let c = (i % 3) as f64 * 30.0;
                vec![c + rng.range_f64(0.0, 3.0), rng.range_f64(0.0, 3.0)]
            })
            .collect();
        bench(&format!("cluster/grid-search n={n}"), 3, 20, || {
            cluster_clients(&pts, 2)
        });
    }

    // --- fleet-scale DBSCAN: naive O(n²) scan vs grid index --------------
    // Behaviour-shaped data: many bounded-density blobs (client speed
    // cohorts), blob centres far apart relative to ε. The naive 100k row
    // is the slow one (~10^10 distance computations per pass) — it runs
    // once, uncooked, purely to put the speedup on record.
    for &n in &[1_000usize, 10_000, 100_000] {
        let blobs = (n / 100).max(1);
        let pts: Vec<Vec<f64>> = (0..n)
            .map(|i| {
                let b = i % blobs;
                let cx = (b % 330) as f64 * 40.0;
                let cy = (b / 330) as f64 * 40.0;
                vec![cx + rng.range_f64(-0.4, 0.4), cy + rng.range_f64(-0.4, 0.4)]
            })
            .collect();
        let params = DbscanParams {
            eps: 0.5,
            min_pts: 4,
        };
        let (gw, gi) = if n >= 100_000 { (1, 3) } else { (2, 10) };
        let grid = bench(&format!("cluster/dbscan-grid n={n}"), gw, gi, || {
            dbscan(&pts, &params)
        });
        let (nw, ni) = if n >= 100_000 {
            (0, 1)
        } else if n >= 10_000 {
            (0, 2)
        } else {
            (1, 5)
        };
        let naive = bench(&format!("cluster/dbscan-naive n={n}"), nw, ni, || {
            dbscan_naive(&pts, &params)
        });
        println!(
            "   -> grid speedup {:.1}x over naive at n={n}",
            naive.mean.as_secs_f64() / grid.mean.as_secs_f64().max(1e-12)
        );
    }

    // --- fleet-scale selection: tiering + cohort clustering --------------
    for &n in &[1_000usize, 10_000, 100_000] {
        let hist = history_with(n, &mut rng);
        let clients: Vec<usize> = (0..n).collect();
        let mut strat = FedLesScan::default();
        let k = 256.min(n / 4).max(4);
        let mut r = Rng::seed_from_u64(3);
        bench(&format!("select/fedlesscan-fleet n={n} k={k}"), 2, 8, || {
            let ctx = SelectionContext {
                round: 5,
                max_rounds: 40,
                clients_per_round: k,
                all_clients: &clients,
                history: &hist,
            };
            strat.select(&ctx, &mut r)
        });
    }

    // --- behaviour features --------------------------------------------
    let times: Vec<f64> = (0..64).map(|i| 10.0 + (i % 7) as f64).collect();
    bench("features/ema len=64", 10, 1000, || ema(&times, 0.5));

    // --- Eq. 3 staleness weights ----------------------------------------
    let updates: Vec<WeightedUpdate> = (0..256)
        .map(|i| WeightedUpdate {
            produced_round: 10 - (i % 3) as u32,
            cardinality: 50 + i % 100,
        })
        .collect();
    bench("aggregate/weights k=256", 10, 2000, || {
        staleness_weights(&updates, 10, 2, true)
    });

    // --- dataset synthesis (per-client shard, mnist-shaped) -------------
    let ds = SynthDataset::new(
        64, 50, 512, 10, vec![28, 28, 1], false, 3, Partition::LabelShard,
    )
    .unwrap();
    bench("data/synthesize shard 50x784", 3, 50, || ds.client_data(7));

    // --- JSON (manifest-sized documents) --------------------------------
    let doc = {
        let entries: Vec<Json> = (0..50)
            .map(|i| {
                Json::obj(vec![
                    ("round", Json::num(i as f64)),
                    ("eur", Json::num(0.9)),
                    ("cost", Json::num(0.0123)),
                ])
            })
            .collect();
        Json::obj(vec![("rounds", Json::Arr(entries))]).to_string_pretty()
    };
    bench("json/parse 50-round result", 10, 500, || {
        Json::parse(&doc).unwrap()
    });

    // --- native aggregation kernel across K and P ------------------------
    for model in ["mnist", "femnist"] {
        let rt = NativeBackend::for_dataset(model).expect("native backend");
        let p = rt.manifest().param_count;
        for k in [2usize, 8, 16] {
            let updates: Vec<Vec<f32>> = (0..k)
                .map(|i| (0..p).map(|j| ((i + j) % 17) as f32 * 0.01).collect())
                .collect();
            let refs: Vec<&[f32]> = updates.iter().map(|u| u.as_slice()).collect();
            let w: Vec<f32> = (0..k).map(|_| 1.0 / k as f32).collect();
            bench(
                &format!("aggregate/native {model} P={p} K={k}"),
                2,
                15,
                || rt.aggregate(&refs, &w).unwrap(),
            );
        }
    }

    // --- params fold: scalar vs chunk-parallel weighted sum --------------
    // The aggregation hot path of the zero-copy parameter plane. The
    // 1-worker case IS the batch scalar reference op for op, so the
    // speedup line is the scalar-vs-chunked comparison. Sized at the
    // largest preset's (P, k_max) plus a north-star ~1M-param case.
    // Honesty note: the coordinator streams one entry per fold call, and
    // the fold-worker heuristic prices the whole fold (P x expected_k
    // multiply-adds) once at begin_fold — a preset-sized model fans out
    // when the round's total work warrants it, even though each streamed
    // entry alone is below the parallel threshold. The crossover line
    // below pins the k at which a preset-sized fold goes parallel; the
    // ~1M-param row is where even a single entry does. Each printout
    // discloses the heuristic's choice at both prices.
    {
        let largest = ["mnist", "femnist", "shakespeare", "speech", "transformer"]
            .iter()
            .map(|d| NativeBackend::for_dataset(d).expect("preset"))
            .max_by_key(|b| b.manifest().param_count)
            .expect("presets");
        let workers = fedless::params::default_workers();
        for (p, k) in [
            (largest.manifest().param_count, largest.manifest().k_max),
            (1 << 20, 8),
        ] {
            let updates: Vec<Vec<f32>> = (0..k)
                .map(|i| (0..p).map(|j| ((i + j) % 17) as f32 * 0.01 - 0.05).collect())
                .collect();
            let entries: Vec<(&[f32], f32)> = updates
                .iter()
                .map(|u| (u.as_slice(), 1.0 / k as f32))
                .collect();
            let serial = bench(&format!("params/fold P={p} K={k} scalar"), 2, 12, || {
                let mut acc = vec![0.0f32; p];
                fold_weighted_into(&mut acc, &entries, 1);
                acc
            });
            let chunked = bench(
                &format!("params/fold P={p} K={k} chunked x{workers}"),
                2,
                12,
                || {
                    let mut acc = vec![0.0f32; p];
                    fold_weighted_into(&mut acc, &entries, workers);
                    acc
                },
            );
            println!(
                "   -> chunk-parallel speedup: {:.2}x over scalar ({workers} workers; \
                 heuristic picks {} worker(s) at k=1, {} at k={k})",
                serial.mean.as_secs_f64() / chunked.mean.as_secs_f64().max(1e-12),
                fedless::params::fold_workers(p, 1),
                fedless::params::fold_workers(p, k),
            );
        }

        // Pin the fan-out crossover for the smallest preset: the first k
        // at which the round-priced heuristic sends a streamed fold
        // parallel (BENCH_params.json `crossover_k` regeneration source).
        let mnist_p = NativeBackend::for_dataset("mnist")
            .expect("preset")
            .manifest()
            .param_count;
        if workers >= 2 {
            let crossover = (1..=1024)
                .find(|&k| fedless::params::fold_workers(mnist_p, k) > 1)
                .unwrap_or(0);
            println!(
                "   -> fold_workers crossover: mnist P={mnist_p} goes parallel at \
                 k={crossover} ({} workers at that k)",
                fedless::params::fold_workers(mnist_p, crossover),
            );
        } else {
            println!("   -> fold_workers crossover: skipped (single-core host)");
        }
    }

    // --- native client round (P-scale training cost) ---------------------
    let rt = NativeBackend::for_dataset("mnist").expect("native backend");
    let mf = rt.manifest().clone();
    let ds = SynthDataset::from_manifest(&mf, 4, 1, Partition::LabelShard).unwrap();
    let shard = ds.client_data(0);
    let p0 = rt.init_params().unwrap();
    let zeros = vec![0f32; p0.len()];
    bench(
        &format!("train/native mnist P={} steps={}", mf.param_count, mf.steps_per_round),
        2,
        15,
        || {
            rt.train_round(&fedless::runtime::TrainRequest {
                params: &p0,
                m: &zeros,
                v: &zeros,
                t: 0.0,
                x: &shard.x,
                y: &shard.y,
                seed: 1,
                num_steps: mf.steps_per_round as i32,
                global: None,
            })
            .unwrap()
        },
    );

    // --- scheduler event queue (virtual-clock replay cost) ---------------
    use fedless::faas::Outcome;
    use fedless::sched::{CompletionEvent, EventQueue};
    for &n in &[100usize, 10_000] {
        let mut r = Rng::seed_from_u64(7);
        let events: Vec<CompletionEvent> = (0..n)
            .map(|seq| CompletionEvent {
                at_s: r.range_f64(0.0, 1e6),
                seq,
                client: seq,
                outcome: Outcome::OnTime,
            })
            .collect();
        bench(&format!("sched/event-queue push+drain n={n}"), 3, 30, || {
            let mut q = EventQueue::new();
            for &ev in &events {
                q.push(ev);
            }
            let mut last = f64::NEG_INFINITY;
            while let Some(ev) = q.pop() {
                debug_assert!(ev.at_s >= last);
                last = ev.at_s;
            }
            last
        });
    }
}
