//! Kernel-plane benchmarks: scalar vs runtime-dispatched AVX2
//! microkernels at every preset model's GEMM shapes plus the ~1M-param
//! fold/optimizer hot loops. These are the numbers behind
//! `BENCH_kernels.json` (regenerate with `cargo bench --bench kernels`).
//!
//! The vector kernels are *bit-identical* to the scalar path (the
//! proptests and goldens pin that), so this sweep is pure throughput:
//! any row where avx2 loses to scalar is a regression, not a tradeoff.

use fedless::runtime::kernel::{avx2_available, AdamParams, Kernel};
use fedless::util::bench::bench;

/// (name, batch, d, h, c) — the per-preset MLP shapes the native
/// backend trains (see `native.rs` presets).
const SHAPES: [(&str, usize, usize, usize, usize); 5] = [
    ("mnist", 10, 784, 32, 10),
    ("femnist", 10, 784, 32, 62),
    ("shakespeare", 32, 10, 32, 82),
    ("speech", 5, 1024, 32, 35),
    ("transformer", 16, 16, 64, 96),
];

const FOLD_P: usize = 1 << 20; // ~1M params, the north-star plane size

fn ramp(len: usize, phase: usize) -> Vec<f32> {
    (0..len)
        .map(|i| ((i + phase) % 23) as f32 * 0.017 - 0.19)
        .collect()
}

fn kernels() -> Vec<Kernel> {
    if avx2_available() {
        vec![Kernel::Scalar, Kernel::Avx2]
    } else {
        println!("   (host lacks AVX2: scalar rows only)");
        vec![Kernel::Scalar]
    }
}

fn main() {
    println!("== kernel-plane benches ==");
    let kernels = kernels();

    for (name, bs, d, h, c) in SHAPES {
        let x = ramp(bs * d, 1);
        let w1 = ramp(d * h, 2);
        let b1 = ramp(h, 3);
        let w2 = ramp(h * c, 4);
        let b2 = ramp(c, 5);
        let dz2 = ramp(bs * c, 6);
        let mut z1 = vec![0.0f32; bs * h];
        let mut a1 = vec![0.0f32; bs * h];
        let mut z2 = vec![0.0f32; bs * c];
        let mut gw1 = vec![0.0f32; d * h];
        let mut w2t = vec![0.0f32; c * h];
        let mut da1 = vec![0.0f32; bs * h];

        let mut base = f64::NAN;
        for &kr in &kernels {
            // the per-step GEMM chain of one training batch: fused
            // hidden forward, logits forward, weight grad, act grad
            let stats = bench(
                &format!("kernels/gemm-chain {name} bs={bs} d={d} h={h} c={c} kernel={}", kr.name()),
                3,
                40,
                || {
                    kr.matmul_bias_relu(&x, &w1, &b1, d, h, &mut z1, &mut a1);
                    kr.matmul_bias(&a1, &w2, &b2, h, c, &mut z2);
                    kr.matmul_at_b(&x, &da1, d, h, &mut gw1);
                    kr.matmul_a_bt(&dz2, &w2, c, h, &mut w2t, &mut da1);
                    z2[0]
                },
            );
            let s = stats.mean.as_secs_f64();
            if kr == Kernel::Scalar {
                base = s;
            } else {
                println!("   -> {name}: {:.2}x vs scalar", base / s.max(1e-12));
            }
        }
    }

    // --- ~1M-param element-wise hot loops --------------------------------
    let u = ramp(FOLD_P, 7);
    let g = ramp(FOLD_P, 11);
    let mut base_fold = f64::NAN;
    let mut base_adam = f64::NAN;
    for &kr in &kernels {
        let mut acc = vec![0.0f32; FOLD_P];
        let stats = bench(
            &format!("kernels/fold-axpy P={FOLD_P} kernel={}", kr.name()),
            2,
            24,
            || {
                kr.axpy(&mut acc, &u, 0.125);
                acc[0]
            },
        );
        let s = stats.mean.as_secs_f64();
        let madds_per_s = FOLD_P as f64 / s.max(1e-12);
        println!("   -> {:.1} M madd/s ({})", madds_per_s / 1e6, kr.name());
        if kr == Kernel::Scalar {
            base_fold = s;
        } else {
            println!("   -> fold-axpy: {:.2}x vs scalar", base_fold / s.max(1e-12));
        }

        let mut w = ramp(FOLD_P, 13);
        let mut m = vec![0.0f32; FOLD_P];
        let mut v = vec![0.0f32; FOLD_P];
        let p = AdamParams {
            lr: 1e-3,
            b1: 0.9,
            b2: 0.999,
            eps: 1e-7,
            bc1: 1.0 - 0.9f32.powf(3.0),
            bc2: 1.0 - 0.999f32.powf(3.0),
        };
        let stats = bench(
            &format!("kernels/adam-step P={FOLD_P} kernel={}", kr.name()),
            2,
            24,
            || {
                kr.adam_step(&mut w, &g, &mut m, &mut v, p);
                w[0]
            },
        );
        let s = stats.mean.as_secs_f64();
        if kr == Kernel::Scalar {
            base_adam = s;
        } else {
            println!("   -> adam-step: {:.2}x vs scalar", base_adam / s.max(1e-12));
        }
    }
}
