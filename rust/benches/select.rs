//! Fleet-scale selection benchmarks: from-scratch clustering (cold
//! persistent plane, full ε search + grid DBSCAN every call) vs the
//! incremental path (warm plane, recluster work proportional to the
//! drift since the last selection) at 10k / 100k / 1M clients and
//! 1% / 10% / 50% per-round behaviour drift — the numbers behind
//! BENCH_select.json.
//!
//!   cargo bench --bench select
//!
//! The fleet geometry is componentized (one giant behaviour blob that
//! anchors the ε grid search's low quantiles, plus many small blobs
//! separated far beyond any winning ε), so drift events recluster only
//! the blobs they land in — the same shape `tests/scale.rs` pins.

use fedless::clientdb::HistoryStore;
use fedless::strategy::{FedLesScan, SelectionContext, Strategy};
use fedless::util::bench::bench;
use fedless::util::Rng;
use fedless::ClientId;

/// Behaviour-blob center for client `c` in a fleet of `n`: 40% of the
/// fleet in one tight giant blob, the rest in 1000-client small blobs
/// 50 virtual seconds apart.
fn blob_center(c: usize, n: usize) -> f64 {
    let giant = n * 2 / 5;
    if c < giant {
        10.0
    } else {
        500.0 + ((c - giant) / 1000) as f64 * 50.0
    }
}

/// Deterministic componentized fleet history (see tests/scale.rs).
fn fleet(n: usize) -> HistoryStore {
    let mut hist = HistoryStore::new();
    for c in 0..n {
        if c % 5000 == 0 {
            continue; // sparse rookie sliver
        }
        let center = blob_center(c, n);
        let j1 = (c % 197) as f64 / 197.0 - 0.5;
        let j2 = ((c * 13) % 197) as f64 / 197.0 - 0.5;
        hist.record_invocation(c);
        hist.record_success(c, 0, center + j1);
        hist.record_invocation(c);
        hist.record_success(c, 1, center + j2);
    }
    hist
}

fn ctx<'a>(
    clients: &'a [ClientId],
    h: &'a HistoryStore,
    round: u32,
    k: usize,
) -> SelectionContext<'a> {
    SelectionContext {
        round,
        max_rounds: 10_000,
        clients_per_round: k,
        all_clients: clients,
        history: h,
    }
}

fn main() {
    println!("== fleet-scale selection benches ==");
    let k = 256usize;
    for &n in &[10_000usize, 100_000, 1_000_000] {
        let clients: Vec<ClientId> = (0..n).collect();
        let iters = if n >= 1_000_000 { 2 } else { 5 };

        // -- from-scratch baseline: cold plane, full build every call --
        let hist = fleet(n);
        let cold = bench(&format!("select/from-scratch {n} clients"), 1, iters, || {
            let mut s = FedLesScan::with_incremental();
            let mut rng = Rng::seed_from_u64(7);
            s.select(&ctx(&clients, &hist, 10, k), &mut rng)
        });

        // -- incremental: warm plane, per-call drift then select --------
        for &frac in &[0.01f64, 0.10, 0.50] {
            let mut hist = fleet(n);
            let mut s = FedLesScan::with_incremental();
            let mut rng = Rng::seed_from_u64(7);
            let mut round = 10u32;
            let _ = s.select(&ctx(&clients, &hist, round, k), &mut rng); // warm build
            let _ = s.take_select_report();
            let m = ((n as f64) * frac).round() as usize;
            let mut cursor = 0usize;
            let mut reclustered_last = 0usize;
            let warm = bench(
                &format!(
                    "select/incremental {n} clients {:.0}% drift",
                    frac * 100.0
                ),
                1,
                iters,
                || {
                    // fresh successes for m clients, times staying inside
                    // their blob so drift cost tracks touched components
                    for i in 0..m {
                        let c = (cursor + i) % n;
                        let j = ((c.wrapping_mul(31).wrapping_add(round as usize)) % 197)
                            as f64
                            / 197.0
                            - 0.5;
                        hist.record_invocation(c);
                        hist.record_success(c, round, blob_center(c, n) + j);
                    }
                    cursor = (cursor + m) % n;
                    round += 1;
                    let sel = s.select(&ctx(&clients, &hist, round, k), &mut rng);
                    if let Some(rep) = s.take_select_report() {
                        reclustered_last = rep.reclustered_clients;
                    }
                    sel
                },
            );
            println!(
                "   -> {:.2}x vs from-scratch at {:.0}% drift ({} reclustered of {n} last pass)",
                cold.mean.as_secs_f64() / warm.mean.as_secs_f64().max(1e-12),
                frac * 100.0,
                reclustered_last,
            );
        }
    }
}
