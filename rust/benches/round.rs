//! End-to-end benchmarks: one full federated round per (dataset,
//! strategy), plus the per-client local-training execution — the numbers
//! behind Tables II-IV's wall-clock feasibility and the §Perf log in
//! EXPERIMENTS.md.
//!
//!   cargo bench --bench round            # native backend, no artifacts
//!
//! With a `--features pjrt` build and `make artifacts`, the same shapes
//! run through the PJRT backend via `fedless train --backend pjrt`.

use fedless::config::{ExperimentConfig, Scenario};
use fedless::coordinator::Controller;
use fedless::data::SynthDataset;
use fedless::runtime::{Backend, NativeBackend, TrainRequest};
use fedless::sched;
use fedless::strategy::StrategyKind;
use fedless::util::bench::bench;

fn main() {
    println!("== end-to-end benches (native backend) ==");

    // --- parallel vs serial client execution (the sched speedup) -------
    // An 8-client round of real local training: 1 worker reproduces the
    // serial seed path; the parallel pool must beat it wall-clock on any
    // multi-core host.
    {
        let rt = NativeBackend::for_dataset("mnist").expect("native backend");
        let mf = rt.manifest().clone();
        let n_clients = 8usize;
        let data = SynthDataset::from_manifest(&mf, n_clients, 1, Default::default()).unwrap();
        let shards: Vec<_> = (0..n_clients).map(|c| data.client_data(c)).collect();
        let p0 = rt.init_params().unwrap();
        let zeros = vec![0f32; p0.len()];
        let jobs: Vec<Option<TrainRequest>> = shards
            .iter()
            .enumerate()
            .map(|(i, shard)| {
                Some(TrainRequest {
                    params: &p0,
                    m: &zeros,
                    v: &zeros,
                    t: 0.0,
                    x: &shard.x,
                    y: &shard.y,
                    seed: i as i32,
                    num_steps: mf.steps_per_round as i32,
                    global: None,
                })
            })
            .collect();
        let workers = sched::default_workers();
        let serial = bench(
            &format!("sched/train {n_clients} clients serial (1 worker)"),
            1,
            8,
            || sched::train_parallel_with(&rt, &jobs, 1).unwrap(),
        );
        let parallel = bench(
            &format!("sched/train {n_clients} clients parallel ({workers} workers)"),
            1,
            8,
            || sched::train_parallel(&rt, &jobs).unwrap(),
        );
        println!(
            "   -> parallel speedup: {:.2}x over serial ({} workers)",
            serial.mean.as_secs_f64() / parallel.mean.as_secs_f64().max(1e-12),
            workers
        );
    }

    for model in ["mnist", "femnist", "shakespeare", "speech", "transformer"] {
        let rt = NativeBackend::for_dataset(model).expect("native backend");
        let mf = rt.manifest().clone();

        // --- single client local round (the dominant compute) ----------
        let data = SynthDataset::from_manifest(&mf, 4, 1, Default::default()).unwrap();
        let shard = data.client_data(0);
        let p0 = rt.init_params().unwrap();
        let zeros = vec![0f32; p0.len()];
        bench(
            &format!("client-round/{model} P={} steps={}", mf.param_count, mf.steps_per_round),
            2,
            10,
            || {
                rt.train_round(&TrainRequest {
                    params: &p0,
                    m: &zeros,
                    v: &zeros,
                    t: 0.0,
                    x: &shard.x,
                    y: &shard.y,
                    seed: 1,
                    num_steps: mf.steps_per_round as i32,
                    global: None,
                })
                .unwrap()
            },
        );

        // --- central evaluation ----------------------------------------
        let eval = data.eval_data();
        bench(&format!("eval/{model} M={}", mf.eval_size), 2, 10, || {
            rt.evaluate(&p0, &eval.x, &eval.y).unwrap()
        });
    }

    // --- one full coordinator round per strategy (mnist) ---------------
    let rt = NativeBackend::for_dataset("mnist").expect("native backend");
    for strategy in [
        StrategyKind::Fedavg,
        StrategyKind::Fedprox,
        StrategyKind::Fedlesscan,
    ] {
        bench(
            &format!("full-round/mnist {} (8 clients)", strategy.as_str()),
            1,
            5,
            || {
                let mut cfg = ExperimentConfig::preset("mnist");
                cfg.strategy = strategy;
                cfg.scenario = Scenario::Straggler(30);
                cfg.rounds = 1;
                cfg.n_clients = 16;
                cfg.clients_per_round = 8;
                let mut ctl = Controller::new(cfg, &rt).unwrap();
                ctl.run().unwrap()
            },
        );
    }
}
