//! Parameter-plane benchmarks: streamed fold throughput vs shard count
//! at a north-star ~1M-param model, and the int8 quantized wire against
//! raw f32 uploads. These are the numbers behind `BENCH_params.json`
//! (regenerate with `cargo bench --bench params`).
//!
//! The sharded accumulator must (a) stay bit-identical to the scalar
//! oracle at every shard count — the proptests pin that — and (b) scale
//! fold throughput with shards until the core count caps it. The int8
//! wire must cut accounted upload bytes ~4x dense (and further with
//! top-k) while the client-side error-feedback residual keeps the
//! cumulative transmitted signal honest.

use fedless::params::{
    default_workers, dequantize, quantize, quantize_topk, wire_bytes_estimate, ErrorFeedback,
    ShardLayout, ShardedAccumulator,
};
use fedless::util::bench::bench;

const P: usize = 1 << 20; // ~1M params, the north-star plane size
const K: usize = 8; // streamed entries per fold (per-round survivors)

fn main() {
    println!("== parameter-plane benches (P={P}, K={K}) ==");
    let workers = default_workers();

    let updates: Vec<Vec<f32>> = (0..K)
        .map(|i| {
            (0..P)
                .map(|j| ((i + j) % 17) as f32 * 0.01 - 0.05)
                .collect()
        })
        .collect();
    let weight = 1.0 / K as f32;

    // --- streamed fold throughput vs shard count -------------------------
    // One accumulate() call per entry, exactly how the coordinator feeds
    // NativeFold; every shard count lands bit-identical, so this sweep
    // is pure throughput.
    let mut base = f64::NAN;
    for shards in [1usize, 2, 4, 8, 16] {
        let stats = bench(&format!("params/fold P={P} K={K} shards={shards}"), 2, 12, || {
            let acc = ShardedAccumulator::new(ShardLayout::new(P, shards));
            for u in &updates {
                acc.accumulate(u, weight, workers);
            }
            acc.finish()
        });
        let s = stats.mean.as_secs_f64();
        if shards == 1 {
            base = s;
        }
        let madds_per_s = (P * K) as f64 / s.max(1e-12);
        println!(
            "   -> {:.1} M madd/s at {shards} shard(s), {:.2}x vs 1 shard",
            madds_per_s / 1e6,
            base / s.max(1e-12),
        );
    }

    // --- int8 wire: encode cost and accounted bytes ----------------------
    let shards = 16usize;
    let layout = ShardLayout::new(P, shards);
    let raw_bytes = P * std::mem::size_of::<f32>();

    let dense = quantize(&updates[0], &layout);
    bench(&format!("params/quantize dense P={P} shards={shards}"), 2, 12, || {
        quantize(&updates[0], &layout)
    });
    bench(&format!("params/dequantize dense P={P} shards={shards}"), 2, 12, || {
        dequantize(&dense, &layout)
    });
    assert_eq!(dense.wire_bytes(), wire_bytes_estimate(P, shards, None));
    println!(
        "   -> dense int8 wire: {} B vs raw {} B ({:.2}x cut)",
        dense.wire_bytes(),
        raw_bytes,
        raw_bytes as f64 / dense.wire_bytes() as f64,
    );

    let frac = 0.1;
    let sparse = quantize_topk(&updates[0], &layout, frac);
    bench(
        &format!("params/quantize topk={frac} P={P} shards={shards}"),
        2,
        12,
        || quantize_topk(&updates[0], &layout, frac),
    );
    println!(
        "   -> top-{frac} int8 wire: {} B vs raw {} B ({:.2}x cut)",
        sparse.wire_bytes(),
        raw_bytes,
        raw_bytes as f64 / sparse.wire_bytes() as f64,
    );

    // --- error-feedback round trip (the full client-side wire path) ------
    bench(&format!("params/ef-encode+decode P={P} shards={shards}"), 2, 12, || {
        let mut ef = ErrorFeedback::new(P);
        let q = ef.encode(&updates[0], &layout, None);
        dequantize(&q, &layout)
    });
}
