//! Executor-plane benchmarks: spawn-per-round (the historical
//! `sched::train_parallel` scoped-spawn path, kept as the bit-for-bit
//! reference) vs the persistent [`fedless::exec::ExecutorPool`], at
//! 8 / 64 / 512-client batch sizes, plus continuous-mode update
//! throughput. These are the numbers behind `BENCH_executor.json`
//! (regenerate with `cargo bench --bench executor`).
//!
//! The pool should match or beat spawn-per-round at every size: it pays
//! thread creation once per experiment instead of once per round, and
//! its work-stealing queue keeps all workers busy when per-client
//! training times are uneven.

use std::sync::Arc;

use fedless::config::{ExperimentConfig, Mode, Scenario};
use fedless::coordinator::Controller;
use fedless::data::SynthDataset;
use fedless::exec::{ExecutorPool, TrainJob};
use fedless::params::ParamBlock;
use fedless::runtime::{Backend, NativeBackend, TrainRequest};
use fedless::sched;
use fedless::strategy::StrategyKind;
use fedless::util::bench::bench;

fn main() {
    println!("== executor-plane benches (native backend) ==");

    let rt = NativeBackend::for_dataset("mnist").expect("native backend");
    let mf = rt.manifest().clone();
    let workers = sched::default_workers();

    for &n_clients in &[8usize, 64, 512] {
        let data =
            SynthDataset::from_manifest(&mf, n_clients, 1, Default::default()).unwrap();
        let shards: Vec<Arc<_>> = (0..n_clients)
            .map(|c| Arc::new(data.client_data(c)))
            .collect();
        let p0 = rt.init_params().unwrap();
        let zeros = vec![0f32; p0.len()];
        let block: ParamBlock = p0.clone().into();

        // spawn-per-round reference: a fresh scoped-thread fleet per call
        let spawn_jobs: Vec<Option<TrainRequest>> = shards
            .iter()
            .enumerate()
            .map(|(i, shard)| {
                Some(TrainRequest {
                    params: &p0,
                    m: &zeros,
                    v: &zeros,
                    t: 0.0,
                    x: &shard.x,
                    y: &shard.y,
                    seed: i as i32,
                    num_steps: mf.steps_per_round as i32,
                    global: None,
                })
            })
            .collect();
        let spawn = bench(
            &format!("executor/spawn-per-round {n_clients} clients ({workers} workers)"),
            1,
            8,
            || sched::train_parallel(&rt, &spawn_jobs).unwrap(),
        );

        // persistent pool: fleet spawned once, batches dispatched into it
        let pool_stats = std::thread::scope(|scope| {
            let pool = ExecutorPool::new(scope, &rt, workers);
            let stats = bench(
                &format!("executor/persistent-pool {n_clients} clients ({workers} workers)"),
                1,
                8,
                || {
                    let jobs: Vec<Option<TrainJob>> = shards
                        .iter()
                        .enumerate()
                        .map(|(i, shard)| {
                            Some(TrainJob {
                                id: 0, // run_batch assigns the slot index
                                params: block.clone(),
                                shard: Arc::clone(shard),
                                seed: i as i32,
                                num_steps: mf.steps_per_round as i32,
                                prox: false,
                                wire: None,
                            })
                        })
                        .collect();
                    pool.run_batch(jobs).unwrap()
                },
            );
            pool.shutdown().unwrap();
            stats
        });
        println!(
            "   -> pool vs spawn: {:.2}x at {n_clients} clients",
            spawn.mean.as_secs_f64() / pool_stats.mean.as_secs_f64().max(1e-12),
        );
    }

    // --- continuous-mode throughput -------------------------------------
    // One full continuous experiment (mnist preset shrunk to bench size):
    // wall-clock per run, plus the virtual-time updates/s the run reports.
    {
        let mk_cfg = || {
            let mut cfg = ExperimentConfig::preset("mnist");
            cfg.strategy = StrategyKind::Fedlesscan;
            cfg.scenario = Scenario::Straggler(30);
            cfg.mode = Mode::Continuous;
            cfg.n_clients = 32;
            cfg.clients_per_round = 8;
            cfg.rounds = 10; // budget: 80 invocations
            cfg.inflight_cohorts = 2;
            cfg
        };
        bench("executor/continuous mnist 80-invocation budget", 1, 5, || {
            let mut ctl = Controller::new(mk_cfg(), &rt).unwrap();
            ctl.run_continuous().unwrap()
        });
        let mut ctl = Controller::new(mk_cfg(), &rt).unwrap();
        let result = ctl.run_continuous().unwrap();
        println!(
            "   -> continuous: {:.3} updates/s (virtual), EUR {:.3}, {} folds / {} completions",
            result.updates_per_s(),
            result.effective_update_ratio(),
            result.folds,
            result.completions,
        );
    }
}
