//! Stub for the `xla` PJRT crate so `--features pjrt` compiles in offline
//! environments without an `xla_extension` install. Host-side literals are
//! fully functional (the `fedless` runtime's marshalling unit tests run
//! against them); everything that would touch the PJRT C API — client
//! creation, compilation, execution — returns an error at runtime.
//!
//! Deployments with the real toolchain swap this out via a Cargo patch:
//!
//! ```toml
//! [patch."<workspace>"]
//! xla = { git = "https://github.com/LaurentMazare/xla-rs" }
//! ```

use std::fmt;
use std::path::Path;

/// Error type mirroring the real crate's surface (`Display` is all the
/// callers use).
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: built against the offline xla stub; install xla_extension and \
         patch in the real `xla` crate to use the pjrt backend"
    ))
}

/// Element types supported by the host-literal subset.
pub trait NativeType: Copy + Default + 'static {
    fn write(lit: &mut Literal, data: Vec<Self>);
    fn read(lit: &Literal) -> Option<&[Self]>;
}

/// Host-side literal: flat element storage plus dimensions, or a tuple.
#[derive(Debug, Clone)]
pub struct Literal {
    data: LiteralData,
    dims: Vec<i64>,
}

#[derive(Debug, Clone)]
enum LiteralData {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

impl NativeType for f32 {
    fn write(lit: &mut Literal, data: Vec<Self>) {
        lit.data = LiteralData::F32(data);
    }
    fn read(lit: &Literal) -> Option<&[Self]> {
        match &lit.data {
            LiteralData::F32(v) => Some(v),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn write(lit: &mut Literal, data: Vec<Self>) {
        lit.data = LiteralData::I32(data);
    }
    fn read(lit: &Literal) -> Option<&[Self]> {
        match &lit.data {
            LiteralData::I32(v) => Some(v),
            _ => None,
        }
    }
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        let mut lit = Literal {
            data: LiteralData::F32(Vec::new()),
            dims: vec![data.len() as i64],
        };
        T::write(&mut lit, data.to_vec());
        lit
    }

    /// Rank-0 literal.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        let mut lit = Literal {
            data: LiteralData::F32(Vec::new()),
            dims: Vec::new(),
        };
        T::write(&mut lit, vec![v]);
        lit
    }

    fn len(&self) -> usize {
        match &self.data {
            LiteralData::F32(v) => v.len(),
            LiteralData::I32(v) => v.len(),
            LiteralData::Tuple(v) => v.len(),
        }
    }

    /// Reinterpret with new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.len() {
            return Err(Error(format!(
                "reshape: {} elements vs dims {dims:?}",
                self.len()
            )));
        }
        let mut out = self.clone();
        out.dims = dims.to_vec();
        Ok(out)
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::read(self)
            .map(<[T]>::to_vec)
            .ok_or_else(|| Error("literal element type mismatch".into()))
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        T::read(self)
            .and_then(|v| v.first().copied())
            .ok_or_else(|| Error("empty or mistyped literal".into()))
    }

    pub fn copy_raw_to<T: NativeType>(&self, dst: &mut [T]) -> Result<()> {
        let src = T::read(self).ok_or_else(|| Error("literal element type mismatch".into()))?;
        if src.len() != dst.len() {
            return Err(Error(format!(
                "copy_raw_to: {} vs {} elements",
                src.len(),
                dst.len()
            )));
        }
        dst.copy_from_slice(src);
        Ok(())
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match &self.data {
            LiteralData::Tuple(v) => Ok(v.clone()),
            _ => Err(Error("literal is not a tuple".into())),
        }
    }
}

/// Parsed HLO module (stub: retains nothing).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(path: &Path) -> Result<Self> {
        std::fs::read_to_string(path).map_err(|e| Error(format!("{}: {e}", path.display())))?;
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// XLA computation handle (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// PJRT client handle (stub: construction fails).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// Compiled executable handle (stub: execution fails).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T: AsRef<Literal>>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

impl AsRef<Literal> for Literal {
    fn as_ref(&self) -> &Literal {
        self
    }
}

/// Device buffer handle (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = lit.reshape(&[2, 2]).unwrap();
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(lit.reshape(&[3, 2]).is_err());
        assert_eq!(Literal::scalar(7i32).get_first_element::<i32>().unwrap(), 7);
    }

    #[test]
    fn client_is_unavailable() {
        assert!(PjRtClient::cpu().is_err());
    }
}
