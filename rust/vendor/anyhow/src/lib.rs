//! Offline substrate for the `anyhow` crate (this build environment has no
//! network access to crates.io). Implements the API subset the `fedless`
//! workspace uses: [`Error`], [`Result`], the [`anyhow!`] / [`bail!`] /
//! [`ensure!`] macros and the [`Context`] extension trait.
//!
//! Like the real crate, `Error` deliberately does **not** implement
//! `std::error::Error`, which is what makes the blanket
//! `From<E: std::error::Error>` conversion coherent.

use std::fmt;

/// A context-chained error value. Each `.context(...)` layer wraps the
/// previous error, and `Debug` prints the whole chain (what `main` shows
/// when it returns `Err`).
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Create an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error {
            msg: message.to_string(),
            source: None,
        }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error {
            msg: context.to_string(),
            source: Some(Box::new(self)),
        }
    }

    /// Iterate the context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &Error> {
        let mut next = Some(self);
        std::iter::from_fn(move || {
            let cur = next?;
            next = cur.source.as_deref();
            Some(cur)
        })
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut rest = self.source.as_deref();
        if rest.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = rest {
            write!(f, "\n    {}", e.msg)?;
            rest = e.source.as_deref();
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        // Preserve the std source chain as context layers.
        let mut layers = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            layers.push(s.to_string());
            src = s.source();
        }
        let mut err = Error {
            msg: layers.pop().unwrap(),
            source: None,
        };
        while let Some(msg) = layers.pop() {
            err = err.context(msg);
        }
        err
    }
}

/// `anyhow::Result<T>` — the crate-wide fallible type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(...)` to
/// `Result` values (including `Result<_, anyhow::Error>` itself).
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("inner {}", 42)
    }

    #[test]
    fn bail_and_display() {
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "inner 42");
    }

    #[test]
    fn context_chains() {
        let e = fails().context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer");
        let chain: Vec<String> = e.chain().map(|x| x.to_string()).collect();
        assert_eq!(chain, vec!["outer", "inner 42"]);
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn std_error_converts() {
        fn parse() -> Result<u32> {
            Ok("nope".parse::<u32>()?)
        }
        assert!(parse().is_err());
    }

    #[test]
    fn ensure_passes_and_fails() {
        fn check(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            Ok(x)
        }
        assert_eq!(check(3).unwrap(), 3);
        assert!(check(-1).is_err());
    }

    #[test]
    fn with_context_is_lazy() {
        let ok: Result<i32> = Ok(1);
        let r = ok.with_context(|| -> String { unreachable!("must not be called") });
        assert_eq!(r.unwrap(), 1);
    }
}
