//! Property-based tests over randomized inputs (offline substrate for
//! `proptest`): each property runs against a few hundred seeded random
//! cases drawn via `fedless::util::Rng`. Failures print the case seed so
//! the exact input can be replayed.

use fedless::clientdb::{HistoryStore, HISTORY_WINDOW};
use fedless::clustering::{
    cluster_clients, cluster_clients_eps, dbscan, dbscan_naive, dedup_eps_candidates,
    relabel_outliers, DbscanParams, IncrementalDbscan, EPS_DEDUP_REL_TOL, NOISE,
};
use fedless::config::Scenario;
use fedless::cost::GcfPricing;
use fedless::data::{Partition, SynthDataset};
use fedless::metrics::RoundRecord;
use fedless::params::{
    dequantize, fold_weighted_into, quantize, weighted_sum_scalar, ErrorFeedback, ShardLayout,
    ShardedAccumulator,
};
use fedless::paramsvr::{staleness_weights, weight_component, WeightedUpdate};
use fedless::runtime::kernel::{avx2_available, AdamParams, Kernel};
use fedless::strategy::{
    ema, feature_row, missed_round_ema, FedAvg, FedLesScan, FedProx, SafaLite,
    SelectionContext, Strategy, StrategyKind,
};
use fedless::util::{Json, Rng};

const CASES: u64 = 200;

/// Build a random history store reflecting a plausible training past.
fn random_history(rng: &mut Rng, n_clients: usize, rounds: u32) -> HistoryStore {
    let mut h = HistoryStore::new();
    for c in 0..n_clients {
        if rng.bernoulli(0.2) {
            continue; // rookie
        }
        for r in 0..rounds {
            if !rng.bernoulli(0.5) {
                continue; // not selected that round
            }
            h.record_invocation(c);
            if rng.bernoulli(0.75) {
                h.record_success(c, r, rng.range_f64(1.0, 120.0));
            } else {
                h.record_failure(c, r);
            }
        }
    }
    h
}

#[test]
fn prop_selection_invariants_all_strategies() {
    for case in 0..CASES {
        let mut rng = Rng::seed_from_u64(case);
        let n_clients = 2 + rng.below(40);
        let k = 1 + rng.below(n_clients);
        let rounds = 1 + rng.below(30) as u32;
        let round = rng.below(rounds as usize) as u32;
        let history = random_history(&mut rng, n_clients, rounds);
        let clients: Vec<usize> = (0..n_clients).collect();
        let ctx = SelectionContext {
            round,
            max_rounds: rounds,
            clients_per_round: k,
            all_clients: &clients,
            history: &history,
        };
        let strategies: Vec<Box<dyn Strategy>> = vec![
            Box::new(FedAvg),
            Box::new(FedProx::default()),
            Box::new(FedLesScan::default()),
            Box::new(SafaLite),
        ];
        for mut s in strategies {
            let sel = s.select(&ctx, &mut rng);
            assert!(
                sel.len() <= k,
                "case {case} {}: selected {} > k {k}",
                s.name(),
                sel.len()
            );
            let mut d = sel.clone();
            d.sort_unstable();
            d.dedup();
            assert_eq!(d.len(), sel.len(), "case {case} {}: duplicates", s.name());
            assert!(
                sel.iter().all(|&c| c < n_clients),
                "case {case} {}: out-of-range client",
                s.name()
            );
            // there are always >= k candidates, so selection must fill k
            assert_eq!(sel.len(), k, "case {case} {}: under-filled", s.name());
        }
    }
}

#[test]
fn prop_work_fraction_bounds() {
    for case in 0..CASES {
        let mut rng = Rng::seed_from_u64(case ^ 0x11);
        let s = FedProx::default();
        let f = s.work_fraction(case as usize, &mut rng);
        assert!((0.5..=1.0).contains(&f), "case {case}: fraction {f}");
    }
}

#[test]
fn prop_cooldown_follows_eq1() {
    // Whatever the event sequence, cooldown always obeys:
    // success -> 0; failure -> 1 if previously 0 else doubles; tick
    // decays by at most 1.
    for case in 0..CASES {
        let mut rng = Rng::seed_from_u64(case ^ 0x22);
        let mut db = HistoryStore::new();
        let mut model: u32 = 0; // our own mirror of Eq. 1
        for r in 0..60u32 {
            match rng.below(3) {
                0 => {
                    db.record_success(0, r, 1.0);
                    model = 0;
                }
                1 => {
                    db.record_failure(0, r);
                    model = if model == 0 { 1 } else { model * 2 };
                    // failed this round: tick spares it
                    db.tick_cooldowns(&[0]);
                }
                _ => {
                    db.tick_cooldowns(&[]);
                    model = model.saturating_sub(1);
                }
            }
            assert_eq!(db.get(0).cooldown, model, "case {case} round {r}");
        }
    }
}

#[test]
fn prop_staleness_weights_invariants() {
    for case in 0..CASES {
        let mut rng = Rng::seed_from_u64(case ^ 0x33);
        let t = 1 + rng.below(50) as u32;
        let tau = 1 + rng.below(5) as u32;
        let n = 1 + rng.below(16);
        let updates: Vec<WeightedUpdate> = (0..n)
            .map(|_| WeightedUpdate {
                produced_round: 1 + rng.below(t as usize) as u32,
                cardinality: 1 + rng.below(500),
            })
            .collect();
        let w = staleness_weights(&updates, t, tau, true);
        assert_eq!(w.len(), n);
        assert!(w.iter().all(|&x| (0.0..=1.0 + 1e-6).contains(&x)), "case {case}");
        // expired updates have zero weight
        for (u, &wi) in updates.iter().zip(&w) {
            if t - u.produced_round >= tau {
                assert_eq!(wi, 0.0, "case {case}: expired update has weight");
            }
        }
        // normalized: weights sum to 1 when anything survives
        let s: f32 = w.iter().sum();
        if w.iter().any(|&x| x > 0.0) {
            assert!((s - 1.0).abs() < 1e-4, "case {case}: sum {s}");
        }
        // fresher update with same cardinality never weighs less
        let un = staleness_weights(&updates, t, tau, false);
        for i in 0..n {
            for j in 0..n {
                if updates[i].cardinality == updates[j].cardinality
                    && updates[i].produced_round >= updates[j].produced_round
                {
                    assert!(
                        un[i] >= un[j] - 1e-7,
                        "case {case}: monotonicity violated"
                    );
                }
            }
        }
    }
}

#[test]
fn prop_chunk_parallel_fold_is_bit_identical_to_scalar_reference() {
    // The streaming-aggregation determinism contract: the chunk-parallel
    // weighted fold is *bit-identical* to the batch scalar reference
    // for every worker count (each element accumulates in entry order
    // no matter how the parameter range is chunked) — strictly stronger
    // than the documented 1e-5 equivalence bound. Random k, random
    // weights with zero-weight entries, 1/2/8 workers.
    for case in 0..CASES {
        let mut rng = Rng::seed_from_u64(case ^ 0xcc);
        let p = 1 + rng.below(3000);
        let k = 1 + rng.below(12);
        let updates: Vec<Vec<f32>> = (0..k)
            .map(|_| (0..p).map(|_| rng.range_f64(-2.0, 2.0) as f32).collect())
            .collect();
        let weights: Vec<f32> = (0..k)
            .map(|_| {
                if rng.bernoulli(0.2) {
                    0.0
                } else {
                    rng.range_f64(0.0, 1.5) as f32
                }
            })
            .collect();
        let refs: Vec<&[f32]> = updates.iter().map(Vec::as_slice).collect();
        let scalar = weighted_sum_scalar(&refs, &weights);
        let entries: Vec<(&[f32], f32)> = refs
            .iter()
            .copied()
            .zip(weights.iter().copied())
            .collect();
        for workers in [1usize, 2, 8] {
            let mut acc = vec![0.0f32; p];
            fold_weighted_into(&mut acc, &entries, workers);
            assert_eq!(acc, scalar, "case {case} workers {workers}");
        }
    }
}

#[test]
fn prop_sharded_fold_matches_scalar_oracle_bit_exact() {
    // Shard-count invariance: shard boundaries are chunk boundaries of
    // the flat vector and each element still accumulates in entry
    // order, so ANY shard count (and any worker fan-out within it) is
    // *bit-identical* to the unsharded batch scalar reference.
    for case in 0..CASES {
        let mut rng = Rng::seed_from_u64(case ^ 0x5a4d);
        let p = 1 + rng.below(3000);
        let k = 1 + rng.below(10);
        let updates: Vec<Vec<f32>> = (0..k)
            .map(|_| (0..p).map(|_| rng.range_f64(-2.0, 2.0) as f32).collect())
            .collect();
        let weights: Vec<f32> = (0..k)
            .map(|_| {
                if rng.bernoulli(0.2) {
                    0.0
                } else {
                    rng.range_f64(0.0, 1.5) as f32
                }
            })
            .collect();
        let refs: Vec<&[f32]> = updates.iter().map(Vec::as_slice).collect();
        let scalar = weighted_sum_scalar(&refs, &weights);
        for shards in [1usize, 2, 8, 17] {
            for workers in [1usize, 3] {
                let acc = ShardedAccumulator::new(ShardLayout::new(p, shards));
                for (u, &w) in updates.iter().zip(&weights) {
                    acc.accumulate(u, w, workers);
                }
                let folded = acc.finish();
                assert_eq!(
                    folded, scalar,
                    "case {case} p={p} k={k} shards={shards} workers={workers}"
                );
            }
        }
    }
}

#[test]
fn prop_int8_roundtrip_error_is_bounded() {
    // Symmetric per-shard int8: every element dequantizes to within
    // half a quantization step (shard_scale / 2) of its source, at any
    // shard count — including shards whose max is 0 (exactly encoded).
    for case in 0..CASES {
        let mut rng = Rng::seed_from_u64(case ^ 0x18b1);
        let p = 1 + rng.below(2000);
        let amp = rng.range_f64(1e-3, 100.0);
        let values: Vec<f32> = (0..p)
            .map(|_| {
                if rng.bernoulli(0.1) {
                    0.0
                } else {
                    rng.range_f64(-amp, amp) as f32
                }
            })
            .collect();
        let shards = 1 + rng.below(20);
        let layout = ShardLayout::new(p, shards);
        let q = quantize(&values, &layout);
        let dq = dequantize(&q, &layout);
        assert_eq!(dq.len(), p, "case {case}");
        for (i, (&v, &d)) in values.iter().zip(&dq).enumerate() {
            let scale = q.scales[layout.shard_of(i)];
            let bound = scale as f64 / 2.0 * (1.0 + 1e-5) + 1e-12;
            assert!(
                (f64::from(v) - f64::from(d)).abs() <= bound,
                "case {case} elem {i}: |{v} - {d}| > {bound} (scale {scale})"
            );
        }
    }
}

#[test]
fn prop_error_feedback_residual_drains_on_constant_updates() {
    // Error feedback on a constant update v: the residual telescopes,
    // so after T rounds the cumulative transmitted signal equals T·v
    // minus the final residual — the per-round mean error drains to
    // zero at rate 1/T, and the residual itself never exceeds half a
    // quantization step.
    for case in 0..CASES {
        let mut rng = Rng::seed_from_u64(case ^ 0xef5d);
        let p = 1 + rng.below(500);
        let values: Vec<f32> = (0..p)
            .map(|_| rng.range_f64(-1.0, 1.0) as f32)
            .collect();
        let shards = 1 + rng.below(8);
        let layout = ShardLayout::new(p, shards);
        let rounds = 2 + rng.below(7);
        let mut ef = ErrorFeedback::new(p);
        let mut transmitted = vec![0f64; p];
        let mut half_step = vec![0f64; p];
        for _ in 0..rounds {
            let q = ef.encode(&values, &layout, None);
            for (i, d) in dequantize(&q, &layout).into_iter().enumerate() {
                transmitted[i] += f64::from(d);
                half_step[i] = half_step[i].max(f64::from(q.scales[layout.shard_of(i)]) / 2.0);
            }
        }
        for (i, &v) in values.iter().enumerate() {
            // |Σ dq - T·v| == |final residual| <= max half-step (+ fp slack)
            let err = (transmitted[i] - rounds as f64 * f64::from(v)).abs();
            let bound = half_step[i] * (1.0 + 1e-4) + 1e-4;
            assert!(
                err <= bound,
                "case {case} elem {i}: cumulative error {err} > {bound} after {rounds} rounds"
            );
            let r = f64::from(ef.residual()[i]).abs();
            assert!(
                r <= bound,
                "case {case} elem {i}: residual {r} > {bound}"
            );
        }
    }
}

#[test]
fn prop_weight_component_factorizes_staleness_weights() {
    // The coordinator streams Σ c_k·u_k and divides by Z once; this
    // pins c_k / Z == staleness_weights for random batches, both
    // normalized and verbatim Eq. 3.
    for case in 0..CASES {
        let mut rng = Rng::seed_from_u64(case ^ 0xdd);
        let t = 1 + rng.below(50) as u32;
        let tau = 1 + rng.below(5) as u32;
        let n = 1 + rng.below(16);
        let updates: Vec<WeightedUpdate> = (0..n)
            .map(|_| WeightedUpdate {
                produced_round: 1 + rng.below(t as usize) as u32,
                cardinality: 1 + rng.below(500),
            })
            .collect();
        let comps: Vec<f64> = updates
            .iter()
            .map(|u| weight_component(u.produced_round, u.cardinality, t, tau).unwrap_or(0.0))
            .collect();
        let card_sum: f64 = updates
            .iter()
            .zip(&comps)
            .filter(|(_, &c)| c > 0.0)
            .map(|(u, _)| u.cardinality as f64)
            .sum();
        for normalize in [false, true] {
            let batch = staleness_weights(&updates, t, tau, normalize);
            let z = if normalize {
                comps.iter().sum::<f64>()
            } else {
                card_sum
            };
            if z <= 0.0 {
                assert!(batch.iter().all(|&w| w == 0.0), "case {case}");
                continue;
            }
            for (i, (&b, &c)) in batch.iter().zip(&comps).enumerate() {
                assert!(
                    (f64::from(b) - c / z).abs() < 1e-5,
                    "case {case} update {i} normalize={normalize}: {b} vs {}",
                    c / z
                );
            }
        }
    }
}

#[test]
fn prop_dbscan_labels_valid() {
    for case in 0..CASES {
        let mut rng = Rng::seed_from_u64(case ^ 0x44);
        let n = 1 + rng.below(60);
        let dim = 1 + rng.below(3);
        let pts: Vec<Vec<f64>> = (0..n)
            .map(|_| (0..dim).map(|_| rng.range_f64(-10.0, 10.0)).collect())
            .collect();
        let eps = rng.range_f64(0.1, 5.0);
        let min_pts = 1 + rng.below(4);
        let mut labels = dbscan(&pts, &DbscanParams { eps, min_pts });
        assert_eq!(labels.len(), n);
        assert!(labels.iter().all(|&l| l >= -1), "case {case}");
        let k = relabel_outliers(&mut labels);
        // after relabel: labels are a contiguous 0..k cover
        assert!(labels.iter().all(|&l| (l as usize) < k), "case {case}");
        for c in 0..k {
            assert!(
                labels.iter().any(|&l| l as usize == c),
                "case {case}: empty cluster {c} of {k}"
            );
        }
        // grid search wrapper invariants
        let (glabels, gk) = cluster_clients(&pts, 2);
        assert_eq!(glabels.len(), n);
        if n > 0 {
            assert!(gk >= 1 && gk <= n, "case {case}: gk {gk}");
        }
    }
}

/// Partition-equivalence oracle check: identical NOISE sets, and the
/// non-noise labellings related by a bijection (cluster renumbering).
fn assert_label_equivalent(a: &[isize], b: &[isize], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    let mut fwd: std::collections::HashMap<isize, isize> = std::collections::HashMap::new();
    let mut rev: std::collections::HashMap<isize, isize> = std::collections::HashMap::new();
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        assert_eq!(
            x == NOISE,
            y == NOISE,
            "{what}: NOISE sets differ at point {i} ({x} vs {y})"
        );
        if x == NOISE {
            continue;
        }
        assert_eq!(*fwd.entry(x).or_insert(y), y, "{what}: non-injective at {i}");
        assert_eq!(*rev.entry(y).or_insert(x), x, "{what}: non-surjective at {i}");
    }
}

#[test]
fn prop_grid_dbscan_matches_naive_oracle() {
    // The tentpole contract: the grid-indexed DBSCAN produces label
    // partitions equivalent to the O(n²) oracle (identical NOISE sets,
    // clusters equal up to renumbering) across random point clouds,
    // eps/min_pts/dimension sweeps, and the degenerate geometries a
    // uniform grid is most likely to fumble — all-identical points,
    // points exactly on cell boundaries, ε spanning many cells.
    for case in 0..CASES {
        let mut rng = Rng::seed_from_u64(case ^ 0x6e1d);
        let n = 1 + rng.below(90);
        let dim = 1 + rng.below(3);
        let style = rng.below(4);
        let pts: Vec<Vec<f64>> = (0..n)
            .map(|_| match style {
                0 => (0..dim).map(|_| rng.range_f64(-10.0, 10.0)).collect(),
                1 => {
                    // clustered blobs
                    let c = rng.below(4) as f64 * 8.0;
                    (0..dim).map(|_| c + rng.range_f64(-0.7, 0.7)).collect()
                }
                2 => vec![3.25; dim], // all points identical
                // lattice of exact ε multiples: every coordinate sits on
                // a cell boundary
                _ => (0..dim).map(|_| rng.below(6) as f64 * 0.5).collect(),
            })
            .collect();
        // ε sweep: sub-cell, exact-boundary, and spanning many cells
        let eps = [0.25, 0.5, 1.0, 5.0, 100.0][rng.below(5)];
        let min_pts = 1 + rng.below(4);
        let params = DbscanParams { eps, min_pts };
        let grid = dbscan(&pts, &params);
        let naive = dbscan_naive(&pts, &params);
        assert_label_equivalent(
            &grid,
            &naive,
            &format!("case {case} n={n} dim={dim} style={style} eps={eps} min_pts={min_pts}"),
        );
    }
}

#[test]
fn prop_incremental_dbscan_matches_full_recluster_under_drift() {
    // The tentpole contract: after ANY multi-round schedule of point
    // insertions, behaviour drift (moves), and departures, the
    // persistent engine's standing labels are partition-identical to a
    // from-scratch DBSCAN of the same points at the same frozen ε —
    // the engine only re-expands affected cell-components, but the
    // result must be indistinguishable from reclustering the world.
    for case in 0..CASES {
        let mut rng = Rng::seed_from_u64(case ^ 0x1ec5);
        let n = 4 + rng.below(40);
        // feature-shaped points: (trainingEma, scaled missedRoundEma)
        let mut live: Vec<Option<Vec<f64>>> = (0..n)
            .map(|_| {
                let c = rng.below(3) as f64 * 40.0;
                Some(vec![
                    c + rng.range_f64(1.0, 20.0),
                    rng.range_f64(0.0, 15.0),
                ])
            })
            .collect();
        let pts: Vec<Vec<f64>> = live.iter().flatten().cloned().collect();
        let min_pts = 1 + rng.below(3);
        // production-style ε freeze: the grid-search winner, when the
        // geometry has one (degenerate cases fall back to a fixed ε —
        // the engine contract is per-ε, not per-search)
        let (_, _, eps_opt) = cluster_clients_eps(&pts, min_pts);
        let eps = eps_opt.unwrap_or(1.0);
        let mut engine = IncrementalDbscan::new(eps, min_pts).expect("positive finite eps");
        let bulk: Vec<(usize, Option<Vec<f64>>)> =
            live.iter().cloned().enumerate().collect();
        engine.update(&bulk).expect("finite points always place");
        let rounds = 1 + rng.below(7);
        for round in 0..=rounds {
            if round > 0 {
                // drift schedule: EMA-style moves, departures, arrivals
                let batch = 1 + rng.below(n);
                let mut changes: Vec<(usize, Option<Vec<f64>>)> = Vec::new();
                let mut touched = std::collections::HashSet::new();
                for _ in 0..batch {
                    let id = rng.below(n);
                    if !touched.insert(id) {
                        continue; // one change per id per update
                    }
                    let p = match &live[id] {
                        // client leaves the participant tier
                        Some(_) if rng.bernoulli(0.15) => None,
                        Some(old) => Some(vec![
                            (old[0] * rng.range_f64(0.7, 1.4)).max(0.0),
                            (old[1] * rng.range_f64(0.5, 1.5) + rng.range_f64(-1.0, 1.0))
                                .max(0.0),
                        ]),
                        None => Some(vec![
                            rng.range_f64(1.0, 120.0),
                            rng.range_f64(0.0, 15.0),
                        ]),
                    };
                    changes.push((id, p));
                }
                engine
                    .update(&changes)
                    .expect("finite points always place");
                for (id, p) in changes {
                    live[id] = p;
                }
            }
            let ids: Vec<usize> = (0..n).filter(|&i| live[i].is_some()).collect();
            let now: Vec<Vec<f64>> =
                ids.iter().map(|&i| live[i].clone().unwrap()).collect();
            let oracle = dbscan(&now, &DbscanParams { eps, min_pts });
            let standing = engine.labels_for(&ids);
            assert_eq!(engine.len(), ids.len(), "case {case} round {round}");
            assert_label_equivalent(
                &standing,
                &oracle,
                &format!("case {case} round {round} eps={eps} min_pts={min_pts}"),
            );
        }
    }
}

#[test]
fn prop_incremental_fedlesscan_selection_identical_on_paper_scale_fleets() {
    // Golden-path guarantee: at ≤ COHORT_MAX registered clients the
    // incremental-capable FedLesScan must be byte-identical to the
    // stateless default — same RNG stream, same selections, no report —
    // under arbitrary multi-round histories evolving between selects.
    for case in 0..CASES {
        let mut rng = Rng::seed_from_u64(case ^ 0x5e1f);
        let n_clients = 2 + rng.below(60);
        let k = 1 + rng.below(n_clients);
        let rounds = 2 + rng.below(14) as u32;
        let clients: Vec<usize> = (0..n_clients).collect();
        let mut history = HistoryStore::new();
        let mut legacy = FedLesScan::default();
        let mut incr = FedLesScan::with_incremental();
        let mut rng_a = Rng::seed_from_u64(case ^ 0xabc);
        let mut rng_b = Rng::seed_from_u64(case ^ 0xabc);
        for round in 0..rounds {
            let ctx = SelectionContext {
                round,
                max_rounds: rounds,
                clients_per_round: k,
                all_clients: &clients,
                history: &history,
            };
            let a = legacy.select(&ctx, &mut rng_a);
            let b = incr.select(&ctx, &mut rng_b);
            assert_eq!(a, b, "case {case} round {round}");
            assert!(
                incr.take_select_report().is_none(),
                "case {case} round {round}: paper-scale path must not report"
            );
            // evolve the shared history off the selection
            let mut failed = Vec::new();
            for &c in &a {
                history.record_invocation(c);
                if rng.bernoulli(0.7) {
                    history.record_success(c, round, rng.range_f64(1.0, 90.0));
                } else {
                    history.record_failure(c, round);
                    failed.push(c);
                }
            }
            history.tick_cooldowns(&failed);
        }
    }
}

#[test]
fn prop_eps_candidate_dedup_collapses_relative_runs() {
    // Regression property for the ε-candidate dedup fix: runs of
    // near-equal candidates (within the relative tolerance of the run
    // head) collapse to their head, and the survivors are pairwise
    // separated beyond the tolerance — exact equality missed the
    // near-degenerate runs that floating-point quantiles produce.
    for case in 0..CASES {
        let mut rng = Rng::seed_from_u64(case ^ 0xded0);
        let mut cands: Vec<f64> = Vec::new();
        let mut base = rng.range_f64(1e-6, 1.0);
        let n_groups = 1 + rng.below(8);
        for _ in 0..n_groups {
            cands.push(base);
            for _ in 0..rng.below(4) {
                let jitter = base * EPS_DEDUP_REL_TOL * rng.range_f64(0.0, 0.99);
                cands.push(base + jitter);
            }
            base *= 1.0 + rng.range_f64(0.01, 2.0); // clearly separated
        }
        let n_before = cands.len();
        dedup_eps_candidates(&mut cands);
        assert_eq!(
            cands.len(),
            n_groups,
            "case {case}: {n_before} candidates -> {} (want {n_groups})",
            cands.len()
        );
        for w in cands.windows(2) {
            assert!(
                (w[1] - w[0]).abs() > EPS_DEDUP_REL_TOL * w[0].abs().max(w[1].abs()),
                "case {case}: survivors {} and {} within tolerance",
                w[0],
                w[1]
            );
        }
    }
}

#[test]
fn prop_bounded_history_features_match_unbounded_oracle() {
    // The bounded ClientHistory must reproduce the unbounded slice
    // oracles: the cached training-time EMA bit-exactly at the store α
    // at ANY history length, and the windowed missed-round feature
    // bit-exactly while a client's uncorrected misses fit the window.
    // Ring lengths must never exceed the window.
    for case in 0..CASES {
        let mut rng = Rng::seed_from_u64(case ^ 0x5107);
        let mut db = HistoryStore::new();
        let mut times: Vec<f64> = Vec::new();
        let mut missed: Vec<u32> = Vec::new();
        // the windowed missed feature is exact until the first eviction
        let mut overflowed = false;
        let rounds = 1 + rng.below(120) as u32;
        for r in 0..rounds {
            match rng.below(4) {
                0 => {
                    db.record_failure(7, r);
                    if !missed.contains(&r) {
                        missed.push(r);
                    }
                    overflowed |= missed.len() > HISTORY_WINDOW;
                }
                1 if !missed.is_empty() => {
                    // late completion corrects the most recent miss
                    let round = *missed.last().unwrap();
                    let t = rng.range_f64(30.0, 90.0);
                    db.record_late_completion(7, round, t);
                    missed.retain(|&x| x != round);
                    times.push(t);
                }
                _ => {
                    let t = rng.range_f64(1.0, 60.0);
                    db.record_success(7, r, t);
                    missed.retain(|&x| x != r);
                    times.push(t);
                }
            }
            let h = db.view(7);
            assert!(h.recent_times().len() <= HISTORY_WINDOW, "case {case}");
            assert!(h.missed_recent().len() <= HISTORY_WINDOW, "case {case}");
            assert_eq!(h.times_count() as usize, times.len(), "case {case}");
            let (t_feat, m_feat) = feature_row(h, r.max(1), 0.5);
            assert_eq!(
                t_feat.to_bits(),
                ema(&times, 0.5).to_bits(),
                "case {case} round {r}: t-EMA diverged at len {}",
                times.len()
            );
            if !overflowed {
                assert_eq!(
                    m_feat.to_bits(),
                    missed_round_ema(&missed, r.max(1), 0.5).to_bits(),
                    "case {case} round {r}: missed feature diverged"
                );
                assert_eq!(h.missed_recent(), &missed[..], "case {case} round {r}");
            }
            assert_eq!(h.missed_total() as usize, missed.len(), "case {case}");
        }
    }
}

#[test]
fn prop_partitioner_covers_every_sample_exactly_once() {
    for case in 0..60 {
        let mut rng = Rng::seed_from_u64(case ^ 0x55);
        let n_clients = 2 + rng.below(12);
        let shard = 2 * (1 + rng.below(20)); // even
        let classes = 2 + rng.below(20);
        let ds = SynthDataset::new(
            n_clients,
            shard,
            64,
            classes,
            vec![3],
            false,
            case,
            Partition::LabelShard,
        )
        .unwrap();
        let mut all: Vec<i32> = (0..n_clients)
            .flat_map(|c| ds.client_data(c).y)
            .collect();
        all.sort_unstable();
        let mut expect: Vec<i32> = (0..n_clients * shard)
            .map(|i| (i % classes) as i32)
            .collect();
        expect.sort_unstable();
        assert_eq!(all, expect, "case {case}");
    }
}

#[test]
fn prop_synthesis_deterministic_and_shaped() {
    for case in 0..60 {
        let mut rng = Rng::seed_from_u64(case ^ 0x66);
        let n_clients = 1 + rng.below(8);
        let shard = 1 + rng.below(30);
        let classes = 2 + rng.below(30);
        let tokens = rng.bernoulli(0.5);
        let dims = if tokens {
            vec![1 + rng.below(12)]
        } else {
            vec![1 + rng.below(6), 1 + rng.below(6)]
        };
        let partition = match rng.below(3) {
            0 => Partition::LabelShard,
            1 => Partition::Iid,
            _ => Partition::Dirichlet(rng.range_f64(0.05, 5.0)),
        };
        let mk = || {
            SynthDataset::new(
                n_clients, shard, 32, classes, dims.clone(), tokens, case, partition,
            )
            .unwrap()
        };
        let a = mk();
        let b = mk();
        for c in 0..n_clients {
            let ca = a.client_data(c);
            let cb = b.client_data(c);
            assert_eq!(ca.y, cb.y, "case {case}");
            assert_eq!(ca.x, cb.x, "case {case}");
            assert_eq!(ca.y.len(), shard);
            assert_eq!(ca.x.len(), shard * a.sample_elems());
            assert!(ca.y.iter().all(|&y| (y as usize) < classes));
        }
    }
}

#[test]
fn prop_cost_monotone_and_nonnegative() {
    let pricing = GcfPricing::default();
    for case in 0..CASES {
        let mut rng = Rng::seed_from_u64(case ^ 0x77);
        let d1 = rng.range_f64(0.0, 600.0);
        let d2 = d1 + rng.range_f64(0.0, 600.0);
        let mem = [128u32, 256, 512, 1024, 2048, 4096][rng.below(6)];
        let c1 = pricing.invocation_cost(d1, mem);
        let c2 = pricing.invocation_cost(d2, mem);
        assert!(c1 >= 0.0 && c2 >= c1 - 1e-15, "case {case}");
    }
}

#[test]
fn prop_eur_bounds() {
    for case in 0..CASES {
        let mut rng = Rng::seed_from_u64(case ^ 0x88);
        let sel = rng.below(50);
        let succ = if sel == 0 { 0 } else { rng.below(sel + 1) };
        let eur = RoundRecord::compute_eur(succ, sel);
        assert!((0.0..=1.0).contains(&eur), "case {case}: {eur}");
    }
}

#[test]
fn prop_ema_bounded_by_series_range() {
    for case in 0..CASES {
        let mut rng = Rng::seed_from_u64(case ^ 0x99);
        let n = 1 + rng.below(30);
        let xs: Vec<f64> = (0..n).map(|_| rng.range_f64(0.0, 100.0)).collect();
        let alpha = rng.range_f64(0.01, 1.0);
        let e = ema(&xs, alpha);
        let lo = xs.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        assert!(e >= lo - 1e-9 && e <= hi + 1e-9, "case {case}: {e} not in [{lo},{hi}]");
    }
}

#[test]
fn prop_missed_round_ema_decays_with_round() {
    for case in 0..CASES {
        let mut rng = Rng::seed_from_u64(case ^ 0xaa);
        let n = 1 + rng.below(8);
        let r1 = 1 + rng.below(40) as u32;
        let missed: Vec<u32> = (0..n).map(|_| rng.below(r1 as usize) as u32).collect();
        let e1 = missed_round_ema(&missed, r1, 0.5);
        let e2 = missed_round_ema(&missed, r1 * 2, 0.5);
        assert!(e2 <= e1 + 1e-12, "case {case}: {e2} > {e1}");
        assert!(e1 >= 0.0);
    }
}

#[test]
fn prop_json_roundtrip_random_values() {
    fn random_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.bernoulli(0.5)),
            2 => Json::Num((rng.range_f64(-1e6, 1e6) * 100.0).round() / 100.0),
            3 => {
                let n = rng.below(12);
                Json::Str(
                    (0..n)
                        .map(|_| {
                            let c = rng.below(96) as u8 + 32;
                            c as char
                        })
                        .collect(),
                )
            }
            4 => Json::Arr((0..rng.below(5)).map(|_| random_json(rng, depth - 1)).collect()),
            _ => Json::obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                    .collect::<Vec<_>>()
                    .iter()
                    .map(|(k, v)| (k.as_str(), v.clone()))
                    .collect(),
            ),
        }
    }
    for case in 0..CASES {
        let mut rng = Rng::seed_from_u64(case ^ 0xbb);
        let v = random_json(&mut rng, 3);
        let re = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, re, "case {case} (pretty)");
        let re2 = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, re2, "case {case} (compact)");
    }
}

/// Both kernels when the host has AVX2. Hosts without it skip the
/// cross-kernel comparison (skip, not fail — same contract as the
/// in-module dispatcher test, so CI stays green on any fleet).
fn kernel_pair() -> Option<[Kernel; 2]> {
    if avx2_available() {
        Some([Kernel::Scalar, Kernel::Avx2])
    } else {
        eprintln!("skipping scalar-vs-avx2 bit-identity: host lacks AVX2");
        None
    }
}

fn bits(x: &[f32]) -> Vec<u32> {
    x.iter().map(|v| v.to_bits()).collect()
}

#[test]
fn prop_gemm_kernels_bit_identical_across_ragged_shapes() {
    // The kernel-plane contract: every GEMM shape (plain, fused
    // bias/bias+ReLU epilogues, Aᵀ@B, A@Bᵀ) is *bit-identical* across
    // kernels at every lane residue (`n % 8` sweeps 0..=7 with the
    // case number), including zero-row outputs — the 16-wide, 8-wide
    // and scalar-tail code paths all reproduce the scalar fold.
    let Some([sc, vx]) = kernel_pair() else { return };
    for case in 0..CASES {
        let mut rng = Rng::seed_from_u64(case ^ 0x6e44);
        let m = rng.below(7); // 0 rows exercises the empty-output edge
        let k = 1 + rng.below(24);
        let n = 1 + 8 * rng.below(4) + (case % 8) as usize;
        let fill = |rng: &mut Rng, len: usize| -> Vec<f32> {
            (0..len).map(|_| rng.range_f64(-2.0, 2.0) as f32).collect()
        };
        let a = fill(&mut rng, m * k);
        let b = fill(&mut rng, k * n);
        let bias = fill(&mut rng, n);
        let what = format!("case {case} m={m} k={k} n={n}");

        let (mut o1, mut o2) = (vec![0.0f32; m * n], vec![0.0f32; m * n]);
        sc.matmul(&a, &b, k, n, &mut o1);
        vx.matmul(&a, &b, k, n, &mut o2);
        assert_eq!(bits(&o1), bits(&o2), "{what}: matmul");

        sc.matmul_bias(&a, &b, &bias, k, n, &mut o1);
        vx.matmul_bias(&a, &b, &bias, k, n, &mut o2);
        assert_eq!(bits(&o1), bits(&o2), "{what}: matmul_bias");

        let (mut z1, mut z2) = (vec![0.0f32; m * n], vec![0.0f32; m * n]);
        sc.matmul_bias_relu(&a, &b, &bias, k, n, &mut z1, &mut o1);
        vx.matmul_bias_relu(&a, &b, &bias, k, n, &mut z2, &mut o2);
        assert_eq!(bits(&z1), bits(&z2), "{what}: fused pre-activation");
        assert_eq!(bits(&o1), bits(&o2), "{what}: fused activation");

        // Aᵀ@B: a is m×k, rhs is m×n, out k×n
        let rhs = fill(&mut rng, m * n);
        let (mut g1, mut g2) = (vec![0.0f32; k * n], vec![0.0f32; k * n]);
        sc.matmul_at_b(&a, &rhs, k, n, &mut g1);
        vx.matmul_at_b(&a, &rhs, k, n, &mut g2);
        assert_eq!(bits(&g1), bits(&g2), "{what}: matmul_at_b");

        // A@Bᵀ: lhs is m×n, b is k×n, out m×k (bt scratch n×k)
        let lhs = fill(&mut rng, m * n);
        let (mut bt1, mut bt2) = (vec![0.0f32; n * k], vec![0.0f32; n * k]);
        let (mut d1, mut d2) = (vec![0.0f32; m * k], vec![0.0f32; m * k]);
        sc.matmul_a_bt(&lhs, &b, n, k, &mut bt1, &mut d1);
        vx.matmul_a_bt(&lhs, &b, n, k, &mut bt2, &mut d2);
        assert_eq!(bits(&d1), bits(&d2), "{what}: matmul_a_bt");
    }
}

#[test]
fn prop_elementwise_kernels_bit_identical() {
    // Every element-wise hot loop (optimizer steps, FedProx anchor,
    // fold axpy, ReLU mask, error-feedback add/sub, int8 codec and the
    // max-abs reduction) is bit-identical across kernels at every lane
    // residue and at zero length.
    let Some([sc, vx]) = kernel_pair() else { return };
    for case in 0..CASES {
        let mut rng = Rng::seed_from_u64(case ^ 0xa7e9);
        // length sweeps every residue mod 8; case 0 pins the zero edge
        let n = if case == 0 {
            0
        } else {
            8 * rng.below(6) + (case % 8) as usize
        };
        let amp = rng.range_f64(1e-3, 50.0);
        let fill = |rng: &mut Rng| -> Vec<f32> {
            (0..n).map(|_| rng.range_f64(-amp, amp) as f32).collect()
        };
        let x = fill(&mut rng);
        let y = fill(&mut rng);
        let g = fill(&mut rng);
        let what = format!("case {case} n={n}");

        let (mut u1, mut u2) = (x.clone(), x.clone());
        sc.add_assign(&mut u1, &y);
        vx.add_assign(&mut u2, &y);
        assert_eq!(bits(&u1), bits(&u2), "{what}: add_assign");

        let w = rng.range_f64(-1.5, 1.5) as f32;
        let (mut u1, mut u2) = (x.clone(), x.clone());
        sc.axpy(&mut u1, &y, w);
        vx.axpy(&mut u2, &y, w);
        assert_eq!(bits(&u1), bits(&u2), "{what}: axpy");

        let (mut o1, mut o2) = (vec![0.0f32; n], vec![0.0f32; n]);
        sc.add(&mut o1, &x, &y);
        vx.add(&mut o2, &x, &y);
        assert_eq!(bits(&o1), bits(&o2), "{what}: add");
        sc.sub(&mut o1, &x, &y);
        vx.sub(&mut o2, &x, &y);
        assert_eq!(bits(&o1), bits(&o2), "{what}: sub");

        let mu = rng.range_f64(0.0, 0.2) as f32;
        let (mut g1, mut g2) = (g.clone(), g.clone());
        sc.prox_add(&mut g1, &x, &y, mu);
        vx.prox_add(&mut g2, &x, &y, mu);
        assert_eq!(bits(&g1), bits(&g2), "{what}: prox_add");

        let lr = rng.range_f64(1e-4, 0.5) as f32;
        let (mut w1, mut w2) = (x.clone(), x.clone());
        sc.sgd_step(&mut w1, &g, lr);
        vx.sgd_step(&mut w2, &g, lr);
        assert_eq!(bits(&w1), bits(&w2), "{what}: sgd_step");

        let t = 1.0 + rng.below(40) as f32;
        let p = AdamParams {
            lr,
            b1: 0.9,
            b2: 0.999,
            eps: 1e-7,
            bc1: 1.0 - 0.9f32.powf(t),
            bc2: 1.0 - 0.999f32.powf(t),
        };
        let (mut w1, mut w2) = (x.clone(), x.clone());
        let (mut m1, mut m2) = (y.clone(), y.clone());
        let mut v1: Vec<f32> = y.iter().map(|v| v.abs()).collect();
        let mut v2 = v1.clone();
        sc.adam_step(&mut w1, &g, &mut m1, &mut v1, p);
        vx.adam_step(&mut w2, &g, &mut m2, &mut v2, p);
        assert_eq!(bits(&w1), bits(&w2), "{what}: adam params");
        assert_eq!(bits(&m1), bits(&m2), "{what}: adam first moment");
        assert_eq!(bits(&v1), bits(&v2), "{what}: adam second moment");

        // relu_mask keys on the sign of z: reuse x (mixed signs)
        let (mut d1, mut d2) = (vec![0.0f32; n], vec![0.0f32; n]);
        sc.relu_mask(&mut d1, &g, &x);
        vx.relu_mask(&mut d2, &g, &x);
        assert_eq!(bits(&d1), bits(&d2), "{what}: relu_mask");

        let ma1 = sc.max_abs(&x);
        let ma2 = vx.max_abs(&x);
        assert_eq!(ma1.to_bits(), ma2.to_bits(), "{what}: max_abs");

        // int8 codec: live scale, plus the all-zero-shard scale==0 path
        for scale in [if ma1 == 0.0 { 0.0 } else { ma1 / 127.0 }, 0.0] {
            let (mut c1, mut c2) = (vec![0i8; n], vec![0i8; n]);
            sc.quant_encode(&mut c1, &x, scale, 127.0);
            vx.quant_encode(&mut c2, &x, scale, 127.0);
            assert_eq!(c1, c2, "{what}: quant_encode scale={scale}");
            let (mut q1, mut q2) = (vec![0.0f32; n], vec![0.0f32; n]);
            sc.dequant(&mut q1, &c1, scale);
            vx.dequant(&mut q2, &c2, scale);
            assert_eq!(bits(&q1), bits(&q2), "{what}: dequant scale={scale}");
        }
    }
}

#[test]
fn prop_quant_encode_rounds_half_away_from_zero_in_both_kernels() {
    // Adversarial rounding inputs: values sitting exactly on (or one
    // ulp off) the half-step grid, where round-half-to-even hardware
    // rounding or a naive `trunc(v + 0.5)` would diverge from Rust's
    // `f32::round`. Both kernels must match the `f32::round` reference
    // code-for-code.
    let Some([sc, vx]) = kernel_pair() else { return };
    for case in 0..CASES {
        let mut rng = Rng::seed_from_u64(case ^ 0x40d5);
        let n = 1 + 8 * rng.below(3) + (case % 8) as usize;
        let values: Vec<f32> = (0..n)
            .map(|_| {
                let half_grid = (rng.below(255) as f32 - 127.0) + 0.5;
                match rng.below(4) {
                    0 => half_grid,
                    1 => half_grid + rng.range_f64(-1e-7, 1e-7) as f32,
                    2 => 0.499_999_97f32.copysign(half_grid),
                    _ => rng.range_f64(-140.0, 140.0) as f32,
                }
            })
            .collect();
        let scale = 1.0f32; // unit scale puts values directly on the code grid
        let reference: Vec<i8> = values
            .iter()
            .map(|v| (v / scale).round().clamp(-127.0, 127.0) as i8)
            .collect();
        let (mut c1, mut c2) = (vec![0i8; n], vec![0i8; n]);
        sc.quant_encode(&mut c1, &values, scale, 127.0);
        vx.quant_encode(&mut c2, &values, scale, 127.0);
        assert_eq!(c1, reference, "case {case}: scalar kernel vs f32::round");
        assert_eq!(c2, reference, "case {case}: avx2 kernel vs f32::round");
    }
}

#[test]
fn prop_scenario_label_roundtrip() {
    use std::str::FromStr;
    for p in [0u8, 10, 30, 50, 70, 99] {
        let s = if p == 0 {
            Scenario::Standard
        } else {
            Scenario::Straggler(p)
        };
        assert_eq!(Scenario::from_str(&s.label()).unwrap(), s);
    }
    for s in [
        Scenario::ColdStartStorm,
        Scenario::Diurnal,
        Scenario::RegionalOutage,
        Scenario::Adversarial,
    ] {
        assert_eq!(Scenario::from_str(&s.label()).unwrap(), s);
    }
    for k in StrategyKind::evaluated()
        .into_iter()
        .chain(StrategyKind::ablation())
    {
        assert_eq!(StrategyKind::from_str(k.as_str()).unwrap(), k);
    }
}
