//! Shared test support for the integration and scale suites.

use std::time::Duration;

use fedless::data::Features;
use fedless::runtime::manifest::Entrypoint;
use fedless::runtime::{
    AggregateFold, Backend, BufferedFold, EvalResult, Manifest, TrainRequest, TrainResult,
};
use fedless::Result;

/// Minimal deterministic mock backend (8 params, trivial transforms):
/// training adds a constant, evaluation is fixed, and `aggregate`
/// enforces the manifest `k_max` as a hard capacity limit — so tests
/// exercise the coordinator's selection/scheduling/accounting without
/// paying for model compute. The `k_max` is the knob: a tiny value
/// (e.g. 2) forces stale-update truncation pressure; a large one lets
/// fleet-scale rounds aggregate freely.
pub struct MockBackend {
    mf: Manifest,
}

impl MockBackend {
    pub fn new(k_max: usize) -> Self {
        let ep = |f: &str| Entrypoint {
            file: f.into(),
            inputs: vec![],
            outputs: vec![],
        };
        let mf = Manifest {
            name: "mnist".into(), // must match the config's dataset
            scale: "mock".into(),
            param_count: 8,
            num_classes: 2,
            input_shape: vec![4],
            input_dtype: "f32".into(),
            shard_size: 4,
            batch_size: 2,
            local_epochs: 1,
            steps_per_round: 2,
            optimizer: "sgd".into(),
            lr: 0.1,
            prox_mu: 0.0,
            eval_size: 4,
            eval_batch: 4,
            k_max,
            seq_len: None,
            flops_per_round: 1,
            entrypoints: ["train", "train_prox", "eval", "aggregate"]
                .iter()
                .map(|n| (n.to_string(), ep(n)))
                .collect(),
            init_file: "unused".into(),
            init_sha256: "unused".into(),
            init_seed: 0,
        };
        Self { mf }
    }
}

impl Backend for MockBackend {
    fn backend_name(&self) -> &'static str {
        "mock"
    }

    fn manifest(&self) -> &Manifest {
        &self.mf
    }

    fn init_params(&self) -> Result<Vec<f32>> {
        Ok(vec![0.0; self.mf.param_count])
    }

    fn train_round(&self, req: &TrainRequest) -> Result<(TrainResult, Duration)> {
        let params: Vec<f32> = req.params.iter().map(|p| p + 0.25).collect();
        let n = params.len();
        Ok((
            TrainResult {
                params,
                m: vec![0.0; n],
                v: vec![0.0; n],
                t: req.num_steps as f32,
                loss: 1.0,
            },
            Duration::from_millis(1),
        ))
    }

    fn evaluate(&self, _params: &[f32], _x: &Features, _y: &[i32]) -> Result<EvalResult> {
        Ok(EvalResult {
            loss: 1.0,
            accuracy: 0.5,
        })
    }

    fn aggregate(&self, updates: &[&[f32]], weights: &[f32]) -> Result<(Vec<f32>, Duration)> {
        // the kernel's hard capacity limit: the coordinator must never
        // exceed it
        anyhow::ensure!(
            !updates.is_empty() && updates.len() <= self.mf.k_max,
            "aggregate called with {} updates (k_max {})",
            updates.len(),
            self.mf.k_max
        );
        let mut out = vec![0.0f32; updates[0].len()];
        for (u, &w) in updates.iter().zip(weights) {
            for (o, &x) in out.iter_mut().zip(u.iter()) {
                *o += w * x;
            }
        }
        Ok((out, Duration::from_millis(1)))
    }

    fn begin_fold(&self, expected_k: usize) -> Result<Box<dyn AggregateFold + '_>> {
        // batch-only mock: buffer and defer to the capacity-checked
        // aggregate above
        Ok(Box::new(BufferedFold::new(self, expected_k)))
    }
}
