//! Full-stack integration tests: the native execution backend through
//! the FaaS simulator, strategies and controller. No artifacts, no
//! external libraries — these run on every `cargo test`.
//!
//! The PJRT backend is only compile-checked by CI (`--features pjrt`
//! against the in-tree xla stub); it has no end-to-end coverage here.
//! Porting this suite to run against `PjrtBackend` behind the feature
//! flag is future work once a real `xla_extension` environment exists.

mod common;

use fedless::config::{ExperimentConfig, Mode, Scenario};
use fedless::coordinator::Controller;
use fedless::data::{Features, SynthDataset};
use fedless::runtime::{Backend, NativeBackend, TrainRequest};
use fedless::strategy::{FedLesScan, FedLesScanParams, StrategyKind};

fn mnist_backend() -> NativeBackend {
    NativeBackend::for_dataset("mnist").expect("native mnist backend")
}

fn quick_cfg(strategy: StrategyKind, scenario: Scenario) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::preset("mnist");
    cfg.strategy = strategy;
    cfg.scenario = scenario;
    cfg.rounds = 5;
    cfg.n_clients = 16;
    cfg.clients_per_round = 6;
    cfg.seed = 7;
    cfg
}

#[test]
fn train_round_decreases_loss_and_changes_params() {
    let rt = mnist_backend();
    let mf = rt.manifest();
    let data = SynthDataset::from_manifest(mf, 4, 3, Default::default()).unwrap();
    let shard = data.client_data(0);
    let p0 = rt.init_params().unwrap();
    let zeros = vec![0f32; p0.len()];

    let run = |params: &[f32], seed: i32| {
        let req = TrainRequest {
            params,
            m: &zeros,
            v: &zeros,
            t: 0.0,
            x: &shard.x,
            y: &shard.y,
            seed,
            num_steps: mf.steps_per_round as i32,
            global: None,
        };
        rt.train_round(&req).unwrap().0
    };
    let r1 = run(&p0, 1);
    assert!(r1.loss.is_finite() && r1.loss > 0.0);
    assert_ne!(r1.params, p0);
    assert_eq!(r1.t, mf.steps_per_round as f32);
    let r2 = run(&r1.params, 2);
    assert!(
        r2.loss < r1.loss,
        "second round loss {} !< first {}",
        r2.loss,
        r1.loss
    );
}

#[test]
fn prox_entrypoint_stays_closer_to_global() {
    let rt = mnist_backend();
    let mf = rt.manifest();
    let data = SynthDataset::from_manifest(mf, 4, 5, Default::default()).unwrap();
    let shard = data.client_data(1);
    let p0 = rt.init_params().unwrap();
    let zeros = vec![0f32; p0.len()];
    let anchor = p0.clone();
    fn req<'a>(
        p0: &'a [f32],
        zeros: &'a [f32],
        shard: &'a fedless::data::ClientData,
        steps: i32,
        global: Option<&'a [f32]>,
    ) -> TrainRequest<'a> {
        TrainRequest {
            params: p0,
            m: zeros,
            v: zeros,
            t: 0.0,
            x: &shard.x,
            y: &shard.y,
            seed: 11,
            num_steps: steps,
            global,
        }
    }
    let steps = mf.steps_per_round as i32;
    let plain = rt
        .train_round(&req(&p0, &zeros, &shard, steps, None))
        .unwrap()
        .0;
    let prox = rt
        .train_round(&req(&p0, &zeros, &shard, steps, Some(anchor.as_slice())))
        .unwrap()
        .0;
    let drift = |p: &[f32]| -> f64 {
        p.iter()
            .zip(&p0)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            .sqrt()
    };
    assert!(drift(&prox.params) < drift(&plain.params));
}

#[test]
fn partial_work_masks_steps() {
    let rt = mnist_backend();
    let mf = rt.manifest();
    let data = SynthDataset::from_manifest(mf, 4, 9, Default::default()).unwrap();
    let shard = data.client_data(2);
    let p0 = rt.init_params().unwrap();
    let zeros = vec![0f32; p0.len()];
    let run = |steps: i32| {
        rt.train_round(&TrainRequest {
            params: &p0,
            m: &zeros,
            v: &zeros,
            t: 0.0,
            x: &shard.x,
            y: &shard.y,
            seed: 4,
            num_steps: steps,
            global: None,
        })
    };
    let half = run((mf.steps_per_round / 2) as i32).unwrap().0;
    assert_eq!(half.t, (mf.steps_per_round / 2) as f32);
    // out-of-range num_steps rejected
    assert!(run((mf.steps_per_round + 1) as i32).is_err());
}

#[test]
fn aggregate_kernel_matches_cpu_reference() {
    let rt = mnist_backend();
    let p = rt.manifest().param_count;
    let u1: Vec<f32> = (0..p).map(|i| (i % 13) as f32 * 0.01).collect();
    let u2: Vec<f32> = (0..p).map(|i| (i % 7) as f32 * -0.02).collect();
    let w = [0.3f32, 0.7];
    let (agg, _) = rt.aggregate(&[&u1, &u2], &w).unwrap();
    for i in (0..p).step_by(997) {
        let want = 0.3 * u1[i] + 0.7 * u2[i];
        assert!(
            (agg[i] - want).abs() < 1e-5,
            "elem {i}: {} vs {}",
            agg[i],
            want
        );
    }
    // k_max overflow rejected
    let too_many: Vec<&[f32]> = (0..rt.manifest().k_max + 1).map(|_| &u1[..]).collect();
    let w_bad = vec![0.0f32; rt.manifest().k_max + 1];
    assert!(rt.aggregate(&too_many, &w_bad).is_err());
}

#[test]
fn evaluate_bounds_and_shape_checks() {
    let rt = mnist_backend();
    let mf = rt.manifest();
    let data = SynthDataset::from_manifest(mf, 4, 1, Default::default()).unwrap();
    let eval = data.eval_data();
    let p0 = rt.init_params().unwrap();
    let r = rt.evaluate(&p0, &eval.x, &eval.y).unwrap();
    assert!((0.0..=1.0).contains(&r.accuracy));
    assert!(r.loss > 0.0);
    // wrong eval length rejected
    let bad_y = vec![0i32; 3];
    assert!(rt.evaluate(&p0, &eval.x, &bad_y).is_err());
    // wrong dtype rejected
    let bad_x = Features::I32(vec![0; mf.eval_size * mf.sample_elems()]);
    assert!(rt.evaluate(&p0, &bad_x, &eval.y).is_err());
}

#[test]
fn full_experiment_standard_has_high_eur_and_learns() {
    let rt = mnist_backend();
    let mut cfg = quick_cfg(StrategyKind::Fedlesscan, Scenario::Standard);
    cfg.rounds = 6;
    let mut ctl = Controller::new(cfg, &rt).unwrap();
    let res = ctl.run().unwrap();
    assert_eq!(res.rounds.len(), 6);
    assert!(res.mean_eur() > 0.85, "standard EUR {}", res.mean_eur());
    assert!(
        res.final_accuracy > 0.25,
        "no learning: acc {}",
        res.final_accuracy
    );
    assert!(res.total_cost > 0.0);
    assert!(res.total_time_s > 0.0);
}

#[test]
fn straggler_scenario_reduces_fedavg_eur() {
    let rt = mnist_backend();
    let run = |scenario| {
        let mut ctl = Controller::new(quick_cfg(StrategyKind::Fedavg, scenario), &rt).unwrap();
        ctl.run().unwrap()
    };
    let std = run(Scenario::Standard);
    let strag = run(Scenario::Straggler(50));
    assert!(
        strag.mean_eur() < std.mean_eur() - 0.2,
        "straggler EUR {} vs standard {}",
        strag.mean_eur(),
        std.mean_eur()
    );
}

#[test]
fn fedlesscan_beats_fedavg_eur_under_stragglers() {
    let rt = mnist_backend();
    let run = |strategy| {
        let mut cfg = quick_cfg(strategy, Scenario::Straggler(50));
        cfg.rounds = 8;
        let mut ctl = Controller::new(cfg, &rt).unwrap();
        ctl.run().unwrap()
    };
    let avg = run(StrategyKind::Fedavg);
    let scan = run(StrategyKind::Fedlesscan);
    assert!(
        scan.mean_eur() > avg.mean_eur(),
        "fedlesscan EUR {} !> fedavg {}",
        scan.mean_eur(),
        avg.mean_eur()
    );
}

#[test]
fn stale_updates_are_applied_by_fedlesscan() {
    let rt = mnist_backend();
    let mut cfg = quick_cfg(StrategyKind::Fedlesscan, Scenario::Straggler(50));
    cfg.straggler_slow_frac = 1.0; // all forced stragglers are slow
    cfg.rounds = 8;
    let mut ctl = Controller::new(cfg, &rt).unwrap();
    let res = ctl.run().unwrap();
    let stale_total: usize = res.rounds.iter().map(|r| r.stale_applied).sum();
    assert!(stale_total > 0, "no stale updates were ever folded in");
}

#[test]
fn experiment_is_deterministic_in_seed() {
    let rt = mnist_backend();
    let run = || {
        let mut ctl =
            Controller::new(quick_cfg(StrategyKind::Fedlesscan, Scenario::Straggler(30)), &rt)
                .unwrap();
        ctl.run().unwrap()
    };
    let a = run();
    let b = run();
    assert_eq!(a.final_accuracy, b.final_accuracy);
    assert_eq!(a.total_cost, b.total_cost);
    assert_eq!(a.total_time_s, b.total_time_s);
    let sel_a: Vec<_> = a.rounds.iter().map(|r| r.selected.clone()).collect();
    let sel_b: Vec<_> = b.rounds.iter().map(|r| r.selected.clone()).collect();
    assert_eq!(sel_a, sel_b);
}

#[test]
fn history_reflects_algorithm_one() {
    let rt = mnist_backend();
    let mut cfg = quick_cfg(StrategyKind::Fedavg, Scenario::Straggler(70));
    cfg.rounds = 6;
    let mut ctl = Controller::new(cfg, &rt).unwrap();
    let res = ctl.run().unwrap();
    let hist = ctl.history();
    // every selected client is recorded as invoked
    let mut invoked: Vec<usize> = res.invocations.keys().copied().collect();
    invoked.sort_unstable();
    for c in invoked {
        assert!(hist.get_ref(c).is_some());
        assert!(hist.get(c).invocations >= 1);
    }
    // with 70% stragglers someone must have missed rounds
    let missed_any = hist.iter().any(|(_, h)| h.missed_total() > 0);
    assert!(missed_any);
}

#[test]
fn result_files_round_trip() {
    let rt = mnist_backend();
    let mut ctl =
        Controller::new(quick_cfg(StrategyKind::Fedprox, Scenario::Standard), &rt).unwrap();
    let res = ctl.run().unwrap();
    let dir = std::env::temp_dir().join(format!("fedless-int-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let csv = dir.join("t.csv");
    let json = dir.join("t.json");
    res.write_timeline_csv(&csv).unwrap();
    res.write_json(&json).unwrap();
    let csv_text = std::fs::read_to_string(&csv).unwrap();
    assert_eq!(csv_text.lines().count(), 1 + res.rounds.len());
    let parsed = fedless::util::Json::parse_file(&json).unwrap();
    assert_eq!(parsed.get("dataset").unwrap().as_str().unwrap(), "mnist");
    assert_eq!(
        parsed.get("rounds").unwrap().as_arr().unwrap().len(),
        res.rounds.len()
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn token_model_backend_works() {
    let rt = NativeBackend::for_dataset("shakespeare").unwrap();
    let mf = rt.manifest();
    assert_eq!(mf.input_dtype, "i32");
    let data = SynthDataset::from_manifest(mf, 4, 2, Default::default()).unwrap();
    let shard = data.client_data(0);
    let p0 = rt.init_params().unwrap();
    let zeros = vec![0f32; p0.len()];
    let (res, _) = rt
        .train_round(&TrainRequest {
            params: &p0,
            m: &zeros,
            v: &zeros,
            t: 0.0,
            x: &shard.x,
            y: &shard.y,
            seed: 3,
            num_steps: mf.steps_per_round as i32,
            global: None,
        })
        .unwrap();
    assert!(res.loss.is_finite());
    assert_ne!(res.params, p0);
}

#[test]
fn every_preset_dataset_runs_a_round_natively() {
    // The backend seam must hold for all five families end to end.
    for dataset in ["mnist", "femnist", "shakespeare", "speech", "transformer"] {
        let rt = NativeBackend::for_dataset(dataset).unwrap();
        let mut cfg = ExperimentConfig::preset(dataset);
        cfg.rounds = 2;
        cfg.n_clients = 8;
        cfg.clients_per_round = 3;
        cfg.seed = 13;
        let mut ctl = Controller::new(cfg, &rt).unwrap();
        let res = ctl.run().unwrap();
        assert_eq!(res.rounds.len(), 2, "{dataset}");
        assert!(res.rounds[0].successes > 0, "{dataset}: nobody succeeded");
    }
}

#[test]
fn adaptive_clients_overprovisions_under_stragglers() {
    let rt = mnist_backend();
    let mut cfg = quick_cfg(StrategyKind::Fedavg, Scenario::Straggler(50));
    cfg.adaptive_clients = true;
    cfg.rounds = 6;
    let mut ctl = Controller::new(cfg.clone(), &rt).unwrap();
    let res = ctl.run().unwrap();
    // under 50% stragglers with random selection, later rounds must select
    // more than the configured k at least once
    let max_sel = res.rounds.iter().map(|r| r.selected.len()).max().unwrap();
    assert!(
        max_sel > cfg.clients_per_round,
        "adaptive k never grew: max {max_sel} vs k {}",
        cfg.clients_per_round
    );
    // and never beyond the 2x clamp
    assert!(max_sel <= cfg.clients_per_round * 2);
}

#[test]
fn in_flight_client_is_not_reinvoked_mid_flight() {
    // One client, forced slow: round 0 invokes it and its update lands
    // past the deadline, i.e. while round 1 is already running. The
    // scheduler must (a) skip the client in round 1 instead of
    // re-invoking it mid-flight, (b) fold the late update into round 1's
    // aggregation, and (c) re-invoke the client in round 2 once the
    // invocation has drained.
    let rt = mnist_backend();
    let mut cfg = quick_cfg(StrategyKind::Fedlesscan, Scenario::Straggler(100));
    cfg.straggler_slow_frac = 1.0; // the single straggler is slow, not crashed
    cfg.faas.transient_failure_rate = 0.0; // keep the timeline fully forced
    cfg.n_clients = 1;
    cfg.clients_per_round = 1;
    cfg.rounds = 4;
    let timeout = cfg.round_timeout_s();
    let mut ctl = Controller::new(cfg, &rt).unwrap();
    let res = ctl.run().unwrap();

    let r1 = &res.rounds[1];
    assert_eq!(r1.in_flight_skipped, 1, "round 1 must skip the in-flight client");
    assert_eq!(r1.successes, 0);
    assert_eq!(r1.failures, 0, "a skipped client is not a failure");
    assert_eq!(r1.eur, 0.0, "empty-round EUR is 0, not the vacuous 1.0");
    assert_eq!(r1.stale_applied, 1, "round 0's late update folds into round 1");
    assert!(
        (r1.duration_s - timeout).abs() < 1e-9,
        "a round blocked on stragglers waits out the deadline"
    );
    // round 2: the invocation has drained -> re-invoked (and late again)
    let r2 = &res.rounds[2];
    assert_eq!(r2.in_flight_skipped, 0);
    assert_eq!(r2.failures, 1);
    // exactly two real invocations across 4 rounds (rounds 0 and 2)
    assert_eq!(res.invocations.get(&0).copied().unwrap_or(0), 2);
    assert_eq!(ctl.history().get(0).invocations, 2);
}

#[test]
fn stale_norm_clip_is_noop_without_fresh_updates() {
    // With no fresh updates there is no reference distance: even a
    // pathological clip of 0.0 must not discard the drained stale
    // update (the filter needs this round's fresh set to calibrate).
    let rt = mnist_backend();
    let mut cfg = quick_cfg(StrategyKind::Fedlesscan, Scenario::Straggler(100));
    cfg.straggler_slow_frac = 1.0;
    cfg.faas.transient_failure_rate = 0.0;
    cfg.n_clients = 1;
    cfg.clients_per_round = 1;
    cfg.rounds = 2;
    cfg.stale_norm_clip = Some(0.0);
    let mut ctl = Controller::new(cfg, &rt).unwrap();
    let res = ctl.run().unwrap();
    assert_eq!(res.rounds[1].successes, 0);
    assert_eq!(res.rounds[1].stale_applied, 1);
}

#[test]
fn scheduler_timeline_is_deterministic_and_deadline_bounded() {
    // Scheduler-vs-deadline golden: the event-driven round (parallel
    // training included) is exactly reproducible, never exceeds the
    // scenario deadline, and respects the k_max aggregation cap.
    let rt = mnist_backend();
    let mut cfg = quick_cfg(StrategyKind::Fedlesscan, Scenario::Straggler(50));
    cfg.straggler_slow_frac = 1.0;
    cfg.rounds = 8;
    let timeout = cfg.round_timeout_s();
    let k_max = rt.manifest().k_max;
    let run = |cfg: ExperimentConfig| {
        let mut ctl = Controller::new(cfg, &rt).unwrap();
        ctl.run().unwrap()
    };
    let a = run(cfg.clone());
    let b = run(cfg);
    for (ra, rb) in a.rounds.iter().zip(&b.rounds) {
        assert_eq!(ra.selected, rb.selected);
        assert_eq!(ra.successes, rb.successes);
        assert_eq!(ra.failures, rb.failures);
        assert_eq!(ra.stale_applied, rb.stale_applied);
        assert_eq!(ra.in_flight_skipped, rb.in_flight_skipped);
        assert_eq!(ra.duration_s.to_bits(), rb.duration_s.to_bits());
        assert_eq!(ra.eur.to_bits(), rb.eur.to_bits());
        assert_eq!(ra.accuracy, rb.accuracy);
    }
    for r in &a.rounds {
        assert!(
            r.duration_s <= timeout + 1e-9,
            "round {} ran {}s past the {}s deadline",
            r.round,
            r.duration_s,
            timeout
        );
        assert!(
            r.successes.min(k_max) + r.stale_applied <= k_max,
            "round {} aggregated past k_max",
            r.round
        );
    }
    // the semi-async path actually exercised: stale updates folded in
    let stale_total: usize = a.rounds.iter().map(|r| r.stale_applied).sum();
    assert!(stale_total > 0);
}

#[test]
fn kmax_truncated_stale_updates_get_no_credit_or_count() {
    // Regression for the k_max truncation accounting bug: every client
    // is forced slow, so each round produces a burst of late updates and
    // the next round drains far more stale updates than k_max = 2 can
    // hold. Truncated-away updates must neither increment stale_applied
    // nor receive record_late_completion credit.
    let rt = common::MockBackend::new(2);
    let mut cfg = quick_cfg(StrategyKind::Fedlesscan, Scenario::Straggler(100));
    cfg.straggler_slow_frac = 1.0; // everyone slow: zero fresh, max stale
    cfg.n_clients = 12;
    cfg.clients_per_round = 6;
    cfg.rounds = 6;
    let mut ctl = Controller::new(cfg, &rt).unwrap();
    let res = ctl.run().unwrap();

    let k_max = rt.manifest().k_max;
    let mut stale_total = 0usize;
    for r in &res.rounds {
        assert_eq!(r.successes, 0);
        assert!(
            r.stale_applied <= k_max,
            "round {} applied {} stale with k_max {}",
            r.round,
            r.stale_applied,
            k_max
        );
        stale_total += r.stale_applied;
    }
    assert!(stale_total > 0, "no stale update was ever applied");
    // More late updates were produced than could ever be applied: with 6
    // slow invocations per round and 2 slots, truncation must have
    // happened at least once.
    let failures_total: usize = res.rounds.iter().map(|r| r.failures).sum();
    assert!(
        failures_total > stale_total,
        "test setup did not create truncation pressure"
    );
    // History credit identity: every recorded training time comes from
    // an on-time success (none here) or a credited late completion. The
    // seed credited truncated updates too, inflating this count.
    let credited: usize = ctl
        .history()
        .iter()
        .map(|(_, h)| h.times_count() as usize)
        .sum();
    assert_eq!(
        credited, stale_total,
        "late-completion credit must match applied stale updates exactly"
    );
}

#[test]
fn kmax_overflow_stale_updates_land_in_a_later_round() {
    // Regression for the cap_stale overflow discard: with every client
    // forced slow and k_max = 2, each drain truncates most of the
    // backlog. Truncated updates that are still τ-valid must re-buffer
    // and land in round t+1 — the seed dropped them permanently, so
    // "dry" rounds (all clients in flight, no new arrivals) applied
    // nothing.
    //
    // Virtual timeline (mnist straggler timeout = 60 s): round 0 invokes
    // 6 slow clients whose updates arrive ~75 s, i.e. inside round 1;
    // round 1 skips everyone (in flight) and drains the 6-update burst:
    // 2 applied, 4 re-buffered. Round 2 re-invokes (the new updates
    // arrive ~195 s, inside round 3), so its only candidates are the 4
    // re-buffered updates: 2 of them must land. τ = 4 keeps the
    // overflow valid across the extra round.
    let rt = common::MockBackend::new(2);
    let mut cfg = quick_cfg(StrategyKind::Fedlesscan, Scenario::Straggler(100));
    cfg.straggler_slow_frac = 1.0;
    cfg.faas.transient_failure_rate = 0.0;
    cfg.n_clients = 6;
    cfg.clients_per_round = 6;
    cfg.rounds = 6;
    let mut ctl = Controller::new(cfg, &rt).unwrap();
    ctl.set_strategy(Box::new(FedLesScan::new(FedLesScanParams {
        tau: 4,
        ..Default::default()
    })));
    let res = ctl.run().unwrap();

    let r1 = &res.rounds[1];
    assert_eq!(r1.in_flight_skipped, 6, "round 1 is blocked on round 0");
    assert_eq!(r1.stale_applied, 2, "burst drain caps at k_max");
    let r2 = &res.rounds[2];
    assert_eq!(r2.in_flight_skipped, 0, "round 2 re-invokes everyone");
    assert_eq!(
        r2.stale_applied, 2,
        "round 2 has no new arrivals: only re-buffered overflow can land"
    );
    for r in &res.rounds {
        assert!(r.stale_applied <= 2, "round {} broke the k_max cap", r.round);
    }
    // an update is applied (and credited) at most once, overflow or not
    let stale_total: usize = res.rounds.iter().map(|r| r.stale_applied).sum();
    let credited: usize = ctl
        .history()
        .iter()
        .map(|(_, h)| h.times_count() as usize)
        .sum();
    assert_eq!(credited, stale_total);
}

#[test]
fn prox_anchor_adds_no_param_plane_bytes() {
    // Zero-copy prox anchor regression: with a noise-free platform every
    // invocation is on-time, so a round's parameter plane holds exactly
    // the global snapshot + one buffer per trained client + the fold
    // accumulator = (k + 2) buffers. The FedProx anchor is an Arc view
    // of the same snapshot handed to every TrainRequest — the seed
    // deep-copied it, which would read (k + 3) here — so the prox peak
    // must equal the anchor-free FedAvg peak byte for byte.
    let rt = mnist_backend();
    let p_bytes = rt.manifest().param_count * std::mem::size_of::<f32>();
    let run = |strategy| {
        let mut cfg = quick_cfg(strategy, Scenario::Standard);
        cfg.faas.transient_failure_rate = 0.0;
        cfg.faas.client_speed_sigma = 1e-9;
        cfg.faas.invocation_jitter_sigma = 1e-9;
        cfg.faas.cold_start_sigma = 1e-9;
        cfg.rounds = 4;
        let mut ctl = Controller::new(cfg, &rt).unwrap();
        ctl.run().unwrap()
    };
    let prox = run(StrategyKind::Fedprox);
    let avg = run(StrategyKind::Fedavg);
    for (rp, ra) in prox.rounds.iter().zip(&avg.rounds) {
        assert_eq!(
            rp.successes,
            rp.selected.len(),
            "precondition: noise-free rounds are all on-time"
        );
        assert_eq!(
            rp.param_plane_peak_bytes,
            (rp.successes + 2) * p_bytes,
            "round {}: prox allocated an extra param buffer",
            rp.round
        );
        assert_eq!(rp.param_plane_peak_bytes, ra.param_plane_peak_bytes);
        assert!(rp.agg_wall_s >= 0.0);
    }
}

#[test]
fn stale_norm_clip_discards_outlier_stale_updates() {
    let rt = mnist_backend();
    let mk = |clip: Option<f64>| {
        let mut cfg = quick_cfg(StrategyKind::Fedlesscan, Scenario::Straggler(50));
        cfg.straggler_slow_frac = 1.0;
        cfg.rounds = 8;
        cfg.stale_norm_clip = clip;
        let mut ctl = Controller::new(cfg, &rt).unwrap();
        ctl.run().unwrap()
    };
    let unclipped = mk(None);
    let clipped = mk(Some(0.0)); // pathological clip: discard everything
    let stale_un: usize = unclipped.rounds.iter().map(|r| r.stale_applied).sum();
    let stale_cl: usize = clipped.rounds.iter().map(|r| r.stale_applied).sum();
    assert!(stale_un > 0);
    assert_eq!(stale_cl, 0, "clip=0 must discard all stale updates");
}

#[test]
fn round_mode_results_are_invariant_in_worker_count() {
    // The executor plane only moves *where* training computes; the
    // virtual timeline, RNG streams and aggregation order are fixed by
    // the coordinator. One worker vs many must be byte-identical.
    let rt = mnist_backend();
    let run = |workers: usize| {
        let mut cfg = quick_cfg(StrategyKind::Fedlesscan, Scenario::Straggler(30));
        cfg.workers = Some(workers);
        let mut ctl = Controller::new(cfg, &rt).unwrap();
        ctl.run().unwrap()
    };
    let a = run(1);
    let b = run(4);
    assert_eq!(a.final_accuracy, b.final_accuracy);
    assert_eq!(a.total_cost, b.total_cost);
    assert_eq!(a.total_time_s.to_bits(), b.total_time_s.to_bits());
    for (ra, rb) in a.rounds.iter().zip(&b.rounds) {
        assert_eq!(ra.selected, rb.selected, "round {} drifted", ra.round);
        assert_eq!(ra.successes, rb.successes);
        assert_eq!(ra.stale_applied, rb.stale_applied);
        assert_eq!(ra.duration_s.to_bits(), rb.duration_s.to_bits());
    }
}

#[test]
fn continuous_mode_replays_and_respects_budget() {
    // Fast every-`cargo test` cousin of the golden: same-seed replay is
    // bit-identical, the invocation budget is exact, and the fold
    // generation counter agrees with the fold count (each fold installs
    // exactly one new global). Worker count must not matter here either.
    let rt = mnist_backend();
    let run = |workers: Option<usize>| {
        let mut cfg = quick_cfg(StrategyKind::Fedlesscan, Scenario::Straggler(30));
        cfg.mode = Mode::Continuous;
        cfg.inflight_cohorts = 2;
        cfg.workers = workers;
        let mut ctl = Controller::new(cfg, &rt).unwrap();
        ctl.run_continuous().unwrap()
    };
    let a = run(Some(1));
    let b = run(Some(3));
    assert_eq!(a.dispatched, 5 * 6, "budget is rounds x clients_per_round");
    assert_eq!(a.completions, a.dispatched, "every invocation completes");
    assert_eq!(a.folds as u32, a.final_generation);
    assert_eq!(
        a.folds + a.crashes + a.expired,
        a.completions,
        "every completion folds, crashes, or expires"
    );
    assert!(a.folds > 0, "nothing folded");
    assert_eq!(a.windows.iter().map(|w| w.dispatched).sum::<usize>(), a.dispatched);
    assert_eq!(a.windows.iter().map(|w| w.folds).sum::<usize>(), a.folds);

    assert_eq!(a.dispatched, b.dispatched);
    assert_eq!(a.folds, b.folds);
    assert_eq!(a.crashes, b.crashes);
    assert_eq!(a.late, b.late);
    assert_eq!(a.duration_s.to_bits(), b.duration_s.to_bits());
    assert_eq!(a.total_cost.to_bits(), b.total_cost.to_bits());
    assert_eq!(a.invocations, b.invocations);
    // the model actually trained: continuous folds move the global, so
    // accuracy is a real evaluation, not the init params
    assert!(a.final_accuracy > 0.0 && a.final_accuracy <= 1.0);
}
