//! Golden-value tests pinning the `NativeBackend` to the hand-written
//! reference semantics of `python/compile/kernels/ref.py`:
//!
//! * `aggregate_ref` — `sum_k w_k * u_k` in f32, checked against an
//!   independent f64 scalar loop and against the zero-weight/pad rules;
//! * `staleness_weights_ref` — the Eq. 3 weights feeding the kernel,
//!   checked end to end (weights * backend aggregation == the reference
//!   convex combination);
//! * the training round — gradient correctness is verified against
//!   central finite differences of the loss (backend-independent ground
//!   truth), and loss must decrease over 3 sequential rounds on a
//!   fixed-seed synthetic dataset.

use fedless::data::{Features, SynthDataset};
use fedless::paramsvr::{staleness_weights, weight_component, WeightedUpdate};
use fedless::runtime::manifest::{Entrypoint, Manifest};
use fedless::runtime::{Backend, NativeBackend, TrainRequest};

/// A tiny fully-specified SGD model (d=10, h=16, c=7) so finite
/// differences are cheap and exact-seed reproducible.
fn tiny_sgd_backend() -> NativeBackend {
    let (d, h, c) = (10usize, 16usize, 7usize);
    let ep = |name: &str| Entrypoint {
        file: format!("<native:{name}>"),
        inputs: Vec::new(),
        outputs: Vec::new(),
    };
    let manifest = Manifest {
        name: "tiny".into(),
        scale: "test".into(),
        param_count: d * h + h + h * c + c,
        num_classes: c,
        input_shape: vec![d],
        input_dtype: "f32".into(),
        shard_size: 8,
        batch_size: 8,
        local_epochs: 1,
        steps_per_round: 1,
        optimizer: "sgd".into(),
        lr: 0.5,
        prox_mu: 0.1,
        eval_size: 16,
        eval_batch: 16,
        k_max: 8,
        seq_len: None,
        flops_per_round: 1000,
        entrypoints: ["train", "train_prox", "eval", "aggregate"]
            .iter()
            .map(|n| (n.to_string(), ep(n)))
            .collect(),
        init_file: "<builtin>".into(),
        init_sha256: "<builtin>".into(),
        init_seed: 0,
    };
    NativeBackend::from_manifest(manifest, h).unwrap()
}

fn tiny_shard(d: usize, n: usize, c: usize) -> (Features, Vec<i32>) {
    // deterministic, label-correlated features
    let mut x = Vec::with_capacity(n * d);
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        let label = (i * 3 + 1) % c;
        y.push(label as i32);
        for j in 0..d {
            let v = ((i * 31 + j * 17) % 23) as f32 / 23.0 + 0.3 * label as f32 / c as f32;
            x.push(v);
        }
    }
    (Features::F32(x), y)
}

/// One SGD step with the shard-sized batch: the parameter delta divided
/// by the learning rate *is* the gradient the backend computed.
fn analytic_grad(rt: &NativeBackend, params: &[f32], x: &Features, y: &[i32]) -> (Vec<f32>, f32) {
    let zeros = vec![0f32; params.len()];
    let (res, _) = rt
        .train_round(&TrainRequest {
            params,
            m: &zeros,
            v: &zeros,
            t: 0.0,
            x,
            y,
            seed: 5,
            num_steps: 1,
            global: None,
        })
        .unwrap();
    let lr = rt.manifest().lr as f32;
    let g = params
        .iter()
        .zip(&res.params)
        .map(|(p0, p1)| (p0 - p1) / lr)
        .collect();
    // num_steps=1: the reported loss is the pre-step loss of this batch
    (g, res.loss)
}

#[test]
fn backward_matches_finite_differences() {
    let rt = tiny_sgd_backend();
    let mf = rt.manifest();
    let (x, y) = tiny_shard(10, mf.shard_size, mf.num_classes);
    let p0 = rt.init_params().unwrap();
    let (g, _) = analytic_grad(&rt, &p0, &x, &y);

    let loss_at = |params: &[f32]| -> f32 { analytic_grad(&rt, params, &x, &y).1 };
    let eps = 1e-2f32;
    // probe every layer: w1 head, w1 interior, b1, w2, b2 tail
    let probes = [0usize, 37, 10 * 16 + 3, 10 * 16 + 16 + 5, p0.len() - 1];
    for &i in &probes {
        let mut pp = p0.clone();
        pp[i] += eps;
        let mut pm = p0.clone();
        pm[i] -= eps;
        let numeric = (loss_at(&pp) - loss_at(&pm)) / (2.0 * eps);
        let diff = (numeric - g[i]).abs();
        assert!(
            diff < 1e-3 + 0.05 * numeric.abs(),
            "coordinate {i}: analytic {} vs numeric {numeric} (diff {diff})",
            g[i]
        );
    }
}

#[test]
fn loss_decreases_over_three_rounds_fixed_seed() {
    let rt = NativeBackend::for_dataset("mnist").unwrap();
    let mf = rt.manifest();
    let data = SynthDataset::from_manifest(mf, 4, 3, Default::default()).unwrap();
    let shard = data.client_data(0);
    let mut params = rt.init_params().unwrap();
    let zeros = vec![0f32; params.len()];
    let mut losses = Vec::new();
    for seed in 1..=3 {
        let (res, _) = rt
            .train_round(&TrainRequest {
                params: &params,
                m: &zeros,
                v: &zeros,
                t: 0.0,
                x: &shard.x,
                y: &shard.y,
                seed,
                num_steps: mf.steps_per_round as i32,
                global: None,
            })
            .unwrap();
        losses.push(res.loss);
        params = res.params;
    }
    assert!(
        losses.windows(2).all(|w| w[1] < w[0]),
        "losses must strictly decrease over 3 rounds: {losses:?}"
    );
    assert!(losses.iter().all(|l| l.is_finite() && *l >= 0.0));
}

#[test]
fn aggregation_matches_f64_reference() {
    let rt = NativeBackend::for_dataset("mnist").unwrap();
    let p = rt.manifest().param_count;
    let updates: Vec<Vec<f32>> = (0..4)
        .map(|k| (0..p).map(|i| ((i + k * 7) % 11) as f32 * 0.03 - 0.15).collect())
        .collect();
    let refs: Vec<&[f32]> = updates.iter().map(Vec::as_slice).collect();
    let weights = [0.1f32, 0.4, 0.2, 0.3];
    let (agg, _) = rt.aggregate(&refs, &weights).unwrap();
    for i in (0..p).step_by(313) {
        let want: f64 = updates
            .iter()
            .zip(&weights)
            .map(|(u, &w)| f64::from(w) * f64::from(u[i]))
            .sum();
        assert!(
            (f64::from(agg[i]) - want).abs() < 1e-5,
            "elem {i}: {} vs {want}",
            agg[i]
        );
    }
}

#[test]
fn aggregation_weights_match_staleness_reference() {
    // End-to-end Eq. 3: weights from `staleness_weights` (the Rust twin
    // of `staleness_weights_ref`) drive the backend aggregation; the
    // result must be the reference convex combination.
    let rt = NativeBackend::for_dataset("mnist").unwrap();
    let p = rt.manifest().param_count;
    let fresh: Vec<f32> = (0..p).map(|i| (i % 5) as f32 * 0.1).collect();
    let stale: Vec<f32> = (0..p).map(|i| (i % 3) as f32 * -0.2).collect();
    let expired: Vec<f32> = vec![9.9; p]; // must contribute nothing

    let t = 10u32;
    let tau = 2u32;
    let winfo = [
        WeightedUpdate {
            produced_round: 10,
            cardinality: 20,
        },
        WeightedUpdate {
            produced_round: 9,
            cardinality: 20,
        },
        WeightedUpdate {
            produced_round: 7,
            cardinality: 20,
        }, // age 3 >= tau
    ];
    let weights = staleness_weights(&winfo, t, tau, true);
    assert_eq!(weights[2], 0.0, "expired update must get weight 0");
    let wsum: f32 = weights.iter().sum();
    assert!((wsum - 1.0).abs() < 1e-5, "normalized weights sum {wsum}");
    // reference semantics: damp_k = t_k/t, scaled by n_k/n, renormalized
    let (w0, w1) = (weights[0], weights[1]);
    assert!((w1 / w0 - 0.9).abs() < 1e-4, "damping ratio {} != t_k/t", w1 / w0);

    let (agg, _) = rt
        .aggregate(&[&fresh, &stale, &expired], &weights)
        .unwrap();
    for i in (0..p).step_by(611) {
        let want = w0 * fresh[i] + w1 * stale[i];
        assert!(
            (agg[i] - want).abs() < 1e-5,
            "elem {i}: {} vs {want}",
            agg[i]
        );
    }
}

#[test]
fn streaming_component_fold_matches_batch_staleness_path() {
    // The coordinator's streaming aggregation: fold each update with its
    // Eq. 3 component c_k = (t_k/t)·n_k, divide by Z once at the end.
    // Must match the batch reference (staleness_weights + aggregate)
    // within 1e-5 — the two differ only in f32 rounding order.
    let rt = NativeBackend::for_dataset("mnist").unwrap();
    let p = rt.manifest().param_count;
    let updates: Vec<Vec<f32>> = (0..3)
        .map(|k| (0..p).map(|i| ((i + k * 5) % 9) as f32 * 0.05 - 0.2).collect())
        .collect();
    let winfo = [
        WeightedUpdate {
            produced_round: 10,
            cardinality: 20,
        },
        WeightedUpdate {
            produced_round: 9,
            cardinality: 35,
        },
        WeightedUpdate {
            produced_round: 8,
            cardinality: 10,
        },
    ];
    let (t, tau) = (10u32, 3u32);
    for normalize in [false, true] {
        let weights = staleness_weights(&winfo, t, tau, normalize);
        let refs: Vec<&[f32]> = updates.iter().map(Vec::as_slice).collect();
        let (batch, _) = rt.aggregate(&refs, &weights).unwrap();

        let mut fold = rt.begin_fold(3).unwrap();
        let mut comp_sum = 0.0f64;
        let mut card_sum = 0.0f64;
        for (u, w) in updates.iter().zip(&winfo) {
            let c = weight_component(w.produced_round, w.cardinality, t, tau).unwrap();
            fold.accumulate(u, c as f32).unwrap();
            comp_sum += c;
            card_sum += w.cardinality as f64;
        }
        let z = if normalize { comp_sum } else { card_sum };
        let (mut streamed, _) = fold.finish().unwrap();
        let scale = (1.0 / z) as f32;
        streamed.iter_mut().for_each(|x| *x *= scale);

        for i in (0..p).step_by(211) {
            assert!(
                (f64::from(streamed[i]) - f64::from(batch[i])).abs() < 1e-5,
                "normalize={normalize} elem {i}: {} vs {}",
                streamed[i],
                batch[i]
            );
        }
    }
}

#[test]
fn init_params_match_glorot_reference_stats() {
    // ref semantics (archs/common.py dense_init): uniform in ±sqrt(6/(fan_in+fan_out)),
    // biases zero. Check bounds and that the empirical mean is near zero.
    let rt = NativeBackend::for_dataset("femnist").unwrap();
    let mf = rt.manifest();
    let p0 = rt.init_params().unwrap();
    let d = mf.sample_elems();
    let h = rt.hidden();
    let lim1 = (6.0 / (d + h) as f64).sqrt();
    let w1 = &p0[..d * h];
    assert!(w1.iter().all(|w| (f64::from(*w)).abs() <= lim1));
    let mean: f64 = w1.iter().map(|w| f64::from(*w)).sum::<f64>() / w1.len() as f64;
    assert!(mean.abs() < 0.01 * lim1 + 1e-3, "w1 mean {mean} vs lim {lim1}");
}
