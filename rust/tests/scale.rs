//! Fleet-scale smoke tests, `#[ignore]`-gated: they need release-mode
//! optimization to meet their wall-clock budgets, so the CI release job
//! runs them explicitly:
//!
//!   cargo test --release -q -- --ignored
//!
//! Budgets are deliberately generous (shared CI runners); the point is
//! catching accidental O(n²) regressions in the behaviour plane — the
//! pre-refactor path at 50k clients is ~10^9–10^10 distance
//! computations per ε candidate and would blow these budgets by orders
//! of magnitude, not percents.

mod common;

use std::time::{Duration, Instant};

use fedless::clientdb::HistoryStore;
use fedless::config::{ExperimentConfig, Mode, Scenario};
use fedless::coordinator::Controller;
use fedless::strategy::{FedLesScan, SelectionContext, Strategy, StrategyKind};
use fedless::util::Rng;
use fedless::ClientId;

/// Scripted 50k-client behaviour history: a sparse rookie sliver (so
/// the rookie shortcut cannot cover the round and selection *must*
/// cluster), ~10% live stragglers, the rest participants with a few
/// recorded events each — the full tier → stratified-cohort →
/// grid-DBSCAN path is what the wall-clock budget measures.
fn fleet_history(n: usize) -> HistoryStore {
    let mut hist = HistoryStore::new();
    for c in 0..n {
        match c % 10 {
            0 if c % 500 == 0 => {} // sparse rookies (~0.2%)
            2 => {
                hist.record_invocation(c);
                hist.record_failure(c, 3); // live cooldown: straggler
            }
            _ => {
                hist.record_invocation(c);
                hist.record_success(c, 0, 5.0 + (c % 211) as f64 * 0.4);
                hist.record_invocation(c);
                hist.record_success(c, 1, 5.0 + ((c * 7) % 211) as f64 * 0.4);
                if c % 13 == 0 {
                    // a past miss followed by an on-time success: missed-
                    // round texture in the window, cooldown back to 0
                    hist.record_invocation(c);
                    hist.record_failure(c, 2);
                    hist.record_invocation(c);
                    hist.record_success(c, 3, 6.0 + (c % 31) as f64);
                }
            }
        }
    }
    hist
}

#[test]
#[ignore = "release-mode scale smoke; run via cargo test --release -- --ignored"]
fn selection_over_50k_clients_is_subsecond_scale_and_deterministic() {
    let n = 50_000usize;
    let k = 256usize;
    let hist = fleet_history(n);
    let clients: Vec<ClientId> = (0..n).collect();
    let run = || {
        let mut strat = FedLesScan::default();
        let mut rng = Rng::seed_from_u64(99);
        let ctx = SelectionContext {
            round: 5,
            max_rounds: 40,
            clients_per_round: k,
            all_clients: &clients,
            history: &hist,
        };
        let t0 = Instant::now();
        let sel = strat.select(&ctx, &mut rng);
        (sel, t0.elapsed())
    };
    let (a, wall_a) = run();
    let (b, _) = run();
    assert_eq!(a, b, "selection must be deterministic in the seed");
    assert_eq!(a.len(), k);
    let mut d = a.clone();
    d.sort_unstable();
    d.dedup();
    assert_eq!(d.len(), k, "duplicate clients selected");
    // Budget: the grid-indexed cohort path runs in tens of milliseconds
    // in release; 10 s is the "did someone reintroduce O(n²)" alarm.
    assert!(
        wall_a < Duration::from_secs(10),
        "50k-client selection took {wall_a:?}"
    );
}

#[test]
#[ignore = "release-mode scale smoke; run via cargo test --release -- --ignored"]
fn a_50k_client_mock_round_completes_within_budget_and_replays() {
    // generous k_max: the fleet round aggregates freely; the shared
    // mock keeps a 50k-client experiment at selection + scheduling cost
    let rt = common::MockBackend::new(512);
    let mut cfg = ExperimentConfig::preset("mnist");
    cfg.strategy = StrategyKind::Fedlesscan;
    cfg.scenario = Scenario::Standard;
    cfg.n_clients = 50_000;
    cfg.clients_per_round = 128;
    cfg.rounds = 2;
    cfg.seed = 23;
    let run = |cfg: ExperimentConfig| {
        let t0 = Instant::now();
        let mut ctl = Controller::new(cfg, &rt).unwrap();
        let res = ctl.run().unwrap();
        (res, t0.elapsed())
    };
    let (a, wall) = run(cfg.clone());
    let (b, _) = run(cfg);
    assert_eq!(a.rounds.len(), 2);
    for (ra, rb) in a.rounds.iter().zip(&b.rounds) {
        assert_eq!(ra.selected, rb.selected, "round {} drifted", ra.round);
        assert_eq!(ra.successes, rb.successes);
        assert_eq!(ra.failures, rb.failures);
        assert_eq!(ra.duration_s.to_bits(), rb.duration_s.to_bits());
        assert!(ra.select_wall_s >= 0.0);
    }
    assert!(a.rounds[0].successes > 0, "nobody trained");
    assert!(
        wall < Duration::from_secs(60),
        "50k-client 2-round experiment took {wall:?}"
    );
}

#[test]
#[ignore = "release-mode scale smoke; run via cargo test --release -- --ignored"]
fn continuous_mode_scales_to_thousands_of_clients_and_replays() {
    // Continuous-mode counterpart of the round smoke: a few-thousand-
    // client fleet, a multi-thousand-invocation budget, everything
    // through the persistent executor pool — and the full event
    // timeline must replay bit-for-bit on a second run.
    let rt = common::MockBackend::new(512);
    let mut cfg = ExperimentConfig::preset("mnist");
    cfg.strategy = StrategyKind::Fedlesscan;
    cfg.scenario = Scenario::Straggler(30);
    cfg.mode = Mode::Continuous;
    cfg.n_clients = 4_000;
    cfg.clients_per_round = 64;
    cfg.rounds = 40; // budget: 2560 invocations
    cfg.inflight_cohorts = 2;
    cfg.seed = 23;
    let run = |cfg: ExperimentConfig| {
        let t0 = Instant::now();
        let mut ctl = Controller::new(cfg, &rt).unwrap();
        let res = ctl.run_continuous().unwrap();
        (res, t0.elapsed())
    };
    let (a, wall) = run(cfg.clone());
    let (b, _) = run(cfg);
    assert!(a.folds > 0, "nothing folded");
    assert_eq!(a.dispatched, b.dispatched);
    assert_eq!(a.completions, b.completions);
    assert_eq!(a.folds, b.folds);
    assert_eq!(a.crashes, b.crashes);
    assert_eq!(a.expired, b.expired);
    assert_eq!(a.late, b.late);
    assert_eq!(a.final_generation, b.final_generation);
    assert_eq!(
        a.duration_s.to_bits(),
        b.duration_s.to_bits(),
        "virtual timeline drifted across replays"
    );
    assert_eq!(a.windows.len(), b.windows.len());
    for (wa, wb) in a.windows.iter().zip(&b.windows) {
        assert_eq!(wa.dispatched, wb.dispatched, "window {} drifted", wa.window);
        assert_eq!(wa.completions, wb.completions);
        assert_eq!(wa.folds, wb.folds);
        assert_eq!(wa.crashes, wb.crashes);
        assert_eq!(wa.expired, wb.expired);
        assert_eq!(wa.in_flight_peak, wb.in_flight_peak);
    }
    assert!(
        wall < Duration::from_secs(120),
        "continuous 2560-invocation experiment took {wall:?}"
    );
}
