//! Fleet-scale smoke tests, `#[ignore]`-gated: they need release-mode
//! optimization to meet their wall-clock budgets, so the CI release job
//! runs them explicitly:
//!
//!   cargo test --release -q -- --ignored
//!
//! Budgets are deliberately generous (shared CI runners); the point is
//! catching accidental O(n²) regressions in the behaviour plane — the
//! pre-refactor path at 50k clients is ~10^9–10^10 distance
//! computations per ε candidate and would blow these budgets by orders
//! of magnitude, not percents.

mod common;

use std::time::{Duration, Instant};

use fedless::clientdb::HistoryStore;
use fedless::config::{ExperimentConfig, Mode, Scenario};
use fedless::coordinator::Controller;
use fedless::strategy::{FedLesScan, SelectionContext, Strategy, StrategyKind};
use fedless::util::Rng;
use fedless::ClientId;

/// Scripted 50k-client behaviour history: a sparse rookie sliver (so
/// the rookie shortcut cannot cover the round and selection *must*
/// cluster), ~10% live stragglers, the rest participants with a few
/// recorded events each — the full tier → stratified-cohort →
/// grid-DBSCAN path is what the wall-clock budget measures.
fn fleet_history(n: usize) -> HistoryStore {
    let mut hist = HistoryStore::new();
    for c in 0..n {
        match c % 10 {
            0 if c % 500 == 0 => {} // sparse rookies (~0.2%)
            2 => {
                hist.record_invocation(c);
                hist.record_failure(c, 3); // live cooldown: straggler
            }
            _ => {
                hist.record_invocation(c);
                hist.record_success(c, 0, 5.0 + (c % 211) as f64 * 0.4);
                hist.record_invocation(c);
                hist.record_success(c, 1, 5.0 + ((c * 7) % 211) as f64 * 0.4);
                if c % 13 == 0 {
                    // a past miss followed by an on-time success: missed-
                    // round texture in the window, cooldown back to 0
                    hist.record_invocation(c);
                    hist.record_failure(c, 2);
                    hist.record_invocation(c);
                    hist.record_success(c, 3, 6.0 + (c % 31) as f64);
                }
            }
        }
    }
    hist
}

#[test]
#[ignore = "release-mode scale smoke; run via cargo test --release -- --ignored"]
fn selection_over_50k_clients_is_subsecond_scale_and_deterministic() {
    let n = 50_000usize;
    let k = 256usize;
    let hist = fleet_history(n);
    let clients: Vec<ClientId> = (0..n).collect();
    let run = || {
        let mut strat = FedLesScan::default();
        let mut rng = Rng::seed_from_u64(99);
        let ctx = SelectionContext {
            round: 5,
            max_rounds: 40,
            clients_per_round: k,
            all_clients: &clients,
            history: &hist,
        };
        let t0 = Instant::now();
        let sel = strat.select(&ctx, &mut rng);
        (sel, t0.elapsed())
    };
    let (a, wall_a) = run();
    let (b, _) = run();
    assert_eq!(a, b, "selection must be deterministic in the seed");
    assert_eq!(a.len(), k);
    let mut d = a.clone();
    d.sort_unstable();
    d.dedup();
    assert_eq!(d.len(), k, "duplicate clients selected");
    // Budget: the grid-indexed cohort path runs in tens of milliseconds
    // in release; 10 s is the "did someone reintroduce O(n²)" alarm.
    assert!(
        wall_a < Duration::from_secs(10),
        "50k-client selection took {wall_a:?}"
    );
}

/// 1M-client behaviour history with deliberately componentized
/// geometry: one tight giant behaviour blob (40% of the fleet, so the
/// ε grid search's sampled low quantiles land *inside* it) plus 600
/// small blobs separated by ~50 virtual seconds — far beyond any
/// plausible winning ε, so each blob is its own cell-component and a
/// drift event reclusters only the blob it lands in. Rookies are a
/// sparse sliver (< k) so selection must walk the clustered path.
fn componentized_fleet(n: usize) -> HistoryStore {
    let giant = n * 2 / 5;
    let mut hist = HistoryStore::new();
    for c in 0..n {
        if c % 5000 == 0 {
            continue; // sparse rookies (~0.02%)
        }
        let center = if c < giant {
            10.0
        } else {
            500.0 + ((c - giant) / 1000) as f64 * 50.0
        };
        let j1 = (c % 197) as f64 / 197.0 - 0.5; // deterministic jitter
        let j2 = ((c * 13) % 197) as f64 / 197.0 - 0.5;
        hist.record_invocation(c);
        hist.record_success(c, 0, center + j1);
        hist.record_invocation(c);
        hist.record_success(c, 1, center + j2);
    }
    hist
}

#[test]
#[ignore = "release-mode scale smoke; run via cargo test --release -- --ignored"]
fn incremental_selection_over_1m_clients_reclusters_only_the_drift() {
    // The tentpole acceptance check: after the first (full-build)
    // selection over a 1M-client fleet, a low-drift schedule — events
    // touching ~0.1% of clients, all inside one behaviour blob — must
    // keep the next selection's recluster work proportional to the
    // drift, not the fleet, and the whole sequence must replay
    // deterministically.
    let n = 1_000_000usize;
    let k = 512usize;
    let giant = n * 2 / 5;
    let clients: Vec<ClientId> = (0..n).collect();
    let run = || {
        let mut hist = componentized_fleet(n);
        let mut strat = FedLesScan::with_incremental();
        let mut rng = Rng::seed_from_u64(99);
        fn ctx<'a>(
            clients: &'a [ClientId],
            h: &'a HistoryStore,
            round: u32,
            k: usize,
        ) -> SelectionContext<'a> {
            SelectionContext {
                round,
                max_rounds: 40,
                clients_per_round: k,
                all_clients: clients,
                history: h,
            }
        }
        let t0 = Instant::now();
        let first = strat.select(&ctx(&clients, &hist, 10, k), &mut rng);
        let build_wall = t0.elapsed();
        let rep1 = strat.take_select_report().expect("incremental path reports");
        // low-drift schedule: fresh successes for ~1000 clients of
        // small blob 7, times staying inside the blob
        let blob7 = giant + 7 * 1000;
        for c in blob7..blob7 + 1000 {
            if c % 5000 == 0 {
                continue; // leave the rookie sliver alone
            }
            hist.record_invocation(c);
            hist.record_success(c, 2, 500.0 + 7.0 * 50.0 + ((c * 31) % 197) as f64 / 197.0 - 0.5);
        }
        let t1 = Instant::now();
        let second = strat.select(&ctx(&clients, &hist, 11, k), &mut rng);
        let drift_wall = t1.elapsed();
        let rep2 = strat.take_select_report().expect("incremental path reports");
        (first, rep1, build_wall, second, rep2, drift_wall)
    };
    let (first_a, rep1_a, build_wall, second_a, rep2_a, drift_wall) = run();
    let (first_b, rep1_b, _, second_b, rep2_b, _) = run();
    assert_eq!(first_a, first_b, "first selection must replay");
    assert_eq!(second_a, second_b, "post-drift selection must replay");
    assert_eq!(rep1_a.reclustered_clients, rep1_b.reclustered_clients);
    assert_eq!(rep2_a.reclustered_clients, rep2_b.reclustered_clients);
    assert_eq!(rep2_a.cluster_cache_hits, rep2_b.cluster_cache_hits);
    for sel in [&first_a, &second_a] {
        assert_eq!(sel.len(), k);
        let mut d = (*sel).clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), k, "duplicate clients selected");
    }
    // first pass clusters the whole participant tier...
    assert!(
        rep1_a.reclustered_clients > n / 2,
        "full build reclustered only {} of {n}",
        rep1_a.reclustered_clients
    );
    // ...the drift pass reclusters only the touched blob's component
    assert!(
        rep2_a.reclustered_clients > 0,
        "drift events produced no recluster work"
    );
    assert!(
        rep2_a.reclustered_clients <= n / 100,
        "low-drift pass reclustered {} of {n} — locality lost",
        rep2_a.reclustered_clients
    );
    assert!(
        rep2_a.cluster_cache_hits >= n / 2,
        "standing assignments not reused: {} cache hits",
        rep2_a.cluster_cache_hits
    );
    // wall budgets: generous CI alarms, not perf targets. The build
    // pays the one-off ε search + full clustering; the drift pass must
    // be far under the 50k-era full-recluster budget.
    assert!(
        build_wall < Duration::from_secs(300),
        "1M-client cold selection took {build_wall:?}"
    );
    assert!(
        drift_wall < Duration::from_secs(10),
        "1M-client low-drift selection took {drift_wall:?}"
    );
}

#[test]
#[ignore = "release-mode scale smoke; run via cargo test --release -- --ignored"]
fn a_50k_client_mock_round_completes_within_budget_and_replays() {
    // generous k_max: the fleet round aggregates freely; the shared
    // mock keeps a 50k-client experiment at selection + scheduling cost
    let rt = common::MockBackend::new(512);
    let mut cfg = ExperimentConfig::preset("mnist");
    cfg.strategy = StrategyKind::Fedlesscan;
    cfg.scenario = Scenario::Standard;
    cfg.n_clients = 50_000;
    cfg.clients_per_round = 128;
    cfg.rounds = 2;
    cfg.seed = 23;
    let run = |cfg: ExperimentConfig| {
        let t0 = Instant::now();
        let mut ctl = Controller::new(cfg, &rt).unwrap();
        let res = ctl.run().unwrap();
        (res, t0.elapsed())
    };
    let (a, wall) = run(cfg.clone());
    let (b, _) = run(cfg);
    assert_eq!(a.rounds.len(), 2);
    for (ra, rb) in a.rounds.iter().zip(&b.rounds) {
        assert_eq!(ra.selected, rb.selected, "round {} drifted", ra.round);
        assert_eq!(ra.successes, rb.successes);
        assert_eq!(ra.failures, rb.failures);
        assert_eq!(ra.duration_s.to_bits(), rb.duration_s.to_bits());
        assert!(ra.select_wall_s >= 0.0);
    }
    assert!(a.rounds[0].successes > 0, "nobody trained");
    assert!(
        wall < Duration::from_secs(60),
        "50k-client 2-round experiment took {wall:?}"
    );
}

#[test]
#[ignore = "release-mode scale smoke; run via cargo test --release -- --ignored"]
fn continuous_mode_scales_to_thousands_of_clients_and_replays() {
    // Continuous-mode counterpart of the round smoke: a few-thousand-
    // client fleet, a multi-thousand-invocation budget, everything
    // through the persistent executor pool — and the full event
    // timeline must replay bit-for-bit on a second run.
    let rt = common::MockBackend::new(512);
    let mut cfg = ExperimentConfig::preset("mnist");
    cfg.strategy = StrategyKind::Fedlesscan;
    cfg.scenario = Scenario::Straggler(30);
    cfg.mode = Mode::Continuous;
    cfg.n_clients = 4_000;
    cfg.clients_per_round = 64;
    cfg.rounds = 40; // budget: 2560 invocations
    cfg.inflight_cohorts = 2;
    cfg.seed = 23;
    let run = |cfg: ExperimentConfig| {
        let t0 = Instant::now();
        let mut ctl = Controller::new(cfg, &rt).unwrap();
        let res = ctl.run_continuous().unwrap();
        (res, t0.elapsed())
    };
    let (a, wall) = run(cfg.clone());
    let (b, _) = run(cfg);
    assert!(a.folds > 0, "nothing folded");
    assert_eq!(a.dispatched, b.dispatched);
    assert_eq!(a.completions, b.completions);
    assert_eq!(a.folds, b.folds);
    assert_eq!(a.crashes, b.crashes);
    assert_eq!(a.expired, b.expired);
    assert_eq!(a.late, b.late);
    assert_eq!(a.final_generation, b.final_generation);
    assert_eq!(
        a.duration_s.to_bits(),
        b.duration_s.to_bits(),
        "virtual timeline drifted across replays"
    );
    assert_eq!(a.windows.len(), b.windows.len());
    for (wa, wb) in a.windows.iter().zip(&b.windows) {
        assert_eq!(wa.dispatched, wb.dispatched, "window {} drifted", wa.window);
        assert_eq!(wa.completions, wb.completions);
        assert_eq!(wa.folds, wb.folds);
        assert_eq!(wa.crashes, wb.crashes);
        assert_eq!(wa.expired, wb.expired);
        assert_eq!(wa.in_flight_peak, wb.in_flight_peak);
    }
    assert!(
        wall < Duration::from_secs(120),
        "continuous 2560-invocation experiment took {wall:?}"
    );
}
