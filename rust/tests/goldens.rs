//! Behaviour-plane selection goldens: end-to-end tier assignment and
//! cohort selection for paper-scale preset shapes, pinned to literal
//! selected-client sets (same RNG seed ⇒ bit-identical selections).
//!
//! The pinned values were computed from a bit-exact mirror of the
//! **pre-refactor** selection path (unbounded per-client history
//! vectors, O(n²) DBSCAN neighbourhood scans) and verified equal under
//! the refactored path (bounded history summaries, grid-indexed
//! DBSCAN, cohort sampling) before pinning — so this suite certifies
//! that the fleet-scale rewrite is behaviour-preserving for the
//! paper-scale path, not merely self-consistent. The generator is
//! committed at `python/mirror/gen_goldens.py` (regeneration recipe in
//! `python/mirror/README.md`).
//!
//! The drive script is deliberately RNG-free in its *outcomes* (client
//! c fails round r iff (7c + r) % 5 == 0; training time is a fixed
//! function of (c, r); half of a round's failures are corrected by a
//! late completion one round later), so the only randomness is the
//! strategy's own sampling stream — exactly what the goldens pin.

use fedless::clientdb::HistoryStore;
use fedless::strategy::{tier_partition, FedLesScan, SelectionContext, Strategy};
use fedless::util::Rng;
use fedless::ClientId;

struct Drive {
    selections: Vec<Vec<ClientId>>,
    rookies: Vec<ClientId>,
    participants: Vec<ClientId>,
    stragglers: Vec<ClientId>,
}

/// Scripted multi-round drive of FedLesScan selection + Algorithm 1
/// history updates (success / failure / late-completion / cooldown
/// tick), mirroring the golden generator exactly.
fn drive(n: usize, k: usize, max_rounds: u32, rounds: u32, seed: u64) -> Drive {
    let mut hist = HistoryStore::new();
    let mut rng = Rng::seed_from_u64(seed);
    let clients: Vec<ClientId> = (0..n).collect();
    let mut strat = FedLesScan::default();
    let mut selections = Vec::new();
    let mut prev_failed: Vec<ClientId> = Vec::new();
    for r in 0..rounds {
        let sel = {
            let ctx = SelectionContext {
                round: r,
                max_rounds,
                clients_per_round: k,
                all_clients: &clients,
                history: &hist,
            };
            strat.select(&ctx, &mut rng)
        };
        // late completions: half of last round's failures correct
        // themselves (the slow-not-crashed clients of §V-B)
        for &c in &prev_failed {
            if (c + r as usize) % 2 == 0 {
                hist.record_late_completion(c, r - 1, 60.0 + c as f64);
            }
        }
        let mut failed = Vec::new();
        for &c in &sel {
            hist.record_invocation(c);
            if (c * 7 + r as usize) % 5 == 0 {
                hist.record_failure(c, r);
                failed.push(c);
            } else {
                let t = 5.0 + ((c * 13 + r as usize * 3) % 40) as f64 * 1.5;
                hist.record_success(c, r, t);
            }
        }
        hist.tick_cooldowns(&failed);
        prev_failed = failed;
        selections.push(sel);
    }
    let ctx = SelectionContext {
        round: rounds,
        max_rounds,
        clients_per_round: k,
        all_clients: &clients,
        history: &hist,
    };
    let (rookies, participants, stragglers) = tier_partition(&ctx);
    Drive {
        selections,
        rookies,
        participants,
        stragglers,
    }
}

fn assert_drive(
    label: &str,
    d: &Drive,
    selections: &[&[ClientId]],
    rookies: &[ClientId],
    participants: &[ClientId],
    stragglers: &[ClientId],
) {
    assert_eq!(
        d.selections.len(),
        selections.len(),
        "{label}: round count"
    );
    for (r, (got, want)) in d.selections.iter().zip(selections).enumerate() {
        assert_eq!(got, want, "{label}: selection drifted in round {r}");
    }
    assert_eq!(d.rookies, rookies, "{label}: rookie tier drifted");
    assert_eq!(d.participants, participants, "{label}: participant tier drifted");
    assert_eq!(d.stragglers, stragglers, "{label}: straggler tier drifted");
}

// mnist_shape: n=60 k=12 max_rounds=20 seed=42
const MNIST_SHAPE_SELECTIONS: &[&[ClientId]] = &[
    &[35, 47, 44, 8, 40, 0, 4, 46, 2, 59, 9, 19],
    &[34, 24, 41, 20, 7, 48, 39, 1, 49, 18, 13, 57],
    &[17, 22, 33, 21, 29, 25, 12, 6, 43, 27, 53, 16],
    &[54, 45, 31, 58, 23, 30, 5, 15, 51, 36, 56, 11],
    &[10, 14, 55, 28, 50, 52, 38, 26, 42, 3, 32, 37],
    &[1, 4, 6, 12, 13, 15, 16, 19, 22, 25, 34, 37],
    &[0, 2, 3, 5, 7, 8, 9, 10, 14, 17, 18, 20],
    &[11, 28, 31, 38, 51, 15, 25, 29, 35, 36, 56, 21],
    &[11, 51, 2, 28, 31, 38, 15, 25, 23, 24, 26, 27],
    &[2, 28, 38, 15, 25, 29, 30, 32, 33, 39, 40, 41],
];
const MNIST_SHAPE_ROOKIES: &[ClientId] = &[];
const MNIST_SHAPE_PARTICIPANTS: &[ClientId] = &[
    0, 1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13, 14, 15, 16, 17, 18, 19, 20, 21, 22, 23, 24,
    25, 26, 27, 29, 30, 31, 32, 34, 35, 36, 37, 39, 40, 41, 42, 43, 44, 45, 46, 47, 48, 49, 50,
    51, 52, 53, 54, 55, 56, 57, 58, 59,
];
const MNIST_SHAPE_STRAGGLERS: &[ClientId] = &[28, 33, 38];

// femnist_shape: n=50 k=10 max_rounds=15 seed=1337
const FEMNIST_SHAPE_SELECTIONS: &[&[ClientId]] = &[
    &[18, 1, 16, 32, 24, 47, 20, 28, 27, 5],
    &[4, 41, 11, 13, 9, 2, 37, 44, 19, 29],
    &[31, 17, 43, 14, 25, 22, 21, 12, 48, 0],
    &[15, 30, 45, 40, 3, 46, 39, 10, 34, 42],
    &[35, 7, 6, 49, 33, 36, 26, 8, 38, 23],
    &[0, 1, 2, 3, 4, 5, 6, 7, 9, 10],
    &[11, 12, 13, 15, 16, 17, 18, 19, 20, 21],
    &[8, 38, 23, 33, 46, 5, 14, 22, 24, 25],
];
const FEMNIST_SHAPE_ROOKIES: &[ClientId] = &[];
const FEMNIST_SHAPE_PARTICIPANTS: &[ClientId] = &[
    0, 1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13, 15, 16, 17, 18, 19, 20, 21, 22, 23, 25, 26,
    27, 28, 29, 30, 31, 32, 33, 34, 35, 36, 37, 38, 39, 40, 41, 42, 43, 44, 45, 46, 47, 48, 49,
];
const FEMNIST_SHAPE_STRAGGLERS: &[ClientId] = &[14, 24];

// speech_shape: n=60 k=15 max_rounds=20 seed=7
const SPEECH_SHAPE_SELECTIONS: &[&[ClientId]] = &[
    &[31, 37, 33, 30, 18, 58, 43, 29, 12, 39, 50, 9, 13, 22, 0],
    &[24, 16, 4, 6, 17, 23, 38, 32, 44, 40, 47, 3, 52, 26, 54],
    &[20, 59, 34, 57, 10, 49, 28, 21, 27, 2, 7, 25, 55, 46, 42],
    &[19, 36, 48, 41, 53, 51, 14, 35, 8, 5, 45, 11, 15, 56, 1],
    &[17, 47, 0, 2, 3, 4, 5, 6, 7, 8, 9, 10, 12, 13, 14],
    &[15, 16, 18, 19, 20, 21, 22, 23, 24, 25, 26, 27, 28, 29, 30],
    &[1, 11, 34, 41, 51, 32, 36, 49, 52, 56, 59, 8, 31, 33, 35],
    &[11, 51, 15, 17, 25, 34, 47, 8, 30, 56, 59, 1, 41, 37, 38],
    &[11, 15, 25, 51, 8, 32, 52, 39, 40, 42, 43, 44, 45, 46, 48],
    &[32, 15, 25, 8, 52, 59, 50, 53, 54, 55, 57, 58, 0, 2, 3],
];
const SPEECH_SHAPE_ROOKIES: &[ClientId] = &[];
const SPEECH_SHAPE_PARTICIPANTS: &[ClientId] = &[
    0, 1, 2, 4, 5, 6, 7, 9, 10, 11, 12, 13, 14, 15, 16, 17, 18, 19, 20, 21, 22, 23, 24, 25, 26,
    27, 28, 29, 30, 31, 32, 33, 34, 35, 36, 37, 38, 39, 40, 41, 42, 43, 44, 45, 46, 47, 48, 49,
    50, 51, 52, 54, 55, 56, 57, 59,
];
const SPEECH_SHAPE_STRAGGLERS: &[ClientId] = &[3, 8, 53, 58];

#[test]
fn mnist_shape_selection_golden() {
    let d = drive(60, 12, 20, 10, 42);
    assert_drive(
        "mnist_shape",
        &d,
        MNIST_SHAPE_SELECTIONS,
        MNIST_SHAPE_ROOKIES,
        MNIST_SHAPE_PARTICIPANTS,
        MNIST_SHAPE_STRAGGLERS,
    );
}

#[test]
fn femnist_shape_selection_golden() {
    let d = drive(50, 10, 15, 8, 1337);
    assert_drive(
        "femnist_shape",
        &d,
        FEMNIST_SHAPE_SELECTIONS,
        FEMNIST_SHAPE_ROOKIES,
        FEMNIST_SHAPE_PARTICIPANTS,
        FEMNIST_SHAPE_STRAGGLERS,
    );
}

#[test]
fn speech_shape_selection_golden() {
    let d = drive(60, 15, 20, 10, 7);
    assert_drive(
        "speech_shape",
        &d,
        SPEECH_SHAPE_SELECTIONS,
        SPEECH_SHAPE_ROOKIES,
        SPEECH_SHAPE_PARTICIPANTS,
        SPEECH_SHAPE_STRAGGLERS,
    );
}

#[test]
fn drive_is_replayable() {
    // The golden harness itself must be a pure function of its seed.
    let a = drive(60, 12, 20, 10, 42);
    let b = drive(60, 12, 20, 10, 42);
    assert_eq!(a.selections, b.selections);
    assert_eq!(a.stragglers, b.stragglers);
}
