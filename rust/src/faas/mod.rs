//! Simulated 2nd-generation Google Cloud Functions platform (substrate).
//!
//! The paper's straggler phenomenology (§II, §III-C) comes from four FaaS
//! properties, all modelled here with a seeded RNG over a **virtual
//! clock** (deterministic, repeatable experiments):
//!
//! * **cold starts** — first invocation, or invocation after the warm
//!   instance was scaled to zero, pays a log-normal startup latency
//!   (published GCF measurements for TF-sized client containers sit in
//!   the ~2-10 s band);
//! * **performance variation** — each client function lands on an
//!   arbitrary provisioned VM ([29]): a static per-client speed factor
//!   plus per-invocation log-normal jitter multiply the compute time;
//! * **transient failures** — GCF's 99.95% SLO means requests get dropped
//!   (§III-C); a Bernoulli failure makes the invocation crash;
//! * **scale-to-zero** — warm instances idle out after
//!   `idle_timeout_s`, re-exposing cold starts mid-experiment.
//!
//! The *actual* training compute happens in the PJRT runtime; the
//! simulator turns a nominal compute time into a virtual invocation
//! timeline (start, finish, billed duration) and a success/crash/slow
//! outcome relative to the round deadline. Straggler-scenario forcing
//! (§VI-A4) is layered on top by the coordinator via [`Forced`].

use std::collections::HashMap;

use crate::util::Rng;
use crate::ClientId;

/// Platform model parameters.
#[derive(Debug, Clone, Copy)]
pub struct FaasConfig {
    /// Median cold-start latency (s).
    pub cold_start_median_s: f64,
    /// Log-normal sigma of the cold-start latency.
    pub cold_start_sigma: f64,
    /// Fixed invocation overhead for warm instances (s).
    pub warm_overhead_s: f64,
    /// Scale-to-zero idle timeout (s).
    pub idle_timeout_s: f64,
    /// Sigma of the static per-client VM speed factor (log-normal, median 1).
    pub client_speed_sigma: f64,
    /// Sigma of the per-invocation jitter (log-normal, median 1).
    pub invocation_jitter_sigma: f64,
    /// Probability an invocation is dropped/crashed by the platform.
    pub transient_failure_rate: f64,
    /// Function memory limit (MB) — drives the cost model tier.
    pub memory_mb: u32,
    /// Model download/upload bandwidth (MB/s) between function and the
    /// parameter store (nginx/DB in the paper's deployment).
    pub network_mbps: f64,
    /// Hard function timeout (s) — 540 s for the paper's clients.
    pub function_timeout_s: f64,
}

impl Default for FaasConfig {
    fn default() -> Self {
        Self {
            cold_start_median_s: 4.0,
            cold_start_sigma: 0.5,
            warm_overhead_s: 0.15,
            idle_timeout_s: 300.0,
            client_speed_sigma: 0.25,
            invocation_jitter_sigma: 0.10,
            transient_failure_rate: 0.02,
            memory_mb: 2048,
            network_mbps: 40.0,
            function_timeout_s: 540.0,
        }
    }
}

/// Behaviour forced by the straggler-% scenario (§VI-A4).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Forced {
    /// Client completes but its update lands after the round deadline.
    Slow,
    /// Client crashes at round start (still billed the round, §VI-C).
    Crash,
}

/// How an invocation ended, relative to the round deadline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Outcome {
    /// Finished before the deadline: update aggregated this round.
    OnTime,
    /// Finished after the deadline but before the function timeout: the
    /// update arrives late (staleness buffer candidate).
    Late,
    /// Crashed (platform drop, forced crash, or function timeout).
    Crash,
}

/// Simulated invocation record (virtual-clock seconds).
#[derive(Debug, Clone, Copy)]
pub struct Invocation {
    pub client: ClientId,
    pub started_at: f64,
    /// Virtual completion time (crash => time the instance died).
    pub finished_at: f64,
    /// Seconds billed by the provider for this invocation.
    pub billed_s: f64,
    /// Pure local-training duration the *client* would report (§V-B) —
    /// excludes the platform cold start, includes model transfer.
    pub training_time_s: f64,
    pub cold: bool,
    pub outcome: Outcome,
}

struct WarmInstance {
    last_used_at: f64,
}

/// The simulated platform. One instance pool per experiment.
pub struct SimulatedGcf {
    pub cfg: FaasConfig,
    rng: Rng,
    warm: HashMap<ClientId, WarmInstance>,
    speed: HashMap<ClientId, f64>,
}

impl SimulatedGcf {
    pub fn new(cfg: FaasConfig, seed: u64) -> Self {
        Self {
            cfg,
            rng: Rng::seed_from_u64(seed ^ 0xfaa5_0001),
            warm: HashMap::new(),
            speed: HashMap::new(),
        }
    }

    /// Static per-client VM speed factor (median 1.0, log-normal).
    pub fn client_speed(&mut self, client: ClientId) -> f64 {
        let sigma = self.cfg.client_speed_sigma.max(1e-9);
        let rng = &mut self.rng;
        *self
            .speed
            .entry(client)
            .or_insert_with(|| rng.lognormal(0.0, sigma))
    }

    /// Model payload transfer time (download global + upload update).
    fn transfer_s(&self, payload_mb: f64) -> f64 {
        2.0 * payload_mb / self.cfg.network_mbps.max(1e-9)
    }

    /// Simulate one invocation issued at virtual time `now_s`.
    ///
    /// `compute_s` is the nominal local-training compute time (derived
    /// from the real PJRT execution), `payload_mb` the model transfer
    /// size, `deadline_s` the round deadline (absolute virtual time), and
    /// `forced` the scenario override.
    pub fn invoke(
        &mut self,
        client: ClientId,
        now_s: f64,
        compute_s: f64,
        payload_mb: f64,
        deadline_s: f64,
        forced: Option<Forced>,
    ) -> Invocation {
        // cold or warm?
        let cold = match self.warm.get(&client) {
            Some(w) => now_s - w.last_used_at > self.cfg.idle_timeout_s,
            None => true,
        };
        let startup = if cold {
            self.rng
                .lognormal(self.cfg.cold_start_median_s.ln(), self.cfg.cold_start_sigma.max(1e-9))
        } else {
            self.cfg.warm_overhead_s
        };

        if forced == Some(Forced::Crash)
            || self.rng.bernoulli(self.cfg.transient_failure_rate)
        {
            // §VI-C worst case: a crashed straggler is billed for the
            // whole round.
            let end = deadline_s.max(now_s);
            self.warm.remove(&client);
            return Invocation {
                client,
                started_at: now_s,
                finished_at: end,
                billed_s: end - now_s,
                training_time_s: 0.0,
                cold,
                outcome: Outcome::Crash,
            };
        }

        let speed = self.client_speed(client);
        let jitter = self
            .rng
            .lognormal(0.0, self.cfg.invocation_jitter_sigma.max(1e-9));
        let mut train_s = compute_s * speed * jitter + self.transfer_s(payload_mb);
        if forced == Some(Forced::Slow) {
            // Scenario forcing (§VI-A4): delays (cold start, bandwidth,
            // ...) push completion past the round deadline.
            let past_deadline = (deadline_s - now_s - startup).max(0.0) * 1.25 + 1.0;
            train_s = train_s.max(past_deadline);
        }
        let total = startup + train_s;

        if total > self.cfg.function_timeout_s {
            // platform kills the function at its hard timeout
            let end = now_s + self.cfg.function_timeout_s;
            self.warm.remove(&client);
            return Invocation {
                client,
                started_at: now_s,
                finished_at: end,
                billed_s: self.cfg.function_timeout_s,
                training_time_s: 0.0,
                cold,
                outcome: Outcome::Crash,
            };
        }

        let finished_at = now_s + total;
        self.warm
            .insert(client, WarmInstance { last_used_at: finished_at });
        Invocation {
            client,
            started_at: now_s,
            finished_at,
            billed_s: total,
            training_time_s: train_s,
            cold,
            outcome: if finished_at <= deadline_s {
                Outcome::OnTime
            } else {
                Outcome::Late
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg_no_noise() -> FaasConfig {
        FaasConfig {
            transient_failure_rate: 0.0,
            client_speed_sigma: 1e-9,
            invocation_jitter_sigma: 1e-9,
            cold_start_sigma: 1e-9,
            ..FaasConfig::default()
        }
    }

    #[test]
    fn first_invocation_is_cold_then_warm() {
        let mut gcf = SimulatedGcf::new(cfg_no_noise(), 1);
        let a = gcf.invoke(0, 0.0, 10.0, 1.0, 1e9, None);
        assert!(a.cold);
        let b = gcf.invoke(0, a.finished_at + 1.0, 10.0, 1.0, 1e9, None);
        assert!(!b.cold);
        // warm start is much cheaper
        assert!(b.billed_s < a.billed_s);
    }

    #[test]
    fn scale_to_zero_reexposes_cold_start() {
        let mut gcf = SimulatedGcf::new(cfg_no_noise(), 1);
        let a = gcf.invoke(0, 0.0, 5.0, 1.0, 1e9, None);
        let b = gcf.invoke(0, a.finished_at + 1000.0, 5.0, 1.0, 1e9, None);
        assert!(b.cold, "idle timeout must re-cold the instance");
    }

    #[test]
    fn forced_crash_bills_round() {
        let mut gcf = SimulatedGcf::new(cfg_no_noise(), 2);
        let inv = gcf.invoke(3, 100.0, 5.0, 1.0, 160.0, Some(Forced::Crash));
        assert_eq!(inv.outcome, Outcome::Crash);
        assert!((inv.billed_s - 60.0).abs() < 1e-9);
    }

    #[test]
    fn forced_slow_finishes_after_deadline() {
        let mut gcf = SimulatedGcf::new(cfg_no_noise(), 3);
        let inv = gcf.invoke(4, 0.0, 1.0, 1.0, 30.0, Some(Forced::Slow));
        assert_eq!(inv.outcome, Outcome::Late);
        assert!(inv.finished_at > 30.0);
        assert!(inv.finished_at < 540.0, "slow must not hit the hard timeout");
    }

    #[test]
    fn fast_client_is_on_time() {
        let mut gcf = SimulatedGcf::new(cfg_no_noise(), 4);
        let inv = gcf.invoke(5, 0.0, 5.0, 1.0, 60.0, None);
        assert_eq!(inv.outcome, Outcome::OnTime);
        assert!(inv.training_time_s > 5.0); // includes transfer
    }

    #[test]
    fn function_timeout_crashes() {
        let mut gcf = SimulatedGcf::new(cfg_no_noise(), 5);
        let inv = gcf.invoke(6, 0.0, 10_000.0, 1.0, 1e9, None);
        assert_eq!(inv.outcome, Outcome::Crash);
        assert!((inv.billed_s - 540.0).abs() < 1e-9);
    }

    #[test]
    fn client_speed_is_stable_per_client() {
        let mut gcf = SimulatedGcf::new(FaasConfig::default(), 6);
        let s1 = gcf.client_speed(1);
        let s2 = gcf.client_speed(1);
        assert_eq!(s1, s2);
    }

    #[test]
    fn deterministic_given_seed() {
        let run = || {
            let mut gcf = SimulatedGcf::new(FaasConfig::default(), 42);
            (0..20)
                .map(|c| gcf.invoke(c, 0.0, 10.0, 1.0, 60.0, None).finished_at)
                .collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn transient_failures_occur_at_configured_rate() {
        let cfg = FaasConfig {
            transient_failure_rate: 0.3,
            ..cfg_no_noise()
        };
        let mut gcf = SimulatedGcf::new(cfg, 7);
        let crashes = (0..1000)
            .filter(|&c| {
                gcf.invoke(c, 0.0, 1.0, 0.1, 1e9, None).outcome == Outcome::Crash
            })
            .count();
        assert!((200..400).contains(&crashes), "crashes={crashes}");
    }
}
