//! Simulated 2nd-generation Google Cloud Functions platform (substrate).
//!
//! The paper's straggler phenomenology (§II, §III-C) comes from four FaaS
//! properties, all modelled here with a seeded RNG over a **virtual
//! clock** (deterministic, repeatable experiments):
//!
//! * **cold starts** — first invocation, or invocation after the warm
//!   instance was scaled to zero, pays a log-normal startup latency
//!   (published GCF measurements for TF-sized client containers sit in
//!   the ~2-10 s band);
//! * **performance variation** — each client function lands on an
//!   arbitrary provisioned VM ([29]): a static per-client speed factor
//!   plus per-invocation log-normal jitter multiply the compute time;
//! * **transient failures** — GCF's 99.95% SLO means requests get dropped
//!   (§III-C); a Bernoulli failure makes the invocation crash;
//! * **scale-to-zero** — warm instances idle out after
//!   `idle_timeout_s`, re-exposing cold starts mid-experiment.
//!
//! The *actual* training compute happens in the PJRT runtime; the
//! simulator turns a nominal compute time into a virtual invocation
//! timeline (start, finish, billed duration) and a success/crash/slow
//! outcome relative to the round deadline. Straggler-scenario forcing
//! (§VI-A4) is layered on top by the coordinator via [`Forced`].
//!
//! The adversarial grid scenarios ([`Scenario`]) are materialized here
//! too, as **deterministic** window/identity functions of the virtual
//! clock and the client id — cold-start storms, a diurnal load wave,
//! rotating regional outages, and a persistent slow tail. None of them
//! adds or removes RNG draws relative to the same decision path under
//! `Standard`, so seeded streams for the old scenarios stay
//! byte-identical (see the draw-order contract on [`Decision`]).

use std::collections::HashMap;

use crate::config::Scenario;
use crate::util::Rng;
use crate::ClientId;

/// Cold-start storm ([`Scenario::ColdStartStorm`]): every
/// [`STORM_DUTY_S`] out of each [`STORM_PERIOD_S`] the provider is
/// recycling instances (deploy wave) and the warm pool is useless.
pub const STORM_PERIOD_S: f64 = 600.0;
pub const STORM_DUTY_S: f64 = 120.0;

/// Diurnal wave ([`Scenario::Diurnal`]): platform latency multiplier
/// `1 + DIURNAL_AMP * sin(2π t / DIURNAL_PERIOD_S)` — peak traffic
/// stretches startup and compute 1.6x, the trough relaxes to 0.4x.
pub const DIURNAL_PERIOD_S: f64 = 2400.0;
pub const DIURNAL_AMP: f64 = 0.6;

/// Regional outages ([`Scenario::RegionalOutage`]): clients hash into
/// [`OUTAGE_REGIONS`] regions by id; during the first [`OUTAGE_DUTY_S`]
/// of each [`OUTAGE_PERIOD_S`] cycle, the cycle's region (rotating
/// round-robin) drops every invocation.
pub const OUTAGE_REGIONS: usize = 4;
pub const OUTAGE_PERIOD_S: f64 = 900.0;
pub const OUTAGE_DUTY_S: f64 = 180.0;

/// Adversarial tail ([`Scenario::Adversarial`]): one client in
/// [`ADVERSARIAL_DECILE`] (stable id hash) trains
/// [`ADVERSARIAL_SLOWDOWN`]x slower, forever.
pub const ADVERSARIAL_DECILE: u64 = 10;
pub const ADVERSARIAL_SLOWDOWN: f64 = 4.0;

/// Is virtual time `now_s` inside a cold-start storm window?
pub fn in_storm(now_s: f64) -> bool {
    now_s.rem_euclid(STORM_PERIOD_S) < STORM_DUTY_S
}

/// The region currently down at `now_s`, if any outage window is open.
pub fn outage_region(now_s: f64) -> Option<usize> {
    let cycle = (now_s / OUTAGE_PERIOD_S).floor();
    if now_s - cycle * OUTAGE_PERIOD_S < OUTAGE_DUTY_S {
        Some(cycle as usize % OUTAGE_REGIONS)
    } else {
        None
    }
}

/// Stable membership test for the adversarially slow tail: a splitmix64
/// hash of the client id, so the set is deterministic, seed-independent
/// and uniformly spread (~1 client in [`ADVERSARIAL_DECILE`]).
pub fn is_adversarial(client: ClientId) -> bool {
    let mut z = (client as u64).wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^= z >> 31;
    z % ADVERSARIAL_DECILE == 0
}

/// Platform model parameters.
#[derive(Debug, Clone, Copy)]
pub struct FaasConfig {
    /// Median cold-start latency (s).
    pub cold_start_median_s: f64,
    /// Log-normal sigma of the cold-start latency.
    pub cold_start_sigma: f64,
    /// Fixed invocation overhead for warm instances (s).
    pub warm_overhead_s: f64,
    /// Scale-to-zero idle timeout (s).
    pub idle_timeout_s: f64,
    /// Sigma of the static per-client VM speed factor (log-normal, median 1).
    pub client_speed_sigma: f64,
    /// Sigma of the per-invocation jitter (log-normal, median 1).
    pub invocation_jitter_sigma: f64,
    /// Probability an invocation is dropped/crashed by the platform.
    pub transient_failure_rate: f64,
    /// Function memory limit (MB) — drives the cost model tier.
    pub memory_mb: u32,
    /// Model download/upload bandwidth (MB/s) between function and the
    /// parameter store (nginx/DB in the paper's deployment).
    pub network_mbps: f64,
    /// Hard function timeout (s) — 540 s for the paper's clients.
    pub function_timeout_s: f64,
}

impl Default for FaasConfig {
    fn default() -> Self {
        Self {
            cold_start_median_s: 4.0,
            cold_start_sigma: 0.5,
            warm_overhead_s: 0.15,
            idle_timeout_s: 300.0,
            client_speed_sigma: 0.25,
            invocation_jitter_sigma: 0.10,
            transient_failure_rate: 0.02,
            memory_mb: 2048,
            network_mbps: 40.0,
            function_timeout_s: 540.0,
        }
    }
}

/// Behaviour forced by the straggler-% scenario (§VI-A4).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Forced {
    /// Client completes but its update lands after the round deadline.
    Slow,
    /// Client crashes at round start (still billed the round, §VI-C).
    Crash,
}

/// How an invocation ended, relative to the round deadline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Outcome {
    /// Finished before the deadline: update aggregated this round.
    OnTime,
    /// Finished after the deadline but before the function timeout: the
    /// update arrives late (staleness buffer candidate).
    Late,
    /// Crashed (platform drop, forced crash, or function timeout).
    Crash,
}

/// Simulated invocation record (virtual-clock seconds).
#[derive(Debug, Clone, Copy)]
pub struct Invocation {
    pub client: ClientId,
    pub started_at: f64,
    /// Virtual completion time (crash => time the instance died).
    pub finished_at: f64,
    /// Seconds billed by the provider for this invocation.
    pub billed_s: f64,
    /// Pure local-training duration the *client* would report (§V-B) —
    /// excludes the platform cold start, includes model transfer.
    pub training_time_s: f64,
    pub cold: bool,
    pub outcome: Outcome,
}

struct WarmInstance {
    last_used_at: f64,
}

/// Seed-mix for the platform RNG stream (public so tests can mirror the
/// stream draw-for-draw; see `rng_stream_contract`).
pub const FAAS_SEED_MIX: u64 = 0xfaa5_0001;

/// Platform-side decision for one invocation. **Every RNG draw happens
/// here**, in the documented order; timeline materialization below is
/// pure arithmetic. The per-invocation draw order is a compatibility
/// contract (seeded goldens depend on it):
///
/// 1. one log-normal **startup** draw — only when the instance is cold
///    (a `ColdStartStorm` window forces this branch: the instance is
///    treated as cold regardless of the warm pool, so the startup draw
///    *is* consumed — deterministic windows, no extra draws);
/// 2. one Bernoulli **transient-crash** draw — skipped entirely when
///    the scenario already forces a crash, either via [`Forced::Crash`]
///    or a `RegionalOutage` window covering this client (both sit left
///    of the `||` short-circuit);
/// 3. one log-normal **VM speed** draw — skipped if step 2 crashed;
///    otherwise drawn on the client's first such invocation and cached;
/// 4. one log-normal **jitter** draw — skipped if step 2 crashed.
///
/// `Diurnal` and `Adversarial` touch no draws at all: they are pure
/// multipliers applied during timeline materialization.
///
/// Note the asymmetry between the two crash kinds: a forced/transient
/// crash kills the function *before* it does any work, so steps 3-4 are
/// never drawn; a hard-timeout kill (decided later, in materialization)
/// happens *after* the work was attempted, so its invocation consumed
/// both draws (and cached the client speed) even though its outcome is
/// also `Crash`.
struct Decision {
    cold: bool,
    startup: f64,
    /// `None` when the invocation crashed before doing any work
    /// (forced/transient); the speed/jitter draws were *not* consumed.
    /// A later hard-timeout kill still carries `Some` here.
    perf: Option<(f64, f64)>,
}

/// The simulated platform. One instance pool per experiment.
pub struct SimulatedGcf {
    pub cfg: FaasConfig,
    /// Platform-stress scenario materialized by this instance
    /// (`Standard` and `Straggler(_)` leave the platform untouched —
    /// straggler forcing arrives per-invocation via [`Forced`]).
    pub scenario: Scenario,
    rng: Rng,
    warm: HashMap<ClientId, WarmInstance>,
    speed: HashMap<ClientId, f64>,
}

impl SimulatedGcf {
    pub fn new(cfg: FaasConfig, seed: u64) -> Self {
        Self::with_scenario(cfg, seed, Scenario::Standard)
    }

    /// A platform materializing the given scenario's stress effects.
    /// `Standard`/`Straggler(_)` behave exactly like [`Self::new`].
    pub fn with_scenario(cfg: FaasConfig, seed: u64, scenario: Scenario) -> Self {
        Self {
            cfg,
            scenario,
            rng: Rng::seed_from_u64(seed ^ FAAS_SEED_MIX),
            warm: HashMap::new(),
            speed: HashMap::new(),
        }
    }

    /// Diurnal latency multiplier at `now_s` (1.0 outside the scenario).
    fn load_factor(&self, now_s: f64) -> f64 {
        if self.scenario == Scenario::Diurnal {
            1.0 + DIURNAL_AMP * (2.0 * std::f64::consts::PI * now_s / DIURNAL_PERIOD_S).sin()
        } else {
            1.0
        }
    }

    /// Does an outage window drop this client's invocation at `now_s`?
    fn outage_drops(&self, client: ClientId, now_s: f64) -> bool {
        self.scenario == Scenario::RegionalOutage
            && outage_region(now_s) == Some(client % OUTAGE_REGIONS)
    }

    /// Static per-client VM speed factor (median 1.0, log-normal).
    pub fn client_speed(&mut self, client: ClientId) -> f64 {
        let sigma = self.cfg.client_speed_sigma.max(1e-9);
        let rng = &mut self.rng;
        *self
            .speed
            .entry(client)
            .or_insert_with(|| rng.lognormal(0.0, sigma))
    }

    /// Model payload transfer time (download global + upload update).
    fn transfer_s(&self, payload_mb: f64) -> f64 {
        2.0 * payload_mb / self.cfg.network_mbps.max(1e-9)
    }

    /// Phase 1 — platform outcome decision: consume the RNG stream in
    /// the contract order documented on [`Decision`] and decide whether
    /// the invocation crashes before doing any work.
    fn decide(&mut self, client: ClientId, now_s: f64, forced: Option<Forced>) -> Decision {
        // cold or warm? A *negative* idle gap means the previously
        // recorded instance is still running at `now_s` (a late client
        // re-invoked mid-flight): the platform then fans out a second,
        // cold instance rather than reusing the busy one — without the
        // clamp the instance looked spuriously warm.
        // A cold-start storm window overrides the pool entirely: the
        // provider is recycling instances, so everything cold-starts.
        let cold = (self.scenario == Scenario::ColdStartStorm && in_storm(now_s))
            || match self.warm.get(&client) {
                Some(w) => !(0.0..=self.cfg.idle_timeout_s).contains(&(now_s - w.last_used_at)),
                None => true,
            };
        let startup = if cold {
            self.rng
                .lognormal(self.cfg.cold_start_median_s.ln(), self.cfg.cold_start_sigma.max(1e-9))
        } else {
            self.cfg.warm_overhead_s
        };
        // Outage drops sit left of the bernoulli like a forced crash:
        // both kill the request before any work, consuming no further
        // draws (contract step 2).
        let crashed = forced == Some(Forced::Crash)
            || self.outage_drops(client, now_s)
            || self.rng.bernoulli(self.cfg.transient_failure_rate);
        let perf = if crashed {
            None
        } else {
            let speed = self.client_speed(client);
            let jitter = self
                .rng
                .lognormal(0.0, self.cfg.invocation_jitter_sigma.max(1e-9));
            Some((speed, jitter))
        };
        Decision {
            cold,
            startup,
            perf,
        }
    }

    /// Phase 2 — pure timeline materialization: no RNG, just the warm
    /// pool bookkeeping and the virtual start/finish/billing arithmetic.
    #[allow(clippy::too_many_arguments)]
    fn materialize(
        &mut self,
        d: Decision,
        client: ClientId,
        now_s: f64,
        compute_s: f64,
        payload_mb: f64,
        deadline_s: f64,
        forced: Option<Forced>,
    ) -> Invocation {
        let (speed, jitter) = match d.perf {
            None => {
                // §VI-C worst case: a crashed straggler is billed for the
                // whole round.
                let end = deadline_s.max(now_s);
                self.warm.remove(&client);
                return Invocation {
                    client,
                    started_at: now_s,
                    finished_at: end,
                    billed_s: end - now_s,
                    training_time_s: 0.0,
                    cold: d.cold,
                    outcome: Outcome::Crash,
                };
            }
            Some(p) => p,
        };

        // Platform-stress multipliers (pure arithmetic, no draws): the
        // diurnal wave stretches startup + compute with load, and the
        // adversarial tail always trains slower. Both are exactly 1x
        // outside their scenarios, so old-scenario timelines are
        // bit-identical.
        let load = self.load_factor(now_s);
        let startup = d.startup * load;
        let mut compute = compute_s * speed * jitter * load;
        if self.scenario == Scenario::Adversarial && is_adversarial(client) {
            compute *= ADVERSARIAL_SLOWDOWN;
        }
        let mut train_s = compute + self.transfer_s(payload_mb);
        if forced == Some(Forced::Slow) {
            // Scenario forcing (§VI-A4): delays (cold start, bandwidth,
            // ...) push completion past the round deadline.
            let past_deadline = (deadline_s - now_s - startup).max(0.0) * 1.25 + 1.0;
            train_s = train_s.max(past_deadline);
        }
        let total = startup + train_s;

        if total > self.cfg.function_timeout_s {
            // platform kills the function at its hard timeout
            let end = now_s + self.cfg.function_timeout_s;
            self.warm.remove(&client);
            return Invocation {
                client,
                started_at: now_s,
                finished_at: end,
                billed_s: self.cfg.function_timeout_s,
                training_time_s: 0.0,
                cold: d.cold,
                outcome: Outcome::Crash,
            };
        }

        let finished_at = now_s + total;
        // Monotonic warm timestamp: never move the pool's "last alive"
        // time backwards — a still-running (in-flight) instance keeps the
        // pool warm past a shorter overlapping invocation.
        let last_used_at = self
            .warm
            .get(&client)
            .map_or(finished_at, |w| w.last_used_at.max(finished_at));
        self.warm.insert(client, WarmInstance { last_used_at });
        Invocation {
            client,
            started_at: now_s,
            finished_at,
            billed_s: total,
            training_time_s: train_s,
            cold: d.cold,
            outcome: if finished_at <= deadline_s {
                Outcome::OnTime
            } else {
                Outcome::Late
            },
        }
    }

    /// Simulate one invocation issued at virtual time `now_s`: the
    /// outcome decision ([`Decision`], all RNG) followed by the pure
    /// timeline materialization.
    ///
    /// `compute_s` is the nominal local-training compute time,
    /// `payload_mb` the model transfer size, `deadline_s` the round
    /// deadline (absolute virtual time), and `forced` the scenario
    /// override. The full timeline — including the crash/late/on-time
    /// outcome — is decided *before* any real training runs, so the
    /// scheduler can skip compute for doomed invocations.
    pub fn invoke(
        &mut self,
        client: ClientId,
        now_s: f64,
        compute_s: f64,
        payload_mb: f64,
        deadline_s: f64,
        forced: Option<Forced>,
    ) -> Invocation {
        let d = self.decide(client, now_s, forced);
        self.materialize(d, client, now_s, compute_s, payload_mb, deadline_s, forced)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg_no_noise() -> FaasConfig {
        FaasConfig {
            transient_failure_rate: 0.0,
            client_speed_sigma: 1e-9,
            invocation_jitter_sigma: 1e-9,
            cold_start_sigma: 1e-9,
            ..FaasConfig::default()
        }
    }

    #[test]
    fn first_invocation_is_cold_then_warm() {
        let mut gcf = SimulatedGcf::new(cfg_no_noise(), 1);
        let a = gcf.invoke(0, 0.0, 10.0, 1.0, 1e9, None);
        assert!(a.cold);
        let b = gcf.invoke(0, a.finished_at + 1.0, 10.0, 1.0, 1e9, None);
        assert!(!b.cold);
        // warm start is much cheaper
        assert!(b.billed_s < a.billed_s);
    }

    #[test]
    fn scale_to_zero_reexposes_cold_start() {
        let mut gcf = SimulatedGcf::new(cfg_no_noise(), 1);
        let a = gcf.invoke(0, 0.0, 5.0, 1.0, 1e9, None);
        let b = gcf.invoke(0, a.finished_at + 1000.0, 5.0, 1.0, 1e9, None);
        assert!(b.cold, "idle timeout must re-cold the instance");
    }

    #[test]
    fn forced_crash_bills_round() {
        let mut gcf = SimulatedGcf::new(cfg_no_noise(), 2);
        let inv = gcf.invoke(3, 100.0, 5.0, 1.0, 160.0, Some(Forced::Crash));
        assert_eq!(inv.outcome, Outcome::Crash);
        assert!((inv.billed_s - 60.0).abs() < 1e-9);
    }

    #[test]
    fn forced_slow_finishes_after_deadline() {
        let mut gcf = SimulatedGcf::new(cfg_no_noise(), 3);
        let inv = gcf.invoke(4, 0.0, 1.0, 1.0, 30.0, Some(Forced::Slow));
        assert_eq!(inv.outcome, Outcome::Late);
        assert!(inv.finished_at > 30.0);
        assert!(inv.finished_at < 540.0, "slow must not hit the hard timeout");
    }

    #[test]
    fn fast_client_is_on_time() {
        let mut gcf = SimulatedGcf::new(cfg_no_noise(), 4);
        let inv = gcf.invoke(5, 0.0, 5.0, 1.0, 60.0, None);
        assert_eq!(inv.outcome, Outcome::OnTime);
        assert!(inv.training_time_s > 5.0); // includes transfer
    }

    #[test]
    fn function_timeout_crashes() {
        let mut gcf = SimulatedGcf::new(cfg_no_noise(), 5);
        let inv = gcf.invoke(6, 0.0, 10_000.0, 1.0, 1e9, None);
        assert_eq!(inv.outcome, Outcome::Crash);
        assert!((inv.billed_s - 540.0).abs() < 1e-9);
    }

    #[test]
    fn client_speed_is_stable_per_client() {
        let mut gcf = SimulatedGcf::new(FaasConfig::default(), 6);
        let s1 = gcf.client_speed(1);
        let s2 = gcf.client_speed(1);
        assert_eq!(s1, s2);
    }

    #[test]
    fn deterministic_given_seed() {
        let run = || {
            let mut gcf = SimulatedGcf::new(FaasConfig::default(), 42);
            (0..20)
                .map(|c| gcf.invoke(c, 0.0, 10.0, 1.0, 60.0, None).finished_at)
                .collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn busy_instance_is_not_spuriously_warm() {
        // A late client is still running past the round deadline; its
        // recorded `last_used_at` (= finished_at) exceeds the next
        // invocation's `now_s`. The negative idle gap must read as COLD
        // (a second instance spins up), not spuriously warm.
        let mut gcf = SimulatedGcf::new(cfg_no_noise(), 9);
        let late = gcf.invoke(0, 0.0, 1.0, 1.0, 30.0, Some(Forced::Slow));
        assert_eq!(late.outcome, Outcome::Late);
        let mid_flight_at = late.finished_at - 1.0;
        assert!(mid_flight_at > 30.0);
        let second = gcf.invoke(0, mid_flight_at, 1.0, 1.0, 1e9, None);
        assert!(second.cold, "re-invocation mid-flight must cold-start");
        // the warm timestamp stays monotonic: after both instances are
        // done, the pool is warm from the *latest* finish time
        let after = late.finished_at.max(second.finished_at) + 1.0;
        let third = gcf.invoke(0, after, 1.0, 1.0, 1e9, None);
        assert!(!third.cold);
    }

    #[test]
    fn rng_stream_contract() {
        // Golden for the documented per-invocation draw order ([cold
        // startup] -> transient bernoulli -> [first-time speed] ->
        // jitter): a raw mirror of the platform RNG stream predicts
        // every invocation exactly. Splitting decide/materialize (or any
        // future refactor) must not reorder these draws — all seeded
        // experiment goldens depend on them.
        let cfg = FaasConfig {
            transient_failure_rate: 0.3,
            ..FaasConfig::default()
        };
        let seed = 2024u64;
        let mut gcf = SimulatedGcf::new(cfg, seed);
        let mut mirror = crate::util::Rng::seed_from_u64(seed ^ FAAS_SEED_MIX);
        let (compute_s, payload_mb, deadline) = (10.0, 1.0, 1e9);
        for client in 0..32usize {
            // each client invoked once at t=0: always a cold start
            let inv = gcf.invoke(client, 0.0, compute_s, payload_mb, deadline, None);
            let startup = mirror.lognormal(cfg.cold_start_median_s.ln(), cfg.cold_start_sigma);
            let crashed = mirror.bernoulli(cfg.transient_failure_rate);
            if crashed {
                assert_eq!(inv.outcome, Outcome::Crash, "client {client}");
                continue; // crash consumed neither speed nor jitter
            }
            let speed = mirror.lognormal(0.0, cfg.client_speed_sigma);
            let jitter = mirror.lognormal(0.0, cfg.invocation_jitter_sigma);
            let train = compute_s * speed * jitter + 2.0 * payload_mb / cfg.network_mbps;
            assert!(
                (inv.finished_at - (startup + train)).abs() < 1e-9,
                "client {client}: {} vs {}",
                inv.finished_at,
                startup + train
            );
        }
        // A *forced* crash short-circuits the bernoulli draw: only the
        // cold-start draw is consumed before the next invocation.
        let mut gcf = SimulatedGcf::new(cfg, seed);
        let mut mirror = crate::util::Rng::seed_from_u64(seed ^ FAAS_SEED_MIX);
        let crash = gcf.invoke(0, 0.0, compute_s, payload_mb, 60.0, Some(Forced::Crash));
        assert_eq!(crash.outcome, Outcome::Crash);
        let _startup0 = mirror.lognormal(cfg.cold_start_median_s.ln(), cfg.cold_start_sigma);
        let inv1 = gcf.invoke(1, 0.0, compute_s, payload_mb, deadline, None);
        let startup1 = mirror.lognormal(cfg.cold_start_median_s.ln(), cfg.cold_start_sigma);
        if !mirror.bernoulli(cfg.transient_failure_rate) {
            let speed = mirror.lognormal(0.0, cfg.client_speed_sigma);
            let jitter = mirror.lognormal(0.0, cfg.invocation_jitter_sigma);
            let train = compute_s * speed * jitter + 2.0 * payload_mb / cfg.network_mbps;
            assert!((inv1.finished_at - (startup1 + train)).abs() < 1e-9);
        } else {
            assert_eq!(inv1.outcome, Outcome::Crash);
        }
        // A hard-timeout kill is also Outcome::Crash but is decided
        // *after* the work ran: it consumes the speed and jitter draws
        // (unlike the forced/transient crashes above).
        let cfg0 = FaasConfig {
            transient_failure_rate: 0.0,
            ..FaasConfig::default()
        };
        let mut gcf = SimulatedGcf::new(cfg0, seed);
        let mut mirror = crate::util::Rng::seed_from_u64(seed ^ FAAS_SEED_MIX);
        let killed = gcf.invoke(0, 0.0, 10_000.0, payload_mb, 1e9, None);
        assert_eq!(killed.outcome, Outcome::Crash);
        let _startup = mirror.lognormal(cfg0.cold_start_median_s.ln(), cfg0.cold_start_sigma);
        let _crash = mirror.bernoulli(cfg0.transient_failure_rate);
        let _speed = mirror.lognormal(0.0, cfg0.client_speed_sigma);
        let _jitter = mirror.lognormal(0.0, cfg0.invocation_jitter_sigma);
        let inv1 = gcf.invoke(1, 0.0, compute_s, payload_mb, 1e9, None);
        let startup1 = mirror.lognormal(cfg0.cold_start_median_s.ln(), cfg0.cold_start_sigma);
        let _crash1 = mirror.bernoulli(cfg0.transient_failure_rate);
        let speed1 = mirror.lognormal(0.0, cfg0.client_speed_sigma);
        let jitter1 = mirror.lognormal(0.0, cfg0.invocation_jitter_sigma);
        let train1 = compute_s * speed1 * jitter1 + 2.0 * payload_mb / cfg0.network_mbps;
        assert!((inv1.finished_at - (startup1 + train1)).abs() < 1e-9);
    }

    #[test]
    fn storm_windows_force_cold_starts() {
        // Huge idle timeout so the Standard control stays warm across
        // the whole test — only the storm window may force cold.
        let cfg = FaasConfig {
            idle_timeout_s: 1e9,
            ..cfg_no_noise()
        };
        let mut gcf = SimulatedGcf::with_scenario(cfg, 1, Scenario::ColdStartStorm);
        // t=130 is outside the storm window (duty 0..120): normal pool
        // behaviour — first call cold, follow-up warm.
        let a = gcf.invoke(0, 130.0, 1.0, 1.0, 1e9, None);
        assert!(a.cold);
        let b = gcf.invoke(0, a.finished_at + 1.0, 1.0, 1.0, 1e9, None);
        assert!(!b.cold, "outside the storm the warm pool works");
        // t=610 is inside the next storm window (610 % 600 = 10 < 120)
        // and well inside the idle timeout: cold anyway.
        let c = gcf.invoke(0, 610.0, 1.0, 1.0, 1e9, None);
        assert!(c.cold, "storm window must override the warm pool");
        // the same timeline under Standard stays warm
        let mut std_gcf = SimulatedGcf::new(cfg, 1);
        let a = std_gcf.invoke(0, 130.0, 1.0, 1.0, 1e9, None);
        let _b = std_gcf.invoke(0, a.finished_at + 1.0, 1.0, 1.0, 1e9, None);
        assert!(!std_gcf.invoke(0, 610.0, 1.0, 1.0, 1e9, None).cold);
    }

    #[test]
    fn diurnal_wave_stretches_peak_and_relaxes_trough() {
        let mut gcf = SimulatedGcf::with_scenario(cfg_no_noise(), 2, Scenario::Diurnal);
        // sin peak at t = period/4, trough at 3*period/4. Different
        // clients so both invocations are cold with identical draws in
        // expectation (no-noise config: draws are ~exact medians).
        let peak = gcf.invoke(0, DIURNAL_PERIOD_S / 4.0, 10.0, 1.0, 1e9, None);
        let trough = gcf.invoke(1, 3.0 * DIURNAL_PERIOD_S / 4.0, 10.0, 1.0, 1e9, None);
        let transfer = 2.0 * 1.0 / gcf.cfg.network_mbps;
        let peak_compute = peak.training_time_s - transfer;
        let trough_compute = trough.training_time_s - transfer;
        assert!(
            (peak_compute - 16.0).abs() < 0.1,
            "peak load 1.6x: {peak_compute}"
        );
        assert!(
            (trough_compute - 4.0).abs() < 0.1,
            "trough load 0.4x: {trough_compute}"
        );
    }

    #[test]
    fn regional_outage_drops_exactly_the_rotating_region() {
        let mut gcf = SimulatedGcf::with_scenario(cfg_no_noise(), 3, Scenario::RegionalOutage);
        // cycle 0 (t in 0..180): region 0 down — clients 0 and 4 crash,
        // clients 1..3 run normally.
        assert_eq!(outage_region(0.0), Some(0));
        for c in [0usize, 4] {
            let inv = gcf.invoke(c, 10.0, 1.0, 1.0, 1e9, None);
            assert_eq!(inv.outcome, Outcome::Crash, "client {c} in downed region");
            assert_eq!(inv.training_time_s, 0.0);
        }
        for c in [1usize, 2, 3] {
            let inv = gcf.invoke(c, 10.0, 1.0, 1.0, 1e9, None);
            assert_eq!(inv.outcome, Outcome::OnTime, "client {c} unaffected");
        }
        // after the window closes the downed region recovers
        assert_eq!(outage_region(200.0), None);
        let inv = gcf.invoke(0, 200.0, 1.0, 1.0, 1e9, None);
        assert_eq!(inv.outcome, Outcome::OnTime);
        // next cycle rotates to region 1
        assert_eq!(outage_region(OUTAGE_PERIOD_S + 10.0), Some(1));
        let inv = gcf.invoke(1, OUTAGE_PERIOD_S + 10.0, 1.0, 1.0, 1e9, None);
        assert_eq!(inv.outcome, Outcome::Crash);
    }

    #[test]
    fn adversarial_tail_is_stable_and_slow() {
        // membership is a pure function of the id
        for c in 0..64usize {
            assert_eq!(is_adversarial(c), is_adversarial(c));
        }
        // roughly one client in ADVERSARIAL_DECILE lands in the tail
        let tail = (0..10_000usize).filter(|&c| is_adversarial(c)).count();
        assert!((800..1200).contains(&tail), "tail size {tail}");
        let slow = (0..100).find(|&c| is_adversarial(c)).unwrap();
        let fast = (0..100).find(|&c| !is_adversarial(c)).unwrap();
        let mut gcf = SimulatedGcf::with_scenario(cfg_no_noise(), 4, Scenario::Adversarial);
        let s = gcf.invoke(slow, 0.0, 10.0, 1.0, 1e9, None);
        let f = gcf.invoke(fast, 0.0, 10.0, 1.0, 1e9, None);
        let transfer = 2.0 * 1.0 / gcf.cfg.network_mbps;
        let ratio = (s.training_time_s - transfer) / (f.training_time_s - transfer);
        assert!(
            (ratio - ADVERSARIAL_SLOWDOWN).abs() < 0.01,
            "slowdown ratio {ratio}"
        );
    }

    #[test]
    fn outage_crash_skips_speed_and_jitter_draws_like_forced_crash() {
        // Contract-test extension for the new decide-phase branch: an
        // outage drop consumes only the startup draw, leaving the
        // stream exactly where a Forced::Crash would.
        let cfg = FaasConfig {
            transient_failure_rate: 0.3,
            ..FaasConfig::default()
        };
        let seed = 77u64;
        let mut gcf = SimulatedGcf::with_scenario(cfg, seed, Scenario::RegionalOutage);
        let mut mirror = crate::util::Rng::seed_from_u64(seed ^ FAAS_SEED_MIX);
        // client 0, t=10: region 0 is down — crash, one startup draw.
        let dropped = gcf.invoke(0, 10.0, 10.0, 1.0, 60.0, None);
        assert_eq!(dropped.outcome, Outcome::Crash);
        let _startup0 = mirror.lognormal(cfg.cold_start_median_s.ln(), cfg.cold_start_sigma);
        // client 1, t=10: region 1 is up — the full draw sequence.
        let inv1 = gcf.invoke(1, 10.0, 10.0, 1.0, 1e9, None);
        let startup1 = mirror.lognormal(cfg.cold_start_median_s.ln(), cfg.cold_start_sigma);
        if !mirror.bernoulli(cfg.transient_failure_rate) {
            let speed = mirror.lognormal(0.0, cfg.client_speed_sigma);
            let jitter = mirror.lognormal(0.0, cfg.invocation_jitter_sigma);
            let train = 10.0 * speed * jitter + 2.0 * 1.0 / cfg.network_mbps;
            assert!((inv1.finished_at - (10.0 + startup1 + train)).abs() < 1e-9);
        } else {
            assert_eq!(inv1.outcome, Outcome::Crash);
        }
    }

    #[test]
    fn grid_scenarios_leave_standard_streams_untouched() {
        // The same seeded invocation sequence under Standard must be
        // bit-identical whether run on a `new` platform or a
        // `with_scenario(Standard)` one — and a Straggler-forced
        // sequence must not see any scenario hooks either.
        let run = |gcf: &mut SimulatedGcf| {
            (0..16)
                .map(|c| {
                    let forced = if c % 5 == 0 { Some(Forced::Slow) } else { None };
                    gcf.invoke(c, c as f64 * 7.0, 10.0, 1.0, 200.0, forced)
                        .finished_at
                })
                .collect::<Vec<_>>()
        };
        let mut a = SimulatedGcf::new(FaasConfig::default(), 42);
        let mut b = SimulatedGcf::with_scenario(FaasConfig::default(), 42, Scenario::Standard);
        assert_eq!(run(&mut a), run(&mut b));
    }

    #[test]
    fn transient_failures_occur_at_configured_rate() {
        let cfg = FaasConfig {
            transient_failure_rate: 0.3,
            ..cfg_no_noise()
        };
        let mut gcf = SimulatedGcf::new(cfg, 7);
        let crashes = (0..1000)
            .filter(|&c| {
                gcf.invoke(c, 0.0, 1.0, 0.1, 1e9, None).outcome == Outcome::Crash
            })
            .count();
        assert!((200..400).contains(&crashes), "crashes={crashes}");
    }
}
