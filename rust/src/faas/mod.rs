//! Simulated 2nd-generation Google Cloud Functions platform (substrate).
//!
//! The paper's straggler phenomenology (§II, §III-C) comes from four FaaS
//! properties, all modelled here with a seeded RNG over a **virtual
//! clock** (deterministic, repeatable experiments):
//!
//! * **cold starts** — first invocation, or invocation after the warm
//!   instance was scaled to zero, pays a log-normal startup latency
//!   (published GCF measurements for TF-sized client containers sit in
//!   the ~2-10 s band);
//! * **performance variation** — each client function lands on an
//!   arbitrary provisioned VM ([29]): a static per-client speed factor
//!   plus per-invocation log-normal jitter multiply the compute time;
//! * **transient failures** — GCF's 99.95% SLO means requests get dropped
//!   (§III-C); a Bernoulli failure makes the invocation crash;
//! * **scale-to-zero** — warm instances idle out after
//!   `idle_timeout_s`, re-exposing cold starts mid-experiment.
//!
//! The *actual* training compute happens in the PJRT runtime; the
//! simulator turns a nominal compute time into a virtual invocation
//! timeline (start, finish, billed duration) and a success/crash/slow
//! outcome relative to the round deadline. Straggler-scenario forcing
//! (§VI-A4) is layered on top by the coordinator via [`Forced`].

use std::collections::HashMap;

use crate::util::Rng;
use crate::ClientId;

/// Platform model parameters.
#[derive(Debug, Clone, Copy)]
pub struct FaasConfig {
    /// Median cold-start latency (s).
    pub cold_start_median_s: f64,
    /// Log-normal sigma of the cold-start latency.
    pub cold_start_sigma: f64,
    /// Fixed invocation overhead for warm instances (s).
    pub warm_overhead_s: f64,
    /// Scale-to-zero idle timeout (s).
    pub idle_timeout_s: f64,
    /// Sigma of the static per-client VM speed factor (log-normal, median 1).
    pub client_speed_sigma: f64,
    /// Sigma of the per-invocation jitter (log-normal, median 1).
    pub invocation_jitter_sigma: f64,
    /// Probability an invocation is dropped/crashed by the platform.
    pub transient_failure_rate: f64,
    /// Function memory limit (MB) — drives the cost model tier.
    pub memory_mb: u32,
    /// Model download/upload bandwidth (MB/s) between function and the
    /// parameter store (nginx/DB in the paper's deployment).
    pub network_mbps: f64,
    /// Hard function timeout (s) — 540 s for the paper's clients.
    pub function_timeout_s: f64,
}

impl Default for FaasConfig {
    fn default() -> Self {
        Self {
            cold_start_median_s: 4.0,
            cold_start_sigma: 0.5,
            warm_overhead_s: 0.15,
            idle_timeout_s: 300.0,
            client_speed_sigma: 0.25,
            invocation_jitter_sigma: 0.10,
            transient_failure_rate: 0.02,
            memory_mb: 2048,
            network_mbps: 40.0,
            function_timeout_s: 540.0,
        }
    }
}

/// Behaviour forced by the straggler-% scenario (§VI-A4).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Forced {
    /// Client completes but its update lands after the round deadline.
    Slow,
    /// Client crashes at round start (still billed the round, §VI-C).
    Crash,
}

/// How an invocation ended, relative to the round deadline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Outcome {
    /// Finished before the deadline: update aggregated this round.
    OnTime,
    /// Finished after the deadline but before the function timeout: the
    /// update arrives late (staleness buffer candidate).
    Late,
    /// Crashed (platform drop, forced crash, or function timeout).
    Crash,
}

/// Simulated invocation record (virtual-clock seconds).
#[derive(Debug, Clone, Copy)]
pub struct Invocation {
    pub client: ClientId,
    pub started_at: f64,
    /// Virtual completion time (crash => time the instance died).
    pub finished_at: f64,
    /// Seconds billed by the provider for this invocation.
    pub billed_s: f64,
    /// Pure local-training duration the *client* would report (§V-B) —
    /// excludes the platform cold start, includes model transfer.
    pub training_time_s: f64,
    pub cold: bool,
    pub outcome: Outcome,
}

struct WarmInstance {
    last_used_at: f64,
}

/// Seed-mix for the platform RNG stream (public so tests can mirror the
/// stream draw-for-draw; see `rng_stream_contract`).
pub const FAAS_SEED_MIX: u64 = 0xfaa5_0001;

/// Platform-side decision for one invocation. **Every RNG draw happens
/// here**, in the documented order; timeline materialization below is
/// pure arithmetic. The per-invocation draw order is a compatibility
/// contract (seeded goldens depend on it):
///
/// 1. one log-normal **startup** draw — only when the instance is cold;
/// 2. one Bernoulli **transient-crash** draw — skipped entirely when the
///    scenario already forces a crash (`||` short-circuit);
/// 3. one log-normal **VM speed** draw — skipped if step 2 crashed;
///    otherwise drawn on the client's first such invocation and cached;
/// 4. one log-normal **jitter** draw — skipped if step 2 crashed.
///
/// Note the asymmetry between the two crash kinds: a forced/transient
/// crash kills the function *before* it does any work, so steps 3-4 are
/// never drawn; a hard-timeout kill (decided later, in materialization)
/// happens *after* the work was attempted, so its invocation consumed
/// both draws (and cached the client speed) even though its outcome is
/// also `Crash`.
struct Decision {
    cold: bool,
    startup: f64,
    /// `None` when the invocation crashed before doing any work
    /// (forced/transient); the speed/jitter draws were *not* consumed.
    /// A later hard-timeout kill still carries `Some` here.
    perf: Option<(f64, f64)>,
}

/// The simulated platform. One instance pool per experiment.
pub struct SimulatedGcf {
    pub cfg: FaasConfig,
    rng: Rng,
    warm: HashMap<ClientId, WarmInstance>,
    speed: HashMap<ClientId, f64>,
}

impl SimulatedGcf {
    pub fn new(cfg: FaasConfig, seed: u64) -> Self {
        Self {
            cfg,
            rng: Rng::seed_from_u64(seed ^ FAAS_SEED_MIX),
            warm: HashMap::new(),
            speed: HashMap::new(),
        }
    }

    /// Static per-client VM speed factor (median 1.0, log-normal).
    pub fn client_speed(&mut self, client: ClientId) -> f64 {
        let sigma = self.cfg.client_speed_sigma.max(1e-9);
        let rng = &mut self.rng;
        *self
            .speed
            .entry(client)
            .or_insert_with(|| rng.lognormal(0.0, sigma))
    }

    /// Model payload transfer time (download global + upload update).
    fn transfer_s(&self, payload_mb: f64) -> f64 {
        2.0 * payload_mb / self.cfg.network_mbps.max(1e-9)
    }

    /// Phase 1 — platform outcome decision: consume the RNG stream in
    /// the contract order documented on [`Decision`] and decide whether
    /// the invocation crashes before doing any work.
    fn decide(&mut self, client: ClientId, now_s: f64, forced: Option<Forced>) -> Decision {
        // cold or warm? A *negative* idle gap means the previously
        // recorded instance is still running at `now_s` (a late client
        // re-invoked mid-flight): the platform then fans out a second,
        // cold instance rather than reusing the busy one — without the
        // clamp the instance looked spuriously warm.
        let cold = match self.warm.get(&client) {
            Some(w) => !(0.0..=self.cfg.idle_timeout_s).contains(&(now_s - w.last_used_at)),
            None => true,
        };
        let startup = if cold {
            self.rng
                .lognormal(self.cfg.cold_start_median_s.ln(), self.cfg.cold_start_sigma.max(1e-9))
        } else {
            self.cfg.warm_overhead_s
        };
        let crashed = forced == Some(Forced::Crash)
            || self.rng.bernoulli(self.cfg.transient_failure_rate);
        let perf = if crashed {
            None
        } else {
            let speed = self.client_speed(client);
            let jitter = self
                .rng
                .lognormal(0.0, self.cfg.invocation_jitter_sigma.max(1e-9));
            Some((speed, jitter))
        };
        Decision {
            cold,
            startup,
            perf,
        }
    }

    /// Phase 2 — pure timeline materialization: no RNG, just the warm
    /// pool bookkeeping and the virtual start/finish/billing arithmetic.
    #[allow(clippy::too_many_arguments)]
    fn materialize(
        &mut self,
        d: Decision,
        client: ClientId,
        now_s: f64,
        compute_s: f64,
        payload_mb: f64,
        deadline_s: f64,
        forced: Option<Forced>,
    ) -> Invocation {
        let (speed, jitter) = match d.perf {
            None => {
                // §VI-C worst case: a crashed straggler is billed for the
                // whole round.
                let end = deadline_s.max(now_s);
                self.warm.remove(&client);
                return Invocation {
                    client,
                    started_at: now_s,
                    finished_at: end,
                    billed_s: end - now_s,
                    training_time_s: 0.0,
                    cold: d.cold,
                    outcome: Outcome::Crash,
                };
            }
            Some(p) => p,
        };

        let mut train_s = compute_s * speed * jitter + self.transfer_s(payload_mb);
        if forced == Some(Forced::Slow) {
            // Scenario forcing (§VI-A4): delays (cold start, bandwidth,
            // ...) push completion past the round deadline.
            let past_deadline = (deadline_s - now_s - d.startup).max(0.0) * 1.25 + 1.0;
            train_s = train_s.max(past_deadline);
        }
        let total = d.startup + train_s;

        if total > self.cfg.function_timeout_s {
            // platform kills the function at its hard timeout
            let end = now_s + self.cfg.function_timeout_s;
            self.warm.remove(&client);
            return Invocation {
                client,
                started_at: now_s,
                finished_at: end,
                billed_s: self.cfg.function_timeout_s,
                training_time_s: 0.0,
                cold: d.cold,
                outcome: Outcome::Crash,
            };
        }

        let finished_at = now_s + total;
        // Monotonic warm timestamp: never move the pool's "last alive"
        // time backwards — a still-running (in-flight) instance keeps the
        // pool warm past a shorter overlapping invocation.
        let last_used_at = self
            .warm
            .get(&client)
            .map_or(finished_at, |w| w.last_used_at.max(finished_at));
        self.warm.insert(client, WarmInstance { last_used_at });
        Invocation {
            client,
            started_at: now_s,
            finished_at,
            billed_s: total,
            training_time_s: train_s,
            cold: d.cold,
            outcome: if finished_at <= deadline_s {
                Outcome::OnTime
            } else {
                Outcome::Late
            },
        }
    }

    /// Simulate one invocation issued at virtual time `now_s`: the
    /// outcome decision ([`Decision`], all RNG) followed by the pure
    /// timeline materialization.
    ///
    /// `compute_s` is the nominal local-training compute time,
    /// `payload_mb` the model transfer size, `deadline_s` the round
    /// deadline (absolute virtual time), and `forced` the scenario
    /// override. The full timeline — including the crash/late/on-time
    /// outcome — is decided *before* any real training runs, so the
    /// scheduler can skip compute for doomed invocations.
    pub fn invoke(
        &mut self,
        client: ClientId,
        now_s: f64,
        compute_s: f64,
        payload_mb: f64,
        deadline_s: f64,
        forced: Option<Forced>,
    ) -> Invocation {
        let d = self.decide(client, now_s, forced);
        self.materialize(d, client, now_s, compute_s, payload_mb, deadline_s, forced)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg_no_noise() -> FaasConfig {
        FaasConfig {
            transient_failure_rate: 0.0,
            client_speed_sigma: 1e-9,
            invocation_jitter_sigma: 1e-9,
            cold_start_sigma: 1e-9,
            ..FaasConfig::default()
        }
    }

    #[test]
    fn first_invocation_is_cold_then_warm() {
        let mut gcf = SimulatedGcf::new(cfg_no_noise(), 1);
        let a = gcf.invoke(0, 0.0, 10.0, 1.0, 1e9, None);
        assert!(a.cold);
        let b = gcf.invoke(0, a.finished_at + 1.0, 10.0, 1.0, 1e9, None);
        assert!(!b.cold);
        // warm start is much cheaper
        assert!(b.billed_s < a.billed_s);
    }

    #[test]
    fn scale_to_zero_reexposes_cold_start() {
        let mut gcf = SimulatedGcf::new(cfg_no_noise(), 1);
        let a = gcf.invoke(0, 0.0, 5.0, 1.0, 1e9, None);
        let b = gcf.invoke(0, a.finished_at + 1000.0, 5.0, 1.0, 1e9, None);
        assert!(b.cold, "idle timeout must re-cold the instance");
    }

    #[test]
    fn forced_crash_bills_round() {
        let mut gcf = SimulatedGcf::new(cfg_no_noise(), 2);
        let inv = gcf.invoke(3, 100.0, 5.0, 1.0, 160.0, Some(Forced::Crash));
        assert_eq!(inv.outcome, Outcome::Crash);
        assert!((inv.billed_s - 60.0).abs() < 1e-9);
    }

    #[test]
    fn forced_slow_finishes_after_deadline() {
        let mut gcf = SimulatedGcf::new(cfg_no_noise(), 3);
        let inv = gcf.invoke(4, 0.0, 1.0, 1.0, 30.0, Some(Forced::Slow));
        assert_eq!(inv.outcome, Outcome::Late);
        assert!(inv.finished_at > 30.0);
        assert!(inv.finished_at < 540.0, "slow must not hit the hard timeout");
    }

    #[test]
    fn fast_client_is_on_time() {
        let mut gcf = SimulatedGcf::new(cfg_no_noise(), 4);
        let inv = gcf.invoke(5, 0.0, 5.0, 1.0, 60.0, None);
        assert_eq!(inv.outcome, Outcome::OnTime);
        assert!(inv.training_time_s > 5.0); // includes transfer
    }

    #[test]
    fn function_timeout_crashes() {
        let mut gcf = SimulatedGcf::new(cfg_no_noise(), 5);
        let inv = gcf.invoke(6, 0.0, 10_000.0, 1.0, 1e9, None);
        assert_eq!(inv.outcome, Outcome::Crash);
        assert!((inv.billed_s - 540.0).abs() < 1e-9);
    }

    #[test]
    fn client_speed_is_stable_per_client() {
        let mut gcf = SimulatedGcf::new(FaasConfig::default(), 6);
        let s1 = gcf.client_speed(1);
        let s2 = gcf.client_speed(1);
        assert_eq!(s1, s2);
    }

    #[test]
    fn deterministic_given_seed() {
        let run = || {
            let mut gcf = SimulatedGcf::new(FaasConfig::default(), 42);
            (0..20)
                .map(|c| gcf.invoke(c, 0.0, 10.0, 1.0, 60.0, None).finished_at)
                .collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn busy_instance_is_not_spuriously_warm() {
        // A late client is still running past the round deadline; its
        // recorded `last_used_at` (= finished_at) exceeds the next
        // invocation's `now_s`. The negative idle gap must read as COLD
        // (a second instance spins up), not spuriously warm.
        let mut gcf = SimulatedGcf::new(cfg_no_noise(), 9);
        let late = gcf.invoke(0, 0.0, 1.0, 1.0, 30.0, Some(Forced::Slow));
        assert_eq!(late.outcome, Outcome::Late);
        let mid_flight_at = late.finished_at - 1.0;
        assert!(mid_flight_at > 30.0);
        let second = gcf.invoke(0, mid_flight_at, 1.0, 1.0, 1e9, None);
        assert!(second.cold, "re-invocation mid-flight must cold-start");
        // the warm timestamp stays monotonic: after both instances are
        // done, the pool is warm from the *latest* finish time
        let after = late.finished_at.max(second.finished_at) + 1.0;
        let third = gcf.invoke(0, after, 1.0, 1.0, 1e9, None);
        assert!(!third.cold);
    }

    #[test]
    fn rng_stream_contract() {
        // Golden for the documented per-invocation draw order ([cold
        // startup] -> transient bernoulli -> [first-time speed] ->
        // jitter): a raw mirror of the platform RNG stream predicts
        // every invocation exactly. Splitting decide/materialize (or any
        // future refactor) must not reorder these draws — all seeded
        // experiment goldens depend on them.
        let cfg = FaasConfig {
            transient_failure_rate: 0.3,
            ..FaasConfig::default()
        };
        let seed = 2024u64;
        let mut gcf = SimulatedGcf::new(cfg, seed);
        let mut mirror = crate::util::Rng::seed_from_u64(seed ^ FAAS_SEED_MIX);
        let (compute_s, payload_mb, deadline) = (10.0, 1.0, 1e9);
        for client in 0..32usize {
            // each client invoked once at t=0: always a cold start
            let inv = gcf.invoke(client, 0.0, compute_s, payload_mb, deadline, None);
            let startup = mirror.lognormal(cfg.cold_start_median_s.ln(), cfg.cold_start_sigma);
            let crashed = mirror.bernoulli(cfg.transient_failure_rate);
            if crashed {
                assert_eq!(inv.outcome, Outcome::Crash, "client {client}");
                continue; // crash consumed neither speed nor jitter
            }
            let speed = mirror.lognormal(0.0, cfg.client_speed_sigma);
            let jitter = mirror.lognormal(0.0, cfg.invocation_jitter_sigma);
            let train = compute_s * speed * jitter + 2.0 * payload_mb / cfg.network_mbps;
            assert!(
                (inv.finished_at - (startup + train)).abs() < 1e-9,
                "client {client}: {} vs {}",
                inv.finished_at,
                startup + train
            );
        }
        // A *forced* crash short-circuits the bernoulli draw: only the
        // cold-start draw is consumed before the next invocation.
        let mut gcf = SimulatedGcf::new(cfg, seed);
        let mut mirror = crate::util::Rng::seed_from_u64(seed ^ FAAS_SEED_MIX);
        let crash = gcf.invoke(0, 0.0, compute_s, payload_mb, 60.0, Some(Forced::Crash));
        assert_eq!(crash.outcome, Outcome::Crash);
        let _startup0 = mirror.lognormal(cfg.cold_start_median_s.ln(), cfg.cold_start_sigma);
        let inv1 = gcf.invoke(1, 0.0, compute_s, payload_mb, deadline, None);
        let startup1 = mirror.lognormal(cfg.cold_start_median_s.ln(), cfg.cold_start_sigma);
        if !mirror.bernoulli(cfg.transient_failure_rate) {
            let speed = mirror.lognormal(0.0, cfg.client_speed_sigma);
            let jitter = mirror.lognormal(0.0, cfg.invocation_jitter_sigma);
            let train = compute_s * speed * jitter + 2.0 * payload_mb / cfg.network_mbps;
            assert!((inv1.finished_at - (startup1 + train)).abs() < 1e-9);
        } else {
            assert_eq!(inv1.outcome, Outcome::Crash);
        }
        // A hard-timeout kill is also Outcome::Crash but is decided
        // *after* the work ran: it consumes the speed and jitter draws
        // (unlike the forced/transient crashes above).
        let cfg0 = FaasConfig {
            transient_failure_rate: 0.0,
            ..FaasConfig::default()
        };
        let mut gcf = SimulatedGcf::new(cfg0, seed);
        let mut mirror = crate::util::Rng::seed_from_u64(seed ^ FAAS_SEED_MIX);
        let killed = gcf.invoke(0, 0.0, 10_000.0, payload_mb, 1e9, None);
        assert_eq!(killed.outcome, Outcome::Crash);
        let _startup = mirror.lognormal(cfg0.cold_start_median_s.ln(), cfg0.cold_start_sigma);
        let _crash = mirror.bernoulli(cfg0.transient_failure_rate);
        let _speed = mirror.lognormal(0.0, cfg0.client_speed_sigma);
        let _jitter = mirror.lognormal(0.0, cfg0.invocation_jitter_sigma);
        let inv1 = gcf.invoke(1, 0.0, compute_s, payload_mb, 1e9, None);
        let startup1 = mirror.lognormal(cfg0.cold_start_median_s.ln(), cfg0.cold_start_sigma);
        let _crash1 = mirror.bernoulli(cfg0.transient_failure_rate);
        let speed1 = mirror.lognormal(0.0, cfg0.client_speed_sigma);
        let jitter1 = mirror.lognormal(0.0, cfg0.invocation_jitter_sigma);
        let train1 = compute_s * speed1 * jitter1 + 2.0 * payload_mb / cfg0.network_mbps;
        assert!((inv1.finished_at - (startup1 + train1)).abs() < 1e-9);
    }

    #[test]
    fn transient_failures_occur_at_configured_rate() {
        let cfg = FaasConfig {
            transient_failure_rate: 0.3,
            ..cfg_no_noise()
        };
        let mut gcf = SimulatedGcf::new(cfg, 7);
        let crashes = (0..1000)
            .filter(|&c| {
                gcf.invoke(c, 0.0, 1.0, 0.1, 1e9, None).outcome == Outcome::Crash
            })
            .count();
        assert!((200..400).contains(&crashes), "crashes={crashes}");
    }
}
