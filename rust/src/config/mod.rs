//! Experiment configuration: per-dataset presets mirroring the paper's
//! Table I / §VI-A3 setup (scaled to the simulator testbed), the two
//! experiment scenarios of §VI-A4, and JSON load/save for custom runs.

use std::path::{Path, PathBuf};
use std::str::FromStr;

use crate::data::Partition;
use crate::faas::FaasConfig;
use crate::strategy::StrategyKind;
use crate::util::Json;
use crate::Result;

/// Experiment scenario: the paper's two (§VI-A4) plus the adversarial
/// grid variants. The grid scenarios stress the platform model rather
/// than forcing per-client straggler roles, so their effects live in
/// `faas::SimulatedGcf` (deterministic window/identity functions — no
/// extra RNG draws, keeping old-scenario streams byte-identical).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Scenario {
    /// Unmodified deployment; round time fits every client.
    Standard,
    /// Forced straggler percentage (10/30/50/70 in the paper).
    Straggler(u8),
    /// Periodic windows in which the warm pool is useless: every
    /// invocation inside a storm window cold-starts (deploy waves /
    /// provider instance recycling).
    ColdStartStorm,
    /// Sinusoidal diurnal traffic wave modulating invocation latency:
    /// startup and training stretch at peak load, relax off-peak.
    Diurnal,
    /// Correlated failure of one client region at a time: clients hash
    /// into regions, and a rotating outage window crashes every
    /// invocation from the affected region.
    RegionalOutage,
    /// Persistent adversarially slow tail: the worst decile of clients
    /// (stable hash of the id) trains several times slower, forever.
    Adversarial,
}

impl Scenario {
    pub fn label(&self) -> String {
        match self {
            Scenario::Standard => "standard".into(),
            Scenario::Straggler(p) => format!("straggler{p}"),
            Scenario::ColdStartStorm => "coldstartstorm".into(),
            Scenario::Diurnal => "diurnal".into(),
            Scenario::RegionalOutage => "regionaloutage".into(),
            Scenario::Adversarial => "adversarial".into(),
        }
    }

    pub fn straggler_fraction(&self) -> f64 {
        match self {
            Scenario::Straggler(p) => *p as f64 / 100.0,
            _ => 0.0,
        }
    }

    /// Does this scenario use the tight straggler-era round deadline?
    /// The adversarial tail only bites when slow clients can actually
    /// miss rounds; the platform-stress scenarios keep the generous
    /// standard deadline so their effect is isolated from timeout
    /// pressure.
    pub fn tight_deadline(&self) -> bool {
        matches!(self, Scenario::Straggler(_) | Scenario::Adversarial)
    }
}

impl FromStr for Scenario {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> std::result::Result<Self, Self::Err> {
        match s {
            "standard" => return Ok(Scenario::Standard),
            "coldstartstorm" => return Ok(Scenario::ColdStartStorm),
            "diurnal" => return Ok(Scenario::Diurnal),
            "regionaloutage" => return Ok(Scenario::RegionalOutage),
            "adversarial" => return Ok(Scenario::Adversarial),
            _ => {}
        }
        if let Some(p) = s.strip_prefix("straggler") {
            return Ok(Scenario::Straggler(p.parse()?));
        }
        anyhow::bail!(
            "unknown scenario {s:?}; expected standard|straggler<pct>|\
             coldstartstorm|diurnal|regionaloutage|adversarial"
        )
    }
}

/// Training loop shape: the paper's round-synchronised protocol, or the
/// rounds-free continuous extension driven by the persistent executor
/// plane (see `coordinator::Controller::run_continuous`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// Round barrier: select k, invoke, wait for the deadline, aggregate
    /// once (the paper's protocol; the default).
    Rounds,
    /// No barrier: keep `clients_per_round x inflight_cohorts` clients
    /// in flight; each completion folds into the global immediately with
    /// Eq. 3 staleness damping keyed to the fold generation it departed
    /// from, and a replacement client is dispatched.
    Continuous,
}

impl Mode {
    pub fn as_str(self) -> &'static str {
        match self {
            Mode::Rounds => "rounds",
            Mode::Continuous => "continuous",
        }
    }
}

impl FromStr for Mode {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> std::result::Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "rounds" => Ok(Mode::Rounds),
            "continuous" | "cont" => Ok(Mode::Continuous),
            other => anyhow::bail!("unknown mode {other:?}; expected rounds|continuous"),
        }
    }
}

/// Full configuration of one experiment run.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// Model family / dataset name (must match an artifacts manifest).
    pub dataset: String,
    pub strategy: StrategyKind,
    pub scenario: Scenario,
    /// Registered clients (the paper: 300 MNIST, 542 Speech, ...).
    pub n_clients: usize,
    /// Clients invoked per round (nClientsPerRound).
    pub clients_per_round: usize,
    pub rounds: u32,
    pub seed: u64,
    /// Evaluate centrally every N rounds (the final round always is).
    pub eval_every: u32,
    pub partition: Partition,
    pub faas: FaasConfig,
    /// Nominal local-training time of a speed-1.0 client (virtual s).
    /// The paper's GCF clients train for tens of seconds per round; the
    /// per-dataset presets encode that magnitude.
    pub base_train_s: f64,
    /// Round deadline in the standard scenario: generous, everyone fits.
    pub round_timeout_standard_s: f64,
    /// Round deadline in straggler scenarios: tight (§VI-A4 limits round
    /// time so delayed clients miss it).
    pub round_timeout_straggler_s: f64,
    /// Among forced stragglers: fraction that are slow (push late
    /// updates); the rest crash outright (§VI-A4's two effects).
    pub straggler_slow_frac: f64,
    pub artifacts_dir: PathBuf,
    /// Optional JSON snapshot path for the client-history DB.
    pub history_path: Option<PathBuf>,
    /// Print per-round progress lines.
    pub verbose: bool,
    /// Extension (paper §VII future work): dynamically adapt the number
    /// of clients selected each round to the observed EUR — when rounds
    /// waste invocations on stragglers, the controller over-provisions
    /// (up to 2x the configured k) so the *effective* update count stays
    /// near the target; it shrinks back as reliability recovers.
    pub adaptive_clients: bool,
    /// Extension (paper §VII future work): "aggregate valuable updates
    /// and discard the unnecessary ones" — drop stale updates whose L2
    /// distance from the current global model exceeds
    /// `stale_norm_clip x` the median distance of this round's fresh
    /// updates. `None` disables the filter (paper behaviour).
    pub stale_norm_clip: Option<f64>,
    /// Training loop shape; [`Mode::Rounds`] is the paper's protocol.
    pub mode: Mode,
    /// Continuous mode: multiples of `clients_per_round` kept in flight
    /// (the target concurrency is `clients_per_round * inflight_cohorts`).
    pub inflight_cohorts: usize,
    /// Continuous mode: base mixing rate of a single folded update
    /// (`new = (1 - a*damp) * global + a*damp * update`, where `damp` is
    /// the Eq. 3 staleness component for the departed generation).
    pub async_alpha: f64,
    /// Executor-pool size override; `None` sizes the fleet from
    /// [`crate::params::default_workers`] (or pins a single persistent
    /// worker for backends that opt out of `parallel_train`).
    pub workers: Option<usize>,
    /// Parameter-plane shard count override; `None` resolves via
    /// [`crate::params::resolve_shards`] (`FEDLESS_SHARDS` env ▸ core
    /// count). Any value is arithmetic-identical — it only sets lock
    /// and fold-parallelism granularity.
    pub shards: Option<usize>,
    /// Compute-kernel override for the math plane: `"scalar"` or
    /// `"avx2"` (`None` auto-detects; the `FEDLESS_KERNEL` env var
    /// outranks both). Every choice is bit-identical — the vector
    /// kernels reproduce the scalar arithmetic exactly — so this only
    /// moves wall-clock, never results.
    pub kernel: Option<String>,
    /// Quantize client uploads: int8 symmetric per-shard with
    /// client-side error-feedback residuals
    /// ([`crate::params::ErrorFeedback`]). Changes the training
    /// arithmetic (updates round to the int8 grid), so the goldens run
    /// with it off.
    pub quantize_updates: bool,
    /// Top-k sparse variant of the quantized upload: keep this fraction
    /// of each shard's largest-magnitude elements. Requires
    /// `quantize_updates`.
    pub quantize_topk: Option<f64>,
}

impl ExperimentConfig {
    /// Per-dataset preset: Table I hyperparameters live in the AOT
    /// manifest; this sets the deployment shape (§VI-A3) scaled ~1/5 for
    /// the simulator plus the virtual-time model.
    pub fn preset(dataset: &str) -> Self {
        // (n_clients, per_round, rounds, base_train_s)
        let (n, k, rounds, base) = match dataset {
            // paper: 300 clients, 200/round, 60 rounds, ~40 s rounds
            "mnist" => (60, 12, 20, 25.0),
            // paper: 300 clients, 175/round, 40 rounds
            "femnist" => (50, 10, 15, 45.0),
            // paper: 100 clients, 50/round, 25 rounds, ~8.7 min rounds
            "shakespeare" => (30, 8, 12, 90.0),
            // paper: 542 clients, 200/round, 35/60 rounds
            "speech" => (60, 15, 20, 28.0),
            // e2e driver (not in the paper)
            "transformer" => (40, 10, 30, 20.0),
            other => panic!("no preset for dataset {other:?}"),
        };
        Self {
            dataset: dataset.to_string(),
            strategy: StrategyKind::Fedlesscan,
            scenario: Scenario::Standard,
            n_clients: n,
            clients_per_round: k,
            rounds,
            seed: 42,
            eval_every: 1,
            partition: Partition::LabelShard,
            faas: FaasConfig::default(),
            base_train_s: base,
            round_timeout_standard_s: base * 3.0 + 20.0,
            round_timeout_straggler_s: base * 2.0 + 10.0,
            straggler_slow_frac: 0.5,
            artifacts_dir: PathBuf::from("artifacts"),
            history_path: None,
            verbose: false,
            adaptive_clients: false,
            stale_norm_clip: None,
            mode: Mode::Rounds,
            inflight_cohorts: 2,
            async_alpha: 0.5,
            workers: None,
            shards: None,
            kernel: None,
            quantize_updates: false,
            quantize_topk: None,
        }
    }

    /// All datasets with presets (the paper's four + the e2e driver).
    pub fn preset_datasets() -> [&'static str; 4] {
        ["mnist", "femnist", "shakespeare", "speech"]
    }

    /// The active round deadline for the configured scenario.
    pub fn round_timeout_s(&self) -> f64 {
        if self.scenario.tight_deadline() {
            self.round_timeout_straggler_s
        } else {
            self.round_timeout_standard_s
        }
    }

    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.n_clients > 0, "n_clients must be positive");
        anyhow::ensure!(
            self.clients_per_round > 0 && self.clients_per_round <= self.n_clients,
            "clients_per_round must be in [1, n_clients]"
        );
        anyhow::ensure!(self.rounds > 0, "rounds must be positive");
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.straggler_slow_frac),
            "straggler_slow_frac must be a fraction"
        );
        anyhow::ensure!(self.base_train_s > 0.0, "base_train_s must be positive");
        anyhow::ensure!(
            self.inflight_cohorts >= 1,
            "inflight_cohorts must be at least 1"
        );
        anyhow::ensure!(
            self.async_alpha > 0.0 && self.async_alpha <= 1.0,
            "async_alpha must be in (0, 1]"
        );
        if let Some(w) = self.workers {
            anyhow::ensure!(w >= 1, "workers must be at least 1 when set");
        }
        if let Some(s) = self.shards {
            anyhow::ensure!(s >= 1, "shards must be at least 1 when set");
        }
        // Rejects unknown kernel names; availability is checked at
        // install time (a config written on an AVX2 host stays loadable
        // elsewhere — it just refuses to run there).
        crate::runtime::kernel::kernel_override(self.kernel.as_deref())?;
        if let Some(f) = self.quantize_topk {
            anyhow::ensure!(
                f > 0.0 && f <= 1.0,
                "quantize_topk must be a fraction in (0, 1]"
            );
            anyhow::ensure!(
                self.quantize_updates,
                "quantize_topk requires quantize_updates"
            );
        }
        Ok(())
    }

    /// Serialize to JSON (the config file format; the FaaS platform block
    /// is included in full so experiments are self-describing).
    pub fn to_json(&self) -> Json {
        let f = &self.faas;
        let partition = match self.partition {
            Partition::LabelShard => Json::str("label_shard"),
            Partition::Iid => Json::str("iid"),
            Partition::Dirichlet(a) => Json::obj(vec![("dirichlet", Json::num(a))]),
        };
        Json::obj(vec![
            ("dataset", Json::str(self.dataset.clone())),
            ("strategy", Json::str(self.strategy.as_str())),
            ("scenario", Json::str(self.scenario.label())),
            ("n_clients", Json::num(self.n_clients as f64)),
            ("clients_per_round", Json::num(self.clients_per_round as f64)),
            ("rounds", Json::num(self.rounds as f64)),
            ("seed", Json::num(self.seed as f64)),
            ("eval_every", Json::num(self.eval_every as f64)),
            ("partition", partition),
            (
                "faas",
                Json::obj(vec![
                    ("cold_start_median_s", Json::num(f.cold_start_median_s)),
                    ("cold_start_sigma", Json::num(f.cold_start_sigma)),
                    ("warm_overhead_s", Json::num(f.warm_overhead_s)),
                    ("idle_timeout_s", Json::num(f.idle_timeout_s)),
                    ("client_speed_sigma", Json::num(f.client_speed_sigma)),
                    ("invocation_jitter_sigma", Json::num(f.invocation_jitter_sigma)),
                    ("transient_failure_rate", Json::num(f.transient_failure_rate)),
                    ("memory_mb", Json::num(f.memory_mb as f64)),
                    ("network_mbps", Json::num(f.network_mbps)),
                    ("function_timeout_s", Json::num(f.function_timeout_s)),
                ]),
            ),
            ("base_train_s", Json::num(self.base_train_s)),
            ("round_timeout_standard_s", Json::num(self.round_timeout_standard_s)),
            ("round_timeout_straggler_s", Json::num(self.round_timeout_straggler_s)),
            ("straggler_slow_frac", Json::num(self.straggler_slow_frac)),
            (
                "artifacts_dir",
                Json::str(self.artifacts_dir.display().to_string()),
            ),
            (
                "history_path",
                self.history_path
                    .as_ref()
                    .map_or(Json::Null, |p| Json::str(p.display().to_string())),
            ),
            ("verbose", Json::Bool(self.verbose)),
            ("adaptive_clients", Json::Bool(self.adaptive_clients)),
            (
                "stale_norm_clip",
                self.stale_norm_clip.map_or(Json::Null, Json::Num),
            ),
            ("mode", Json::str(self.mode.as_str())),
            ("inflight_cohorts", Json::num(self.inflight_cohorts as f64)),
            ("async_alpha", Json::num(self.async_alpha)),
            (
                "workers",
                self.workers.map_or(Json::Null, |w| Json::num(w as f64)),
            ),
            (
                "shards",
                self.shards.map_or(Json::Null, |s| Json::num(s as f64)),
            ),
            (
                "kernel",
                self.kernel
                    .as_ref()
                    .map_or(Json::Null, |k| Json::str(k.clone())),
            ),
            ("quantize_updates", Json::Bool(self.quantize_updates)),
            (
                "quantize_topk",
                self.quantize_topk.map_or(Json::Null, Json::Num),
            ),
        ])
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        self.to_json().write_file(path)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        // Start from the dataset preset so configs may be sparse.
        let dataset = j.get("dataset")?.as_str()?.to_string();
        let mut cfg = ExperimentConfig::preset(&dataset);
        if let Some(v) = j.get_opt("strategy") {
            cfg.strategy = StrategyKind::from_str(v.as_str()?)?;
        }
        if let Some(v) = j.get_opt("scenario") {
            cfg.scenario = Scenario::from_str(v.as_str()?)?;
        }
        if let Some(v) = j.get_opt("n_clients") {
            cfg.n_clients = v.as_usize()?;
        }
        if let Some(v) = j.get_opt("clients_per_round") {
            cfg.clients_per_round = v.as_usize()?;
        }
        if let Some(v) = j.get_opt("rounds") {
            cfg.rounds = v.as_u64()? as u32;
        }
        if let Some(v) = j.get_opt("seed") {
            cfg.seed = v.as_u64()?;
        }
        if let Some(v) = j.get_opt("eval_every") {
            cfg.eval_every = (v.as_u64()? as u32).max(1);
        }
        if let Some(v) = j.get_opt("partition") {
            cfg.partition = match v {
                Json::Str(s) if s == "label_shard" => Partition::LabelShard,
                Json::Str(s) if s == "iid" => Partition::Iid,
                Json::Obj(_) => Partition::Dirichlet(v.get("dirichlet")?.as_f64()?),
                other => anyhow::bail!("bad partition {other:?}"),
            };
        }
        if let Some(v) = j.get_opt("faas") {
            let g = |k: &str, d: f64| -> Result<f64> {
                Ok(v.get_opt(k).map(|x| x.as_f64()).transpose()?.unwrap_or(d))
            };
            let dflt = FaasConfig::default();
            cfg.faas = FaasConfig {
                cold_start_median_s: g("cold_start_median_s", dflt.cold_start_median_s)?,
                cold_start_sigma: g("cold_start_sigma", dflt.cold_start_sigma)?,
                warm_overhead_s: g("warm_overhead_s", dflt.warm_overhead_s)?,
                idle_timeout_s: g("idle_timeout_s", dflt.idle_timeout_s)?,
                client_speed_sigma: g("client_speed_sigma", dflt.client_speed_sigma)?,
                invocation_jitter_sigma: g(
                    "invocation_jitter_sigma",
                    dflt.invocation_jitter_sigma,
                )?,
                transient_failure_rate: g(
                    "transient_failure_rate",
                    dflt.transient_failure_rate,
                )?,
                memory_mb: g("memory_mb", dflt.memory_mb as f64)? as u32,
                network_mbps: g("network_mbps", dflt.network_mbps)?,
                function_timeout_s: g("function_timeout_s", dflt.function_timeout_s)?,
            };
        }
        if let Some(v) = j.get_opt("base_train_s") {
            cfg.base_train_s = v.as_f64()?;
        }
        if let Some(v) = j.get_opt("round_timeout_standard_s") {
            cfg.round_timeout_standard_s = v.as_f64()?;
        }
        if let Some(v) = j.get_opt("round_timeout_straggler_s") {
            cfg.round_timeout_straggler_s = v.as_f64()?;
        }
        if let Some(v) = j.get_opt("straggler_slow_frac") {
            cfg.straggler_slow_frac = v.as_f64()?;
        }
        if let Some(v) = j.get_opt("artifacts_dir") {
            cfg.artifacts_dir = PathBuf::from(v.as_str()?);
        }
        if let Some(v) = j.get_opt("history_path") {
            if !v.is_null() {
                cfg.history_path = Some(PathBuf::from(v.as_str()?));
            }
        }
        if let Some(v) = j.get_opt("verbose") {
            cfg.verbose = v.as_bool()?;
        }
        if let Some(v) = j.get_opt("adaptive_clients") {
            cfg.adaptive_clients = v.as_bool()?;
        }
        if let Some(v) = j.get_opt("stale_norm_clip") {
            if !v.is_null() {
                cfg.stale_norm_clip = Some(v.as_f64()?);
            }
        }
        if let Some(v) = j.get_opt("mode") {
            cfg.mode = Mode::from_str(v.as_str()?)?;
        }
        if let Some(v) = j.get_opt("inflight_cohorts") {
            cfg.inflight_cohorts = v.as_usize()?;
        }
        if let Some(v) = j.get_opt("async_alpha") {
            cfg.async_alpha = v.as_f64()?;
        }
        if let Some(v) = j.get_opt("workers") {
            if !v.is_null() {
                cfg.workers = Some(v.as_usize()?);
            }
        }
        if let Some(v) = j.get_opt("shards") {
            if !v.is_null() {
                cfg.shards = Some(v.as_usize()?);
            }
        }
        if let Some(v) = j.get_opt("kernel") {
            if !v.is_null() {
                cfg.kernel = Some(v.as_str()?.to_string());
            }
        }
        if let Some(v) = j.get_opt("quantize_updates") {
            cfg.quantize_updates = v.as_bool()?;
        }
        if let Some(v) = j.get_opt("quantize_topk") {
            if !v.is_null() {
                cfg.quantize_topk = Some(v.as_f64()?);
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn load(path: &Path) -> Result<Self> {
        Self::from_json(&Json::parse_file(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate() {
        for d in ExperimentConfig::preset_datasets() {
            ExperimentConfig::preset(d).validate().unwrap();
        }
        ExperimentConfig::preset("transformer").validate().unwrap();
    }

    #[test]
    fn preset_rounds_fit_the_history_window() {
        // The bounded client history documents full-series-exact feature
        // folds for every in-repo experiment; the repro harness inflates
        // preset rounds by 5/3 for its convergence runs, so that
        // inflated count is the bound that must stay under the window.
        // If a preset grows past this, grow clientdb::HISTORY_WINDOW
        // with it (the exactness claim rots silently otherwise).
        for d in ["mnist", "femnist", "shakespeare", "speech", "transformer"] {
            let inflated = ExperimentConfig::preset(d).rounds * 5 / 3;
            assert!(
                (inflated as usize) <= crate::clientdb::HISTORY_WINDOW,
                "{d}: {inflated} inflated rounds exceed HISTORY_WINDOW"
            );
        }
    }

    #[test]
    fn scenario_labels() {
        assert_eq!(Scenario::Standard.label(), "standard");
        assert_eq!(Scenario::Straggler(30).label(), "straggler30");
        assert_eq!(Scenario::Straggler(30).straggler_fraction(), 0.3);
    }

    #[test]
    fn straggler_timeout_is_tighter() {
        let mut cfg = ExperimentConfig::preset("mnist");
        let std_t = cfg.round_timeout_s();
        cfg.scenario = Scenario::Straggler(30);
        assert!(cfg.round_timeout_s() < std_t);
    }

    #[test]
    fn json_roundtrip() {
        let mut cfg = ExperimentConfig::preset("speech");
        cfg.scenario = Scenario::Straggler(30);
        cfg.partition = Partition::Dirichlet(0.3);
        cfg.rounds = 7;
        let p = std::env::temp_dir().join(format!("fedless-cfg-{}.json", std::process::id()));
        cfg.save(&p).unwrap();
        let cfg2 = ExperimentConfig::load(&p).unwrap();
        assert_eq!(cfg.dataset, cfg2.dataset);
        assert_eq!(cfg.rounds, cfg2.rounds);
        assert_eq!(cfg.scenario, cfg2.scenario);
        assert_eq!(cfg.partition, cfg2.partition);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn mode_roundtrip_and_validation() {
        assert_eq!(Mode::from_str("rounds").unwrap(), Mode::Rounds);
        assert_eq!(Mode::from_str("continuous").unwrap(), Mode::Continuous);
        assert_eq!(Mode::from_str("cont").unwrap(), Mode::Continuous);
        assert!(Mode::from_str("async").is_err());

        let mut cfg = ExperimentConfig::preset("mnist");
        assert_eq!(cfg.mode, Mode::Rounds);
        cfg.mode = Mode::Continuous;
        cfg.inflight_cohorts = 3;
        cfg.async_alpha = 0.25;
        cfg.workers = Some(4);
        cfg.validate().unwrap();
        let p = std::env::temp_dir().join(format!(
            "fedless-cfg-mode-{}.json",
            std::process::id()
        ));
        cfg.save(&p).unwrap();
        let cfg2 = ExperimentConfig::load(&p).unwrap();
        assert_eq!(cfg2.mode, Mode::Continuous);
        assert_eq!(cfg2.inflight_cohorts, 3);
        assert_eq!(cfg2.async_alpha, 0.25);
        assert_eq!(cfg2.workers, Some(4));
        std::fs::remove_file(&p).ok();

        cfg.inflight_cohorts = 0;
        assert!(cfg.validate().is_err());
        cfg.inflight_cohorts = 2;
        cfg.async_alpha = 0.0;
        assert!(cfg.validate().is_err());
        cfg.async_alpha = 1.5;
        assert!(cfg.validate().is_err());
        cfg.async_alpha = 0.5;
        cfg.workers = Some(0);
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn shard_and_quantization_fields_roundtrip_and_validate() {
        let mut cfg = ExperimentConfig::preset("mnist");
        assert_eq!(cfg.shards, None, "presets default to unsharded-choice");
        assert!(!cfg.quantize_updates, "quantization defaults off");
        cfg.shards = Some(8);
        cfg.quantize_updates = true;
        cfg.quantize_topk = Some(0.1);
        cfg.validate().unwrap();
        let p = std::env::temp_dir().join(format!(
            "fedless-cfg-quant-{}.json",
            std::process::id()
        ));
        cfg.save(&p).unwrap();
        let cfg2 = ExperimentConfig::load(&p).unwrap();
        assert_eq!(cfg2.shards, Some(8));
        assert!(cfg2.quantize_updates);
        assert_eq!(cfg2.quantize_topk, Some(0.1));
        std::fs::remove_file(&p).ok();

        cfg.shards = Some(0);
        assert!(cfg.validate().is_err(), "zero shards rejected");
        cfg.shards = None;
        cfg.quantize_topk = Some(1.5);
        assert!(cfg.validate().is_err(), "topk fraction out of range");
        cfg.quantize_topk = Some(0.1);
        cfg.quantize_updates = false;
        assert!(cfg.validate().is_err(), "topk requires quantize_updates");
    }

    #[test]
    fn kernel_field_roundtrips_and_rejects_unknown_names() {
        let mut cfg = ExperimentConfig::preset("mnist");
        assert_eq!(cfg.kernel, None, "presets default to auto-detect");
        cfg.kernel = Some("scalar".into());
        cfg.validate().unwrap();
        let p = std::env::temp_dir().join(format!(
            "fedless-cfg-kernel-{}.json",
            std::process::id()
        ));
        cfg.save(&p).unwrap();
        let cfg2 = ExperimentConfig::load(&p).unwrap();
        assert_eq!(cfg2.kernel, Some("scalar".into()));
        std::fs::remove_file(&p).ok();

        // avx2 is a valid *name* even off-host: validate accepts it,
        // only kernel::install refuses when the CPU can't run it.
        cfg.kernel = Some("AVX2".into());
        cfg.validate().unwrap();
        cfg.kernel = Some("sse9".into());
        assert!(cfg.validate().is_err(), "unknown kernel name rejected");
    }

    #[test]
    fn scenario_from_str() {
        use std::str::FromStr;
        assert_eq!(Scenario::from_str("standard").unwrap(), Scenario::Standard);
        assert_eq!(
            Scenario::from_str("straggler30").unwrap(),
            Scenario::Straggler(30)
        );
        assert!(Scenario::from_str("nope").is_err());
    }

    #[test]
    fn grid_scenarios_roundtrip_label_fromstr() {
        use std::str::FromStr;
        for s in [
            Scenario::Standard,
            Scenario::Straggler(10),
            Scenario::Straggler(70),
            Scenario::ColdStartStorm,
            Scenario::Diurnal,
            Scenario::RegionalOutage,
            Scenario::Adversarial,
        ] {
            assert_eq!(Scenario::from_str(&s.label()).unwrap(), s);
        }
    }

    #[test]
    fn grid_scenarios_force_no_stragglers_and_pick_the_right_deadline() {
        let mut cfg = ExperimentConfig::preset("mnist");
        for s in [
            Scenario::ColdStartStorm,
            Scenario::Diurnal,
            Scenario::RegionalOutage,
        ] {
            assert_eq!(s.straggler_fraction(), 0.0);
            cfg.scenario = s;
            assert_eq!(cfg.round_timeout_s(), cfg.round_timeout_standard_s);
        }
        // Adversarial: no forced straggler roles, but the tight deadline
        // so the slow tail actually misses rounds.
        assert_eq!(Scenario::Adversarial.straggler_fraction(), 0.0);
        cfg.scenario = Scenario::Adversarial;
        assert_eq!(cfg.round_timeout_s(), cfg.round_timeout_straggler_s);
    }

    #[test]
    #[should_panic]
    fn unknown_preset_panics() {
        ExperimentConfig::preset("imagenet");
    }
}
