//! Reproduction harness: regenerates every table and figure of the
//! paper's evaluation (§VI) — see DESIGN.md §4 for the experiment index.
//!
//! | id    | paper artifact | regenerator            |
//! |-------|----------------|------------------------|
//! | FIG1  | Fig. 1         | [`fig1`]               |
//! | TAB2  | Table II       | [`table2`]             |
//! | TAB3  | Table III      | [`table3`]             |
//! | TAB4  | Table IV       | [`table4`]             |
//! | FIG3a | Fig. 3a        | [`fig3`] (accuracy)    |
//! | FIG3b | Fig. 3b        | [`fig3`] (EUR)         |
//! | FIG3c | Fig. 3c        | [`fig3`] (bias/violin) |
//! | ABL   | (ours)         | [`ablations`]          |
//!
//! Absolute numbers differ from the paper (simulated GCF testbed,
//! synthetic data, scaled deployment — DESIGN.md §2); the harness is
//! judged on the *shape*: who wins, by roughly what factor, where the
//! crossovers fall. Results land as CSV/JSON under the output directory
//! and as aligned text tables on stdout.

use std::collections::BTreeMap;
use std::path::PathBuf;

use crate::config::{ExperimentConfig, Scenario};
use crate::coordinator::Controller;
use crate::metrics::ExperimentResult;
use crate::runtime::{load_backend, Backend, BackendKind};
use crate::strategy::StrategyKind;
use crate::util::Json;
use crate::Result;

/// Effort profile for a harness invocation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Profile {
    /// Minutes-fast: fewer rounds/clients, single repeat. The profile
    /// used for the checked-in EXPERIMENTS.md runs.
    Quick,
    /// The full default-scale matrix (hours on CPU).
    Full,
}

impl std::str::FromStr for Profile {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> std::result::Result<Self, Self::Err> {
        match s {
            "quick" => Ok(Profile::Quick),
            "full" => Ok(Profile::Full),
            other => anyhow::bail!("unknown profile {other:?}; expected quick|full"),
        }
    }
}

/// Shared harness options.
#[derive(Debug, Clone)]
pub struct Options {
    pub artifacts_dir: PathBuf,
    pub out_dir: PathBuf,
    pub datasets: Vec<String>,
    pub profile: Profile,
    pub seed: u64,
    /// Repeats per cell; the paper uses 3 (§VI, [68]).
    pub repeats: usize,
    pub verbose: bool,
    /// Execution backend for every cell (native unless overridden).
    pub backend: BackendKind,
}

impl Options {
    pub fn scenarios(&self) -> Vec<Scenario> {
        match self.profile {
            Profile::Quick => vec![
                Scenario::Standard,
                Scenario::Straggler(30),
                Scenario::Straggler(70),
            ],
            Profile::Full => vec![
                Scenario::Standard,
                Scenario::Straggler(10),
                Scenario::Straggler(30),
                Scenario::Straggler(50),
                Scenario::Straggler(70),
            ],
        }
    }

    /// Scenario axis for the adversarial grid sweep ([`sweep`]): the
    /// paper's straggler fractions plus the platform-stress scenarios.
    /// Both profiles clear the ≥ 6-scenario bar the committed
    /// `BENCH_matrix.json` tracks.
    pub fn grid_scenarios(&self) -> Vec<Scenario> {
        let mut v = self.scenarios();
        v.extend([
            Scenario::ColdStartStorm,
            Scenario::Diurnal,
            Scenario::RegionalOutage,
            Scenario::Adversarial,
        ]);
        v
    }

    fn shrink(&self, cfg: &mut ExperimentConfig) {
        if self.profile == Profile::Quick {
            // This testbed is a single CPU core; the quick profile keeps
            // the full matrix *shape* at ~1/4 the paper-preset volume.
            cfg.rounds = (cfg.rounds / 4).max(5);
            cfg.n_clients = (cfg.n_clients / 3).max(10);
            cfg.clients_per_round = (cfg.clients_per_round / 3).max(3);
            cfg.eval_every = 2;
        }
    }
}

/// Cache of loaded execution backends (built / compiled once per dataset).
pub struct Backends {
    kind: BackendKind,
    map: BTreeMap<String, Box<dyn Backend>>,
    dir: PathBuf,
}

impl Backends {
    pub fn new(kind: BackendKind, artifacts_dir: PathBuf) -> Result<Self> {
        Ok(Self {
            kind,
            map: BTreeMap::new(),
            dir: artifacts_dir,
        })
    }

    pub fn get(&mut self, dataset: &str) -> Result<&dyn Backend> {
        if !self.map.contains_key(dataset) {
            let b = load_backend(self.kind, &self.dir, dataset)?;
            self.map.insert(dataset.to_string(), b);
        }
        Ok(self.map[dataset].as_ref())
    }
}

/// Run one experiment cell (dataset x strategy x scenario), averaging
/// `repeats` seeds. Returns all repeat results.
pub fn run_cell(
    backends: &mut Backends,
    opts: &Options,
    dataset: &str,
    strategy: StrategyKind,
    scenario: Scenario,
) -> Result<Vec<ExperimentResult>> {
    let mut results = Vec::with_capacity(opts.repeats);
    for rep in 0..opts.repeats {
        let mut cfg = ExperimentConfig::preset(dataset);
        cfg.artifacts_dir = opts.artifacts_dir.clone();
        cfg.strategy = strategy;
        cfg.scenario = scenario;
        cfg.seed = opts.seed + rep as u64 * 1000;
        cfg.verbose = opts.verbose;
        opts.shrink(&mut cfg);
        // paper Table I: speech trains longer under straggler scenarios
        if dataset == "speech" && scenario != Scenario::Standard {
            cfg.rounds = cfg.rounds * 5 / 3;
        }
        let backend = backends.get(dataset)?;
        let mut ctl = Controller::new(cfg, backend)?;
        results.push(ctl.run()?);
    }
    Ok(results)
}

fn mean<T: Copy + Into<f64>>(xs: impl Iterator<Item = T>) -> f64 {
    let v: Vec<f64> = xs.map(Into::into).collect();
    if v.is_empty() {
        0.0
    } else {
        v.iter().sum::<f64>() / v.len() as f64
    }
}

/// Aggregated cell statistics used by the table printers.
#[derive(Debug, Clone)]
pub struct CellStats {
    pub dataset: String,
    pub strategy: String,
    pub scenario: String,
    pub accuracy: f64,
    pub eur: f64,
    pub time_s: f64,
    pub cost: f64,
    pub bias: f64,
    /// Mean stale updates folded in per experiment (semi-async depth).
    pub stale_applied: f64,
    /// Mean in-flight skips per experiment (scheduler back-pressure).
    pub in_flight_skipped: f64,
    pub repeats: usize,
}

impl CellStats {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("dataset", Json::str(self.dataset.clone())),
            ("strategy", Json::str(self.strategy.clone())),
            ("scenario", Json::str(self.scenario.clone())),
            ("accuracy", Json::num(self.accuracy)),
            ("eur", Json::num(self.eur)),
            ("time_s", Json::num(self.time_s)),
            ("cost", Json::num(self.cost)),
            ("bias", Json::num(self.bias)),
            ("stale_applied", Json::num(self.stale_applied)),
            ("in_flight_skipped", Json::num(self.in_flight_skipped)),
            ("repeats", Json::num(self.repeats as f64)),
        ])
    }
}

pub fn cell_stats(results: &[ExperimentResult], n_clients: usize) -> CellStats {
    CellStats {
        dataset: results[0].dataset.clone(),
        strategy: results[0].strategy.clone(),
        scenario: results[0].scenario.clone(),
        accuracy: mean(results.iter().map(|r| r.final_accuracy)),
        eur: mean(results.iter().map(|r| r.mean_eur())),
        time_s: mean(results.iter().map(|r| r.total_time_s)),
        cost: mean(results.iter().map(|r| r.total_cost)),
        bias: mean(results.iter().map(|r| r.bias(n_clients) as f64)),
        stale_applied: mean(
            results
                .iter()
                .map(|r| r.rounds.iter().map(|x| x.stale_applied).sum::<usize>() as f64),
        ),
        in_flight_skipped: mean(
            results
                .iter()
                .map(|r| r.rounds.iter().map(|x| x.in_flight_skipped).sum::<usize>() as f64),
        ),
        repeats: results.len(),
    }
}

/// Run the full (datasets x strategies x scenarios) matrix once and
/// reuse it for Tables II-IV (they share the same underlying runs, as in
/// the paper).
pub fn run_matrix(opts: &Options) -> Result<Vec<CellStats>> {
    let mut backends = Backends::new(opts.backend, opts.artifacts_dir.clone())?;
    std::fs::create_dir_all(&opts.out_dir)?;
    let mut cells = Vec::new();
    for dataset in &opts.datasets {
        for strategy in StrategyKind::evaluated() {
            for scenario in opts.scenarios() {
                eprintln!(
                    "[matrix] {dataset} / {} / {} ...",
                    strategy.as_str(),
                    scenario.label()
                );
                let results = run_cell(&mut backends, opts, dataset, strategy, scenario)?;
                // persist per-run timelines for the figure harness
                for (i, r) in results.iter().enumerate() {
                    let base = format!(
                        "{}_{}_{}_{i}",
                        dataset,
                        strategy.as_str(),
                        scenario.label()
                    );
                    r.write_timeline_csv(&opts.out_dir.join(format!("{base}.csv")))?;
                    r.write_json(&opts.out_dir.join(format!("{base}.json")))?;
                }
                let n_clients = effective_n_clients(opts, dataset);
                cells.push(cell_stats(&results, n_clients));
            }
        }
    }
    let path = opts.out_dir.join("matrix.json");
    Json::Arr(cells.iter().map(|c| c.to_json()).collect()).write_file(&path)?;
    eprintln!("[matrix] wrote {}", path.display());
    Ok(cells)
}

fn effective_n_clients(opts: &Options, dataset: &str) -> usize {
    let mut cfg = ExperimentConfig::preset(dataset);
    opts.shrink(&mut cfg);
    cfg.n_clients
}

// ---------------------------------------------------------------------------
// FIG1 — motivation: FedAvg accuracy + round duration vs straggler %
// ---------------------------------------------------------------------------

pub fn fig1(opts: &Options) -> Result<()> {
    let mut backends = Backends::new(opts.backend, opts.artifacts_dir.clone())?;
    std::fs::create_dir_all(&opts.out_dir)?;
    // Fig. 1 / Fig. 3 are speech-dataset deep dives in the paper.
    let dataset = opts
        .datasets
        .iter()
        .find(|d| d.as_str() == "speech")
        .or_else(|| opts.datasets.first())
        .cloned()
        .unwrap_or_else(|| "speech".to_string());
    println!("FIG 1 — {dataset} with FedAvg, varying straggler % (paper Fig. 1)");
    println!("{:<12} {:>10} {:>18}", "stragglers", "accuracy", "avg round (s)");
    let mut rows = Vec::new();
    let mut scenarios = vec![Scenario::Standard];
    scenarios.extend(opts.scenarios().into_iter().skip(1));
    for scenario in scenarios {
        let results = run_cell(&mut backends, opts, &dataset, StrategyKind::Fedavg, scenario)?;
        let acc = mean(results.iter().map(|r| r.final_accuracy));
        let avg_round = mean(results.iter().map(|r| {
            r.total_time_s / r.rounds.len().max(1) as f64
        }));
        println!("{:<12} {:>10.3} {:>18.1}", scenario.label(), acc, avg_round);
        rows.push((scenario.label(), acc, avg_round));
    }
    let csv: String = std::iter::once("scenario,accuracy,avg_round_s".to_string())
        .chain(rows.iter().map(|(s, a, d)| format!("{s},{a:.4},{d:.2}")))
        .collect::<Vec<_>>()
        .join("\n");
    std::fs::write(opts.out_dir.join("fig1.csv"), csv)?;
    Ok(())
}

// ---------------------------------------------------------------------------
// TAB2/3/4 — shared matrix, three views
// ---------------------------------------------------------------------------

fn print_table(
    cells: &[CellStats],
    title: &str,
    header: &str,
    value: impl Fn(&CellStats) -> String,
) {
    println!("\n{title}");
    let mut scenarios: Vec<String> = cells.iter().map(|c| c.scenario.clone()).collect();
    scenarios.sort();
    scenarios.dedup();
    print!("{:<14}{:<12}", "dataset", "strategy");
    for s in &scenarios {
        print!("{s:>14}");
    }
    println!("   ({header})");
    let mut datasets: Vec<String> = cells.iter().map(|c| c.dataset.clone()).collect();
    datasets.dedup();
    let mut strategies: Vec<String> = cells.iter().map(|c| c.strategy.clone()).collect();
    strategies.sort();
    strategies.dedup();
    for d in &datasets {
        for st in &strategies {
            print!("{d:<14}{st:<12}");
            for sc in &scenarios {
                let cell = cells
                    .iter()
                    .find(|c| &c.dataset == d && &c.strategy == st && &c.scenario == sc);
                match cell {
                    Some(c) => print!("{:>14}", value(c)),
                    None => print!("{:>14}", "-"),
                }
            }
            println!();
        }
    }
}

pub fn table2(cells: &[CellStats]) {
    print_table(
        cells,
        "TABLE II — accuracy and EUR (paper Table II)",
        "acc / eur",
        |c| format!("{:.3}/{:.2}", c.accuracy, c.eur),
    );
}

pub fn table3(cells: &[CellStats]) {
    print_table(
        cells,
        "TABLE III — total experiment time, minutes (paper Table III)",
        "minutes",
        |c| format!("{:.1}", c.time_s / 60.0),
    );
}

pub fn table4(cells: &[CellStats]) {
    print_table(
        cells,
        "TABLE IV — total experiment cost, $ (paper Table IV)",
        "$",
        |c| format!("{:.4}", c.cost),
    );
}

// ---------------------------------------------------------------------------
// SWEEP — strategy zoo x adversarial scenario grid
// ---------------------------------------------------------------------------

/// Run the full strategy-zoo × scenario-grid matrix (every evaluated
/// strategy plus the ablation set, across [`Options::grid_scenarios`])
/// and write the per-cell time/cost/EUR/bias stats to
/// `<out_dir>/matrix.json`. This is the generator behind the committed
/// `BENCH_matrix.json` trajectory file; `only_scenario` restricts the
/// grid to one scenario (the CI smoke runs the zoo against
/// `adversarial` alone).
pub fn sweep(opts: &Options, only_scenario: Option<Scenario>) -> Result<Vec<CellStats>> {
    let mut backends = Backends::new(opts.backend, opts.artifacts_dir.clone())?;
    std::fs::create_dir_all(&opts.out_dir)?;
    let scenarios = match only_scenario {
        Some(s) => vec![s],
        None => opts.grid_scenarios(),
    };
    let mut cells = Vec::new();
    for dataset in &opts.datasets {
        let n_clients = effective_n_clients(opts, dataset);
        for strategy in StrategyKind::evaluated()
            .into_iter()
            .chain(StrategyKind::ablation())
        {
            for &scenario in &scenarios {
                eprintln!(
                    "[sweep] {dataset} / {} / {} ...",
                    strategy.as_str(),
                    scenario.label()
                );
                let results = run_cell(&mut backends, opts, dataset, strategy, scenario)?;
                cells.push(cell_stats(&results, n_clients));
            }
        }
    }
    print_table(
        &cells,
        "SWEEP — strategy zoo x scenario grid (time min / $ / EUR / bias)",
        "min/$/eur/bias",
        |c| {
            format!(
                "{:.0}/{:.3}/{:.2}/{:.0}",
                c.time_s / 60.0,
                c.cost,
                c.eur,
                c.bias
            )
        },
    );
    let path = opts.out_dir.join("matrix.json");
    Json::Arr(cells.iter().map(|c| c.to_json()).collect()).write_file(&path)?;
    eprintln!("[sweep] wrote {} ({} cells)", path.display(), cells.len());
    Ok(cells)
}

/// Median of a sorted invocation distribution; 0 for the degenerate
/// empty cell (zero-client/zero-round grid corners must print, not
/// panic — mirrors the `first()/last().unwrap_or(0)` neighbors).
fn dist_median(dist: &[u32]) -> u32 {
    dist.get(dist.len() / 2).copied().unwrap_or(0)
}

// ---------------------------------------------------------------------------
// FIG3 — speech deep-dive: accuracy / EUR timelines + bias distribution
// ---------------------------------------------------------------------------

pub fn fig3(opts: &Options) -> Result<()> {
    let mut backends = Backends::new(opts.backend, opts.artifacts_dir.clone())?;
    std::fs::create_dir_all(&opts.out_dir)?;
    // Fig. 1 / Fig. 3 are speech-dataset deep dives in the paper.
    let dataset = opts
        .datasets
        .iter()
        .find(|d| d.as_str() == "speech")
        .or_else(|| opts.datasets.first())
        .cloned()
        .unwrap_or_else(|| "speech".to_string());
    let n_clients = effective_n_clients(opts, &dataset);
    println!("FIG 3 — {dataset}: per-round accuracy (3a), EUR (3b), bias (3c)");
    for scenario in opts.scenarios() {
        println!("\n== scenario {} ==", scenario.label());
        println!(
            "{:<12} {:>9} {:>9} {:>7} {:>22}",
            "strategy", "final acc", "mean EUR", "bias", "invocations (min/med/max)"
        );
        // Evaluated zoo *plus* the ablation set: the Fig. 3c bias panel
        // exists precisely to contrast FedLesScan against SAFA-lite's
        // high bias, so the ablation strategies run here too.
        for strategy in StrategyKind::evaluated()
            .into_iter()
            .chain(StrategyKind::ablation())
        {
            let results = run_cell(&mut backends, opts, &dataset, strategy, scenario)?;
            let r = &results[0];
            // fig3a/b: write the full timeline of the first repeat
            let base = format!("fig3_{}_{}_{}", dataset, strategy.as_str(), scenario.label());
            r.write_timeline_csv(&opts.out_dir.join(format!("{base}.csv")))?;
            // fig3c: invocation distribution (violin input)
            let mut dist = r.invocation_distribution(n_clients);
            dist.sort_unstable();
            let dist_csv: String = std::iter::once("client_rank,invocations".to_string())
                .chain(dist.iter().enumerate().map(|(i, v)| format!("{i},{v}")))
                .collect::<Vec<_>>()
                .join("\n");
            std::fs::write(
                opts.out_dir.join(format!("{base}_invocations.csv")),
                dist_csv,
            )?;
            let acc = mean(results.iter().map(|x| x.final_accuracy));
            let eur = mean(results.iter().map(|x| x.mean_eur()));
            let bias = mean(results.iter().map(|x| x.bias(n_clients) as f64));
            let med = dist_median(&dist);
            println!(
                "{:<12} {:>9.3} {:>9.3} {:>7.1} {:>10}/{}/{}",
                strategy.as_str(),
                acc,
                eur,
                bias,
                dist.first().copied().unwrap_or(0),
                med,
                dist.last().copied().unwrap_or(0),
            );
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Ablations (ours): design-choice sensitivity of FedLesScan
// ---------------------------------------------------------------------------

pub fn ablations(opts: &Options) -> Result<()> {
    use crate::strategy::{FedLesScan, FedLesScanParams};

    let mut backends = Backends::new(opts.backend, opts.artifacts_dir.clone())?;
    std::fs::create_dir_all(&opts.out_dir)?;
    // Fig. 1 / Fig. 3 are speech-dataset deep dives in the paper.
    let dataset = opts
        .datasets
        .iter()
        .find(|d| d.as_str() == "speech")
        .or_else(|| opts.datasets.first())
        .cloned()
        .unwrap_or_else(|| "speech".to_string());
    let scenario = Scenario::Straggler(30);
    println!("ABLATIONS — FedLesScan design choices on {dataset} @ {}", scenario.label());
    println!(
        "{:<22} {:>9} {:>9} {:>11} {:>9}",
        "variant", "final acc", "mean EUR", "time (min)", "cost ($)"
    );

    let variants: Vec<(&str, FedLesScanParams)> = vec![
        ("default", FedLesScanParams::default()),
        (
            "tau=1 (no stale)",
            FedLesScanParams {
                tau: 1,
                ..Default::default()
            },
        ),
        (
            "tau=4",
            FedLesScanParams {
                tau: 4,
                ..Default::default()
            },
        ),
        (
            "no-normalize (Eq.3)",
            FedLesScanParams {
                normalize: false,
                ..Default::default()
            },
        ),
        (
            "alpha=0.1",
            FedLesScanParams {
                ema_alpha: 0.1,
                ..Default::default()
            },
        ),
        (
            "alpha=0.9",
            FedLesScanParams {
                ema_alpha: 0.9,
                ..Default::default()
            },
        ),
    ];

    // config-level extension variants (paper §VII future work)
    type CfgMut = fn(&mut ExperimentConfig);
    let cfg_variants: Vec<(&str, CfgMut)> = vec![
        ("ext: adaptive-k", |c| c.adaptive_clients = true),
        ("ext: norm-clip 3x", |c| c.stale_norm_clip = Some(3.0)),
    ];

    let mut rows = Vec::new();
    let runs = variants
        .into_iter()
        .map(|(l, p)| (l, Some(p), None::<CfgMut>))
        .chain(cfg_variants.into_iter().map(|(l, m)| (l, None, Some(m))));
    for (label, params, cfg_mut) in runs {
        let mut cfg = ExperimentConfig::preset(&dataset);
        cfg.artifacts_dir = opts.artifacts_dir.clone();
        cfg.scenario = scenario;
        cfg.seed = opts.seed;
        cfg.verbose = opts.verbose;
        opts.shrink(&mut cfg);
        if let Some(m) = cfg_mut {
            m(&mut cfg);
        }
        let backend = backends.get(&dataset)?;
        let mut ctl = Controller::new(cfg, backend)?;
        if let Some(params) = params {
            ctl.set_strategy(Box::new(FedLesScan::new(params)));
        }
        let r = ctl.run()?;
        println!(
            "{:<22} {:>9.3} {:>9.3} {:>11.1} {:>9.4}",
            label,
            r.final_accuracy,
            r.mean_eur(),
            r.total_time_s / 60.0,
            r.total_cost
        );
        rows.push((label.to_string(), r));
    }
    let json = Json::Arr(
        rows.iter()
            .map(|(l, r)| {
                Json::obj(vec![
                    ("variant", Json::str(l.clone())),
                    ("final_accuracy", Json::num(r.final_accuracy as f64)),
                    ("mean_eur", Json::num(r.mean_eur())),
                    ("total_time_s", Json::num(r.total_time_s)),
                    ("total_cost", Json::num(r.total_cost)),
                ])
            })
            .collect(),
    );
    json.write_file(&opts.out_dir.join("ablations.json"))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dist_median_guards_the_degenerate_cell() {
        // The empty invocation distribution of a zero-client/zero-round
        // grid corner must yield 0, not panic (regression: fig3 indexed
        // `dist[dist.len() / 2]` unguarded).
        assert_eq!(dist_median(&[]), 0);
        assert_eq!(dist_median(&[7]), 7);
        assert_eq!(dist_median(&[1, 2, 3]), 2);
        assert_eq!(dist_median(&[1, 2, 3, 4]), 3);
    }

    #[test]
    fn grid_covers_at_least_six_scenarios_both_profiles() {
        for profile in [Profile::Quick, Profile::Full] {
            let opts = Options {
                artifacts_dir: PathBuf::from("artifacts"),
                out_dir: PathBuf::from("out"),
                datasets: vec!["mnist".into()],
                profile,
                seed: 42,
                repeats: 1,
                verbose: false,
                backend: BackendKind::Native,
            };
            let grid = opts.grid_scenarios();
            assert!(grid.len() >= 6, "{profile:?}: {} scenarios", grid.len());
            for s in [
                Scenario::ColdStartStorm,
                Scenario::Diurnal,
                Scenario::RegionalOutage,
                Scenario::Adversarial,
            ] {
                assert!(grid.contains(&s), "{profile:?} grid missing {}", s.label());
            }
            // labels are unique — each cell keys on (strategy, scenario)
            let mut labels: Vec<String> = grid.iter().map(|s| s.label()).collect();
            labels.sort();
            labels.dedup();
            assert_eq!(labels.len(), grid.len());
        }
    }
}
