//! Deterministic random numbers for the simulator (offline substrate for
//! the `rand`/`rand_distr` crates): xoshiro256** core seeded via
//! SplitMix64, plus the distributions the platform model needs
//! (normal, log-normal, gamma, Bernoulli) and Fisher–Yates shuffling.
//!
//! Every experiment stream is seeded explicitly, so runs are exactly
//! repeatable across machines — a requirement for the paper-reproduction
//! harness.

/// xoshiro256** PRNG (Blackman & Vigna). Passes BigCrush; more than
/// adequate for simulation sampling.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box–Muller deviate
    spare_normal: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e37_79b9_7f4a_7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

impl Rng {
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Self {
            s,
            spare_normal: None,
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform usize in [0, n). n must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free enough for simulation use.
        (self.f64() * n as f64) as usize % n
    }

    /// Uniform i32 in [lo, hi).
    pub fn range_i32(&mut self, lo: i32, hi: i32) -> i32 {
        debug_assert!(hi > lo);
        lo + self.below((hi - lo) as usize) as i32
    }

    /// Uniform f64 in [lo, hi].
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (with spare caching).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        let (u1, u2) = (self.f64().max(1e-300), self.f64());
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Log-normal with the given ln-space mu and sigma.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Gamma(alpha, 1) via Marsaglia–Tsang (with the alpha < 1 boost).
    pub fn gamma(&mut self, alpha: f64) -> f64 {
        if alpha < 1.0 {
            let u: f64 = self.f64().max(1e-300);
            return self.gamma(alpha + 1.0) * u.powf(1.0 / alpha);
        }
        let d = alpha - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u: f64 = self.f64().max(1e-300);
            if u.ln() < 0.5 * x * x + d - d * v + d * v.ln() {
                return d * v;
            }
        }
    }

    /// Fisher–Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// `k` distinct elements sampled uniformly (order randomized).
    pub fn sample<T: Clone>(&mut self, xs: &[T], k: usize) -> Vec<T> {
        let mut pool: Vec<T> = xs.to_vec();
        self.shuffle(&mut pool);
        pool.truncate(k);
        pool
    }

    /// `k` distinct indices drawn uniformly from `0..n`, in O(k) time
    /// and space (sparse partial Fisher–Yates over a virtual identity
    /// array) — the fleet-scale counterpart of [`sample`](Rng::sample),
    /// which clones and fully shuffles its pool even for k ≪ n. The
    /// draw sequence differs from `sample`, so behaviour-pinned call
    /// sites keep the historical path.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        if k >= n {
            let mut pool: Vec<usize> = (0..n).collect();
            self.shuffle(&mut pool);
            return pool;
        }
        // Only the displaced entries of the virtual array are stored.
        let mut swapped: std::collections::HashMap<usize, usize> =
            std::collections::HashMap::with_capacity(k * 2);
        let mut out = Vec::with_capacity(k);
        for i in 0..k {
            let j = i + self.below(n - i);
            let vj = swapped.get(&j).copied().unwrap_or(j);
            let vi = swapped.get(&i).copied().unwrap_or(i);
            swapped.insert(j, vi);
            out.push(vj);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::seed_from_u64(7);
        let mut b = Rng::seed_from_u64(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seed_from_u64(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_bounded_and_covers() {
        let mut r = Rng::seed_from_u64(4);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(5);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn lognormal_median_is_exp_mu() {
        let mut r = Rng::seed_from_u64(6);
        let mut xs: Vec<f64> = (0..20_001).map(|_| r.lognormal(4.0f64.ln(), 0.5)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = xs[10_000];
        assert!((median - 4.0).abs() < 0.2, "median {median}");
        assert!(xs.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn gamma_mean_is_alpha() {
        let mut r = Rng::seed_from_u64(7);
        for &alpha in &[0.5, 1.0, 3.0] {
            let n = 30_000;
            let mean = (0..n).map(|_| r.gamma(alpha)).sum::<f64>() / n as f64;
            assert!((mean - alpha).abs() < 0.1 * alpha.max(0.5), "alpha {alpha} mean {mean}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(8);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_distinct() {
        let mut r = Rng::seed_from_u64(9);
        let xs: Vec<u32> = (0..20).collect();
        let s = r.sample(&xs, 5);
        assert_eq!(s.len(), 5);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 5);
    }

    #[test]
    fn sample_indices_distinct_bounded_and_deterministic() {
        let mut r = Rng::seed_from_u64(10);
        let s = r.sample_indices(10_000, 64);
        assert_eq!(s.len(), 64);
        assert!(s.iter().all(|&i| i < 10_000));
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 64, "duplicates in {s:?}");
        let mut r2 = Rng::seed_from_u64(10);
        assert_eq!(s, r2.sample_indices(10_000, 64));
        // k >= n degenerates to a full permutation
        let mut all = r.sample_indices(7, 99);
        all.sort_unstable();
        assert_eq!(all, (0..7).collect::<Vec<_>>());
        // k = 0 draws nothing
        assert!(r.sample_indices(5, 0).is_empty());
    }

    #[test]
    fn sample_indices_is_roughly_uniform() {
        // every index of a small domain must appear across many draws
        let mut r = Rng::seed_from_u64(11);
        let mut counts = [0usize; 10];
        for _ in 0..2000 {
            for i in r.sample_indices(10, 3) {
                counts[i] += 1;
            }
        }
        // expectation 600 each; a dead or doubled cell is a bug
        assert!(
            counts.iter().all(|&c| (400..=800).contains(&c)),
            "skewed counts {counts:?}"
        );
    }
}
