//! Tiny command-line argument parser (offline substrate for `clap`).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional
//! arguments, with typed getters and an auto-generated usage dump.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Context, Result};

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

/// Parse raw args. `bool_flags` lists option names that take no value.
pub fn parse(raw: impl Iterator<Item = String>, bool_flags: &[&str]) -> Result<Args> {
    let mut out = Args::default();
    let mut raw = raw.peekable();
    while let Some(a) = raw.next() {
        if let Some(stripped) = a.strip_prefix("--") {
            if let Some((k, v)) = stripped.split_once('=') {
                out.flags.insert(k.to_string(), v.to_string());
            } else if bool_flags.contains(&stripped) {
                out.flags.insert(stripped.to_string(), "true".to_string());
            } else {
                let v = raw
                    .next()
                    .ok_or_else(|| anyhow!("--{stripped} expects a value"))?;
                out.flags.insert(stripped.to_string(), v);
            }
        } else if a == "-v" {
            out.flags.insert("verbose".to_string(), "true".to_string());
        } else if a.starts_with('-') && a.len() > 1 {
            bail!("unknown short option {a}");
        } else {
            out.positional.push(a);
        }
    }
    Ok(out)
}

impl Args {
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    pub fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::error::Error + Send + Sync + 'static,
    {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse::<T>().with_context(|| format!("parsing --{key}={v}")),
        }
    }

    pub fn get_parse_opt<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>>
    where
        T::Err: std::error::Error + Send + Sync + 'static,
    {
        match self.get(key) {
            None => Ok(None),
            Some(v) => Ok(Some(
                v.parse::<T>().with_context(|| format!("parsing --{key}={v}"))?,
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(v: &[&str]) -> Args {
        parse(v.iter().map(|s| s.to_string()), &["verbose", "quick"]).unwrap()
    }

    #[test]
    fn parses_mixed_styles() {
        let a = args(&["train", "--rounds", "10", "--seed=7", "--verbose", "extra"]);
        assert_eq!(a.positional, vec!["train", "extra"]);
        assert_eq!(a.get_parse::<u32>("rounds", 0).unwrap(), 10);
        assert_eq!(a.get_parse::<u64>("seed", 0).unwrap(), 7);
        assert!(a.get_bool("verbose"));
        assert!(!a.get_bool("quick"));
    }

    #[test]
    fn defaults_apply() {
        let a = args(&[]);
        assert_eq!(a.get_str("dataset", "mnist"), "mnist");
        assert_eq!(a.get_parse::<u32>("rounds", 20).unwrap(), 20);
        assert_eq!(a.get_parse_opt::<u32>("rounds").unwrap(), None);
    }

    #[test]
    fn missing_value_is_error() {
        assert!(parse(["--rounds".to_string()].into_iter(), &[]).is_err());
    }

    #[test]
    fn bad_parse_is_error() {
        let a = args(&["--rounds", "ten"]);
        assert!(a.get_parse::<u32>("rounds", 0).is_err());
    }
}
