//! Micro-benchmark harness (offline substrate for `criterion`).
//!
//! Warm-up + fixed-iteration-count timing with mean / p50 / p95 / p99
//! reporting and a stable text output format that the perf logs in
//! EXPERIMENTS.md §Perf reference.

use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub p99: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl BenchStats {
    pub fn print(&self) {
        println!(
            "{:<44} {:>10} iters  mean {:>12?}  p50 {:>12?}  p95 {:>12?}  min {:>12?}",
            self.name, self.iters, self.mean, self.p50, self.p95, self.min
        );
    }
}

/// Time `f` for `iters` iterations after `warmup` warm-up runs. The
/// closure should return something observable to keep the optimizer
/// honest; we black-box it via `std::hint::black_box`.
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchStats {
    assert!(iters > 0);
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed());
    }
    samples.sort();
    let total: Duration = samples.iter().sum();
    let pick = |q: f64| samples[((samples.len() - 1) as f64 * q) as usize];
    let stats = BenchStats {
        name: name.to_string(),
        iters,
        mean: total / iters as u32,
        p50: pick(0.50),
        p95: pick(0.95),
        p99: pick(0.99),
        min: samples[0],
        max: *samples.last().unwrap(),
    };
    stats.print();
    stats
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_are_ordered() {
        let s = bench("noop-spin", 2, 50, || {
            let mut acc = 0u64;
            for i in 0..100 {
                acc = acc.wrapping_add(i);
            }
            acc
        });
        assert!(s.min <= s.p50 && s.p50 <= s.p95 && s.p95 <= s.max);
        assert_eq!(s.iters, 50);
    }
}
