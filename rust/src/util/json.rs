//! Minimal JSON reader/writer (offline substrate for `serde_json`).
//!
//! Supports everything the artifact manifests, history snapshots and
//! result files need: the full JSON value model, a strict recursive
//! parser, a pretty printer, and ergonomic typed accessors that produce
//! useful error messages.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Context, Result};

/// A JSON value. Objects preserve no insertion order (BTreeMap) — fine
/// for configs and results, and it makes output deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ------------------------------------------------------------------
    // constructors
    // ------------------------------------------------------------------

    pub fn obj(entries: Vec<(&str, Json)>) -> Json {
        Json::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }

    pub fn num<T: Into<f64>>(v: T) -> Json {
        Json::Num(v.into())
    }

    pub fn str(v: impl Into<String>) -> Json {
        Json::Str(v.into())
    }

    pub fn from_f64_slice(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    // ------------------------------------------------------------------
    // accessors
    // ------------------------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn get_opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_u64(&self) -> Result<u64> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            bail!("expected unsigned integer, got {f}");
        }
        Ok(f as u64)
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_u64()? as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("expected object, got {self:?}"),
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // ------------------------------------------------------------------
    // parse
    // ------------------------------------------------------------------

    pub fn parse(src: &str) -> Result<Json> {
        let mut p = Parser {
            bytes: src.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value().context("parsing JSON")?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing characters at byte {}", p.pos);
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> Result<Json> {
        let raw = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Json::parse(&raw).with_context(|| format!("parsing {}", path.display()))
    }

    // ------------------------------------------------------------------
    // write
    // ------------------------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    pub fn write_file(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.to_string_pretty())
            .with_context(|| format!("writing {}", path.display()))?;
        Ok(())
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.2e18 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    it.write(out, indent + 1, pretty);
                }
                pad(out, indent);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                pad(out, indent);
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!(
                "expected {:?} at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            )
        }
    }

    fn literal(&mut self, lit: &str, value: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(value)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let Some(c) = self.peek() else {
                bail!("unterminated string");
            };
            self.pos += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let Some(e) = self.peek() else {
                        bail!("unterminated escape");
                    };
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.pos += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        other => bail!("bad escape \\{}", other as char),
                    }
                }
                c => {
                    // collect the full UTF-8 sequence starting at c
                    let start = self.pos - 1;
                    let len = utf8_len(c);
                    self.pos = start + len;
                    if self.pos > self.bytes.len() {
                        bail!("truncated UTF-8");
                    }
                    s.push_str(std::str::from_utf8(&self.bytes[start..self.pos])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>().context("bad number")?))
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => bail!("expected , or ] got {:?}", other.map(|c| c as char)),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => bail!("expected , or }} got {:?}", other.map(|c| c as char)),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_types() {
        let src = r#"{"a": 1, "b": [true, null, -2.5e3], "c": {"d": "x\ny"}, "e": ""}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, re);
        let re2 = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, re2);
    }

    #[test]
    fn typed_accessors() {
        let v = Json::parse(r#"{"n": 42, "s": "hi", "f": 1.5, "b": false, "a": [1,2]}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_usize().unwrap(), 42);
        assert_eq!(v.get("s").unwrap().as_str().unwrap(), "hi");
        assert_eq!(v.get("f").unwrap().as_f64().unwrap(), 1.5);
        assert!(!v.get("b").unwrap().as_bool().unwrap());
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 2);
        assert!(v.get("missing").is_err());
        assert!(v.get("s").unwrap().as_f64().is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""café \"quoted\" \\ tab\t""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café \"quoted\" \\ tab\t");
        // non-ASCII passthrough
        let v = Json::parse("\"grüße 北京\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "grüße 北京");
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Json::num(5.0).to_string_compact(), "5");
        assert_eq!(Json::num(5.25).to_string_compact(), "5.25");
    }

    #[test]
    fn u64_rejects_negative_and_fractional() {
        assert!(Json::Num(-1.0).as_u64().is_err());
        assert!(Json::Num(1.5).as_u64().is_err());
        assert_eq!(Json::Num(7.0).as_u64().unwrap(), 7);
    }
}
