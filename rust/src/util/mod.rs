//! Offline substrates for the usual ecosystem crates (this build
//! environment has no network access to crates.io): deterministic RNG
//! (`rand`), JSON (`serde_json`), CLI parsing (`clap`) and a bench
//! harness (`criterion`). Each is a small, tested, self-contained module
//! implementing exactly what this crate needs.

pub mod bench;
pub mod cli;
pub mod json;
pub mod rng;

pub use json::Json;
pub use rng::Rng;
