//! fedless — CLI launcher for the FedLesScan reproduction.
//!
//! ```text
//! fedless train   [--dataset D] [--strategy S] [--stragglers P] [...]
//! fedless repro   <fig1|tables|fig3|ablations|all> [--profile quick|full] [...]
//! fedless inspect
//! ```
//!
//! The default (native) backend is self-contained: no artifacts, no
//! Python, no external libraries. `--backend pjrt` switches to the AOT
//! HLO path (requires a `--features pjrt` build and `make artifacts`).

use std::path::PathBuf;
use std::str::FromStr;

use fedless::config::{ExperimentConfig, Mode, Scenario};
use fedless::coordinator::Controller;
use fedless::repro::{self, Options, Profile};
use fedless::runtime::kernel;
use fedless::runtime::{load_backend, ArtifactIndex, BackendKind, Manifest};
use fedless::strategy::StrategyKind;
use fedless::util::cli;
use fedless::Result;

const USAGE: &str = "\
fedless — serverless federated learning with straggler mitigation (FedLesScan)

USAGE:
  fedless train [--dataset D]
                [--strategy fedavg|fedprox|fedlesscan|safalite|apodotiko|fedavgdrop|salf]
                [--stragglers PCT] [--scenario NAME] [--rounds N] [--clients N]
                [--per-round K] [--mode rounds|continuous] [--cohorts C]
                [--workers W] [--shards N] [--kernel scalar|avx2] [--quantize]
                [--topk F] [--seed S] [--config FILE.json] [--out DIR] [--verbose]
  fedless repro <fig1|tables|fig3|ablations|sweep|all>
                [--datasets a,b,c] [--profile quick|full] [--out DIR]
                [--seed S] [--repeats N] [--scenario NAME] [--verbose]
  fedless inspect

SCENARIOS:
  standard | straggler<pct> | coldstartstorm | diurnal | regionaloutage
  | adversarial — `--scenario` names one directly (outranks --stragglers);
  `repro sweep` runs the full strategy x scenario grid and writes
  matrix.json (restrict with --scenario for a single-column smoke)

GLOBAL:
  --backend KIND    execution backend: native (default) | pjrt
  --artifacts DIR   artifacts directory, pjrt backend only (default: artifacts)
  --workers W       executor-pool size (default: one per core, or the
                    FEDLESS_WORKERS env var; backends that opt out of
                    parallel training always get a single worker)
  --mode M          rounds (default, the paper's protocol) or continuous
                    (rounds-free: fold every completion, Eq. 3 damping)
  --cohorts C       continuous mode: keep C x per-round clients in flight
  --shards N        parameter-plane shard count (default: one per core, or
                    the FEDLESS_SHARDS env var; folds, anchor reads and
                    snapshot installs proceed per-shard)
  --kernel K        compute kernel for the math plane: scalar | avx2
                    (default: auto-detect; the FEDLESS_KERNEL env var
                    outranks both). Bit-identical either way — vector
                    kernels reproduce the scalar arithmetic exactly
  --quantize        int8-quantize client updates (symmetric per-shard
                    scales, client-side error-feedback residuals); cuts
                    accounted upload bytes ~4x
  --topk F          with --quantize: ship only the top F fraction of
                    entries per shard (0 < F <= 1)
";

fn main() -> Result<()> {
    let args = cli::parse(std::env::args().skip(1), &["verbose", "help", "quantize"])?;
    if args.get_bool("help") || args.positional.is_empty() {
        print!("{USAGE}");
        return Ok(());
    }
    let artifacts = PathBuf::from(args.get_str("artifacts", "artifacts"));
    let backend = BackendKind::from_str(&args.get_str("backend", "native"))?;
    match args.positional[0].as_str() {
        "train" => cmd_train(&args, backend, artifacts),
        "repro" => cmd_repro(&args, backend, artifacts),
        "inspect" => cmd_inspect(artifacts),
        other => {
            print!("{USAGE}");
            anyhow::bail!("unknown command {other:?}");
        }
    }
}

fn cmd_train(args: &cli::Args, backend_kind: BackendKind, artifacts: PathBuf) -> Result<()> {
    let dataset = args.get_str("dataset", "mnist");
    let mut cfg = match args.get("config") {
        Some(p) => ExperimentConfig::load(&PathBuf::from(p))?,
        None => ExperimentConfig::preset(&dataset),
    };
    cfg.artifacts_dir = artifacts.clone();
    if let Some(s) = args.get("strategy") {
        cfg.strategy = StrategyKind::from_str(s)?;
    }
    // --scenario names any grid scenario directly; --stragglers stays as
    // the historical shorthand for the paper's straggler axis.
    if let Some(s) = args.get("scenario") {
        cfg.scenario = Scenario::from_str(s)?;
    } else {
        let stragglers: u8 = args.get_parse("stragglers", 0)?;
        cfg.scenario = if stragglers == 0 {
            Scenario::Standard
        } else {
            Scenario::Straggler(stragglers)
        };
    }
    if let Some(r) = args.get_parse_opt::<u32>("rounds")? {
        cfg.rounds = r;
    }
    if let Some(n) = args.get_parse_opt::<usize>("clients")? {
        cfg.n_clients = n;
    }
    if let Some(k) = args.get_parse_opt::<usize>("per-round")? {
        cfg.clients_per_round = k;
    }
    cfg.seed = args.get_parse("seed", cfg.seed)?;
    cfg.verbose = args.get_bool("verbose");
    if let Some(m) = args.get("mode") {
        cfg.mode = Mode::from_str(m)?;
    }
    if let Some(c) = args.get_parse_opt::<usize>("cohorts")? {
        cfg.inflight_cohorts = c;
    }
    if let Some(w) = args.get_parse_opt::<usize>("workers")? {
        cfg.workers = Some(w);
    }
    if let Some(s) = args.get_parse_opt::<usize>("shards")? {
        cfg.shards = Some(s);
    }
    if let Some(k) = args.get("kernel") {
        cfg.kernel = Some(k.to_string());
    }
    if args.get_bool("quantize") {
        cfg.quantize_updates = true;
    }
    if let Some(f) = args.get_parse_opt::<f64>("topk")? {
        cfg.quantize_topk = Some(f);
    }
    cfg.validate()?;

    // Pin the compute kernel for the whole run (env ▸ --kernel/config ▸
    // CPU detection) so every worker dispatches the same microkernels.
    let kernel = kernel::install(kernel::kernel_override(cfg.kernel.as_deref())?)?;
    let backend = load_backend(backend_kind, &artifacts, &cfg.dataset)?;
    eprintln!(
        "[fedless] backend {}: {} P={} kernel={}",
        backend.backend_name(),
        backend.manifest().name,
        backend.manifest().param_count,
        kernel.name()
    );
    let n_clients = cfg.n_clients;
    let mode = cfg.mode;
    let mut ctl = Controller::new(cfg, backend.as_ref())?;
    if mode == Mode::Continuous {
        let result = ctl.run_continuous()?;
        println!(
            "\n{} / {} / {} (continuous): final acc {:.3}, folds {}/{} completions \
             (EUR {:.3}), {:.3} updates/s, time {:.1} min, crashes {}, expired {}, \
             late {}, generation {}, cost ${:.4}, select wall {:.1} ms, \
             reclustered {} / cache hits {}, kernel {}",
            result.dataset,
            result.strategy,
            result.scenario,
            result.final_accuracy,
            result.folds,
            result.completions,
            result.effective_update_ratio(),
            result.updates_per_s(),
            result.duration_s / 60.0,
            result.crashes,
            result.expired,
            result.late,
            result.final_generation,
            result.total_cost,
            result.select_wall_s * 1e3,
            result.reclustered_clients,
            result.cluster_cache_hits,
            kernel.name(),
        );
        if let Some(out) = args.get("out") {
            let out = PathBuf::from(out);
            std::fs::create_dir_all(&out)?;
            let base = format!(
                "{}_{}_{}_continuous",
                result.dataset, result.strategy, result.scenario
            );
            result.write_json(&out.join(format!("{base}.json")))?;
            println!("wrote {}/{base}.json", out.display());
        }
        return Ok(());
    }
    let result = ctl.run()?;
    let stale_total: usize = result.rounds.iter().map(|r| r.stale_applied).sum();
    let in_flight_total: usize = result.rounds.iter().map(|r| r.in_flight_skipped).sum();
    let agg_wall_total: f64 = result.rounds.iter().map(|r| r.agg_wall_s).sum();
    let select_wall_total: f64 = result.rounds.iter().map(|r| r.select_wall_s).sum();
    let peak_bytes = result
        .rounds
        .iter()
        .map(|r| r.param_plane_peak_bytes)
        .max()
        .unwrap_or(0);
    let bytes_down_total: usize = result.rounds.iter().map(|r| r.bytes_down).sum();
    let bytes_up_total: usize = result.rounds.iter().map(|r| r.bytes_up).sum();
    let reclustered_total: usize = result.rounds.iter().map(|r| r.reclustered_clients).sum();
    let cache_hits_total: usize = result.rounds.iter().map(|r| r.cluster_cache_hits).sum();
    println!(
        "\n{} / {} / {}: final acc {:.3}, mean EUR {:.3}, time {:.1} min, cost ${:.4}, \
         bias {}, stale applied {}, in-flight skips {}, select wall {:.1} ms, \
         agg wall {:.1} ms, param-plane peak {:.2} MB, net down/up {:.2}/{:.2} MB, \
         reclustered {} / cache hits {}, kernel {}",
        result.dataset,
        result.strategy,
        result.scenario,
        result.final_accuracy,
        result.mean_eur(),
        result.total_time_s / 60.0,
        result.total_cost,
        result.bias(n_clients),
        stale_total,
        in_flight_total,
        select_wall_total * 1e3,
        agg_wall_total * 1e3,
        peak_bytes as f64 / 1e6,
        bytes_down_total as f64 / 1e6,
        bytes_up_total as f64 / 1e6,
        reclustered_total,
        cache_hits_total,
        kernel.name(),
    );
    if let Some(out) = args.get("out") {
        let out = PathBuf::from(out);
        std::fs::create_dir_all(&out)?;
        let base = format!("{}_{}_{}", result.dataset, result.strategy, result.scenario);
        result.write_timeline_csv(&out.join(format!("{base}.csv")))?;
        result.write_json(&out.join(format!("{base}.json")))?;
        println!("wrote {}/{base}.{{csv,json}}", out.display());
    }
    Ok(())
}

fn cmd_repro(args: &cli::Args, backend: BackendKind, artifacts: PathBuf) -> Result<()> {
    let target = args
        .positional
        .get(1)
        .map(String::as_str)
        .unwrap_or("all");
    let default_datasets: Vec<String> = match target {
        "tables" | "all" => ExperimentConfig::preset_datasets()
            .iter()
            .map(|s| s.to_string())
            .collect(),
        // the grid sweep defaults to one dataset: the matrix is already
        // |strategies| x |scenarios| cells
        "sweep" => vec!["mnist".to_string()],
        _ => vec!["speech".to_string()],
    };
    let opts = Options {
        artifacts_dir: artifacts,
        out_dir: PathBuf::from(args.get_str("out", "results")),
        datasets: args
            .get("datasets")
            .map(|d| d.split(',').map(str::to_string).collect())
            .unwrap_or(default_datasets),
        profile: Profile::from_str(&args.get_str("profile", "quick"))?,
        seed: args.get_parse("seed", 42)?,
        repeats: args.get_parse("repeats", 1)?,
        verbose: args.get_bool("verbose"),
        backend,
    };
    match target {
        "fig1" => repro::fig1(&opts)?,
        "tables" => {
            let cells = repro::run_matrix(&opts)?;
            repro::table2(&cells);
            repro::table3(&cells);
            repro::table4(&cells);
        }
        "fig3" => repro::fig3(&opts)?,
        "ablations" => repro::ablations(&opts)?,
        "sweep" => {
            let only = args
                .get("scenario")
                .map(|s| Scenario::from_str(s))
                .transpose()?;
            repro::sweep(&opts, only)?;
        }
        "all" => {
            repro::fig1(&opts)?;
            let cells = repro::run_matrix(&opts)?;
            repro::table2(&cells);
            repro::table3(&cells);
            repro::table4(&cells);
            repro::fig3(&opts)?;
            repro::ablations(&opts)?;
        }
        other => anyhow::bail!(
            "unknown repro target {other:?} (fig1|tables|fig3|ablations|sweep|all)"
        ),
    }
    Ok(())
}

fn cmd_inspect(artifacts: PathBuf) -> Result<()> {
    println!("native backend models (always available):");
    for d in ExperimentConfig::preset_datasets() {
        let b = load_backend(BackendKind::Native, &artifacts, d)?;
        let mf = b.manifest();
        println!(
            "  {:<14} P={:<9} shard={} batch={} epochs={} opt={} lr={} k_max={}",
            mf.name,
            mf.param_count,
            mf.shard_size,
            mf.batch_size,
            mf.local_epochs,
            mf.optimizer,
            mf.lr,
            mf.k_max
        );
    }
    match ArtifactIndex::load(&artifacts) {
        Ok(idx) => {
            println!("\npjrt artifacts @ {} (scale: {})", artifacts.display(), idx.scale);
            for m in &idx.models {
                let mf = Manifest::load(&artifacts, m)?;
                println!(
                    "  {:<14} P={:<9} shard={} batch={} epochs={} opt={} lr={} k_max={}",
                    mf.name,
                    mf.param_count,
                    mf.shard_size,
                    mf.batch_size,
                    mf.local_epochs,
                    mf.optimizer,
                    mf.lr,
                    mf.k_max
                );
            }
        }
        Err(e) => println!("\nno pjrt artifacts found ({e}); run `make artifacts`"),
    }
    println!("\nexperiment presets (deployment shape, §VI-A3 scaled):");
    for d in ExperimentConfig::preset_datasets() {
        let c = ExperimentConfig::preset(d);
        println!(
            "  {:<14} clients={:<4} per_round={:<4} rounds={:<4} base_train={}s timeouts={}s/{}s",
            d,
            c.n_clients,
            c.clients_per_round,
            c.rounds,
            c.base_train_s,
            c.round_timeout_standard_s,
            c.round_timeout_straggler_s
        );
    }
    Ok(())
}
