//! Thin PJRT wrapper: load AOT HLO-text artifacts, compile once, execute
//! many times. Adapted from /opt/xla-example/load_hlo — HLO *text* is the
//! interchange format (serialized protos from jax >= 0.5 carry 64-bit
//! instruction ids that xla_extension 0.5.1 rejects).

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use anyhow::anyhow;

use crate::Result;

/// A PJRT client. One per process is plenty; cloning the underlying
/// client handle is cheap (ref-counted on the C side).
pub struct Engine {
    client: xla::PjRtClient,
}

impl Engine {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
        Ok(Self { client })
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO text file and compile it for this client.
    pub fn load_hlo(&self, path: &Path) -> Result<Executable> {
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parsing HLO text {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e}", path.display()))?;
        Ok(Executable {
            exe,
            path: path.to_path_buf(),
            compile_time: t0.elapsed(),
        })
    }
}

/// A compiled HLO module ready to execute. The lowered functions all
/// return a tuple root (`return_tuple=True` at lowering), so `run`
/// decomposes the single output literal into tuple elements.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    path: PathBuf,
    /// Time spent in XLA compilation (reported once in metrics).
    pub compile_time: Duration,
}

impl Executable {
    /// Execute with host literals; returns the output tuple elements and
    /// the device wall time of this call.
    pub fn run(&self, args: &[xla::Literal]) -> Result<(Vec<xla::Literal>, Duration)> {
        let t0 = Instant::now();
        let bufs = self
            .exe
            .execute::<xla::Literal>(args)
            .map_err(|e| anyhow!("executing {}: {e}", self.path.display()))?;
        let root = bufs
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| anyhow!("{}: empty execution result", self.path.display()))?
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {}: {e}", self.path.display()))?;
        let parts = root
            .to_tuple()
            .map_err(|e| anyhow!("decomposing tuple of {}: {e}", self.path.display()))?;
        Ok((parts, t0.elapsed()))
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

// ---------------------------------------------------------------------------
// literal helpers
// ---------------------------------------------------------------------------

/// Build an f32 literal with the given dimensions.
pub fn lit_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    if n as usize != data.len() {
        return Err(anyhow!("lit_f32: {} elems vs dims {:?}", data.len(), dims));
    }
    if dims.len() == 1 {
        return Ok(xla::Literal::vec1(data));
    }
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| anyhow!("reshape {dims:?}: {e}"))
}

/// Build an i32 literal with the given dimensions.
pub fn lit_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    if n as usize != data.len() {
        return Err(anyhow!("lit_i32: {} elems vs dims {:?}", data.len(), dims));
    }
    if dims.len() == 1 {
        return Ok(xla::Literal::vec1(data));
    }
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| anyhow!("reshape {dims:?}: {e}"))
}

/// Scalar literals.
pub fn scalar_f32(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

pub fn scalar_i32(v: i32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Extract a flat f32 vector from a literal.
pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow!("literal to f32 vec: {e}"))
}

/// Extract the single f32 element of a scalar literal.
pub fn to_scalar_f32(lit: &xla::Literal) -> Result<f32> {
    lit.get_first_element::<f32>()
        .map_err(|e| anyhow!("literal to f32 scalar: {e}"))
}

/// Copy a literal's f32 contents into an existing buffer (no realloc).
pub fn copy_f32_into(lit: &xla::Literal, dst: &mut [f32]) -> Result<()> {
    lit.copy_raw_to::<f32>(dst)
        .map_err(|e| anyhow!("literal raw copy: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lit_f32_checks_element_count() {
        assert!(lit_f32(&[1.0, 2.0], &[3]).is_err());
        assert!(lit_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).is_ok());
    }

    #[test]
    fn lit_i32_checks_element_count() {
        assert!(lit_i32(&[1, 2, 3], &[2, 2]).is_err());
        assert!(lit_i32(&[1, 2, 3, 4, 5, 6], &[2, 3]).is_ok());
    }

    #[test]
    fn scalar_roundtrip() {
        let lit = scalar_f32(3.5);
        assert_eq!(to_scalar_f32(&lit).unwrap(), 3.5);
    }
}
