//! Kernel plane: runtime-dispatched SIMD microkernels behind the dense
//! training math and the parameter-plane hot loops.
//!
//! Every kernel exists in two implementations selected by [`Kernel`]:
//!
//! * **scalar** — the seed loops of `native.rs` / `params/`, verbatim.
//!   This is the reference semantics; every golden in the repo pins it.
//! * **avx2** — `std::arch` x86_64 AVX2 vectorization of the same loops,
//!   compiled behind `#[target_feature(enable = "avx2")]` and only ever
//!   dispatched after a runtime `is_x86_feature_detected!("avx2")` check.
//!
//! Selection order (first match wins):
//!
//! 1. `FEDLESS_KERNEL=scalar|avx2` environment override;
//! 2. an explicit request (the `--kernel` CLI flag / config field),
//!    passed to [`install`];
//! 3. CPU detection: AVX2 when available, scalar otherwise.
//!
//! Requesting `avx2` on a host without AVX2 is an error, never UB.
//!
//! ## Bit-exactness contract
//!
//! The vector kernels are **bit-identical** to the scalar ones, not just
//! close: `f32::to_bits` equality on every output element (pinned by the
//! proptests in `tests/proptests.rs` and by every existing golden). The
//! vectorization discipline that makes this possible:
//!
//! * GEMMs vectorize only over the output-contiguous `j` dimension, so
//!   each output element's `k`-accumulation order is exactly the scalar
//!   order (lanes are independent output elements, never partial sums).
//! * Multiplies and adds stay separate (`_mm256_mul_ps` then
//!   `_mm256_add_ps`) — FMA contraction would change the rounding.
//! * `a @ bᵀ` ([`Kernel::matmul_a_bt`]) is restructured by pre-transposing
//!   `b` into a caller scratch so the product runs in the `j`-inner form;
//!   the seed's per-element `Σ_l a[i,l]·b[j,l]` fold order is unchanged.
//! * Element-wise kernels use only IEEE correctly-rounded lane ops
//!   (add/sub/mul/div/sqrt/round-to-zero), identical to scalar.
//! * Int8 encode emulates Rust's round-half-away-from-zero exactly via
//!   truncate + fractional-part compare (`_mm256_round_ps` itself rounds
//!   half-to-even, which differs from `f32::round` on exact halves).
//!
//! Known caveat: ReLU uses `_mm256_max_ps(z, 0.0)`, whose zero-sign on a
//! `-0.0` input is platform-pinned rather than specified by `f32::max`.
//! A `-0.0` pre-activation would require the bias add `acc + b` to
//! produce `-0.0`, i.e. both operands `-0.0` — unreachable from the
//! Glorot init and the goldens' finite data, and pinned harmless by the
//! proptests.

// Kernels are argument-heavy by nature (matrix dims + fused epilogue
// buffers); grouping them into structs would only obscure the shapes.
#![allow(clippy::too_many_arguments)]

use std::sync::OnceLock;

use anyhow::bail;

use crate::Result;

/// Environment variable overriding kernel selection (highest precedence).
pub const KERNEL_ENV: &str = "FEDLESS_KERNEL";

/// Which microkernel implementation executes the hot loops.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kernel {
    /// The seed scalar loops — reference semantics, always available.
    Scalar,
    /// AVX2 vector kernels; only dispatched when the CPU supports AVX2.
    Avx2,
}

/// Per-step Adam scalars, precomputed once per optimizer step.
#[derive(Debug, Clone, Copy)]
pub struct AdamParams {
    pub lr: f32,
    pub b1: f32,
    pub b2: f32,
    pub eps: f32,
    /// Bias corrections `1 - b1^t` / `1 - b2^t` for the current step.
    pub bc1: f32,
    pub bc2: f32,
}

/// Whether this host can run the AVX2 kernels.
pub fn avx2_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::arch::is_x86_feature_detected!("avx2")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

impl std::str::FromStr for Kernel {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> std::result::Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "scalar" => Ok(Kernel::Scalar),
            "avx2" => Ok(Kernel::Avx2),
            other => bail!("unknown kernel {other:?}; expected scalar|avx2"),
        }
    }
}

impl Kernel {
    pub fn name(self) -> &'static str {
        match self {
            Kernel::Scalar => "scalar",
            Kernel::Avx2 => "avx2",
        }
    }
}

/// Parse an explicit kernel request (CLI flag / config field / env
/// value). `None` or an empty string means "no preference".
pub fn kernel_override(raw: Option<&str>) -> Result<Option<Kernel>> {
    match raw {
        None => Ok(None),
        Some(s) if s.trim().is_empty() => Ok(None),
        Some(s) => Ok(Some(s.parse()?)),
    }
}

fn env_kernel() -> Result<Option<Kernel>> {
    kernel_override(std::env::var(KERNEL_ENV).ok().as_deref())
}

/// Resolve the kernel to run: `FEDLESS_KERNEL` env ▸ explicit `request`
/// ▸ CPU detection. Fails (rather than risking UB) when `avx2` is
/// requested on a host without AVX2.
pub fn resolve_kernel(request: Option<Kernel>) -> Result<Kernel> {
    let k = match env_kernel()? {
        Some(k) => k,
        None => match request {
            Some(k) => k,
            None => {
                if avx2_available() {
                    Kernel::Avx2
                } else {
                    Kernel::Scalar
                }
            }
        },
    };
    if k == Kernel::Avx2 && !avx2_available() {
        bail!("kernel avx2 requested but this host does not support AVX2");
    }
    Ok(k)
}

static ACTIVE: OnceLock<Kernel> = OnceLock::new();

/// Pin the process-wide kernel from an explicit request (the `--kernel`
/// flag), honoring the env override. Call before any training work; a
/// later call that would change an already-pinned kernel fails.
pub fn install(request: Option<Kernel>) -> Result<Kernel> {
    let want = resolve_kernel(request)?;
    let got = *ACTIVE.get_or_init(|| want);
    if got != want {
        bail!(
            "kernel already pinned to {} (requested {})",
            got.name(),
            want.name()
        );
    }
    Ok(got)
}

/// The process-wide kernel, resolving env ▸ detection on first use. An
/// invalid `FEDLESS_KERNEL` value falls back to scalar with a warning
/// (hot loops cannot surface a `Result` per call).
pub fn active() -> Kernel {
    *ACTIVE.get_or_init(|| match resolve_kernel(None) {
        Ok(k) => k,
        Err(e) => {
            eprintln!("[fedless] kernel selection: {e}; falling back to scalar");
            Kernel::Scalar
        }
    })
}

/// Dispatch one kernel op. The AVX2 arm is reached only for
/// `Kernel::Avx2`, which is only ever constructed behind an
/// `avx2_available()` check (`resolve_kernel`), making the
/// `target_feature` call sound.
macro_rules! dispatch {
    ($self:expr, $f:ident($($arg:expr),* $(,)?)) => {
        match $self {
            Kernel::Scalar => scalar::$f($($arg),*),
            #[cfg(target_arch = "x86_64")]
            Kernel::Avx2 => {
                debug_assert!(avx2_available());
                unsafe { avx2::$f($($arg),*) }
            }
            #[cfg(not(target_arch = "x86_64"))]
            Kernel::Avx2 => scalar::$f($($arg),*),
        }
    };
}

impl Kernel {
    /// `out[m,n] = a[m,k] @ b[k,n]` (m inferred from `out.len() / n`).
    pub fn matmul(self, a: &[f32], b: &[f32], k: usize, n: usize, out: &mut [f32]) {
        check_gemm(a, b, k, n, out.len());
        dispatch!(self, matmul(a, b, k, n, out))
    }

    /// `out[m,n] = a[m,k] @ b[k,n] + bias[n]` (row-broadcast bias).
    pub fn matmul_bias(
        self,
        a: &[f32],
        b: &[f32],
        bias: &[f32],
        k: usize,
        n: usize,
        out: &mut [f32],
    ) {
        check_gemm(a, b, k, n, out.len());
        assert_eq!(bias.len(), n, "bias length mismatch");
        dispatch!(self, matmul_bias(a, b, bias, k, n, out))
    }

    /// Fused hidden-layer epilogue: `z = a @ b + bias`, `act = max(z, 0)`
    /// — both pre-activation and activation are materialized because the
    /// backward pass masks on `z > 0`.
    pub fn matmul_bias_relu(
        self,
        a: &[f32],
        b: &[f32],
        bias: &[f32],
        k: usize,
        n: usize,
        z: &mut [f32],
        act: &mut [f32],
    ) {
        check_gemm(a, b, k, n, z.len());
        assert_eq!(bias.len(), n, "bias length mismatch");
        assert_eq!(z.len(), act.len(), "z/act length mismatch");
        dispatch!(self, matmul_bias_relu(a, b, bias, k, n, z, act))
    }

    /// `out[k,n] = a[m,k]ᵀ @ b[m,n]` (weight gradient shape).
    pub fn matmul_at_b(self, a: &[f32], b: &[f32], k: usize, n: usize, out: &mut [f32]) {
        assert!(k > 0 && n > 0, "matmul_at_b with zero dimension");
        assert_eq!(out.len(), k * n, "matmul_at_b out length mismatch");
        assert_eq!(a.len() / k, b.len() / n, "matmul_at_b row count mismatch");
        dispatch!(self, matmul_at_b(a, b, k, n, out))
    }

    /// `out[m,k] = a[m,n] @ b[k,n]ᵀ` (back-propagated activation
    /// gradient), restructured into the `j`-inner form by pre-transposing
    /// `b` into `bt` (caller scratch, length `n * k`). Per output
    /// element the `Σ_l a[i,l]·b[j,l]` accumulation order is exactly the
    /// seed's dot-product fold, so the restructure is bit-exact.
    pub fn matmul_a_bt(
        self,
        a: &[f32],
        b: &[f32],
        n: usize,
        k: usize,
        bt: &mut [f32],
        out: &mut [f32],
    ) {
        assert_eq!(b.len(), k * n, "matmul_a_bt b length mismatch");
        assert_eq!(bt.len(), n * k, "matmul_a_bt bt scratch mismatch");
        transpose(b, k, n, bt);
        self.matmul(a, bt, n, k, out);
    }

    /// `acc[i] += x[i]` (bias-gradient row reduction).
    pub fn add_assign(self, acc: &mut [f32], x: &[f32]) {
        assert_eq!(acc.len(), x.len(), "add_assign length mismatch");
        dispatch!(self, add_assign(acc, x))
    }

    /// `acc[i] += w * x[i]` (weighted fold accumulation, Eq. 3 inner sum).
    pub fn axpy(self, acc: &mut [f32], x: &[f32], w: f32) {
        assert_eq!(acc.len(), x.len(), "axpy length mismatch");
        dispatch!(self, axpy(acc, x, w))
    }

    /// `out[i] = a[i] + b[i]` (error-feedback compensation).
    pub fn add(self, out: &mut [f32], a: &[f32], b: &[f32]) {
        assert!(out.len() == a.len() && out.len() == b.len(), "add length mismatch");
        dispatch!(self, add(out, a, b))
    }

    /// `out[i] = a[i] - b[i]` (error-feedback residual).
    pub fn sub(self, out: &mut [f32], a: &[f32], b: &[f32]) {
        assert!(out.len() == a.len() && out.len() == b.len(), "sub length mismatch");
        dispatch!(self, sub(out, a, b))
    }

    /// FedProx anchor pull: `g[i] += mu * (w[i] - anchor[i])`.
    pub fn prox_add(self, g: &mut [f32], w: &[f32], anchor: &[f32], mu: f32) {
        assert!(g.len() == w.len() && g.len() == anchor.len(), "prox length mismatch");
        dispatch!(self, prox_add(g, w, anchor, mu))
    }

    /// SGD step: `w[i] -= lr * g[i]`.
    pub fn sgd_step(self, w: &mut [f32], g: &[f32], lr: f32) {
        assert_eq!(w.len(), g.len(), "sgd length mismatch");
        dispatch!(self, sgd_step(w, g, lr))
    }

    /// One fused Adam step over the flat parameter vector (moment
    /// update, bias correction, parameter update — `optim.py` order).
    pub fn adam_step(self, w: &mut [f32], g: &[f32], m: &mut [f32], v: &mut [f32], p: AdamParams) {
        assert!(
            w.len() == g.len() && w.len() == m.len() && w.len() == v.len(),
            "adam length mismatch"
        );
        dispatch!(self, adam_step(w, g, m, v, p))
    }

    /// ReLU backward mask: `dz[i] = if z[i] > 0 { da[i] } else { 0 }`.
    pub fn relu_mask(self, dz: &mut [f32], da: &[f32], z: &[f32]) {
        assert!(dz.len() == da.len() && dz.len() == z.len(), "relu mask length mismatch");
        dispatch!(self, relu_mask(dz, da, z))
    }

    /// `max_i |x[i]|` with NaN entries ignored (shard-scale reduction;
    /// order-independent for the non-NaN max, so lane-parallel reduction
    /// is value-exact).
    pub fn max_abs(self, x: &[f32]) -> f32 {
        dispatch!(self, max_abs(x))
    }

    /// Int8 symmetric encode: `out[i] = round(v[i] / scale)` clamped to
    /// `[-qmax, qmax]`, with Rust's round-half-away-from-zero semantics.
    /// `scale == 0` (all-zero shard) encodes to all-zero codes.
    pub fn quant_encode(self, out: &mut [i8], values: &[f32], scale: f32, qmax: f32) {
        assert_eq!(out.len(), values.len(), "quant encode length mismatch");
        dispatch!(self, quant_encode(out, values, scale, qmax))
    }

    /// Int8 decode: `out[i] = codes[i] as f32 * scale`.
    pub fn dequant(self, out: &mut [f32], codes: &[i8], scale: f32) {
        assert_eq!(out.len(), codes.len(), "dequant length mismatch");
        dispatch!(self, dequant(out, codes, scale))
    }
}

fn check_gemm(a: &[f32], b: &[f32], k: usize, n: usize, out_len: usize) {
    assert!(k > 0 && n > 0, "gemm with zero inner/output dimension");
    assert_eq!(out_len % n, 0, "gemm out length not a multiple of n");
    let m = out_len / n;
    assert_eq!(a.len(), m * k, "gemm a length mismatch");
    assert_eq!(b.len(), k * n, "gemm b length mismatch");
}

/// `out[n,k] = b[k,n]ᵀ` — scalar row-major transpose (memory-bound;
/// element moves are rounding-free so no vector variant is needed).
fn transpose(b: &[f32], rows: usize, cols: usize, out: &mut [f32]) {
    for (i, br) in b.chunks_exact(cols).enumerate() {
        for (j, &v) in br.iter().enumerate() {
            out[j * rows + i] = v;
        }
    }
}

// ---------------------------------------------------------------------------
// scalar kernels — the seed loops, verbatim (reference semantics)
// ---------------------------------------------------------------------------

mod scalar {
    use super::AdamParams;

    pub(super) fn matmul(a: &[f32], b: &[f32], k: usize, n: usize, out: &mut [f32]) {
        out.fill(0.0);
        for (ar, or) in a.chunks_exact(k).zip(out.chunks_exact_mut(n)) {
            for (aik, br) in ar.iter().zip(b.chunks_exact(n)) {
                for (o, bkj) in or.iter_mut().zip(br) {
                    *o += aik * bkj;
                }
            }
        }
    }

    pub(super) fn matmul_bias(
        a: &[f32],
        b: &[f32],
        bias: &[f32],
        k: usize,
        n: usize,
        out: &mut [f32],
    ) {
        matmul(a, b, k, n, out);
        for or in out.chunks_exact_mut(n) {
            for (o, bi) in or.iter_mut().zip(bias) {
                *o += bi;
            }
        }
    }

    pub(super) fn matmul_bias_relu(
        a: &[f32],
        b: &[f32],
        bias: &[f32],
        k: usize,
        n: usize,
        z: &mut [f32],
        act: &mut [f32],
    ) {
        matmul(a, b, k, n, z);
        for (zr, ar) in z.chunks_exact_mut(n).zip(act.chunks_exact_mut(n)) {
            for ((zv, bi), av) in zr.iter_mut().zip(bias).zip(ar) {
                *zv += bi;
                *av = zv.max(0.0);
            }
        }
    }

    pub(super) fn matmul_at_b(a: &[f32], b: &[f32], k: usize, n: usize, out: &mut [f32]) {
        out.fill(0.0);
        for (ar, br) in a.chunks_exact(k).zip(b.chunks_exact(n)) {
            for (aik, or) in ar.iter().zip(out.chunks_exact_mut(n)) {
                for (o, bij) in or.iter_mut().zip(br) {
                    *o += aik * bij;
                }
            }
        }
    }

    pub(super) fn add_assign(acc: &mut [f32], x: &[f32]) {
        for (a, v) in acc.iter_mut().zip(x) {
            *a += v;
        }
    }

    pub(super) fn axpy(acc: &mut [f32], x: &[f32], w: f32) {
        for (a, v) in acc.iter_mut().zip(x) {
            *a += w * v;
        }
    }

    pub(super) fn add(out: &mut [f32], a: &[f32], b: &[f32]) {
        for ((o, x), y) in out.iter_mut().zip(a).zip(b) {
            *o = x + y;
        }
    }

    pub(super) fn sub(out: &mut [f32], a: &[f32], b: &[f32]) {
        for ((o, x), y) in out.iter_mut().zip(a).zip(b) {
            *o = x - y;
        }
    }

    pub(super) fn prox_add(g: &mut [f32], w: &[f32], anchor: &[f32], mu: f32) {
        for ((gi, wi), ai) in g.iter_mut().zip(w).zip(anchor) {
            *gi += mu * (wi - ai);
        }
    }

    pub(super) fn sgd_step(w: &mut [f32], g: &[f32], lr: f32) {
        for (wi, gi) in w.iter_mut().zip(g) {
            *wi -= lr * gi;
        }
    }

    pub(super) fn adam_step(
        w: &mut [f32],
        g: &[f32],
        m: &mut [f32],
        v: &mut [f32],
        p: AdamParams,
    ) {
        let c1 = 1.0 - p.b1;
        let c2 = 1.0 - p.b2;
        for (((wi, gi), mi), vi) in w.iter_mut().zip(g).zip(m.iter_mut()).zip(v.iter_mut()) {
            *mi = p.b1 * *mi + c1 * gi;
            *vi = p.b2 * *vi + c2 * gi * gi;
            let mhat = *mi / p.bc1;
            let vhat = *vi / p.bc2;
            *wi -= p.lr * mhat / (vhat.sqrt() + p.eps);
        }
    }

    pub(super) fn relu_mask(dz: &mut [f32], da: &[f32], z: &[f32]) {
        for ((d, a), zv) in dz.iter_mut().zip(da).zip(z) {
            *d = if *zv > 0.0 { *a } else { 0.0 };
        }
    }

    pub(super) fn max_abs(x: &[f32]) -> f32 {
        x.iter().fold(0.0f32, |m, v| m.max(v.abs()))
    }

    pub(super) fn quant_encode(out: &mut [i8], values: &[f32], scale: f32, qmax: f32) {
        if scale == 0.0 {
            out.fill(0);
            return;
        }
        for (o, &v) in out.iter_mut().zip(values) {
            *o = (v / scale).round().clamp(-qmax, qmax) as i8;
        }
    }

    pub(super) fn dequant(out: &mut [f32], codes: &[i8], scale: f32) {
        for (o, &c) in out.iter_mut().zip(codes) {
            *o = c as f32 * scale;
        }
    }
}

// ---------------------------------------------------------------------------
// AVX2 kernels — bit-identical vector forms of the scalar loops
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
#[allow(clippy::needless_range_loop)] // index math mirrors the register tiling
mod avx2 {
    use std::arch::x86_64::*;

    use super::AdamParams;

    /// f32 lanes per ymm register.
    const LANES: usize = 8;
    /// Row-block height: accumulator tiles live in registers across the
    /// whole `k` loop (register blocking over rows).
    const MR: usize = 4;

    #[derive(Clone, Copy, PartialEq)]
    enum Epi {
        None,
        Bias,
        BiasRelu,
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn matmul(a: &[f32], b: &[f32], k: usize, n: usize, out: &mut [f32]) {
        gemm(a, b, std::ptr::null(), k, n, out, std::ptr::null_mut(), Epi::None)
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn matmul_bias(
        a: &[f32],
        b: &[f32],
        bias: &[f32],
        k: usize,
        n: usize,
        out: &mut [f32],
    ) {
        gemm(a, b, bias.as_ptr(), k, n, out, std::ptr::null_mut(), Epi::Bias)
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn matmul_bias_relu(
        a: &[f32],
        b: &[f32],
        bias: &[f32],
        k: usize,
        n: usize,
        z: &mut [f32],
        act: &mut [f32],
    ) {
        let actp = act.as_mut_ptr();
        gemm(a, b, bias.as_ptr(), k, n, z, actp, Epi::BiasRelu)
    }

    /// Shared GEMM core: `z = a @ b [+ bias] [, act = relu(z)]`.
    ///
    /// Lanes are independent output columns of one row, so each output
    /// element accumulates its `k` products in exactly the scalar order;
    /// mul and add stay separate (no FMA). Row blocks of `MR` keep
    /// `MR × 2` ymm accumulators live across the whole `k` loop.
    #[target_feature(enable = "avx2")]
    unsafe fn gemm(
        a: &[f32],
        b: &[f32],
        bias: *const f32,
        k: usize,
        n: usize,
        z: &mut [f32],
        act: *mut f32,
        epi: Epi,
    ) {
        let m = z.len() / n;
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let zp = z.as_mut_ptr();
        let zero = _mm256_setzero_ps();

        let mut i = 0;
        while i < m {
            let rb = MR.min(m - i);
            let mut j = 0;
            // 16-wide j tiles: MR×2 ymm accumulators in registers.
            while j + 2 * LANES <= n {
                let mut acc = [[zero; 2]; MR];
                for l in 0..k {
                    let b0 = _mm256_loadu_ps(bp.add(l * n + j));
                    let b1 = _mm256_loadu_ps(bp.add(l * n + j + LANES));
                    for r in 0..rb {
                        let av = _mm256_set1_ps(*ap.add((i + r) * k + l));
                        acc[r][0] = _mm256_add_ps(acc[r][0], _mm256_mul_ps(av, b0));
                        acc[r][1] = _mm256_add_ps(acc[r][1], _mm256_mul_ps(av, b1));
                    }
                }
                for r in 0..rb {
                    let base = (i + r) * n + j;
                    let mut c0 = acc[r][0];
                    let mut c1 = acc[r][1];
                    if epi != Epi::None {
                        c0 = _mm256_add_ps(c0, _mm256_loadu_ps(bias.add(j)));
                        c1 = _mm256_add_ps(c1, _mm256_loadu_ps(bias.add(j + LANES)));
                    }
                    _mm256_storeu_ps(zp.add(base), c0);
                    _mm256_storeu_ps(zp.add(base + LANES), c1);
                    if epi == Epi::BiasRelu {
                        _mm256_storeu_ps(act.add(base), _mm256_max_ps(c0, zero));
                        _mm256_storeu_ps(act.add(base + LANES), _mm256_max_ps(c1, zero));
                    }
                }
                j += 2 * LANES;
            }
            // 8-wide j tile.
            while j + LANES <= n {
                let mut acc = [zero; MR];
                for l in 0..k {
                    let b0 = _mm256_loadu_ps(bp.add(l * n + j));
                    for r in 0..rb {
                        let av = _mm256_set1_ps(*ap.add((i + r) * k + l));
                        acc[r] = _mm256_add_ps(acc[r], _mm256_mul_ps(av, b0));
                    }
                }
                for r in 0..rb {
                    let base = (i + r) * n + j;
                    let mut c0 = acc[r];
                    if epi != Epi::None {
                        c0 = _mm256_add_ps(c0, _mm256_loadu_ps(bias.add(j)));
                    }
                    _mm256_storeu_ps(zp.add(base), c0);
                    if epi == Epi::BiasRelu {
                        _mm256_storeu_ps(act.add(base), _mm256_max_ps(c0, zero));
                    }
                }
                j += LANES;
            }
            // scalar remainder columns (n % 8), same per-element order.
            while j < n {
                for r in 0..rb {
                    let row = ap.add((i + r) * k);
                    let mut s = 0.0f32;
                    for l in 0..k {
                        s += *row.add(l) * *bp.add(l * n + j);
                    }
                    if epi != Epi::None {
                        s += *bias.add(j);
                    }
                    let base = (i + r) * n + j;
                    *zp.add(base) = s;
                    if epi == Epi::BiasRelu {
                        *act.add(base) = s.max(0.0);
                    }
                }
                j += 1;
            }
            i += rb;
        }
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn matmul_at_b(
        a: &[f32],
        b: &[f32],
        k: usize,
        n: usize,
        out: &mut [f32],
    ) {
        out.fill(0.0);
        let m = a.len() / k;
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let op = out.as_mut_ptr();
        let zero = _mm256_setzero_ps();

        // Row blocks of MR: per (i, j-tile), the block's MR contributions
        // accumulate in a register in ascending row order — the same
        // per-element order as the scalar row-at-a-time loop.
        let mut r = 0;
        while r < m {
            let rb = MR.min(m - r);
            for i in 0..k {
                let mut av = [zero; MR];
                for (t, slot) in av.iter_mut().enumerate().take(rb) {
                    *slot = _mm256_set1_ps(*ap.add((r + t) * k + i));
                }
                let mut j = 0;
                while j + LANES <= n {
                    let mut acc = _mm256_loadu_ps(op.add(i * n + j));
                    for t in 0..rb {
                        let bv = _mm256_loadu_ps(bp.add((r + t) * n + j));
                        acc = _mm256_add_ps(acc, _mm256_mul_ps(av[t], bv));
                    }
                    _mm256_storeu_ps(op.add(i * n + j), acc);
                    j += LANES;
                }
                while j < n {
                    let mut s = *op.add(i * n + j);
                    for t in 0..rb {
                        s += *ap.add((r + t) * k + i) * *bp.add((r + t) * n + j);
                    }
                    *op.add(i * n + j) = s;
                    j += 1;
                }
            }
            r += rb;
        }
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn add_assign(acc: &mut [f32], x: &[f32]) {
        let n = acc.len();
        let ap = acc.as_mut_ptr();
        let xp = x.as_ptr();
        let mut i = 0;
        while i + LANES <= n {
            let v = _mm256_add_ps(_mm256_loadu_ps(ap.add(i)), _mm256_loadu_ps(xp.add(i)));
            _mm256_storeu_ps(ap.add(i), v);
            i += LANES;
        }
        while i < n {
            *ap.add(i) += *xp.add(i);
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn axpy(acc: &mut [f32], x: &[f32], w: f32) {
        let wv = _mm256_set1_ps(w);
        let n = acc.len();
        let ap = acc.as_mut_ptr();
        let xp = x.as_ptr();
        let mut i = 0;
        while i + LANES <= n {
            let t = _mm256_mul_ps(wv, _mm256_loadu_ps(xp.add(i)));
            _mm256_storeu_ps(ap.add(i), _mm256_add_ps(_mm256_loadu_ps(ap.add(i)), t));
            i += LANES;
        }
        while i < n {
            *ap.add(i) += w * *xp.add(i);
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn add(out: &mut [f32], a: &[f32], b: &[f32]) {
        let n = out.len();
        let op = out.as_mut_ptr();
        let (ap, bp) = (a.as_ptr(), b.as_ptr());
        let mut i = 0;
        while i + LANES <= n {
            let v = _mm256_add_ps(_mm256_loadu_ps(ap.add(i)), _mm256_loadu_ps(bp.add(i)));
            _mm256_storeu_ps(op.add(i), v);
            i += LANES;
        }
        while i < n {
            *op.add(i) = *ap.add(i) + *bp.add(i);
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn sub(out: &mut [f32], a: &[f32], b: &[f32]) {
        let n = out.len();
        let op = out.as_mut_ptr();
        let (ap, bp) = (a.as_ptr(), b.as_ptr());
        let mut i = 0;
        while i + LANES <= n {
            let v = _mm256_sub_ps(_mm256_loadu_ps(ap.add(i)), _mm256_loadu_ps(bp.add(i)));
            _mm256_storeu_ps(op.add(i), v);
            i += LANES;
        }
        while i < n {
            *op.add(i) = *ap.add(i) - *bp.add(i);
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn prox_add(g: &mut [f32], w: &[f32], anchor: &[f32], mu: f32) {
        let muv = _mm256_set1_ps(mu);
        let n = g.len();
        let gp = g.as_mut_ptr();
        let (wp, ap) = (w.as_ptr(), anchor.as_ptr());
        let mut i = 0;
        while i + LANES <= n {
            let diff = _mm256_sub_ps(_mm256_loadu_ps(wp.add(i)), _mm256_loadu_ps(ap.add(i)));
            let t = _mm256_mul_ps(muv, diff);
            _mm256_storeu_ps(gp.add(i), _mm256_add_ps(_mm256_loadu_ps(gp.add(i)), t));
            i += LANES;
        }
        while i < n {
            *gp.add(i) += mu * (*wp.add(i) - *ap.add(i));
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn sgd_step(w: &mut [f32], g: &[f32], lr: f32) {
        let lrv = _mm256_set1_ps(lr);
        let n = w.len();
        let wp = w.as_mut_ptr();
        let gp = g.as_ptr();
        let mut i = 0;
        while i + LANES <= n {
            let t = _mm256_mul_ps(lrv, _mm256_loadu_ps(gp.add(i)));
            _mm256_storeu_ps(wp.add(i), _mm256_sub_ps(_mm256_loadu_ps(wp.add(i)), t));
            i += LANES;
        }
        while i < n {
            *wp.add(i) -= lr * *gp.add(i);
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn adam_step(
        w: &mut [f32],
        g: &[f32],
        m: &mut [f32],
        v: &mut [f32],
        p: AdamParams,
    ) {
        let b1v = _mm256_set1_ps(p.b1);
        let b2v = _mm256_set1_ps(p.b2);
        let c1v = _mm256_set1_ps(1.0 - p.b1);
        let c2v = _mm256_set1_ps(1.0 - p.b2);
        let bc1v = _mm256_set1_ps(p.bc1);
        let bc2v = _mm256_set1_ps(p.bc2);
        let lrv = _mm256_set1_ps(p.lr);
        let epsv = _mm256_set1_ps(p.eps);
        let n = w.len();
        let wp = w.as_mut_ptr();
        let gp = g.as_ptr();
        let mp = m.as_mut_ptr();
        let vp = v.as_mut_ptr();
        let mut i = 0;
        while i + LANES <= n {
            let gv = _mm256_loadu_ps(gp.add(i));
            // m = b1*m + (1-b1)*g ; v = b2*v + ((1-b2)*g)*g — scalar order
            let mv = _mm256_add_ps(
                _mm256_mul_ps(b1v, _mm256_loadu_ps(mp.add(i))),
                _mm256_mul_ps(c1v, gv),
            );
            _mm256_storeu_ps(mp.add(i), mv);
            let vv = _mm256_add_ps(
                _mm256_mul_ps(b2v, _mm256_loadu_ps(vp.add(i))),
                _mm256_mul_ps(_mm256_mul_ps(c2v, gv), gv),
            );
            _mm256_storeu_ps(vp.add(i), vv);
            let mhat = _mm256_div_ps(mv, bc1v);
            let vhat = _mm256_div_ps(vv, bc2v);
            let denom = _mm256_add_ps(_mm256_sqrt_ps(vhat), epsv);
            let step = _mm256_div_ps(_mm256_mul_ps(lrv, mhat), denom);
            _mm256_storeu_ps(wp.add(i), _mm256_sub_ps(_mm256_loadu_ps(wp.add(i)), step));
            i += LANES;
        }
        let c1 = 1.0 - p.b1;
        let c2 = 1.0 - p.b2;
        while i < n {
            let gi = *gp.add(i);
            let mi = p.b1 * *mp.add(i) + c1 * gi;
            *mp.add(i) = mi;
            let vi = p.b2 * *vp.add(i) + c2 * gi * gi;
            *vp.add(i) = vi;
            let mhat = mi / p.bc1;
            let vhat = vi / p.bc2;
            *wp.add(i) -= p.lr * mhat / (vhat.sqrt() + p.eps);
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn relu_mask(dz: &mut [f32], da: &[f32], z: &[f32]) {
        let zero = _mm256_setzero_ps();
        let n = dz.len();
        let dp = dz.as_mut_ptr();
        let (ap, zp) = (da.as_ptr(), z.as_ptr());
        let mut i = 0;
        while i + LANES <= n {
            let mask = _mm256_cmp_ps(_mm256_loadu_ps(zp.add(i)), zero, _CMP_GT_OQ);
            _mm256_storeu_ps(dp.add(i), _mm256_and_ps(mask, _mm256_loadu_ps(ap.add(i))));
            i += LANES;
        }
        while i < n {
            *dp.add(i) = if *zp.add(i) > 0.0 { *ap.add(i) } else { 0.0 };
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn max_abs(x: &[f32]) -> f32 {
        let sign = _mm256_set1_ps(-0.0);
        let mut acc = _mm256_setzero_ps();
        let n = x.len();
        let xp = x.as_ptr();
        let mut i = 0;
        while i + LANES <= n {
            let av = _mm256_andnot_ps(sign, _mm256_loadu_ps(xp.add(i)));
            // operand order (av, acc): a NaN lane resolves to acc,
            // matching the scalar fold's NaN-ignoring `m.max(v.abs())`.
            acc = _mm256_max_ps(av, acc);
            i += LANES;
        }
        let mut lanes = [0.0f32; LANES];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        let mut m = lanes.iter().fold(0.0f32, |m, &v| m.max(v));
        while i < n {
            m = m.max((*xp.add(i)).abs());
            i += 1;
        }
        m
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn quant_encode(out: &mut [i8], values: &[f32], scale: f32, qmax: f32) {
        if scale == 0.0 {
            out.fill(0);
            return;
        }
        let sv = _mm256_set1_ps(scale);
        let half = _mm256_set1_ps(0.5);
        let one = _mm256_set1_ps(1.0);
        let sign = _mm256_set1_ps(-0.0);
        let hi = _mm256_set1_ps(qmax);
        let lo = _mm256_set1_ps(-qmax);
        let n = out.len();
        let vp = values.as_ptr();
        let mut i = 0;
        while i + LANES <= n {
            let x = _mm256_div_ps(_mm256_loadu_ps(vp.add(i)), sv);
            // Exact round-half-away-from-zero (f32::round semantics):
            // t = trunc(x) and frac = x - t are both exact, so comparing
            // |frac| >= 0.5 and adding copysign(1, x) reproduces the
            // scalar result bit-for-bit (`_mm256_round_ps` to nearest
            // would round halves to even instead).
            let t = _mm256_round_ps(x, _MM_FROUND_TO_ZERO | _MM_FROUND_NO_EXC);
            let frac = _mm256_sub_ps(x, t);
            let afrac = _mm256_andnot_ps(sign, frac);
            let ge = _mm256_cmp_ps(afrac, half, _CMP_GE_OQ);
            let sone = _mm256_or_ps(_mm256_and_ps(x, sign), one);
            let r = _mm256_add_ps(t, _mm256_and_ps(ge, sone));
            let c = _mm256_max_ps(_mm256_min_ps(r, hi), lo);
            // value is integral in [-qmax, qmax] — the cvt is exact
            let ci = _mm256_cvtps_epi32(c);
            let mut tmp = [0i32; LANES];
            _mm256_storeu_si256(tmp.as_mut_ptr() as *mut __m256i, ci);
            for (o, &code) in out[i..i + LANES].iter_mut().zip(&tmp) {
                *o = code as i8;
            }
            i += LANES;
        }
        for (o, &v) in out[i..].iter_mut().zip(&values[i..]) {
            *o = (v / scale).round().clamp(-qmax, qmax) as i8;
        }
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn dequant(out: &mut [f32], codes: &[i8], scale: f32) {
        let sv = _mm256_set1_ps(scale);
        let n = out.len();
        let op = out.as_mut_ptr();
        let cp = codes.as_ptr();
        let mut i = 0;
        while i + LANES <= n {
            let c8 = _mm_loadl_epi64(cp.add(i) as *const __m128i);
            let c32 = _mm256_cvtepi8_epi32(c8);
            let f = _mm256_cvtepi32_ps(c32);
            _mm256_storeu_ps(op.add(i), _mm256_mul_ps(f, sv));
            i += LANES;
        }
        while i < n {
            *op.add(i) = *cp.add(i) as f32 * scale;
            i += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn kernels_under_test() -> Vec<Kernel> {
        let mut ks = vec![Kernel::Scalar];
        if avx2_available() {
            ks.push(Kernel::Avx2);
        } else {
            eprintln!("skip: AVX2 unavailable, scalar-only kernel tests");
        }
        ks
    }

    fn fill(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.range_f64(-2.0, 2.0) as f32).collect()
    }

    #[test]
    fn kernel_parses_and_rejects() {
        assert_eq!("scalar".parse::<Kernel>().unwrap(), Kernel::Scalar);
        assert_eq!("AVX2".parse::<Kernel>().unwrap(), Kernel::Avx2);
        assert!("sse".parse::<Kernel>().is_err());
        assert_eq!(Kernel::Scalar.name(), "scalar");
        assert_eq!(Kernel::Avx2.name(), "avx2");
    }

    #[test]
    fn override_parsing_handles_empty_and_bad_values() {
        assert_eq!(kernel_override(None).unwrap(), None);
        assert_eq!(kernel_override(Some("")).unwrap(), None);
        assert_eq!(kernel_override(Some("  ")).unwrap(), None);
        assert_eq!(kernel_override(Some("scalar")).unwrap(), Some(Kernel::Scalar));
        assert!(kernel_override(Some("neon")).is_err());
    }

    #[test]
    fn resolve_honors_request_and_detection() {
        if std::env::var_os(KERNEL_ENV).is_some() {
            eprintln!("skip: {KERNEL_ENV} set, precedence exercised via env instead");
            return;
        }
        assert_eq!(resolve_kernel(Some(Kernel::Scalar)).unwrap(), Kernel::Scalar);
        if avx2_available() {
            assert_eq!(resolve_kernel(Some(Kernel::Avx2)).unwrap(), Kernel::Avx2);
            assert_eq!(resolve_kernel(None).unwrap(), Kernel::Avx2);
        } else {
            assert!(resolve_kernel(Some(Kernel::Avx2)).is_err(), "must refuse, not UB");
            assert_eq!(resolve_kernel(None).unwrap(), Kernel::Scalar);
        }
    }

    /// CI dispatcher assertion: on an AVX2 host with no env override the
    /// dispatcher must pick the vector kernel (skip-not-fail otherwise).
    #[test]
    fn dispatcher_picks_vector_kernel_when_available() {
        if std::env::var_os(KERNEL_ENV).is_some() {
            eprintln!("skip: {KERNEL_ENV} override set");
            return;
        }
        if !avx2_available() {
            eprintln!("skip: host has no AVX2");
            return;
        }
        assert_eq!(resolve_kernel(None).unwrap(), Kernel::Avx2);
    }

    #[test]
    fn gemm_shapes_are_bit_identical_across_kernels() {
        let mut rng = Rng::seed_from_u64(0xbeef);
        // ragged n exercises the 16/8/scalar tail split
        for &(m, k, n) in &[(4usize, 7usize, 19usize), (5, 3, 8), (1, 1, 1), (6, 13, 33)] {
            let a = fill(&mut rng, m * k);
            let b = fill(&mut rng, k * n);
            let bias = fill(&mut rng, n);
            let mut want = vec![0.0f32; m * n];
            Kernel::Scalar.matmul(&a, &b, k, n, &mut want);
            for kr in kernels_under_test() {
                let mut out = vec![f32::NAN; m * n];
                kr.matmul(&a, &b, k, n, &mut out);
                assert_eq!(bits(&out), bits(&want), "{} matmul {m}x{k}x{n}", kr.name());
            }
            // fused epilogues against the scalar reference
            let mut zref = vec![0.0f32; m * n];
            let mut aref = vec![0.0f32; m * n];
            Kernel::Scalar.matmul_bias_relu(&a, &b, &bias, k, n, &mut zref, &mut aref);
            for kr in kernels_under_test() {
                let mut z = vec![f32::NAN; m * n];
                let mut act = vec![f32::NAN; m * n];
                kr.matmul_bias_relu(&a, &b, &bias, k, n, &mut z, &mut act);
                assert_eq!(bits(&z), bits(&zref), "{} fused z", kr.name());
                assert_eq!(bits(&act), bits(&aref), "{} fused act", kr.name());
            }
        }
    }

    #[test]
    fn transposed_product_matches_dot_product_reference() {
        let mut rng = Rng::seed_from_u64(0x7ab1e);
        let (m, n, k) = (5usize, 11usize, 9usize);
        let a = fill(&mut rng, m * n);
        let b = fill(&mut rng, k * n);
        // seed semantics: out[i,j] = Σ_l a[i,l] * b[j,l] via f32 sum fold
        let mut want = vec![0.0f32; m * k];
        for (ar, or) in a.chunks_exact(n).zip(want.chunks_exact_mut(k)) {
            for (o, br) in or.iter_mut().zip(b.chunks_exact(n)) {
                *o = ar.iter().zip(br).map(|(x, y)| x * y).sum();
            }
        }
        for kr in kernels_under_test() {
            let mut bt = vec![0.0f32; n * k];
            let mut out = vec![f32::NAN; m * k];
            kr.matmul_a_bt(&a, &b, n, k, &mut bt, &mut out);
            assert_eq!(bits(&out), bits(&want), "{} a@bt", kr.name());
        }
    }

    #[test]
    fn quant_encode_matches_f32_round_on_half_cases() {
        // values that separate round-half-away from round-half-even and
        // from the naive trunc(x + 0.5) trick
        let tricky = [
            0.5f32, -0.5, 1.5, -1.5, 2.5, -2.5, 126.5, -126.5, 0.499_999_97, -0.499_999_97,
            130.0, -130.0, 0.0, 1.0e-8, 3.49, -3.51,
        ];
        let scale = 1.0f32;
        let want: Vec<i8> = tricky
            .iter()
            .map(|v| (v / scale).round().clamp(-127.0, 127.0) as i8)
            .collect();
        for kr in kernels_under_test() {
            let mut out = vec![0i8; tricky.len()];
            kr.quant_encode(&mut out, &tricky, scale, 127.0);
            assert_eq!(out, want, "{} half-case rounding", kr.name());
        }
    }

    #[test]
    fn zero_length_inputs_are_noops() {
        for kr in kernels_under_test() {
            let mut out: Vec<f32> = Vec::new();
            kr.matmul(&[], &[0.0; 3], 1, 3, &mut out); // m = 0
            kr.add_assign(&mut out, &[]);
            kr.axpy(&mut out, &[], 0.5);
            kr.sgd_step(&mut out, &[], 0.1);
            kr.relu_mask(&mut out, &[], &[]);
            assert_eq!(kr.max_abs(&[]), 0.0);
            let mut codes: Vec<i8> = Vec::new();
            kr.quant_encode(&mut codes, &[], 1.0, 127.0);
            kr.dequant(&mut out, &codes, 1.0);
            assert!(out.is_empty() && codes.is_empty());
        }
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }
}
