//! `ModelRuntime` — one model family's four compiled entrypoints plus the
//! typed argument marshalling between Rust buffers and XLA literals.
//!
//! This is the only place where the flat-parameter convention (DESIGN.md
//! §1) is materialized: params / Adam moments / updates are plain
//! `Vec<f32>`, features are [`Features`], and each call maps to exactly
//! one PJRT execution.

use std::path::{Path, PathBuf};
use std::time::Duration;

use anyhow::{anyhow, bail};

use super::engine::{
    lit_f32, lit_i32, scalar_f32, scalar_i32, to_scalar_f32, to_vec_f32, Engine, Executable,
};
use super::manifest::Manifest;
use crate::data::Features;
use crate::Result;

/// Inputs of one local training round (Algorithm 1, Client_Update).
pub struct TrainRequest<'a> {
    pub params: &'a [f32],
    /// Adam first/second moments; zeroed by stateless FaaS clients.
    pub m: &'a [f32],
    pub v: &'a [f32],
    /// Optimizer step counter (f32 in the lowered module).
    pub t: f32,
    pub x: &'a Features,
    pub y: &'a [i32],
    /// Shuffling / dropout seed for this invocation.
    pub seed: i32,
    /// Partial-work cutoff (FedProx toleration); pass
    /// `manifest.steps_per_round` for full work.
    pub num_steps: i32,
    /// FedProx anchor; `Some` routes to the `train_prox` entrypoint.
    pub global: Option<&'a [f32]>,
}

/// Outputs of one local training round.
#[derive(Debug, Clone)]
pub struct TrainResult {
    pub params: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub t: f32,
    /// Mean training loss over the executed steps.
    pub loss: f32,
}

/// Central evaluation result.
#[derive(Debug, Clone, Copy)]
pub struct EvalResult {
    pub loss: f32,
    pub accuracy: f32,
}

/// One model family's compiled artifact set.
pub struct ModelRuntime {
    pub manifest: Manifest,
    pub dir: PathBuf,
    train: Executable,
    train_prox: Executable,
    eval_exe: Executable,
    aggregate_exe: Executable,
    /// Total XLA compile time across the four entrypoints.
    pub compile_time: Duration,
}

impl ModelRuntime {
    /// Load and compile all four entrypoints of `<dir>/<model>.*`.
    pub fn load(engine: &Engine, dir: &Path, model: &str) -> Result<Self> {
        let manifest = Manifest::load(dir, model)?;
        let load = |ep: &str| -> Result<Executable> {
            engine.load_hlo(&manifest.hlo_path(dir, ep)?)
        };
        let train = load("train")?;
        let train_prox = load("train_prox")?;
        let eval_exe = load("eval")?;
        let aggregate_exe = load("aggregate")?;
        let compile_time = train.compile_time
            + train_prox.compile_time
            + eval_exe.compile_time
            + aggregate_exe.compile_time;
        Ok(Self {
            manifest,
            dir: dir.to_path_buf(),
            train,
            train_prox,
            eval_exe,
            aggregate_exe,
            compile_time,
        })
    }

    /// The seed-0 initial global model.
    pub fn init_params(&self) -> Result<Vec<f32>> {
        self.manifest.load_init(&self.dir)
    }

    fn check_params(&self, what: &str, p: &[f32]) -> Result<()> {
        if p.len() != self.manifest.param_count {
            bail!(
                "{}: {what} has {} elements, expected P={}",
                self.manifest.name,
                p.len(),
                self.manifest.param_count
            );
        }
        Ok(())
    }

    fn features_literal(&self, x: &Features, n: usize) -> Result<xla::Literal> {
        let mut dims: Vec<i64> = vec![n as i64];
        dims.extend(self.manifest.input_shape.iter().map(|&d| d as i64));
        match (x, self.manifest.input_dtype.as_str()) {
            (Features::F32(v), "f32") => lit_f32(v, &dims),
            (Features::I32(v), "i32") => lit_i32(v, &dims),
            (got, want) => Err(anyhow!(
                "{}: features dtype {} but manifest wants {want}",
                self.manifest.name,
                got.dtype()
            )),
        }
    }

    /// Execute one full local training round (a single PJRT call).
    /// Returns the result and the device wall time (the FaaS simulator's
    /// compute-time input).
    pub fn train_round(&self, req: &TrainRequest) -> Result<(TrainResult, Duration)> {
        let mf = &self.manifest;
        self.check_params("params", req.params)?;
        self.check_params("m", req.m)?;
        self.check_params("v", req.v)?;
        if req.y.len() != mf.shard_size {
            bail!("{}: y has {} labels, want {}", mf.name, req.y.len(), mf.shard_size);
        }
        let expect = mf.shard_size * mf.sample_elems();
        if req.x.len() != expect {
            bail!("{}: x has {} elements, want {}", mf.name, req.x.len(), expect);
        }
        if req.num_steps < 0 || req.num_steps as usize > mf.steps_per_round {
            bail!(
                "{}: num_steps {} outside [0, {}]",
                mf.name,
                req.num_steps,
                mf.steps_per_round
            );
        }

        let p = mf.param_count as i64;
        let mut args: Vec<xla::Literal> = vec![
            lit_f32(req.params, &[p])?,
            lit_f32(req.m, &[p])?,
            lit_f32(req.v, &[p])?,
            scalar_f32(req.t),
            self.features_literal(req.x, mf.shard_size)?,
            lit_i32(req.y, &[mf.shard_size as i64])?,
            scalar_i32(req.seed),
            scalar_i32(req.num_steps),
        ];
        let exe = if let Some(g) = req.global {
            self.check_params("global", g)?;
            args.push(lit_f32(g, &[p])?);
            &self.train_prox
        } else {
            &self.train
        };
        let (out, wall) = exe.run(&args)?;
        if out.len() != 5 {
            bail!("{}: train returned {} outputs, want 5", mf.name, out.len());
        }
        Ok((
            TrainResult {
                params: to_vec_f32(&out[0])?,
                m: to_vec_f32(&out[1])?,
                v: to_vec_f32(&out[2])?,
                t: to_scalar_f32(&out[3])?,
                loss: to_scalar_f32(&out[4])?,
            },
            wall,
        ))
    }

    /// Central federated evaluation on the fixed-size test set.
    pub fn evaluate(&self, params: &[f32], x: &Features, y: &[i32]) -> Result<EvalResult> {
        let mf = &self.manifest;
        self.check_params("params", params)?;
        if y.len() != mf.eval_size {
            bail!("{}: eval y has {} labels, want {}", mf.name, y.len(), mf.eval_size);
        }
        let args = vec![
            lit_f32(params, &[mf.param_count as i64])?,
            self.features_literal(x, mf.eval_size)?,
            lit_i32(y, &[mf.eval_size as i64])?,
        ];
        let (out, _) = self.eval_exe.run(&args)?;
        if out.len() != 2 {
            bail!("{}: eval returned {} outputs, want 2", mf.name, out.len());
        }
        let loss_sum = to_scalar_f32(&out[0])?;
        let correct = to_scalar_f32(&out[1])?;
        Ok(EvalResult {
            loss: loss_sum / mf.eval_size as f32,
            accuracy: correct / mf.eval_size as f32,
        })
    }

    /// Weighted aggregation through the Pallas kernel. `updates.len()`
    /// must be <= `k_max`; missing rows are zero-padded (exact, see the
    /// kernel tests). Weight semantics (Eq. 3 / FedAvg) belong to the
    /// caller.
    pub fn aggregate(
        &self,
        updates: &[&[f32]],
        weights: &[f32],
    ) -> Result<(Vec<f32>, Duration)> {
        let mf = &self.manifest;
        if updates.len() != weights.len() {
            bail!(
                "{}: {} updates vs {} weights",
                mf.name,
                updates.len(),
                weights.len()
            );
        }
        if updates.is_empty() {
            bail!("{}: aggregate called with no updates", mf.name);
        }
        if updates.len() > mf.k_max {
            bail!(
                "{}: {} updates exceed k_max={}",
                mf.name,
                updates.len(),
                mf.k_max
            );
        }
        let p = mf.param_count;
        let mut stacked = vec![0f32; mf.k_max * p];
        for (i, u) in updates.iter().enumerate() {
            self.check_params("update", u)?;
            stacked[i * p..(i + 1) * p].copy_from_slice(u);
        }
        let mut w = vec![0f32; mf.k_max];
        w[..weights.len()].copy_from_slice(weights);
        let args = vec![
            lit_f32(&stacked, &[mf.k_max as i64, p as i64])?,
            lit_f32(&w, &[mf.k_max as i64])?,
        ];
        let (out, wall) = self.aggregate_exe.run(&args)?;
        if out.len() != 1 {
            bail!("{}: aggregate returned {} outputs, want 1", mf.name, out.len());
        }
        Ok((to_vec_f32(&out[0])?, wall))
    }
}
