//! `ModelRuntime` — the PJRT execution backend (`pjrt` cargo feature):
//! one model family's four compiled entrypoints plus the typed argument
//! marshalling between Rust buffers and XLA literals.
//!
//! This is the only place where the flat-parameter convention (DESIGN.md
//! §1) crosses into XLA: params / Adam moments / updates are plain
//! `Vec<f32>`, features are [`Features`], and each call maps to exactly
//! one PJRT execution. Shape/dtype validation is shared with the native
//! backend (see [`super::backend`]).

use std::path::{Path, PathBuf};
use std::time::Duration;

use anyhow::bail;

use super::backend::{
    check_aggregate_args, check_eval_args, check_train_request, AggregateFold, Backend,
    BufferedFold, EvalResult, TrainRequest, TrainResult,
};
use super::engine::{
    lit_f32, lit_i32, scalar_f32, scalar_i32, to_scalar_f32, to_vec_f32, Engine, Executable,
};
use super::manifest::Manifest;
use crate::data::Features;
use crate::Result;

/// One model family's compiled artifact set.
pub struct ModelRuntime {
    pub manifest: Manifest,
    pub dir: PathBuf,
    train: Executable,
    train_prox: Executable,
    eval_exe: Executable,
    aggregate_exe: Executable,
    /// Total XLA compile time across the four entrypoints.
    pub compile_time: Duration,
}

impl ModelRuntime {
    /// Load and compile all four entrypoints of `<dir>/<model>.*`.
    pub fn load(engine: &Engine, dir: &Path, model: &str) -> Result<Self> {
        let manifest = Manifest::load(dir, model)?;
        let load = |ep: &str| -> Result<Executable> {
            engine.load_hlo(&manifest.hlo_path(dir, ep)?)
        };
        let train = load("train")?;
        let train_prox = load("train_prox")?;
        let eval_exe = load("eval")?;
        let aggregate_exe = load("aggregate")?;
        let compile_time = train.compile_time
            + train_prox.compile_time
            + eval_exe.compile_time
            + aggregate_exe.compile_time;
        Ok(Self {
            manifest,
            dir: dir.to_path_buf(),
            train,
            train_prox,
            eval_exe,
            aggregate_exe,
            compile_time,
        })
    }

    /// The seed-0 initial global model.
    pub fn init_params(&self) -> Result<Vec<f32>> {
        self.manifest.load_init(&self.dir)
    }

    fn features_literal(&self, x: &Features, n: usize) -> Result<xla::Literal> {
        let mut dims: Vec<i64> = vec![n as i64];
        dims.extend(self.manifest.input_shape.iter().map(|&d| d as i64));
        match x {
            Features::F32(v) => lit_f32(v, &dims),
            Features::I32(v) => lit_i32(v, &dims),
        }
    }

    /// Execute one full local training round (a single PJRT call).
    /// Returns the result and the device wall time (the FaaS simulator's
    /// compute-time input).
    pub fn train_round(&self, req: &TrainRequest) -> Result<(TrainResult, Duration)> {
        let mf = &self.manifest;
        check_train_request(mf, req)?;

        let p = mf.param_count as i64;
        let mut args: Vec<xla::Literal> = vec![
            lit_f32(req.params, &[p])?,
            lit_f32(req.m, &[p])?,
            lit_f32(req.v, &[p])?,
            scalar_f32(req.t),
            self.features_literal(req.x, mf.shard_size)?,
            lit_i32(req.y, &[mf.shard_size as i64])?,
            scalar_i32(req.seed),
            scalar_i32(req.num_steps),
        ];
        let exe = if let Some(g) = req.global {
            args.push(lit_f32(g, &[p])?);
            &self.train_prox
        } else {
            &self.train
        };
        let (out, wall) = exe.run(&args)?;
        if out.len() != 5 {
            bail!("{}: train returned {} outputs, want 5", mf.name, out.len());
        }
        Ok((
            TrainResult {
                params: to_vec_f32(&out[0])?,
                m: to_vec_f32(&out[1])?,
                v: to_vec_f32(&out[2])?,
                t: to_scalar_f32(&out[3])?,
                loss: to_scalar_f32(&out[4])?,
            },
            wall,
        ))
    }

    /// Central federated evaluation on the fixed-size test set.
    pub fn evaluate(&self, params: &[f32], x: &Features, y: &[i32]) -> Result<EvalResult> {
        let mf = &self.manifest;
        check_eval_args(mf, params, x, y)?;
        let args = vec![
            lit_f32(params, &[mf.param_count as i64])?,
            self.features_literal(x, mf.eval_size)?,
            lit_i32(y, &[mf.eval_size as i64])?,
        ];
        let (out, _) = self.eval_exe.run(&args)?;
        if out.len() != 2 {
            bail!("{}: eval returned {} outputs, want 2", mf.name, out.len());
        }
        let loss_sum = to_scalar_f32(&out[0])?;
        let correct = to_scalar_f32(&out[1])?;
        Ok(EvalResult {
            loss: loss_sum / mf.eval_size as f32,
            accuracy: correct / mf.eval_size as f32,
        })
    }

    /// Weighted aggregation through the Pallas kernel. `updates.len()`
    /// must be <= `k_max`; missing rows are zero-padded (exact, see the
    /// kernel tests). Weight semantics (Eq. 3 / FedAvg) belong to the
    /// caller.
    pub fn aggregate(
        &self,
        updates: &[&[f32]],
        weights: &[f32],
    ) -> Result<(Vec<f32>, Duration)> {
        let mf = &self.manifest;
        check_aggregate_args(mf, updates, weights)?;
        let p = mf.param_count;
        let mut stacked = vec![0f32; mf.k_max * p];
        for (i, u) in updates.iter().enumerate() {
            stacked[i * p..(i + 1) * p].copy_from_slice(u);
        }
        let mut w = vec![0f32; mf.k_max];
        w[..weights.len()].copy_from_slice(weights);
        let args = vec![
            lit_f32(&stacked, &[mf.k_max as i64, p as i64])?,
            lit_f32(&w, &[mf.k_max as i64])?,
        ];
        let (out, wall) = self.aggregate_exe.run(&args)?;
        if out.len() != 1 {
            bail!("{}: aggregate returned {} outputs, want 1", mf.name, out.len());
        }
        Ok((to_vec_f32(&out[0])?, wall))
    }
}

/// The PJRT path packaged as a [`Backend`]. PJRT client handles are not
/// `Send`/`Sync`, but the `Backend` trait requires `Sync` (the round
/// scheduler shares one backend across worker threads), so this struct
/// holds only plain data — artifacts directory, model name and a cached
/// manifest — and resolves the actual engine + compiled executables
/// through thread-local storage: each worker thread lazily compiles its
/// own [`ModelRuntime`] on first use and reuses it afterwards.
pub struct PjrtBackend {
    dir: PathBuf,
    model: String,
    manifest: Manifest,
}

/// A per-thread compiled runtime plus the engine that owns its buffers
/// (kept alive together for as long as the cache entry exists).
struct ThreadRuntime {
    _engine: std::rc::Rc<Engine>,
    runtime: ModelRuntime,
}

thread_local! {
    /// One PJRT client per thread (handles are not Send/Sync): loading
    /// several model families — e.g. the 4-dataset repro matrix — reuses
    /// a single client instead of instantiating one per dataset.
    static SHARED_ENGINE: std::cell::RefCell<std::rc::Weak<Engine>> =
        std::cell::RefCell::new(std::rc::Weak::new());

    /// Per-thread compiled artifact sets, keyed by (artifacts dir,
    /// model). Worker threads of the parallel scheduler each get their
    /// own engine and executables; within a thread, repeated calls hit
    /// the cache.
    static THREAD_RUNTIMES: std::cell::RefCell<
        std::collections::HashMap<(PathBuf, String), std::rc::Rc<ThreadRuntime>>,
    > = std::cell::RefCell::new(std::collections::HashMap::new());
}

fn shared_engine() -> Result<std::rc::Rc<Engine>> {
    SHARED_ENGINE.with(|slot| {
        if let Some(engine) = slot.borrow().upgrade() {
            return Ok(engine);
        }
        let engine = std::rc::Rc::new(Engine::cpu()?);
        *slot.borrow_mut() = std::rc::Rc::downgrade(&engine);
        Ok(engine)
    })
}

impl PjrtBackend {
    /// Compile the artifact set for `model` on the calling thread's PJRT
    /// client (so load/compile errors surface here, not mid-round).
    pub fn load(artifacts_dir: &Path, model: &str) -> Result<Self> {
        let backend = Self {
            dir: artifacts_dir.to_path_buf(),
            model: model.to_string(),
            manifest: Manifest::load(artifacts_dir, model)?,
        };
        backend.with_runtime(|_| Ok(()))?;
        Ok(backend)
    }

    /// Run `f` against this thread's compiled runtime, compiling it
    /// first if this thread has never executed this model.
    fn with_runtime<R>(&self, f: impl FnOnce(&ModelRuntime) -> Result<R>) -> Result<R> {
        THREAD_RUNTIMES.with(|cell| {
            let key = (self.dir.clone(), self.model.clone());
            let cached = cell.borrow().get(&key).cloned();
            let entry = match cached {
                Some(entry) => entry,
                None => {
                    let engine = shared_engine()?;
                    let runtime = ModelRuntime::load(&engine, &self.dir, &self.model)?;
                    let entry = std::rc::Rc::new(ThreadRuntime {
                        _engine: engine,
                        runtime,
                    });
                    cell.borrow_mut().insert(key, entry.clone());
                    entry
                }
            };
            f(&entry.runtime)
        })
    }
}

impl Backend for PjrtBackend {
    fn backend_name(&self) -> &'static str {
        "pjrt"
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn init_params(&self) -> Result<Vec<f32>> {
        self.with_runtime(|rt| rt.init_params())
    }

    fn train_round(&self, req: &TrainRequest) -> Result<(TrainResult, Duration)> {
        self.with_runtime(|rt| rt.train_round(req))
    }

    fn evaluate(&self, params: &[f32], x: &Features, y: &[i32]) -> Result<EvalResult> {
        self.with_runtime(|rt| rt.evaluate(params, x, y))
    }

    fn aggregate(&self, updates: &[&[f32]], weights: &[f32]) -> Result<(Vec<f32>, Duration)> {
        self.with_runtime(|rt| rt.aggregate(updates, weights))
    }

    /// The Pallas aggregation kernel is one HLO call over a stacked
    /// `[k_max, P]` buffer, so streaming element folds would launch one
    /// execution per update. Keep the batch semantics behind the fold
    /// API: buffer the updates and run the kernel once at `finish`.
    fn begin_fold(&self, expected_k: usize) -> Result<Box<dyn AggregateFold + '_>> {
        Ok(Box::new(BufferedFold::new(self, expected_k)))
    }

    /// Compiled engine handles live in thread-local storage, so fanning
    /// out across many executor workers would compile one engine per
    /// worker. Opt out: the persistent pool then runs a **single
    /// long-lived worker**, which compiles once (via
    /// [`Backend::init_worker`]) and stays warm for the whole
    /// experiment — the same compile-once economics as the old inline
    /// path, without tying compute to the coordinator's thread.
    fn parallel_train(&self) -> bool {
        false
    }

    /// Warm this worker thread's engine cache before it accepts jobs:
    /// compile the model into the thread-local runtime so the first
    /// training job doesn't pay the compile latency.
    fn init_worker(&self) -> Result<()> {
        self.with_runtime(|_| Ok(()))
    }
}
