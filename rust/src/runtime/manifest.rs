//! AOT artifact manifests — the contract between `python/compile/aot.py`
//! and the Rust runtime. One manifest per model variant describes the HLO
//! entrypoints, tensor shapes and the Table-I hyperparameters baked into
//! the lowered module.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context};

use crate::util::Json;
use crate::Result;

/// One lowered HLO entrypoint (train / train_prox / eval / aggregate).
#[derive(Debug, Clone)]
pub struct Entrypoint {
    /// HLO text file name, relative to the artifacts directory.
    pub file: String,
    /// Positional input names, in lowering order.
    pub inputs: Vec<String>,
    /// Output tuple element names, in order.
    pub outputs: Vec<String>,
}

/// Manifest for one (model family, scale) artifact set.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub name: String,
    pub scale: String,
    /// Flat parameter vector length P.
    pub param_count: usize,
    pub num_classes: usize,
    /// Per-sample feature shape (e.g. `[28, 28, 1]` or `[seq_len]`).
    pub input_shape: Vec<usize>,
    /// `"f32"` for image models, `"i32"` for token models.
    pub input_dtype: String,
    /// Fixed per-client local dataset cardinality N.
    pub shard_size: usize,
    pub batch_size: usize,
    pub local_epochs: usize,
    /// `local_epochs * shard_size / batch_size` — optimizer steps per round.
    pub steps_per_round: usize,
    pub optimizer: String,
    pub lr: f64,
    pub prox_mu: f64,
    pub eval_size: usize,
    pub eval_batch: usize,
    /// Max stacked updates per aggregate call (zero-padded below).
    pub k_max: usize,
    pub seq_len: Option<usize>,
    /// Rough fwd+bwd flop estimate per local round (cost model input).
    pub flops_per_round: u64,
    pub entrypoints: HashMap<String, Entrypoint>,
    pub init_file: String,
    pub init_sha256: String,
    pub init_seed: u64,
}

impl Manifest {
    /// Load `<dir>/<model>.manifest.json`.
    pub fn load(dir: &Path, model: &str) -> Result<Self> {
        let path = dir.join(format!("{model}.manifest.json"));
        let j = Json::parse_file(&path)?;
        let m = Self::from_json(&j)
            .with_context(|| format!("decoding manifest {}", path.display()))?;
        m.validate()?;
        Ok(m)
    }

    fn from_json(j: &Json) -> Result<Self> {
        let str_vec = |v: &Json| -> Result<Vec<String>> {
            v.as_arr()?.iter().map(|s| Ok(s.as_str()?.to_string())).collect()
        };
        let mut entrypoints = HashMap::new();
        for (name, ep) in j.get("entrypoints")?.as_obj()? {
            entrypoints.insert(
                name.clone(),
                Entrypoint {
                    file: ep.get("file")?.as_str()?.to_string(),
                    inputs: str_vec(ep.get("inputs")?)?,
                    outputs: str_vec(ep.get("outputs")?)?,
                },
            );
        }
        Ok(Manifest {
            name: j.get("name")?.as_str()?.to_string(),
            scale: j.get("scale")?.as_str()?.to_string(),
            param_count: j.get("param_count")?.as_usize()?,
            num_classes: j.get("num_classes")?.as_usize()?,
            input_shape: j
                .get("input_shape")?
                .as_arr()?
                .iter()
                .map(|v| v.as_usize())
                .collect::<Result<_>>()?,
            input_dtype: j.get("input_dtype")?.as_str()?.to_string(),
            shard_size: j.get("shard_size")?.as_usize()?,
            batch_size: j.get("batch_size")?.as_usize()?,
            local_epochs: j.get("local_epochs")?.as_usize()?,
            steps_per_round: j.get("steps_per_round")?.as_usize()?,
            optimizer: j.get("optimizer")?.as_str()?.to_string(),
            lr: j.get("lr")?.as_f64()?,
            prox_mu: j.get("prox_mu")?.as_f64()?,
            eval_size: j.get("eval_size")?.as_usize()?,
            eval_batch: j.get("eval_batch")?.as_usize()?,
            k_max: j.get("k_max")?.as_usize()?,
            seq_len: match j.get("seq_len")? {
                Json::Null => None,
                v => Some(v.as_usize()?),
            },
            flops_per_round: j.get("flops_per_round")?.as_u64()?,
            init_file: j.get("init_file")?.as_str()?.to_string(),
            init_sha256: j.get("init_sha256")?.as_str()?.to_string(),
            init_seed: j.get("init_seed")?.as_u64()?,
            entrypoints,
        })
    }

    /// Internal consistency checks (cheap; run on every load).
    pub fn validate(&self) -> Result<()> {
        if self.param_count == 0 {
            bail!("{}: param_count == 0", self.name);
        }
        if self.batch_size == 0 {
            bail!("{}: batch_size must be positive", self.name);
        }
        if self.shard_size % self.batch_size != 0 {
            bail!("{}: batch_size must divide shard_size", self.name);
        }
        // eval_batch need not divide eval_size: backends process the
        // ragged tail batch (it used to be silently dropped).
        if self.eval_batch == 0 {
            bail!("{}: eval_batch must be positive", self.name);
        }
        if self.steps_per_round != self.shard_size / self.batch_size * self.local_epochs {
            bail!("{}: steps_per_round inconsistent", self.name);
        }
        for ep in ["train", "train_prox", "eval", "aggregate"] {
            if !self.entrypoints.contains_key(ep) {
                bail!("{}: missing entrypoint {ep}", self.name);
            }
        }
        match self.input_dtype.as_str() {
            "f32" | "i32" => {}
            d => bail!("{}: unsupported input dtype {d}", self.name),
        }
        Ok(())
    }

    /// Flat feature element count per sample.
    pub fn sample_elems(&self) -> usize {
        self.input_shape.iter().product()
    }

    /// Path of an entrypoint's HLO file.
    pub fn hlo_path(&self, dir: &Path, ep: &str) -> Result<PathBuf> {
        let e = self
            .entrypoints
            .get(ep)
            .ok_or_else(|| anyhow!("{}: no entrypoint {ep}", self.name))?;
        Ok(dir.join(&e.file))
    }

    /// Load the seed-0 initial flat parameter vector (little-endian f32).
    pub fn load_init(&self, dir: &Path) -> Result<Vec<f32>> {
        let path = dir.join(&self.init_file);
        let raw = std::fs::read(&path)
            .with_context(|| format!("reading init params {}", path.display()))?;
        if raw.len() != 4 * self.param_count {
            bail!(
                "{}: init file has {} bytes, expected {}",
                self.name,
                raw.len(),
                4 * self.param_count
            );
        }
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Uncompressed model payload size in MB (network-transfer model input).
    pub fn payload_mb(&self) -> f64 {
        (self.param_count * 4) as f64 / 1e6
    }
}

/// `index.json` written alongside the manifests.
#[derive(Debug, Clone)]
pub struct ArtifactIndex {
    pub scale: String,
    pub models: Vec<String>,
    pub manifests: HashMap<String, String>,
}

impl ArtifactIndex {
    pub fn load(dir: &Path) -> Result<Self> {
        let j = Json::parse_file(&dir.join("index.json"))?;
        Ok(Self {
            scale: j.get("scale")?.as_str()?.to_string(),
            models: j
                .get("models")?
                .as_arr()?
                .iter()
                .map(|v| Ok(v.as_str()?.to_string()))
                .collect::<Result<_>>()?,
            manifests: j
                .get("manifests")?
                .as_obj()?
                .iter()
                .map(|(k, v)| Ok((k.clone(), v.as_str()?.to_string())))
                .collect::<Result<_>>()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dummy() -> Manifest {
        let ep = |f: &str| Entrypoint {
            file: f.into(),
            inputs: vec!["params".into()],
            outputs: vec!["params".into()],
        };
        Manifest {
            name: "m".into(),
            scale: "smoke".into(),
            param_count: 10,
            num_classes: 2,
            input_shape: vec![4, 4, 1],
            input_dtype: "f32".into(),
            shard_size: 20,
            batch_size: 10,
            local_epochs: 5,
            steps_per_round: 10,
            optimizer: "adam".into(),
            lr: 1e-3,
            prox_mu: 0.01,
            eval_size: 128,
            eval_batch: 128,
            k_max: 8,
            seq_len: None,
            flops_per_round: 1000,
            entrypoints: ["train", "train_prox", "eval", "aggregate"]
                .iter()
                .map(|n| (n.to_string(), ep(&format!("m.{n}.hlo.txt"))))
                .collect(),
            init_file: "m.init.bin".into(),
            init_sha256: "x".into(),
            init_seed: 0,
        }
    }

    #[test]
    fn validate_accepts_consistent_manifest() {
        dummy().validate().unwrap();
    }

    #[test]
    fn validate_accepts_ragged_eval_but_rejects_zero_batches() {
        let mut m = dummy();
        m.eval_size = 10;
        m.eval_batch = 4; // ragged tail batch of 2 — processed, not dropped
        m.validate().unwrap();
        m.eval_batch = 0;
        assert!(m.validate().is_err());
        let mut m = dummy();
        m.batch_size = 0;
        assert!(m.validate().is_err(), "zero batch_size must not panic");
    }

    #[test]
    fn validate_rejects_bad_steps() {
        let mut m = dummy();
        m.steps_per_round = 7;
        assert!(m.validate().is_err());
    }

    #[test]
    fn validate_rejects_missing_entrypoint() {
        let mut m = dummy();
        m.entrypoints.remove("eval");
        assert!(m.validate().is_err());
    }

    #[test]
    fn validate_rejects_unknown_dtype() {
        let mut m = dummy();
        m.input_dtype = "f64".into();
        assert!(m.validate().is_err());
    }

    #[test]
    fn sample_elems_products_shape() {
        assert_eq!(dummy().sample_elems(), 16);
    }

    #[test]
    fn init_roundtrip() {
        let dir = std::env::temp_dir().join(format!("fedless-manifest-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let m = dummy();
        let vals: Vec<f32> = (0..10).map(|i| i as f32 * 0.5).collect();
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(dir.join(&m.init_file), bytes).unwrap();
        assert_eq!(m.load_init(&dir).unwrap(), vals);
        std::fs::remove_dir_all(&dir).ok();
    }
}
