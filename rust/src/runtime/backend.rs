//! The pluggable execution backend seam: everything the coordinator needs
//! from "the thing that computes" — local training rounds, central
//! evaluation, weighted aggregation and the initial global model — behind
//! one object-safe trait.
//!
//! Two implementations exist:
//!
//! * [`NativeBackend`](super::NativeBackend) (default build): pure-Rust
//!   dense-MLP forward/backward with the SGD/Adam steps and the
//!   staleness-weighted aggregation of `python/compile/kernels/ref.py`.
//!   Zero external dependencies; this is what CI and the tier-1 tests run.
//! * `ModelRuntime` (behind the `pjrt` cargo feature): the AOT HLO
//!   artifact path through the PJRT C API, structurally identical models
//!   to the paper's (§VI-A2).
//!
//! Both share the argument-validation helpers below, so shape/dtype
//! errors are identical across backends.

use std::path::Path;
use std::time::Duration;

use anyhow::bail;

use super::manifest::Manifest;
use crate::data::Features;
use crate::Result;

/// Inputs of one local training round (Algorithm 1, Client_Update).
pub struct TrainRequest<'a> {
    pub params: &'a [f32],
    /// Adam first/second moments; zeroed by stateless FaaS clients.
    pub m: &'a [f32],
    pub v: &'a [f32],
    /// Optimizer step counter (f32 across the backend boundary).
    pub t: f32,
    pub x: &'a Features,
    pub y: &'a [i32],
    /// Shuffling / dropout seed for this invocation.
    pub seed: i32,
    /// Partial-work cutoff (FedProx toleration); pass
    /// `manifest.steps_per_round` for full work.
    pub num_steps: i32,
    /// FedProx anchor; `Some` routes to the proximal training variant.
    pub global: Option<&'a [f32]>,
}

/// Outputs of one local training round.
#[derive(Debug, Clone)]
pub struct TrainResult {
    pub params: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub t: f32,
    /// Mean training loss over the executed steps.
    pub loss: f32,
}

/// Central evaluation result.
#[derive(Debug, Clone, Copy)]
pub struct EvalResult {
    pub loss: f32,
    pub accuracy: f32,
}

/// An in-progress streaming aggregation (`begin → accumulate → finish`):
/// updates fold into a single O(P) accumulator one at a time
/// (`acc += w * update`, Eq. 3 inner sum in registration order), so the
/// caller never has to materialize all `k` update vectors
/// simultaneously. Obtain one via [`Backend::begin_fold`]; the batch
/// [`Backend::aggregate`] is a thin wrapper that pushes every update
/// through a fold.
pub trait AggregateFold {
    /// Fold one weighted update into the accumulator. Zero-weight
    /// entries are skipped, matching the batch scalar reference. Fails
    /// on a shape mismatch or once `k_max` updates have been folded.
    fn accumulate(&mut self, update: &[f32], weight: f32) -> Result<()>;

    /// Number of updates folded so far.
    fn count(&self) -> usize;

    /// Bytes of parameter data this fold currently holds — O(P) for a
    /// streaming accumulator, O(count × P) for a buffering fold. Feeds
    /// the coordinator's `param_plane_peak_bytes` accounting, so batch
    /// backends report their true footprint.
    fn held_bytes(&self) -> usize;

    /// Consume the fold: the weighted sum plus the aggregation wall
    /// time. Fails if no update was folded.
    fn finish(self: Box<Self>) -> Result<(Vec<f32>, Duration)>;
}

/// One model family's execution engine. Object-safe: the coordinator and
/// the repro harness hold `&dyn Backend` / `Box<dyn Backend>`.
///
/// `Sync` is a supertrait: the round scheduler shares one backend across
/// scoped worker threads ([`crate::sched::train_parallel`]), so every
/// implementation must be callable concurrently through `&self`. The
/// native backend is stateless per call; the PJRT backend keeps its
/// non-`Send` engine handles in thread-local storage (one engine per
/// worker thread) to satisfy the bound.
pub trait Backend: Sync {
    /// Backend implementation name ("native" / "pjrt").
    fn backend_name(&self) -> &'static str;

    /// The model description this backend executes.
    fn manifest(&self) -> &Manifest;

    /// The seed-0 initial global model.
    fn init_params(&self) -> Result<Vec<f32>>;

    /// Execute one full local training round. Returns the result and the
    /// compute wall time (the FaaS simulator's nominal-compute input).
    fn train_round(&self, req: &TrainRequest) -> Result<(TrainResult, Duration)>;

    /// Central federated evaluation on the fixed-size test set.
    fn evaluate(&self, params: &[f32], x: &Features, y: &[i32]) -> Result<EvalResult>;

    /// Begin a streaming aggregation (`begin → accumulate(update, w) →
    /// finish`). `expected_k` is a capacity hint bounded by
    /// `manifest().k_max`, not a contract. The native backend streams
    /// into a single O(P) accumulator, chunk-parallel when an entry is
    /// large enough to amortize the fan-out; batch-only backends (PJRT:
    /// one HLO call over a stacked buffer) return a [`BufferedFold`]
    /// that defers to their `aggregate` override.
    fn begin_fold(&self, expected_k: usize) -> Result<Box<dyn AggregateFold + '_>>;

    /// Begin a streaming aggregation whose accumulator is cut into
    /// `shards` independently-locked shards (see
    /// [`crate::params::ShardedAccumulator`]). Shard boundaries are
    /// chunk boundaries, so any shard count is **bit-identical** to
    /// [`Backend::begin_fold`] — sharding only changes lock and
    /// parallelism granularity. The default delegates to the unsharded
    /// fold, so batch-only backends (PJRT's [`BufferedFold`]) and the
    /// test mocks need no changes; the native backend overrides it.
    fn begin_fold_sharded(
        &self,
        expected_k: usize,
        shards: usize,
    ) -> Result<Box<dyn AggregateFold + '_>> {
        let _ = shards;
        self.begin_fold(expected_k)
    }

    /// Weighted aggregation: `out = sum_k weights[k] * updates[k]` in f32
    /// (paper Eq. 3 inner sum; weight semantics belong to the caller).
    /// `updates.len()` must be in `[1, k_max]`.
    ///
    /// Default: a thin wrapper over [`Backend::begin_fold`], so the
    /// Eq. 3 goldens in `tests/native_golden.rs` pin one entry point for
    /// both the batch and streaming paths.
    fn aggregate(&self, updates: &[&[f32]], weights: &[f32]) -> Result<(Vec<f32>, Duration)> {
        check_aggregate_args(self.manifest(), updates, weights)?;
        let mut fold = self.begin_fold(updates.len())?;
        for (u, &w) in updates.iter().zip(weights) {
            fold.accumulate(u, w)?;
        }
        fold.finish()
    }

    /// Whether `train_round` benefits from fanning out across multiple
    /// executor workers. Backends whose per-worker setup is expensive
    /// return `false` and get a **single persistent worker** instead
    /// (see [`crate::exec::pool_workers`]): the PJRT backend compiles
    /// its executables into thread-local storage, so one long-lived
    /// worker compiles once via [`Backend::init_worker`] and stays warm
    /// for the whole experiment.
    fn parallel_train(&self) -> bool {
        true
    }

    /// Per-worker-thread initialization hook, called once by each
    /// executor-pool worker before it accepts jobs. Backends with
    /// thread-local engine state (PJRT) warm their caches here so the
    /// first training job doesn't pay the compile; stateless backends
    /// keep the no-op default. An error fails every job the worker
    /// would have run (surfaced per-job, never a hang).
    fn init_worker(&self) -> Result<()> {
        Ok(())
    }
}

/// [`AggregateFold`] for batch-only backends: buffers owned copies of
/// every update and runs the backend's batch `aggregate` at `finish`.
/// O(k × P) memory by construction (each `accumulate` is one full
/// P-length copy — the price of keeping one-call batch semantics behind
/// the streaming API; `held_bytes` reports it honestly), and only
/// correct for backends that *override* [`Backend::aggregate`] (a
/// backend relying on the default wrapper would recurse back into
/// `begin_fold`).
pub struct BufferedFold<'b> {
    backend: &'b dyn Backend,
    updates: Vec<Vec<f32>>,
    weights: Vec<f32>,
}

impl<'b> BufferedFold<'b> {
    pub fn new(backend: &'b dyn Backend, expected_k: usize) -> Self {
        let cap = expected_k.min(backend.manifest().k_max);
        Self {
            backend,
            updates: Vec::with_capacity(cap),
            weights: Vec::with_capacity(cap),
        }
    }
}

impl AggregateFold for BufferedFold<'_> {
    fn accumulate(&mut self, update: &[f32], weight: f32) -> Result<()> {
        let mf = self.backend.manifest();
        check_params(mf, "update", update)?;
        if self.updates.len() == mf.k_max {
            bail!("{}: fold exceeds k_max={}", mf.name, mf.k_max);
        }
        self.updates.push(update.to_vec());
        self.weights.push(weight);
        Ok(())
    }

    fn count(&self) -> usize {
        self.updates.len()
    }

    fn held_bytes(&self) -> usize {
        let floats: usize = self.updates.iter().map(Vec::len).sum();
        floats * std::mem::size_of::<f32>()
    }

    fn finish(self: Box<Self>) -> Result<(Vec<f32>, Duration)> {
        let refs: Vec<&[f32]> = self.updates.iter().map(Vec::as_slice).collect();
        self.backend.aggregate(&refs, &self.weights)
    }
}

// ---------------------------------------------------------------------------
// shared argument validation
// ---------------------------------------------------------------------------

pub(crate) fn check_params(mf: &Manifest, what: &str, p: &[f32]) -> Result<()> {
    if p.len() != mf.param_count {
        bail!(
            "{}: {what} has {} elements, expected P={}",
            mf.name,
            p.len(),
            mf.param_count
        );
    }
    Ok(())
}

pub(crate) fn check_labels(mf: &Manifest, what: &str, y: &[i32]) -> Result<()> {
    if let Some(&bad) = y
        .iter()
        .find(|&&v| v < 0 || v as usize >= mf.num_classes)
    {
        bail!(
            "{}: {what} label {bad} outside [0, {})",
            mf.name,
            mf.num_classes
        );
    }
    Ok(())
}

pub(crate) fn check_features(mf: &Manifest, x: &Features, n: usize) -> Result<()> {
    if x.dtype() != mf.input_dtype {
        bail!(
            "{}: features dtype {} but manifest wants {}",
            mf.name,
            x.dtype(),
            mf.input_dtype
        );
    }
    let expect = n * mf.sample_elems();
    if x.len() != expect {
        bail!("{}: x has {} elements, want {}", mf.name, x.len(), expect);
    }
    Ok(())
}

pub(crate) fn check_train_request(mf: &Manifest, req: &TrainRequest) -> Result<()> {
    check_params(mf, "params", req.params)?;
    check_params(mf, "m", req.m)?;
    check_params(mf, "v", req.v)?;
    if let Some(g) = req.global {
        check_params(mf, "global", g)?;
    }
    if req.y.len() != mf.shard_size {
        bail!(
            "{}: y has {} labels, want {}",
            mf.name,
            req.y.len(),
            mf.shard_size
        );
    }
    check_labels(mf, "y", req.y)?;
    check_features(mf, req.x, mf.shard_size)?;
    if req.num_steps < 0 || req.num_steps as usize > mf.steps_per_round {
        bail!(
            "{}: num_steps {} outside [0, {}]",
            mf.name,
            req.num_steps,
            mf.steps_per_round
        );
    }
    Ok(())
}

pub(crate) fn check_eval_args(
    mf: &Manifest,
    params: &[f32],
    x: &Features,
    y: &[i32],
) -> Result<()> {
    check_params(mf, "params", params)?;
    if y.len() != mf.eval_size {
        bail!(
            "{}: eval y has {} labels, want {}",
            mf.name,
            y.len(),
            mf.eval_size
        );
    }
    check_labels(mf, "eval y", y)?;
    check_features(mf, x, mf.eval_size)
}

pub(crate) fn check_aggregate_args(
    mf: &Manifest,
    updates: &[&[f32]],
    weights: &[f32],
) -> Result<()> {
    if updates.len() != weights.len() {
        bail!(
            "{}: {} updates vs {} weights",
            mf.name,
            updates.len(),
            weights.len()
        );
    }
    if updates.is_empty() {
        bail!("{}: aggregate called with no updates", mf.name);
    }
    if updates.len() > mf.k_max {
        bail!(
            "{}: {} updates exceed k_max={}",
            mf.name,
            updates.len(),
            mf.k_max
        );
    }
    for u in updates {
        check_params(mf, "update", u)?;
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// backend selection
// ---------------------------------------------------------------------------

/// Which execution backend to load.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-Rust dense-MLP backend; always available.
    Native,
    /// AOT HLO artifacts via PJRT; requires the `pjrt` cargo feature and
    /// a `make artifacts` run.
    Pjrt,
}

impl BackendKind {
    pub fn as_str(self) -> &'static str {
        match self {
            BackendKind::Native => "native",
            BackendKind::Pjrt => "pjrt",
        }
    }
}

impl std::str::FromStr for BackendKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> std::result::Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "native" => Ok(BackendKind::Native),
            "pjrt" | "xla" => Ok(BackendKind::Pjrt),
            other => bail!("unknown backend {other:?}; expected native|pjrt"),
        }
    }
}

/// Load an execution backend for one model family. `artifacts_dir` is
/// only consulted by the PJRT backend; the native backend synthesizes its
/// model from the built-in per-family presets.
pub fn load_backend(
    kind: BackendKind,
    artifacts_dir: &Path,
    dataset: &str,
) -> Result<Box<dyn Backend>> {
    match kind {
        BackendKind::Native => {
            let _ = artifacts_dir;
            Ok(Box::new(super::NativeBackend::for_dataset(dataset)?))
        }
        #[cfg(feature = "pjrt")]
        BackendKind::Pjrt => Ok(Box::new(super::model::PjrtBackend::load(
            artifacts_dir,
            dataset,
        )?)),
        #[cfg(not(feature = "pjrt"))]
        BackendKind::Pjrt => bail!(
            "backend pjrt requested but this binary was built without the \
             `pjrt` feature; rebuild with `cargo build --features pjrt`"
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::str::FromStr;

    #[test]
    fn backend_kind_parses() {
        assert_eq!(BackendKind::from_str("native").unwrap(), BackendKind::Native);
        assert_eq!(BackendKind::from_str("PJRT").unwrap(), BackendKind::Pjrt);
        assert!(BackendKind::from_str("tpu").is_err());
    }

    #[test]
    fn default_aggregate_wrapper_matches_manual_fold() {
        let b = load_backend(BackendKind::Native, Path::new("unused"), "mnist").unwrap();
        let p = b.manifest().param_count;
        let u1: Vec<f32> = (0..p).map(|i| (i % 13) as f32 * 0.01).collect();
        let u2: Vec<f32> = (0..p).map(|i| (i % 7) as f32 * -0.02).collect();
        let (batch, _) = b.aggregate(&[&u1, &u2], &[0.25, 0.75]).unwrap();
        let mut fold = b.begin_fold(2).unwrap();
        fold.accumulate(&u1, 0.25).unwrap();
        assert_eq!(fold.count(), 1);
        fold.accumulate(&u2, 0.75).unwrap();
        let (streamed, _) = fold.finish().unwrap();
        assert_eq!(streamed, batch, "wrapper and fold are the same math");
    }

    #[test]
    fn buffered_fold_defers_to_batch_aggregate() {
        // The native backend overrides begin_fold (not aggregate), so
        // the default wrapper is safe for BufferedFold to call back into.
        let b = load_backend(BackendKind::Native, Path::new("unused"), "mnist").unwrap();
        let p = b.manifest().param_count;
        let u: Vec<f32> = (0..p).map(|i| (i % 5) as f32).collect();
        let mut fold: Box<dyn AggregateFold + '_> = Box::new(BufferedFold::new(b.as_ref(), 1));
        assert_eq!(fold.held_bytes(), 0);
        fold.accumulate(&u, 0.5).unwrap();
        // a buffering fold holds a full copy per entry
        assert_eq!(fold.held_bytes(), p * std::mem::size_of::<f32>());
        let (out, _) = fold.finish().unwrap();
        assert!(out.iter().zip(&u).all(|(o, x)| *o == 0.5 * x));
        // shape and emptiness validation
        let mut bad: Box<dyn AggregateFold + '_> = Box::new(BufferedFold::new(b.as_ref(), 1));
        assert!(bad.accumulate(&u[..3], 1.0).is_err());
        assert!(bad.finish().is_err());
    }

    #[test]
    fn native_backend_loads_for_every_preset() {
        for d in ["mnist", "femnist", "shakespeare", "speech", "transformer"] {
            let b = load_backend(BackendKind::Native, Path::new("unused"), d).unwrap();
            assert_eq!(b.backend_name(), "native");
            assert_eq!(b.manifest().name, d);
        }
        assert!(load_backend(BackendKind::Native, Path::new("unused"), "nope").is_err());
    }
}
