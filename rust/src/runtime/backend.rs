//! The pluggable execution backend seam: everything the coordinator needs
//! from "the thing that computes" — local training rounds, central
//! evaluation, weighted aggregation and the initial global model — behind
//! one object-safe trait.
//!
//! Two implementations exist:
//!
//! * [`NativeBackend`](super::NativeBackend) (default build): pure-Rust
//!   dense-MLP forward/backward with the SGD/Adam steps and the
//!   staleness-weighted aggregation of `python/compile/kernels/ref.py`.
//!   Zero external dependencies; this is what CI and the tier-1 tests run.
//! * `ModelRuntime` (behind the `pjrt` cargo feature): the AOT HLO
//!   artifact path through the PJRT C API, structurally identical models
//!   to the paper's (§VI-A2).
//!
//! Both share the argument-validation helpers below, so shape/dtype
//! errors are identical across backends.

use std::path::Path;
use std::time::Duration;

use anyhow::bail;

use super::manifest::Manifest;
use crate::data::Features;
use crate::Result;

/// Inputs of one local training round (Algorithm 1, Client_Update).
pub struct TrainRequest<'a> {
    pub params: &'a [f32],
    /// Adam first/second moments; zeroed by stateless FaaS clients.
    pub m: &'a [f32],
    pub v: &'a [f32],
    /// Optimizer step counter (f32 across the backend boundary).
    pub t: f32,
    pub x: &'a Features,
    pub y: &'a [i32],
    /// Shuffling / dropout seed for this invocation.
    pub seed: i32,
    /// Partial-work cutoff (FedProx toleration); pass
    /// `manifest.steps_per_round` for full work.
    pub num_steps: i32,
    /// FedProx anchor; `Some` routes to the proximal training variant.
    pub global: Option<&'a [f32]>,
}

/// Outputs of one local training round.
#[derive(Debug, Clone)]
pub struct TrainResult {
    pub params: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub t: f32,
    /// Mean training loss over the executed steps.
    pub loss: f32,
}

/// Central evaluation result.
#[derive(Debug, Clone, Copy)]
pub struct EvalResult {
    pub loss: f32,
    pub accuracy: f32,
}

/// One model family's execution engine. Object-safe: the coordinator and
/// the repro harness hold `&dyn Backend` / `Box<dyn Backend>`.
///
/// `Sync` is a supertrait: the round scheduler shares one backend across
/// scoped worker threads ([`crate::sched::train_parallel`]), so every
/// implementation must be callable concurrently through `&self`. The
/// native backend is stateless per call; the PJRT backend keeps its
/// non-`Send` engine handles in thread-local storage (one engine per
/// worker thread) to satisfy the bound.
pub trait Backend: Sync {
    /// Backend implementation name ("native" / "pjrt").
    fn backend_name(&self) -> &'static str;

    /// The model description this backend executes.
    fn manifest(&self) -> &Manifest;

    /// The seed-0 initial global model.
    fn init_params(&self) -> Result<Vec<f32>>;

    /// Execute one full local training round. Returns the result and the
    /// compute wall time (the FaaS simulator's nominal-compute input).
    fn train_round(&self, req: &TrainRequest) -> Result<(TrainResult, Duration)>;

    /// Central federated evaluation on the fixed-size test set.
    fn evaluate(&self, params: &[f32], x: &Features, y: &[i32]) -> Result<EvalResult>;

    /// Weighted aggregation: `out = sum_k weights[k] * updates[k]` in f32
    /// (paper Eq. 3 inner sum; weight semantics belong to the caller).
    /// `updates.len()` must be in `[1, k_max]`.
    fn aggregate(&self, updates: &[&[f32]], weights: &[f32]) -> Result<(Vec<f32>, Duration)>;

    /// Whether `train_round` should be fanned out across short-lived
    /// worker threads. Backends whose per-thread setup is expensive
    /// return `false` and run inline on the scheduler's thread instead:
    /// the PJRT backend compiles its executables into thread-local
    /// storage, so a fresh scope thread per round would recompile the
    /// model every round.
    fn parallel_train(&self) -> bool {
        true
    }
}

// ---------------------------------------------------------------------------
// shared argument validation
// ---------------------------------------------------------------------------

pub(crate) fn check_params(mf: &Manifest, what: &str, p: &[f32]) -> Result<()> {
    if p.len() != mf.param_count {
        bail!(
            "{}: {what} has {} elements, expected P={}",
            mf.name,
            p.len(),
            mf.param_count
        );
    }
    Ok(())
}

pub(crate) fn check_labels(mf: &Manifest, what: &str, y: &[i32]) -> Result<()> {
    if let Some(&bad) = y
        .iter()
        .find(|&&v| v < 0 || v as usize >= mf.num_classes)
    {
        bail!(
            "{}: {what} label {bad} outside [0, {})",
            mf.name,
            mf.num_classes
        );
    }
    Ok(())
}

pub(crate) fn check_features(mf: &Manifest, x: &Features, n: usize) -> Result<()> {
    if x.dtype() != mf.input_dtype {
        bail!(
            "{}: features dtype {} but manifest wants {}",
            mf.name,
            x.dtype(),
            mf.input_dtype
        );
    }
    let expect = n * mf.sample_elems();
    if x.len() != expect {
        bail!("{}: x has {} elements, want {}", mf.name, x.len(), expect);
    }
    Ok(())
}

pub(crate) fn check_train_request(mf: &Manifest, req: &TrainRequest) -> Result<()> {
    check_params(mf, "params", req.params)?;
    check_params(mf, "m", req.m)?;
    check_params(mf, "v", req.v)?;
    if let Some(g) = req.global {
        check_params(mf, "global", g)?;
    }
    if req.y.len() != mf.shard_size {
        bail!(
            "{}: y has {} labels, want {}",
            mf.name,
            req.y.len(),
            mf.shard_size
        );
    }
    check_labels(mf, "y", req.y)?;
    check_features(mf, req.x, mf.shard_size)?;
    if req.num_steps < 0 || req.num_steps as usize > mf.steps_per_round {
        bail!(
            "{}: num_steps {} outside [0, {}]",
            mf.name,
            req.num_steps,
            mf.steps_per_round
        );
    }
    Ok(())
}

pub(crate) fn check_eval_args(
    mf: &Manifest,
    params: &[f32],
    x: &Features,
    y: &[i32],
) -> Result<()> {
    check_params(mf, "params", params)?;
    if y.len() != mf.eval_size {
        bail!(
            "{}: eval y has {} labels, want {}",
            mf.name,
            y.len(),
            mf.eval_size
        );
    }
    check_labels(mf, "eval y", y)?;
    check_features(mf, x, mf.eval_size)
}

pub(crate) fn check_aggregate_args(
    mf: &Manifest,
    updates: &[&[f32]],
    weights: &[f32],
) -> Result<()> {
    if updates.len() != weights.len() {
        bail!(
            "{}: {} updates vs {} weights",
            mf.name,
            updates.len(),
            weights.len()
        );
    }
    if updates.is_empty() {
        bail!("{}: aggregate called with no updates", mf.name);
    }
    if updates.len() > mf.k_max {
        bail!(
            "{}: {} updates exceed k_max={}",
            mf.name,
            updates.len(),
            mf.k_max
        );
    }
    for u in updates {
        check_params(mf, "update", u)?;
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// backend selection
// ---------------------------------------------------------------------------

/// Which execution backend to load.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-Rust dense-MLP backend; always available.
    Native,
    /// AOT HLO artifacts via PJRT; requires the `pjrt` cargo feature and
    /// a `make artifacts` run.
    Pjrt,
}

impl BackendKind {
    pub fn as_str(self) -> &'static str {
        match self {
            BackendKind::Native => "native",
            BackendKind::Pjrt => "pjrt",
        }
    }
}

impl std::str::FromStr for BackendKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> std::result::Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "native" => Ok(BackendKind::Native),
            "pjrt" | "xla" => Ok(BackendKind::Pjrt),
            other => bail!("unknown backend {other:?}; expected native|pjrt"),
        }
    }
}

/// Load an execution backend for one model family. `artifacts_dir` is
/// only consulted by the PJRT backend; the native backend synthesizes its
/// model from the built-in per-family presets.
pub fn load_backend(
    kind: BackendKind,
    artifacts_dir: &Path,
    dataset: &str,
) -> Result<Box<dyn Backend>> {
    match kind {
        BackendKind::Native => {
            let _ = artifacts_dir;
            Ok(Box::new(super::NativeBackend::for_dataset(dataset)?))
        }
        #[cfg(feature = "pjrt")]
        BackendKind::Pjrt => Ok(Box::new(super::model::PjrtBackend::load(
            artifacts_dir,
            dataset,
        )?)),
        #[cfg(not(feature = "pjrt"))]
        BackendKind::Pjrt => bail!(
            "backend pjrt requested but this binary was built without the \
             `pjrt` feature; rebuild with `cargo build --features pjrt`"
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::str::FromStr;

    #[test]
    fn backend_kind_parses() {
        assert_eq!(BackendKind::from_str("native").unwrap(), BackendKind::Native);
        assert_eq!(BackendKind::from_str("PJRT").unwrap(), BackendKind::Pjrt);
        assert!(BackendKind::from_str("tpu").is_err());
    }

    #[test]
    fn native_backend_loads_for_every_preset() {
        for d in ["mnist", "femnist", "shakespeare", "speech", "transformer"] {
            let b = load_backend(BackendKind::Native, Path::new("unused"), d).unwrap();
            assert_eq!(b.backend_name(), "native");
            assert_eq!(b.manifest().name, d);
        }
        assert!(load_backend(BackendKind::Native, Path::new("unused"), "nope").is_err());
    }
}
