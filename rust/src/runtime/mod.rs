//! Runtime layer: loads the AOT-compiled HLO artifacts (built once by
//! `make artifacts`) and executes them through the PJRT C API. This is
//! the only boundary between the Rust coordinator and the JAX/Pallas
//! compute; Python is never on the request path.

pub mod engine;
pub mod manifest;
pub mod model;

pub use engine::{Engine, Executable};
pub use manifest::{ArtifactIndex, Manifest};
pub use model::{EvalResult, ModelRuntime, TrainRequest, TrainResult};
