//! Runtime layer: the pluggable execution [`Backend`] behind the Rust
//! coordinator. The default build ships the dependency-free
//! [`NativeBackend`] (pure-Rust dense MLP, SGD/Adam, staleness-weighted
//! aggregation); the `pjrt` cargo feature adds `ModelRuntime`, which
//! loads the AOT-compiled HLO artifacts (built once by `make artifacts`)
//! and executes them through the PJRT C API. Either way Python is never
//! on the request path.

pub mod backend;
pub mod kernel;
pub mod manifest;
pub mod native;

#[cfg(feature = "pjrt")]
pub mod engine;
#[cfg(feature = "pjrt")]
pub mod model;

pub use backend::{
    load_backend, AggregateFold, Backend, BackendKind, BufferedFold, EvalResult, TrainRequest,
    TrainResult,
};
pub use kernel::Kernel;
pub use manifest::{ArtifactIndex, Manifest};
pub use native::NativeBackend;

#[cfg(feature = "pjrt")]
pub use engine::{Engine, Executable};
#[cfg(feature = "pjrt")]
pub use model::{ModelRuntime, PjrtBackend};
