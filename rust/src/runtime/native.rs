//! `NativeBackend` — the default, dependency-free execution backend: a
//! dense MLP (input → ReLU hidden → softmax logits) with full-batch-exact
//! forward/backward, the flat-vector SGD/Adam steps of
//! `python/compile/optim.py`, and the staleness-weighted aggregation of
//! `python/compile/kernels/ref.py` (`aggregate_ref`: f32 accumulation of
//! `sum_k w_k * u_k`).
//!
//! The paper's strategies never inspect model internals — only losses,
//! training times and update vectors — so a compact MLP substrate keeps
//! every L3 behaviour (selection, tiering, staleness handling, cost)
//! faithful while making the whole stack runnable with `cargo test` alone.
//! The structurally-paper-exact CNN/LSTM path lives behind the `pjrt`
//! feature (see [`super::backend`]).
//!
//! Token-family inputs (`i32`) are embedded by scaling each token to
//! `t / num_classes` — the synthetic token datasets encode the label in
//! the final token (see `crate::data`), which stays linearly recoverable.

use std::cell::RefCell;
use std::time::{Duration, Instant};

use anyhow::bail;

use super::backend::{
    check_eval_args, check_params, check_train_request, AggregateFold, Backend, EvalResult,
    TrainRequest, TrainResult,
};
use super::kernel::{self, AdamParams, Kernel};
use super::manifest::{Entrypoint, Manifest};
use crate::data::Features;
use crate::params::{fold_workers, resolve_shards, ShardLayout, ShardedAccumulator};
use crate::util::Rng;
use crate::Result;

/// Seed-mixing constants: keep the init / shuffle RNG streams disjoint
/// from the dataset and platform streams derived from related seeds.
const INIT_SEED_MIX: u64 = 0x9d1e_5eed;
const SHUFFLE_SEED_MIX: u64 = 0x7ea1_7a1e;

/// Adam hyperparameters (fixed across the stack, `optim.py`).
const ADAM_B1: f32 = 0.9;
const ADAM_B2: f32 = 0.999;
const ADAM_EPS: f32 = 1e-8;

/// The pure-Rust execution backend for one model family.
pub struct NativeBackend {
    manifest: Manifest,
    /// Hidden-layer width H of the MLP.
    hidden: usize,
}

/// Per-family MLP preset for the native backend: smoke-scale shapes
/// (fast enough for CI sweeps) with the Table-I optimizer settings.
struct Preset {
    input_shape: &'static [usize],
    input_dtype: &'static str,
    num_classes: usize,
    shard_size: usize,
    batch_size: usize,
    local_epochs: usize,
    optimizer: &'static str,
    lr: f64,
    hidden: usize,
}

fn preset(name: &str) -> Option<Preset> {
    let p = match name {
        "mnist" => Preset {
            input_shape: &[28, 28, 1],
            input_dtype: "f32",
            num_classes: 10,
            shard_size: 20,
            batch_size: 10,
            local_epochs: 5,
            optimizer: "adam",
            lr: 1e-3,
            hidden: 32,
        },
        "femnist" => Preset {
            input_shape: &[28, 28, 1],
            input_dtype: "f32",
            num_classes: 62,
            shard_size: 20,
            batch_size: 10,
            local_epochs: 5,
            optimizer: "adam",
            lr: 1e-3,
            hidden: 32,
        },
        "shakespeare" => Preset {
            input_shape: &[10],
            input_dtype: "i32",
            num_classes: 82,
            shard_size: 32,
            batch_size: 32,
            local_epochs: 1,
            optimizer: "sgd",
            lr: 0.8,
            hidden: 32,
        },
        "speech" => Preset {
            input_shape: &[32, 32, 1],
            input_dtype: "f32",
            num_classes: 35,
            shard_size: 20,
            batch_size: 5,
            local_epochs: 5,
            optimizer: "adam",
            lr: 1e-3,
            hidden: 32,
        },
        "transformer" => Preset {
            input_shape: &[16],
            input_dtype: "i32",
            num_classes: 96,
            shard_size: 32,
            batch_size: 16,
            local_epochs: 1,
            optimizer: "adam",
            lr: 3e-4,
            hidden: 64,
        },
        _ => return None,
    };
    Some(p)
}

/// Flat parameter count of a `d → h → c` MLP.
fn mlp_param_count(d: usize, h: usize, c: usize) -> usize {
    d * h + h + h * c + c
}

impl NativeBackend {
    /// Build the native backend for one of the built-in model families.
    pub fn for_dataset(name: &str) -> Result<Self> {
        let Some(p) = preset(name) else {
            bail!("no native-backend preset for dataset {name:?}");
        };
        let d: usize = p.input_shape.iter().product();
        let param_count = mlp_param_count(d, p.hidden, p.num_classes);
        let steps_per_round = p.shard_size / p.batch_size * p.local_epochs;
        let flops =
            6 * steps_per_round * p.batch_size * (d * p.hidden + p.hidden * p.num_classes);
        let builtin = |ep: &str| Entrypoint {
            file: format!("<native:{ep}>"),
            inputs: Vec::new(),
            outputs: Vec::new(),
        };
        let manifest = Manifest {
            name: name.to_string(),
            scale: "native".to_string(),
            param_count,
            num_classes: p.num_classes,
            input_shape: p.input_shape.to_vec(),
            input_dtype: p.input_dtype.to_string(),
            shard_size: p.shard_size,
            batch_size: p.batch_size,
            local_epochs: p.local_epochs,
            steps_per_round,
            optimizer: p.optimizer.to_string(),
            lr: p.lr,
            // Native smoke scale: a larger proximal pull than the paper's
            // CNN setting so FedProx's anchor effect is measurable within
            // a handful of MLP steps.
            prox_mu: 0.1,
            eval_size: 128,
            eval_batch: 128,
            k_max: 64,
            seq_len: match p.input_dtype {
                "i32" => Some(d),
                _ => None,
            },
            flops_per_round: flops as u64,
            entrypoints: ["train", "train_prox", "eval", "aggregate"]
                .iter()
                .map(|ep| (ep.to_string(), builtin(ep)))
                .collect(),
            init_file: "<builtin>".to_string(),
            init_sha256: "<builtin>".to_string(),
            init_seed: 0,
        };
        Self::from_manifest(manifest, p.hidden)
    }

    /// Build the backend from an explicit manifest (tests / custom
    /// models). `manifest.param_count` must equal the MLP layout size.
    pub fn from_manifest(manifest: Manifest, hidden: usize) -> Result<Self> {
        manifest.validate()?;
        if hidden == 0 {
            bail!("{}: hidden width must be positive", manifest.name);
        }
        let d = manifest.sample_elems();
        let want = mlp_param_count(d, hidden, manifest.num_classes);
        if manifest.param_count != want {
            bail!(
                "{}: param_count {} but a {d}x{hidden}x{} MLP has {want}",
                manifest.name,
                manifest.param_count,
                manifest.num_classes
            );
        }
        Ok(Self { manifest, hidden })
    }

    pub fn hidden(&self) -> usize {
        self.hidden
    }

    fn dims(&self) -> (usize, usize, usize) {
        (
            self.manifest.sample_elems(),
            self.hidden,
            self.manifest.num_classes,
        )
    }

    /// Features as f32 rows; `i32` tokens are scaled into [0, 1).
    fn features_f32<'a>(&self, x: &'a Features, scratch: &'a mut Vec<f32>) -> &'a [f32] {
        match x {
            Features::F32(v) => v,
            Features::I32(v) => {
                let scale = 1.0 / self.manifest.num_classes as f32;
                scratch.clear();
                scratch.extend(v.iter().map(|&t| t as f32 * scale));
                scratch
            }
        }
    }
}

// ---------------------------------------------------------------------------
// dense math (mirrors kernels/ref.py: plain definitions, f32 accumulate).
// The GEMMs and element-wise steps run through the kernel plane
// (`super::kernel`), whose scalar path is the seed loops verbatim and
// whose AVX2 path is bit-identical by construction.
// ---------------------------------------------------------------------------

/// Flat-layout views of `[w1 | b1 | w2 | b2]`.
fn split_params(flat: &[f32], d: usize, h: usize, c: usize) -> (&[f32], &[f32], &[f32], &[f32]) {
    let (w1, rest) = flat.split_at(d * h);
    let (b1, rest) = rest.split_at(h);
    let (w2, b2) = rest.split_at(h * c);
    (w1, b1, w2, b2)
}

fn split_params_mut(
    flat: &mut [f32],
    d: usize,
    h: usize,
    c: usize,
) -> (&mut [f32], &mut [f32], &mut [f32], &mut [f32]) {
    let (w1, rest) = flat.split_at_mut(d * h);
    let (b1, rest) = rest.split_at_mut(h);
    let (w2, b2) = rest.split_at_mut(h * c);
    (w1, b1, w2, b2)
}

/// Reusable per-batch scratch buffers. Grown (never shrunk below use)
/// by [`Scratch::ensure`]; every field is fully overwritten per batch,
/// so cross-job reuse through the worker arena is semantics-free.
#[derive(Default)]
struct Scratch {
    xb: Vec<f32>,
    z1: Vec<f32>,
    a1: Vec<f32>,
    z2: Vec<f32>,
    dz2: Vec<f32>,
    da1: Vec<f32>,
    dz1: Vec<f32>,
    /// `W2ᵀ` staging for the backward `dz2 @ W2ᵀ` product (the kernel
    /// plane's `j`-inner restructure of `matmul_a_bt`).
    w2t: Vec<f32>,
}

impl Scratch {
    fn ensure(&mut self, bs: usize, d: usize, h: usize, c: usize) {
        self.xb.resize(bs * d, 0.0);
        self.z1.resize(bs * h, 0.0);
        self.a1.resize(bs * h, 0.0);
        self.z2.resize(bs * c, 0.0);
        self.dz2.resize(bs * c, 0.0);
        self.da1.resize(bs * h, 0.0);
        self.dz1.resize(bs * h, 0.0);
        self.w2t.resize(c * h, 0.0);
    }
}

/// Per-worker-thread arena: every buffer a training/eval job needs,
/// allocated once per executor-pool worker (warmed by
/// [`Backend::init_worker`]) instead of per job. Each job fully
/// overwrites what it reads, so reuse never changes results.
#[derive(Default)]
struct Arena {
    s: Scratch,
    /// Flat gradient vector.
    g: Vec<f32>,
    /// Per-batch label staging.
    yb: Vec<i32>,
    /// Concatenated per-epoch shuffles (index table).
    idx_table: Vec<usize>,
    /// Reusable permutation buffer (one allocation for all epochs).
    perm: Vec<usize>,
    /// Token-features-to-f32 staging for `i32` model families.
    tokens: Vec<f32>,
}

thread_local! {
    static ARENA: RefCell<Arena> = RefCell::new(Arena::default());
}

/// Forward the first `rows` rows of `s.xb` through the MLP, writing
/// `z1`, `a1` (fused bias+ReLU epilogue) and `z2` (bias epilogue).
fn forward(kr: Kernel, flat: &[f32], (d, h, c): (usize, usize, usize), s: &mut Scratch, rows: usize) {
    let (w1, b1, w2, b2) = split_params(flat, d, h, c);
    kr.matmul_bias_relu(
        &s.xb[..rows * d],
        w1,
        b1,
        d,
        h,
        &mut s.z1[..rows * h],
        &mut s.a1[..rows * h],
    );
    kr.matmul_bias(&s.a1[..rows * h], w2, b2, h, c, &mut s.z2[..rows * c]);
}

/// Mean softmax cross-entropy of the already-forwarded logits, plus the
/// logit gradient `dz2 = (softmax - onehot) / B` left in scratch.
fn softmax_xent_backward(yb: &[i32], c: usize, s: &mut Scratch) -> f32 {
    let bs = yb.len();
    let inv_b = 1.0 / bs as f32;
    let mut loss = 0.0f32;
    for ((zr, dr), &y) in s
        .z2
        .chunks_exact(c)
        .zip(s.dz2.chunks_exact_mut(c))
        .zip(yb)
    {
        let zmax = zr.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut denom = 0.0f32;
        for z in zr {
            denom += (z - zmax).exp();
        }
        let log_denom = denom.ln();
        loss += -(zr[y as usize] - zmax - log_denom);
        for (j, (dz, z)) in dr.iter_mut().zip(zr).enumerate() {
            let sm = (z - zmax).exp() / denom;
            let onehot = if j == y as usize { 1.0 } else { 0.0 };
            *dz = (sm - onehot) * inv_b;
        }
    }
    loss * inv_b
}

/// Back-propagate `dz2` (first `rows` rows) into the flat gradient `g`.
fn backward(
    kr: Kernel,
    flat: &[f32],
    (d, h, c): (usize, usize, usize),
    s: &mut Scratch,
    g: &mut [f32],
    rows: usize,
) {
    let (_w1, _b1, w2, _b2) = split_params(flat, d, h, c);
    let (gw1, gb1, gw2, gb2) = split_params_mut(g, d, h, c);
    // dW2 = a1ᵀ dz2 ; db2 = Σ_rows dz2
    kr.matmul_at_b(&s.a1[..rows * h], &s.dz2[..rows * c], h, c, gw2);
    gb2.fill(0.0);
    for dr in s.dz2[..rows * c].chunks_exact(c) {
        kr.add_assign(gb2, dr);
    }
    // da1 = dz2 @ W2ᵀ (via the pre-transposed W2 staging) ; dz1 = da1 ⊙ (z1 > 0)
    kr.matmul_a_bt(
        &s.dz2[..rows * c],
        w2,
        c,
        h,
        &mut s.w2t,
        &mut s.da1[..rows * h],
    );
    kr.relu_mask(&mut s.dz1[..rows * h], &s.da1[..rows * h], &s.z1[..rows * h]);
    // dW1 = xbᵀ dz1 ; db1 = Σ_rows dz1
    kr.matmul_at_b(&s.xb[..rows * d], &s.dz1[..rows * h], d, h, gw1);
    gb1.fill(0.0);
    for dr in s.dz1[..rows * h].chunks_exact(h) {
        kr.add_assign(gb1, dr);
    }
}

impl Backend for NativeBackend {
    fn backend_name(&self) -> &'static str {
        "native"
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Glorot-uniform dense init (matches `archs/common.py::dense_init`),
    /// deterministic in the manifest's `init_seed`.
    fn init_params(&self) -> Result<Vec<f32>> {
        let (d, h, c) = self.dims();
        let mut rng = Rng::seed_from_u64(INIT_SEED_MIX ^ self.manifest.init_seed);
        let mut flat = vec![0.0f32; self.manifest.param_count];
        {
            let (w1, _b1, w2, _b2) = split_params_mut(&mut flat, d, h, c);
            let lim1 = (6.0 / (d + h) as f64).sqrt();
            for w in w1.iter_mut() {
                *w = rng.range_f64(-lim1, lim1) as f32;
            }
            let lim2 = (6.0 / (h + c) as f64).sqrt();
            for w in w2.iter_mut() {
                *w = rng.range_f64(-lim2, lim2) as f32;
            }
        }
        Ok(flat)
    }

    fn train_round(&self, req: &TrainRequest) -> Result<(TrainResult, Duration)> {
        let mf = &self.manifest;
        check_train_request(mf, req)?;
        let t0 = Instant::now();
        let (d, h, c) = self.dims();
        let n = mf.shard_size;
        let bs = mf.batch_size;
        let steps_per_epoch = n / bs;
        let num_steps = req.num_steps as usize;

        let kr = kernel::active();

        ARENA.with(|cell| {
            let a = &mut *cell.borrow_mut();
            a.s.ensure(bs, d, h, c);
            a.g.resize(mf.param_count, 0.0);
            a.yb.resize(bs, 0);
            let x = self.features_f32(req.x, &mut a.tokens);

            // Per-epoch shuffles, concatenated into one index table — the
            // native analogue of `model.py`'s permutation scan input. The
            // permutation buffer is reused across epochs (refilled with
            // 0..n before each shuffle, so the shuffle stream is
            // unchanged from the per-epoch-allocation seed).
            let mut rng = Rng::seed_from_u64(u64::from(req.seed as u32) ^ SHUFFLE_SEED_MIX);
            a.idx_table.clear();
            a.idx_table.reserve(mf.steps_per_round * bs);
            for _ in 0..mf.local_epochs {
                a.perm.clear();
                a.perm.extend(0..n);
                rng.shuffle(&mut a.perm);
                a.idx_table.extend_from_slice(&a.perm[..steps_per_epoch * bs]);
            }

            let mut flat = req.params.to_vec();
            let mut m = req.m.to_vec();
            let mut v = req.v.to_vec();
            let mut t = req.t;
            let lr = mf.lr as f32;
            let mu = mf.prox_mu as f32;
            let is_adam = mf.optimizer == "adam";
            let mut loss_sum = 0.0f32;

            for idx in a.idx_table.chunks_exact(bs).take(num_steps) {
                for (row, (&i, y)) in idx.iter().zip(a.yb.iter_mut()).enumerate() {
                    a.s.xb[row * d..(row + 1) * d].copy_from_slice(&x[i * d..(i + 1) * d]);
                    *y = req.y[i];
                }
                forward(kr, &flat, (d, h, c), &mut a.s, bs);
                loss_sum += softmax_xent_backward(&a.yb, c, &mut a.s);
                backward(kr, &flat, (d, h, c), &mut a.s, &mut a.g, bs);
                if let Some(anchor) = req.global {
                    // FedProx: g += mu * (w - w_global)
                    kr.prox_add(&mut a.g, &flat, anchor, mu);
                }
                t += 1.0;
                if is_adam {
                    let p = AdamParams {
                        lr,
                        b1: ADAM_B1,
                        b2: ADAM_B2,
                        eps: ADAM_EPS,
                        bc1: 1.0 - ADAM_B1.powf(t),
                        bc2: 1.0 - ADAM_B2.powf(t),
                    };
                    kr.adam_step(&mut flat, &a.g, &mut m, &mut v, p);
                } else {
                    kr.sgd_step(&mut flat, &a.g, lr);
                }
            }

            let denom = (num_steps.max(1) as f32).min(mf.steps_per_round as f32);
            Ok((
                TrainResult {
                    params: flat,
                    m,
                    v,
                    t,
                    loss: loss_sum / denom,
                },
                t0.elapsed(),
            ))
        })
    }

    fn evaluate(&self, params: &[f32], x: &Features, y: &[i32]) -> Result<EvalResult> {
        let mf = &self.manifest;
        check_eval_args(mf, params, x, y)?;
        let kr = kernel::active();
        let (d, h, c) = self.dims();
        let eb = mf.eval_batch.min(mf.eval_size.max(1));

        ARENA.with(|cell| {
            let a = &mut *cell.borrow_mut();
            a.s.ensure(eb, d, h, c);
            let xf = self.features_f32(x, &mut a.tokens);

            let mut loss_sum = 0.0f32;
            let mut correct = 0.0f32;
            // Ragged eval sets are supported: the final batch simply has
            // fewer rows. Per-row math is batch-independent and the
            // loss/correct sums accumulate in global row order, so any
            // batch split is bit-identical.
            let mut off = 0usize;
            while off < y.len() {
                let rows = eb.min(y.len() - off);
                a.s.xb[..rows * d].copy_from_slice(&xf[off * d..(off + rows) * d]);
                forward(kr, params, (d, h, c), &mut a.s, rows);
                for (zr, &yi) in a.s.z2[..rows * c].chunks_exact(c).zip(&y[off..off + rows]) {
                    let zmax = zr.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                    let denom: f32 = zr.iter().map(|z| (z - zmax).exp()).sum();
                    loss_sum += -(zr[yi as usize] - zmax - denom.ln());
                    // first maximal index (jnp.argmax tie-breaking)
                    let mut best = 0usize;
                    for (i, z) in zr.iter().enumerate() {
                        if *z > zr[best] {
                            best = i;
                        }
                    }
                    if best == yi as usize {
                        correct += 1.0;
                    }
                }
                off += rows;
            }
            Ok(EvalResult {
                loss: loss_sum / mf.eval_size as f32,
                accuracy: correct / mf.eval_size as f32,
            })
        })
    }

    fn begin_fold(&self, expected_k: usize) -> Result<Box<dyn AggregateFold + '_>> {
        self.begin_fold_sharded(expected_k, resolve_shards(None))
    }

    fn begin_fold_sharded(
        &self,
        expected_k: usize,
        shards: usize,
    ) -> Result<Box<dyn AggregateFold + '_>> {
        let mf = &self.manifest;
        let layout = ShardLayout::new(mf.param_count, shards);
        // Price the fan-out on the whole expected cohort, once: the old
        // per-entry `fold_workers(P, 1)` kept preset-sized streamed
        // entries serial forever (the PR-4 review note), because a
        // single ~10⁵-param entry never clears the work gate even when
        // the fold will see dozens of them.
        let workers = fold_workers(mf.param_count, expected_k.clamp(1, mf.k_max));
        Ok(Box::new(NativeFold {
            mf,
            acc: ShardedAccumulator::new(layout),
            workers,
            count: 0,
            wall: Duration::ZERO,
        }))
    }

    /// Warm this worker thread's arena: pre-size every scratch buffer a
    /// training job needs (batch scratch, gradient, index table,
    /// permutation, token staging) so the persistent executor pool stops
    /// re-allocating per job.
    fn init_worker(&self) -> Result<()> {
        let mf = &self.manifest;
        let (d, h, c) = self.dims();
        ARENA.with(|cell| {
            let a = &mut *cell.borrow_mut();
            a.s.ensure(mf.batch_size, d, h, c);
            a.g.resize(mf.param_count, 0.0);
            a.yb.resize(mf.batch_size, 0);
            a.idx_table.reserve(mf.steps_per_round * mf.batch_size);
            a.perm.reserve(mf.shard_size);
            if mf.input_dtype == "i32" {
                a.tokens.reserve(mf.shard_size * d);
            }
        });
        Ok(())
    }
}

/// Streaming O(P) accumulator behind [`NativeBackend::begin_fold`] /
/// `begin_fold_sharded`: each `accumulate` is one `acc += w * u` pass
/// folded shard-by-shard into a [`ShardedAccumulator`], fanned out over
/// `workers` scoped threads when the expected cohort's total work
/// amortizes the spawn ([`fold_workers`], priced once at `begin_fold`)
/// and bit-identical to the serial seed loop for every shard/worker
/// choice. The batch [`Backend::aggregate`] default wrapper drives this
/// same fold, so the Eq. 3 goldens pin both paths at once.
struct NativeFold<'b> {
    mf: &'b Manifest,
    acc: ShardedAccumulator,
    workers: usize,
    count: usize,
    wall: Duration,
}

impl AggregateFold for NativeFold<'_> {
    fn accumulate(&mut self, update: &[f32], weight: f32) -> Result<()> {
        check_params(self.mf, "update", update)?;
        if self.count == self.mf.k_max {
            bail!("{}: fold exceeds k_max={}", self.mf.name, self.mf.k_max);
        }
        let t0 = Instant::now();
        self.acc.accumulate(update, weight, self.workers);
        self.wall += t0.elapsed();
        self.count += 1;
        Ok(())
    }

    fn count(&self) -> usize {
        self.count
    }

    fn held_bytes(&self) -> usize {
        self.acc.held_bytes()
    }

    fn finish(self: Box<Self>) -> Result<(Vec<f32>, Duration)> {
        if self.count == 0 {
            bail!("{}: fold finished with no updates", self.mf.name);
        }
        let wall = self.wall;
        Ok((self.acc.finish(), wall))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mnist() -> NativeBackend {
        NativeBackend::for_dataset("mnist").unwrap()
    }

    #[test]
    fn preset_param_counts_are_consistent() {
        for name in ["mnist", "femnist", "shakespeare", "speech", "transformer"] {
            let b = NativeBackend::for_dataset(name).unwrap();
            let mf = b.manifest();
            assert_eq!(
                mf.param_count,
                mlp_param_count(mf.sample_elems(), b.hidden(), mf.num_classes),
                "{name}"
            );
            mf.validate().unwrap();
        }
    }

    #[test]
    fn init_is_deterministic_and_bounded() {
        let b = mnist();
        let p1 = b.init_params().unwrap();
        let p2 = b.init_params().unwrap();
        assert_eq!(p1, p2);
        assert_eq!(p1.len(), b.manifest().param_count);
        let (d, h, c) = (784usize, 32usize, 10usize);
        let lim1 = (6.0f32 / (d + h) as f32).sqrt();
        assert!(p1[..d * h].iter().all(|w| w.abs() <= lim1));
        // biases zero
        assert!(p1[d * h..d * h + h].iter().all(|&w| w == 0.0));
        assert!(p1[d * h + h + h * c..].iter().all(|&w| w == 0.0));
        // weights actually vary
        assert!(p1[..d * h].iter().any(|&w| w != 0.0));
    }

    #[test]
    fn unknown_dataset_is_rejected() {
        assert!(NativeBackend::for_dataset("imagenet").is_err());
    }

    #[test]
    fn from_manifest_checks_param_count() {
        let mut mf = mnist().manifest.clone();
        mf.param_count += 1;
        assert!(NativeBackend::from_manifest(mf, 32).is_err());
    }

    #[test]
    fn aggregate_matches_scalar_reference() {
        let b = mnist();
        let p = b.manifest().param_count;
        let u1: Vec<f32> = (0..p).map(|i| (i % 13) as f32 * 0.01).collect();
        let u2: Vec<f32> = (0..p).map(|i| (i % 7) as f32 * -0.02).collect();
        let (agg, _) = b.aggregate(&[&u1, &u2], &[0.3, 0.7]).unwrap();
        for i in (0..p).step_by(199) {
            let want = 0.3 * u1[i] + 0.7 * u2[i];
            assert!((agg[i] - want).abs() < 1e-6, "elem {i}");
        }
    }

    #[test]
    fn streaming_fold_matches_batch_bit_for_bit() {
        let b = mnist();
        let p = b.manifest().param_count;
        let us: Vec<Vec<f32>> = (0..3)
            .map(|k| (0..p).map(|i| ((i + 7 * k) % 11) as f32 * 0.03 - 0.1).collect())
            .collect();
        let w = [0.5f32, 0.0, 0.3];
        let refs: Vec<&[f32]> = us.iter().map(Vec::as_slice).collect();
        let (batch, _) = b.aggregate(&refs, &w).unwrap();
        let mut fold = b.begin_fold(3).unwrap();
        for (u, &wi) in refs.iter().zip(&w) {
            fold.accumulate(u, wi).unwrap();
        }
        assert_eq!(fold.count(), 3);
        // streaming fold: one O(P) accumulator no matter how many entries
        assert_eq!(fold.held_bytes(), p * std::mem::size_of::<f32>());
        let (streamed, _) = fold.finish().unwrap();
        assert_eq!(streamed, batch);
    }

    #[test]
    fn sharded_fold_is_bit_identical_across_shard_counts() {
        // Shard boundaries are chunk boundaries: any shard count must
        // reproduce the batch aggregate bit-for-bit at preset size.
        let b = mnist();
        let p = b.manifest().param_count;
        let us: Vec<Vec<f32>> = (0..4)
            .map(|k| (0..p).map(|i| ((i + 11 * k) % 23) as f32 * 0.017 - 0.2).collect())
            .collect();
        let w = [0.25f32, 0.1, 0.0, 0.65];
        let refs: Vec<&[f32]> = us.iter().map(Vec::as_slice).collect();
        let (batch, _) = b.aggregate(&refs, &w).unwrap();
        for shards in [1usize, 2, 8, 17] {
            let mut fold = b.begin_fold_sharded(refs.len(), shards).unwrap();
            for (u, &wi) in refs.iter().zip(&w) {
                fold.accumulate(u, wi).unwrap();
            }
            assert_eq!(fold.held_bytes(), p * std::mem::size_of::<f32>());
            let (out, _) = fold.finish().unwrap();
            assert_eq!(out, batch, "shards={shards} drifted from batch");
        }
    }

    #[test]
    fn fold_validates_shapes_count_and_emptiness() {
        let b = mnist();
        let p = b.manifest().param_count;
        let u = vec![0.25f32; p];
        let mut fold = b.begin_fold(1).unwrap();
        assert!(fold.accumulate(&u[..p - 1], 1.0).is_err(), "short update");
        for _ in 0..b.manifest().k_max {
            fold.accumulate(&u, 0.0).unwrap();
        }
        assert!(fold.accumulate(&u, 0.0).is_err(), "k_max overflow");
        let empty = b.begin_fold(0).unwrap();
        assert!(empty.finish().is_err(), "empty fold must not finish");
    }

    #[test]
    fn aggregate_rejects_bad_shapes() {
        let b = mnist();
        let p = b.manifest().param_count;
        let u = vec![0.1f32; p];
        assert!(b.aggregate(&[], &[]).is_err());
        assert!(b.aggregate(&[&u], &[0.5, 0.5]).is_err());
        let short = vec![0.1f32; p - 1];
        assert!(b.aggregate(&[&short], &[1.0]).is_err());
        let too_many: Vec<&[f32]> = (0..b.manifest().k_max + 1).map(|_| &u[..]).collect();
        let w = vec![0.0f32; b.manifest().k_max + 1];
        assert!(b.aggregate(&too_many, &w).is_err());
    }

    #[test]
    fn evaluate_rejects_wrong_dtype_and_len() {
        let b = mnist();
        let mf = b.manifest();
        let p0 = b.init_params().unwrap();
        let x_bad = Features::I32(vec![0; mf.eval_size * mf.sample_elems()]);
        let y = vec![0i32; mf.eval_size];
        assert!(b.evaluate(&p0, &x_bad, &y).is_err());
        let x = Features::F32(vec![0.0; mf.eval_size * mf.sample_elems()]);
        assert!(b.evaluate(&p0, &x, &y[..3]).is_err());
        assert!(b.evaluate(&p0, &x, &y).is_ok());
    }

    #[test]
    fn ragged_eval_tail_batch_is_processed_and_split_invariant() {
        // eval_size = 10 with eval_batch ∈ {1, 3, 4, 8, 128}: every
        // batch split must be bit-identical to the single-batch result
        // (the ragged tail used to be silently dropped by chunks_exact
        // while loss/accuracy still divided by eval_size).
        let base = mnist();
        let p0 = base.init_params().unwrap();
        let mk = |eval_batch: usize| {
            let mut mf = base.manifest.clone();
            mf.eval_size = 10;
            mf.eval_batch = eval_batch;
            NativeBackend::from_manifest(mf, 32).unwrap()
        };
        let x = Features::F32(
            (0..10 * 784)
                .map(|i| ((i % 23) as f32 - 11.0) * 0.07)
                .collect(),
        );
        let y: Vec<i32> = (0..10i32).map(|i| i % 10).collect();
        let want = mk(10).evaluate(&p0, &x, &y).unwrap();
        assert!(want.loss > 0.0, "all ten rows must contribute loss");
        for eb in [1usize, 3, 4, 8, 128] {
            let r = mk(eb).evaluate(&p0, &x, &y).unwrap();
            assert_eq!(r.loss.to_bits(), want.loss.to_bits(), "eval_batch={eb}");
            assert_eq!(
                r.accuracy.to_bits(),
                want.accuracy.to_bits(),
                "eval_batch={eb}"
            );
        }
    }

    #[test]
    fn train_round_validates_inputs() {
        let b = mnist();
        let mf = b.manifest();
        let p0 = b.init_params().unwrap();
        let zeros = vec![0.0f32; p0.len()];
        let x = Features::F32(vec![0.1; mf.shard_size * mf.sample_elems()]);
        let y = vec![0i32; mf.shard_size];
        let mk = |num_steps: i32| TrainRequest {
            params: &p0,
            m: &zeros,
            v: &zeros,
            t: 0.0,
            x: &x,
            y: &y,
            seed: 1,
            num_steps,
            global: None,
        };
        assert!(b.train_round(&mk(mf.steps_per_round as i32)).is_ok());
        assert!(b.train_round(&mk(mf.steps_per_round as i32 + 1)).is_err());
        assert!(b.train_round(&mk(-1)).is_err());
    }

    #[test]
    fn out_of_range_labels_are_rejected_not_panicking() {
        let b = mnist();
        let mf = b.manifest();
        let p0 = b.init_params().unwrap();
        let zeros = vec![0.0f32; p0.len()];
        let x = Features::F32(vec![0.1; mf.shard_size * mf.sample_elems()]);
        for bad in [-1i32, mf.num_classes as i32] {
            let y = vec![bad; mf.shard_size];
            let req = TrainRequest {
                params: &p0,
                m: &zeros,
                v: &zeros,
                t: 0.0,
                x: &x,
                y: &y,
                seed: 1,
                num_steps: 1,
                global: None,
            };
            assert!(b.train_round(&req).is_err(), "label {bad} must be rejected");
        }
        let ex = Features::F32(vec![0.1; mf.eval_size * mf.sample_elems()]);
        let ey = vec![mf.num_classes as i32; mf.eval_size];
        assert!(b.evaluate(&p0, &ex, &ey).is_err());
    }

    #[test]
    fn partial_work_advances_t_by_num_steps() {
        let b = mnist();
        let mf = b.manifest();
        let p0 = b.init_params().unwrap();
        let zeros = vec![0.0f32; p0.len()];
        let x = Features::F32(vec![0.1; mf.shard_size * mf.sample_elems()]);
        let y: Vec<i32> = (0..mf.shard_size as i32).map(|i| i % 10).collect();
        let half = (mf.steps_per_round / 2) as i32;
        let req = TrainRequest {
            params: &p0,
            m: &zeros,
            v: &zeros,
            t: 0.0,
            x: &x,
            y: &y,
            seed: 2,
            num_steps: half,
            global: None,
        };
        let (r, _) = b.train_round(&req).unwrap();
        assert_eq!(r.t, half as f32);
        assert!(r.loss.is_finite());
    }

    #[test]
    fn zero_steps_is_a_noop_round() {
        let b = mnist();
        let mf = b.manifest();
        let p0 = b.init_params().unwrap();
        let zeros = vec![0.0f32; p0.len()];
        let x = Features::F32(vec![0.1; mf.shard_size * mf.sample_elems()]);
        let y = vec![0i32; mf.shard_size];
        let req = TrainRequest {
            params: &p0,
            m: &zeros,
            v: &zeros,
            t: 0.0,
            x: &x,
            y: &y,
            seed: 3,
            num_steps: 0,
            global: None,
        };
        let (r, _) = b.train_round(&req).unwrap();
        assert_eq!(r.params, p0);
        assert_eq!(r.t, 0.0);
        assert_eq!(r.loss, 0.0);
    }

    #[test]
    fn train_round_is_deterministic_in_seed() {
        let b = mnist();
        let mf = b.manifest();
        let p0 = b.init_params().unwrap();
        let zeros = vec![0.0f32; p0.len()];
        let x = Features::F32(
            (0..mf.shard_size * mf.sample_elems())
                .map(|i| (i % 17) as f32 * 0.1)
                .collect(),
        );
        let y: Vec<i32> = (0..mf.shard_size as i32).map(|i| i % 10).collect();
        let run = |seed: i32| {
            let req = TrainRequest {
                params: &p0,
                m: &zeros,
                v: &zeros,
                t: 0.0,
                x: &x,
                y: &y,
                seed,
                num_steps: mf.steps_per_round as i32,
                global: None,
            };
            b.train_round(&req).unwrap().0
        };
        let a = run(5);
        let b2 = run(5);
        assert_eq!(a.params, b2.params);
        assert_eq!(a.loss, b2.loss);
        let c = run(6);
        assert_ne!(a.params, c.params, "different seed must shuffle differently");
    }
}
