//! Clustering substrate for FedLesScan's client selection (§V-C):
//! DBSCAN over client behaviour features (grid-indexed neighbourhood
//! queries, naive-scan oracle), cluster-quality scoring via the
//! Calinski–Harabasz index, and the ε grid search the paper uses to pick
//! DBSCAN's neighbourhood radius — with the pairwise-distance quantile
//! estimate subsampled above [`EPS_SAMPLE_MAX`] points so the search
//! stays O(n) in the cohort size.

mod ch;
mod dbscan;
mod grid;
mod incremental;

pub use ch::calinski_harabasz;
pub use dbscan::{dbscan, dbscan_naive, DbscanParams};
pub use grid::GridIndex;
pub use incremental::{IncrementalDbscan, PointId, Splice};

/// Outlier label produced by DBSCAN before [`relabel_outliers`].
pub const NOISE: isize = -1;

/// A point in client-behaviour feature space (trainingEma,
/// missedRoundEma) — kept generic over dimensionality for tests.
pub type Point = Vec<f64>;

/// Squared Euclidean distance.
pub(crate) fn dist2(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// The paper "treats outliers as a single cluster" (§V-C): remap all
/// NOISE labels to one fresh cluster id. Returns the total cluster count.
pub fn relabel_outliers(labels: &mut [isize]) -> usize {
    let max = labels.iter().copied().max().unwrap_or(NOISE);
    let noise_id = max + 1;
    let mut any_noise = false;
    for l in labels.iter_mut() {
        if *l == NOISE {
            *l = noise_id;
            any_noise = true;
        }
    }
    (max + 1) as usize + usize::from(any_noise)
}

/// Cap on the points entering the pairwise-distance quantile estimate
/// that seeds the ε grid search. Above it, a deterministic seeded
/// subsample stands in for the full O(n²) distance set (the quantiles
/// of a ~500-point sample pin the scale well enough to seed a grid
/// search); at or below it the estimate is exact and byte-identical to
/// the historical behaviour, which keeps the paper-scale selection
/// goldens valid.
pub const EPS_SAMPLE_MAX: usize = 512;

/// Seed of the internal subsample RNG: fixed, so `cluster_clients`
/// stays a pure function of its inputs (a stride sample would be
/// cheaper but can alias with structured point orderings, e.g. two
/// interleaved behaviour cohorts).
const EPS_SAMPLE_SEED: u64 = 0x5eed_ca11_ab5a_7e57;

/// Relative tolerance for ε-candidate dedup: adjacent distance
/// quantiles within one part in 10⁶ of each other produce the same
/// grid geometry for clustering purposes, so running DBSCAN for both
/// is pure waste. (`Vec::dedup` alone only drops *exactly* equal
/// values — near-degenerate distance distributions, e.g. a tight blob
/// plus float jitter, used to run the full search up to 8 times for
/// one structure.)
pub const EPS_DEDUP_REL_TOL: f64 = 1e-6;

/// Collapse adjacent near-equal ε candidates (input ascending,
/// positive). Keeps the first of each near-equal run, matching what
/// `dedup()` kept for exact ties — so historical search results (and
/// the selection goldens downstream of them) are unchanged whenever
/// the old dedup already collapsed the run.
pub fn dedup_eps_candidates(candidates: &mut Vec<f64>) {
    candidates.dedup_by(|a, b| (*a - *b).abs() <= EPS_DEDUP_REL_TOL * a.abs().max(b.abs()));
}

/// ε grid search (§V-C): pick the ε whose DBSCAN clustering maximizes the
/// Calinski–Harabasz index. Candidates are quantiles of the pairwise
/// distance distribution, so the search adapts to the feature scale.
/// Falls back to a single cluster when every ε yields one.
pub fn cluster_clients(points: &[Point], min_pts: usize) -> (Vec<isize>, usize) {
    let (labels, k, _) = cluster_clients_eps(points, min_pts);
    (labels, k)
}

/// [`cluster_clients`], additionally reporting the winning ε so a
/// caller can freeze the grid geometry (the incremental engine re-runs
/// this search only when drift crosses its documented threshold).
/// `None` when no ε produced usable structure — empty/singleton input,
/// all points identical, or every candidate collapsing to one cluster
/// (the degenerate single-cluster fallbacks).
pub fn cluster_clients_eps(points: &[Point], min_pts: usize) -> (Vec<isize>, usize, Option<f64>) {
    let n = points.len();
    if n == 0 {
        return (Vec::new(), 0, None);
    }
    if n == 1 {
        return (vec![0], 1, None);
    }

    // Pairwise distances -> ε candidates at fixed quantiles. Large
    // cohorts estimate the quantiles from a seeded subsample so this
    // stays O(EPS_SAMPLE_MAX²) instead of O(n²).
    let sample: Vec<&Point> = if n <= EPS_SAMPLE_MAX {
        points.iter().collect()
    } else {
        let mut rng = crate::util::Rng::seed_from_u64(EPS_SAMPLE_SEED ^ n as u64);
        let mut picked = rng.sample_indices(n, EPS_SAMPLE_MAX);
        picked.sort_unstable();
        picked.iter().map(|&i| &points[i]).collect()
    };
    let m = sample.len();
    let mut dists: Vec<f64> = Vec::with_capacity(m * (m - 1) / 2);
    for i in 0..m {
        for j in (i + 1)..m {
            dists.push(dist2(sample[i], sample[j]).sqrt());
        }
    }
    dists.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let quantile = |q: f64| -> f64 {
        let idx = ((dists.len() - 1) as f64 * q).round() as usize;
        dists[idx]
    };
    let mut candidates: Vec<f64> = [0.05, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.75]
        .iter()
        .map(|&q| quantile(q))
        .filter(|&e| e > 0.0)
        .collect();
    dedup_eps_candidates(&mut candidates);
    if candidates.is_empty() {
        // all points identical: one cluster
        return (vec![0; n], 1, None);
    }

    let mut best: Option<(f64, Vec<isize>, usize, f64)> = None;
    for eps in candidates {
        let mut labels = dbscan(points, &DbscanParams { eps, min_pts });
        let k = relabel_outliers(&mut labels);
        if k < 2 || k >= n {
            continue; // CH undefined; also useless for selection
        }
        let score = calinski_harabasz(points, &labels, k);
        if best.as_ref().map_or(true, |(s, _, _, _)| score > *s) {
            best = Some((score, labels, k, eps));
        }
    }
    match best {
        Some((_, labels, k, eps)) => (labels, k, Some(eps)),
        None => (vec![0; n], 1, None),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blob(cx: f64, cy: f64, n: usize, spread: f64) -> Vec<Point> {
        (0..n)
            .map(|i| {
                let a = i as f64 * 0.7;
                vec![cx + spread * a.sin(), cy + spread * a.cos()]
            })
            .collect()
    }

    #[test]
    fn grid_search_separates_two_blobs() {
        let mut pts = blob(0.0, 0.0, 10, 0.05);
        pts.extend(blob(10.0, 10.0, 10, 0.05));
        let (labels, k) = cluster_clients(&pts, 2);
        assert_eq!(k, 2);
        assert!(labels[..10].iter().all(|&l| l == labels[0]));
        assert!(labels[10..].iter().all(|&l| l == labels[10]));
        assert_ne!(labels[0], labels[10]);
    }

    #[test]
    fn grid_search_three_blobs() {
        let mut pts = blob(0.0, 0.0, 8, 0.05);
        pts.extend(blob(5.0, 5.0, 8, 0.05));
        pts.extend(blob(10.0, 0.0, 8, 0.05));
        let (_, k) = cluster_clients(&pts, 2);
        assert_eq!(k, 3);
    }

    #[test]
    fn identical_points_become_one_cluster() {
        let pts = vec![vec![1.0, 1.0]; 6];
        let (labels, k) = cluster_clients(&pts, 2);
        assert_eq!(k, 1);
        assert!(labels.iter().all(|&l| l == 0));
    }

    #[test]
    fn single_and_empty_inputs() {
        assert_eq!(cluster_clients(&[], 2), (vec![], 0));
        assert_eq!(cluster_clients(&[vec![3.0]], 2), (vec![0], 1));
    }

    #[test]
    fn relabel_outliers_makes_fresh_cluster() {
        let mut labels = vec![0, 1, NOISE, 0, NOISE];
        let k = relabel_outliers(&mut labels);
        assert_eq!(k, 3);
        assert_eq!(labels, vec![0, 1, 2, 0, 2]);
    }

    #[test]
    fn relabel_without_noise_keeps_count() {
        let mut labels = vec![0, 1, 1, 0];
        assert_eq!(relabel_outliers(&mut labels), 2);
    }

    #[test]
    fn dedup_collapses_near_equal_candidates() {
        // exact ties (the old behaviour) still collapse
        let mut c = vec![0.5, 0.5, 0.7];
        dedup_eps_candidates(&mut c);
        assert_eq!(c, vec![0.5, 0.7]);
        // near-equal within the relative tolerance collapse too,
        // keeping the first of the run
        let mut c = vec![1.0, 1.0 + 1e-9, 1.0 + 2e-9, 2.0];
        dedup_eps_candidates(&mut c);
        assert_eq!(c, vec![1.0, 2.0]);
        // distinct values survive
        let mut c = vec![1.0, 1.1, 2.0];
        dedup_eps_candidates(&mut c);
        assert_eq!(c, vec![1.0, 1.1, 2.0]);
    }

    #[test]
    fn near_degenerate_distances_dedup_to_few_candidates() {
        // Regression for the `candidates.dedup()` bug: a tight blob
        // (plus one far point so some quantiles differ) yields distance
        // quantiles that differ only by float jitter. The relative
        // tolerance must collapse each jitter run, and the search must
        // still produce a sane clustering.
        let mut pts: Vec<Point> = (0..40)
            .map(|i| {
                let a = i as f64 * 0.618;
                vec![1.0 + 1e-12 * a.sin(), 1.0 + 1e-12 * a.cos()]
            })
            .collect();
        pts.push(vec![100.0, 100.0]);
        let (labels, k, eps) = cluster_clients_eps(&pts, 2);
        assert_eq!(labels.len(), pts.len());
        assert!(k >= 1, "search must still produce a clustering, got {k}");
        if let Some(e) = eps {
            assert!(e.is_finite() && e > 0.0);
        }
        // pure function of its inputs, jitter or not
        assert_eq!(cluster_clients_eps(&pts, 2), (labels, k, eps));
        // and the exactly-degenerate case (every quantile identical)
        // still collapses to the single-cluster fallback
        let mut flat: Vec<Point> = vec![vec![2.0, 2.0]; 30];
        flat.push(vec![2.0, 2.0]);
        assert_eq!(cluster_clients_eps(&flat, 2), (vec![0; 31], 1, None));
    }

    #[test]
    fn winning_eps_is_reported_and_reusable() {
        let mut pts = blob(0.0, 0.0, 10, 0.05);
        pts.extend(blob(10.0, 10.0, 10, 0.05));
        let (labels, k, eps) = cluster_clients_eps(&pts, 2);
        assert_eq!(k, 2);
        let eps = eps.expect("two-blob structure must pin an ε");
        // re-running plain DBSCAN at the frozen ε reproduces the
        // partition (this is the contract the incremental engine leans on)
        let mut again = dbscan(&pts, &DbscanParams { eps, min_pts: 2 });
        let k_again = relabel_outliers(&mut again);
        assert_eq!(k_again, k);
        assert_eq!(again, labels);
        // degenerate inputs report no ε
        assert_eq!(cluster_clients_eps(&[], 2).2, None);
        assert_eq!(cluster_clients_eps(&[vec![1.0]], 2).2, None);
        assert_eq!(cluster_clients_eps(&vec![vec![1.0, 1.0]; 6], 2).2, None);
    }

    #[test]
    fn subsampled_eps_estimate_still_separates_blobs() {
        // Above EPS_SAMPLE_MAX the ε candidates come from a seeded
        // subsample; the search must stay deterministic and still find
        // the obvious 2-cluster structure — including on an interleaved
        // ordering a stride sample would alias with.
        let n = EPS_SAMPLE_MAX + 200;
        let pts: Vec<Point> = (0..n)
            .map(|i| {
                let c = if i % 2 == 0 { 0.0 } else { 50.0 };
                let a = i as f64 * 0.37;
                vec![c + 0.3 * a.sin(), 0.3 * a.cos()]
            })
            .collect();
        let (la, ka) = cluster_clients(&pts, 2);
        let (lb, kb) = cluster_clients(&pts, 2);
        assert_eq!(la, lb);
        assert_eq!(ka, kb);
        assert_eq!(ka, 2, "two blobs 50 apart must separate");
        assert_ne!(la[0], la[1]);
        assert_eq!(la[0], la[2]);
        assert_eq!(la[1], la[3]);
    }
}
