//! Persistent, incrementally-maintained DBSCAN over the behaviour grid.
//!
//! [`IncrementalDbscan`] keeps the uniform grid (cell size = ε), the
//! point set, and the standing cluster labels alive across selection
//! rounds. When a batch of points moves, appears, or disappears,
//! [`IncrementalDbscan::update`] reclusters only the affected
//! *cell-connected components* and splices the fresh labels into the
//! standing assignment — every untouched component keeps its labels
//! verbatim. Per-update work is proportional to the size of the
//! touched components, not to the total point count, which is what
//! lets `FedLesScan::select` run the full participant tier at 1M
//! clients instead of stratify-sampling it down to `COHORT_MAX`.
//!
//! ## Why splicing is exact
//!
//! With cell size = ε, two points whose cell coordinates differ by ≥ 2
//! on any axis are strictly more than ε apart. Density-reachability
//! therefore never crosses between two sets of occupied cells that are
//! not Chebyshev-1 adjacent: DBSCAN's partition factors over the
//! connected components of the "occupied cells, ±1 adjacency" graph.
//! An update seeds a BFS from every cell a changed point left or
//! entered, closes over the touched components, and re-runs the *same*
//! expansion ([`super::dbscan::expand`]) on exactly those members (in
//! ascending point-id order, matching the from-scratch seed order), so
//! the spliced labels are — component by component — the labels a
//! from-scratch [`super::dbscan::dbscan`] pass at the same ε assigns.
//! The property suite (`tests/proptests.rs`) pins this equivalence
//! under hundreds of random multi-round drift schedules.
//!
//! Fresh cluster ids come from a monotone allocator, so a spliced
//! component can never collide with a standing label of an untouched
//! one. [`NOISE`] stays `NOISE`. Label *values* are therefore not
//! byte-identical to a from-scratch run — only the partition is, which
//! is all the selection layer consumes (it orders clusters by mean
//! behaviour, not by id).

use std::collections::{HashMap, HashSet};

use super::dbscan::expand;
use super::grid::cell_key;
use super::{dist2, Point, NOISE};

/// Stable identifier for a point across updates (the strategy layer
/// uses client ids).
pub type PointId = usize;

/// Result of one [`IncrementalDbscan::update`] splice.
#[derive(Debug, Clone, Default)]
pub struct Splice {
    /// Points whose cell-components were re-expanded this update —
    /// `relabeled.len()`. Everything else kept its standing label.
    pub reclustered: usize,
    /// Touched cell-connected components.
    pub components: usize,
    /// `(id, label)` for every point in a touched component, ascending
    /// by id. Includes points whose label value is unchanged
    /// (`NOISE` → `NOISE`); non-noise components always get fresh ids.
    pub relabeled: Vec<(PointId, isize)>,
}

/// Persistent grid + standing labels; see the module docs.
#[derive(Debug, Clone)]
pub struct IncrementalDbscan {
    eps: f64,
    eps2: f64,
    min_pts: usize,
    /// Point dimensionality, fixed by the first insert. Mixed
    /// dimensions are refused (`update` → `None`): zip-shorter
    /// distance semantics are unrepresentable on a per-axis grid.
    dim: Option<usize>,
    /// Occupied cell → member ids. `HashSet` so membership updates are
    /// O(1) even in degenerate all-points-in-one-cell geometries; no
    /// output ever iterates a set without sorting first.
    cells: HashMap<Vec<i64>, HashSet<PointId>>,
    /// id → (point, its cell key).
    pts: HashMap<PointId, (Point, Vec<i64>)>,
    /// Standing labels; `NOISE` for outliers.
    labels: HashMap<PointId, isize>,
    /// Monotone cluster-id allocator — ids are never reused.
    next_cluster: isize,
}

impl IncrementalDbscan {
    /// A new empty engine at a frozen ε. `None` for a ε the grid cannot
    /// represent (non-finite or ≤ 0) — the caller keeps the
    /// from-scratch oracle for those.
    pub fn new(eps: f64, min_pts: usize) -> Option<Self> {
        if !eps.is_finite() || eps <= 0.0 {
            return None;
        }
        Some(Self {
            eps,
            eps2: eps * eps,
            min_pts,
            dim: None,
            cells: HashMap::new(),
            pts: HashMap::new(),
            labels: HashMap::new(),
            next_cluster: 0,
        })
    }

    /// The frozen neighbourhood radius.
    pub fn eps(&self) -> f64 {
        self.eps
    }

    /// Points currently in the engine.
    pub fn len(&self) -> usize {
        self.pts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pts.is_empty()
    }

    /// Standing label of a point, if present.
    pub fn label(&self, id: PointId) -> Option<isize> {
        self.labels.get(&id).copied()
    }

    /// Grid cell of a point, if present.
    pub fn cell(&self, id: PointId) -> Option<&[i64]> {
        self.pts.get(&id).map(|(_, k)| k.as_slice())
    }

    /// The cell a point *would* occupy, without inserting it. `None`
    /// when the coordinates are outside the grid's preconditions.
    pub fn key_for(&self, p: &[f64]) -> Option<Vec<i64>> {
        cell_key(p, self.eps)
    }

    /// Standing labels for `ids`, in order. Panics if an id is absent —
    /// callers query the ids they maintain.
    pub fn labels_for(&self, ids: &[PointId]) -> Vec<isize> {
        ids.iter().map(|id| self.labels[id]).collect()
    }

    /// Apply a batch of changes — `(id, Some(point))` upserts, `(id,
    /// None)` removes — and recluster the touched cell-components.
    ///
    /// Returns `None` (state **unchanged**) when a point cannot be
    /// placed on the grid: non-finite coordinate, cell index beyond the
    /// grid bound, or dimensionality differing from the standing
    /// points. The caller falls back to a full from-scratch recluster.
    pub fn update(&mut self, changes: &[(PointId, Option<Point>)]) -> Option<Splice> {
        // Validate every change before mutating anything, so a refusal
        // leaves the standing state intact for the caller's fallback.
        let mut dim = self.dim;
        let mut keyed: Vec<(PointId, Option<(&Point, Vec<i64>)>)> =
            Vec::with_capacity(changes.len());
        for (id, p) in changes {
            match p {
                Some(pt) => {
                    match dim {
                        Some(d) if d != pt.len() => return None,
                        None => dim = Some(pt.len()),
                        _ => {}
                    }
                    keyed.push((*id, Some((pt, cell_key(pt, self.eps)?))));
                }
                None => keyed.push((*id, None)),
            }
        }

        // Apply the grid mutations, collecting every cell a changed
        // point left or entered as a BFS seed.
        let mut seeds: HashSet<Vec<i64>> = HashSet::new();
        for (id, upsert) in keyed {
            let old_key = self.pts.get(&id).map(|(_, k)| k.clone());
            if let Some(old_key) = old_key {
                let emptied = match self.cells.get_mut(&old_key) {
                    Some(members) => {
                        members.remove(&id);
                        members.is_empty()
                    }
                    None => false,
                };
                if emptied {
                    self.cells.remove(&old_key);
                }
                seeds.insert(old_key);
            }
            match upsert {
                Some((pt, key)) => {
                    seeds.insert(key.clone());
                    self.cells.entry(key.clone()).or_default().insert(id);
                    self.pts.insert(id, (pt.clone(), key));
                }
                None => {
                    self.pts.remove(&id);
                    self.labels.remove(&id);
                }
            }
        }
        self.dim = dim;

        // Close over the touched cell-components: flood from every
        // occupied cell in or Chebyshev-1-adjacent to a seed cell.
        let mut visited: HashSet<Vec<i64>> = HashSet::new();
        let mut frontier: Vec<Vec<i64>> = Vec::new();
        let mut components = 0usize;
        let mut seed_cells: Vec<&Vec<i64>> = seeds.iter().collect();
        seed_cells.sort(); // deterministic component count, not required for labels
        for seed in seed_cells {
            let mut started = false;
            for_block(seed, |cell| {
                if self.cells.contains_key(cell) && !visited.contains(cell) {
                    visited.insert(cell.to_vec());
                    frontier.push(cell.to_vec());
                    started = true;
                }
            });
            if !started {
                continue;
            }
            components += 1; // adjacent seeds may merge components; this over-counts at most by seeds
            while let Some(cell) = frontier.pop() {
                for_block(&cell, |nb| {
                    if self.cells.contains_key(nb) && !visited.contains(nb) {
                        visited.insert(nb.to_vec());
                        frontier.push(nb.to_vec());
                    }
                });
            }
        }

        // Gather the members of the touched components in ascending id
        // order — the same seed order a from-scratch pass uses.
        let mut ids: Vec<PointId> = visited
            .iter()
            .flat_map(|c| self.cells[c].iter().copied())
            .collect();
        ids.sort_unstable();
        let index: HashMap<PointId, usize> =
            ids.iter().enumerate().map(|(i, &id)| (id, i)).collect();

        // Re-run the shared expansion on exactly these points. Every
        // ε-neighbour of a gathered point is itself gathered (the
        // closure walked all adjacent occupied cells), so the local
        // neighbourhood oracle sees the same sets the global one would.
        let neighbours = |i: usize| -> Vec<usize> {
            let (p, key) = &self.pts[&ids[i]];
            let mut out = Vec::new();
            for_block(key, |cell| {
                if let Some(members) = self.cells.get(cell) {
                    for &j in members {
                        if dist2(p, &self.pts[&j].0) <= self.eps2 {
                            out.push(index[&j]);
                        }
                    }
                }
            });
            out
        };
        let (local, _) = expand(ids.len(), self.min_pts, neighbours);

        // Splice: fresh ids for the non-noise local clusters.
        let base = self.next_cluster;
        let max_local = local.iter().copied().max().unwrap_or(NOISE);
        self.next_cluster += max_local + 1;
        let mut relabeled = Vec::with_capacity(ids.len());
        for (i, &id) in ids.iter().enumerate() {
            let label = if local[i] == NOISE { NOISE } else { base + local[i] };
            self.labels.insert(id, label);
            relabeled.push((id, label));
        }
        Some(Splice {
            reclustered: relabeled.len(),
            components,
            relabeled,
        })
    }
}

/// Visit the 3^d offset block [-1, 1]^d around `center` (odometer over
/// one scratch key, same discipline as `GridIndex::neighbours`).
fn for_block(center: &[i64], mut visit: impl FnMut(&[i64])) {
    let d = center.len();
    let mut offs = vec![-1i64; d];
    let mut key = vec![0i64; d];
    'cells: loop {
        for (k, (c, o)) in key.iter_mut().zip(center.iter().zip(&offs)) {
            *k = c + o;
        }
        visit(&key);
        let mut axis = 0;
        while axis < d {
            offs[axis] += 1;
            if offs[axis] <= 1 {
                continue 'cells;
            }
            offs[axis] = -1;
            axis += 1;
        }
        break;
    }
}

#[cfg(test)]
mod tests {
    use super::super::{dbscan, relabel_outliers, DbscanParams};
    use super::*;

    /// Partition-identity (with NOISE preserved on both sides): every
    /// pair clustered together on one side is together on the other.
    fn assert_partition_eq(ids: &[PointId], got: &[isize], want: &[isize], what: &str) {
        assert_eq!(got.len(), want.len(), "{what}: length");
        let mut fwd: HashMap<isize, isize> = HashMap::new();
        let mut rev: HashMap<isize, isize> = HashMap::new();
        for (i, (&g, &w)) in got.iter().zip(want).enumerate() {
            assert_eq!(
                g == NOISE,
                w == NOISE,
                "{what}: id {} noise mismatch ({g} vs {w})",
                ids[i]
            );
            if g == NOISE {
                continue;
            }
            assert_eq!(*fwd.entry(g).or_insert(w), w, "{what}: id {} fwd", ids[i]);
            assert_eq!(*rev.entry(w).or_insert(g), g, "{what}: id {} rev", ids[i]);
        }
    }

    fn engine_matches_oracle(engine: &IncrementalDbscan, pts: &[(PointId, Point)], what: &str) {
        let mut sorted: Vec<&(PointId, Point)> = pts.iter().collect();
        sorted.sort_by_key(|(id, _)| *id);
        let ids: Vec<PointId> = sorted.iter().map(|(id, _)| *id).collect();
        let points: Vec<Point> = sorted.iter().map(|(_, p)| p.clone()).collect();
        let want = dbscan(
            &points,
            &DbscanParams {
                eps: engine.eps(),
                min_pts: engine.min_pts,
            },
        );
        let got = engine.labels_for(&ids);
        assert_partition_eq(&ids, &got, &want, what);
    }

    #[test]
    fn bulk_insert_matches_from_scratch() {
        let pts: Vec<(PointId, Point)> = vec![
            (0, vec![0.0, 0.0]),
            (1, vec![0.1, 0.0]),
            (2, vec![0.0, 0.1]),
            (3, vec![5.0, 5.0]),
            (4, vec![5.1, 5.0]),
            (5, vec![9.9, 9.9]),
        ];
        let mut e = IncrementalDbscan::new(0.5, 2).unwrap();
        let changes: Vec<_> = pts.iter().map(|(id, p)| (*id, Some(p.clone()))).collect();
        let s = e.update(&changes).unwrap();
        assert_eq!(s.reclustered, 6);
        engine_matches_oracle(&e, &pts, "bulk insert");
        assert_eq!(e.label(5), Some(NOISE));
    }

    #[test]
    fn moving_a_point_merges_and_splits() {
        let mut pts: Vec<(PointId, Point)> = vec![
            (0, vec![0.0]),
            (1, vec![0.3]),
            (2, vec![2.0]),
            (3, vec![2.3]),
        ];
        let mut e = IncrementalDbscan::new(0.5, 2).unwrap();
        let changes: Vec<_> = pts.iter().map(|(id, p)| (*id, Some(p.clone()))).collect();
        e.update(&changes).unwrap();
        assert_ne!(e.label(0), e.label(2));

        // move id 1 next to the right pair: (0.0) alone, (1.7, 2.0, 2.3) chained
        pts[1].1 = vec![1.7];
        let s = e.update(&[(1, Some(vec![1.7]))]).unwrap();
        assert!(s.reclustered >= 3, "moved point's components recluster");
        engine_matches_oracle(&e, &pts, "after merge-ish move");

        // move it far away: 0 becomes noise, right blob survives
        pts[1].1 = vec![50.0];
        e.update(&[(1, Some(vec![50.0]))]).unwrap();
        engine_matches_oracle(&e, &pts, "after split move");
        assert_eq!(e.label(0), Some(NOISE));
        assert_eq!(e.label(1), Some(NOISE));
    }

    #[test]
    fn removal_recluster_only_touches_neighbourhood() {
        // two far-apart blobs; removing from one must not relabel the other
        let mut e = IncrementalDbscan::new(0.5, 2).unwrap();
        let pts: Vec<(PointId, Point)> = (0..4)
            .map(|i| (i, vec![i as f64 * 0.3]))
            .chain((4..8).map(|i| (i, vec![100.0 + i as f64 * 0.3])))
            .collect();
        let changes: Vec<_> = pts.iter().map(|(id, p)| (*id, Some(p.clone()))).collect();
        e.update(&changes).unwrap();
        let right_before = e.label(5).unwrap();
        let s = e.update(&[(0, None)]).unwrap();
        assert!(s.reclustered <= 3, "only the left blob reclusters, got {}", s.reclustered);
        assert_eq!(e.label(5), Some(right_before), "untouched component keeps labels");
        assert_eq!(e.len(), 7);
        let remaining: Vec<(PointId, Point)> =
            pts.into_iter().filter(|(id, _)| *id != 0).collect();
        engine_matches_oracle(&e, &remaining, "after removal");
    }

    #[test]
    fn noop_update_is_empty_splice() {
        let mut e = IncrementalDbscan::new(0.5, 2).unwrap();
        e.update(&[(0, Some(vec![0.0])), (1, Some(vec![0.1]))]).unwrap();
        let s = e.update(&[]).unwrap();
        assert_eq!(s.reclustered, 0);
        assert!(s.relabeled.is_empty());
    }

    #[test]
    fn unplaceable_point_refuses_and_preserves_state() {
        let mut e = IncrementalDbscan::new(0.5, 2).unwrap();
        e.update(&[(0, Some(vec![0.0])), (1, Some(vec![0.1]))]).unwrap();
        let before = (e.label(0), e.label(1), e.len());
        assert!(e.update(&[(2, Some(vec![f64::NAN]))]).is_none());
        assert!(e.update(&[(2, Some(vec![0.0, 0.0]))]).is_none(), "dim mismatch");
        assert_eq!((e.label(0), e.label(1), e.len()), before);
    }

    #[test]
    fn degenerate_eps_refuses_to_build() {
        assert!(IncrementalDbscan::new(0.0, 2).is_none());
        assert!(IncrementalDbscan::new(-1.0, 2).is_none());
        assert!(IncrementalDbscan::new(f64::NAN, 2).is_none());
    }

    #[test]
    fn relabel_outliers_view_matches_oracle_count() {
        // the strategy layer treats NOISE as one pseudo-cluster; check
        // the engine's label set supports the same view as the oracle's
        let pts: Vec<(PointId, Point)> = vec![
            (7, vec![0.0]),
            (3, vec![0.2]),
            (9, vec![10.0]),
        ];
        let mut e = IncrementalDbscan::new(0.5, 2).unwrap();
        let changes: Vec<_> = pts.iter().map(|(id, p)| (*id, Some(p.clone()))).collect();
        e.update(&changes).unwrap();
        let ids = vec![3, 7, 9];
        let mut got = e.labels_for(&ids);
        let k = relabel_outliers(&mut got);
        assert_eq!(k, 2, "one real cluster + outlier pseudo-cluster");
    }
}
