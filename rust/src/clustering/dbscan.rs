//! DBSCAN (Ester et al., KDD'96) — density-based clustering with noise.
//!
//! FedLesScan clusters at most a few hundred clients per round on 2-D
//! behaviour features, so the plain O(n²) neighbourhood scan is already
//! far below the round budget (the paper makes the same argument for
//! DBSCAN's cost, §V-C). No spatial index needed.

use super::{dist2, Point, NOISE};

#[derive(Debug, Clone, Copy)]
pub struct DbscanParams {
    /// Neighbourhood radius (Euclidean).
    pub eps: f64,
    /// Minimum neighbourhood size (including the point itself) to be a
    /// core point.
    pub min_pts: usize,
}

const UNVISITED: isize = -2;

/// Run DBSCAN; returns one label per point, `NOISE` (-1) for outliers.
pub fn dbscan(points: &[Point], params: &DbscanParams) -> Vec<isize> {
    let n = points.len();
    let eps2 = params.eps * params.eps;
    let mut labels = vec![UNVISITED; n];
    let mut cluster: isize = 0;

    let neighbours = |i: usize| -> Vec<usize> {
        (0..n)
            .filter(|&j| dist2(&points[i], &points[j]) <= eps2)
            .collect()
    };

    for i in 0..n {
        if labels[i] != UNVISITED {
            continue;
        }
        let nb = neighbours(i);
        if nb.len() < params.min_pts {
            labels[i] = NOISE;
            continue;
        }
        // expand a new cluster from this core point
        labels[i] = cluster;
        let mut frontier: Vec<usize> = nb;
        while let Some(j) = frontier.pop() {
            if labels[j] == NOISE {
                labels[j] = cluster; // border point adopted by the cluster
            }
            if labels[j] != UNVISITED {
                continue;
            }
            labels[j] = cluster;
            let nb_j = neighbours(j);
            if nb_j.len() >= params.min_pts {
                frontier.extend(nb_j);
            }
        }
        cluster += 1;
    }
    labels
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn two_well_separated_clusters() {
        let pts: Vec<Point> = vec![
            vec![0.0, 0.0],
            vec![0.1, 0.0],
            vec![0.0, 0.1],
            vec![5.0, 5.0],
            vec![5.1, 5.0],
            vec![5.0, 5.1],
        ];
        let labels = dbscan(
            &pts,
            &DbscanParams {
                eps: 0.5,
                min_pts: 2,
            },
        );
        assert_eq!(labels[0], labels[1]);
        assert_eq!(labels[0], labels[2]);
        assert_eq!(labels[3], labels[4]);
        assert_ne!(labels[0], labels[3]);
        assert!(labels.iter().all(|&l| l >= 0));
    }

    #[test]
    fn isolated_point_is_noise() {
        let pts: Vec<Point> = vec![
            vec![0.0],
            vec![0.1],
            vec![0.2],
            vec![100.0],
        ];
        let labels = dbscan(
            &pts,
            &DbscanParams {
                eps: 0.5,
                min_pts: 2,
            },
        );
        assert_eq!(labels[3], NOISE);
        assert!(labels[..3].iter().all(|&l| l == 0));
    }

    #[test]
    fn chain_connectivity_merges() {
        // points spaced 0.4 apart form one density-connected chain
        let pts: Vec<Point> = (0..10).map(|i| vec![i as f64 * 0.4]).collect();
        let labels = dbscan(
            &pts,
            &DbscanParams {
                eps: 0.5,
                min_pts: 2,
            },
        );
        assert!(labels.iter().all(|&l| l == 0));
    }

    #[test]
    fn min_pts_one_makes_every_point_core() {
        let pts: Vec<Point> = vec![vec![0.0], vec![10.0]];
        let labels = dbscan(
            &pts,
            &DbscanParams {
                eps: 0.5,
                min_pts: 1,
            },
        );
        assert_eq!(labels, vec![0, 1]);
    }

    #[test]
    fn empty_input() {
        let labels = dbscan(
            &[],
            &DbscanParams {
                eps: 1.0,
                min_pts: 2,
            },
        );
        assert!(labels.is_empty());
    }
}
