//! DBSCAN (Ester et al., KDD'96) — density-based clustering with noise.
//!
//! The paper evaluates ≤ 300 clients and waves the clustering cost off
//! accordingly (§V-C); this implementation does not. Neighbourhood
//! queries run through a uniform-grid spatial index
//! ([`super::grid::GridIndex`], cell size = ε, ≤ 3^d adjacent cells per
//! query), so a round's clustering is O(n · m̄) in the number of
//! eligible clients instead of the O(n²) full scan — the difference
//! between sub-second and hours at the 100k+ fleet sizes the ROADMAP
//! targets. The plain scan survives as [`dbscan_naive`]: it is the
//! oracle the property suite checks the indexed path against
//! (`tests/proptests.rs`) and the fallback for degenerate inputs the
//! grid refuses (ε ≤ 0, non-finite coordinates, cell-index overflow).
//!
//! The indexed path runs a rewritten expansion ([`expand`]) whose
//! frontier is deduplicated: a point enters it at most once, so peak
//! frontier memory is O(n). (The seed implementation pushed every
//! neighbour list verbatim, which on a dense blob — every point within
//! ε of every other — queued O(n²) entries.) [`dbscan_naive`] keeps
//! the seed's loop *verbatim* so the oracle shares no code with the
//! path under test.
//!
//! Label semantics are identical between the two paths: cluster ids are
//! assigned in seed order (ascending point index), membership is the
//! standard density-reachability closure, and a border point adopted by
//! several clusters keeps the lowest-id cluster that expanded first —
//! all functions of the neighbour *sets*, not of the order a query
//! returns them in or the frontier's duplication discipline, which is
//! what makes the index (and the deduped expansion) drop-in.

use super::grid::GridIndex;
use super::{dist2, Point, NOISE};

#[derive(Debug, Clone, Copy)]
pub struct DbscanParams {
    /// Neighbourhood radius (Euclidean).
    pub eps: f64,
    /// Minimum neighbourhood size (including the point itself) to be a
    /// core point.
    pub min_pts: usize,
}

const UNVISITED: isize = -2;

/// Run DBSCAN; returns one label per point, `NOISE` (-1) for outliers.
/// Grid-indexed neighbourhood queries; falls back to [`dbscan_naive`]
/// when the input is outside the grid's preconditions.
pub fn dbscan(points: &[Point], params: &DbscanParams) -> Vec<isize> {
    match GridIndex::build(points, params.eps) {
        Some(grid) => expand(points.len(), params.min_pts, |i| grid.neighbours(i)).0,
        None => dbscan_naive(points, params),
    }
}

/// Reference DBSCAN: the seed implementation, verbatim — O(n²)
/// neighbourhood scans *and* the original duplicated-frontier
/// expansion. Label-identical to [`dbscan`], and deliberately sharing
/// no code with it: this is the independent oracle the property suite
/// checks both the grid index and the rewritten [`expand`] against, so
/// a bug in either cannot cancel out of the comparison. Also the
/// fallback for inputs the grid index cannot represent (where its
/// O(n²) scan and O(n²)-worst-case frontier are acceptable because the
/// fallback only triggers on degenerate inputs or small test cases).
pub fn dbscan_naive(points: &[Point], params: &DbscanParams) -> Vec<isize> {
    let n = points.len();
    let eps2 = params.eps * params.eps;
    let mut labels = vec![UNVISITED; n];
    let mut cluster: isize = 0;

    let neighbours = |i: usize| -> Vec<usize> {
        (0..n)
            .filter(|&j| dist2(&points[i], &points[j]) <= eps2)
            .collect()
    };

    for i in 0..n {
        if labels[i] != UNVISITED {
            continue;
        }
        let nb = neighbours(i);
        if nb.len() < params.min_pts {
            labels[i] = NOISE;
            continue;
        }
        // expand a new cluster from this core point
        labels[i] = cluster;
        let mut frontier: Vec<usize> = nb;
        while let Some(j) = frontier.pop() {
            if labels[j] == NOISE {
                labels[j] = cluster; // border point adopted by the cluster
            }
            if labels[j] != UNVISITED {
                continue;
            }
            labels[j] = cluster;
            let nb_j = neighbours(j);
            if nb_j.len() >= params.min_pts {
                frontier.extend(nb_j);
            }
        }
        cluster += 1;
    }
    labels
}

/// Frontier push with the visited/queued dedupe: only points that can
/// still change state (unvisited, or noise awaiting border adoption)
/// enter, each at most once — peak frontier memory is O(n).
fn enqueue(frontier: &mut Vec<usize>, queued: &mut [bool], labels: &[isize], nb: &[usize]) {
    for &j in nb {
        if !queued[j] && (labels[j] == UNVISITED || labels[j] == NOISE) {
            queued[j] = true;
            frontier.push(j);
        }
    }
}

/// Shared cluster expansion over a neighbourhood oracle. Returns the
/// labels plus the peak frontier length — the latter is O(n) thanks to
/// the queued-point dedupe and is pinned by the dense-blob regression
/// test below. `pub(crate)`: [`super::incremental`] re-runs this exact
/// expansion on affected cell-components, so a component's spliced
/// labels are definitionally the labels a from-scratch pass assigns.
pub(crate) fn expand(
    n: usize,
    min_pts: usize,
    neighbours: impl Fn(usize) -> Vec<usize>,
) -> (Vec<isize>, usize) {
    let mut labels = vec![UNVISITED; n];
    let mut queued = vec![false; n];
    let mut cluster: isize = 0;
    let mut peak_frontier = 0usize;
    let mut frontier: Vec<usize> = Vec::new();

    for i in 0..n {
        if labels[i] != UNVISITED {
            continue;
        }
        let nb = neighbours(i);
        if nb.len() < min_pts {
            labels[i] = NOISE;
            continue;
        }
        // expand a new cluster from this core point
        labels[i] = cluster;
        enqueue(&mut frontier, &mut queued, &labels, &nb);
        peak_frontier = peak_frontier.max(frontier.len());
        while let Some(j) = frontier.pop() {
            if labels[j] == NOISE {
                labels[j] = cluster; // border point adopted by the cluster
                continue;
            }
            debug_assert_eq!(labels[j], UNVISITED, "queued points cannot be labelled yet");
            labels[j] = cluster;
            let nb_j = neighbours(j);
            if nb_j.len() >= min_pts {
                enqueue(&mut frontier, &mut queued, &labels, &nb_j);
                peak_frontier = peak_frontier.max(frontier.len());
            }
        }
        cluster += 1;
    }
    (labels, peak_frontier)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn two_well_separated_clusters() {
        let pts: Vec<Point> = vec![
            vec![0.0, 0.0],
            vec![0.1, 0.0],
            vec![0.0, 0.1],
            vec![5.0, 5.0],
            vec![5.1, 5.0],
            vec![5.0, 5.1],
        ];
        let labels = dbscan(
            &pts,
            &DbscanParams {
                eps: 0.5,
                min_pts: 2,
            },
        );
        assert_eq!(labels[0], labels[1]);
        assert_eq!(labels[0], labels[2]);
        assert_eq!(labels[3], labels[4]);
        assert_ne!(labels[0], labels[3]);
        assert!(labels.iter().all(|&l| l >= 0));
    }

    #[test]
    fn isolated_point_is_noise() {
        let pts: Vec<Point> = vec![
            vec![0.0],
            vec![0.1],
            vec![0.2],
            vec![100.0],
        ];
        let labels = dbscan(
            &pts,
            &DbscanParams {
                eps: 0.5,
                min_pts: 2,
            },
        );
        assert_eq!(labels[3], NOISE);
        assert!(labels[..3].iter().all(|&l| l == 0));
    }

    #[test]
    fn chain_connectivity_merges() {
        // points spaced 0.4 apart form one density-connected chain
        let pts: Vec<Point> = (0..10).map(|i| vec![i as f64 * 0.4]).collect();
        let labels = dbscan(
            &pts,
            &DbscanParams {
                eps: 0.5,
                min_pts: 2,
            },
        );
        assert!(labels.iter().all(|&l| l == 0));
    }

    #[test]
    fn min_pts_one_makes_every_point_core() {
        let pts: Vec<Point> = vec![vec![0.0], vec![10.0]];
        let labels = dbscan(
            &pts,
            &DbscanParams {
                eps: 0.5,
                min_pts: 1,
            },
        );
        assert_eq!(labels, vec![0, 1]);
    }

    #[test]
    fn empty_input() {
        let labels = dbscan(
            &[],
            &DbscanParams {
                eps: 1.0,
                min_pts: 2,
            },
        );
        assert!(labels.is_empty());
    }

    #[test]
    fn naive_matches_grid_on_the_unit_cases() {
        let cases: Vec<Vec<Point>> = vec![
            vec![
                vec![0.0, 0.0],
                vec![0.1, 0.0],
                vec![0.0, 0.1],
                vec![5.0, 5.0],
                vec![5.1, 5.0],
                vec![5.0, 5.1],
            ],
            (0..10).map(|i| vec![i as f64 * 0.4]).collect(),
            vec![vec![1.0, 1.0]; 6],
        ];
        for (ci, pts) in cases.iter().enumerate() {
            for min_pts in [1usize, 2, 3] {
                let p = DbscanParams { eps: 0.5, min_pts };
                assert_eq!(
                    dbscan(pts, &p),
                    dbscan_naive(pts, &p),
                    "case {ci} min_pts {min_pts}"
                );
            }
        }
    }

    #[test]
    fn degenerate_eps_falls_back_to_naive() {
        // ε = 0: only exactly-coincident points are neighbours. The grid
        // cannot build (cell size 0); the public entrypoint must still
        // answer, via the naive fallback.
        let pts: Vec<Point> = vec![vec![1.0], vec![1.0], vec![2.0]];
        let p = DbscanParams {
            eps: 0.0,
            min_pts: 2,
        };
        let labels = dbscan(&pts, &p);
        assert_eq!(labels, dbscan_naive(&pts, &p));
        assert_eq!(labels[0], labels[1]);
        assert_eq!(labels[2], NOISE);
    }

    #[test]
    fn ragged_dimensions_fall_back_to_naive() {
        // dist2 zips the shorter point, so [0.0] and [0.0, 9.0] are
        // coincident under the naive scan; the grid refuses ragged
        // inputs and the public entrypoint must agree with the oracle.
        let pts: Vec<Point> = vec![vec![0.0], vec![0.0, 9.0], vec![5.0]];
        let p = DbscanParams {
            eps: 1.0,
            min_pts: 2,
        };
        let labels = dbscan(&pts, &p);
        assert_eq!(labels, dbscan_naive(&pts, &p));
        assert_eq!(labels[0], labels[1], "zip-shorter semantics preserved");
    }

    #[test]
    fn dense_blob_frontier_stays_linear() {
        // Regression: `frontier.extend(nb_j)` queues every neighbour
        // list verbatim — on a blob where everyone is within ε of
        // everyone the frontier balloons to O(n²) entries (the oracle
        // still does this, deliberately). The indexed path's deduped
        // expansion must keep the peak frontier at most n.
        let n = 400;
        let pts: Vec<Point> = (0..n)
            .map(|i| {
                let a = i as f64 * 0.618;
                vec![0.01 * a.sin(), 0.01 * a.cos()]
            })
            .collect();
        let eps2 = 1.0f64;
        let neighbours = |i: usize| -> Vec<usize> {
            (0..n).filter(|&j| dist2(&pts[i], &pts[j]) <= eps2).collect()
        };
        let (labels, peak) = expand(n, 2, neighbours);
        assert!(labels.iter().all(|&l| l == 0), "one dense cluster expected");
        assert!(peak <= n, "frontier peaked at {peak} for n = {n}");
        // both public paths agree with the deduped expansion here
        let params = DbscanParams {
            eps: 1.0,
            min_pts: 2,
        };
        assert_eq!(dbscan(&pts, &params), labels);
        assert_eq!(dbscan_naive(&pts, &params), labels);
    }
}
