//! Uniform-grid spatial index for DBSCAN neighbourhood queries.
//!
//! Cell size equals the query radius ε, so every ε-neighbour of a point
//! lives in the point's own cell or one of its 3^d − 1 adjacent cells
//! (d = 2–3 for the behaviour features; the index is dimension-generic
//! for the test suite). Building the index is one O(n) pass; a
//! neighbourhood query scans ≤ 3^d cells and distance-filters their
//! occupants, so DBSCAN over n clients costs O(n · m̄) where m̄ is the
//! mean occupancy of a 3^d cell block — linear for the bounded-density
//! clouds client behaviour produces, against the naive scan's O(n²).
//!
//! Degenerate inputs (ε ≤ 0, non-finite ε, or coordinates whose cell
//! index would overflow `i64`) refuse to build ([`GridIndex::build`]
//! returns `None`) and the caller falls back to the naive scan, which
//! has no such preconditions.

use std::collections::HashMap;

use super::{dist2, Point};

/// Grid index over a point set for radius-ε neighbourhood queries.
pub struct GridIndex<'a> {
    points: &'a [Point],
    eps2: f64,
    /// cell coordinate (⌊x_j/ε⌋ per axis) → indices of occupants, in
    /// point order (deterministic: built by one in-order pass).
    cells: HashMap<Vec<i64>, Vec<u32>>,
    /// Per-point cell key, precomputed at build time so a query never
    /// re-derives it (and the odometer below can reuse one scratch
    /// buffer instead of allocating a key per adjacent cell — queries
    /// are the 100k-per-pass hot path).
    keys: Vec<Vec<i64>>,
}

/// Cell-coordinate bound: beyond it the `x / eps` quotient's f64
/// rounding error approaches a whole cell (ulp(2^52) ≈ 0.5), which
/// could bin a true ε-neighbour two cells away and silently escape the
/// ±1 scan. At ≤ 1e12 (< 2^40) the quotient error is ≤ ~2^-12 cells —
/// geometrically irrelevant — and ±1 stepping cannot overflow `i64`
/// either. Inputs beyond the bound fall back to the naive scan.
const MAX_CELL: f64 = 1.0e12;

pub(crate) fn cell_key(p: &[f64], eps: f64) -> Option<Vec<i64>> {
    p.iter()
        .map(|&x| {
            let c = (x / eps).floor();
            if c.is_finite() && c.abs() <= MAX_CELL {
                Some(c as i64)
            } else {
                None
            }
        })
        .collect()
}

impl<'a> GridIndex<'a> {
    /// Build the index, or `None` when ε or the coordinates are outside
    /// the grid's preconditions (the caller should use the naive scan).
    /// Ragged dimensionality is refused too: `dist2` zips the shorter
    /// point, so under the naive scan points of different dimension can
    /// be neighbours — a cell grid keyed per-dimension cannot represent
    /// that, and label identity with the oracle comes first.
    pub fn build(points: &'a [Point], eps: f64) -> Option<Self> {
        if !eps.is_finite() || eps <= 0.0 {
            return None;
        }
        if points.len() > u32::MAX as usize {
            return None;
        }
        let dim = points.first().map_or(0, |p| p.len());
        if points.iter().any(|p| p.len() != dim) {
            return None;
        }
        let mut cells: HashMap<Vec<i64>, Vec<u32>> = HashMap::new();
        let mut keys = Vec::with_capacity(points.len());
        for (i, p) in points.iter().enumerate() {
            let key = cell_key(p, eps)?;
            cells.entry(key.clone()).or_default().push(i as u32);
            keys.push(key);
        }
        Some(Self {
            points,
            eps2: eps * eps,
            cells,
            keys,
        })
    }

    /// Indices (ascending, self included) of all points within ε of
    /// point `i` — the same set the naive O(n) scan returns.
    pub fn neighbours(&self, i: usize) -> Vec<usize> {
        let p = &self.points[i][..];
        let center = &self.keys[i];
        let d = center.len();
        let mut out = Vec::new();
        // Odometer over the 3^d offset block [-1, 1]^d; one scratch key
        // buffer serves every probed cell.
        let mut offs = vec![-1i64; d];
        let mut key = vec![0i64; d];
        'cells: loop {
            for (k, (c, o)) in key.iter_mut().zip(center.iter().zip(&offs)) {
                *k = c + o;
            }
            if let Some(cands) = self.cells.get(&key) {
                for &j in cands {
                    if dist2(p, &self.points[j as usize]) <= self.eps2 {
                        out.push(j as usize);
                    }
                }
            }
            let mut axis = 0;
            while axis < d {
                offs[axis] += 1;
                if offs[axis] <= 1 {
                    continue 'cells;
                }
                offs[axis] = -1;
                axis += 1;
            }
            break; // 0-d points: the single (empty-offset) cell was visited
        }
        out.sort_unstable();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_neighbours(points: &[Point], i: usize, eps: f64) -> Vec<usize> {
        let eps2 = eps * eps;
        (0..points.len())
            .filter(|&j| dist2(&points[i], &points[j]) <= eps2)
            .collect()
    }

    #[test]
    fn matches_naive_on_a_small_cloud() {
        let pts: Vec<Point> = vec![
            vec![0.0, 0.0],
            vec![0.4, 0.1],
            vec![0.9, 0.9],
            vec![5.0, 5.0],
            vec![-0.3, 0.2],
        ];
        let eps = 1.0;
        let g = GridIndex::build(&pts, eps).unwrap();
        for i in 0..pts.len() {
            assert_eq!(g.neighbours(i), naive_neighbours(&pts, i, eps), "point {i}");
        }
    }

    #[test]
    fn exact_cell_boundary_points_are_found() {
        // Points sitting exactly on multiples of ε land on cell edges;
        // the ±1 block scan must still see neighbours across the edge.
        let eps = 0.5;
        let pts: Vec<Point> = (0..8).map(|i| vec![i as f64 * eps]).collect();
        let g = GridIndex::build(&pts, eps).unwrap();
        for i in 0..pts.len() {
            assert_eq!(g.neighbours(i), naive_neighbours(&pts, i, eps), "point {i}");
        }
    }

    #[test]
    fn identical_points_share_one_cell() {
        let pts = vec![vec![2.0, 2.0]; 5];
        let g = GridIndex::build(&pts, 0.1).unwrap();
        assert_eq!(g.neighbours(3), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn negative_coordinates_floor_correctly() {
        // floor(-0.1 / 1.0) = -1: the point must not be binned with cell 0.
        let pts: Vec<Point> = vec![vec![-0.1], vec![0.1], vec![-1.5]];
        let g = GridIndex::build(&pts, 1.0).unwrap();
        for i in 0..pts.len() {
            assert_eq!(g.neighbours(i), naive_neighbours(&pts, i, 1.0), "point {i}");
        }
    }

    #[test]
    fn degenerate_eps_refuses_to_build() {
        let pts = vec![vec![0.0]];
        assert!(GridIndex::build(&pts, 0.0).is_none());
        assert!(GridIndex::build(&pts, -1.0).is_none());
        assert!(GridIndex::build(&pts, f64::NAN).is_none());
        assert!(GridIndex::build(&pts, f64::INFINITY).is_none());
        // non-finite coordinate: no valid cell
        assert!(GridIndex::build(&[vec![f64::NAN]], 1.0).is_none());
        // tiny ε under a huge coordinate overflows the cell index
        assert!(GridIndex::build(&[vec![1.0e300]], 1.0e-300).is_none());
        // ragged dimensionality: naive-scan semantics (dist2 zips the
        // shorter point) are unrepresentable on a grid
        assert!(GridIndex::build(&[vec![0.0], vec![0.0, 0.0]], 1.0).is_none());
    }
}
