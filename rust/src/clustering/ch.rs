//! Calinski–Harabasz index (variance-ratio criterion, 1974).
//!
//! The paper picks DBSCAN's ε by grid search on this score (§V-C): the
//! ratio of between-cluster to within-cluster dispersion, scaled by the
//! degrees of freedom. Higher is better; undefined for k < 2 or k == n.

use super::Point;

/// Compute the CH index for a labelling with `k` clusters. Labels must be
/// in `0..k`. Returns 0.0 when within-cluster dispersion is zero (the
/// clustering is "perfect"; callers treat larger as better so a tiny
/// positive epsilon denominator would also work — 0 keeps it total).
pub fn calinski_harabasz(points: &[Point], labels: &[isize], k: usize) -> f64 {
    let n = points.len();
    assert_eq!(n, labels.len());
    if k < 2 || k >= n {
        return f64::NEG_INFINITY;
    }
    let dim = points[0].len();

    // global centroid
    let mut global = vec![0.0; dim];
    for p in points {
        for (g, v) in global.iter_mut().zip(p) {
            *g += v;
        }
    }
    global.iter_mut().for_each(|g| *g /= n as f64);

    // per-cluster centroids + sizes
    let mut centroids = vec![vec![0.0; dim]; k];
    let mut sizes = vec![0usize; k];
    for (p, &l) in points.iter().zip(labels) {
        let l = l as usize;
        sizes[l] += 1;
        for (c, v) in centroids[l].iter_mut().zip(p) {
            *c += v;
        }
    }
    for (c, &s) in centroids.iter_mut().zip(&sizes) {
        if s > 0 {
            c.iter_mut().for_each(|v| *v /= s as f64);
        }
    }

    // between-group and within-group sums of squares
    let mut ssb = 0.0;
    for (c, &s) in centroids.iter().zip(&sizes) {
        let d2: f64 = c
            .iter()
            .zip(&global)
            .map(|(a, b)| (a - b) * (a - b))
            .sum();
        ssb += s as f64 * d2;
    }
    let mut ssw = 0.0;
    for (p, &l) in points.iter().zip(labels) {
        let c = &centroids[l as usize];
        ssw += p
            .iter()
            .zip(c)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>();
    }
    if ssw <= f64::EPSILON {
        return if ssb > 0.0 { f64::INFINITY } else { 0.0 };
    }
    (ssb / (k as f64 - 1.0)) / (ssw / (n as f64 - k as f64))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn good_split_beats_bad_split() {
        let pts: Vec<Point> = vec![
            vec![0.0],
            vec![0.1],
            vec![0.2],
            vec![10.0],
            vec![10.1],
            vec![10.2],
        ];
        let good = vec![0, 0, 0, 1, 1, 1];
        let bad = vec![0, 1, 0, 1, 0, 1];
        assert!(
            calinski_harabasz(&pts, &good, 2) > calinski_harabasz(&pts, &bad, 2)
        );
    }

    #[test]
    fn degenerate_k_is_neg_infinity() {
        let pts: Vec<Point> = vec![vec![0.0], vec![1.0], vec![2.0]];
        assert_eq!(
            calinski_harabasz(&pts, &[0, 0, 0], 1),
            f64::NEG_INFINITY
        );
        assert_eq!(
            calinski_harabasz(&pts, &[0, 1, 2], 3),
            f64::NEG_INFINITY
        );
    }

    #[test]
    fn perfect_separation_is_infinite() {
        let pts: Vec<Point> = vec![vec![0.0], vec![0.0], vec![5.0], vec![5.0]];
        assert_eq!(
            calinski_harabasz(&pts, &[0, 0, 1, 1], 2),
            f64::INFINITY
        );
    }
}
