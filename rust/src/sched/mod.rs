//! Event-driven round scheduler: the virtual-clock machinery between the
//! coordinator and the simulated FaaS platform.
//!
//! The seed controller ran one monolithic serial loop — every client was
//! invoked at `round_start`, trained sequentially on one core, and its
//! update was folded in in *selection* order. This module replaces that
//! with the semi-asynchronous shape the paper actually describes (§V-D)
//! and FedLess implements (functions fire concurrently; updates land on
//! their own timeline):
//!
//! * **Outcome before compute** — the platform decides each invocation's
//!   fate (crash / late / on-time) and full virtual timeline up front
//!   ([`crate::faas::SimulatedGcf::invoke`] draws no RNG from the
//!   training path), so doomed invocations never burn real training
//!   cycles.
//! * **Parallel client execution** — the real `Backend::train_round`
//!   calls for the surviving invocations run on the persistent executor
//!   plane ([`crate::exec`]): a long-lived worker pool with
//!   work-stealing dispatch, spawned once per experiment instead of one
//!   `thread::scope` per round. Round mode re-slots completions
//!   positionally, so the outcome is identical to the serial order.
//!   (The scoped-thread fan-out [`train_parallel`] is retained as the
//!   spawn-per-round reference path that `benches/executor.rs` compares
//!   the pool against.)
//! * **Virtual-clock event queue** — completions are replayed through a
//!   [`BinaryHeap`] min-heap ([`EventQueue`]) in true arrival order:
//!   fresh updates aggregate in the order they reached the parameter
//!   server, and late updates enter the staleness buffer the same way.
//!   Continuous mode pushes events incrementally into the same queue as
//!   it dispatches replacements.
//! * **In-flight ledger** — a late client whose function is still
//!   running past the round boundary ([`InFlight`]) is not re-invoked
//!   mid-flight; the seed controller happily double-invoked it, which
//!   both corrupted the warm-instance bookkeeping and double-billed the
//!   client.
//!
//! Everything here is deterministic in the experiment seed: the heap
//! tie-breaks on platform issue order (a **pinned** contract — see
//! [`CompletionEvent`]'s `Ord`), executor completions are re-keyed by
//! job id, and no wall-clock time ever enters the virtual timeline.

use std::collections::{BinaryHeap, HashMap};

use crate::faas::{Invocation, Outcome};
use crate::paramsvr::StaleUpdate;
use crate::runtime::{Backend, TrainRequest, TrainResult};
use crate::{ClientId, Result};

/// One planned invocation: the platform decided the entire virtual
/// timeline (including the crash/late/on-time outcome) before any real
/// compute ran.
#[derive(Debug, Clone, Copy)]
pub struct ClientPlan {
    pub client: ClientId,
    pub inv: Invocation,
    /// Partial-work step count for this client (FedProx toleration).
    pub num_steps: i32,
}

/// A completion on the virtual clock. `seq` is the platform issue order
/// (selection order): it tie-breaks simultaneous completions
/// deterministically and indexes back into the plan/result tables.
#[derive(Debug, Clone, Copy)]
pub struct CompletionEvent {
    pub at_s: f64,
    pub seq: usize,
    pub client: ClientId,
    pub outcome: Outcome,
}

impl PartialEq for CompletionEvent {
    fn eq(&self, other: &Self) -> bool {
        self.at_s.total_cmp(&other.at_s).is_eq() && self.seq == other.seq
    }
}

impl Eq for CompletionEvent {}

impl PartialOrd for CompletionEvent {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for CompletionEvent {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // `BinaryHeap` is a max-heap; invert so the earliest completion
        // (lowest time, then lowest issue seq) pops first.
        //
        // The `seq` tie-break is a **pinned contract**, not a nicety:
        // `BinaryHeap` makes no ordering promise for equal elements, so
        // without it, simultaneous completions (same `at_s` — e.g. two
        // forced crashes billed to the same deadline) would pop in
        // unspecified heap order. Round mode tolerates that only by
        // luck of its accounting; continuous-mode replay determinism
        // (selection/history state evolves per event) requires
        // simultaneous events to pop in platform issue order. Pinned by
        // `event_queue_ties_break_on_issue_order` and
        // `event_queue_interleaved_ties_stay_in_issue_order`; mirrored
        // exactly by `python/mirror/continuous.py`.
        other
            .at_s
            .total_cmp(&self.at_s)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Min-heap of completion events ordered by virtual arrival time.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<CompletionEvent>,
}

impl EventQueue {
    pub fn new() -> Self {
        Self::default()
    }

    /// Queue every completion of a planned invocation batch. The heap
    /// is pre-sized: fleet-scale rounds schedule tens of thousands of
    /// completions and should not pay the doubling reallocations.
    pub fn schedule(plans: &[ClientPlan]) -> Self {
        let mut q = Self {
            heap: BinaryHeap::with_capacity(plans.len()),
        };
        for (seq, p) in plans.iter().enumerate() {
            q.push(CompletionEvent {
                at_s: p.inv.finished_at,
                seq,
                client: p.client,
                outcome: p.inv.outcome,
            });
        }
        q
    }

    pub fn push(&mut self, ev: CompletionEvent) {
        self.heap.push(ev);
    }

    /// Earliest pending completion, or `None` when drained.
    pub fn pop(&mut self) -> Option<CompletionEvent> {
        self.heap.pop()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

/// In-flight ledger: client → virtual time its current invocation
/// finishes. A client still running past the round boundary must not be
/// re-invoked mid-flight — the platform would fan out a second instance
/// while the controller double-counted the client.
#[derive(Debug, Default)]
pub struct InFlight {
    until: HashMap<ClientId, f64>,
}

impl InFlight {
    pub fn new() -> Self {
        Self::default()
    }

    /// Drop entries whose invocation has completed by `now_s`.
    pub fn expire(&mut self, now_s: f64) {
        self.until.retain(|_, &mut t| t > now_s);
    }

    pub fn is_busy(&self, client: ClientId) -> bool {
        self.until.contains_key(&client)
    }

    /// Record an invocation that outlives the current round (late
    /// completion or hard-timeout kill).
    pub fn track(&mut self, client: ClientId, until_s: f64) {
        self.until.insert(client, until_s);
    }

    pub fn len(&self) -> usize {
        self.until.len()
    }

    pub fn is_empty(&self) -> bool {
        self.until.is_empty()
    }
}

/// Partition a strategy selection into the clients to invoke now and
/// those skipped because their previous invocation is still in flight.
/// Order is preserved (the platform RNG stream is consumed in invoke
/// order, so this must stay deterministic).
pub fn split_in_flight(
    selected: &[ClientId],
    in_flight: &InFlight,
) -> (Vec<ClientId>, Vec<ClientId>) {
    let mut invoke = Vec::with_capacity(selected.len());
    let mut skipped = Vec::new();
    for &c in selected {
        if in_flight.is_busy(c) {
            skipped.push(c);
        } else {
            invoke.push(c);
        }
    }
    (invoke, skipped)
}

/// Order drained stale updates newest-first — highest produced round,
/// then earliest arrival, then client id — and cap the combined
/// fresh + stale aggregation set at `k_max`, fresh first. Returns
/// `(kept, overflow)`: only `kept` enters the aggregation, and only it
/// may receive `stale_applied` accounting or `record_late_completion`
/// history credit. `overflow` is still-τ-valid work the round had no
/// room for — the coordinator re-buffers it into the parameter server
/// so it can land in a later aggregation (the seed discarded it
/// permanently even when it had not yet τ-expired; `drain_stale`
/// remains the only place updates age out).
pub fn cap_stale(
    fresh_len: usize,
    mut drained: Vec<StaleUpdate>,
    k_max: usize,
) -> (Vec<StaleUpdate>, Vec<StaleUpdate>) {
    drained.sort_by(|a, b| {
        b.produced_round
            .cmp(&a.produced_round)
            .then_with(|| a.arrived_at_s.total_cmp(&b.arrived_at_s))
            .then_with(|| a.client.cmp(&b.client))
    });
    let keep = k_max.saturating_sub(fresh_len).min(drained.len());
    let overflow = drained.split_off(keep);
    (drained, overflow)
}

/// Median of an already-sorted distance set (the `stale_norm_clip`
/// reference). Even-length sets average the two middles — the seed took
/// the upper middle, biasing the clip threshold wide on every
/// even-sized fresh set. Empty input has no median; the caller skips
/// the filter when there are no fresh updates.
pub fn median_sorted(sorted: &[f64]) -> f64 {
    assert!(!sorted.is_empty(), "median of an empty set");
    let mid = sorted.len() / 2;
    if sorted.len() % 2 == 0 {
        0.5 * (sorted[mid - 1] + sorted[mid])
    } else {
        sorted[mid]
    }
}

/// Default worker count for the parallel training pool — the same
/// per-core fan-out the parameter plane uses for chunk-parallel folds
/// ([`crate::params::default_workers`] is the single definition).
pub fn default_workers() -> usize {
    crate::params::default_workers()
}

/// Execute `Backend::train_round` for every `Some` job across scoped
/// worker threads. Results come back positionally aligned with `jobs`;
/// `None` marks a skipped (doomed) invocation. Uses
/// [`default_workers`] threads — unless the backend opts out of
/// fan-out via [`Backend::parallel_train`] (the PJRT backend would
/// recompile its executables on every fresh worker thread), in which
/// case the jobs run inline on the caller's thread.
///
/// This is the historical **spawn-per-round** path: one `thread::scope`
/// per call, threads joined before returning. The coordinator now runs
/// on the persistent [`crate::exec::ExecutorPool`] instead; this
/// function remains as the reference implementation the executor bench
/// (`benches/executor.rs`, `BENCH_executor.json`) measures the pool
/// against, and as the proof that results are a pure function of the
/// jobs (both paths must agree bit-for-bit).
pub fn train_parallel(
    backend: &dyn Backend,
    jobs: &[Option<TrainRequest<'_>>],
) -> Result<Vec<Option<TrainResult>>> {
    let workers = if backend.parallel_train() {
        default_workers()
    } else {
        1
    };
    train_parallel_with(backend, jobs, workers)
}

/// [`train_parallel`] with an explicit worker count (`1` reproduces the
/// serial seed path; the benches compare the two). Jobs are chunked
/// contiguously so the work split is deterministic; if several jobs
/// fail, the lowest-indexed error wins.
///
/// `workers == 1` runs inline on the caller's thread — no spawn — so
/// backends with per-thread state (the PJRT backend caches its engine
/// and compiled executables in thread-local storage) keep their caches
/// warm across rounds instead of recompiling on every fresh scope
/// thread.
pub fn train_parallel_with(
    backend: &dyn Backend,
    jobs: &[Option<TrainRequest<'_>>],
    workers: usize,
) -> Result<Vec<Option<TrainResult>>> {
    let n = jobs.len();
    if n == 0 {
        return Ok(Vec::new());
    }
    let workers = workers.clamp(1, n);
    if workers == 1 {
        let mut out = Vec::with_capacity(n);
        for job in jobs {
            out.push(match job {
                Some(req) => Some(backend.train_round(req).map(|(result, _wall)| result)?),
                None => None,
            });
        }
        return Ok(out);
    }
    let chunk = n.div_ceil(workers);
    let mut slots: Vec<Option<Result<TrainResult>>> = Vec::new();
    slots.resize_with(n, || None);
    std::thread::scope(|scope| {
        for (job_chunk, slot_chunk) in jobs.chunks(chunk).zip(slots.chunks_mut(chunk)) {
            scope.spawn(move || {
                for (job, slot) in job_chunk.iter().zip(slot_chunk.iter_mut()) {
                    if let Some(req) = job {
                        *slot = Some(backend.train_round(req).map(|(result, _wall)| result));
                    }
                }
            });
        }
    });
    let mut out = Vec::with_capacity(n);
    for slot in slots {
        match slot {
            Some(Ok(result)) => out.push(Some(result)),
            Some(Err(e)) => return Err(e),
            None => out.push(None),
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthDataset;
    use crate::runtime::NativeBackend;

    fn ev(at_s: f64, seq: usize, outcome: Outcome) -> CompletionEvent {
        CompletionEvent {
            at_s,
            seq,
            client: seq,
            outcome,
        }
    }

    #[test]
    fn event_queue_pops_in_arrival_order() {
        let mut q = EventQueue::new();
        q.push(ev(30.0, 0, Outcome::Late));
        q.push(ev(10.0, 1, Outcome::OnTime));
        q.push(ev(20.0, 2, Outcome::OnTime));
        let order: Vec<usize> = std::iter::from_fn(|| q.pop()).map(|e| e.seq).collect();
        assert_eq!(order, vec![1, 2, 0]);
    }

    #[test]
    fn event_queue_ties_break_on_issue_order() {
        let mut q = EventQueue::new();
        q.push(ev(5.0, 2, Outcome::Crash));
        q.push(ev(5.0, 0, Outcome::Crash));
        q.push(ev(5.0, 1, Outcome::Crash));
        let order: Vec<usize> = std::iter::from_fn(|| q.pop()).map(|e| e.seq).collect();
        assert_eq!(order, vec![0, 1, 2]);
    }

    #[test]
    fn event_queue_interleaved_ties_stay_in_issue_order() {
        // The pinned tie-break contract under adversarial conditions:
        // several distinct timestamps, each with several simultaneous
        // events, pushed in scrambled order and interleaved with pops
        // (continuous mode pushes replacements while draining). Every
        // timestamp group must come out in ascending issue order.
        let mut q = EventQueue::new();
        for &(at, seq) in &[
            (20.0, 7),
            (10.0, 4),
            (20.0, 3),
            (10.0, 0),
            (20.0, 5),
            (10.0, 2),
        ] {
            q.push(ev(at, seq, Outcome::OnTime));
        }
        // drain the t=10 group...
        assert_eq!(q.pop().unwrap().seq, 0);
        assert_eq!(q.pop().unwrap().seq, 2);
        // ...push more simultaneous events mid-drain, as the continuous
        // driver does when a completion triggers replacement dispatch
        q.push(ev(10.0, 6, Outcome::OnTime));
        q.push(ev(20.0, 1, Outcome::OnTime));
        assert_eq!(q.pop().unwrap().seq, 4);
        assert_eq!(q.pop().unwrap().seq, 6);
        let tail: Vec<usize> = std::iter::from_fn(|| q.pop()).map(|e| e.seq).collect();
        assert_eq!(tail, vec![1, 3, 5, 7], "t=20 group out of issue order");
        // -0.0 and +0.0 are one timestamp under total_cmp? No: total_cmp
        // orders -0.0 < +0.0, so they are distinct instants — pin that
        // too, since finished_at arithmetic can produce signed zeros.
        q.push(ev(0.0, 9, Outcome::OnTime));
        q.push(ev(-0.0, 8, Outcome::OnTime));
        assert_eq!(q.pop().unwrap().seq, 8);
        assert_eq!(q.pop().unwrap().seq, 9);
    }

    #[test]
    fn in_flight_tracks_and_expires() {
        let mut f = InFlight::new();
        f.track(3, 100.0);
        f.track(7, 50.0);
        assert!(f.is_busy(3) && f.is_busy(7));
        f.expire(50.0); // boundary: an invocation finishing exactly now is done
        assert!(f.is_busy(3) && !f.is_busy(7));
        let (invoke, skipped) = split_in_flight(&[1, 3, 5], &f);
        assert_eq!(invoke, vec![1, 5]);
        assert_eq!(skipped, vec![3]);
    }

    fn stale(client: ClientId, produced_round: u32, arrived_at_s: f64) -> StaleUpdate {
        StaleUpdate {
            client,
            produced_round,
            arrived_at_s,
            training_time_s: 1.0,
            params: vec![0.0],
            cardinality: 1,
            loss: 0.0,
        }
    }

    #[test]
    fn cap_stale_keeps_newest_and_returns_overflow() {
        // 2 fresh + k_max 4 leaves two stale slots: the round-5 updates
        // win over the round-4 one; within round 5 the earlier arrival
        // wins. The round-4 update is overflow, not garbage — it goes
        // back to the staleness buffer.
        let drained = vec![stale(0, 4, 10.0), stale(1, 5, 30.0), stale(2, 5, 20.0)];
        let (kept, overflow) = cap_stale(2, drained, 4);
        assert_eq!(
            kept.iter().map(|u| u.client).collect::<Vec<_>>(),
            vec![2, 1]
        );
        assert_eq!(
            overflow.iter().map(|u| u.client).collect::<Vec<_>>(),
            vec![0]
        );
        // a full fresh set leaves no stale slots at all
        let (kept, overflow) = cap_stale(4, vec![stale(0, 5, 1.0)], 4);
        assert!(kept.is_empty());
        assert_eq!(overflow.len(), 1);
        // and more fresh than k_max must not underflow
        let (kept, overflow) = cap_stale(9, vec![stale(0, 5, 1.0)], 4);
        assert!(kept.is_empty());
        assert_eq!(overflow.len(), 1);
    }

    #[test]
    fn median_averages_even_length_sets() {
        assert_eq!(median_sorted(&[3.0]), 3.0);
        assert_eq!(median_sorted(&[1.0, 3.0]), 2.0); // not the upper middle
        assert_eq!(median_sorted(&[1.0, 2.0, 9.0]), 2.0);
        assert_eq!(median_sorted(&[1.0, 2.0, 4.0, 9.0]), 3.0);
    }

    #[test]
    fn train_parallel_matches_serial_and_skips_none_jobs() {
        let rt = NativeBackend::for_dataset("mnist").unwrap();
        let mf = rt.manifest().clone();
        let data = SynthDataset::from_manifest(&mf, 4, 11, Default::default()).unwrap();
        let shards: Vec<_> = (0..4).map(|c| data.client_data(c)).collect();
        let p0 = rt.init_params().unwrap();
        let zeros = vec![0f32; p0.len()];
        let jobs: Vec<Option<TrainRequest>> = shards
            .iter()
            .enumerate()
            .map(|(i, shard)| {
                if i == 2 {
                    return None; // doomed invocation: no compute
                }
                Some(TrainRequest {
                    params: &p0,
                    m: &zeros,
                    v: &zeros,
                    t: 0.0,
                    x: &shard.x,
                    y: &shard.y,
                    seed: i as i32,
                    num_steps: mf.steps_per_round as i32,
                    global: None,
                })
            })
            .collect();
        let serial = train_parallel_with(&rt, &jobs, 1).unwrap();
        let parallel = train_parallel_with(&rt, &jobs, 4).unwrap();
        assert_eq!(serial.len(), 4);
        assert!(serial[2].is_none() && parallel[2].is_none());
        for (s, p) in serial.iter().zip(&parallel) {
            match (s, p) {
                (Some(a), Some(b)) => {
                    assert_eq!(a.params, b.params);
                    assert_eq!(a.loss, b.loss);
                }
                (None, None) => {}
                _ => panic!("serial/parallel slot mismatch"),
            }
        }
    }
}
