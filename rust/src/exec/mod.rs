//! The persistent executor plane: a long-lived, channel-based training
//! worker pool replacing the per-round scoped spawn/join fan-out.
//!
//! Motivation (ROADMAP open item #1): `sched::train_parallel` pays a
//! full thread spawn/join cycle every round, and — more importantly —
//! couples *compute* lifetime to *round* lifetime, which makes a
//! rounds-free (continuous) training mode impossible. The pool here
//! decouples them:
//!
//! * a **fixed worker fleet** is spawned once per experiment (sized by
//!   [`pool_workers`]: CLI/config override, else
//!   [`sched::default_workers`](crate::sched::default_workers), else 1
//!   for backends that opt out of fan-out via
//!   [`Backend::parallel_train`](crate::runtime::Backend::parallel_train));
//! * jobs are **work-stealing dispatched**: all workers pull from one
//!   shared `Mutex<mpsc::Receiver<TrainJob>>`, so a slow job never
//!   blocks the queue behind a fixed pre-partition;
//! * completions stream back over a second mpsc channel **in
//!   completion order**, tagged with the job id, so the coordinator can
//!   fold them as they land (continuous mode) or re-slot them
//!   positionally (round mode);
//! * each worker runs [`Backend::init_worker`] once before accepting
//!   jobs — the hook that lets the PJRT backend warm its thread-local
//!   compiled engines exactly once per worker thread, while the
//!   `Sync`-shared `NativeBackend` keeps a no-op;
//! * a worker **panic mid-`train_round` is caught** and surfaced as a
//!   per-job error (never a hang), and [`ExecutorPool::shutdown`]
//!   drains/abandons queued jobs and joins every worker.
//!
//! Determinism: the pool moves *where* training computes, never *what*
//! is computed — `train_round` is a pure function of its request, and
//! round mode re-slots results by job id — so round-mode outputs are
//! byte-identical to the scoped-thread path for every worker count.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::{Scope, ScopedJoinHandle};

use crate::data::ClientData;
use crate::params::{ErrorFeedback, ParamBlock, ShardLayout};
use crate::runtime::{Backend, TrainRequest, TrainResult};
use crate::Result;

/// Client-side wire policy for one job: quantize the trained delta
/// (int8 symmetric per shard of `layout`, optionally top-k sparse)
/// with the client's carried error-feedback residual. The worker plays
/// the serverless client here — it encodes, then *reconstructs* its
/// parameters as `departed global + dequantized delta`, so the server
/// fold path downstream sees exactly what crossed the simulated wire.
#[derive(Clone)]
pub struct WireSpec {
    pub layout: ShardLayout,
    /// Top-k sparse fraction per shard; `None` sends dense int8.
    pub topk: Option<f64>,
    /// Error-feedback residual carried from this client's previous
    /// invocation (all-zero on its first; the coordinator's client DB
    /// plane stores it between invocations — serverless clients are
    /// stateless).
    pub residual: Vec<f32>,
}

/// What the wire policy produced for one completion: the accounted
/// upload bytes and the residual to carry to the client's next
/// invocation.
pub struct WireMeta {
    pub bytes_up: usize,
    pub residual: Vec<f32>,
}

/// One completed training job: the training result (with `params`
/// already reconstructed from the quantized wire when a [`WireSpec`]
/// was attached) plus the wire metadata.
pub struct TrainOutput {
    pub train: TrainResult,
    /// `None` when the job had no wire policy (raw f32 upload).
    pub wire: Option<WireMeta>,
}

/// One unit of training work: everything `train_round` needs, owned (or
/// refcounted), so the job can cross a channel into any worker thread.
#[derive(Clone)]
pub struct TrainJob {
    /// Caller-chosen completion tag. Round mode overwrites it with the
    /// positional slot index (see [`ExecutorPool::run_batch`]);
    /// continuous mode uses the invocation sequence number.
    pub id: usize,
    /// Global snapshot the client trains from (refcount bump, no copy).
    pub params: ParamBlock,
    /// The client's local shard (shared with the coordinator's cache).
    pub shard: Arc<ClientData>,
    pub seed: i32,
    pub num_steps: i32,
    /// FedProx: anchor the proximal term to `params` (same snapshot the
    /// client departs from — refcount-only, no extra param-plane bytes).
    pub prox: bool,
    /// Quantize the upload (`None` ships raw f32, the default).
    pub wire: Option<WireSpec>,
}

/// One completion, tagged with the job id it answers.
pub struct TrainDone {
    pub id: usize,
    /// `Err` carries a rendered message (worker panics included) rather
    /// than `anyhow::Error` so it stays `Send` across the channel
    /// unconditionally.
    pub result: std::result::Result<TrainOutput, String>,
}

/// The persistent training worker pool. Lives inside a
/// `std::thread::scope` so workers may borrow the backend; construct
/// with [`ExecutorPool::new`], retire with [`ExecutorPool::shutdown`].
pub struct ExecutorPool<'scope> {
    job_tx: Option<mpsc::Sender<TrainJob>>,
    done_rx: mpsc::Receiver<TrainDone>,
    handles: Vec<ScopedJoinHandle<'scope, ()>>,
    abandon: Arc<AtomicBool>,
    workers: usize,
}

/// Render a caught panic payload for the per-job error message.
fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

impl<'scope> ExecutorPool<'scope> {
    /// Spawn the worker fleet inside `scope`. Workers immediately run
    /// [`Backend::init_worker`] (an init failure is reported lazily, as
    /// the error result of every job that worker pulls) and then block
    /// on the shared job queue.
    pub fn new<'env: 'scope>(
        scope: &'scope Scope<'scope, 'env>,
        backend: &'env dyn Backend,
        workers: usize,
    ) -> ExecutorPool<'scope> {
        let workers = workers.max(1);
        let (job_tx, job_rx) = mpsc::channel::<TrainJob>();
        let (done_tx, done_rx) = mpsc::channel::<TrainDone>();
        let job_rx = Arc::new(Mutex::new(job_rx));
        let abandon = Arc::new(AtomicBool::new(false));
        let mut handles = Vec::with_capacity(workers);
        for _ in 0..workers {
            let job_rx = Arc::clone(&job_rx);
            let done_tx = done_tx.clone();
            let abandon = Arc::clone(&abandon);
            handles.push(scope.spawn(move || {
                worker_loop(backend, &job_rx, &done_tx, &abandon)
            }));
        }
        drop(done_tx);
        ExecutorPool {
            job_tx: Some(job_tx),
            done_rx,
            handles,
            abandon,
            workers,
        }
    }

    /// Number of worker threads in the fleet.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Enqueue one job; some worker will pull it and eventually answer
    /// with a [`TrainDone`] carrying `job.id`.
    pub fn submit(&self, job: TrainJob) -> Result<()> {
        let tx = self
            .job_tx
            .as_ref()
            .expect("submit after shutdown");
        tx.send(job)
            .map_err(|_| anyhow::anyhow!("executor workers exited unexpectedly"))
    }

    /// Block for the next completion, in completion order.
    pub fn next_done(&self) -> Result<TrainDone> {
        self.done_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("executor workers exited unexpectedly"))
    }

    /// Round-mode batch: run every `Some` job and return results in the
    /// same slots (`None` jobs — crashed invocations — stay `None`).
    /// Job ids are overwritten with the slot index, so `run_batch` must
    /// not be interleaved with manual [`submit`](Self::submit) /
    /// [`next_done`](Self::next_done) traffic. On failure the
    /// lowest-slot error wins (matching the scoped-thread path's
    /// lowest-index contract).
    pub fn run_batch(&self, jobs: Vec<Option<TrainJob>>) -> Result<Vec<Option<TrainOutput>>> {
        let mut slots: Vec<Option<TrainOutput>> = Vec::new();
        slots.resize_with(jobs.len(), || None);
        let mut expected = 0usize;
        for (i, job) in jobs.into_iter().enumerate() {
            if let Some(mut job) = job {
                job.id = i;
                self.submit(job)?;
                expected += 1;
            }
        }
        let mut first_err: Option<(usize, String)> = None;
        for _ in 0..expected {
            let done = self.next_done()?;
            match done.result {
                Ok(r) => slots[done.id] = Some(r),
                Err(e) => {
                    if first_err.as_ref().map_or(true, |(i, _)| done.id < *i) {
                        first_err = Some((done.id, e));
                    }
                }
            }
        }
        if let Some((i, e)) = first_err {
            anyhow::bail!("train job {i}: {e}");
        }
        Ok(slots)
    }

    /// Graceful shutdown: abandon still-queued jobs (workers ack them
    /// with an error instead of training), close the queue, join every
    /// worker. Errs if any worker thread itself died (which the
    /// catch_unwind in the worker loop should make impossible).
    pub fn shutdown(mut self) -> Result<()> {
        self.abandon.store(true, Ordering::SeqCst);
        drop(self.job_tx.take()); // closes the queue; workers drain out
        let mut panicked = 0usize;
        for h in self.handles.drain(..) {
            if h.join().is_err() {
                panicked += 1;
            }
        }
        anyhow::ensure!(
            panicked == 0,
            "{panicked} executor worker thread(s) panicked"
        );
        Ok(())
    }
}

/// Per-worker wire scratch: the delta and dequantized-delta buffers
/// [`encode_wire`] fills on every quantized job, hoisted into worker
/// state so the persistent pool stops re-allocating them per job
/// (buffers are fully overwritten before each use, so reuse across
/// jobs never changes a result).
#[derive(Default)]
struct WireArena {
    delta: Vec<f32>,
    dq: Vec<f32>,
}

/// One worker: init the backend's thread-local state, then pull jobs
/// until the queue closes. Panics inside `train_round` are caught and
/// reported as that job's error; the worker itself survives.
fn worker_loop(
    backend: &dyn Backend,
    job_rx: &Mutex<mpsc::Receiver<TrainJob>>,
    done_tx: &mpsc::Sender<TrainDone>,
    abandon: &AtomicBool,
) {
    let init_err = backend
        .init_worker()
        .err()
        .map(|e| format!("worker init failed: {e:#}"));
    // Workers own their (all-zero) optimizer-state scratch: clients are
    // stateless between rounds, per the paper's serverless model.
    let zeros = vec![0f32; backend.manifest().param_count];
    let mut wire_arena = WireArena::default();
    loop {
        // lock scoped to the recv: release before training so other
        // workers can steal the next job mid-compute
        let mut job = {
            let rx = match job_rx.lock() {
                Ok(rx) => rx,
                Err(_) => return, // a sibling panicked holding the lock
            };
            match rx.recv() {
                Ok(job) => job,
                Err(_) => return, // queue closed: clean exit
            }
        };
        let result = if abandon.load(Ordering::SeqCst) {
            Err("executor pool shut down before the job ran".to_string())
        } else if let Some(e) = &init_err {
            Err(e.clone())
        } else {
            let trained = {
                let req = TrainRequest {
                    params: job.params.as_slice(),
                    m: &zeros,
                    v: &zeros,
                    t: 0.0,
                    x: &job.shard.x,
                    y: &job.shard.y,
                    seed: job.seed,
                    num_steps: job.num_steps,
                    global: if job.prox { Some(&job.params[..]) } else { None },
                };
                match catch_unwind(AssertUnwindSafe(|| backend.train_round(&req))) {
                    Ok(Ok((r, _wall))) => Ok(r),
                    Ok(Err(e)) => Err(format!("{e:#}")),
                    Err(payload) => Err(format!(
                        "worker panicked mid-train_round: {}",
                        panic_message(payload)
                    )),
                }
            };
            trained.map(|mut r| {
                let wire = job.wire.take().map(|spec| {
                    encode_wire(&mut r.params, &job.params, spec, &mut wire_arena)
                });
                TrainOutput { train: r, wire }
            })
        };
        // send failure just means the coordinator stopped listening
        // (shutdown with unread completions) — never panic the worker
        let _ = done_tx.send(TrainDone { id: job.id, result });
    }
}

/// Apply one job's wire policy on the worker (client) side: quantize
/// `trained − departed global` through the client's error-feedback
/// residual, then overwrite `trained` with `global + dequantized delta`
/// — the value the server actually receives over the simulated wire.
/// Deterministic per client regardless of worker scheduling: the
/// residual rides the job and the encoded result depends only on it and
/// the training output.
fn encode_wire(
    trained: &mut [f32],
    departed: &ParamBlock,
    spec: WireSpec,
    arena: &mut WireArena,
) -> WireMeta {
    let kr = crate::runtime::kernel::active();
    arena.delta.resize(trained.len(), 0.0);
    kr.sub(&mut arena.delta, trained, departed.as_slice());
    let mut ef = ErrorFeedback::from_residual(spec.residual);
    let q = ef.encode(&arena.delta, &spec.layout, spec.topk);
    let bytes_up = q.wire_bytes();
    crate::params::dequantize_into(&q, &spec.layout, &mut arena.dq);
    kr.add(trained, departed.as_slice(), &arena.dq);
    WireMeta {
        bytes_up,
        residual: ef.into_residual(),
    }
}

/// Pool sizing: explicit override (CLI `--workers` / config, clamped
/// ≥ 1) wins; otherwise one worker per core for backends that fan out
/// ([`Backend::parallel_train`]), or a single persistent worker for
/// backends with thread-local engine state (PJRT compiles once in that
/// worker via [`Backend::init_worker`] and stays warm).
pub fn pool_workers(backend: &dyn Backend, override_workers: Option<usize>) -> usize {
    match override_workers {
        Some(w) => w.max(1),
        None => {
            if backend.parallel_train() {
                crate::sched::default_workers()
            } else {
                1
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Features;
    use crate::runtime::manifest::{Entrypoint, Manifest};
    use crate::runtime::{AggregateFold, BufferedFold, EvalResult};
    use std::time::Duration;

    /// Tiny in-module backend with failure-injection knobs.
    struct TestBackend {
        mf: Manifest,
        panic_on_seed: Option<i32>,
        fail_init_worker: bool,
    }

    impl TestBackend {
        fn new() -> Self {
            let ep = |f: &str| Entrypoint {
                file: f.into(),
                inputs: vec![],
                outputs: vec![],
            };
            let mf = Manifest {
                name: "mnist".into(),
                scale: "mock".into(),
                param_count: 4,
                num_classes: 2,
                input_shape: vec![2],
                input_dtype: "f32".into(),
                shard_size: 2,
                batch_size: 2,
                local_epochs: 1,
                steps_per_round: 2,
                optimizer: "sgd".into(),
                lr: 0.1,
                prox_mu: 0.0,
                eval_size: 2,
                eval_batch: 2,
                k_max: 64,
                seq_len: None,
                flops_per_round: 1,
                entrypoints: ["train", "train_prox", "eval", "aggregate"]
                    .iter()
                    .map(|n| (n.to_string(), ep(n)))
                    .collect(),
                init_file: "unused".into(),
                init_sha256: "unused".into(),
                init_seed: 0,
            };
            Self {
                mf,
                panic_on_seed: None,
                fail_init_worker: false,
            }
        }
    }

    impl Backend for TestBackend {
        fn backend_name(&self) -> &'static str {
            "exec-test"
        }

        fn manifest(&self) -> &Manifest {
            &self.mf
        }

        fn init_params(&self) -> Result<Vec<f32>> {
            Ok(vec![0.0; self.mf.param_count])
        }

        fn init_worker(&self) -> Result<()> {
            anyhow::ensure!(!self.fail_init_worker, "injected init failure");
            Ok(())
        }

        fn train_round(&self, req: &TrainRequest) -> Result<(TrainResult, Duration)> {
            if self.panic_on_seed == Some(req.seed) {
                panic!("injected panic for seed {}", req.seed);
            }
            let params: Vec<f32> =
                req.params.iter().map(|p| p + req.seed as f32).collect();
            let n = params.len();
            Ok((
                TrainResult {
                    params,
                    m: vec![0.0; n],
                    v: vec![0.0; n],
                    t: req.num_steps as f32,
                    loss: 0.5,
                },
                Duration::from_millis(1),
            ))
        }

        fn evaluate(&self, _p: &[f32], _x: &Features, _y: &[i32]) -> Result<EvalResult> {
            Ok(EvalResult {
                loss: 1.0,
                accuracy: 0.5,
            })
        }

        fn aggregate(
            &self,
            updates: &[&[f32]],
            weights: &[f32],
        ) -> Result<(Vec<f32>, Duration)> {
            let mut out = vec![0.0f32; updates[0].len()];
            for (u, &w) in updates.iter().zip(weights) {
                for (o, &x) in out.iter_mut().zip(u.iter()) {
                    *o += w * x;
                }
            }
            Ok((out, Duration::from_millis(1)))
        }

        fn begin_fold(&self, expected_k: usize) -> Result<Box<dyn AggregateFold + '_>> {
            Ok(Box::new(BufferedFold::new(self, expected_k)))
        }
    }

    fn shard() -> Arc<ClientData> {
        Arc::new(ClientData {
            x: Features::F32(vec![0.0; 4]),
            y: vec![0, 1],
        })
    }

    fn job(id: usize, seed: i32) -> TrainJob {
        TrainJob {
            id,
            params: ParamBlock::from(vec![1.0f32; 4]),
            shard: shard(),
            seed,
            num_steps: 2,
            prox: false,
            wire: None,
        }
    }

    #[test]
    fn pool_matches_inline_train_round() {
        let be = TestBackend::new();
        let jobs: Vec<Option<TrainJob>> =
            (0..8).map(|i| Some(job(0, i as i32 + 1))).collect();
        let inline: Vec<Vec<f32>> = (0..8)
            .map(|i| {
                let j = job(0, i as i32 + 1);
                let req = TrainRequest {
                    params: j.params.as_slice(),
                    m: &[0.0; 4],
                    v: &[0.0; 4],
                    t: 0.0,
                    x: &j.shard.x,
                    y: &j.shard.y,
                    seed: j.seed,
                    num_steps: j.num_steps,
                    global: None,
                };
                be.train_round(&req).unwrap().0.params
            })
            .collect();
        std::thread::scope(|scope| {
            let pool = ExecutorPool::new(scope, &be, 3);
            let results = pool.run_batch(jobs).unwrap();
            for (i, r) in results.iter().enumerate() {
                let out = r.as_ref().unwrap();
                assert_eq!(out.train.params, inline[i], "slot {i}");
                assert!(out.wire.is_none(), "no wire policy attached");
            }
            pool.shutdown().unwrap();
        });
    }

    #[test]
    fn quantized_wire_reconstructs_params_and_accounts_bytes() {
        // TestBackend trains params = departed + seed, so the delta is
        // the constant `seed` — exactly representable (scale = seed/127
        // times code 127): the reconstruction matches the raw result
        // bit-for-bit and the residual stays zero.
        let be = TestBackend::new();
        let layout = ShardLayout::new(4, 2);
        std::thread::scope(|scope| {
            let pool = ExecutorPool::new(scope, &be, 2);
            let mut j = job(0, 3);
            j.wire = Some(WireSpec {
                layout,
                topk: None,
                residual: vec![0.0; 4],
            });
            let out = pool.run_batch(vec![Some(j)]).unwrap();
            let out = out[0].as_ref().unwrap();
            assert_eq!(out.train.params, vec![4.0f32; 4], "1.0 + seed 3");
            let wire = out.wire.as_ref().unwrap();
            assert_eq!(
                wire.bytes_up,
                crate::params::wire_bytes_estimate(4, 2, None),
                "actual wire == deterministic estimate"
            );
            assert!(wire.bytes_up < 4 * std::mem::size_of::<f32>());
            assert!(wire.residual.iter().all(|&e| e == 0.0));
            pool.shutdown().unwrap();
        });
    }

    #[test]
    fn none_jobs_keep_their_slots() {
        let be = TestBackend::new();
        std::thread::scope(|scope| {
            let pool = ExecutorPool::new(scope, &be, 2);
            let jobs = vec![Some(job(0, 1)), None, Some(job(0, 3)), None];
            let results = pool.run_batch(jobs).unwrap();
            assert!(results[0].is_some());
            assert!(results[1].is_none());
            assert!(results[2].is_some());
            assert!(results[3].is_none());
            pool.shutdown().unwrap();
        });
    }

    #[test]
    fn worker_panic_surfaces_error_not_hang() {
        let mut be = TestBackend::new();
        be.panic_on_seed = Some(2);
        std::thread::scope(|scope| {
            let pool = ExecutorPool::new(scope, &be, 2);
            let jobs: Vec<Option<TrainJob>> =
                (0..4).map(|i| Some(job(0, i as i32 + 1))).collect();
            let err = pool.run_batch(jobs).unwrap_err().to_string();
            assert!(err.contains("panicked"), "unexpected error: {err}");
            // the worker caught the panic and stays serviceable
            let ok = pool.run_batch(vec![Some(job(0, 5))]).unwrap();
            assert!(ok[0].is_some());
            pool.shutdown().unwrap();
        });
    }

    #[test]
    fn shutdown_drains_with_jobs_still_queued() {
        let be = TestBackend::new();
        std::thread::scope(|scope| {
            let pool = ExecutorPool::new(scope, &be, 1);
            // flood the single worker, then shut down without reading
            // any completion: abandoned jobs are acked (not trained),
            // the queue closes, and the join must not hang
            for i in 0..64 {
                pool.submit(job(i, i as i32 + 1)).unwrap();
            }
            pool.shutdown().unwrap();
        });
    }

    #[test]
    fn init_worker_failure_fails_jobs() {
        let mut be = TestBackend::new();
        be.fail_init_worker = true;
        std::thread::scope(|scope| {
            let pool = ExecutorPool::new(scope, &be, 2);
            let err = pool
                .run_batch(vec![Some(job(0, 1))])
                .unwrap_err()
                .to_string();
            assert!(err.contains("init"), "unexpected error: {err}");
            pool.shutdown().unwrap();
        });
    }

    #[test]
    fn pool_workers_sizing() {
        let be = TestBackend::new();
        assert_eq!(pool_workers(&be, Some(3)), 3);
        assert_eq!(pool_workers(&be, Some(0)), 1);
        // TestBackend keeps the default parallel_train() == true
        assert!(pool_workers(&be, None) >= 1);
    }
}
