//! Parameter server: the global model blob plus the staleness buffer for
//! late client updates (§V-D).
//!
//! Late ("slow") updates land here tagged with the round they were
//! *produced for* (t_k) and their arrival time; the FedLesScan aggregator
//! drains the buffer at the next aggregation, dampens each update by
//! t_k / t (Eq. 3) and discards anything older than τ. Updates the
//! `k_max` cap truncates out of a round re-enter the buffer (via
//! [`ParameterServer::push_stale`]) so still-τ-valid work lands in a
//! later round instead of being dropped.
//!
//! The global model itself is a zero-copy [`ParamBlock`] snapshot:
//! handing it to the FedProx anchor or to concurrent train requests is
//! an `Arc` refcount bump, not a buffer copy.

use crate::params::{resolve_shards, ParamBlock, ShardLayout};
use crate::ClientId;

/// A late client update waiting in the staleness buffer.
#[derive(Debug, Clone)]
pub struct StaleUpdate {
    pub client: ClientId,
    /// Round the update was trained for (t_k in Eq. 3).
    pub produced_round: u32,
    /// Virtual time at which it reached the parameter server.
    pub arrived_at_s: f64,
    /// Client training time, for the client's own history correction.
    pub training_time_s: f64,
    pub params: Vec<f32>,
    /// Local dataset cardinality n_k.
    pub cardinality: usize,
    /// Mean local training loss (metrics only).
    pub loss: f32,
}

/// Eq. 3 weight components for one update (pre-normalization):
/// `(t_k / t) * (n_k / n)` with the τ cutoff. `n` is the cardinality sum
/// over the *included* updates, computed by [`staleness_weights`].
#[derive(Debug, Clone, Copy)]
pub struct WeightedUpdate {
    pub produced_round: u32,
    pub cardinality: usize,
}

/// Compute the Eq. 3 aggregation weights for a batch of updates at
/// aggregation round `t`. Updates with `t - t_k >= tau` get weight 0
/// (discarded). When `normalize` is set the weights are rescaled to sum
/// to 1 (see DESIGN.md: verbatim Eq. 3 shrinks the global model whenever
/// any update is stale; the normalized variant is the default and the
/// difference is an ablation).
pub fn staleness_weights(
    updates: &[WeightedUpdate],
    t: u32,
    tau: u32,
    normalize: bool,
) -> Vec<f32> {
    let t_f = t.max(1) as f64;
    let included: Vec<bool> = updates
        .iter()
        .map(|u| t.saturating_sub(u.produced_round) < tau)
        .collect();
    let n: f64 = updates
        .iter()
        .zip(&included)
        .filter(|(_, &inc)| inc)
        .map(|(u, _)| u.cardinality as f64)
        .sum();
    if n == 0.0 {
        return vec![0.0; updates.len()];
    }
    let mut w: Vec<f64> = updates
        .iter()
        .zip(&included)
        .map(|(u, &inc)| {
            if !inc {
                return 0.0;
            }
            let damp = (u.produced_round as f64 / t_f).min(1.0);
            damp * u.cardinality as f64 / n
        })
        .collect();
    if normalize {
        let s: f64 = w.iter().sum();
        if s > 0.0 {
            w.iter_mut().for_each(|v| *v /= s);
        }
    }
    w.into_iter().map(|v| v as f32).collect()
}

/// Streaming factorization of the Eq. 3 weights: for any update batch,
/// [`staleness_weights`] yields `w_k = c_k / Z`, where
/// `c_k = (t_k / t) · n_k` is the per-update **weight component**
/// (`None` once τ-expired) and `Z` is one global normalizer — the
/// included-cardinality sum `n` for verbatim Eq. 3, or `Σ c_k` when
/// normalizing. The coordinator folds `Σ c_k · u_k` into a single O(P)
/// accumulator as updates arrive and divides by `Z` once at the end,
/// which is what lets aggregation stream instead of materializing the
/// whole batch. Equivalence with [`staleness_weights`] is pinned by the
/// tests below and in `tests/proptests.rs`.
pub fn weight_component(produced_round: u32, cardinality: usize, t: u32, tau: u32) -> Option<f64> {
    if t.saturating_sub(produced_round) >= tau {
        return None;
    }
    let damp = (produced_round as f64 / t.max(1) as f64).min(1.0);
    Some(damp * cardinality as f64)
}

/// The parameter server state.
///
/// The global blob is one flat [`ParamBlock`] cut by a [`ShardLayout`]
/// into independently-tracked shards: installs bump a per-shard
/// generation only for shards whose contents actually changed, so
/// shard-local readers (FedProx anchor slices, snapshot clones, fold
/// accumulators) can detect "my shard moved" without a whole-model
/// comparison. The cross-shard snapshot stays trivially consistent
/// because an install swaps the single `ParamBlock` atomically — there
/// is never a torn state where shard 0 is new and shard 1 old.
pub struct ParameterServer {
    global: ParamBlock,
    layout: ShardLayout,
    /// Completed aggregation count == current round index for Eq. 3.
    round: u32,
    /// Fold generation: bumps on **every** global install, independent
    /// of the mode-specific `round` argument. Round mode installs once
    /// per aggregated round; continuous mode installs once per folded
    /// completion — and keys its Eq. 3 staleness damping to the
    /// generation an update departed from.
    gen: u32,
    /// Per-shard install generations: `shard_gens[i]` bumps only when
    /// an install changed shard `i`'s bytes.
    shard_gens: Vec<u32>,
    stale: Vec<StaleUpdate>,
}

impl ParameterServer {
    /// Server with the default shard resolution (`FEDLESS_SHARDS` env ▸
    /// core count).
    pub fn new(init: Vec<f32>) -> Self {
        let shards = resolve_shards(None);
        Self::with_shards(init, shards)
    }

    /// Server with an explicit shard count (the coordinator threads the
    /// config's resolved count through here). Any count is
    /// arithmetic-identical; it only sets tracking/lock granularity.
    pub fn with_shards(init: Vec<f32>, shards: usize) -> Self {
        let layout = ShardLayout::new(init.len(), shards);
        let shard_gens = vec![0; layout.shards()];
        Self {
            global: init.into(),
            layout,
            round: 0,
            gen: 0,
            shard_gens,
            stale: Vec::new(),
        }
    }

    /// Borrow the current global snapshot.
    pub fn global(&self) -> &ParamBlock {
        &self.global
    }

    /// The shard layout the server tracks installs under.
    pub fn layout(&self) -> &ShardLayout {
        &self.layout
    }

    /// Zero-copy view of shard `i` of the current global.
    pub fn global_shard(&self, i: usize) -> &[f32] {
        self.global.shard(&self.layout, i)
    }

    /// Install generation of shard `i`: how many installs have changed
    /// this shard's contents since the initial model.
    pub fn shard_generation(&self, i: usize) -> u32 {
        self.shard_gens[i]
    }

    /// A shared handle to the current global snapshot: an `Arc`
    /// refcount bump, no float copied. The FedProx anchor and every
    /// concurrent `TrainRequest` read the same allocation through
    /// handles like this one.
    pub fn global_block(&self) -> ParamBlock {
        self.global.clone()
    }

    pub fn round(&self) -> u32 {
        self.round
    }

    /// Fold generation of the current global (number of installs since
    /// the initial model).
    pub fn generation(&self) -> u32 {
        self.gen
    }

    /// Install the freshly aggregated global model; bumps the fold
    /// generation, plus the per-shard generation of every shard whose
    /// contents changed (bitwise compare per shard — a fold that only
    /// moved some shards leaves the others' generations alone).
    pub fn set_global(&mut self, params: ParamBlock, round: u32) {
        assert_eq!(params.len(), self.global.len(), "param length change");
        if !params.ptr_eq(&self.global) {
            for (i, r) in self.layout.ranges().enumerate() {
                let same = self.global[r.clone()]
                    .iter()
                    .zip(&params[r])
                    .all(|(a, b)| a.to_bits() == b.to_bits());
                if !same {
                    self.shard_gens[i] = self.shard_gens[i].saturating_add(1);
                }
            }
        }
        self.global = params;
        self.round = round;
        self.gen = self.gen.saturating_add(1);
    }

    /// Buffer a late update for a future aggregation.
    pub fn push_stale(&mut self, u: StaleUpdate) {
        self.stale.push(u);
    }

    pub fn stale_len(&self) -> usize {
        self.stale.len()
    }

    /// Drain buffered updates that have *arrived* by `now_s` and are not
    /// yet older than `tau` relative to aggregation round `t`. Expired
    /// updates are dropped permanently (τ discard, §V-D); not-yet-arrived
    /// updates stay buffered.
    ///
    /// Returned updates are in **true arrival order** (earliest
    /// `arrived_at_s` first, client id as the deterministic tie-break)
    /// regardless of the order they were pushed — the server replays the
    /// semi-asynchronous timeline, not the controller's invocation order.
    pub fn drain_stale(&mut self, now_s: f64, t: u32, tau: u32) -> Vec<StaleUpdate> {
        let mut ready = Vec::new();
        let mut keep = Vec::new();
        for u in self.stale.drain(..) {
            let age = t.saturating_sub(u.produced_round);
            if age >= tau {
                continue; // expired: discard
            }
            if u.arrived_at_s <= now_s {
                ready.push(u);
            } else {
                keep.push(u);
            }
        }
        self.stale = keep;
        ready.sort_by(|a, b| {
            a.arrived_at_s
                .total_cmp(&b.arrived_at_s)
                .then_with(|| a.client.cmp(&b.client))
        });
        ready
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wu(round: u32, card: usize) -> WeightedUpdate {
        WeightedUpdate {
            produced_round: round,
            cardinality: card,
        }
    }

    #[test]
    fn same_round_weights_are_fedavg() {
        let w = staleness_weights(&[wu(5, 10), wu(5, 30)], 5, 2, false);
        assert!((w[0] - 0.25).abs() < 1e-6);
        assert!((w[1] - 0.75).abs() < 1e-6);
    }

    #[test]
    fn stale_updates_are_dampened() {
        let w = staleness_weights(&[wu(10, 100), wu(9, 100)], 10, 3, false);
        assert!(w[1] < w[0]);
        assert!((w[1] / w[0] - 0.9).abs() < 1e-5); // t_k/t = 9/10
    }

    #[test]
    fn tau_cutoff_discards() {
        let w = staleness_weights(&[wu(10, 100), wu(8, 100)], 10, 2, false);
        assert_eq!(w[1], 0.0);
        // and the cardinality sum excludes the discarded update
        assert!((w[0] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn normalized_weights_sum_to_one() {
        let w = staleness_weights(&[wu(10, 50), wu(9, 50), wu(8, 50)], 10, 5, true);
        let s: f32 = w.iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
    }

    #[test]
    fn all_expired_gives_zeros() {
        let w = staleness_weights(&[wu(1, 10)], 10, 2, true);
        assert_eq!(w, vec![0.0]);
    }

    #[test]
    fn drain_respects_arrival_and_tau() {
        let mk = |round, arrive| StaleUpdate {
            client: 0,
            produced_round: round,
            arrived_at_s: arrive,
            training_time_s: 1.0,
            params: vec![0.0],
            cardinality: 1,
            loss: 0.0,
        };
        let mut ps = ParameterServer::new(vec![0.0]);
        ps.push_stale(mk(9, 10.0)); // ready
        ps.push_stale(mk(9, 99.0)); // not yet arrived
        ps.push_stale(mk(2, 5.0)); // expired at t=10, tau=2
        let ready = ps.drain_stale(50.0, 10, 2);
        assert_eq!(ready.len(), 1);
        assert_eq!(ready[0].produced_round, 9);
        assert_eq!(ps.stale_len(), 1); // the future one stays
    }

    #[test]
    fn drain_returns_true_arrival_order() {
        let mk = |client, arrive| StaleUpdate {
            client,
            produced_round: 9,
            arrived_at_s: arrive,
            training_time_s: 1.0,
            params: vec![0.0],
            cardinality: 1,
            loss: 0.0,
        };
        let mut ps = ParameterServer::new(vec![0.0]);
        // pushed in controller/invocation order, deliberately shuffled
        // relative to the arrival timeline
        ps.push_stale(mk(3, 40.0));
        ps.push_stale(mk(1, 10.0));
        ps.push_stale(mk(2, 10.0)); // simultaneous: client id tie-break
        ps.push_stale(mk(0, 25.0));
        let ready = ps.drain_stale(100.0, 10, 2);
        let order: Vec<_> = ready.iter().map(|u| u.client).collect();
        assert_eq!(order, vec![1, 2, 0, 3]);
    }

    #[test]
    fn set_global_updates_round() {
        let mut ps = ParameterServer::new(vec![1.0, 2.0]);
        ps.set_global(vec![3.0, 4.0].into(), 7);
        assert_eq!(ps.global().as_slice(), &[3.0, 4.0]);
        assert_eq!(ps.round(), 7);
    }

    #[test]
    fn generation_counts_installs_not_rounds() {
        // The continuous-mode staleness key: one bump per install,
        // regardless of the round argument (which round mode reuses and
        // continuous mode sets to the generation itself).
        let mut ps = ParameterServer::new(vec![0.0]);
        assert_eq!(ps.generation(), 0);
        ps.set_global(vec![1.0].into(), 7);
        assert_eq!(ps.generation(), 1);
        ps.set_global(vec![2.0].into(), 7); // same round, new install
        assert_eq!(ps.generation(), 2);
    }

    #[test]
    fn shard_generations_bump_only_for_changed_shards() {
        // 8 params in 4 shards of 2. An install that only moves the
        // second shard bumps that shard's generation alone, while the
        // whole-model generation (the continuous staleness key) bumps
        // on every install.
        let mut ps = ParameterServer::with_shards(vec![0.0; 8], 4);
        assert_eq!(ps.layout().shards(), 4);
        assert_eq!(ps.global_shard(1), &[0.0, 0.0]);
        let mut next = vec![0.0f32; 8];
        next[2] = 1.0; // shard 1 only
        ps.set_global(next.into(), 1);
        assert_eq!(
            (0..4).map(|i| ps.shard_generation(i)).collect::<Vec<_>>(),
            vec![0, 1, 0, 0]
        );
        assert_eq!(ps.generation(), 1);
        assert_eq!(ps.global_shard(1), &[1.0, 0.0]);
        // re-installing the identical snapshot handle bumps no shard
        let same = ps.global_block();
        ps.set_global(same, 1);
        assert_eq!(ps.shard_generation(1), 1);
        assert_eq!(ps.generation(), 2, "whole-model gen still bumps");
        // a full-model change bumps every shard
        ps.set_global(vec![2.0; 8].into(), 2);
        assert!((0..4).all(|i| ps.shard_generation(i) >= 1));
    }

    #[test]
    fn global_block_shares_storage_with_the_server() {
        // The zero-copy contract behind the FedProx anchor: every handle
        // to the global model is the same allocation, so a prox round
        // never materializes a second full parameter buffer.
        let ps = ParameterServer::new(vec![0.5; 64]);
        let anchor = ps.global_block();
        let request_view = ps.global_block();
        assert!(anchor.ptr_eq(ps.global()));
        assert!(anchor.ptr_eq(&request_view));
        assert_eq!(anchor.bytes(), 64 * std::mem::size_of::<f32>());
    }

    #[test]
    fn weight_component_factorizes_batch_weights() {
        // Streaming contract: staleness_weights == component / Z for
        // both the verbatim-Eq. 3 and normalized variants.
        let ups = [wu(10, 20), wu(9, 35), wu(7, 50), wu(10, 5)];
        let (t, tau) = (10u32, 3u32);
        for normalize in [false, true] {
            let batch = staleness_weights(&ups, t, tau, normalize);
            let comps: Vec<f64> = ups
                .iter()
                .map(|u| weight_component(u.produced_round, u.cardinality, t, tau).unwrap_or(0.0))
                .collect();
            let n: f64 = ups
                .iter()
                .zip(&comps)
                .filter(|(_, &c)| c > 0.0)
                .map(|(u, _)| u.cardinality as f64)
                .sum();
            let z = if normalize { comps.iter().sum::<f64>() } else { n };
            assert_eq!(comps[2], 0.0, "age 3 >= tau must have no component");
            for (b, c) in batch.iter().zip(&comps) {
                assert!(
                    (f64::from(*b) - c / z).abs() < 1e-6,
                    "normalize={normalize}: {b} vs {}",
                    c / z
                );
            }
        }
    }
}
