//! The FedLess controller (§IV, Algorithm 1 Train_Global_Model): the L3
//! event loop that drives one federated experiment end to end.
//!
//! Per round (event-driven since the [`crate::sched`] refactor):
//! 1. the strategy selects clients; clients whose previous invocation is
//!    still in flight are skipped, never re-invoked mid-flight;
//! 2. the simulated GCF platform *plans* every invocation up front —
//!    full virtual timeline plus the crash/late/on-time outcome — so
//!    doomed invocations skip real compute entirely;
//! 3. local training for the surviving invocations runs for real
//!    through the execution [`Backend`] (native MLP or one PJRT HLO
//!    call each), on the persistent executor plane ([`crate::exec`]):
//!    one long-lived worker pool per experiment, work-stealing
//!    dispatch, results re-slotted positionally;
//! 4. completions are replayed through the virtual-clock event queue in
//!    true arrival order: on-time updates stream straight into the
//!    backend's O(P) aggregation fold ([`RoundAgg`], weighted by their
//!    Eq. 3 component) and their buffers are released immediately; late
//!    updates enter the staleness buffer the same way;
//! 5. for staleness-aware strategies the buffer is drained into the
//!    same fold, capped at the kernel's `k_max` with fresh-first /
//!    newest-stale-next priority — still-τ-valid overflow re-buffers
//!    for a later round — and the accumulator is normalized once;
//! 6. the client-history DB is updated exactly as Algorithm 1 does,
//!    including the client-side correction of missed rounds when a slow
//!    update finally lands;
//! 7. the model is centrally evaluated and the §VI metrics recorded.
//!
//! Everything is deterministic in the experiment seed: the platform RNG
//! is consumed in selection order (identical to the serial seed loop),
//! pool completions are re-slotted by job id (so worker count and
//! completion order never leak into results), and the event queue
//! tie-breaks on issue order.
//!
//! Besides the paper's round-synchronous loop, the controller offers a
//! rounds-free **continuous mode** ([`Controller::run_continuous`],
//! `--mode continuous`): no round barrier — the event-driven scheduler
//! keeps `clients_per_round × inflight_cohorts` invocations in flight,
//! folds each completion into the global model as it lands
//! (`new = (1-α·damp)·global + α·damp·update`, with the Eq. 3 staleness
//! damp keyed to the fold *generation* the update departed from), and
//! re-selects replacement clients on completion instead of on a round
//! tick. Same seed ⇒ same event timeline, pinned by
//! `tests/continuous_golden.rs` against a Python mirror.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::clientdb::HistoryStore;
use crate::config::ExperimentConfig;
use crate::cost::CostLedger;
use crate::data::{ClientData, SynthDataset};
use crate::exec;
use crate::faas::{Forced, Outcome, SimulatedGcf};
use crate::metrics::{ContinuousResult, ExperimentResult, RoundRecord, WindowRecord};
use crate::params::{resolve_shards, wire_bytes_estimate, ParamBlock, PlaneGauge, ShardLayout};
use crate::paramsvr::{weight_component, ParameterServer, StaleUpdate};
use crate::runtime::{AggregateFold, Backend};
use crate::sched;
use crate::strategy::{Aggregation, SelectionContext, Strategy};
use crate::util::Rng;
use crate::{ClientId, Result};

/// Metadata of a fresh (on-time) update that has already streamed into
/// this round's aggregation fold — the parameter buffer itself was
/// released at arrival.
struct FreshMeta {
    client: ClientId,
    training_time_s: f64,
    loss: f32,
}

/// The experiment controller.
pub struct Controller<'rt> {
    cfg: ExperimentConfig,
    backend: &'rt dyn Backend,
    data: SynthDataset,
    eval_set: ClientData,
    faas: SimulatedGcf,
    history: HistoryStore,
    server: ParameterServer,
    strategy: Box<dyn Strategy>,
    ledger: CostLedger,
    rng: Rng,
    /// Scenario-forced behaviour per straggler client (fixed at start,
    /// like the paper's "randomly select a specific ratio of clients to
    /// fail at the beginning of each experiment").
    forced: HashMap<ClientId, Forced>,
    clock_s: f64,
    invocations: HashMap<ClientId, u32>,
    /// Synthesized-once cache of client shards (perf: shard synthesis is
    /// deterministic, so re-deriving it every invocation is pure waste).
    /// `Arc` so executor-pool jobs share the shard refcount-only.
    shard_cache: HashMap<ClientId, Arc<ClientData>>,
    /// Adaptive clients-per-round (extension, config.adaptive_clients):
    /// starts at the configured k and tracks recent EUR.
    effective_k: usize,
    /// Registered client ids, materialized once (the seed rebuilt this
    /// O(n) vector every round — real money at 100k+ clients).
    client_ids: Vec<ClientId>,
    /// Clients whose latest invocation is still running on the virtual
    /// clock (late completion or hard-timeout kill): the scheduler never
    /// re-invokes them mid-flight.
    in_flight: sched::InFlight,
    /// Live/peak accounting of parameter-plane bytes (model-weight
    /// buffers only); windowed per round into
    /// `RoundRecord::param_plane_peak_bytes`.
    gauge: PlaneGauge,
    /// Resolved parameter-plane shard count (`FEDLESS_SHARDS` env ▸
    /// config `shards` ▸ core count), threaded through the server, the
    /// aggregation folds, and the quantized wire layout.
    shards: usize,
    /// Per-client error-feedback residuals (quantized-update state):
    /// serverless clients are stateless, so the residual rides the
    /// client DB plane between invocations. Empty when quantization is
    /// off.
    residuals: HashMap<ClientId, Vec<f32>>,
}

impl<'rt> Controller<'rt> {
    pub fn new(cfg: ExperimentConfig, backend: &'rt dyn Backend) -> Result<Self> {
        cfg.validate()?;
        anyhow::ensure!(
            cfg.dataset == backend.manifest().name,
            "config dataset {} vs backend model {}",
            cfg.dataset,
            backend.manifest().name
        );
        let data = SynthDataset::from_manifest(
            backend.manifest(),
            cfg.n_clients,
            cfg.seed,
            cfg.partition,
        )?;
        let eval_set = data.eval_data();
        let mut rng = Rng::seed_from_u64(cfg.seed ^ COORD_SEED_MIX);
        // Platform-stress scenarios (storms, diurnal wave, outages, the
        // adversarial tail) live inside the platform model; Standard /
        // Straggler(_) leave it exactly as `SimulatedGcf::new` would.
        let faas = SimulatedGcf::with_scenario(cfg.faas, cfg.seed, cfg.scenario);

        // §VI-A4: fix the forced straggler set up front.
        let mut forced = HashMap::new();
        let frac = cfg.scenario.straggler_fraction();
        if frac > 0.0 {
            let mut ids: Vec<ClientId> = (0..cfg.n_clients).collect();
            rng.shuffle(&mut ids);
            let n_strag = ((cfg.n_clients as f64) * frac).round() as usize;
            for &c in ids.iter().take(n_strag) {
                let f = if rng.bernoulli(cfg.straggler_slow_frac) {
                    Forced::Slow
                } else {
                    Forced::Crash
                };
                forced.insert(c, f);
            }
        }

        let init = backend.init_params()?;
        let mut gauge = PlaneGauge::default();
        gauge.add(init.len() * std::mem::size_of::<f32>());
        // The controller is a long-lived home for strategy state, so
        // FedLesScan gets the persistent incremental cluster plane here.
        // Paper-scale fleets (≤ COHORT_MAX) still run the stateless
        // path inside select(), keeping seeded goldens byte-identical.
        let strategy = cfg.strategy.build_persistent();
        let cfg_k = cfg.clients_per_round;
        let n_clients = cfg.n_clients;
        let shards = resolve_shards(cfg.shards);
        Ok(Self {
            cfg,
            backend,
            data,
            eval_set,
            faas,
            history: HistoryStore::new(),
            server: ParameterServer::with_shards(init, shards),
            strategy,
            ledger: CostLedger::default(),
            rng,
            forced,
            clock_s: 0.0,
            invocations: HashMap::new(),
            shard_cache: HashMap::new(),
            effective_k: cfg_k,
            client_ids: (0..n_clients).collect(),
            in_flight: sched::InFlight::new(),
            gauge,
            shards,
            residuals: HashMap::new(),
        })
    }

    /// Build the wire policy for one invocation of `client` (`None`
    /// when quantization is off): attach the shard layout and top-k
    /// fraction, and take the client's carried error-feedback residual
    /// out of the client-DB plane (all-zero on first invocation; its
    /// bytes enter the parameter-plane gauge when first materialized
    /// and stay live — residuals are persistent client state).
    fn wire_spec(&mut self, client: ClientId) -> Option<exec::WireSpec> {
        if !self.cfg.quantize_updates {
            return None;
        }
        let p = self.backend.manifest().param_count;
        let residual = match self.residuals.remove(&client) {
            Some(r) => r,
            None => {
                self.gauge.add(p * std::mem::size_of::<f32>());
                vec![0.0f32; p]
            }
        };
        Some(exec::WireSpec {
            layout: ShardLayout::new(p, self.shards),
            topk: self.cfg.quantize_topk,
            residual,
        })
    }

    /// Account one delivered upload and store the client's residual
    /// back into the client-DB plane. Returns the accounted upload
    /// bytes — the quantized wire size, or raw f32 (`p_bytes`) when the
    /// job carried no wire policy.
    fn absorb_wire(
        &mut self,
        client: ClientId,
        wire: Option<exec::WireMeta>,
        p_bytes: usize,
    ) -> usize {
        match wire {
            None => p_bytes,
            Some(w) => {
                let bytes = w.bytes_up;
                self.residuals.insert(client, w.residual);
                bytes
            }
        }
    }

    /// Simulated invocation payload (MB): the platform's transfer model
    /// doubles it (`transfer_s = 2·payload/bw`, download + upload), so
    /// this is the *mean* of the raw-f32 download leg and the upload
    /// leg ([`wire_bytes_estimate`] — deterministic pre-outcome, so the
    /// platform RNG stream order never depends on training results).
    /// With quantization off it returns `manifest().payload_mb()`
    /// verbatim, keeping existing timelines/costs bit-identical.
    fn invoke_payload_mb(&self) -> f64 {
        let mf = self.backend.manifest();
        if !self.cfg.quantize_updates {
            return mf.payload_mb();
        }
        let down = mf.param_count * std::mem::size_of::<f32>();
        let up = wire_bytes_estimate(mf.param_count, self.shards, self.cfg.quantize_topk);
        (down as f64 + up as f64) / 2.0 / 1e6
    }

    /// Number of forced stragglers (used by tests / reports).
    pub fn forced_stragglers(&self) -> usize {
        self.forced.len()
    }

    /// Swap in a custom strategy instance (ablations use this to run
    /// FedLesScan with non-default parameters).
    pub fn set_strategy(&mut self, strategy: Box<dyn Strategy>) {
        self.strategy = strategy;
    }

    pub fn history(&self) -> &HistoryStore {
        &self.history
    }

    /// Drain the strategy's report of its most recent selection pass:
    /// persist fresh cluster assignments into the client DB, truncate
    /// the consumed prefix of the dirty log, and return the pass's
    /// `(reclustered_clients, cluster_cache_hits)` counters. `(0, 0)`
    /// for stateless strategies / the paper-scale path.
    fn absorb_select_report(&mut self) -> (usize, usize) {
        match self.strategy.take_select_report() {
            None => (0, 0),
            Some(rep) => {
                for n in &rep.notes {
                    self.history
                        .note_cluster(n.client, n.feature, n.cell, n.cluster);
                }
                if let Some(cursor) = rep.dirty_cursor {
                    self.history.truncate_dirty(cursor);
                }
                (rep.reclustered_clients, rep.cluster_cache_hits)
            }
        }
    }

    /// Run the full round-synchronous experiment: spawn the persistent
    /// executor pool once, drive every round through it, retire it.
    pub fn run(&mut self) -> Result<ExperimentResult> {
        let backend = self.backend;
        let workers = exec::pool_workers(backend, self.cfg.workers);
        let rounds = std::thread::scope(|scope| {
            let pool = exec::ExecutorPool::new(scope, backend, workers);
            let result = self.run_rounds(&pool);
            let shut = pool.shutdown();
            match (result, shut) {
                (Ok(r), Ok(())) => Ok(r),
                (Err(e), _) => Err(e),
                (Ok(_), Err(e)) => Err(e),
            }
        })?;
        if let Some(path) = &self.cfg.history_path {
            self.history.save(path)?;
        }
        let final_accuracy = rounds
            .iter()
            .rev()
            .find_map(|r| r.accuracy)
            .unwrap_or(0.0);
        Ok(ExperimentResult {
            dataset: self.cfg.dataset.clone(),
            strategy: self.strategy.name().to_string(),
            scenario: self.cfg.scenario.label(),
            seed: self.cfg.seed,
            total_time_s: rounds.iter().map(|r| r.duration_s).sum(),
            total_cost: self.ledger.total,
            final_accuracy,
            rounds,
            invocations: self.invocations.clone(),
        })
    }

    /// The round loop proper, driving every round through the pool.
    fn run_rounds(&mut self, pool: &exec::ExecutorPool<'_>) -> Result<Vec<RoundRecord>> {
        let mut rounds = Vec::with_capacity(self.cfg.rounds as usize);
        for round in 0..self.cfg.rounds {
            let rec = self.run_round(round, pool)?;
            if self.cfg.verbose {
                eprintln!(
                    "[{} {} {}] round {:>3}: eur={:.2} dur={:>7.1}s acc={} cost=${:.4}",
                    self.cfg.dataset,
                    self.strategy.name(),
                    self.cfg.scenario.label(),
                    round,
                    rec.eur,
                    rec.duration_s,
                    rec.accuracy.map_or("-".into(), |a| format!("{a:.3}")),
                    rec.cost,
                );
            }
            rounds.push(rec);
        }
        Ok(rounds)
    }

    fn run_round(&mut self, round: u32, pool: &exec::ExecutorPool<'_>) -> Result<RoundRecord> {
        let round_start = self.clock_s;
        let deadline = round_start + self.cfg.round_timeout_s();
        let cost_before = self.ledger.total;
        let mf = self.backend.manifest();
        let p_bytes = mf.param_count * std::mem::size_of::<f32>();
        self.gauge.begin_window();

        // 1. selection (clients_per_round may be adapted — extension);
        //    timed for the per-round `select_wall_s` observability row
        //    (tiering + clustering + cohort sampling are the scaling-
        //    sensitive path at fleet sizes).
        let select_t0 = Instant::now();
        let selected = {
            let k_now = if self.cfg.adaptive_clients {
                self.effective_k
            } else {
                self.cfg.clients_per_round
            };
            let ctx = SelectionContext {
                round,
                max_rounds: self.cfg.rounds,
                clients_per_round: k_now,
                all_clients: &self.client_ids,
                history: &self.history,
            };
            self.strategy.select(&ctx, &mut self.rng)
        };
        let select_wall_s = select_t0.elapsed().as_secs_f64();
        let (reclustered_clients, cluster_cache_hits) = self.absorb_select_report();

        // 2. in-flight filter: a client whose previous invocation is
        //    still running on the virtual clock is never re-invoked
        //    mid-flight (the seed double-invoked it, corrupting the warm
        //    pool and double-billing the client).
        self.in_flight.expire(round_start);
        let (invoked, skipped) = sched::split_in_flight(&selected, &self.in_flight);
        let in_flight_skipped = skipped.len();

        // 3. plan every invocation up front: the platform decides each
        //    outcome and timeline before any real compute runs. The
        //    platform RNG stream is consumed in selection order, exactly
        //    as the serial seed loop drew it.
        let payload_mb = self.invoke_payload_mb();
        let mut plans: Vec<sched::ClientPlan> = Vec::with_capacity(invoked.len());
        for &client in &invoked {
            self.history.record_invocation(client);
            *self.invocations.entry(client).or_insert(0) += 1;
            let forced = self.forced.get(&client).copied();
            // FedProx partial-work toleration
            let frac = self.strategy.work_fraction(client, &mut self.rng);
            let num_steps = ((mf.steps_per_round as f64 * frac).round() as i32).max(1);
            let compute_s = self.cfg.base_train_s * frac;
            let inv = self.faas.invoke(
                client,
                round_start,
                compute_s,
                payload_mb,
                deadline,
                forced,
            );
            self.ledger.bill(inv.billed_s, self.cfg.faas.memory_mb);
            plans.push(sched::ClientPlan {
                client,
                inv,
                num_steps,
            });
        }

        // 4. real compute through the persistent executor pool, only for
        //    invocations that will deliver an update — crashed
        //    invocations skip training entirely (their work would be
        //    thrown away; the platform still billed them above).
        //    `run_batch` re-slots completions positionally, so the
        //    worker count and completion order never leak into results.
        for p in &plans {
            if p.inv.outcome != Outcome::Crash && !self.shard_cache.contains_key(&p.client) {
                self.shard_cache
                    .insert(p.client, Arc::new(self.data.client_data(p.client)));
            }
        }
        // Zero-copy prox anchor: the round-start global is one shared
        // `ParamBlock` snapshot — every job's `params` and the FedProx
        // anchor read the same allocation (the seed deep-copied the
        // anchor into a second full buffer every prox round).
        let global_now: ParamBlock = self.server.global_block();
        let use_prox = self.strategy.uses_prox();
        // Every invocation downloads the global model; uploads accrue
        // at event replay as each surviving update actually arrives.
        let bytes_down = plans.len() * p_bytes;
        let mut bytes_up = 0usize;
        let mut jobs: Vec<Option<exec::TrainJob>> = Vec::with_capacity(plans.len());
        for p in &plans {
            if p.inv.outcome == Outcome::Crash {
                jobs.push(None);
                continue;
            }
            let wire = self.wire_spec(p.client);
            jobs.push(Some(exec::TrainJob {
                id: 0, // run_batch assigns the slot index
                params: global_now.clone(),
                shard: Arc::clone(&self.shard_cache[&p.client]),
                seed: (round as i32) * 100_003 + p.client as i32,
                num_steps: p.num_steps,
                prox: use_prox,
                wire,
            }));
        }
        let mut results = pool.run_batch(jobs)?;
        let trained = results.iter().flatten().count();
        self.gauge.add(trained * p_bytes);

        // 5. replay completions on the virtual clock, in true arrival
        //    order: fresh updates stream straight into the backend's
        //    O(P) aggregation fold (weighted by their Eq. 3 component)
        //    and their buffers are released immediately; stale updates
        //    enter the buffer in the same order.
        let (tau, normalize) = match self.strategy.aggregation() {
            Aggregation::Synchronous => (1, true),
            Aggregation::StalenessAware { tau, normalize } => (tau, normalize),
        };
        let staleness_aware = matches!(
            self.strategy.aggregation(),
            Aggregation::StalenessAware { .. }
        );
        let t_1b = round + 1; // 1-based aggregation round for Eq. 3
        let expected_k = mf.k_max.min(trained + self.server.stale_len()).max(1);
        let mut agg = RoundAgg::new(self.backend, expected_k, self.shards);
        let mut queue = sched::EventQueue::schedule(&plans);
        let mut fresh: Vec<FreshMeta> = Vec::new();
        let mut fresh_dists: Vec<f64> = Vec::new();
        let mut failed_now: Vec<ClientId> = Vec::new();
        let mut latest_ontime = round_start;
        let mut any_missed = false;
        while let Some(ev) = queue.pop() {
            let plan = &plans[ev.seq];
            match ev.outcome {
                Outcome::OnTime => {
                    let out = results[ev.seq]
                        .take()
                        .expect("on-time invocation must have trained");
                    bytes_up += self.absorb_wire(ev.client, out.wire, p_bytes);
                    let result = out.train;
                    latest_ontime = latest_ontime.max(ev.at_s);
                    if self.cfg.stale_norm_clip.is_some() {
                        // stale_norm_clip reference distance, measured
                        // against the round-start global (the server is
                        // not mutated until this round's fold finishes)
                        fresh_dists.push(l2_dist(&result.params, global_now.as_slice()));
                    }
                    // fresh updates beyond k_max (unreachable with the
                    // presets) still count as successes; they just
                    // cannot enter this round's fold
                    if fresh.len() < mf.k_max {
                        let card = self.data.cardinality(ev.client);
                        // fresh component: damp = t/t = 1, so c_k = n_k
                        let held_before = agg.held_bytes();
                        agg.push(&result.params, card as f64, card)?;
                        // fold growth: O(P) once for a streaming
                        // accumulator, O(P) per entry for a buffered one
                        self.gauge.add(agg.held_bytes().saturating_sub(held_before));
                    }
                    self.gauge.sub(p_bytes); // update buffer released
                    fresh.push(FreshMeta {
                        client: ev.client,
                        training_time_s: plan.inv.training_time_s,
                        loss: result.loss,
                    });
                }
                Outcome::Late => {
                    let out = results[ev.seq]
                        .take()
                        .expect("late invocation must have trained");
                    bytes_up += self.absorb_wire(ev.client, out.wire, p_bytes);
                    let result = out.train;
                    any_missed = true;
                    // Controller assumes the client failed (Alg. 1 L9-12);
                    // the slow update itself lands in the staleness buffer
                    // and the client corrects its history on arrival.
                    self.history.record_failure(ev.client, round);
                    failed_now.push(ev.client);
                    self.in_flight.track(ev.client, ev.at_s);
                    if staleness_aware {
                        self.server.push_stale(StaleUpdate {
                            client: ev.client,
                            produced_round: round + 1, // 1-based t_k for Eq. 3
                            arrived_at_s: ev.at_s,
                            training_time_s: plan.inv.training_time_s,
                            params: result.params,
                            cardinality: self.data.cardinality(ev.client),
                            loss: result.loss,
                        });
                    } else {
                        // synchronous strategies never drain the buffer:
                        // keeping the update would grow the parameter
                        // plane forever for work Alg. 1 already wrote
                        // off as a failure
                        self.gauge.sub(p_bytes);
                    }
                }
                Outcome::Crash => {
                    any_missed = true;
                    self.history.record_failure(ev.client, round);
                    failed_now.push(ev.client);
                    if ev.at_s > deadline {
                        // hard-timeout kill: the doomed instance occupies
                        // the platform into future rounds
                        self.in_flight.track(ev.client, ev.at_s);
                    }
                }
            }
        }

        // Round end: everyone on time -> slowest client; any miss -> the
        // controller waited for the timeout (Alg. 1 "finish or timeout").
        // A round whose entire selection was still in flight also waits
        // out the deadline (the controller is blocked on stragglers).
        //
        // Straggler-drop strategies (SNIPPETS snippet 2) never wait:
        // the round closes at the last on-time arrival and everything
        // still running is discarded — unless nothing arrived at all,
        // in which case the controller still sat out its timeout. The
        // dropped functions were already billed above (§VI-C: they run
        // to completion/timeout on the provider's dime regardless).
        let round_end = if self.strategy.drops_stragglers() {
            if fresh.is_empty() {
                deadline
            } else {
                latest_ontime
            }
        } else if any_missed || (invoked.is_empty() && in_flight_skipped > 0) {
            deadline
        } else {
            latest_ontime
        };

        // 6. aggregation tail: drain the staleness buffer, clip/cap,
        //    fold the surviving stale updates into the same accumulator
        //    the fresh updates streamed into, then normalize once.
        let successes = fresh.len();
        let mut drained = if staleness_aware && self.server.stale_len() > 0 {
            let buffered = self.server.stale_len();
            let ready = self.server.drain_stale(round_end, t_1b, tau);
            // τ-expired updates were dropped inside the drain
            let expired = buffered - self.server.stale_len() - ready.len();
            self.gauge.sub(expired * p_bytes);
            ready
        } else {
            Vec::new()
        };
        // Extension (config.stale_norm_clip): discard stale updates
        // that drifted too far from the current global relative to
        // this round's fresh updates — "aggregate valuable updates
        // and discard the unnecessary ones" (paper §VII). The fresh
        // reference distances were recorded at arrival (the buffers are
        // gone); with no fresh updates the filter is a no-op.
        if let (Some(clip), false) = (self.cfg.stale_norm_clip, fresh_dists.is_empty()) {
            fresh_dists.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let median = sched::median_sorted(&fresh_dists).max(1e-12);
            let before = drained.len();
            drained.retain(|u| l2_dist(&u.params, global_now.as_slice()) <= clip * median);
            self.gauge.sub((before - drained.len()) * p_bytes);
        }
        // k_max cap: fresh first, newest stale next. Only applied stale
        // updates receive history credit and `stale_applied` accounting;
        // the still-τ-valid overflow re-buffers for a later aggregation
        // round instead of being discarded (the seed dropped it).
        let (kept, overflow) = sched::cap_stale(successes, drained, mf.k_max);
        for u in overflow {
            self.server.push_stale(u);
        }
        for u in &kept {
            // client-side history correction (§V-B): round numbers in
            // the DB are 0-based
            self.history
                .record_late_completion(u.client, u.produced_round - 1, u.training_time_s);
        }
        let stale_applied = kept.len();
        for u in kept {
            if let Some(c) = weight_component(u.produced_round, u.cardinality, t_1b, tau) {
                let held_before = agg.held_bytes();
                agg.push(&u.params, c, u.cardinality)?;
                self.gauge.add(agg.held_bytes().saturating_sub(held_before));
            }
            self.gauge.sub(p_bytes); // stale buffer entry released
        }
        let fold_held = agg.held_bytes();
        let mut agg_wall_s = 0.0;
        match agg.finish(normalize)? {
            Some((aggregated, wall)) => {
                agg_wall_s = wall.as_secs_f64();
                self.gauge.add(p_bytes); // frozen snapshot materializes
                self.server.set_global(aggregated.into(), t_1b);
                self.gauge.sub(fold_held); // fold holdings released by finish
                self.gauge.sub(p_bytes); // previous global released
            }
            None => self.gauge.sub(fold_held), // degenerate fold dropped unused
        }

        // 7. history bookkeeping for on-time clients + cooldown decay
        for u in &fresh {
            self.history
                .record_success(u.client, round, u.training_time_s);
        }
        self.history.tick_cooldowns(&failed_now);

        // 8. central evaluation
        let do_eval = round % self.cfg.eval_every == 0 || round + 1 == self.cfg.rounds;
        let (accuracy, eval_loss) = if do_eval {
            let ev = self.backend.evaluate(
                self.server.global().as_slice(),
                &self.eval_set.x,
                &self.eval_set.y,
            )?;
            (Some(ev.accuracy), Some(ev.loss))
        } else {
            (None, None)
        };

        // Extension: adapt k to the observed EUR so the next round's
        // *effective* (on-time) update count tracks the configured k. A
        // round that invoked nobody (all selected were in flight)
        // produced no evidence, so it leaves k untouched rather than
        // over-provisioning off the vacuous EUR of 0.
        if self.cfg.adaptive_clients && !invoked.is_empty() {
            let eur = RoundRecord::compute_eur(successes, invoked.len());
            let target = self.cfg.clients_per_round as f64;
            let want = (target / eur.max(0.25)).round() as usize;
            self.effective_k = want
                .clamp(
                    (self.cfg.clients_per_round / 2).max(1),
                    (self.cfg.clients_per_round * 2).min(self.cfg.n_clients),
                );
        }

        self.clock_s = round_end;
        let train_loss = if fresh.is_empty() {
            None
        } else {
            Some(fresh.iter().map(|u| u.loss).sum::<f32>() / fresh.len() as f32)
        };
        Ok(RoundRecord {
            round,
            eur: RoundRecord::compute_eur(successes, invoked.len()),
            selected,
            successes,
            failures: failed_now.len(),
            stale_applied,
            in_flight_skipped,
            duration_s: round_end - round_start,
            accuracy,
            eval_loss,
            train_loss,
            cost: self.ledger.total - cost_before,
            select_wall_s,
            agg_wall_s,
            param_plane_peak_bytes: self.gauge.peak(),
            bytes_down,
            bytes_up,
            reclustered_clients,
            cluster_cache_hits,
        })
    }

    /// Run the rounds-free **continuous mode** experiment
    /// (`--mode continuous`): spawn the persistent executor pool, keep
    /// `clients_per_round × inflight_cohorts` invocations in flight,
    /// fold each completion into the global as it lands, and re-select
    /// replacement clients on completion. The total invocation budget
    /// is `rounds × clients_per_round`, so continuous and round mode
    /// spend comparable platform work for one config.
    pub fn run_continuous(&mut self) -> Result<ContinuousResult> {
        let backend = self.backend;
        let workers = exec::pool_workers(backend, self.cfg.workers);
        let result = std::thread::scope(|scope| {
            let pool = exec::ExecutorPool::new(scope, backend, workers);
            let result = self.drive_continuous(&pool);
            let shut = pool.shutdown();
            match (result, shut) {
                (Ok(r), Ok(())) => Ok(r),
                (Err(e), _) => Err(e),
                (Ok(_), Err(e)) => Err(e),
            }
        })?;
        if let Some(path) = &self.cfg.history_path {
            self.history.save(path)?;
        }
        Ok(result)
    }

    /// The continuous event loop. Determinism contract (pinned by
    /// `tests/continuous_golden.rs` against the Python mirror in
    /// `python/mirror/continuous.py`):
    ///
    /// * invocations are dispatched in selection order, consuming the
    ///   platform RNG exactly as round mode does;
    /// * each invocation's deadline is `dispatch + round_timeout_s()`
    ///   (finishing later ⇒ `Late`, which still folds — there is no
    ///   round barrier to miss);
    /// * completions replay through the [`sched::EventQueue`] with its
    ///   pinned `(arrival, issue-seq)` ordering;
    /// * staleness is keyed to **fold generations**: an update that
    ///   departed from generation `g` and lands at generation `t` gets
    ///   Eq. 3 damp `(g+1)/(t+1)` and expires when `t - g ≥ τ·k` (the
    ///   per-round τ rescaled to per-completion granularity; a
    ///   synchronous strategy never expires, it only damps);
    /// * metrics are windowed by `round_timeout_s()` so updates/s and
    ///   the effective update ratio are comparable across modes.
    fn drive_continuous(&mut self, pool: &exec::ExecutorPool<'_>) -> Result<ContinuousResult> {
        let mf = self.backend.manifest();
        let p_bytes = mf.param_count * std::mem::size_of::<f32>();
        let k = self.cfg.clients_per_round.max(1);
        let budget = self.cfg.rounds as usize * k;
        let target = k * self.cfg.inflight_cohorts.max(1);
        let window_s = self.cfg.round_timeout_s();
        // Rescale the per-round staleness bound to per-completion fold
        // generations: one round ≈ k folds.
        let tau_gen = match self.strategy.aggregation() {
            Aggregation::Synchronous => u32::MAX,
            Aggregation::StalenessAware { tau, .. } => {
                tau.saturating_mul(k as u32).max(1)
            }
        };
        let alpha0 = self.cfg.async_alpha;
        self.gauge.begin_window();

        let mut st = ContState {
            queue: sched::EventQueue::new(),
            pending: HashMap::new(),
            seq: 0,
            dispatched: 0,
            bytes_down: 0,
        };
        let mut bytes_up = 0usize;
        let mut results: HashMap<usize, exec::TrainOutput> = HashMap::new();
        let mut windows: Vec<WindowRecord> = Vec::new();
        let mut win = WindowAcc::new(0, 0.0, window_s);
        let mut failed_since_tick: Vec<ClientId> = Vec::new();
        let (mut completions, mut folds, mut crashes) = (0usize, 0usize, 0usize);
        let (mut expired, mut late, mut in_flight_skipped) = (0usize, 0usize, 0usize);
        let mut agg_wall_s = 0.0;
        let mut select_wall_s = 0.0;
        let (mut reclustered_clients, mut cluster_cache_hits) = (0usize, 0usize);
        let mut now_s = 0.0;

        let d = self.dispatch_continuous(pool, &mut st, target, now_s, budget, window_s)?;
        win.absorb(&d);
        in_flight_skipped += d.skipped;
        select_wall_s += d.select_wall_s;
        reclustered_clients += d.reclustered;
        cluster_cache_hits += d.cache_hits;
        win.in_flight_peak = win.in_flight_peak.max(st.pending.len());

        while let Some(ev) = st.queue.pop() {
            now_s = ev.at_s;
            // close metric windows the virtual clock has passed (empty
            // windows are recorded too — a stall is a data point)
            while now_s >= win.end_s {
                windows.push(win.finish());
                let start = win.end_s;
                win = WindowAcc::new(windows.len() as u32, start, start + window_s);
                win.in_flight_peak = st.pending.len();
            }
            let p = st
                .pending
                .remove(&ev.seq)
                .expect("completion event without a pending invocation");
            self.in_flight.expire(now_s);
            let pseudo_round = (completions / k) as u32;
            win.completions += 1;
            match ev.outcome {
                Outcome::Crash => {
                    crashes += 1;
                    win.crashes += 1;
                    self.history.record_failure(ev.client, pseudo_round);
                    failed_since_tick.push(ev.client);
                }
                Outcome::OnTime | Outcome::Late => {
                    if ev.outcome == Outcome::Late {
                        late += 1;
                    }
                    let out = take_result(pool, &mut results, ev.seq)?;
                    // the upload crossed the wire whether or not the
                    // update survives the τ check below
                    bytes_up += self.absorb_wire(ev.client, out.wire, p_bytes);
                    let result = out.train;
                    self.gauge.add(p_bytes); // trained update materializes
                    let gen_now = self.server.generation();
                    // Eq. 3 damp on generation staleness (cardinality 1:
                    // shards are uniform and α carries the mixing rate)
                    match weight_component(p.departed_gen + 1, 1, gen_now + 1, tau_gen) {
                        None => {
                            // τ-expired: the global moved too far since
                            // this update departed — discard, count as a
                            // failure (Alg. 1's write-off)
                            expired += 1;
                            win.expired += 1;
                            self.history.record_failure(ev.client, pseudo_round);
                            failed_since_tick.push(ev.client);
                            self.gauge.sub(p_bytes);
                        }
                        Some(damp) => {
                            let alpha = (alpha0 * damp).clamp(0.0, 1.0) as f32;
                            let global_now = self.server.global_block();
                            let mut fold =
                                self.backend.begin_fold_sharded(2, self.shards)?;
                            fold.accumulate(global_now.as_slice(), 1.0 - alpha)?;
                            fold.accumulate(&result.params, alpha)?;
                            let held = fold.held_bytes();
                            self.gauge.add(held);
                            let (new_global, wall) = fold.finish()?;
                            agg_wall_s += wall.as_secs_f64();
                            self.gauge.add(p_bytes); // new snapshot
                            self.server.set_global(new_global.into(), gen_now + 1);
                            self.gauge.sub(held);
                            self.gauge.sub(p_bytes); // previous global
                            self.gauge.sub(p_bytes); // update released
                            folds += 1;
                            win.folds += 1;
                            self.history.record_success(
                                ev.client,
                                pseudo_round,
                                p.training_time_s,
                            );
                        }
                    }
                }
            }
            completions += 1;
            // cooldown decay at round-equivalent cadence (every k
            // completions ≈ one round of platform work)
            if completions % k == 0 {
                self.history.tick_cooldowns(&failed_since_tick);
                failed_since_tick.clear();
            }
            let free = target.saturating_sub(st.pending.len());
            if free > 0 {
                let d =
                    self.dispatch_continuous(pool, &mut st, free, now_s, budget, window_s)?;
                win.absorb(&d);
                in_flight_skipped += d.skipped;
                select_wall_s += d.select_wall_s;
                reclustered_clients += d.reclustered;
                cluster_cache_hits += d.cache_hits;
            }
            win.in_flight_peak = win.in_flight_peak.max(st.pending.len());
        }
        windows.push(win.finish());
        if !failed_since_tick.is_empty() {
            self.history.tick_cooldowns(&failed_since_tick);
        }
        self.clock_s = now_s;

        let ev = self.backend.evaluate(
            self.server.global().as_slice(),
            &self.eval_set.x,
            &self.eval_set.y,
        )?;
        Ok(ContinuousResult {
            dataset: self.cfg.dataset.clone(),
            strategy: self.strategy.name().to_string(),
            scenario: self.cfg.scenario.label(),
            seed: self.cfg.seed,
            windows,
            duration_s: now_s,
            dispatched: st.dispatched,
            completions,
            folds,
            crashes,
            expired,
            late,
            in_flight_skipped,
            final_generation: self.server.generation(),
            final_accuracy: ev.accuracy,
            total_cost: self.ledger.total,
            agg_wall_s,
            select_wall_s,
            reclustered_clients,
            cluster_cache_hits,
            bytes_down: st.bytes_down,
            bytes_up,
            invocations: self.invocations.clone(),
        })
    }

    /// Select and dispatch up to `want` replacement invocations at
    /// virtual time `now_s` (bounded by the remaining budget). Mirrors
    /// round-mode dispatch draw-for-draw: record_invocation →
    /// work_fraction → platform invoke → bill, in selection order.
    fn dispatch_continuous(
        &mut self,
        pool: &exec::ExecutorPool<'_>,
        st: &mut ContState,
        want: usize,
        now_s: f64,
        budget: usize,
        window_s: f64,
    ) -> Result<Dispatched> {
        let want = want.min(budget.saturating_sub(st.dispatched));
        if want == 0 {
            return Ok(Dispatched::default());
        }
        let k = self.cfg.clients_per_round.max(1);
        let payload_mb = self.invoke_payload_mb();
        let pseudo_round = (st.dispatched / k) as u32;
        let select_t0 = Instant::now();
        let selected = {
            let ctx = SelectionContext {
                round: pseudo_round,
                max_rounds: self.cfg.rounds,
                clients_per_round: want,
                all_clients: &self.client_ids,
                history: &self.history,
            };
            self.strategy.select_replacements(&ctx, &mut self.rng)
        };
        let select_wall_s = select_t0.elapsed().as_secs_f64();
        let (reclustered, cache_hits) = self.absorb_select_report();
        self.in_flight.expire(now_s);
        let (invoked, skipped) = sched::split_in_flight(&selected, &self.in_flight);
        let mf = self.backend.manifest();
        let global_now = self.server.global_block();
        let gen_now = self.server.generation();
        let use_prox = self.strategy.uses_prox();
        let mut n_invoked = 0usize;
        for &client in &invoked {
            if st.dispatched >= budget {
                break;
            }
            self.history.record_invocation(client);
            *self.invocations.entry(client).or_insert(0) += 1;
            let forced = self.forced.get(&client).copied();
            let frac = self.strategy.work_fraction(client, &mut self.rng);
            let num_steps = ((mf.steps_per_round as f64 * frac).round() as i32).max(1);
            let compute_s = self.cfg.base_train_s * frac;
            // per-invocation deadline: one round-timeout of grace; a
            // later finish is merely Late (it still folds)
            let deadline = now_s + window_s;
            let inv = self.faas.invoke(
                client,
                now_s,
                compute_s,
                payload_mb,
                deadline,
                forced,
            );
            self.ledger.bill(inv.billed_s, self.cfg.faas.memory_mb);
            self.in_flight.track(client, inv.finished_at);
            st.bytes_down += mf.param_count * std::mem::size_of::<f32>();
            let seq = st.seq;
            st.seq += 1;
            st.dispatched += 1;
            n_invoked += 1;
            if inv.outcome != Outcome::Crash {
                if !self.shard_cache.contains_key(&client) {
                    self.shard_cache
                        .insert(client, Arc::new(self.data.client_data(client)));
                }
                let wire = self.wire_spec(client);
                pool.submit(exec::TrainJob {
                    id: seq,
                    params: global_now.clone(),
                    shard: Arc::clone(&self.shard_cache[&client]),
                    seed: (seq as i32) * 100_003 + client as i32,
                    num_steps,
                    prox: use_prox,
                    wire,
                })?;
            }
            st.pending.insert(
                seq,
                PendingInv {
                    departed_gen: gen_now,
                    training_time_s: inv.training_time_s,
                },
            );
            st.queue.push(sched::CompletionEvent {
                at_s: inv.finished_at,
                seq,
                client,
                outcome: inv.outcome,
            });
        }
        Ok(Dispatched {
            invoked: n_invoked,
            skipped: skipped.len(),
            select_wall_s,
            reclustered,
            cache_hits,
        })
    }
}

/// Continuous-mode dispatch bookkeeping.
struct ContState {
    queue: sched::EventQueue,
    /// seq → in-flight invocation metadata (crashes included: they hold
    /// an in-flight slot until their event fires).
    pending: HashMap<usize, PendingInv>,
    /// Monotonic invocation sequence number (job id + event tie-break).
    seq: usize,
    /// Total invocations dispatched (the budget counter).
    dispatched: usize,
    /// Accounted download bytes (every dispatch ships the raw f32
    /// global to the client).
    bytes_down: usize,
}

/// What the continuous driver remembers about one in-flight invocation.
struct PendingInv {
    /// Fold generation of the global snapshot the client departed with.
    departed_gen: u32,
    training_time_s: f64,
}

/// Per-dispatch summary.
#[derive(Default)]
struct Dispatched {
    invoked: usize,
    skipped: usize,
    /// Wall-clock seconds the replacement selection took.
    select_wall_s: f64,
    /// Cluster counters drained from the strategy's select report.
    reclustered: usize,
    cache_hits: usize,
}

/// One metric window being accumulated (continuous mode records
/// per-unit-time rows instead of per-round rows).
struct WindowAcc {
    window: u32,
    start_s: f64,
    end_s: f64,
    dispatched: usize,
    completions: usize,
    folds: usize,
    crashes: usize,
    expired: usize,
    in_flight_peak: usize,
    select_wall_s: f64,
    reclustered_clients: usize,
    cluster_cache_hits: usize,
}

impl WindowAcc {
    fn new(window: u32, start_s: f64, end_s: f64) -> Self {
        Self {
            window,
            start_s,
            end_s,
            dispatched: 0,
            completions: 0,
            folds: 0,
            crashes: 0,
            expired: 0,
            in_flight_peak: 0,
            select_wall_s: 0.0,
            reclustered_clients: 0,
            cluster_cache_hits: 0,
        }
    }

    /// Fold one dispatch pass's selection accounting into the window.
    fn absorb(&mut self, d: &Dispatched) {
        self.dispatched += d.invoked;
        self.select_wall_s += d.select_wall_s;
        self.reclustered_clients += d.reclustered;
        self.cluster_cache_hits += d.cache_hits;
    }

    fn finish(&self) -> WindowRecord {
        let dur = self.end_s - self.start_s;
        WindowRecord {
            window: self.window,
            start_s: self.start_s,
            end_s: self.end_s,
            dispatched: self.dispatched,
            completions: self.completions,
            folds: self.folds,
            crashes: self.crashes,
            expired: self.expired,
            updates_per_s: if dur > 0.0 {
                self.folds as f64 / dur
            } else {
                0.0
            },
            effective_update_ratio: if self.completions > 0 {
                self.folds as f64 / self.completions as f64
            } else {
                0.0
            },
            in_flight_peak: self.in_flight_peak,
            select_wall_s: self.select_wall_s,
            reclustered_clients: self.reclustered_clients,
            cluster_cache_hits: self.cluster_cache_hits,
        }
    }
}

/// Pull completions off the pool until `seq`'s result arrives, parking
/// out-of-order results for later events. Never hangs: a job was
/// submitted for every non-crash event, and worker panics come back as
/// errors, not silence.
fn take_result(
    pool: &exec::ExecutorPool<'_>,
    results: &mut HashMap<usize, exec::TrainOutput>,
    seq: usize,
) -> Result<exec::TrainOutput> {
    if let Some(r) = results.remove(&seq) {
        return Ok(r);
    }
    loop {
        let done = pool.next_done()?;
        match done.result {
            Ok(r) => {
                if done.id == seq {
                    return Ok(r);
                }
                results.insert(done.id, r);
            }
            Err(e) => anyhow::bail!("train job {}: {e}", done.id),
        }
    }
}

/// L2 distance between an update and the round-start global snapshot
/// (the `stale_norm_clip` reference metric).
fn l2_dist(p: &[f32], q: &[f32]) -> f64 {
    p.iter()
        .zip(q)
        .map(|(a, b)| f64::from(a - b).powi(2))
        .sum::<f64>()
        .sqrt()
}

/// One round's streaming Eq. 3 aggregation: updates fold into the
/// backend's O(P) accumulator as the event queue replays their arrival,
/// each weighted by its Eq. 3 component `c_k = (t_k/t) · n_k`
/// ([`weight_component`]); `finish` divides by the batch normalizer `Z`
/// (the included-cardinality sum, or `Σ c_k` when normalizing) exactly
/// once. Algebraically identical to weighting each update by
/// `staleness_weights` and batch-aggregating — the floating-point
/// rounding differs in the last ulp, and the equivalence is pinned to
/// 1e-5 by `tests/native_golden.rs` — but the hot path holds one O(P)
/// accumulator instead of every update vector simultaneously.
struct RoundAgg<'b> {
    backend: &'b dyn Backend,
    expected_k: usize,
    /// Parameter-plane shard count for the backend fold accumulator.
    shards: usize,
    fold: Option<Box<dyn AggregateFold + 'b>>,
    /// Σ c_k over folded updates (the normalized-variant divisor).
    comp_sum: f64,
    /// Σ n_k over folded updates (the verbatim-Eq. 3 divisor).
    card_sum: f64,
}

impl<'b> RoundAgg<'b> {
    fn new(backend: &'b dyn Backend, expected_k: usize, shards: usize) -> Self {
        Self {
            backend,
            expected_k,
            shards,
            fold: None,
            comp_sum: 0.0,
            card_sum: 0.0,
        }
    }

    /// Bytes the backend fold currently holds (0 before the first
    /// push): O(P) for the native streaming accumulator, O(count × P)
    /// for a buffered batch fold — the gauge tracks whichever is real.
    fn held_bytes(&self) -> usize {
        self.fold.as_ref().map_or(0, |f| f.held_bytes())
    }

    /// Fold one update with Eq. 3 component `c`; `cardinality` feeds
    /// the verbatim-Eq. 3 divisor. The fold allocates lazily so empty
    /// rounds never touch the backend.
    fn push(&mut self, update: &[f32], component: f64, cardinality: usize) -> Result<()> {
        if self.fold.is_none() {
            self.fold = Some(
                self.backend
                    .begin_fold_sharded(self.expected_k, self.shards)?,
            );
        }
        let fold = self.fold.as_mut().expect("fold just created");
        fold.accumulate(update, component as f32)?;
        self.comp_sum += component;
        self.card_sum += cardinality as f64;
        Ok(())
    }

    /// Normalize the accumulator by the Eq. 3 divisor and return the
    /// new global plus the aggregation wall time. `None` when nothing
    /// was folded or every component was zero (mirroring the batch
    /// path, which skips `set_global` when all weights are zero).
    fn finish(self, normalize: bool) -> Result<Option<(Vec<f32>, Duration)>> {
        let Some(fold) = self.fold else {
            return Ok(None);
        };
        let z = if normalize { self.comp_sum } else { self.card_sum };
        if z <= 0.0 {
            return Ok(None);
        }
        let (mut out, wall) = fold.finish()?;
        let t0 = Instant::now();
        let scale = (1.0 / z) as f32;
        for o in out.iter_mut() {
            *o *= scale;
        }
        Ok(Some((out, wall + t0.elapsed())))
    }
}

/// Seed-mixing constant: keeps the controller RNG stream independent of
/// the dataset / platform streams derived from the same experiment seed.
const COORD_SEED_MIX: u64 = 0xc00d_1234_5678_9abc;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Scenario;

    #[test]
    fn scenario_forcing_counts() {
        // Forced straggler assignment logic is deterministic in the seed;
        // exercised end-to-end in tests/integration.rs (needs artifacts).
        let cfg = ExperimentConfig::preset("mnist");
        assert_eq!(cfg.scenario.straggler_fraction(), 0.0);
        assert_eq!(Scenario::Straggler(50).straggler_fraction(), 0.5);
    }
}
