//! Google Cloud Functions cost model (the paper's §VI-A5 / Table IV
//! methodology, using the published 2022 unit prices [85]).
//!
//! GCF bills three meters per invocation:
//!   * invocations:   $0.40 per million,
//!   * memory time:   $0.0000025 per GB-second,
//!   * compute time:  $0.0000100 per GHz-second,
//! with duration rounded up to the 100 ms granularity. The CPU clock
//! allocated to a function scales with its memory tier; the paper's
//! clients use 2048 MB (-> 2.4 GHz on the GCF tier table).
//!
//! Straggler accounting follows §VI-C: a straggler (slow or crashed) is
//! billed for the **entire round duration** — the worst case the authors
//! assume, since its function instance keeps computing until timeout.

/// 2022 GCF unit prices (no free tier — the paper's experiments are far
/// beyond it and include it in neither direction).
#[derive(Debug, Clone, Copy)]
pub struct GcfPricing {
    pub per_invocation: f64,
    pub per_gb_second: f64,
    pub per_ghz_second: f64,
    /// Billing granularity in seconds (GCF rounds up to 100 ms).
    pub granularity_s: f64,
}

impl Default for GcfPricing {
    fn default() -> Self {
        Self {
            per_invocation: 0.40 / 1e6,
            per_gb_second: 0.000_002_5,
            per_ghz_second: 0.000_010_0,
            granularity_s: 0.1,
        }
    }
}

/// Memory tier -> allocated CPU clock (GHz), per the GCF pricing table.
pub fn ghz_for_memory_mb(memory_mb: u32) -> f64 {
    match memory_mb {
        0..=128 => 0.2,
        129..=256 => 0.4,
        257..=512 => 0.8,
        513..=1024 => 1.4,
        1025..=2048 => 2.4,
        _ => 4.8,
    }
}

impl GcfPricing {
    /// Duration the provider actually meters: `duration_s` rounded up
    /// to the billing granularity. This is the single definition of the
    /// rounding — both the cost formula and the ledger's
    /// `billed_seconds` accumulator go through it, so the two can never
    /// disagree about what was billed.
    pub fn billed_duration(&self, duration_s: f64) -> f64 {
        assert!(duration_s >= 0.0, "negative duration");
        (duration_s / self.granularity_s).ceil() * self.granularity_s
    }

    /// Cost of one invocation of `duration_s` at `memory_mb`.
    pub fn invocation_cost(&self, duration_s: f64, memory_mb: u32) -> f64 {
        let billed = self.billed_duration(duration_s);
        let gb = memory_mb as f64 / 1024.0;
        self.per_invocation
            + billed * gb * self.per_gb_second
            + billed * ghz_for_memory_mb(memory_mb) * self.per_ghz_second
    }
}

/// Running cost accumulator for one experiment.
#[derive(Debug, Clone, Default)]
pub struct CostLedger {
    pub pricing: GcfPricing,
    pub total: f64,
    pub invocations: u64,
    pub billed_seconds: f64,
}

impl CostLedger {
    pub fn new(pricing: GcfPricing) -> Self {
        Self {
            pricing,
            total: 0.0,
            invocations: 0,
            billed_seconds: 0.0,
        }
    }

    /// Bill one function invocation; returns its cost.
    pub fn bill(&mut self, duration_s: f64, memory_mb: u32) -> f64 {
        let c = self.pricing.invocation_cost(duration_s, memory_mb);
        self.total += c;
        self.invocations += 1;
        // Accumulate what the provider meters, not the raw wall time:
        // GCF rounds every invocation up to the billing granularity, so
        // `billed_seconds` must agree with the durations `total` was
        // computed from.
        self.billed_seconds += self.pricing.billed_duration(duration_s);
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_duration_still_bills_invocation() {
        let p = GcfPricing::default();
        let c = p.invocation_cost(0.0, 2048);
        assert!((c - p.per_invocation).abs() < 1e-12);
    }

    #[test]
    fn cost_monotone_in_duration() {
        let p = GcfPricing::default();
        let c1 = p.invocation_cost(1.0, 2048);
        let c2 = p.invocation_cost(2.0, 2048);
        let c60 = p.invocation_cost(60.0, 2048);
        assert!(c1 < c2 && c2 < c60);
    }

    #[test]
    fn granularity_rounds_up() {
        let p = GcfPricing::default();
        // 10 ms bills like 100 ms
        assert_eq!(p.invocation_cost(0.01, 1024), p.invocation_cost(0.1, 1024));
        assert!(p.invocation_cost(0.11, 1024) > p.invocation_cost(0.1, 1024));
    }

    #[test]
    fn memory_tier_scales_clock() {
        assert_eq!(ghz_for_memory_mb(2048), 2.4);
        assert_eq!(ghz_for_memory_mb(128), 0.2);
        assert!(ghz_for_memory_mb(4096) > ghz_for_memory_mb(2048));
    }

    #[test]
    fn paper_magnitude_sanity() {
        // 2048 MB client running 60 s: a few millicents — matches the
        // paper's per-experiment dollars at hundreds of invocations.
        let p = GcfPricing::default();
        let c = p.invocation_cost(60.0, 2048);
        assert!(c > 0.001 && c < 0.01, "cost {c}");
    }

    #[test]
    fn ledger_bills_granularity_rounded_seconds() {
        // A 10 ms invocation is metered as one full 100 ms slice; the
        // ledger must accumulate the rounded duration, matching what
        // `invocation_cost` charged for.
        let mut l = CostLedger::new(GcfPricing::default());
        l.bill(0.01, 2048);
        assert!((l.billed_seconds - 0.1).abs() < 1e-12, "{}", l.billed_seconds);
        l.bill(0.11, 2048);
        assert!((l.billed_seconds - 0.3).abs() < 1e-12, "{}", l.billed_seconds);
    }

    #[test]
    fn ledger_accumulates() {
        let mut l = CostLedger::new(GcfPricing::default());
        let a = l.bill(10.0, 2048);
        let b = l.bill(20.0, 2048);
        assert_eq!(l.invocations, 2);
        assert!((l.total - (a + b)).abs() < 1e-12);
        assert!((l.billed_seconds - 30.0).abs() < 1e-12);
    }
}
