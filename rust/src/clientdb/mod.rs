//! Client-history database (the paper's MongoDB "client history
//! collection", §IV-A) — behavioural data per client: training times,
//! missed rounds and the cooldown counter of Eq. 1.
//!
//! Update semantics follow Algorithm 1 exactly:
//!
//! * controller, on success: cooldown := 0, record training time;
//! * controller, on failure: append the round to `missed_rounds` and
//!   apply Eq. 1 (`0 -> 1`, else `*2`);
//! * client, on late completion (a "slow update" arriving after the
//!   round): remove the round from `missed_rounds` and record the time —
//!   distinguishing *slow* from *crashed* is done on the client side
//!   (§V-B).
//!
//! The paper describes cooldown as "the number of rounds a client has to
//! stay in the last tier" (§V-B); Algorithm 1 only shows the growth rule,
//! so this implementation also ticks the counter down by one at the end
//! of every round in which the client did not fail again — without the
//! tick a client that is never re-invoked would remain a straggler
//! forever, contradicting §V-A ("tier-3 clients can move to tier-2 and
//! vice-versa").

use std::collections::{HashMap, HashSet};
use std::path::Path;

use crate::util::Json;
use crate::{ClientId, Result};

/// Behavioural record for one client (§V-B).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ClientHistory {
    /// Completed local-training durations, in order (seconds, virtual).
    pub training_times: Vec<f64>,
    /// Rounds this client was invoked in but missed (slow or crashed).
    pub missed_rounds: Vec<u32>,
    /// Eq. 1 counter: > 0 means tier-3 (straggler).
    pub cooldown: u32,
    /// Total controller invocations.
    pub invocations: u32,
    /// On-time completions.
    pub successes: u32,
}

impl ClientHistory {
    /// A rookie has never been invoked (§V-A tier 1).
    pub fn is_rookie(&self) -> bool {
        self.invocations == 0
    }

    /// Tier-3 test (§V-A): any live cooldown marks a straggler.
    pub fn is_straggler(&self) -> bool {
        self.cooldown > 0
    }
}

/// In-memory history store with JSON snapshot persistence.
#[derive(Debug, Default, Clone)]
pub struct HistoryStore {
    map: HashMap<ClientId, ClientHistory>,
}

impl HistoryStore {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn get(&self, id: ClientId) -> ClientHistory {
        self.map.get(&id).cloned().unwrap_or_default()
    }

    pub fn get_ref(&self, id: ClientId) -> Option<&ClientHistory> {
        self.map.get(&id)
    }

    fn entry(&mut self, id: ClientId) -> &mut ClientHistory {
        self.map.entry(id).or_default()
    }

    /// Controller marked this client as invoked this round.
    pub fn record_invocation(&mut self, id: ClientId) {
        self.entry(id).invocations += 1;
    }

    /// On-time completion (Algorithm 1 lines 5-8 + client lines 22-27).
    pub fn record_success(&mut self, id: ClientId, round: u32, training_time: f64) {
        let h = self.entry(id);
        h.cooldown = 0;
        h.successes += 1;
        h.training_times.push(training_time);
        h.missed_rounds.retain(|&r| r != round);
    }

    /// Missed round (Algorithm 1 lines 9-13): Eq. 1 growth.
    pub fn record_failure(&mut self, id: ClientId, round: u32) {
        let h = self.entry(id);
        if !h.missed_rounds.contains(&round) {
            h.missed_rounds.push(round);
        }
        h.cooldown = if h.cooldown == 0 { 1 } else { h.cooldown * 2 };
    }

    /// Late ("slow") update arrived after its round finished — the client
    /// corrects its own record (§V-B): un-miss the round, record the time.
    pub fn record_late_completion(&mut self, id: ClientId, round: u32, training_time: f64) {
        let h = self.entry(id);
        h.missed_rounds.retain(|&r| r != round);
        h.training_times.push(training_time);
    }

    /// End-of-round tick: cooldowns decay by one except for clients that
    /// failed *this* round (their Eq. 1 value is fresh). The failed list
    /// is hashed once up front so the tick is O(clients + failed) rather
    /// than O(clients * failed); duplicate ids in the list are harmless.
    pub fn tick_cooldowns(&mut self, failed_this_round: &[ClientId]) {
        let failed: HashSet<ClientId> = failed_this_round.iter().copied().collect();
        for (id, h) in self.map.iter_mut() {
            if h.cooldown > 0 && !failed.contains(id) {
                h.cooldown -= 1;
            }
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&ClientId, &ClientHistory)> {
        self.map.iter()
    }

    /// Snapshot to JSON (the paper's DB persistence stand-in).
    pub fn save(&self, path: &Path) -> Result<()> {
        let entries: Vec<Json> = self
            .map
            .iter()
            .map(|(id, h)| {
                Json::obj(vec![
                    ("client", Json::num(*id as f64)),
                    ("training_times", Json::from_f64_slice(&h.training_times)),
                    (
                        "missed_rounds",
                        Json::Arr(h.missed_rounds.iter().map(|&r| Json::num(r as f64)).collect()),
                    ),
                    ("cooldown", Json::num(h.cooldown as f64)),
                    ("invocations", Json::num(h.invocations as f64)),
                    ("successes", Json::num(h.successes as f64)),
                ])
            })
            .collect();
        Json::obj(vec![("clients", Json::Arr(entries))]).write_file(path)
    }

    pub fn load(path: &Path) -> Result<Self> {
        let root = Json::parse_file(path)?;
        let mut map = HashMap::new();
        for e in root.get("clients")?.as_arr()? {
            let id = e.get("client")?.as_usize()?;
            let h = ClientHistory {
                training_times: e
                    .get("training_times")?
                    .as_arr()?
                    .iter()
                    .map(|v| v.as_f64())
                    .collect::<Result<_>>()?,
                missed_rounds: e
                    .get("missed_rounds")?
                    .as_arr()?
                    .iter()
                    .map(|v| Ok(v.as_u64()? as u32))
                    .collect::<Result<_>>()?,
                cooldown: e.get("cooldown")?.as_u64()? as u32,
                invocations: e.get("invocations")?.as_u64()? as u32,
                successes: e.get("successes")?.as_u64()? as u32,
            };
            map.insert(id, h);
        }
        Ok(Self { map })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rookie_until_first_invocation() {
        let mut db = HistoryStore::new();
        assert!(db.get(1).is_rookie());
        db.record_invocation(1);
        assert!(!db.get(1).is_rookie());
    }

    #[test]
    fn eq1_cooldown_progression() {
        let mut db = HistoryStore::new();
        db.record_failure(1, 2);
        assert_eq!(db.get(1).cooldown, 1); // 0 -> 1
        db.record_failure(1, 4);
        assert_eq!(db.get(1).cooldown, 2); // *2
        db.record_failure(1, 5);
        assert_eq!(db.get(1).cooldown, 4); // *2
        db.record_success(1, 6, 12.0);
        assert_eq!(db.get(1).cooldown, 0); // completed in time
    }

    #[test]
    fn missed_rounds_tracked_and_corrected() {
        let mut db = HistoryStore::new();
        db.record_failure(7, 3);
        db.record_failure(7, 5);
        assert_eq!(db.get(7).missed_rounds, vec![3, 5]);
        // slow update for round 3 arrives later: client corrects itself
        db.record_late_completion(7, 3, 40.0);
        assert_eq!(db.get(7).missed_rounds, vec![5]);
        assert_eq!(db.get(7).training_times, vec![40.0]);
        // cooldown untouched by a late completion (only on-time resets)
        assert_eq!(db.get(7).cooldown, 2);
    }

    #[test]
    fn duplicate_failure_same_round_counted_once() {
        let mut db = HistoryStore::new();
        db.record_failure(1, 3);
        db.record_failure(1, 3);
        assert_eq!(db.get(1).missed_rounds, vec![3]);
    }

    #[test]
    fn tick_decays_but_spares_fresh_failures() {
        let mut db = HistoryStore::new();
        db.record_failure(1, 1); // cooldown 1
        db.record_failure(2, 1);
        db.record_failure(2, 2); // cooldown 2, failed in round 2
        db.tick_cooldowns(&[2]);
        assert_eq!(db.get(1).cooldown, 0);
        assert_eq!(db.get(2).cooldown, 2);
        db.tick_cooldowns(&[]);
        assert_eq!(db.get(2).cooldown, 1);
    }

    #[test]
    fn tick_handles_duplicate_failed_ids() {
        let mut db = HistoryStore::new();
        db.record_failure(1, 0); // cooldown 1
        db.record_failure(2, 0);
        db.record_failure(2, 1); // cooldown 2, fresh failure
        // duplicate ids in the failed list must behave like a single entry
        db.tick_cooldowns(&[2, 2, 2]);
        assert_eq!(db.get(1).cooldown, 0);
        assert_eq!(db.get(2).cooldown, 2);
        db.tick_cooldowns(&[]);
        assert_eq!(db.get(2).cooldown, 1);
    }

    #[test]
    fn straggler_flag_follows_cooldown() {
        let mut db = HistoryStore::new();
        db.record_failure(1, 1);
        assert!(db.get(1).is_straggler());
        db.tick_cooldowns(&[]);
        assert!(!db.get(1).is_straggler());
    }

    #[test]
    fn save_load_roundtrip() {
        let mut db = HistoryStore::new();
        db.record_invocation(1);
        db.record_success(1, 0, 5.0);
        db.record_failure(2, 0);
        let path = std::env::temp_dir().join(format!("fedless-hist-{}.json", std::process::id()));
        db.save(&path).unwrap();
        let db2 = HistoryStore::load(&path).unwrap();
        assert_eq!(db.get(1), db2.get(1));
        assert_eq!(db.get(2), db2.get(2));
        std::fs::remove_file(&path).ok();
    }
}
