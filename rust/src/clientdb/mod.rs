//! Client-history database (the paper's MongoDB "client history
//! collection", §IV-A) — behavioural data per client: training times,
//! missed rounds and the cooldown counter of Eq. 1.
//!
//! Update semantics follow Algorithm 1 exactly:
//!
//! * controller, on success: cooldown := 0, record training time;
//! * controller, on failure: append the round to the missed-round window
//!   and apply Eq. 1 (`0 -> 1`, else `*2`);
//! * client, on late completion (a "slow update" arriving after the
//!   round): remove the round from the missed window and record the time
//!   — distinguishing *slow* from *crashed* is done on the client side
//!   (§V-B).
//!
//! The paper describes cooldown as "the number of rounds a client has to
//! stay in the last tier" (§V-B); Algorithm 1 only shows the growth rule,
//! so this implementation also ticks the counter down by one at the end
//! of every round in which the client did not fail again — without the
//! tick a client that is never re-invoked would remain a straggler
//! forever, contradicting §V-A ("tier-3 clients can move to tier-2 and
//! vice-versa").
//!
//! ## Bounded memory
//!
//! A [`ClientHistory`] is **O([`HISTORY_WINDOW`]) regardless of round
//! count**. The seed kept every training time and missed round in
//! unbounded vectors — O(rounds) per client, which a fleet of 100k+
//! clients cannot afford — and recomputed behaviour features from the
//! full series each selection. This version keeps:
//!
//! * a running EMA of training times at [`HISTORY_EMA_ALPHA`], updated
//!   incrementally on every recorded time. The incremental update
//!   `ema' = α·t + (1−α)·ema` performs *exactly* the fold
//!   [`crate::strategy::ema`] performs over the full series, so for the
//!   default strategy α the cached value is bit-identical to the
//!   unbounded computation at any history length (pinned by the
//!   property suite);
//! * running count/sum summaries (`times_count`, `training_mean`);
//! * two bounded recency windows — the last [`HISTORY_WINDOW`]
//!   training times (for features at a non-default α) and the last
//!   [`HISTORY_WINDOW`] uncorrected missed rounds (the missed-round
//!   feature depends on the *current* round at query time, so it is a
//!   windowed fold, exact whenever a client has ≤ window misses).
//!   Deliberately `Vec`-backed rather than a ring: eviction shifts at
//!   most window elements (a bounded constant, a few cache lines) in
//!   exchange for contiguous zero-copy slice reads on every feature
//!   fold, which is the hot direction. Late-completion corrections
//!   always target a round within the staleness cutoff τ ≪ window, so
//!   a correction never chases an entry that was already evicted.
//!
//! Hot paths read through [`HistoryStore::view`], which returns a
//! reference (the seed's `get()` cloned the whole record per lookup —
//! O(rounds) per client per selection).

use std::collections::{HashMap, HashSet};
use std::path::Path;

use crate::util::Json;
use crate::{ClientId, Result};

/// Recency window per client: both windows hold at most this many
/// entries, bounding per-client memory regardless of experiment length.
/// Must comfortably exceed the staleness cutoff τ (≤ 4 in every preset)
/// so late-completion corrections always find their missed-round entry.
/// Sized above the longest in-repo experiment (~50 rounds under the
/// full-profile convergence runs), so a windowed feature fold is a
/// full-series fold for every shipped configuration — including the
/// `ema_alpha` 0.1/0.9 ablations, which bypass the cached-EMA fast
/// path.
pub const HISTORY_WINDOW: usize = 64;

/// Smoothing factor of the incrementally-maintained training-time EMA.
/// Matches the default `FedLesScanParams::ema_alpha` and SAFA-lite's
/// fixed α, so the shipped strategies read the exact cached value;
/// features at any other α fold over the recency window instead.
pub const HISTORY_EMA_ALPHA: f64 = 0.5;

/// Behavioural record for one client (§V-B), bounded at
/// O([`HISTORY_WINDOW`]) memory.
#[derive(Debug, Clone, PartialEq)]
pub struct ClientHistory {
    /// Running EMA of recorded training times at [`HISTORY_EMA_ALPHA`]
    /// (bit-identical to folding the full series; 0 until a time lands).
    t_ema: f64,
    /// Running sum of recorded training times (for the mean).
    t_sum: f64,
    /// Total training times ever recorded (on-time successes plus
    /// credited late completions).
    times_count: u32,
    /// Last ≤ [`HISTORY_WINDOW`] recorded training times, oldest first.
    recent_times: Vec<f64>,
    /// Last ≤ [`HISTORY_WINDOW`] uncorrected missed rounds, oldest
    /// first.
    missed_recent: Vec<u32>,
    /// Misses evicted from the window (still uncorrected); total misses
    /// = `missed_evicted + missed_recent.len()`.
    missed_evicted: u32,
    /// Eq. 1 counter: > 0 means tier-3 (straggler).
    pub cooldown: u32,
    /// Total controller invocations.
    pub invocations: u32,
    /// On-time completions.
    pub successes: u32,
    /// Last behaviour-feature row `(trainingEma, missedRoundEma)` the
    /// selection layer clustered this client under, persisted so a
    /// reloaded store can report where the client sat (§IV-A keeps the
    /// clustering inputs in the client DB). Written by
    /// [`HistoryStore::note_cluster`]; never read by the selection hot
    /// path itself.
    last_feature: Option<(f64, f64)>,
    /// Grid cell key of `last_feature` on the frozen-ε behaviour grid
    /// (`None` when the incremental engine was not active, e.g. the
    /// degenerate all-identical geometry).
    last_cell: Option<(i64, i64)>,
    /// Standing cluster assignment from the last selection that touched
    /// this client (`-1` = outlier pseudo-cluster).
    last_cluster: Option<i64>,
}

impl Default for ClientHistory {
    fn default() -> Self {
        Self::empty()
    }
}

impl ClientHistory {
    /// The never-invoked record (also the [`HistoryStore::view`]
    /// default). `const` so a static empty instance can back the
    /// zero-allocation view of unknown clients.
    pub const fn empty() -> Self {
        Self {
            t_ema: 0.0,
            t_sum: 0.0,
            times_count: 0,
            recent_times: Vec::new(),
            missed_recent: Vec::new(),
            missed_evicted: 0,
            cooldown: 0,
            invocations: 0,
            successes: 0,
            last_feature: None,
            last_cell: None,
            last_cluster: None,
        }
    }

    /// Last clustered feature row, if any selection recorded one.
    pub fn last_feature(&self) -> Option<(f64, f64)> {
        self.last_feature
    }

    /// Grid cell of the last clustered feature row, if the incremental
    /// engine was active.
    pub fn last_cell(&self) -> Option<(i64, i64)> {
        self.last_cell
    }

    /// Standing cluster assignment from the last selection.
    pub fn last_cluster(&self) -> Option<i64> {
        self.last_cluster
    }

    /// A rookie has never been invoked (§V-A tier 1).
    pub fn is_rookie(&self) -> bool {
        self.invocations == 0
    }

    /// Tier-3 test (§V-A): any live cooldown marks a straggler.
    pub fn is_straggler(&self) -> bool {
        self.cooldown > 0
    }

    /// Cached training-time EMA at [`HISTORY_EMA_ALPHA`]; 0.0 before
    /// the first recorded time (mirroring `ema(&[], _)`).
    pub fn training_time_ema(&self) -> f64 {
        self.t_ema
    }

    /// Mean recorded training time (0.0 before the first).
    pub fn training_mean(&self) -> f64 {
        if self.times_count == 0 {
            0.0
        } else {
            self.t_sum / self.times_count as f64
        }
    }

    /// Total training times ever recorded (on-time + credited late).
    pub fn times_count(&self) -> u32 {
        self.times_count
    }

    /// Recency window of recorded training times, oldest first.
    pub fn recent_times(&self) -> &[f64] {
        &self.recent_times
    }

    /// Recency window of still-uncorrected missed rounds, oldest first.
    pub fn missed_recent(&self) -> &[u32] {
        &self.missed_recent
    }

    /// Total uncorrected misses, including entries evicted from the
    /// window.
    pub fn missed_total(&self) -> u32 {
        self.missed_evicted + self.missed_recent.len() as u32
    }

    /// Record one training time: incremental EMA + running sums + the
    /// recency window (evicting the oldest entry beyond the window).
    fn note_time(&mut self, t: f64) {
        self.t_ema = if self.times_count == 0 {
            t
        } else {
            HISTORY_EMA_ALPHA * t + (1.0 - HISTORY_EMA_ALPHA) * self.t_ema
        };
        self.t_sum += t;
        self.times_count += 1;
        if self.recent_times.len() == HISTORY_WINDOW {
            self.recent_times.remove(0);
        }
        self.recent_times.push(t);
    }

    /// Record a missed round in the window (evicting the oldest
    /// still-uncorrected miss beyond the window).
    fn note_miss(&mut self, round: u32) {
        if self.missed_recent.contains(&round) {
            return;
        }
        if self.missed_recent.len() == HISTORY_WINDOW {
            self.missed_recent.remove(0);
            self.missed_evicted += 1;
        }
        self.missed_recent.push(round);
    }

    /// Client-side correction: un-miss `round` if it is still in the
    /// window (corrections target rounds within τ ≪ window, so an
    /// evicted entry is unreachable by construction).
    fn unmiss(&mut self, round: u32) {
        self.missed_recent.retain(|&r| r != round);
    }
}

/// In-memory history store with JSON snapshot persistence.
///
/// ## Dirty-set contract (incremental selection)
///
/// Every behaviour-mutating operation appends the client id to an
/// internal **dirty log** (deduplicated — an id appears at most once
/// until the log is truncated past it). A consumer reads the suffix it
/// has not seen via [`dirty_since`] with a cursor it keeps, making
/// "who changed since my last selection" an O(changed) read instead of
/// an O(n) fleet rescan. The coordinator truncates the consumed prefix
/// after each selection ([`truncate_dirty`]) so the log stays
/// O(changed-since-last-round). [`note_cluster`] is deliberately *not*
/// a dirtying write: it records the selection layer's own output, and
/// marking it dirty would make every selection invalidate itself.
///
/// [`dirty_since`]: HistoryStore::dirty_since
/// [`truncate_dirty`]: HistoryStore::truncate_dirty
/// [`note_cluster`]: HistoryStore::note_cluster
#[derive(Debug, Default, Clone)]
pub struct HistoryStore {
    map: HashMap<ClientId, ClientHistory>,
    /// Ids currently present in `dirty_log` (the append dedupe).
    dirty_pending: HashSet<ClientId>,
    /// Dirty ids in first-touch order; absolute position = index +
    /// `dirty_base`.
    dirty_log: Vec<ClientId>,
    /// Absolute position of `dirty_log[0]` (grows on truncation, so
    /// consumer cursors survive compaction).
    dirty_base: u64,
    /// Clients with ≥ 1 still-uncorrected miss in the recency window.
    /// The missed-round feature (§V-C) decays with the current round,
    /// so exactly these clients drift every round *without* any new
    /// event — the incremental consumer unions them into its dirty set
    /// on round advance.
    missed_ids: HashSet<ClientId>,
}

/// Zero-allocation default for [`HistoryStore::view`] lookups of
/// never-seen clients.
static EMPTY_HISTORY: ClientHistory = ClientHistory::empty();

impl HistoryStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// Owned copy of a client's record (the empty record for unknown
    /// ids). Convenient for tests and reports; hot paths use [`view`]
    /// to avoid the clone.
    ///
    /// [`view`]: HistoryStore::view
    pub fn get(&self, id: ClientId) -> ClientHistory {
        self.map.get(&id).cloned().unwrap_or_default()
    }

    /// Borrowed view of a client's record; unknown ids read as the
    /// static empty record. This is the per-client hot-path lookup —
    /// no clone, no allocation.
    pub fn view(&self, id: ClientId) -> &ClientHistory {
        self.map.get(&id).unwrap_or(&EMPTY_HISTORY)
    }

    pub fn get_ref(&self, id: ClientId) -> Option<&ClientHistory> {
        self.map.get(&id)
    }

    fn entry(&mut self, id: ClientId) -> &mut ClientHistory {
        self.map.entry(id).or_default()
    }

    /// Append to the dirty log (at most once per id until truncation).
    fn mark_dirty(&mut self, id: ClientId) {
        if self.dirty_pending.insert(id) {
            self.dirty_log.push(id);
        }
    }

    /// Controller marked this client as invoked this round.
    pub fn record_invocation(&mut self, id: ClientId) {
        self.entry(id).invocations += 1;
        self.mark_dirty(id);
    }

    /// On-time completion (Algorithm 1 lines 5-8 + client lines 22-27).
    pub fn record_success(&mut self, id: ClientId, round: u32, training_time: f64) {
        let h = self.entry(id);
        h.cooldown = 0;
        h.successes += 1;
        h.note_time(training_time);
        h.unmiss(round);
        if h.missed_recent.is_empty() {
            self.missed_ids.remove(&id);
        }
        self.mark_dirty(id);
    }

    /// Missed round (Algorithm 1 lines 9-13): Eq. 1 growth.
    pub fn record_failure(&mut self, id: ClientId, round: u32) {
        let h = self.entry(id);
        h.note_miss(round);
        h.cooldown = if h.cooldown == 0 { 1 } else { h.cooldown * 2 };
        self.missed_ids.insert(id);
        self.mark_dirty(id);
    }

    /// Late ("slow") update arrived after its round finished — the client
    /// corrects its own record (§V-B): un-miss the round, record the time.
    pub fn record_late_completion(&mut self, id: ClientId, round: u32, training_time: f64) {
        let h = self.entry(id);
        h.unmiss(round);
        h.note_time(training_time);
        if h.missed_recent.is_empty() {
            self.missed_ids.remove(&id);
        }
        self.mark_dirty(id);
    }

    /// End-of-round tick: cooldowns decay by one except for clients that
    /// failed *this* round (their Eq. 1 value is fresh). The failed list
    /// is hashed once up front so the tick is O(clients + failed) rather
    /// than O(clients * failed); duplicate ids in the list are harmless.
    /// Only clients whose cooldown actually moved are marked dirty (a
    /// decayed cooldown can change the rookie/participant/straggler
    /// tier), so an all-healthy fleet ticks without dirtying anyone.
    pub fn tick_cooldowns(&mut self, failed_this_round: &[ClientId]) {
        let failed: HashSet<ClientId> = failed_this_round.iter().copied().collect();
        let mut decayed: Vec<ClientId> = Vec::new();
        for (id, h) in self.map.iter_mut() {
            if h.cooldown > 0 && !failed.contains(id) {
                h.cooldown -= 1;
                decayed.push(*id);
            }
        }
        for id in decayed {
            self.mark_dirty(id);
        }
    }

    /// The dirty-log suffix at absolute positions ≥ `cursor`, plus the
    /// cursor to pass next time (= current end of the log). Ids appear
    /// in first-touch order, each at most once. A cursor older than the
    /// truncated prefix clamps to the log start (the consumer just sees
    /// ids it may have already processed — a refresh no-op).
    pub fn dirty_since(&self, cursor: u64) -> (&[ClientId], u64) {
        let start = cursor.saturating_sub(self.dirty_base).min(self.dirty_log.len() as u64);
        (
            &self.dirty_log[start as usize..],
            self.dirty_base + self.dirty_log.len() as u64,
        )
    }

    /// Drop the dirty-log prefix below absolute position `cursor` —
    /// called by the coordinator once its (single) selection consumer
    /// has read up to `cursor`, keeping the log O(changed-per-round).
    pub fn truncate_dirty(&mut self, cursor: u64) {
        let n = cursor.saturating_sub(self.dirty_base).min(self.dirty_log.len() as u64) as usize;
        if n == 0 {
            return;
        }
        for id in self.dirty_log.drain(..n) {
            self.dirty_pending.remove(&id);
        }
        self.dirty_base += n as u64;
    }

    /// Clients with at least one still-uncorrected miss in the window —
    /// exactly the records whose missed-round feature drifts on every
    /// round advance with no new event (see the struct docs).
    pub fn clients_with_misses(&self) -> &HashSet<ClientId> {
        &self.missed_ids
    }

    /// Record the selection layer's clustering outcome for a client:
    /// feature row, grid cell (when the incremental engine is active),
    /// and standing cluster id. **Not** a dirtying write — this is the
    /// cluster plane's own output flowing back into the client DB
    /// (§IV-A), not new client behaviour.
    pub fn note_cluster(
        &mut self,
        id: ClientId,
        feature: (f64, f64),
        cell: Option<(i64, i64)>,
        cluster: i64,
    ) {
        let h = self.entry(id);
        h.last_feature = Some(feature);
        h.last_cell = cell;
        h.last_cluster = Some(cluster);
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&ClientId, &ClientHistory)> {
        self.map.iter()
    }

    /// Snapshot to JSON (the paper's DB persistence stand-in). The
    /// schema mirrors the bounded record: summary scalars plus the two
    /// recency windows — O(window) per client on disk too.
    pub fn save(&self, path: &Path) -> Result<()> {
        let entries: Vec<Json> = self
            .map
            .iter()
            .map(|(id, h)| {
                let mut fields = vec![
                    ("client", Json::num(*id as f64)),
                    ("t_ema", Json::num(h.t_ema)),
                    ("t_sum", Json::num(h.t_sum)),
                    ("times_count", Json::num(h.times_count as f64)),
                    ("recent_times", Json::from_f64_slice(&h.recent_times)),
                    (
                        "missed_recent",
                        Json::Arr(h.missed_recent.iter().map(|&r| Json::num(r as f64)).collect()),
                    ),
                    ("missed_evicted", Json::num(h.missed_evicted as f64)),
                    ("cooldown", Json::num(h.cooldown as f64)),
                    ("invocations", Json::num(h.invocations as f64)),
                    ("successes", Json::num(h.successes as f64)),
                ];
                // cluster snapshot: written only when present, so
                // snapshots from non-incremental runs stay byte-stable
                if let Some((t, m)) = h.last_feature {
                    fields.push(("last_feature", Json::from_f64_slice(&[t, m])));
                }
                if let Some((cx, cy)) = h.last_cell {
                    fields.push((
                        "last_cell",
                        Json::Arr(vec![Json::num(cx as f64), Json::num(cy as f64)]),
                    ));
                }
                if let Some(c) = h.last_cluster {
                    fields.push(("last_cluster", Json::num(c as f64)));
                }
                Json::obj(fields)
            })
            .collect();
        Json::obj(vec![("clients", Json::Arr(entries))]).write_file(path)
    }

    pub fn load(path: &Path) -> Result<Self> {
        let root = Json::parse_file(path)?;
        let mut map = HashMap::new();
        for e in root.get("clients")?.as_arr()? {
            let id = e.get("client")?.as_usize()?;
            let mut h = if e.get("t_ema").is_ok() {
                ClientHistory {
                    t_ema: e.get("t_ema")?.as_f64()?,
                    t_sum: e.get("t_sum")?.as_f64()?,
                    times_count: e.get("times_count")?.as_u64()? as u32,
                    recent_times: e
                        .get("recent_times")?
                        .as_arr()?
                        .iter()
                        .map(|v| v.as_f64())
                        .collect::<Result<_>>()?,
                    missed_recent: e
                        .get("missed_recent")?
                        .as_arr()?
                        .iter()
                        .map(|v| Ok(v.as_u64()? as u32))
                        .collect::<Result<_>>()?,
                    missed_evicted: e.get("missed_evicted")?.as_u64()? as u32,
                    cooldown: e.get("cooldown")?.as_u64()? as u32,
                    invocations: e.get("invocations")?.as_u64()? as u32,
                    successes: e.get("successes")?.as_u64()? as u32,
                }
            } else {
                // Legacy (pre-bounded) snapshot: unbounded
                // `training_times` / `missed_rounds` vectors. Replay
                // them through the summary updates so old artifacts
                // keep loading instead of erroring on a missing key.
                let mut h = ClientHistory {
                    cooldown: e.get("cooldown")?.as_u64()? as u32,
                    invocations: e.get("invocations")?.as_u64()? as u32,
                    successes: e.get("successes")?.as_u64()? as u32,
                    ..ClientHistory::empty()
                };
                for v in e.get("training_times")?.as_arr()? {
                    h.note_time(v.as_f64()?);
                }
                for v in e.get("missed_rounds")?.as_arr()? {
                    h.note_miss(v.as_u64()? as u32);
                }
                h
            };
            // optional cluster snapshot (absent in legacy and
            // non-incremental artifacts)
            if let Ok(v) = e.get("last_feature") {
                let a = v.as_arr()?;
                if a.len() == 2 {
                    h.last_feature = Some((a[0].as_f64()?, a[1].as_f64()?));
                }
            }
            if let Ok(v) = e.get("last_cell") {
                let a = v.as_arr()?;
                if a.len() == 2 {
                    h.last_cell = Some((a[0].as_f64()? as i64, a[1].as_f64()? as i64));
                }
            }
            if let Ok(v) = e.get("last_cluster") {
                h.last_cluster = Some(v.as_f64()? as i64);
            }
            map.insert(id, h);
        }
        let missed_ids = map
            .iter()
            .filter(|(_, h)| !h.missed_recent.is_empty())
            .map(|(&id, _)| id)
            .collect();
        Ok(Self {
            map,
            missed_ids,
            ..Self::default()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rookie_until_first_invocation() {
        let mut db = HistoryStore::new();
        assert!(db.get(1).is_rookie());
        db.record_invocation(1);
        assert!(!db.get(1).is_rookie());
    }

    #[test]
    fn eq1_cooldown_progression() {
        let mut db = HistoryStore::new();
        db.record_failure(1, 2);
        assert_eq!(db.get(1).cooldown, 1); // 0 -> 1
        db.record_failure(1, 4);
        assert_eq!(db.get(1).cooldown, 2); // *2
        db.record_failure(1, 5);
        assert_eq!(db.get(1).cooldown, 4); // *2
        db.record_success(1, 6, 12.0);
        assert_eq!(db.get(1).cooldown, 0); // completed in time
    }

    #[test]
    fn missed_rounds_tracked_and_corrected() {
        let mut db = HistoryStore::new();
        db.record_failure(7, 3);
        db.record_failure(7, 5);
        assert_eq!(db.get(7).missed_recent(), &[3, 5]);
        assert_eq!(db.get(7).missed_total(), 2);
        // slow update for round 3 arrives later: client corrects itself
        db.record_late_completion(7, 3, 40.0);
        assert_eq!(db.get(7).missed_recent(), &[5]);
        assert_eq!(db.get(7).recent_times(), &[40.0]);
        assert_eq!(db.get(7).times_count(), 1);
        // cooldown untouched by a late completion (only on-time resets)
        assert_eq!(db.get(7).cooldown, 2);
    }

    #[test]
    fn duplicate_failure_same_round_counted_once() {
        let mut db = HistoryStore::new();
        db.record_failure(1, 3);
        db.record_failure(1, 3);
        assert_eq!(db.get(1).missed_recent(), &[3]);
        assert_eq!(db.get(1).missed_total(), 1);
    }

    #[test]
    fn tick_decays_but_spares_fresh_failures() {
        let mut db = HistoryStore::new();
        db.record_failure(1, 1); // cooldown 1
        db.record_failure(2, 1);
        db.record_failure(2, 2); // cooldown 2, failed in round 2
        db.tick_cooldowns(&[2]);
        assert_eq!(db.get(1).cooldown, 0);
        assert_eq!(db.get(2).cooldown, 2);
        db.tick_cooldowns(&[]);
        assert_eq!(db.get(2).cooldown, 1);
    }

    #[test]
    fn tick_handles_duplicate_failed_ids() {
        let mut db = HistoryStore::new();
        db.record_failure(1, 0); // cooldown 1
        db.record_failure(2, 0);
        db.record_failure(2, 1); // cooldown 2, fresh failure
        // duplicate ids in the failed list must behave like a single entry
        db.tick_cooldowns(&[2, 2, 2]);
        assert_eq!(db.get(1).cooldown, 0);
        assert_eq!(db.get(2).cooldown, 2);
        db.tick_cooldowns(&[]);
        assert_eq!(db.get(2).cooldown, 1);
    }

    #[test]
    fn straggler_flag_follows_cooldown() {
        let mut db = HistoryStore::new();
        db.record_failure(1, 1);
        assert!(db.get(1).is_straggler());
        db.tick_cooldowns(&[]);
        assert!(!db.get(1).is_straggler());
    }

    #[test]
    fn view_is_borrowed_and_defaults_empty() {
        let mut db = HistoryStore::new();
        assert!(db.view(99).is_rookie());
        assert_eq!(db.view(99).times_count(), 0);
        db.record_invocation(5);
        db.record_success(5, 0, 7.0);
        assert_eq!(db.view(5).training_time_ema(), 7.0);
        // view and get agree
        assert_eq!(*db.view(5), db.get(5));
    }

    #[test]
    fn incremental_ema_matches_full_series_fold() {
        // The cached EMA must perform exactly the fold `strategy::ema`
        // performs over the unbounded series — seed with the first
        // value, then α·x + (1−α)·acc — at any length, including far
        // past the recency window.
        let mut db = HistoryStore::new();
        let mut series: Vec<f64> = Vec::new();
        for i in 0..200u32 {
            let t = 5.0 + ((i * 37) % 97) as f64 * 0.5;
            db.record_success(1, i, t);
            series.push(t);
            let mut oracle = series[0];
            for &x in &series[1..] {
                oracle = HISTORY_EMA_ALPHA * x + (1.0 - HISTORY_EMA_ALPHA) * oracle;
            }
            assert_eq!(db.view(1).training_time_ema().to_bits(), oracle.to_bits());
        }
    }

    #[test]
    fn memory_is_bounded_by_the_window() {
        // O(window) regardless of round count: thousands of recorded
        // events never grow either ring past HISTORY_WINDOW, while the
        // running summaries keep full-series accuracy.
        let mut db = HistoryStore::new();
        let rounds = 10_000u32;
        for r in 0..rounds {
            db.record_invocation(1);
            if r % 3 == 0 {
                db.record_failure(1, r);
            } else {
                db.record_success(1, r, 10.0 + (r % 7) as f64);
            }
        }
        let h = db.get(1);
        assert!(h.recent_times().len() <= HISTORY_WINDOW);
        assert!(h.missed_recent().len() <= HISTORY_WINDOW);
        assert_eq!(h.invocations, rounds);
        let expected_misses = rounds.div_ceil(3);
        assert_eq!(h.missed_total(), expected_misses);
        assert_eq!(h.times_count(), rounds - expected_misses);
        let mean = h.training_mean();
        assert!((10.0..=16.0).contains(&mean), "mean {mean}");
    }

    #[test]
    fn load_accepts_legacy_unbounded_snapshots() {
        // Snapshots written before the bounded-history refactor carry
        // `training_times` / `missed_rounds` vectors; load must replay
        // them into the summary form, not error on the missing keys.
        let legacy = Json::obj(vec![(
            "clients",
            Json::Arr(vec![Json::obj(vec![
                ("client", Json::num(4.0)),
                ("training_times", Json::from_f64_slice(&[5.0, 9.0, 7.0])),
                (
                    "missed_rounds",
                    Json::Arr(vec![Json::num(2.0), Json::num(6.0)]),
                ),
                ("cooldown", Json::num(2.0)),
                ("invocations", Json::num(5.0)),
                ("successes", Json::num(3.0)),
            ])]),
        )]);
        let path = std::env::temp_dir().join(format!("fedless-leg-{}.json", std::process::id()));
        legacy.write_file(&path).unwrap();
        let db = HistoryStore::load(&path).unwrap();
        std::fs::remove_file(&path).ok();

        // oracle: replay the same events through the live API
        let mut want = HistoryStore::new();
        for _ in 0..5 {
            want.record_invocation(4);
        }
        let w = want.entry(4);
        w.successes = 3;
        w.note_time(5.0);
        w.note_time(9.0);
        w.note_time(7.0);
        w.note_miss(2);
        w.note_miss(6);
        w.cooldown = 2;
        assert_eq!(db.get(4), want.get(4));
        assert_eq!(db.view(4).times_count(), 3);
        assert_eq!(db.view(4).missed_recent(), &[2, 6]);
    }

    #[test]
    fn dirty_log_tracks_touched_clients_once() {
        let mut db = HistoryStore::new();
        let (d, c0) = db.dirty_since(0);
        assert!(d.is_empty());
        assert_eq!(c0, 0);
        db.record_invocation(3);
        db.record_success(3, 0, 5.0); // same id: still one entry
        db.record_invocation(7);
        let (d, c1) = db.dirty_since(0);
        assert_eq!(d, &[3, 7], "first-touch order, deduped");
        // a later reader from the cursor sees only newer dirt
        db.record_failure(9, 1);
        let (d, c2) = db.dirty_since(c1);
        assert_eq!(d, &[9]);
        // truncating the consumed prefix keeps cursors valid
        db.truncate_dirty(c1);
        let (d, _) = db.dirty_since(c1);
        assert_eq!(d, &[9]);
        // a re-touch after truncation re-enters the log
        db.record_invocation(3);
        let (d, _) = db.dirty_since(c2);
        assert_eq!(d, &[3]);
        // stale cursor (before the truncated prefix) clamps, no panic
        let (d, _) = db.dirty_since(0);
        assert_eq!(d, &[9, 3]);
    }

    #[test]
    fn tick_dirties_only_decayed_cooldowns() {
        let mut db = HistoryStore::new();
        db.record_invocation(1);
        db.record_failure(2, 0); // cooldown 1
        let (_, cur) = db.dirty_since(0);
        db.tick_cooldowns(&[]); // 2 decays to 0; 1 untouched
        let (d, cur) = db.dirty_since(cur);
        assert_eq!(d, &[2]);
        db.tick_cooldowns(&[]); // nobody has a live cooldown left
        let (d, _) = db.dirty_since(cur);
        assert!(d.is_empty(), "healthy fleet ticks dirty no one");
    }

    #[test]
    fn missed_ids_follow_the_miss_window() {
        let mut db = HistoryStore::new();
        assert!(db.clients_with_misses().is_empty());
        db.record_failure(4, 2);
        db.record_failure(4, 3);
        db.record_failure(5, 2);
        assert_eq!(db.clients_with_misses().len(), 2);
        // correcting one of two misses keeps the client listed
        db.record_late_completion(4, 2, 9.0);
        assert!(db.clients_with_misses().contains(&4));
        // correcting the last one drops it
        db.record_late_completion(4, 3, 9.0);
        assert!(!db.clients_with_misses().contains(&4));
        // an on-time success for the missed round clears it too
        db.record_success(5, 2, 7.0);
        assert!(db.clients_with_misses().is_empty());
    }

    #[test]
    fn note_cluster_persists_without_dirtying() {
        let mut db = HistoryStore::new();
        db.record_invocation(6);
        let (_, cur) = db.dirty_since(0);
        db.note_cluster(6, (12.5, 0.25), Some((3, -1)), 2);
        let (d, _) = db.dirty_since(cur);
        assert!(d.is_empty(), "note_cluster is not a dirtying write");
        assert_eq!(db.view(6).last_feature(), Some((12.5, 0.25)));
        assert_eq!(db.view(6).last_cell(), Some((3, -1)));
        assert_eq!(db.view(6).last_cluster(), Some(2));
        // and it round-trips through the snapshot
        let path =
            std::env::temp_dir().join(format!("fedless-note-{}.json", std::process::id()));
        db.save(&path).unwrap();
        let db2 = HistoryStore::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(db2.view(6).last_feature(), Some((12.5, 0.25)));
        assert_eq!(db2.view(6).last_cell(), Some((3, -1)));
        assert_eq!(db2.view(6).last_cluster(), Some(2));
        assert_eq!(db.get(6), db2.get(6));
    }

    #[test]
    fn load_rebuilds_missed_ids() {
        let mut db = HistoryStore::new();
        db.record_failure(8, 1);
        db.record_success(9, 1, 4.0);
        let path =
            std::env::temp_dir().join(format!("fedless-missed-{}.json", std::process::id()));
        db.save(&path).unwrap();
        let db2 = HistoryStore::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert!(db2.clients_with_misses().contains(&8));
        assert!(!db2.clients_with_misses().contains(&9));
    }

    #[test]
    fn save_load_roundtrip() {
        let mut db = HistoryStore::new();
        db.record_invocation(1);
        db.record_success(1, 0, 5.0);
        db.record_success(1, 1, 7.25);
        db.record_failure(2, 0);
        let path = std::env::temp_dir().join(format!("fedless-hist-{}.json", std::process::id()));
        db.save(&path).unwrap();
        let db2 = HistoryStore::load(&path).unwrap();
        assert_eq!(db.get(1), db2.get(1));
        assert_eq!(db.get(2), db2.get(2));
        std::fs::remove_file(&path).ok();
    }
}
