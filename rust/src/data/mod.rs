//! Synthetic federated datasets (substrate for the paper's four datasets).
//!
//! The paper's straggler experiments run on MNIST / FEMNIST / Shakespeare /
//! Google Speech with non-IID client partitions (§VI-A1). FedLesScan never
//! inspects sample *content* — only training time and success — so the
//! reproduction substitutes seeded synthetic datasets with the same tensor
//! shapes, class counts and partition skew (DESIGN.md §2):
//!
//! * image families: one Gaussian prototype per class plus per-sample
//!   noise — linearly separable enough that the LEAF CNNs actually learn,
//!   so accuracy/convergence comparisons between strategies stay
//!   meaningful;
//! * token families: uniform token sequences whose final token encodes the
//!   label (next-char-style objective).
//!
//! Partitions: `LabelShard` reproduces the paper's MNIST protocol (sort by
//! label, split into shards, two shards per client — each client sees very
//! few classes); `Dirichlet` and `Iid` are provided for ablations.
//!
//! Everything is deterministic in `(seed, client_id)` and synthesized on
//! demand, so 200-client experiments do not hold 200 shards in memory.

use crate::runtime::manifest::Manifest;
use crate::util::Rng;
use crate::Result;

/// Feature tensor for one shard: flat row-major `[n, sample_elems]`.
#[derive(Debug, Clone, PartialEq)]
pub enum Features {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Features {
    pub fn len(&self) -> usize {
        match self {
            Features::F32(v) => v.len(),
            Features::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> &'static str {
        match self {
            Features::F32(_) => "f32",
            Features::I32(_) => "i32",
        }
    }
}

/// One client's local shard (or the central eval set).
#[derive(Debug, Clone)]
pub struct ClientData {
    pub x: Features,
    pub y: Vec<i32>,
}

/// How labels are spread across clients.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Partition {
    /// Paper §VI-A1: sort by label, cut into shards, 2 shards per client.
    LabelShard,
    /// Uniform labels (sanity baseline / ablation).
    Iid,
    /// Per-client class distribution ~ Dirichlet(alpha) (ablation).
    Dirichlet(f64),
}

impl Default for Partition {
    fn default() -> Self {
        Partition::LabelShard
    }
}

/// Deterministic synthetic dataset generator for one model family.
pub struct SynthDataset {
    pub n_clients: usize,
    pub shard_size: usize,
    pub eval_size: usize,
    pub num_classes: usize,
    pub input_shape: Vec<usize>,
    pub is_tokens: bool,
    pub partition: Partition,
    seed: u64,
    /// class -> flat prototype (image families only)
    prototypes: Vec<Vec<f32>>,
    /// client -> per-sample labels (precomputed; ints only, cheap)
    labels: Vec<Vec<i32>>,
}

/// Noise scale around class prototypes: chosen so smoke-scale CNNs reach
/// high accuracy in a handful of rounds while leaving a learnable margin.
const NOISE: f32 = 0.3;
const PROTO_SCALE: f32 = 2.0;

impl SynthDataset {
    pub fn from_manifest(
        m: &Manifest,
        n_clients: usize,
        seed: u64,
        partition: Partition,
    ) -> Result<Self> {
        Self::new(
            n_clients,
            m.shard_size,
            m.eval_size,
            m.num_classes,
            m.input_shape.clone(),
            m.input_dtype == "i32",
            seed,
            partition,
        )
    }

    #[allow(clippy::too_many_arguments)]
    pub fn new(
        n_clients: usize,
        shard_size: usize,
        eval_size: usize,
        num_classes: usize,
        input_shape: Vec<usize>,
        is_tokens: bool,
        seed: u64,
        partition: Partition,
    ) -> Result<Self> {
        anyhow::ensure!(n_clients > 0, "need at least one client");
        anyhow::ensure!(num_classes > 1, "need at least two classes");
        let sample_elems: usize = input_shape.iter().product();
        anyhow::ensure!(sample_elems > 0, "empty input shape");

        let mut rng = Rng::seed_from_u64(seed ^ 0x5ed5_0bad);
        let prototypes = if is_tokens {
            Vec::new()
        } else {
            (0..num_classes)
                .map(|_| {
                    (0..sample_elems)
                        .map(|_| rng.normal() as f32 * PROTO_SCALE)
                        .collect()
                })
                .collect()
        };

        let labels = assign_labels(
            n_clients,
            shard_size,
            num_classes,
            partition,
            &mut Rng::seed_from_u64(seed ^ 0x9a27_1e11),
        );

        Ok(Self {
            n_clients,
            shard_size,
            eval_size,
            num_classes,
            input_shape,
            is_tokens,
            partition,
            seed,
            prototypes,
            labels,
        })
    }

    pub fn sample_elems(&self) -> usize {
        self.input_shape.iter().product()
    }

    /// Synthesize client `cid`'s local shard.
    pub fn client_data(&self, cid: usize) -> ClientData {
        assert!(cid < self.n_clients, "client {cid} out of range");
        let labels = &self.labels[cid];
        let mut rng = Rng::seed_from_u64(self.seed ^ (0xc11e_0000 + cid as u64));
        self.synthesize(labels, &mut rng)
    }

    /// Central evaluation set: class-balanced, disjoint RNG stream.
    pub fn eval_data(&self) -> ClientData {
        let labels: Vec<i32> = (0..self.eval_size)
            .map(|i| (i % self.num_classes) as i32)
            .collect();
        let mut rng = Rng::seed_from_u64(self.seed ^ 0xe7a1_0f5e);
        self.synthesize(&labels, &mut rng)
    }

    /// All clients have fixed-cardinality shards (the lowered HLO is
    /// shape-static); statistical heterogeneity is in the label skew.
    pub fn cardinality(&self, _cid: usize) -> usize {
        self.shard_size
    }

    /// Distinct labels present in a client's shard (used by tests and the
    /// heterogeneity report).
    pub fn client_label_set(&self, cid: usize) -> Vec<i32> {
        let mut set: Vec<i32> = self.labels[cid].clone();
        set.sort_unstable();
        set.dedup();
        set
    }

    fn synthesize(&self, labels: &[i32], rng: &mut Rng) -> ClientData {
        let d = self.sample_elems();
        if self.is_tokens {
            let mut x = Vec::with_capacity(labels.len() * d);
            for &y in labels {
                for j in 0..d {
                    if j == d - 1 {
                        x.push(y);
                    } else {
                        x.push(rng.range_i32(0, self.num_classes as i32));
                    }
                }
            }
            ClientData {
                x: Features::I32(x),
                y: labels.to_vec(),
            }
        } else {
            let mut x = Vec::with_capacity(labels.len() * d);
            for &y in labels {
                let proto = &self.prototypes[y as usize];
                for p in proto {
                    x.push(p + NOISE * rng.normal() as f32);
                }
            }
            ClientData {
                x: Features::F32(x),
                y: labels.to_vec(),
            }
        }
    }
}

/// Compute the per-client label lists for a partition scheme.
fn assign_labels(
    n_clients: usize,
    shard_size: usize,
    num_classes: usize,
    partition: Partition,
    rng: &mut Rng,
) -> Vec<Vec<i32>> {
    match partition {
        Partition::Iid => (0..n_clients)
            .map(|_| {
                (0..shard_size)
                    .map(|_| rng.range_i32(0, num_classes as i32))
                    .collect()
            })
            .collect(),
        Partition::LabelShard => {
            // Paper MNIST protocol: balanced global pool, sorted by label,
            // cut into 2*n_clients shards, each client draws two shards.
            let total = n_clients * shard_size;
            let mut pool: Vec<i32> = (0..total).map(|i| (i % num_classes) as i32).collect();
            pool.sort_unstable();
            let half = shard_size / 2;
            if half == 0 {
                // degenerate tiny shards: one shard per client
                let mut shards: Vec<Vec<i32>> =
                    pool.chunks(shard_size).map(|c| c.to_vec()).collect();
                rng.shuffle(&mut shards);
                shards.truncate(n_clients);
                return shards;
            }
            let mut shard_ids: Vec<usize> = (0..2 * n_clients).collect();
            rng.shuffle(&mut shard_ids);
            (0..n_clients)
                .map(|c| {
                    let mut lab = Vec::with_capacity(shard_size);
                    for s in [shard_ids[2 * c], shard_ids[2 * c + 1]] {
                        let start = s * half;
                        lab.extend_from_slice(&pool[start..start + half]);
                    }
                    // odd shard sizes: top up from the tail of the pool
                    while lab.len() < shard_size {
                        lab.push(pool[total - 1 - (lab.len() - 2 * half)]);
                    }
                    lab
                })
                .collect()
        }
        Partition::Dirichlet(alpha) => {
            let alpha = alpha.max(1e-3);
            (0..n_clients)
                .map(|_| {
                    let mut w: Vec<f64> =
                        (0..num_classes).map(|_| rng.gamma(alpha).max(1e-12)).collect();
                    let s: f64 = w.iter().sum();
                    w.iter_mut().for_each(|v| *v /= s);
                    // cumulative inverse sampling
                    let mut cdf = vec![0.0; num_classes];
                    let mut acc = 0.0;
                    for (i, v) in w.iter().enumerate() {
                        acc += v;
                        cdf[i] = acc;
                    }
                    (0..shard_size)
                        .map(|_| {
                            let u: f64 = rng.f64();
                            cdf.iter().position(|&c| u <= c).unwrap_or(num_classes - 1)
                                as i32
                        })
                        .collect()
                })
                .collect()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(partition: Partition) -> SynthDataset {
        SynthDataset::new(8, 20, 40, 10, vec![4, 4, 1], false, 7, partition).unwrap()
    }

    #[test]
    fn deterministic_given_seed() {
        let a = mk(Partition::LabelShard);
        let b = mk(Partition::LabelShard);
        assert_eq!(a.client_data(3).y, b.client_data(3).y);
        assert_eq!(a.client_data(3).x, b.client_data(3).x);
    }

    #[test]
    fn clients_differ() {
        let d = mk(Partition::Iid);
        assert_ne!(d.client_data(0).x, d.client_data(1).x);
    }

    #[test]
    fn shard_shapes() {
        let d = mk(Partition::LabelShard);
        let c = d.client_data(0);
        assert_eq!(c.y.len(), 20);
        assert_eq!(c.x.len(), 20 * 16);
    }

    #[test]
    fn label_shard_is_skewed() {
        // 2 shards of 10 same-ish labels each -> far fewer distinct
        // classes per client than IID.
        let d = mk(Partition::LabelShard);
        let max_classes = (0..8)
            .map(|c| d.client_label_set(c).len())
            .max()
            .unwrap();
        assert!(max_classes <= 4, "label shard too uniform: {max_classes}");
    }

    #[test]
    fn label_shard_covers_all_shards_once() {
        let d = mk(Partition::LabelShard);
        let mut all: Vec<i32> = (0..8).flat_map(|c| d.labels[c].clone()).collect();
        all.sort_unstable();
        let mut expect: Vec<i32> = (0..8 * 20).map(|i| (i % 10) as i32).collect();
        expect.sort_unstable();
        assert_eq!(all, expect);
    }

    #[test]
    fn dirichlet_labels_valid() {
        let d = mk(Partition::Dirichlet(0.1));
        for c in 0..8 {
            assert!(d.client_data(c).y.iter().all(|&y| (0..10).contains(&y)));
        }
    }

    #[test]
    fn eval_is_balanced() {
        let d = mk(Partition::LabelShard);
        let e = d.eval_data();
        let count0 = e.y.iter().filter(|&&y| y == 0).count();
        assert_eq!(count0, 4); // 40 / 10 classes
    }

    #[test]
    fn token_family_leaks_label_in_last_token() {
        let d = SynthDataset::new(4, 8, 16, 12, vec![5], true, 9, Partition::Iid).unwrap();
        let c = d.client_data(2);
        if let Features::I32(x) = &c.x {
            for (i, &y) in c.y.iter().enumerate() {
                assert_eq!(x[i * 5 + 4], y);
            }
        } else {
            panic!("token family must be i32");
        }
    }

    #[test]
    fn odd_shard_size_still_full() {
        let d = SynthDataset::new(4, 7, 16, 3, vec![2], false, 9, Partition::LabelShard)
            .unwrap();
        for c in 0..4 {
            assert_eq!(d.client_data(c).y.len(), 7);
        }
    }
}
