//! FedAvg with straggler dropping (SNIPPETS.md snippet 2, the Flower
//! `FedAvgWithStragglerDrop` baseline): invoke a uniform random cohort
//! exactly like FedAvg, but when the deadline passes, *discard* any
//! update that has not arrived — no staleness folding, no waiting out
//! the slowest client. The round ends at the last on-time arrival, so
//! rounds are fast; the cost ledger still bills the dropped functions
//! (they ran to timeout, §VI-C), which is precisely the time/cost
//! trade-off the grid is meant to expose.
//!
//! Selection and aggregation are byte-identical to FedAvg (same
//! `random_sample` draw stream, synchronous n_k/n weights); the only
//! behavioural difference is the [`Strategy::drops_stragglers`] hook
//! the coordinator consults when closing a round.

use super::{random_sample, Aggregation, SelectionContext, Strategy};
use crate::util::Rng;
use crate::ClientId;

pub struct FedAvgDrop;

impl Strategy for FedAvgDrop {
    fn name(&self) -> &'static str {
        "fedavgdrop"
    }

    fn select(&mut self, ctx: &SelectionContext, rng: &mut Rng) -> Vec<ClientId> {
        random_sample(ctx.all_clients, ctx.clients_per_round, rng)
    }

    fn drops_stragglers(&self) -> bool {
        true
    }

    fn aggregation(&self) -> Aggregation {
        Aggregation::Synchronous
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clientdb::HistoryStore;
    use crate::strategy::FedAvg;

    #[test]
    fn selection_matches_fedavg_draw_for_draw() {
        // Dropping happens at round close, not at selection: the cohort
        // must be exactly FedAvg's under the same seed.
        let clients: Vec<ClientId> = (0..40).collect();
        let hist = HistoryStore::new();
        let ctx = SelectionContext {
            round: 2,
            max_rounds: 10,
            clients_per_round: 10,
            all_clients: &clients,
            history: &hist,
        };
        let drop = FedAvgDrop.select(&ctx, &mut Rng::seed_from_u64(11));
        let avg = FedAvg.select(&ctx, &mut Rng::seed_from_u64(11));
        assert_eq!(drop, avg);
    }

    #[test]
    fn drop_semantics_flagged() {
        assert!(FedAvgDrop.drops_stragglers());
        assert!(!FedAvg.drops_stragglers());
        assert_eq!(FedAvgDrop.aggregation(), Aggregation::Synchronous);
    }
}
