//! Apodotiko-style scoring-based probabilistic selection (Elzohairy et
//! al., arXiv 2404.14033): the strongest modern baseline for
//! heterogeneous serverless FL. Each client gets a score blending
//! speed (inverse EMA training time), reliability (on-time success
//! rate) and freshness (exploration bonus decaying with invocation
//! count); selection is softmax sampling over those scores, so fast
//! reliable clients are *preferred* rather than guaranteed — the
//! probabilistic margin is what keeps the invocation distribution
//! flatter (lower Bias) than SAFA's greedy fastest-first.
//!
//! Everything is computed from the bounded O(1) `ClientHistory`
//! summaries, so a selection pass stays O(n + k·n) worst case with no
//! per-client allocation beyond the score table. The sampling consumes
//! exactly `k` draws of `Rng::f64` (one roulette spin per pick),
//! independent of fleet size — pinned by the determinism test below.

use super::{training_time_feature, Aggregation, SelectionContext, Strategy};
use crate::util::Rng;
use crate::ClientId;

/// Softmax temperature: lower sharpens the preference for high scores.
/// At 0.25 a 0.1 score gap is ~1.5x selection odds — enough signal to
/// beat uniform sampling, soft enough to keep exploring the tail.
pub const APODOTIKO_TEMPERATURE: f64 = 0.25;

/// Score blend weights (speed, reliability, freshness). Sum to 1 so
/// scores live in [0, 1] and the temperature has a stable meaning.
const W_SPEED: f64 = 0.5;
const W_RELIABILITY: f64 = 0.3;
const W_FRESHNESS: f64 = 0.2;

pub struct Apodotiko;

impl Apodotiko {
    /// Per-client scores in selection-pool order. Public within the
    /// crate for the sanity test; the blend is documented above.
    fn scores(ctx: &SelectionContext) -> Vec<f64> {
        // Normalizer: slowest known EMA in the pool. With no known
        // clients every speed term is neutral (0.5).
        let mut max_t = 0.0f64;
        for &c in ctx.all_clients {
            let h = ctx.history.view(c);
            if !h.is_rookie() {
                max_t = max_t.max(training_time_feature(h, 0.5));
            }
        }
        ctx.all_clients
            .iter()
            .map(|&c| {
                let h = ctx.history.view(c);
                let (speed, reliability, freshness) = if h.is_rookie() {
                    // Unknown client: neutral speed/reliability, full
                    // exploration bonus.
                    (0.5, 0.5, 1.0)
                } else {
                    let speed = if max_t > 0.0 {
                        1.0 - training_time_feature(h, 0.5) / max_t
                    } else {
                        0.5
                    };
                    let reliability = h.successes as f64 / h.invocations as f64;
                    let freshness = 1.0 / (1.0 + h.invocations as f64);
                    (speed, reliability, freshness)
                };
                W_SPEED * speed + W_RELIABILITY * reliability + W_FRESHNESS * freshness
            })
            .collect()
    }
}

impl Strategy for Apodotiko {
    fn name(&self) -> &'static str {
        "apodotiko"
    }

    fn select(&mut self, ctx: &SelectionContext, rng: &mut Rng) -> Vec<ClientId> {
        let k = ctx.clients_per_round.min(ctx.all_clients.len());
        if k == 0 {
            return Vec::new();
        }
        let scores = Self::scores(ctx);
        // Softmax weights. Scores are bounded in [0, 1] so exp() needs
        // no max-shift for stability.
        let mut weights: Vec<f64> = scores
            .iter()
            .map(|s| (s / APODOTIKO_TEMPERATURE).exp())
            .collect();
        let mut total: f64 = weights.iter().sum();
        // k roulette spins without replacement: one f64 draw per pick,
        // picked clients zeroed out of the wheel. O(n·k) walk — fine at
        // paper scale, and the draw count stays exactly k regardless.
        let mut selected = Vec::with_capacity(k);
        let mut taken = vec![false; ctx.all_clients.len()];
        for _ in 0..k {
            let spin = rng.f64() * total;
            let mut acc = 0.0;
            let mut pick = usize::MAX;
            for (i, &w) in weights.iter().enumerate() {
                if taken[i] {
                    continue;
                }
                acc += w;
                if spin < acc {
                    pick = i;
                    break;
                }
            }
            if pick == usize::MAX {
                // Float-sum slack pushed the spin past the last sliver;
                // take the last remaining client.
                pick = taken.iter().rposition(|&t| !t).expect("pool not exhausted");
            }
            taken[pick] = true;
            selected.push(ctx.all_clients[pick]);
            total -= weights[pick];
            weights[pick] = 0.0;
        }
        selected
    }

    fn aggregation(&self) -> Aggregation {
        Aggregation::Synchronous
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clientdb::HistoryStore;

    fn ctx<'a>(clients: &'a [ClientId], hist: &'a HistoryStore, k: usize) -> SelectionContext<'a> {
        SelectionContext {
            round: 1,
            max_rounds: 10,
            clients_per_round: k,
            all_clients: clients,
            history: hist,
        }
    }

    #[test]
    fn seeded_selection_is_deterministic_and_distinct() {
        let clients: Vec<ClientId> = (0..30).collect();
        let mut hist = HistoryStore::new();
        for c in 0..30 {
            hist.record_invocation(c);
            hist.record_success(c, 0, 10.0 + c as f64);
        }
        let a = Apodotiko.select(&ctx(&clients, &hist, 8), &mut Rng::seed_from_u64(42));
        let b = Apodotiko.select(&ctx(&clients, &hist, 8), &mut Rng::seed_from_u64(42));
        assert_eq!(a, b);
        let mut d = a.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 8, "picks must be distinct: {a:?}");
    }

    #[test]
    fn softmax_prefers_fast_reliable_clients() {
        // Client 0: fast + always on time. Client 1: slow + always
        // missing. Over many seeded trials the fast one must be picked
        // substantially more often.
        let clients: Vec<ClientId> = (0..10).collect();
        let mut hist = HistoryStore::new();
        for c in 0..10 {
            for _ in 0..4 {
                hist.record_invocation(c);
            }
            if c == 0 {
                for r in 0..4 {
                    hist.record_success(0, r, 5.0);
                }
            } else if c == 1 {
                for r in 0..4 {
                    hist.record_failure(1, r);
                }
            } else {
                for r in 0..4 {
                    hist.record_success(c, r, 30.0);
                }
            }
        }
        let (mut fast, mut slow) = (0u32, 0u32);
        for seed in 0..200u64 {
            let sel = Apodotiko.select(&ctx(&clients, &hist, 3), &mut Rng::seed_from_u64(seed));
            fast += sel.contains(&0) as u32;
            slow += sel.contains(&1) as u32;
        }
        assert!(
            fast > slow * 2,
            "fast reliable client should dominate: fast={fast} slow={slow}"
        );
    }

    #[test]
    fn rookies_keep_exploration_pressure() {
        // A never-seen client must still get picked sometimes even when
        // the rest of the fleet has perfect records.
        let clients: Vec<ClientId> = (0..8).collect();
        let mut hist = HistoryStore::new();
        for c in 1..8 {
            hist.record_invocation(c);
            hist.record_success(c, 0, 10.0);
        }
        let mut rookie_hits = 0u32;
        for seed in 0..100u64 {
            let sel = Apodotiko.select(&ctx(&clients, &hist, 2), &mut Rng::seed_from_u64(seed));
            rookie_hits += sel.contains(&0) as u32;
        }
        assert!(rookie_hits > 10, "rookie starved: {rookie_hits}/100");
    }

    #[test]
    fn exact_draw_count_per_selection() {
        // The sampling contract: exactly k f64 draws, independent of
        // pool size. Verified by running the same selection with two
        // rngs and checking the streams stay aligned afterwards.
        let clients: Vec<ClientId> = (0..50).collect();
        let hist = HistoryStore::new();
        let mut rng = Rng::seed_from_u64(7);
        Apodotiko.select(&ctx(&clients, &hist, 5), &mut rng);
        let mut oracle = Rng::seed_from_u64(7);
        for _ in 0..5 {
            oracle.f64();
        }
        assert_eq!(rng.next_u64(), oracle.next_u64());
    }
}
