//! Behavioural feature extraction for FedLesScan's clustering (§V-C):
//! exponential moving averages over training times and missed-round
//! ratios.
//!
//! Since the bounded-history refactor, per-client feature rows are
//! **incremental**: [`feature_row`] reads the summaries `ClientHistory`
//! maintains on every success/failure event — the cached training-time
//! EMA (O(1), bit-identical to folding the unbounded series at the
//! default α) and a fold over the ≤ [`HISTORY_WINDOW`] missed-round
//! window — instead of rebuilding both features from full per-client
//! vectors each selection. The slice functions [`ema`] and
//! [`missed_round_ema`] remain the definition: they are what the
//! incremental path is property-tested against, and the fallback for a
//! non-default training-time α (folded over the recency window).
//!
//! [`HISTORY_WINDOW`]: crate::clientdb::HISTORY_WINDOW

use crate::clientdb::{ClientHistory, HISTORY_EMA_ALPHA};

/// Exponential moving average with smoothing factor `alpha` in (0, 1]:
/// recent observations get higher weight (the paper's rationale for EMA
/// over a plain mean, §V-C). Returns 0.0 for an empty series.
pub fn ema(values: &[f64], alpha: f64) -> f64 {
    debug_assert!((0.0..=1.0).contains(&alpha));
    let mut it = values.iter();
    let Some(&first) = it.next() else {
        return 0.0;
    };
    it.fold(first, |acc, &x| alpha * x + (1.0 - alpha) * acc)
}

/// The missed-round penalty feature (§V-C): divide each missed round
/// number by the current round to get ratios, then take their EMA. As
/// training progresses the ratio of an old miss shrinks, so the penalty
/// decays exactly as the paper requires; recent misses (ratio near 1)
/// dominate through the EMA recency weighting.
pub fn missed_round_ema(missed_rounds: &[u32], current_round: u32, alpha: f64) -> f64 {
    if current_round == 0 {
        return 0.0;
    }
    let ratios: Vec<f64> = missed_rounds
        .iter()
        .map(|&r| r as f64 / current_round as f64)
        .collect();
    ema(&ratios, alpha)
}

/// Training-time EMA feature from the bounded history: the cached
/// incremental EMA when `alpha` is the store's [`HISTORY_EMA_ALPHA`]
/// (exact at any history length), otherwise a fold over the recency
/// window — exact while the client has at most window entries, which
/// the window size guarantees for every in-repo experiment length (the
/// repro α ablations included); beyond that, the evicted prefix
/// carries EMA weight ≤ (1−α)^window.
pub fn training_time_feature(h: &ClientHistory, alpha: f64) -> f64 {
    if alpha == HISTORY_EMA_ALPHA {
        h.training_time_ema()
    } else {
        ema(h.recent_times(), alpha)
    }
}

/// One client's behaviour feature row `(trainingEma, missedRoundEma)`
/// for round `current_round`, read incrementally from the bounded
/// history summaries. O(window) worst case, O(1) for the shipped α.
pub fn feature_row(h: &ClientHistory, current_round: u32, alpha: f64) -> (f64, f64) {
    (
        training_time_feature(h, alpha),
        missed_round_ema(h.missed_recent(), current_round, alpha),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clientdb::HistoryStore;

    #[test]
    fn ema_empty_is_zero() {
        assert_eq!(ema(&[], 0.5), 0.0);
    }

    #[test]
    fn ema_single_value_is_value() {
        assert_eq!(ema(&[3.5], 0.5), 3.5);
    }

    #[test]
    fn ema_weights_recent_higher() {
        // rising series: EMA must sit above the plain mean's distance to
        // the last value, i.e. closer to the recent observations
        let rising = [1.0, 2.0, 3.0, 10.0];
        let mean = rising.iter().sum::<f64>() / 4.0;
        assert!(ema(&rising, 0.5) > mean);
    }

    #[test]
    fn ema_alpha_one_is_last_value() {
        assert_eq!(ema(&[1.0, 2.0, 9.0], 1.0), 9.0);
    }

    #[test]
    fn missed_round_penalty_decays_with_progress() {
        let missed = [2u32, 4];
        let early = missed_round_ema(&missed, 5, 0.5);
        let late = missed_round_ema(&missed, 50, 0.5);
        assert!(late < early);
        assert!(late > 0.0);
    }

    #[test]
    fn recent_miss_penalized_more_than_old() {
        let old_miss = missed_round_ema(&[1], 10, 0.5);
        let new_miss = missed_round_ema(&[9], 10, 0.5);
        assert!(new_miss > old_miss);
    }

    #[test]
    fn no_misses_no_penalty() {
        assert_eq!(missed_round_ema(&[], 10, 0.5), 0.0);
    }

    #[test]
    fn feature_row_matches_slice_oracles_at_default_alpha() {
        // Mirror the store updates into unbounded vectors and check the
        // incremental row is bit-identical to the slice definitions
        // (while within the window, where both are exact).
        let mut db = HistoryStore::new();
        let mut times: Vec<f64> = Vec::new();
        let mut missed: Vec<u32> = Vec::new();
        for r in 0..24u32 {
            db.record_invocation(3);
            if r % 4 == 1 {
                db.record_failure(3, r);
                missed.push(r);
            } else {
                let t = 8.0 + (r % 5) as f64 * 1.25;
                db.record_success(3, r, t);
                times.push(t);
            }
            let (t_feat, m_feat) = feature_row(db.view(3), r.max(1), 0.5);
            assert_eq!(t_feat.to_bits(), ema(&times, 0.5).to_bits(), "round {r}");
            assert_eq!(
                m_feat.to_bits(),
                missed_round_ema(&missed, r.max(1), 0.5).to_bits(),
                "round {r}"
            );
        }
    }

    #[test]
    fn feature_row_non_default_alpha_folds_the_window() {
        let mut db = HistoryStore::new();
        for (i, t) in [4.0, 6.0, 10.0].iter().enumerate() {
            db.record_success(1, i as u32, *t);
        }
        let (t_feat, _) = feature_row(db.view(1), 3, 0.25);
        assert_eq!(t_feat.to_bits(), ema(&[4.0, 6.0, 10.0], 0.25).to_bits());
    }
}
