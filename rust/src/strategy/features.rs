//! Behavioural feature extraction for FedLesScan's clustering (§V-C):
//! exponential moving averages over training times and missed-round
//! ratios.

/// Exponential moving average with smoothing factor `alpha` in (0, 1]:
/// recent observations get higher weight (the paper's rationale for EMA
/// over a plain mean, §V-C). Returns 0.0 for an empty series.
pub fn ema(values: &[f64], alpha: f64) -> f64 {
    debug_assert!((0.0..=1.0).contains(&alpha));
    let mut it = values.iter();
    let Some(&first) = it.next() else {
        return 0.0;
    };
    it.fold(first, |acc, &x| alpha * x + (1.0 - alpha) * acc)
}

/// The missed-round penalty feature (§V-C): divide each missed round
/// number by the current round to get ratios, then take their EMA. As
/// training progresses the ratio of an old miss shrinks, so the penalty
/// decays exactly as the paper requires; recent misses (ratio near 1)
/// dominate through the EMA recency weighting.
pub fn missed_round_ema(missed_rounds: &[u32], current_round: u32, alpha: f64) -> f64 {
    if current_round == 0 {
        return 0.0;
    }
    let ratios: Vec<f64> = missed_rounds
        .iter()
        .map(|&r| r as f64 / current_round as f64)
        .collect();
    ema(&ratios, alpha)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ema_empty_is_zero() {
        assert_eq!(ema(&[], 0.5), 0.0);
    }

    #[test]
    fn ema_single_value_is_value() {
        assert_eq!(ema(&[3.5], 0.5), 3.5);
    }

    #[test]
    fn ema_weights_recent_higher() {
        // rising series: EMA must sit above the plain mean's distance to
        // the last value, i.e. closer to the recent observations
        let rising = [1.0, 2.0, 3.0, 10.0];
        let mean = rising.iter().sum::<f64>() / 4.0;
        assert!(ema(&rising, 0.5) > mean);
    }

    #[test]
    fn ema_alpha_one_is_last_value() {
        assert_eq!(ema(&[1.0, 2.0, 9.0], 1.0), 9.0);
    }

    #[test]
    fn missed_round_penalty_decays_with_progress() {
        let missed = [2u32, 4];
        let early = missed_round_ema(&missed, 5, 0.5);
        let late = missed_round_ema(&missed, 50, 0.5);
        assert!(late < early);
        assert!(late > 0.0);
    }

    #[test]
    fn recent_miss_penalized_more_than_old() {
        let old_miss = missed_round_ema(&[1], 10, 0.5);
        let new_miss = missed_round_ema(&[9], 10, 0.5);
        assert!(new_miss > old_miss);
    }

    #[test]
    fn no_misses_no_penalty() {
        assert_eq!(missed_round_ema(&[], 10, 0.5), 0.0);
    }
}
