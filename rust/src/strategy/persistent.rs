//! Persistent cluster plane for FedLesScan's fleet-scale selection.
//!
//! [`ClusterPlane`] keeps the §V-A tier partition, the behaviour
//! feature rows, the frozen-ε [`IncrementalDbscan`] engine and the
//! per-cluster selection aggregates alive across rounds. Each
//! selection pass consumes the client DB's O(changed) dirty-set
//! ([`HistoryStore::dirty_since`]) instead of rescanning the fleet:
//!
//! * tier moves (rookie → participant → straggler → back) are applied
//!   per dirty client against O(1) tier sets;
//! * changed participant feature rows become engine updates, which
//!   recluster only the touched grid cell-components and splice the
//!   result into the standing labels;
//! * per-cluster aggregates (Σ totalEma + a members set ordered by
//!   `(invocations, id)` — the fairness walk order) are maintained by
//!   detach/attach on exactly the touched records.
//!
//! ## Frozen geometry and the drift threshold
//!
//! DBSCAN's grid geometry is a function of ε *and* of the y-axis scale
//! `max_t` (points are `[t, m·max_t]`). Both are frozen at (re)search
//! time so standing cells stay comparable across rounds. The
//! Calinski–Harabasz ε grid search re-runs only when the fraction of
//! participants whose point moved grid cells since the last freeze
//! exceeds [`DRIFT_RESEARCH_FRAC`] (or when the engine cannot place a
//! point) — at which point the plane rebuilds from scratch through the
//! [`cluster_clients_eps`] oracle, exactly the paper's per-round
//! search. Between rebuilds the standing partition is — component by
//! component — what a from-scratch DBSCAN pass at the frozen ε
//! produces (see `clustering::incremental`); the property suite pins
//! this under random drift schedules.

use std::collections::{BTreeSet, HashMap, HashSet};
use std::mem;

use super::{feature_row, ClusterNote, SelectReport, SelectionContext};
use crate::clientdb::ClientHistory;
use crate::clustering::{cluster_clients_eps, IncrementalDbscan};
use crate::ClientId;

/// Re-run the ε grid search when more than this fraction of the
/// participant tier moved grid cells since the last freeze. Below it,
/// the frozen geometry still reflects the behaviour distribution the
/// search saw; above it, enough of the fleet re-arranged that the
/// standing ε may no longer be the CH-optimal one.
pub const DRIFT_RESEARCH_FRAC: f64 = 0.10;

/// Label sentinel for a member record not yet attached to any cluster
/// aggregate (freshly upserted; the engine splice assigns it).
const UNASSIGNED: isize = isize::MIN;

/// §V-A tier of one client.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Tier {
    Rookie,
    Participant,
    Straggler,
}

fn classify(h: &ClientHistory) -> Tier {
    if h.is_rookie() {
        Tier::Rookie
    } else if h.is_straggler() {
        Tier::Straggler
    } else {
        Tier::Participant
    }
}

/// O(1) add/remove id set exposing a stable slice for seeded sampling.
/// Swap-remove keeps operations constant-time; the resulting order is a
/// deterministic function of the operation sequence (never of a hash
/// map's iteration order), which is all replay determinism needs.
#[derive(Debug, Default)]
struct TierSet {
    order: Vec<ClientId>,
    pos: HashMap<ClientId, usize>,
}

impl TierSet {
    fn insert(&mut self, c: ClientId) {
        if self.pos.contains_key(&c) {
            return;
        }
        self.pos.insert(c, self.order.len());
        self.order.push(c);
    }

    fn remove(&mut self, c: ClientId) {
        if let Some(i) = self.pos.remove(&c) {
            let last = self.order.pop().expect("pos non-empty implies order non-empty");
            if i < self.order.len() {
                self.order[i] = last;
                self.pos.insert(last, i);
            }
        }
    }

    fn as_slice(&self) -> &[ClientId] {
        &self.order
    }

    fn len(&self) -> usize {
        self.order.len()
    }

    fn clear(&mut self) {
        self.order.clear();
        self.pos.clear();
    }
}

/// Per-participant behaviour record mirrored from the client DB.
#[derive(Debug, Clone, Copy)]
struct MemberRec {
    /// trainingEma (x axis).
    t: f64,
    /// missedRoundEma (unscaled).
    m: f64,
    /// Eq. 2 totalEma at the frozen `max_t`: `t + m·max_t`.
    total: f64,
    /// Fairness key (least-invoked first).
    invocations: u32,
    /// Standing cluster label ([`UNASSIGNED`] between upsert and splice).
    label: isize,
}

/// Selection aggregate of one standing cluster.
#[derive(Debug, Default)]
struct ClusterAgg {
    /// Σ totalEma over members (mean = sum / members.len()).
    sum: f64,
    /// Members in fairness order `(invocations, id)` ascending —
    /// exactly the within-cluster order of the paper-scale walk.
    members: BTreeSet<(u32, ClientId)>,
}

/// The persistent selection state; see the module docs.
#[derive(Debug, Default)]
pub(crate) struct ClusterPlane {
    alpha: f64,
    min_pts: usize,
    built: bool,
    /// Standing tier of every registered client.
    tier: HashMap<ClientId, Tier>,
    rookies: TierSet,
    stragglers: TierSet,
    /// Participant records; keys are exactly the engine's point ids.
    members: HashMap<ClientId, MemberRec>,
    /// Frozen-ε engine; `None` in the degenerate frozen state (no ε
    /// produced structure — e.g. all points identical), where every
    /// participant sits in one standing cluster until the next rebuild.
    engine: Option<IncrementalDbscan>,
    /// Frozen y-axis scale (see module docs).
    max_t: f64,
    clusters: HashMap<isize, ClusterAgg>,
    /// Participants whose point changed grid cells since the last ε
    /// freeze (plus joins/leaves) — the drift measure.
    moved_since_freeze: HashSet<ClientId>,
    /// Dirty-log cursor into [`HistoryStore::dirty_since`].
    dirty_cursor: u64,
    last_round: Option<u32>,
    // -- report accumulators, drained by `take_report` --
    reclustered: usize,
    cache_hits: usize,
    notes: Vec<ClusterNote>,
}

impl ClusterPlane {
    pub(crate) fn new(alpha: f64, min_pts: usize) -> Self {
        Self {
            alpha,
            min_pts,
            ..Self::default()
        }
    }

    pub(crate) fn rookies(&self) -> &[ClientId] {
        self.rookies.as_slice()
    }

    pub(crate) fn stragglers(&self) -> &[ClientId] {
        self.stragglers.as_slice()
    }

    pub(crate) fn participant_count(&self) -> usize {
        self.members.len()
    }

    /// Bring the plane up to date with the client DB. First call (or a
    /// drift/degeneracy trigger) runs the full ε grid search; steady
    /// state is O(dirty + touched cell-components).
    pub(crate) fn refresh(&mut self, ctx: &SelectionContext) {
        let (dirty_slice, cursor) = ctx.history.dirty_since(self.dirty_cursor);
        let mut dirty: Vec<ClientId> = dirty_slice.to_vec();
        self.dirty_cursor = cursor;
        if !self.built {
            self.rebuild(ctx);
            return;
        }

        // The missed-round feature decays with the current round, so on
        // a round advance every client with a live miss drifts even
        // without a new event.
        if self.last_round != Some(ctx.round) {
            dirty.extend(ctx.history.clients_with_misses().iter().copied());
            self.last_round = Some(ctx.round);
        }
        dirty.sort_unstable();
        dirty.dedup();
        if dirty.is_empty() {
            self.cache_hits += self.members.len();
            return;
        }

        // Classify the dirty clients; collect engine changes.
        let round = ctx.round.max(1);
        let mut changes: Vec<(ClientId, Option<Vec<f64>>)> = Vec::new();
        let mut pending: HashMap<ClientId, (f64, f64, u32)> = HashMap::new();
        for &c in &dirty {
            let h = ctx.history.view(c);
            let new_tier = classify(h);
            let old_tier = self.tier.get(&c).copied();
            if old_tier != Some(new_tier) {
                match old_tier {
                    Some(Tier::Rookie) => self.rookies.remove(c),
                    Some(Tier::Straggler) => self.stragglers.remove(c),
                    Some(Tier::Participant) => {
                        if let Some(rec) = self.members.remove(&c) {
                            detach(&mut self.clusters, c, &rec);
                            changes.push((c, None));
                            self.moved_since_freeze.insert(c);
                        }
                    }
                    None => {} // client unseen by the last rebuild (late registration)
                }
                match new_tier {
                    Tier::Rookie => self.rookies.insert(c),
                    Tier::Straggler => self.stragglers.insert(c),
                    Tier::Participant => {}
                }
                self.tier.insert(c, new_tier);
            }
            if new_tier == Tier::Participant {
                let (t, m) = feature_row(h, round, self.alpha);
                let inv = h.invocations;
                match self.members.get_mut(&c) {
                    Some(rec) if rec.t == t && rec.m == m => {
                        // geometry unchanged: at most a fairness-order
                        // move within the standing cluster
                        if rec.invocations != inv {
                            if let Some(agg) = self.clusters.get_mut(&rec.label) {
                                agg.members.remove(&(rec.invocations, c));
                                agg.members.insert((inv, c));
                            }
                            rec.invocations = inv;
                        }
                    }
                    _ => {
                        changes.push((c, Some(vec![t, m * self.max_t])));
                        pending.insert(c, (t, m, inv));
                    }
                }
            }
        }

        if changes.is_empty() {
            self.cache_hits += self.members.len();
            return;
        }

        // Drift accounting before the engine mutates its cells.
        if let Some(engine) = &self.engine {
            for (c, p) in &changes {
                let old = engine.cell(*c).map(<[i64]>::to_vec);
                let new = p.as_deref().and_then(|pt| engine.key_for(pt));
                if old != new {
                    self.moved_since_freeze.insert(*c);
                }
            }
        }

        let splice = match self.engine.as_mut() {
            // Degenerate frozen state: any structural change re-searches.
            None => None,
            Some(engine) => engine.update(&changes),
        };
        let Some(splice) = splice else {
            self.rebuild(ctx);
            return;
        };

        // Apply the row updates: detach stale aggregate entries and
        // refresh the records; the splice pass below re-attaches every
        // touched point under its fresh label (a changed row's point is
        // always inside a reclustered component).
        for (c, p) in &changes {
            if p.is_none() {
                continue; // departures already detached above
            }
            let (t, m, inv) = pending[c];
            let total = t + m * self.max_t;
            match self.members.get_mut(c) {
                Some(rec) => {
                    let old = *rec;
                    detach(&mut self.clusters, *c, &old);
                    rec.t = t;
                    rec.m = m;
                    rec.total = total;
                    rec.invocations = inv;
                    rec.label = UNASSIGNED;
                }
                None => {
                    self.members.insert(
                        *c,
                        MemberRec {
                            t,
                            m,
                            total,
                            invocations: inv,
                            label: UNASSIGNED,
                        },
                    );
                    self.moved_since_freeze.insert(*c);
                }
            }
        }

        // Splice: move every relabeled point to its fresh cluster.
        for &(id, new_label) in &splice.relabeled {
            let old = *self
                .members
                .get(&id)
                .expect("engine points and member records share keys");
            if old.label == new_label {
                continue; // NOISE -> NOISE: still attached correctly
            }
            detach(&mut self.clusters, id, &old); // no-op for UNASSIGNED
            let rec = self.members.get_mut(&id).expect("still present");
            rec.label = new_label;
            let agg = self.clusters.entry(new_label).or_default();
            agg.sum += old.total;
            agg.members.insert((old.invocations, id));
            self.notes.push(ClusterNote {
                client: id,
                feature: (old.t, old.m),
                cell: self.engine.as_ref().and_then(|e| cell_pair(e.cell(id))),
                cluster: new_label as i64,
            });
        }

        self.reclustered += splice.reclustered;
        self.cache_hits += self.members.len().saturating_sub(splice.reclustered);

        // ε-freeze drift check, after the splice so the measure sees
        // this round's moves.
        let drifted = self.moved_since_freeze.len() as f64;
        if drifted > DRIFT_RESEARCH_FRAC * self.members.len().max(1) as f64 {
            self.rebuild(ctx);
        }
    }

    /// Full rebuild: classify the fleet, re-run the Calinski–Harabasz
    /// ε grid search (the from-scratch oracle), freeze the winning
    /// geometry and reload the engine. O(fleet); runs on first use and
    /// on drift/degeneracy triggers only.
    fn rebuild(&mut self, ctx: &SelectionContext) {
        self.tier.clear();
        self.rookies.clear();
        self.stragglers.clear();
        self.members.clear();
        self.clusters.clear();
        self.moved_since_freeze.clear();
        self.engine = None;

        let round = ctx.round.max(1);
        let mut parts: Vec<(ClientId, f64, f64, u32)> = Vec::new();
        for &c in ctx.all_clients {
            let h = ctx.history.view(c);
            let tier = classify(h);
            self.tier.insert(c, tier);
            match tier {
                Tier::Rookie => self.rookies.insert(c),
                Tier::Straggler => self.stragglers.insert(c),
                Tier::Participant => {
                    let (t, m) = feature_row(h, round, self.alpha);
                    parts.push((c, t, m, h.invocations));
                }
            }
        }

        let max_t = parts
            .iter()
            .map(|p| p.1)
            .fold(0.0f64, f64::max)
            .max(1e-9);
        self.max_t = max_t;
        let points: Vec<Vec<f64>> = parts.iter().map(|&(_, t, m, _)| vec![t, m * max_t]).collect();
        let (oracle_labels, _, eps) = cluster_clients_eps(&points, self.min_pts);

        // Try to freeze the winning ε into the engine; fall back to the
        // oracle's labels (single standing cluster, typically) when no
        // ε produced structure or the engine refuses the geometry.
        let mut engine_labels: Option<Vec<(ClientId, isize)>> = None;
        if let Some(eps) = eps {
            if let Some(mut engine) = IncrementalDbscan::new(eps, self.min_pts) {
                let inserts: Vec<(ClientId, Option<Vec<f64>>)> = parts
                    .iter()
                    .zip(&points)
                    .map(|(&(c, ..), p)| (c, Some(p.clone())))
                    .collect();
                if let Some(splice) = engine.update(&inserts) {
                    engine_labels = Some(splice.relabeled);
                    self.engine = Some(engine);
                }
            }
        }

        match engine_labels {
            Some(relabeled) => {
                let label_of: HashMap<ClientId, isize> = relabeled.into_iter().collect();
                for &(c, t, m, inv) in &parts {
                    let label = label_of[&c];
                    self.install(c, t, m, inv, label);
                }
            }
            None => {
                // oracle labels are already outlier-relabelled (no NOISE)
                for (i, &(c, t, m, inv)) in parts.iter().enumerate() {
                    let label = oracle_labels.get(i).copied().unwrap_or(0);
                    self.install(c, t, m, inv, label);
                }
            }
        }

        self.reclustered += parts.len();
        self.built = true;
        self.last_round = Some(ctx.round);
    }

    /// Insert a participant record and attach it to its cluster.
    fn install(&mut self, c: ClientId, t: f64, m: f64, inv: u32, label: isize) {
        let total = t + m * self.max_t;
        self.members.insert(
            c,
            MemberRec {
                t,
                m,
                total,
                invocations: inv,
                label,
            },
        );
        let agg = self.clusters.entry(label).or_default();
        agg.sum += total;
        agg.members.insert((inv, c));
        self.notes.push(ClusterNote {
            client: c,
            feature: (t, m),
            cell: self.engine.as_ref().and_then(|e| cell_pair(e.cell(c))),
            cluster: label as i64,
        });
    }

    /// Algorithm 2 lines 9-17 against the standing aggregates: clusters
    /// ascending by mean totalEma (ties on label id — deterministic),
    /// rotation start from training progress, least-invoked first
    /// within a cluster. NOISE participates as the outlier
    /// pseudo-cluster, ordered by its mean like any other (§V-C "treat
    /// outliers as a single cluster").
    pub(crate) fn pick_clustered(&self, take: usize, ctx: &SelectionContext) -> Vec<ClientId> {
        if take == 0 || self.clusters.is_empty() {
            return Vec::new();
        }
        let mut order: Vec<(f64, isize)> = self
            .clusters
            .iter()
            .map(|(&label, agg)| (agg.sum / agg.members.len().max(1) as f64, label))
            .collect();
        order.sort_by(|a, b| {
            a.0.partial_cmp(&b.0)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.1.cmp(&b.1))
        });
        let n_clusters = order.len();
        let progress = if ctx.max_rounds == 0 {
            0.0
        } else {
            ctx.round as f64 / ctx.max_rounds as f64
        };
        let start = ((progress * n_clusters as f64) as usize).min(n_clusters - 1);
        let mut picked = Vec::with_capacity(take);
        'outer: for step in 0..n_clusters {
            let (_, label) = order[(start + step) % n_clusters];
            for &(_, c) in &self.clusters[&label].members {
                picked.push(c);
                if picked.len() == take {
                    break 'outer;
                }
            }
        }
        picked
    }

    /// Drain the accumulated report (counters reset to zero).
    pub(crate) fn take_report(&mut self) -> SelectReport {
        SelectReport {
            reclustered_clients: mem::take(&mut self.reclustered),
            cluster_cache_hits: mem::take(&mut self.cache_hits),
            dirty_cursor: Some(self.dirty_cursor),
            notes: mem::take(&mut self.notes),
        }
    }
}

/// Remove a record's entry from its cluster aggregate (no-op for
/// [`UNASSIGNED`]); drops the aggregate when it empties so cluster
/// iteration never sees ghosts.
fn detach(clusters: &mut HashMap<isize, ClusterAgg>, c: ClientId, rec: &MemberRec) {
    if rec.label == UNASSIGNED {
        return;
    }
    if let Some(agg) = clusters.get_mut(&rec.label) {
        agg.sum -= rec.total;
        agg.members.remove(&(rec.invocations, c));
        if agg.members.is_empty() {
            clusters.remove(&rec.label);
        }
    }
}

fn cell_pair(cell: Option<&[i64]>) -> Option<(i64, i64)> {
    match cell {
        Some([x, y]) => Some((*x, *y)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clientdb::HistoryStore;
    use crate::clustering::{dbscan, relabel_outliers, DbscanParams};

    fn ctx<'a>(
        clients: &'a [ClientId],
        history: &'a HistoryStore,
        round: u32,
        k: usize,
    ) -> SelectionContext<'a> {
        SelectionContext {
            round,
            max_rounds: 20,
            clients_per_round: k,
            all_clients: clients,
            history,
        }
    }

    /// Partition-identity of the plane's standing labels against the
    /// from-scratch oracle at the plane's own frozen geometry.
    fn assert_matches_frozen_oracle(plane: &ClusterPlane, c: &SelectionContext) {
        let Some(engine) = &plane.engine else { return };
        let mut ids: Vec<ClientId> = plane.members.keys().copied().collect();
        ids.sort_unstable();
        let round = c.round.max(1);
        let points: Vec<Vec<f64>> = ids
            .iter()
            .map(|&id| {
                let (t, m) = feature_row(c.history.view(id), round, plane.alpha);
                vec![t, m * plane.max_t]
            })
            .collect();
        let want = {
            let mut l = dbscan(
                &points,
                &DbscanParams {
                    eps: engine.eps(),
                    min_pts: plane.min_pts,
                },
            );
            relabel_outliers(&mut l);
            l
        };
        let got: Vec<isize> = ids.iter().map(|id| plane.members[id].label).collect();
        // bijective label mapping, NOISE folded into the same rules on
        // both sides (plane keeps NOISE; oracle relabels it — the
        // partition must still agree)
        let mut fwd: HashMap<isize, isize> = HashMap::new();
        let mut rev: HashMap<isize, isize> = HashMap::new();
        for (i, (&g, &w)) in got.iter().zip(&want).enumerate() {
            assert_eq!(*fwd.entry(g).or_insert(w), w, "client {} fwd", ids[i]);
            assert_eq!(*rev.entry(w).or_insert(g), g, "client {} rev", ids[i]);
        }
    }

    fn seed_fleet(hist: &mut HistoryStore, n: usize) {
        for c in 0..n {
            hist.record_invocation(c);
            let t = if c % 2 == 0 { 5.0 } else { 60.0 };
            hist.record_success(c, 1, t + (c % 7) as f64 * 0.05);
        }
    }

    #[test]
    fn first_refresh_builds_then_caches() {
        let n = 40;
        let clients: Vec<ClientId> = (0..n).collect();
        let mut hist = HistoryStore::new();
        seed_fleet(&mut hist, n);
        let mut plane = ClusterPlane::new(0.5, 2);
        let c = ctx(&clients, &hist, 2, 8);
        plane.refresh(&c);
        assert_eq!(plane.participant_count(), n);
        let rep = plane.take_report();
        assert_eq!(rep.reclustered_clients, n, "first build reclusters everyone");
        assert_eq!(rep.notes.len(), n);
        hist.truncate_dirty(rep.dirty_cursor.unwrap());
        assert_matches_frozen_oracle(&plane, &c);

        // same round, no new events: pure cache
        plane.refresh(&c);
        let rep = plane.take_report();
        assert_eq!(rep.reclustered_clients, 0);
        assert_eq!(rep.cluster_cache_hits, n);
        assert!(rep.notes.is_empty());
    }

    #[test]
    fn incremental_refresh_tracks_events_and_matches_oracle() {
        let n = 60;
        let clients: Vec<ClientId> = (0..n).collect();
        let mut hist = HistoryStore::new();
        seed_fleet(&mut hist, n);
        let mut plane = ClusterPlane::new(0.5, 2);
        {
            let c = ctx(&clients, &hist, 2, 8);
            plane.refresh(&c);
            hist.truncate_dirty(plane.take_report().dirty_cursor.unwrap());
        }
        // one client reports a meaningfully different time (same round:
        // no missed-round drift) — only its cell-component reclusters
        hist.record_invocation(4);
        hist.record_success(4, 2, 8.0);
        let c = ctx(&clients, &hist, 2, 8);
        plane.refresh(&c);
        let rep = plane.take_report();
        assert!(rep.reclustered_clients > 0);
        assert!(
            rep.reclustered_clients < n,
            "only touched components recluster, got {}",
            rep.reclustered_clients
        );
        assert!(rep.cluster_cache_hits > 0);
        hist.truncate_dirty(rep.dirty_cursor.unwrap());
        assert_matches_frozen_oracle(&plane, &c);
    }

    #[test]
    fn tier_moves_update_the_sets() {
        let clients: Vec<ClientId> = (0..10).collect();
        let mut hist = HistoryStore::new();
        for c in 0..8 {
            hist.record_invocation(c);
            hist.record_success(c, 1, 10.0 + c as f64);
        }
        // 8, 9 stay rookies
        let mut plane = ClusterPlane::new(0.5, 2);
        plane.refresh(&ctx(&clients, &hist, 1, 4));
        assert_eq!(plane.rookies().len(), 2);
        assert_eq!(plane.stragglers().len(), 0);
        assert_eq!(plane.participant_count(), 8);
        plane.take_report();

        // 3 fails -> straggler; 8 invoked+fails -> rookie to straggler
        hist.record_failure(3, 2);
        hist.record_invocation(8);
        hist.record_failure(8, 2);
        plane.refresh(&ctx(&clients, &hist, 2, 4));
        assert_eq!(plane.rookies().len(), 1);
        assert_eq!(plane.stragglers().len(), 2);
        assert_eq!(plane.participant_count(), 7);
        plane.take_report();

        // cooldowns decay: both return (8 as participant now)
        hist.tick_cooldowns(&[]);
        plane.refresh(&ctx(&clients, &hist, 3, 4));
        assert_eq!(plane.stragglers().len(), 0);
        assert_eq!(plane.participant_count(), 9);
    }

    #[test]
    fn pick_clustered_is_fair_and_progress_rotated() {
        // one tight cluster: least-invoked first
        let clients: Vec<ClientId> = (0..4).collect();
        let mut hist = HistoryStore::new();
        for c in 0..4 {
            for _ in 0..(c + 1) {
                hist.record_invocation(c);
            }
            hist.record_success(c, 1, 10.0);
        }
        let mut plane = ClusterPlane::new(0.5, 2);
        let c = ctx(&clients, &hist, 0, 2);
        plane.refresh(&c);
        assert_eq!(plane.pick_clustered(2, &c), vec![0, 1]);
        // take = everyone: full coverage, no duplicates
        let all = plane.pick_clustered(4, &c);
        let mut d = all.clone();
        d.sort_unstable();
        assert_eq!(d, clients);
    }

    #[test]
    fn heavy_drift_triggers_the_oracle_research() {
        let n = 30;
        let clients: Vec<ClientId> = (0..n).collect();
        let mut hist = HistoryStore::new();
        seed_fleet(&mut hist, n);
        let mut plane = ClusterPlane::new(0.5, 2);
        plane.refresh(&ctx(&clients, &hist, 2, 8));
        plane.take_report();
        let eps_before = plane.engine.as_ref().map(|e| e.eps());

        // move well over DRIFT_RESEARCH_FRAC of the fleet to a new regime
        for c in 0..n / 2 {
            hist.record_invocation(c);
            hist.record_success(c, 3, 200.0 + c as f64);
        }
        let c = ctx(&clients, &hist, 3, 8);
        plane.refresh(&c);
        let rep = plane.take_report();
        // the pass did incremental splice work AND the full rebuild, so
        // the counter is at least the tier size
        assert!(
            rep.reclustered_clients >= n,
            "drift past the threshold rebuilds the whole tier, got {}",
            rep.reclustered_clients
        );
        assert!(
            plane.moved_since_freeze.is_empty(),
            "rebuild freezes a fresh geometry"
        );
        let _ = eps_before; // ε may or may not move; the rebuild itself is the contract
        assert_matches_frozen_oracle(&plane, &c);
    }

    #[test]
    fn degenerate_geometry_falls_back_to_single_cluster() {
        // identical behaviour: no ε candidate survives -> engine-less
        // frozen state with one standing cluster
        let clients: Vec<ClientId> = (0..6).collect();
        let mut hist = HistoryStore::new();
        for c in 0..6 {
            hist.record_invocation(c);
            hist.record_success(c, 1, 10.0);
        }
        let mut plane = ClusterPlane::new(0.5, 2);
        let c = ctx(&clients, &hist, 1, 3);
        plane.refresh(&c);
        assert!(plane.engine.is_none());
        assert_eq!(plane.clusters.len(), 1);
        let picked = plane.pick_clustered(3, &c);
        assert_eq!(picked.len(), 3);
        plane.take_report();

        // any structural change re-searches (and may find structure now)
        hist.record_invocation(0);
        hist.record_success(0, 2, 99.0);
        let c = ctx(&clients, &hist, 2, 3);
        plane.refresh(&c);
        let rep = plane.take_report();
        assert_eq!(rep.reclustered_clients, 6, "engine-less dirt => full rebuild");
    }
}
