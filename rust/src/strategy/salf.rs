//! SALF-style deadline optimization (SNIPPETS.md snippet 3, arXiv
//! SALF: straggler-aware layer-wise FL). The original lets stragglers
//! upload whatever layers they finished by the deadline; our training
//! plane exchanges whole parameter blocks, so the equivalent lever is
//! the partial-work channel the coordinator already applies per
//! invocation: predicted-slow clients are asked for a *smaller
//! fraction* of the local workload so they land inside the deadline,
//! and whatever still arrives late folds through the staleness-aware
//! Eq. 3 scheme instead of being discarded.
//!
//! Mechanics: selection is uniform (FedAvg's exact `random_sample`
//! stream). After picking the cohort, `select` computes a per-round
//! time budget — the median predicted training time of the known
//! cohort members with [`SALF_BUDGET_SLACK`] headroom — and plans each
//! client's work fraction as `clamp(budget / predicted, MIN_WORK, 1)`.
//! Rookies and everyone at-or-under budget run full workloads.
//! `work_fraction` then just reads the plan: it consumes **no** RNG
//! draws, keeping the per-invocation draw stream identical to FedAvg's
//! (the contract the seeded goldens pin).

use std::collections::HashMap;

use super::{random_sample, training_time_feature, Aggregation, SelectionContext, Strategy};
use crate::util::Rng;
use crate::ClientId;

/// Headroom multiplier on the cohort-median predicted time: clients up
/// to 25% slower than the median still run full workloads.
pub const SALF_BUDGET_SLACK: f64 = 1.25;

/// Floor on the planned work fraction — below this a partial update is
/// too noisy to be worth folding.
pub const SALF_MIN_WORK: f64 = 0.25;

#[derive(Default)]
pub struct Salf {
    /// Work plan for the most recent cohort, rebuilt on every
    /// selection pass. Missing clients (e.g. replacement dispatches
    /// before their first plan) default to full work.
    planned: HashMap<ClientId, f64>,
}

impl Salf {
    fn plan(&mut self, cohort: &[ClientId], ctx: &SelectionContext) {
        self.planned.clear();
        let mut known: Vec<f64> = cohort
            .iter()
            .map(|&c| ctx.history.view(c))
            .filter(|h| !h.is_rookie())
            .map(|h| training_time_feature(h, 0.5))
            .filter(|&t| t > 0.0)
            .collect();
        if known.is_empty() {
            return; // everyone rookie/unknown: full work across the board
        }
        known.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let budget = known[known.len() / 2] * SALF_BUDGET_SLACK;
        for &c in cohort {
            let h = ctx.history.view(c);
            if h.is_rookie() {
                continue;
            }
            let predicted = training_time_feature(h, 0.5);
            if predicted > budget {
                self.planned
                    .insert(c, (budget / predicted).max(SALF_MIN_WORK));
            }
        }
    }
}

impl Strategy for Salf {
    fn name(&self) -> &'static str {
        "salf"
    }

    fn select(&mut self, ctx: &SelectionContext, rng: &mut Rng) -> Vec<ClientId> {
        let cohort = random_sample(ctx.all_clients, ctx.clients_per_round, rng);
        self.plan(&cohort, ctx);
        cohort
    }

    fn work_fraction(&self, client: ClientId, _rng: &mut Rng) -> f64 {
        self.planned.get(&client).copied().unwrap_or(1.0)
    }

    fn aggregation(&self) -> Aggregation {
        // Updates that miss the deadline anyway still fold, dampened by
        // Eq. 3 — the SALF philosophy of never wasting straggler work.
        Aggregation::StalenessAware {
            tau: 2,
            normalize: true,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clientdb::HistoryStore;
    use crate::strategy::FedAvg;

    fn ctx<'a>(clients: &'a [ClientId], hist: &'a HistoryStore, k: usize) -> SelectionContext<'a> {
        SelectionContext {
            round: 1,
            max_rounds: 10,
            clients_per_round: k,
            all_clients: clients,
            history: hist,
        }
    }

    #[test]
    fn selection_matches_fedavg_and_work_fraction_draws_no_rng() {
        let clients: Vec<ClientId> = (0..30).collect();
        let mut hist = HistoryStore::new();
        for c in 0..30 {
            hist.record_invocation(c);
            hist.record_success(c, 0, 10.0 + c as f64);
        }
        let mut s = Salf::default();
        let mut rng = Rng::seed_from_u64(9);
        let cohort = s.select(&ctx(&clients, &hist, 8), &mut rng);
        assert_eq!(
            cohort,
            FedAvg.select(&ctx(&clients, &hist, 8), &mut Rng::seed_from_u64(9)),
            "selection stream must be FedAvg's"
        );
        // work_fraction must not touch the rng stream
        let before = rng.next_u64();
        let mut rng2 = Rng::seed_from_u64(9);
        let mut s2 = Salf::default();
        s2.select(&ctx(&clients, &hist, 8), &mut rng2);
        for &c in &cohort {
            s2.work_fraction(c, &mut rng2);
        }
        assert_eq!(rng2.next_u64(), before);
    }

    #[test]
    fn slow_clients_get_reduced_work_fast_get_full() {
        let clients: Vec<ClientId> = (0..10).collect();
        let mut hist = HistoryStore::new();
        for c in 0..10 {
            hist.record_invocation(c);
            // client 9 is 10x slower than the pack
            let t = if c == 9 { 100.0 } else { 10.0 };
            hist.record_success(c, 0, t);
        }
        let mut s = Salf::default();
        // select everyone so the plan covers the whole fleet
        let cohort = s.select(&ctx(&clients, &hist, 10), &mut Rng::seed_from_u64(1));
        assert_eq!(cohort.len(), 10);
        let mut rng = Rng::seed_from_u64(0);
        let slow = s.work_fraction(9, &mut rng);
        let fast = s.work_fraction(0, &mut rng);
        assert_eq!(fast, 1.0);
        assert!(
            (SALF_MIN_WORK..1.0).contains(&slow),
            "slow client should be throttled: {slow}"
        );
        // budget = median(10.0) * 1.25 = 12.5 → 12.5/100 = 0.125 < floor
        assert_eq!(slow, SALF_MIN_WORK);
    }

    #[test]
    fn rookies_and_unplanned_clients_run_full_work() {
        let clients: Vec<ClientId> = (0..5).collect();
        let hist = HistoryStore::new();
        let mut s = Salf::default();
        s.select(&ctx(&clients, &hist, 5), &mut Rng::seed_from_u64(2));
        let mut rng = Rng::seed_from_u64(0);
        for c in 0..5 {
            assert_eq!(s.work_fraction(c, &mut rng), 1.0);
        }
        // a client never selected (no plan entry) also defaults to 1.0
        assert_eq!(s.work_fraction(999, &mut rng), 1.0);
    }
}
