//! FedLesScan (the paper's contribution, §V): clustering-based
//! semi-asynchronous client selection + staleness-aware aggregation.
//!
//! Selection (Algorithm 2):
//! 1. partition clients into **rookies** (never invoked), **stragglers**
//!    (cooldown > 0, Eq. 1) and **participants** (the rest);
//! 2. rookies first — everyone gets a chance to contribute and to
//!    produce behavioural data;
//! 3. participants are clustered with DBSCAN over
//!    `(trainingEma, missedRoundEma · maxTrainingTime)` — both axes in
//!    seconds so the Euclidean ε is meaningful; ε is grid-searched by
//!    Calinski–Harabasz score (§V-C); outliers form one extra cluster;
//! 4. clusters are sorted by ascending mean `totalEma` (Eq. 2) and
//!    sampled starting from the cluster matching the training progress
//!    (`round / maxRounds`), rotating onward; within a cluster the
//!    least-invoked clients go first (fair selection);
//! 5. stragglers back-fill only if tiers 1+2 cannot cover the round.
//!
//! Aggregation: staleness-aware Eq. 3 with the τ cutoff (§V-D).
//!
//! ## Fleet-scale path
//!
//! The paper evaluates ≤ 300 clients; this implementation also serves
//! the ROADMAP's 100k+ fleets. Feature rows are read incrementally from
//! the bounded history ([`feature_row`], O(1)–O(window) per client), and
//! when the participant tier exceeds [`COHORT_MAX`] the clustering input
//! is a **stratified cohort**: participants are bucketed by their cached
//! training-time EMA and sampled proportionally per stratum, so the
//! behaviour spectrum survives while clustering allocates O(cohort), not
//! O(n). At paper scale (participants ≤ [`COHORT_MAX`]) the path is
//! byte-identical to clustering everyone — pinned by the selection
//! goldens in `tests/goldens.rs`.

use super::persistent::ClusterPlane;
use super::{feature_row, random_sample, Aggregation, SelectReport, SelectionContext, Strategy};
use crate::clustering::cluster_clients;
use crate::util::Rng;
use crate::ClientId;

#[derive(Debug, Clone, Copy)]
pub struct FedLesScanParams {
    /// EMA smoothing factor for both behaviour features.
    pub ema_alpha: f64,
    /// DBSCAN minimum neighbourhood size.
    pub min_pts: usize,
    /// Maximum accepted update age (Eq. 3 cutoff); the paper uses 2.
    pub tau: u32,
    /// Normalize Eq. 3 weights to sum to one (see paramsvr docs).
    pub normalize: bool,
}

impl Default for FedLesScanParams {
    fn default() -> Self {
        Self {
            ema_alpha: 0.5,
            min_pts: 2,
            tau: 2,
            normalize: true,
        }
    }
}

/// Participant tiers larger than this are stratified-sampled down to a
/// clustering cohort (see the module doc). Far above every paper-scale
/// preset (≤ a few hundred clients), so the small path never changes;
/// the effective cap also never drops below 4× the number of clients
/// the round still needs.
pub const COHORT_MAX: usize = 1024;

/// Strata count for the cohort sample: buckets over the cached
/// training-time EMA range.
const COHORT_STRATA: usize = 16;

#[derive(Default)]
pub struct FedLesScan {
    pub params: FedLesScanParams,
    /// Persistent incremental cluster plane (opt-in via
    /// [`with_incremental`](Self::with_incremental)). `None` keeps the
    /// historical stateless selection on every fleet size.
    plane: Option<ClusterPlane>,
    /// Report of the last incremental pass, drained by
    /// [`Strategy::take_select_report`].
    report: Option<SelectReport>,
}

impl FedLesScan {
    pub fn new(params: FedLesScanParams) -> Self {
        Self {
            params,
            plane: None,
            report: None,
        }
    }

    /// FedLesScan with the persistent incremental cluster plane. Above
    /// [`COHORT_MAX`] registered clients, `select` consumes the client
    /// DB's dirty-set and the standing frozen-ε clustering instead of
    /// re-stratifying and re-clustering the world — per-round work
    /// scales with behaviour drift, not fleet size, and the *whole*
    /// participant tier is clustered (no stratified cohort cap). At or
    /// below [`COHORT_MAX`] clients the stateless paper-scale path runs
    /// unchanged, byte-identical to [`FedLesScan::default`] (pinned by
    /// the selection goldens and the property suite).
    pub fn with_incremental() -> Self {
        Self::new_incremental(FedLesScanParams::default())
    }

    /// [`with_incremental`](Self::with_incremental) at explicit params.
    pub fn new_incremental(params: FedLesScanParams) -> Self {
        Self {
            params,
            plane: Some(ClusterPlane::new(params.ema_alpha, params.min_pts)),
            report: None,
        }
    }

    /// Algorithm 2 against the persistent cluster plane: same tier
    /// policy and RNG draw order as the stateless path (rookie sample,
    /// then straggler sample — the clustered walk draws nothing), but
    /// tiers, features and clusters come from the standing state
    /// refreshed by the dirty-set.
    fn select_incremental(&mut self, ctx: &SelectionContext, rng: &mut Rng) -> Vec<ClientId> {
        let k = ctx.clients_per_round;
        let plane = self.plane.as_mut().expect("gated on plane presence");
        plane.refresh(ctx);

        let selected = {
            if plane.rookies().len() >= k {
                random_sample(plane.rookies(), k, rng)
            } else {
                let mut selected = plane.rookies().to_vec();
                let need = k - selected.len();
                let n_cluster = need.min(plane.participant_count());
                let n_straggler = (need - n_cluster).min(plane.stragglers().len());
                let straggler_picks = random_sample(plane.stragglers(), n_straggler, rng);
                if n_cluster > 0 {
                    selected.extend(plane.pick_clustered(n_cluster, ctx));
                }
                selected.extend(straggler_picks);
                selected.truncate(k);
                selected
            }
        };
        self.report = Some(plane.take_report());
        selected
    }
}

/// §V-A tier partition over the registered fleet:
/// `(rookies, participants, stragglers)`. Reads the history through the
/// borrowed [`view`](crate::clientdb::HistoryStore::view) — no per-client
/// clone.
pub fn tier_partition(ctx: &SelectionContext) -> (Vec<ClientId>, Vec<ClientId>, Vec<ClientId>) {
    let mut rookies = Vec::new();
    let mut participants = Vec::new();
    let mut stragglers = Vec::new();
    for &c in ctx.all_clients {
        let h = ctx.history.view(c);
        if h.is_rookie() {
            rookies.push(c);
        } else if h.is_straggler() {
            stragglers.push(c);
        } else {
            participants.push(c);
        }
    }
    (rookies, participants, stragglers)
}

impl Strategy for FedLesScan {
    fn name(&self) -> &'static str {
        "fedlesscan"
    }

    fn select(&mut self, ctx: &SelectionContext, rng: &mut Rng) -> Vec<ClientId> {
        // Fleet-scale incremental path: only when the persistent plane
        // is enabled AND the fleet exceeds the paper-scale cohort cap.
        // At or below COHORT_MAX the stateless path below runs even
        // with a plane configured, keeping the ≤COHORT_MAX selection
        // stream byte-identical to `FedLesScan::default()` (goldens).
        if self.plane.is_some() && ctx.all_clients.len() > COHORT_MAX {
            return self.select_incremental(ctx, rng);
        }

        let k = ctx.clients_per_round;
        let a = self.params.ema_alpha;

        // ---- tier partitioning (§V-A) --------------------------------
        let (rookies, participants, stragglers) = tier_partition(ctx);

        // ---- Algorithm 2, lines 3-5: rookies cover the round ---------
        if rookies.len() >= k {
            return random_sample(&rookies, k, rng);
        }
        let mut selected = rookies;
        let need = k - selected.len();
        let n_cluster = need.min(participants.len());
        let n_straggler = (need - n_cluster).min(stragglers.len());

        // ---- lines 6-8: straggler back-fill ---------------------------
        let straggler_picks = random_sample(&stragglers, n_straggler, rng);

        // ---- lines 9-17: cluster the participants ---------------------
        if n_cluster > 0 {
            // Fleet-scale: stratify the participant tier down to a
            // clustering cohort. Below the cap this is the identity.
            let cohort_cap = COHORT_MAX.max(n_cluster * 4);
            let cohort: Vec<ClientId> = if participants.len() > cohort_cap {
                stratified_cohort(&participants, ctx, cohort_cap, rng)
            } else {
                participants
            };

            // behaviour features, incremental from the bounded history
            let feats: Vec<(f64, f64)> = cohort
                .iter()
                .map(|&c| feature_row(ctx.history.view(c), ctx.round.max(1), a))
                .collect();
            let max_t = feats
                .iter()
                .map(|f| f.0)
                .fold(0.0f64, f64::max)
                .max(1e-9);
            let points: Vec<Vec<f64>> = feats
                .iter()
                .map(|&(t, m)| vec![t, m * max_t])
                .collect();
            let (labels, n_clusters) = cluster_clients(&points, self.params.min_pts);

            // Eq. 2 totalEma per participant; cluster order = ascending
            // mean totalEma (fast clusters first).
            let total_ema: Vec<f64> = feats.iter().map(|&(t, m)| t + m * max_t).collect();
            selected.extend(sample_clustered(
                &cohort,
                &total_ema,
                &labels,
                n_clusters,
                n_cluster,
                ctx,
                rng,
            ));
        }

        selected.extend(straggler_picks);
        selected.truncate(k);
        selected
    }

    fn aggregation(&self) -> Aggregation {
        Aggregation::StalenessAware {
            tau: self.params.tau,
            normalize: self.params.normalize,
        }
    }

    fn take_select_report(&mut self) -> Option<SelectReport> {
        self.report.take()
    }
}

/// Stratified cohort sample for fleet-scale participant tiers: bucket by
/// the cached training-time EMA (O(1) per client), then draw from every
/// stratum proportionally (largest-remainder rounding) so slow and fast
/// behaviour regions are all represented in the clustering input.
/// Deterministic in the RNG stream; only reached when
/// `participants.len() > take`.
fn stratified_cohort(
    participants: &[ClientId],
    ctx: &SelectionContext,
    take: usize,
    rng: &mut Rng,
) -> Vec<ClientId> {
    debug_assert!(take < participants.len());
    let keys: Vec<f64> = participants
        .iter()
        .map(|&c| ctx.history.view(c).training_time_ema())
        .collect();
    let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
    for &x in &keys {
        lo = lo.min(x);
        hi = hi.max(x);
    }
    if hi <= lo {
        // flat behaviour range: plain uniform sample
        return random_sample(participants, take, rng);
    }
    let mut buckets: Vec<Vec<ClientId>> = vec![Vec::new(); COHORT_STRATA];
    for (&c, &x) in participants.iter().zip(&keys) {
        let b = (((x - lo) / (hi - lo) * COHORT_STRATA as f64) as usize).min(COHORT_STRATA - 1);
        buckets[b].push(c);
    }

    // Proportional quota per stratum, floor first ...
    let n = participants.len();
    let mut quota: Vec<usize> = buckets.iter().map(|b| b.len() * take / n).collect();
    // ... then the leftover slots by largest remainder (stable
    // tie-break on bucket index keeps this deterministic).
    let mut rem: Vec<(usize, usize)> = buckets
        .iter()
        .enumerate()
        .map(|(i, b)| ((b.len() * take) % n, i))
        .collect();
    rem.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
    let mut short = take - quota.iter().sum::<usize>();
    for &(_, i) in &rem {
        if short == 0 {
            break;
        }
        if quota[i] < buckets[i].len() {
            quota[i] += 1;
            short -= 1;
        }
    }
    // Saturated strata can still leave a shortfall; sweep the rest up
    // from whichever buckets have room (total capacity n > take).
    while short > 0 {
        let mut progressed = false;
        for i in 0..COHORT_STRATA {
            if short > 0 && quota[i] < buckets[i].len() {
                quota[i] += 1;
                short -= 1;
                progressed = true;
            }
        }
        if !progressed {
            break;
        }
    }

    let mut cohort = Vec::with_capacity(take);
    for (bucket, &q) in buckets.iter().zip(&quota) {
        if q > 0 {
            cohort.extend(random_sample(bucket, q, rng));
        }
    }
    cohort
}

/// Algorithm 2 lines 9-17: walk the behaviour clusters (ascending mean
/// totalEma, rotation start from training progress) and take `take`
/// participants, least-invoked first within each cluster.
///
/// Degenerate clusterings are handled here rather than by the caller: a
/// zero-cluster result for a non-empty participant set (every point
/// rejected by the ε grid search) falls back to a uniform sample instead
/// of underflowing `n_clusters - 1` in the rotation-start computation.
fn sample_clustered(
    participants: &[ClientId],
    total_ema: &[f64],
    labels: &[isize],
    n_clusters: usize,
    take: usize,
    ctx: &SelectionContext,
    rng: &mut Rng,
) -> Vec<ClientId> {
    if n_clusters == 0 {
        return random_sample(participants, take, rng);
    }
    let mut cluster_sum = vec![0.0f64; n_clusters];
    let mut cluster_cnt = vec![0usize; n_clusters];
    for (i, &l) in labels.iter().enumerate() {
        cluster_sum[l as usize] += total_ema[i];
        cluster_cnt[l as usize] += 1;
    }
    let mut order: Vec<usize> = (0..n_clusters).collect();
    order.sort_by(|&x, &y| {
        let mx = cluster_sum[x] / cluster_cnt[x].max(1) as f64;
        let my = cluster_sum[y] / cluster_cnt[y].max(1) as f64;
        mx.partial_cmp(&my).unwrap()
    });

    // members per cluster, least-invoked first (fairness)
    let mut members: Vec<Vec<ClientId>> = vec![Vec::new(); n_clusters];
    for (i, &l) in labels.iter().enumerate() {
        members[l as usize].push(participants[i]);
    }
    for m in members.iter_mut() {
        m.sort_by_key(|&c| (ctx.history.view(c).invocations, c));
    }

    // rotation start from training progress (§V-C)
    let progress = if ctx.max_rounds == 0 {
        0.0
    } else {
        ctx.round as f64 / ctx.max_rounds as f64
    };
    let start = ((progress * n_clusters as f64) as usize).min(n_clusters - 1);

    let mut picked = Vec::with_capacity(take);
    'outer: for step in 0..n_clusters {
        let cl = order[(start + step) % n_clusters];
        for &c in &members[cl] {
            picked.push(c);
            if picked.len() == take {
                break 'outer;
            }
        }
    }
    picked
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clientdb::HistoryStore;

    fn ctx<'a>(
        clients: &'a [ClientId],
        history: &'a HistoryStore,
        round: u32,
        k: usize,
    ) -> SelectionContext<'a> {
        SelectionContext {
            round,
            max_rounds: 20,
            clients_per_round: k,
            all_clients: clients,
            history,
        }
    }

    #[test]
    fn all_rookies_random_sample() {
        let clients: Vec<ClientId> = (0..30).collect();
        let hist = HistoryStore::new();
        let mut s = FedLesScan::default();
        let mut rng = Rng::seed_from_u64(0);
        let sel = s.select(&ctx(&clients, &hist, 0, 10), &mut rng);
        assert_eq!(sel.len(), 10);
    }

    #[test]
    fn rookies_prioritized_before_participants() {
        let clients: Vec<ClientId> = (0..10).collect();
        let mut hist = HistoryStore::new();
        // clients 0..7 have history; 8, 9 are rookies
        for c in 0..8 {
            hist.record_invocation(c);
            hist.record_success(c, 0, 10.0 + c as f64);
        }
        let mut s = FedLesScan::default();
        let mut rng = Rng::seed_from_u64(1);
        let sel = s.select(&ctx(&clients, &hist, 1, 4), &mut rng);
        assert!(sel.contains(&8));
        assert!(sel.contains(&9));
        assert_eq!(sel.len(), 4);
    }

    #[test]
    fn stragglers_only_backfill() {
        let clients: Vec<ClientId> = (0..6).collect();
        let mut hist = HistoryStore::new();
        // 0..4 reliable participants, 4 and 5 stragglers
        for c in 0..4 {
            hist.record_invocation(c);
            hist.record_success(c, 0, 10.0);
        }
        for c in 4..6 {
            hist.record_invocation(c);
            hist.record_failure(c, 0);
        }
        let mut s = FedLesScan::default();
        let mut rng = Rng::seed_from_u64(2);
        // k=4 covered entirely by participants -> no stragglers
        let sel = s.select(&ctx(&clients, &hist, 1, 4), &mut rng);
        assert!(!sel.contains(&4) && !sel.contains(&5), "{sel:?}");
        // k=6 forces straggler back-fill
        let sel = s.select(&ctx(&clients, &hist, 1, 6), &mut rng);
        assert!(sel.contains(&4) && sel.contains(&5));
    }

    #[test]
    fn fast_cluster_preferred_early() {
        let clients: Vec<ClientId> = (0..8).collect();
        let mut hist = HistoryStore::new();
        // two clear behaviour clusters: fast (~5 s) and slow (~50 s)
        for c in 0..4 {
            hist.record_invocation(c);
            hist.record_success(c, 0, 5.0 + 0.01 * c as f64);
        }
        for c in 4..8 {
            hist.record_invocation(c);
            hist.record_success(c, 0, 50.0 + 0.01 * c as f64);
        }
        let mut s = FedLesScan::default();
        let mut rng = Rng::seed_from_u64(3);
        // round 0 of 20: progress 0 -> start from the fastest cluster
        let sel = s.select(&ctx(&clients, &hist, 0, 4), &mut rng);
        let fast: usize = sel.iter().filter(|&&c| c < 4).count();
        assert_eq!(fast, 4, "expected the fast cluster, got {sel:?}");
    }

    #[test]
    fn selection_size_and_uniqueness_invariants() {
        let clients: Vec<ClientId> = (0..25).collect();
        let mut hist = HistoryStore::new();
        for c in 0..15 {
            hist.record_invocation(c);
            if c % 4 == 0 {
                hist.record_failure(c, 1);
            } else {
                hist.record_success(c, 1, 5.0 + c as f64);
            }
        }
        let mut s = FedLesScan::default();
        let mut rng = Rng::seed_from_u64(4);
        for round in 0..10 {
            let sel = s.select(&ctx(&clients, &hist, round, 12), &mut rng);
            assert!(sel.len() <= 12);
            let mut d = sel.clone();
            d.sort_unstable();
            d.dedup();
            assert_eq!(d.len(), sel.len(), "duplicates in {sel:?}");
            assert!(sel.iter().all(|c| clients.contains(c)));
        }
    }

    #[test]
    fn least_invoked_first_within_cluster() {
        let clients: Vec<ClientId> = (0..4).collect();
        let mut hist = HistoryStore::new();
        // identical behaviour -> one cluster; invocation counts differ
        for c in 0..4 {
            for _ in 0..(c + 1) {
                hist.record_invocation(c);
            }
            hist.record_success(c, 0, 10.0);
        }
        let mut s = FedLesScan::default();
        let mut rng = Rng::seed_from_u64(5);
        let sel = s.select(&ctx(&clients, &hist, 0, 2), &mut rng);
        assert_eq!(sel, vec![0, 1]);
    }

    #[test]
    fn zero_clusters_falls_back_instead_of_underflowing() {
        // Regression: a zero-cluster result for a non-empty participant
        // set used to underflow `n_clusters - 1` (usize) when computing
        // the rotation start. The fallback must sample uniformly.
        let participants: Vec<ClientId> = vec![3, 5, 9];
        let total_ema = vec![1.0, 2.0, 3.0];
        let hist = HistoryStore::new();
        let c = ctx(&participants, &hist, 4, 2);
        let mut rng = Rng::seed_from_u64(11);
        let picked = sample_clustered(&participants, &total_ema, &[], 0, 2, &c, &mut rng);
        assert_eq!(picked.len(), 2);
        assert!(picked.iter().all(|p| participants.contains(p)));
        let mut d = picked.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 2, "duplicates in fallback sample {picked:?}");
    }

    #[test]
    fn sample_clustered_respects_rotation_and_fairness() {
        // One cluster, distinct invocation counts: least-invoked first.
        let participants: Vec<ClientId> = vec![0, 1, 2];
        let total_ema = vec![5.0, 5.0, 5.0];
        let mut hist = HistoryStore::new();
        for c in 0..3 {
            for _ in 0..(3 - c) {
                hist.record_invocation(c);
            }
            hist.record_success(c, 0, 10.0);
        }
        let c = ctx(&participants, &hist, 0, 2);
        let mut rng = Rng::seed_from_u64(12);
        let picked =
            sample_clustered(&participants, &total_ema, &[0, 0, 0], 1, 2, &c, &mut rng);
        assert_eq!(picked, vec![2, 1]);
    }

    #[test]
    fn tier_partition_buckets_by_state() {
        let clients: Vec<ClientId> = (0..6).collect();
        let mut hist = HistoryStore::new();
        for c in 0..2 {
            hist.record_invocation(c);
            hist.record_success(c, 0, 5.0);
        }
        for c in 2..4 {
            hist.record_invocation(c);
            hist.record_failure(c, 0);
        }
        let c = ctx(&clients, &hist, 1, 3);
        let (rookies, participants, stragglers) = tier_partition(&c);
        assert_eq!(rookies, vec![4, 5]);
        assert_eq!(participants, vec![0, 1]);
        assert_eq!(stragglers, vec![2, 3]);
    }

    #[test]
    fn stratified_cohort_spans_the_behaviour_range() {
        // 4000 participants in two speed regimes: the cohort must carry
        // members of both, be duplicate-free and exactly `take` long.
        let n = 4000usize;
        let clients: Vec<ClientId> = (0..n).collect();
        let mut hist = HistoryStore::new();
        for c in 0..n {
            hist.record_invocation(c);
            let t = if c % 2 == 0 { 5.0 } else { 80.0 };
            hist.record_success(c, 0, t + (c % 17) as f64 * 0.1);
        }
        let c = ctx(&clients, &hist, 1, 64);
        let mut rng = Rng::seed_from_u64(21);
        let take = 512;
        let cohort = stratified_cohort(&clients, &c, take, &mut rng);
        assert_eq!(cohort.len(), take);
        let mut d = cohort.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), take, "duplicates in cohort");
        let fast = cohort.iter().filter(|&&c| c % 2 == 0).count();
        let slow = take - fast;
        // proportional sampling from a 50/50 fleet: both regimes well
        // represented (exact split depends on stratum boundaries)
        assert!(fast > take / 4 && slow > take / 4, "fast {fast} slow {slow}");
    }

    #[test]
    fn large_fleet_selection_is_bounded_and_deterministic() {
        // Above COHORT_MAX participants the cohort path kicks in; the
        // selection must stay duplicate-free, k-sized and a pure
        // function of the RNG seed.
        let n = COHORT_MAX * 3;
        let clients: Vec<ClientId> = (0..n).collect();
        let mut hist = HistoryStore::new();
        for c in 0..n {
            hist.record_invocation(c);
            hist.record_success(c, 0, 5.0 + (c % 97) as f64);
        }
        let run = |seed: u64| {
            let mut s = FedLesScan::default();
            let mut rng = Rng::seed_from_u64(seed);
            s.select(&ctx(&clients, &hist, 3, 48), &mut rng)
        };
        let a = run(7);
        let b = run(7);
        assert_eq!(a, b);
        assert_eq!(a.len(), 48);
        let mut d = a.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 48);
        assert_ne!(a, run(8), "different seeds should move the sample");
    }

    #[test]
    fn incremental_is_byte_identical_at_paper_scale() {
        // at ≤ COHORT_MAX registered clients the plane must never
        // engage: same RNG stream, same selections, and no report
        let n = 60;
        let clients: Vec<ClientId> = (0..n).collect();
        let mut hist = HistoryStore::new();
        for c in 0..40 {
            hist.record_invocation(c);
            if c % 5 == 0 {
                hist.record_failure(c, 0);
            } else {
                hist.record_success(c, 0, 5.0 + (c % 11) as f64);
            }
        }
        let mut legacy = FedLesScan::default();
        let mut incr = FedLesScan::with_incremental();
        let mut rng_a = Rng::seed_from_u64(17);
        let mut rng_b = Rng::seed_from_u64(17);
        for round in 0..8 {
            let a = legacy.select(&ctx(&clients, &hist, round, 16), &mut rng_a);
            let b = incr.select(&ctx(&clients, &hist, round, 16), &mut rng_b);
            assert_eq!(a, b, "round {round}");
            assert!(incr.take_select_report().is_none(), "paper-scale path has no report");
        }
    }

    #[test]
    fn incremental_large_fleet_is_deterministic_and_reports() {
        let n = COHORT_MAX * 2;
        let clients: Vec<ClientId> = (0..n).collect();
        let mut hist = HistoryStore::new();
        for c in 0..n {
            hist.record_invocation(c);
            hist.record_success(c, 1, 5.0 + (c % 97) as f64);
        }
        let run = |seed: u64| {
            let mut s = FedLesScan::with_incremental();
            let mut rng = Rng::seed_from_u64(seed);
            let mut out = Vec::new();
            let mut reports = Vec::new();
            for round in 2..6 {
                let sel = s.select(&ctx(&clients, &hist, round, 48), &mut rng);
                let rep = s.take_select_report().expect("incremental path reports");
                out.push(sel);
                reports.push((rep.reclustered_clients, rep.cluster_cache_hits));
            }
            (out, reports)
        };
        let (sels_a, reps_a) = run(7);
        let (sels_b, reps_b) = run(7);
        assert_eq!(sels_a, sels_b, "pure function of the seed");
        assert_eq!(reps_a, reps_b);
        // first pass is the full build; later passes (history untouched
        // between selects) are pure cache
        assert_eq!(reps_a[0].0, n, "first select clusters the whole tier");
        for (i, &(reclustered, hits)) in reps_a.iter().enumerate().skip(1) {
            assert_eq!(reclustered, 0, "round {i}: nothing drifted");
            assert_eq!(hits, n, "round {i}: standing assignment reused");
        }
        for sel in &sels_a {
            assert_eq!(sel.len(), 48);
            let mut d = sel.clone();
            d.sort_unstable();
            d.dedup();
            assert_eq!(d.len(), 48, "duplicates in {sel:?}");
        }
    }

    #[test]
    fn staleness_aware_aggregation_configured() {
        let s = FedLesScan::default();
        assert_eq!(
            s.aggregation(),
            Aggregation::StalenessAware {
                tau: 2,
                normalize: true,
            }
        );
    }
}
