//! Training strategies: the paper's contribution (FedLesScan) and the
//! baselines it is evaluated against (FedAvg, FedProx), plus the
//! strategy zoo the adversarial grid sweeps: Apodotiko's scoring-based
//! probabilistic selection, the straggler-drop FedAvg baseline, and a
//! SALF-style deadline optimizer — with a SAFA-like greedy-fast
//! selector kept for the bias ablation.
//!
//! A strategy owns two decisions (§IV Strategy Manager):
//! * **client selection** for each round, and
//! * the **aggregation scheme** (synchronous FedAvg weights vs the
//!   staleness-aware Eq. 3 scheme).

mod apodotiko;
mod features;
mod fedavg;
mod fedavgdrop;
mod fedlesscan;
mod fedprox;
mod persistent;
mod safa;
mod salf;

pub use apodotiko::{Apodotiko, APODOTIKO_TEMPERATURE};
pub use features::{ema, feature_row, missed_round_ema, training_time_feature};
pub use fedavg::FedAvg;
pub use fedavgdrop::FedAvgDrop;
pub use fedlesscan::{tier_partition, FedLesScan, FedLesScanParams, COHORT_MAX};
pub use fedprox::FedProx;
pub use persistent::DRIFT_RESEARCH_FRAC;
pub use safa::SafaLite;
pub use salf::{Salf, SALF_BUDGET_SLACK, SALF_MIN_WORK};

use crate::clientdb::HistoryStore;
use crate::util::Rng;
use crate::ClientId;

/// Everything a strategy may look at when selecting clients.
pub struct SelectionContext<'a> {
    /// Current round (0-based).
    pub round: u32,
    pub max_rounds: u32,
    /// Number of clients to select (nClientsPerRound).
    pub clients_per_round: usize,
    pub all_clients: &'a [ClientId],
    pub history: &'a HistoryStore,
}

/// Aggregation scheme selected by the strategy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Aggregation {
    /// Wait for on-time updates only; weights are n_k/n (FedAvg).
    Synchronous,
    /// Eq. 3: fold in late updates dampened by t_k/t, discard age >= tau.
    StalenessAware { tau: u32, normalize: bool },
}

/// One client's clustering outcome from a selection pass, flowing back
/// into the client DB ([`HistoryStore::note_cluster`]).
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterNote {
    pub client: ClientId,
    /// Behaviour feature row `(trainingEma, missedRoundEma)`.
    pub feature: (f64, f64),
    /// Grid cell on the frozen-ε behaviour grid (`None` when the
    /// incremental engine was inactive, e.g. degenerate geometry).
    pub cell: Option<(i64, i64)>,
    /// Standing cluster id (`-1` = outlier pseudo-cluster).
    pub cluster: i64,
}

/// What a selection pass did to the persistent cluster state — drained
/// by the coordinator after each `select`/`select_replacements` call
/// via [`Strategy::take_select_report`].
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SelectReport {
    /// Clients whose cluster assignment was recomputed this pass
    /// (touched cell-components, or the whole tier on a rebuild).
    pub reclustered_clients: usize,
    /// Clustered participants whose standing assignment was reused.
    pub cluster_cache_hits: usize,
    /// Dirty-log position consumed ([`HistoryStore::dirty_since`]); the
    /// coordinator truncates the store's log up to it.
    pub dirty_cursor: Option<u64>,
    /// Fresh cluster assignments to persist into the client DB.
    pub notes: Vec<ClusterNote>,
}

/// A federated training strategy.
pub trait Strategy {
    fn name(&self) -> &'static str;

    /// Pick the clients to invoke this round.
    fn select(&mut self, ctx: &SelectionContext, rng: &mut Rng) -> Vec<ClientId>;

    /// Pick replacement clients in continuous mode, where completions
    /// free capacity one at a time instead of a round barrier emptying
    /// the whole cohort at once. `ctx.clients_per_round` carries the
    /// number of slots to refill (often 1). Defaults to [`Self::select`]
    /// — every strategy's selection logic already takes the cohort size
    /// from the context, so the same policy applies unchanged; override
    /// only if a strategy wants different steady-state behaviour.
    fn select_replacements(&mut self, ctx: &SelectionContext, rng: &mut Rng) -> Vec<ClientId> {
        self.select(ctx, rng)
    }

    /// Route client training through the FedProx proximal entrypoint?
    fn uses_prox(&self) -> bool {
        false
    }

    /// FedProx partial-work toleration (§III-B): fraction of the full
    /// local workload a client is asked to perform this round.
    fn work_fraction(&self, _client: ClientId, _rng: &mut Rng) -> f64 {
        1.0
    }

    fn aggregation(&self) -> Aggregation {
        Aggregation::Synchronous
    }

    /// Should the coordinator close the round at the last **on-time**
    /// arrival and discard everything still running (the straggler-drop
    /// FedAvg baseline, SNIPPETS.md snippet 2)? Default `false`: the
    /// round waits out the deadline when anyone missed it. Dropped
    /// functions are still billed — they ran to timeout (§VI-C).
    fn drops_stragglers(&self) -> bool {
        false
    }

    /// Drain the report of the most recent selection pass. `None` for
    /// strategies without persistent cluster state (the default) and
    /// for passes that ran the stateless paper-scale path.
    fn take_select_report(&mut self) -> Option<SelectReport> {
        None
    }
}

/// CLI-facing strategy selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StrategyKind {
    Fedavg,
    Fedprox,
    Fedlesscan,
    Safalite,
    Apodotiko,
    Fedavgdrop,
    Salf,
}

impl StrategyKind {
    pub fn build(self) -> Box<dyn Strategy> {
        match self {
            StrategyKind::Fedavg => Box::new(FedAvg),
            StrategyKind::Fedprox => Box::new(FedProx::default()),
            StrategyKind::Fedlesscan => Box::new(FedLesScan::default()),
            StrategyKind::Safalite => Box::new(SafaLite),
            StrategyKind::Apodotiko => Box::new(Apodotiko),
            StrategyKind::Fedavgdrop => Box::new(FedAvgDrop),
            StrategyKind::Salf => Box::new(Salf::default()),
        }
    }

    /// [`build`](Self::build), but FedLesScan gets the persistent
    /// incremental cluster plane. This is what the coordinator uses: a
    /// long-lived strategy instance whose per-round selection work
    /// scales with behaviour drift, not fleet size. Paper-scale fleets
    /// (≤ [`COHORT_MAX`]) still take the stateless path inside
    /// `FedLesScan::select`, so seeded reproductions are unchanged.
    pub fn build_persistent(self) -> Box<dyn Strategy> {
        match self {
            StrategyKind::Fedlesscan => Box::new(FedLesScan::with_incremental()),
            other => other.build(),
        }
    }

    /// Strategies the tables and grid sweeps evaluate head-to-head:
    /// the paper trio plus the zoo. Replaces the old `all()` (which
    /// silently meant "paper trio"): table printers now iterate this,
    /// with [`Self::ablation`] appended where the ablation-only
    /// contrast belongs (e.g. the Fig. 3 bias panel).
    pub fn evaluated() -> [StrategyKind; 6] {
        [
            StrategyKind::Fedavg,
            StrategyKind::Fedprox,
            StrategyKind::Fedlesscan,
            StrategyKind::Apodotiko,
            StrategyKind::Fedavgdrop,
            StrategyKind::Salf,
        ]
    }

    /// Ablation-only strategies: contrast points that are not fair
    /// head-to-head baselines (SAFA-lite deliberately has no fairness
    /// mechanism — it exists to show the bias FedLesScan avoids).
    pub fn ablation() -> [StrategyKind; 1] {
        [StrategyKind::Safalite]
    }

    pub fn as_str(self) -> &'static str {
        match self {
            StrategyKind::Fedavg => "fedavg",
            StrategyKind::Fedprox => "fedprox",
            StrategyKind::Fedlesscan => "fedlesscan",
            StrategyKind::Safalite => "safalite",
            StrategyKind::Apodotiko => "apodotiko",
            StrategyKind::Fedavgdrop => "fedavgdrop",
            StrategyKind::Salf => "salf",
        }
    }
}

impl std::str::FromStr for StrategyKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> std::result::Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "fedavg" => Ok(StrategyKind::Fedavg),
            "fedprox" => Ok(StrategyKind::Fedprox),
            "fedlesscan" => Ok(StrategyKind::Fedlesscan),
            "safalite" | "safa" => Ok(StrategyKind::Safalite),
            "apodotiko" => Ok(StrategyKind::Apodotiko),
            "fedavgdrop" | "fedavg-drop" => Ok(StrategyKind::Fedavgdrop),
            "salf" => Ok(StrategyKind::Salf),
            other => anyhow::bail!(
                "unknown strategy {other:?}; expected \
                 fedavg|fedprox|fedlesscan|safalite|apodotiko|fedavgdrop|salf"
            ),
        }
    }
}

/// Pool size above which [`random_sample`] switches from the
/// historical clone-and-shuffle to the O(k) sparse sampler. Changing
/// it changes the RNG draw sequence for every strategy on pools beyond
/// the smaller of the two values, which invalidates seeded
/// reproductions — it equals [`COHORT_MAX`] today but is deliberately
/// a separate knob so tuning the clustering-cohort cap cannot silently
/// move this switch.
const SAMPLE_SWITCH_MIN: usize = 1024;

/// Shared helper: uniform random sample of `k` distinct clients. Pools
/// up to [`SAMPLE_SWITCH_MIN`] use the historical clone-and-shuffle
/// (the exact RNG draw sequence the selection goldens pin); larger
/// pools — never reachable at paper scale — switch to the O(k) sparse
/// partial Fisher–Yates of [`Rng::sample_indices`] instead of cloning
/// and fully shuffling 100k ids to keep a few hundred.
pub(crate) fn random_sample(clients: &[ClientId], k: usize, rng: &mut Rng) -> Vec<ClientId> {
    if clients.len() > SAMPLE_SWITCH_MIN {
        rng.sample_indices(clients.len(), k)
            .into_iter()
            .map(|i| clients[i])
            .collect()
    } else {
        rng.sample(clients, k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_sample_is_distinct_and_bounded() {
        let clients: Vec<ClientId> = (0..10).collect();
        let mut rng = Rng::seed_from_u64(1);
        let s = random_sample(&clients, 4, &mut rng);
        assert_eq!(s.len(), 4);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 4);
        // k larger than the pool: everything
        let s = random_sample(&clients, 99, &mut rng);
        assert_eq!(s.len(), 10);
    }

    #[test]
    fn select_replacements_defaults_to_select() {
        use crate::clientdb::HistoryStore;
        let clients: Vec<ClientId> = (0..20).collect();
        let history = HistoryStore::new();
        let ctx = SelectionContext {
            round: 3,
            max_rounds: 10,
            clients_per_round: 5,
            all_clients: &clients,
            history: &history,
        };
        for kind in StrategyKind::evaluated()
            .into_iter()
            .chain(StrategyKind::ablation())
        {
            // Identical RNG state => the default delegation must produce
            // exactly the cohort select() would have produced.
            let picked = kind.build().select(&ctx, &mut Rng::seed_from_u64(7));
            let replaced = kind
                .build()
                .select_replacements(&ctx, &mut Rng::seed_from_u64(7));
            assert_eq!(picked, replaced, "{}", kind.as_str());
        }
    }

    #[test]
    fn strategy_kind_builds() {
        for k in StrategyKind::evaluated()
            .into_iter()
            .chain(StrategyKind::ablation())
        {
            let s = k.build();
            assert_eq!(s.name(), k.as_str());
        }
    }

    #[test]
    fn kind_string_roundtrip() {
        for k in StrategyKind::evaluated()
            .into_iter()
            .chain(StrategyKind::ablation())
        {
            assert_eq!(k.as_str().parse::<StrategyKind>().unwrap(), k);
        }
    }

    #[test]
    fn evaluated_and_ablation_are_disjoint_and_cover_the_zoo() {
        let eval = StrategyKind::evaluated();
        let abl = StrategyKind::ablation();
        for a in abl {
            assert!(!eval.contains(&a), "{} is in both sets", a.as_str());
        }
        assert!(eval.contains(&StrategyKind::Fedlesscan));
        assert!(eval.contains(&StrategyKind::Apodotiko));
        assert!(abl.contains(&StrategyKind::Safalite));
    }
}
