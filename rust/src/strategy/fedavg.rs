//! FedAvg (McMahan et al., 2017): uniform random client selection and
//! synchronous cardinality-weighted averaging. The paper's first
//! baseline.

use super::{random_sample, Aggregation, SelectionContext, Strategy};
use crate::util::Rng;
use crate::ClientId;

pub struct FedAvg;

impl Strategy for FedAvg {
    fn name(&self) -> &'static str {
        "fedavg"
    }

    fn select(&mut self, ctx: &SelectionContext, rng: &mut Rng) -> Vec<ClientId> {
        random_sample(ctx.all_clients, ctx.clients_per_round, rng)
    }

    fn aggregation(&self) -> Aggregation {
        Aggregation::Synchronous
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clientdb::HistoryStore;

    #[test]
    fn selects_k_distinct_clients() {
        let clients: Vec<ClientId> = (0..20).collect();
        let hist = HistoryStore::new();
        let ctx = SelectionContext {
            round: 0,
            max_rounds: 10,
            clients_per_round: 5,
            all_clients: &clients,
            history: &hist,
        };
        let mut s = FedAvg;
        let mut rng = Rng::seed_from_u64(0);
        let sel = s.select(&ctx, &mut rng);
        assert_eq!(sel.len(), 5);
        let mut d = sel.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 5);
    }
}
