//! FedProx (Li et al., 2018): FedAvg-style random selection plus
//! (i) a proximal term mu/2 ||w - w_global||^2 in the client objective
//! (lowered into the `train_prox` HLO entrypoint) and (ii) partial-work
//! toleration — clients may perform a variable fraction of the local
//! workload (§III-B). The paper's second baseline.

use super::{random_sample, Aggregation, SelectionContext, Strategy};
use crate::util::Rng;
use crate::ClientId;

pub struct FedProx {
    /// Minimum fraction of the local workload a client may be asked to
    /// run (gamma-inexactness knob; 1.0 disables partial work).
    pub min_work: f64,
}

impl Default for FedProx {
    fn default() -> Self {
        Self { min_work: 0.5 }
    }
}

impl Strategy for FedProx {
    fn name(&self) -> &'static str {
        "fedprox"
    }

    fn select(&mut self, ctx: &SelectionContext, rng: &mut Rng) -> Vec<ClientId> {
        random_sample(ctx.all_clients, ctx.clients_per_round, rng)
    }

    fn uses_prox(&self) -> bool {
        true
    }

    fn work_fraction(&self, _client: ClientId, rng: &mut Rng) -> f64 {
        if self.min_work >= 1.0 {
            return 1.0;
        }
        rng.range_f64(self.min_work, 1.0)
    }

    fn aggregation(&self) -> Aggregation {
        Aggregation::Synchronous
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn work_fraction_in_range() {
        let s = FedProx::default();
        let mut rng = Rng::seed_from_u64(3);
        for _ in 0..100 {
            let f = s.work_fraction(0, &mut rng);
            assert!((0.5..=1.0).contains(&f));
        }
    }

    #[test]
    fn full_work_when_disabled() {
        let s = FedProx { min_work: 1.0 };
        let mut rng = Rng::seed_from_u64(3);
        assert_eq!(s.work_fraction(0, &mut rng), 1.0);
    }

    #[test]
    fn uses_prox_entrypoint() {
        assert!(FedProx::default().uses_prox());
    }
}
