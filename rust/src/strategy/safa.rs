//! SAFA-lite: a greedy fastest-first selector in the spirit of SAFA
//! (Wu et al. [26]) used for the bias ablation (§VI-A5 takes both the
//! EUR and Bias metrics from SAFA).
//!
//! Full SAFA invokes *all* clients every round and keeps the fastest
//! responses — prohibitive in a pay-per-invocation FaaS setting (§III-B).
//! This lite variant keeps the "prefer the fastest known clients"
//! behaviour at a fixed invocation budget: rookies first (to learn their
//! speed), then ascending EMA training time. It deliberately has *no*
//! fairness mechanism, so its Bias is high — the contrast FedLesScan's
//! violin plots are judged against.
//!
//! Fleet-scale: the speed key is the O(1) cached training-time EMA from
//! the bounded history, and the k fastest are found with a
//! `select_nth_unstable` partition + prefix sort — O(n + k log k)
//! instead of the full O(n log n) sort, with byte-identical output (the
//! comparator totally orders on (EMA, client id)).

use super::{random_sample, training_time_feature, Aggregation, SelectionContext, Strategy};
use crate::util::Rng;
use crate::ClientId;

pub struct SafaLite;

impl Strategy for SafaLite {
    fn name(&self) -> &'static str {
        "safalite"
    }

    fn select(&mut self, ctx: &SelectionContext, rng: &mut Rng) -> Vec<ClientId> {
        let k = ctx.clients_per_round;
        let mut rookies = Vec::new();
        let mut known: Vec<(f64, ClientId)> = Vec::new();
        for &c in ctx.all_clients {
            let h = ctx.history.view(c);
            if h.is_rookie() {
                rookies.push(c);
            } else {
                known.push((training_time_feature(h, 0.5), c));
            }
        }
        if rookies.len() >= k {
            return random_sample(&rookies, k, rng);
        }
        let mut selected = rookies;
        let need = k - selected.len();
        let cmp = |a: &(f64, ClientId), b: &(f64, ClientId)| {
            a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1))
        };
        if need < known.len() {
            // partition the k fastest to the front, then order just them
            known.select_nth_unstable_by(need - 1, cmp);
            known.truncate(need);
        }
        known.sort_by(cmp);
        for (_, c) in known {
            if selected.len() == k {
                break;
            }
            selected.push(c);
        }
        selected
    }

    fn aggregation(&self) -> Aggregation {
        Aggregation::StalenessAware {
            tau: 2,
            normalize: true,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clientdb::HistoryStore;

    #[test]
    fn picks_fastest_known_clients() {
        let clients: Vec<ClientId> = (0..6).collect();
        let mut hist = HistoryStore::new();
        for c in 0..6 {
            hist.record_invocation(c);
            hist.record_success(c, 0, (6 - c) as f64 * 10.0); // 5 is fastest
        }
        let ctx = SelectionContext {
            round: 1,
            max_rounds: 10,
            clients_per_round: 2,
            all_clients: &clients,
            history: &hist,
        };
        let mut s = SafaLite;
        let mut rng = Rng::seed_from_u64(0);
        let sel = s.select(&ctx, &mut rng);
        assert_eq!(sel, vec![5, 4]);
    }

    #[test]
    fn partial_selection_matches_full_sort() {
        // The select_nth fast path must reproduce the full-sort answer
        // exactly, ties broken by client id.
        let n = 500usize;
        let clients: Vec<ClientId> = (0..n).collect();
        let mut hist = HistoryStore::new();
        for c in 0..n {
            hist.record_invocation(c);
            // many duplicate speeds to stress the id tie-break
            hist.record_success(c, 0, ((c * 31) % 13) as f64);
        }
        let ctx = SelectionContext {
            round: 1,
            max_rounds: 10,
            clients_per_round: 40,
            all_clients: &clients,
            history: &hist,
        };
        let mut s = SafaLite;
        let mut rng = Rng::seed_from_u64(1);
        let sel = s.select(&ctx, &mut rng);
        // oracle: full sort on (speed, id)
        let mut all: Vec<(f64, ClientId)> = (0..n)
            .map(|c| (((c * 31) % 13) as f64, c))
            .collect();
        all.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
        let want: Vec<ClientId> = all[..40].iter().map(|&(_, c)| c).collect();
        assert_eq!(sel, want);
    }
}
