//! # fedless — FedLesScan reproduction
//!
//! A serverless federated-learning system reproducing *"FedLesScan:
//! Mitigating Stragglers in Serverless Federated Learning"* (Elzohairy et
//! al., IEEE BigData 2022) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the FedLess controller: client selection
//!   strategies (FedAvg, FedProx, FedLesScan, SAFA-lite), the simulated
//!   FaaS platform, parameter server, client-history database, cost
//!   model and metrics.
//! * **L2 (python/compile, build time)** — JAX forward/backward local
//!   training rounds for the paper's four model families plus a
//!   char-transformer, AOT-lowered to HLO text.
//! * **L1 (python/compile/kernels, build time)** — Pallas kernels for the
//!   dense-layer matmuls and the staleness-weighted aggregation (Eq. 3).
//!
//! Python never runs on the request path. All compute flows through the
//! pluggable [`runtime::Backend`] trait (`train_round` / `evaluate` /
//! `init_params` / `aggregate`):
//!
//! * [`runtime::NativeBackend`] (default) — pure-Rust dense-MLP
//!   forward/backward with the SGD/Adam steps and Eq. 3 aggregation of
//!   `python/compile/kernels/ref.py`; zero external dependencies, so
//!   `cargo test` exercises the full federated loop out of the box;
//! * `runtime::ModelRuntime` (`pjrt` cargo feature) — the AOT HLO
//!   artifacts executed through the PJRT C API (`xla` crate), with
//!   model architectures structurally identical to the paper's.
//!
//! The controller is event-driven: [`sched`] plans every invocation's
//! platform outcome up front (crashes never burn compute), the
//! persistent executor plane ([`exec`]) runs the surviving local
//! training rounds on a long-lived worker pool, and completions replay
//! through a virtual-clock event queue so updates land in true arrival
//! order. Two driving modes share that machinery: the paper's
//! round-synchronous loop, and a rounds-free **continuous mode** that
//! keeps a target number of cohorts in flight and folds each completion
//! into the global model as it lands (Eq. 3 staleness damping keyed to
//! the global's fold generation).
//!
//! Model bytes move through the zero-copy parameter plane ([`params`]):
//! the global model is an immutable `Arc<[f32]>` snapshot shared by the
//! parameter server, the FedProx anchor and every concurrent
//! `TrainRequest`, and aggregation streams updates into a single O(P)
//! accumulator (`Backend::begin_fold`) as they arrive instead of
//! materializing O(k x P) batches.
//!
//! Entry points: [`coordinator::Controller`] drives one experiment;
//! [`repro`] regenerates every table and figure of the paper's §VI.

pub mod clientdb;
pub mod clustering;
pub mod config;
pub mod coordinator;
pub mod cost;
pub mod data;
pub mod exec;
pub mod faas;
pub mod metrics;
pub mod params;
pub mod paramsvr;
pub mod repro;
pub mod runtime;
pub mod sched;
pub mod strategy;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Stable client identifier: index into the experiment's client registry.
pub type ClientId = usize;
