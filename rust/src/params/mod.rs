//! The zero-copy parameter plane: immutable [`ParamBlock`] snapshots
//! (`Arc<[f32]>`) plus the chunk-parallel weighted-sum engine behind the
//! backends' fold-style aggregation API.
//!
//! Every layer that hands a full model around — the parameter server's
//! global blob, the FedProx anchor, the staleness buffer, the
//! aggregation fold — shares one refcounted allocation instead of
//! deep-copying `Vec<f32>`s. The only copy left is the one-time
//! "freeze" when a freshly trained (mutable) parameter vector becomes a
//! snapshot; after that, clones are pointer bumps.
//!
//! The weighted sum itself ([`fold_weighted_into`]) is element-wise
//! (`acc[i] += w_k * u_k[i]`, entries folded in registration order), so
//! chunking the parameter range across scoped worker threads never
//! changes any element's accumulation order: the result is
//! **bit-identical across worker counts**, including the serial path.
//! That determinism contract is tested here and in `tests/proptests.rs`.
//!
//! [`shard`] cuts the same flat vector into independently-locked shards
//! (shard boundaries are chunk boundaries, so the invariance argument
//! carries over verbatim: any shard count is bit-identical to the
//! unsharded fold). [`quant`] adds int8 symmetric per-shard client
//! updates with error-feedback residuals.

pub mod quant;
pub mod shard;

pub use quant::{
    dequantize, dequantize_into, quantize, quantize_topk, wire_bytes_estimate, ErrorFeedback,
    QuantizedUpdate,
};
pub use shard::{default_shards, resolve_shards, shards_override, ShardLayout, ShardedAccumulator};

use std::sync::Arc;

/// An immutable, cheaply clonable snapshot of one flat parameter
/// vector. `Clone` is an `Arc` refcount bump; the float data is shared.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamBlock(Arc<[f32]>);

impl ParamBlock {
    pub fn as_slice(&self) -> &[f32] {
        &self.0
    }

    pub fn len(&self) -> usize {
        self.0.len()
    }

    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }

    /// Size of the shared float data in bytes (the param-plane
    /// accounting unit).
    pub fn bytes(&self) -> usize {
        self.0.len() * std::mem::size_of::<f32>()
    }

    /// Do two blocks share the same allocation? The zero-copy tests pin
    /// snapshot semantics with this.
    pub fn ptr_eq(&self, other: &ParamBlock) -> bool {
        Arc::ptr_eq(&self.0, &other.0)
    }

    /// Zero-copy view of shard `i` under `layout`: a borrowed slice of
    /// the shared allocation, so per-shard anchor reads and snapshot
    /// clones never copy the flat vector.
    ///
    /// Panics if the layout length differs from the block length.
    pub fn shard(&self, layout: &ShardLayout, i: usize) -> &[f32] {
        assert_eq!(layout.len(), self.len(), "shard layout length mismatch");
        &self.0[layout.range(i)]
    }
}

impl From<Vec<f32>> for ParamBlock {
    /// Freeze a trained parameter vector into a snapshot. This is the
    /// parameter plane's single remaining copy (the `Arc<[f32]>` header
    /// forces a reallocation); everything downstream shares it.
    fn from(v: Vec<f32>) -> Self {
        Self(v.into())
    }
}

impl From<&[f32]> for ParamBlock {
    fn from(s: &[f32]) -> Self {
        Self(Arc::from(s))
    }
}

impl std::ops::Deref for ParamBlock {
    type Target = [f32];

    fn deref(&self) -> &[f32] {
        &self.0
    }
}

/// Default worker count for chunk-parallel folds and the executor
/// pool's training fleet (one per available core). A `FEDLESS_WORKERS`
/// environment override (clamped ≥ 1) wins, so CI and the 50k scale
/// smokes can pin the pool size on shared runners.
pub fn default_workers() -> usize {
    if let Some(w) = workers_override(std::env::var("FEDLESS_WORKERS").ok().as_deref()) {
        return w;
    }
    std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1)
}

/// Parse a `FEDLESS_WORKERS`-style override: `None`/empty/garbage fall
/// through to the core count; a parsed value is clamped to ≥ 1 (a pool
/// of zero workers would deadlock every job). Pure so the clamp rules
/// are unit-testable without mutating process environment.
pub fn workers_override(raw: Option<&str>) -> Option<usize> {
    raw.and_then(|s| s.trim().parse::<usize>().ok())
        .map(|w| w.max(1))
}

/// Minimum multiply-accumulate count (`k * P`) before a fold fans out
/// across threads; below this the scoped-spawn overhead dominates the
/// arithmetic and the serial path wins.
const MIN_PARALLEL_MADDS: usize = 1 << 18;

/// Worker-count heuristic for a fold of `k` updates over `param_count`
/// parameters: one worker per [`MIN_PARALLEL_MADDS`] of total work,
/// capped at the core count. The old all-or-nothing gate kept
/// preset-sized (~10⁵-param) streamed entries serial forever because
/// the streaming path priced each entry at `k = 1`; the proportional
/// ramp (plus the streaming folds now pricing their whole expected
/// cohort up front) lets them fan out once the cohort is large enough —
/// e.g. the mnist preset (P = 25450) crosses to 2 workers at k = 11.
/// Every choice produces bit-identical results; the crossover is pinned
/// by a `benches/micro.rs` row and the unit test below.
pub fn fold_workers(param_count: usize, k: usize) -> usize {
    param_count
        .saturating_mul(k)
        .div_ceil(MIN_PARALLEL_MADDS)
        .clamp(1, default_workers())
}

/// Fold `acc[i] += w * u[i]` for every `(u, w)` entry, in entry order,
/// chunk-parallel over `workers` scoped threads (`workers == 1` runs
/// serially on the caller's thread, spawn-free). Zero-weight entries
/// are skipped, matching the batch scalar reference. Because each
/// element's accumulation order is the entry order regardless of how
/// the parameter range is chunked, the result is bit-identical for
/// every worker count.
///
/// Panics if any entry's length differs from `acc.len()` (the backends
/// validate shapes before registering entries).
pub fn fold_weighted_into(acc: &mut [f32], entries: &[(&[f32], f32)], workers: usize) {
    for (u, _) in entries {
        assert_eq!(u.len(), acc.len(), "fold entry length mismatch");
    }
    let workers = workers.clamp(1, acc.len().max(1));
    if workers == 1 {
        fold_chunk(acc, entries, 0);
        return;
    }
    let chunk = acc.len().div_ceil(workers);
    std::thread::scope(|scope| {
        for (ci, acc_chunk) in acc.chunks_mut(chunk).enumerate() {
            scope.spawn(move || fold_chunk(acc_chunk, entries, ci * chunk));
        }
    });
}

/// Serial weighted fold of one contiguous parameter range. The
/// `acc[i] += w * u[i]` pass runs through the kernel plane's axpy
/// ([`crate::runtime::kernel`]) — its AVX2 path is lane-wise
/// bit-identical to the scalar seed loop, so the fold's
/// worker/shard-count invariance contract is untouched.
fn fold_chunk(acc: &mut [f32], entries: &[(&[f32], f32)], offset: usize) {
    let kr = crate::runtime::kernel::active();
    for &(u, w) in entries {
        if w == 0.0 {
            continue;
        }
        kr.axpy(acc, &u[offset..offset + acc.len()], w);
    }
}

/// Running/peak accounting of live parameter-plane bytes. This is an
/// accounting gauge, not an allocator hook: the coordinator reports
/// buffers when they become live (a trained update materializes, the
/// fold accumulator is allocated, a snapshot freezes) and releases them
/// at their last logical use. Tracked state is model-weight buffers
/// only — the global snapshot, per-update parameter vectors, the
/// staleness buffer and the fold accumulator; optimizer moments and
/// feature shards belong to the compute plane, not the parameter plane.
#[derive(Debug, Clone, Copy, Default)]
pub struct PlaneGauge {
    live: usize,
    peak: usize,
}

impl PlaneGauge {
    pub fn add(&mut self, bytes: usize) {
        self.live += bytes;
        self.peak = self.peak.max(self.live);
    }

    pub fn sub(&mut self, bytes: usize) {
        self.live = self.live.saturating_sub(bytes);
    }

    pub fn live(&self) -> usize {
        self.live
    }

    /// Peak live bytes since the last [`PlaneGauge::begin_window`].
    pub fn peak(&self) -> usize {
        self.peak
    }

    /// Start a fresh peak window (per-round accounting); live bytes
    /// carry over.
    pub fn begin_window(&mut self) {
        self.peak = self.live;
    }
}

/// Batch scalar reference for the Eq. 3 inner sum — the seed
/// `NativeBackend::aggregate` loop, kept verbatim as the oracle the
/// golden/property tests pin the streaming fold against.
pub fn weighted_sum_scalar(updates: &[&[f32]], weights: &[f32]) -> Vec<f32> {
    assert_eq!(updates.len(), weights.len(), "updates vs weights");
    let p = updates.first().map_or(0, |u| u.len());
    let mut out = vec![0.0f32; p];
    for (u, &w) in updates.iter().zip(weights) {
        if w == 0.0 {
            continue;
        }
        for (o, x) in out.iter_mut().zip(*u) {
            *o += w * x;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_clone_shares_storage() {
        let a = ParamBlock::from(vec![1.0f32, 2.0, 3.0]);
        let b = a.clone();
        assert!(a.ptr_eq(&b));
        assert_eq!(a.as_slice(), &[1.0, 2.0, 3.0]);
        assert_eq!(a.len(), 3);
        assert!(!a.is_empty());
        assert_eq!(a.bytes(), 12);
        // a fresh block over equal contents is a different allocation
        let c = ParamBlock::from(vec![1.0f32, 2.0, 3.0]);
        assert_eq!(a, c);
        assert!(!a.ptr_eq(&c));
    }

    #[test]
    fn fold_is_bit_identical_across_worker_counts() {
        let p = 1031; // prime: uneven chunks
        let u1: Vec<f32> = (0..p).map(|i| (i % 17) as f32 * 0.3 - 1.0).collect();
        let u2: Vec<f32> = (0..p).map(|i| (i % 5) as f32 * -0.7).collect();
        let u3: Vec<f32> = (0..p).map(|i| (i % 29) as f32 * 0.01).collect();
        let entries: Vec<(&[f32], f32)> = vec![(&u1, 0.4), (&u2, 0.0), (&u3, 0.6)];
        let scalar = weighted_sum_scalar(&[&u1, &u2, &u3], &[0.4, 0.0, 0.6]);
        for workers in [1usize, 2, 3, 8, 64] {
            let mut acc = vec![0.0f32; p];
            fold_weighted_into(&mut acc, &entries, workers);
            assert_eq!(acc, scalar, "workers={workers}");
        }
    }

    #[test]
    fn zero_weight_entries_are_skipped_not_multiplied() {
        // skip semantics matter: 0.0 * NaN would poison the accumulator
        let poison = vec![f32::NAN; 64];
        let good = vec![1.0f32; 64];
        let entries: Vec<(&[f32], f32)> = vec![(&poison, 0.0), (&good, 0.5)];
        let mut acc = vec![0.0f32; 64];
        fold_weighted_into(&mut acc, &entries, 4);
        assert!(acc.iter().all(|&x| x == 0.5));
    }

    #[test]
    fn fold_workers_gates_on_total_work() {
        assert_eq!(fold_workers(100, 2), 1, "tiny folds stay serial");
        assert!(fold_workers(1 << 20, 8) >= 1);
        assert!(default_workers() >= 1);
    }

    #[test]
    fn fold_workers_ramps_proportionally_to_total_work() {
        // The mnist preset (P = 25450) must cross from serial to 2
        // workers at k = 11 (25450 * 11 = 279950 > 2^18 = 262144) —
        // the satellite retune: preset-sized streamed folds fan out
        // once the cohort warrants it instead of staying serial.
        let p = 25450;
        assert_eq!(fold_workers(p, 10), 1, "just under one work quantum");
        if default_workers() >= 2 {
            assert_eq!(fold_workers(p, 11), 2, "crossover at k = 11");
        }
        // the ramp is monotone and capped at the core count
        let mut last = 0;
        for k in 1..=256 {
            let w = fold_workers(p, k);
            assert!(w >= last, "ramp must be monotone in k");
            assert!(w <= default_workers(), "capped at cores");
            last = w;
        }
    }

    #[test]
    fn workers_override_parses_and_clamps() {
        assert_eq!(workers_override(Some("3")), Some(3));
        assert_eq!(workers_override(Some(" 16 ")), Some(16), "whitespace trimmed");
        assert_eq!(workers_override(Some("0")), Some(1), "clamped to >= 1");
        assert_eq!(workers_override(Some("")), None);
        assert_eq!(workers_override(Some("lots")), None);
        assert_eq!(workers_override(Some("-2")), None);
        assert_eq!(workers_override(None), None);
    }

    #[test]
    fn fedless_workers_env_overrides_default() {
        // Regression for the FEDLESS_WORKERS contract: the env override
        // wins over the core count and is clamped to >= 1. Env mutation
        // is process-global, so both cases run inside this one test
        // (cargo runs tests in threads; restore the prior value after).
        let prior = std::env::var("FEDLESS_WORKERS").ok();
        std::env::set_var("FEDLESS_WORKERS", "3");
        assert_eq!(default_workers(), 3);
        std::env::set_var("FEDLESS_WORKERS", "0");
        assert_eq!(default_workers(), 1, "zero workers would deadlock");
        std::env::set_var("FEDLESS_WORKERS", "not-a-number");
        assert!(default_workers() >= 1, "garbage falls back to cores");
        match prior {
            Some(v) => std::env::set_var("FEDLESS_WORKERS", v),
            None => std::env::remove_var("FEDLESS_WORKERS"),
        }
    }

    #[test]
    fn fedless_shards_env_overrides_config_and_default() {
        // Shard-count precedence: FEDLESS_SHARDS env ▸ config `shards`
        // ▸ core count. Sharding is bit-identical at any count, so a
        // concurrent test seeing the temporary value stays correct.
        let prior = std::env::var("FEDLESS_SHARDS").ok();
        std::env::set_var("FEDLESS_SHARDS", "5");
        assert_eq!(default_shards(), 5);
        assert_eq!(resolve_shards(Some(3)), 5, "env wins over config");
        std::env::remove_var("FEDLESS_SHARDS");
        assert_eq!(resolve_shards(Some(3)), 3, "config wins over cores");
        assert_eq!(resolve_shards(Some(0)), 1, "config clamped to >= 1");
        assert!(resolve_shards(None) >= 1);
        match prior {
            Some(v) => std::env::set_var("FEDLESS_SHARDS", v),
            None => std::env::remove_var("FEDLESS_SHARDS"),
        }
    }

    #[test]
    fn plane_gauge_tracks_live_and_windowed_peak() {
        let mut g = PlaneGauge::default();
        g.add(100);
        g.add(50);
        assert_eq!(g.live(), 150);
        assert_eq!(g.peak(), 150);
        g.sub(120);
        assert_eq!(g.live(), 30);
        assert_eq!(g.peak(), 150, "peak survives releases");
        g.begin_window();
        assert_eq!(g.peak(), 30, "window restarts the peak at live");
        g.add(10);
        assert_eq!(g.peak(), 40);
        g.sub(1000);
        assert_eq!(g.live(), 0, "release saturates at zero");
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn fold_rejects_mismatched_lengths() {
        let short = vec![0.0f32; 3];
        let entries: Vec<(&[f32], f32)> = vec![(&short, 1.0)];
        let mut acc = vec![0.0f32; 4];
        fold_weighted_into(&mut acc, &entries, 1);
    }
}
