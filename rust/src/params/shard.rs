//! Sharded view of the parameter plane: one flat parameter vector cut
//! into N contiguous, independently-locked shards so folds, FedProx
//! anchor reads and snapshot clones touching different shards never
//! serialize on a single accumulator lock.
//!
//! The shard count resolves `FEDLESS_SHARDS` env ▸ config `shards` ▸
//! core-count default ([`resolve_shards`]). Sharding is a **layout**
//! choice, never a numeric one: shard boundaries are just chunk
//! boundaries of the flat vector, and every element accumulates its
//! fold entries in registration order regardless of which shard owns
//! it, so a sharded fold is bit-identical to the unsharded scalar
//! reference for any shard count (pinned by `tests/proptests.rs`).

use std::ops::Range;
use std::sync::Mutex;

use super::{default_workers, fold_weighted_into, workers_override};

/// How one flat parameter vector of `len` floats is cut into `shards`
/// contiguous ranges. Balanced layout: the first `len % shards` shards
/// hold one extra element, so shard sizes differ by at most one and the
/// concatenation of [`ShardLayout::range`]s is exactly `0..len`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardLayout {
    len: usize,
    shards: usize,
}

impl ShardLayout {
    /// `shards` is clamped to `[1, len.max(1)]` — more shards than
    /// elements would only manufacture empty locks.
    pub fn new(len: usize, shards: usize) -> Self {
        Self {
            len,
            shards: shards.clamp(1, len.max(1)),
        }
    }

    /// Total element count of the flat vector.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Number of shards (post-clamp).
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Element range owned by shard `i`.
    pub fn range(&self, i: usize) -> Range<usize> {
        assert!(i < self.shards, "shard {i} out of {}", self.shards);
        let base = self.len / self.shards;
        let rem = self.len % self.shards;
        let start = i * base + i.min(rem);
        let end = start + base + usize::from(i < rem);
        start..end
    }

    /// Shard owning flat element index `elem`.
    pub fn shard_of(&self, elem: usize) -> usize {
        assert!(elem < self.len, "element {elem} out of {}", self.len);
        let base = self.len / self.shards;
        let rem = self.len % self.shards;
        let fat = rem * (base + 1); // elements owned by the base+1 shards
        if elem < fat {
            elem / (base + 1)
        } else {
            rem + (elem - fat) / base
        }
    }

    /// Iterate every shard's range in order (their concatenation is
    /// `0..len`).
    pub fn ranges(&self) -> impl Iterator<Item = Range<usize>> + '_ {
        (0..self.shards).map(|i| self.range(i))
    }
}

/// Parse a `FEDLESS_SHARDS`-style override: `None`/empty/garbage fall
/// through; a parsed value is clamped to ≥ 1. Pure, mirroring
/// [`workers_override`], so the clamp rules stay unit-testable without
/// mutating process environment.
pub fn shards_override(raw: Option<&str>) -> Option<usize> {
    workers_override(raw)
}

/// Default shard count: the `FEDLESS_SHARDS` env override (clamped
/// ≥ 1) wins, else one shard per available core.
pub fn default_shards() -> usize {
    if let Some(s) = shards_override(std::env::var("FEDLESS_SHARDS").ok().as_deref()) {
        return s;
    }
    std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1)
}

/// Resolve the parameter-plane shard count with the documented
/// precedence: `FEDLESS_SHARDS` env ▸ config `shards` ▸ core-count
/// default. Any choice is bit-identical; this only tunes lock
/// granularity and fold parallelism.
pub fn resolve_shards(config: Option<usize>) -> usize {
    if let Some(s) = shards_override(std::env::var("FEDLESS_SHARDS").ok().as_deref()) {
        return s;
    }
    match config {
        Some(s) => s.max(1),
        None => std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1),
    }
}

/// A weighted-sum accumulator cut into independently-locked shards.
///
/// Each shard is its own `Mutex<Vec<f32>>`, so concurrent
/// [`ShardedAccumulator::accumulate`] calls from different threads only
/// contend per shard, and the intra-call fan-out gives each worker a
/// disjoint shard subset (no lock contention at all on the hot path).
///
/// Determinism: within one accumulate call every shard folds the same
/// `(update, weight)` entry, so per-element accumulation order equals
/// the call order. Callers that need bit-reproducibility (the
/// coordinator's single-threaded event replay) establish one entry
/// order; the locks make *concurrent* callers safe, not bit-pinned.
pub struct ShardedAccumulator {
    layout: ShardLayout,
    shards: Vec<Mutex<Vec<f32>>>,
}

impl ShardedAccumulator {
    pub fn new(layout: ShardLayout) -> Self {
        let shards = layout
            .ranges()
            .map(|r| Mutex::new(vec![0.0f32; r.len()]))
            .collect();
        Self { layout, shards }
    }

    pub fn layout(&self) -> &ShardLayout {
        &self.layout
    }

    /// Bytes of parameter data held: O(P) total across shards.
    pub fn held_bytes(&self) -> usize {
        self.layout.len() * std::mem::size_of::<f32>()
    }

    /// Fold `acc[i] += weight * update[i]` across every shard,
    /// `workers` scoped threads each owning a strided, disjoint shard
    /// subset (`workers == 1` loops shards serially on the caller's
    /// thread, spawn-free). Zero-weight entries are skipped, matching
    /// [`fold_weighted_into`]. Takes `&self`: concurrent folds are
    /// safe, serialized per shard by each shard's own lock.
    ///
    /// Panics if `update.len()` differs from the layout length.
    pub fn accumulate(&self, update: &[f32], weight: f32, workers: usize) {
        assert_eq!(update.len(), self.layout.len(), "fold entry length mismatch");
        if weight == 0.0 {
            return;
        }
        let workers = workers.clamp(1, self.shards.len());
        if workers == 1 {
            for (i, shard) in self.shards.iter().enumerate() {
                self.fold_shard(i, shard, update, weight);
            }
            return;
        }
        std::thread::scope(|scope| {
            for w in 0..workers {
                scope.spawn(move || {
                    for (i, shard) in self.shards.iter().enumerate().skip(w).step_by(workers) {
                        self.fold_shard(i, shard, update, weight);
                    }
                });
            }
        });
    }

    /// Fold one entry into one shard behind its own lock.
    fn fold_shard(&self, i: usize, shard: &Mutex<Vec<f32>>, update: &[f32], weight: f32) {
        let range = self.layout.range(i);
        let mut acc = shard.lock().expect("shard lock poisoned");
        fold_weighted_into(&mut acc, &[(&update[range], weight)], 1);
    }

    /// A copy of shard `i`'s current accumulator contents.
    pub fn shard_snapshot(&self, i: usize) -> Vec<f32> {
        self.shards[i].lock().expect("shard lock poisoned").clone()
    }

    /// Concatenate the shards back into the flat vector (bit-identical
    /// to an unsharded fold of the same entry sequence).
    pub fn finish(self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.layout.len());
        for shard in self.shards {
            out.extend_from_slice(&shard.into_inner().expect("shard lock poisoned"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::weighted_sum_scalar;

    #[test]
    fn layout_ranges_partition_the_vector() {
        for (len, shards) in [(0usize, 1usize), (1, 4), (10, 3), (10, 7), (1031, 8), (64, 64)] {
            let l = ShardLayout::new(len, shards);
            let mut next = 0usize;
            for (i, r) in l.ranges().enumerate() {
                assert_eq!(r.start, next, "len={len} shards={shards} shard {i}");
                assert!(!r.is_empty(), "clamped layout never has empty shards");
                for e in r.clone() {
                    assert_eq!(l.shard_of(e), i);
                }
                next = r.end;
            }
            assert_eq!(next, len);
            // balanced: sizes differ by at most one
            let sizes: Vec<usize> = l.ranges().map(|r| r.len()).collect();
            let (min, max) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(max - min <= 1, "unbalanced layout {sizes:?}");
        }
    }

    #[test]
    fn layout_clamps_shard_count() {
        assert_eq!(ShardLayout::new(4, 0).shards(), 1);
        assert_eq!(ShardLayout::new(4, 9).shards(), 4);
        assert_eq!(ShardLayout::new(0, 5).shards(), 1);
        assert!(ShardLayout::new(0, 5).is_empty());
    }

    #[test]
    fn sharded_fold_is_bit_identical_to_scalar_oracle() {
        let p = 1031; // prime: uneven shard sizes
        let u1: Vec<f32> = (0..p).map(|i| (i % 17) as f32 * 0.3 - 1.0).collect();
        let u2: Vec<f32> = (0..p).map(|i| (i % 5) as f32 * -0.7).collect();
        let u3: Vec<f32> = (0..p).map(|i| (i % 29) as f32 * 0.01).collect();
        let scalar = weighted_sum_scalar(&[&u1, &u2, &u3], &[0.4, 0.0, 0.6]);
        for shards in [1usize, 2, 8, 17] {
            for workers in [1usize, 3] {
                let acc = ShardedAccumulator::new(ShardLayout::new(p, shards));
                for (u, w) in [(&u1, 0.4f32), (&u2, 0.0), (&u3, 0.6)] {
                    acc.accumulate(u, w, workers);
                }
                assert_eq!(
                    acc.finish(),
                    scalar,
                    "shards={shards} workers={workers} drifted from the oracle"
                );
            }
        }
    }

    #[test]
    fn concurrent_folds_land_every_entry() {
        // The per-shard locks make concurrent accumulate calls safe;
        // with commutative-exact entries (integers) the result is the
        // full sum regardless of interleaving.
        let p = 257;
        let acc = ShardedAccumulator::new(ShardLayout::new(p, 4));
        std::thread::scope(|scope| {
            for t in 0..4 {
                let acc = &acc;
                scope.spawn(move || {
                    let u: Vec<f32> = vec![(t + 1) as f32; p];
                    for _ in 0..8 {
                        acc.accumulate(&u, 1.0, 2);
                    }
                });
            }
        });
        let want = 8.0 * (1.0 + 2.0 + 3.0 + 4.0);
        assert!(ShardedAccumulator::new(ShardLayout::new(p, 4))
            .finish()
            .iter()
            .all(|&x| x == 0.0));
        assert!(acc.finish().iter().all(|&x| x == want));
    }

    #[test]
    fn shards_override_and_resolution() {
        assert_eq!(shards_override(Some("5")), Some(5));
        assert_eq!(shards_override(Some("0")), Some(1), "clamped to >= 1");
        assert_eq!(shards_override(Some("")), None);
        assert_eq!(shards_override(None), None);
        assert!(default_shards() >= 1);
        // config wins over the core default when the env is unset; the
        // env-over-config precedence is covered with the env tests in
        // the parent module (env mutation is process-global).
        assert!(resolve_shards(None) >= 1);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn accumulate_rejects_mismatched_lengths() {
        let acc = ShardedAccumulator::new(ShardLayout::new(8, 2));
        acc.accumulate(&[0.0; 7], 1.0, 1);
    }
}
