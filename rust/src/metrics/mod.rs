//! Experiment metrics (§VI-A5): accuracy, Effective Update Ratio, bias,
//! durations, cost — recorded per round and summarized per experiment,
//! with CSV/JSON writers for the table/figure regeneration harness.

use std::collections::HashMap;
use std::path::Path;

use crate::util::Json;
use crate::{ClientId, Result};

/// Per-round record. Times are virtual-clock seconds.
#[derive(Debug, Clone)]
pub struct RoundRecord {
    pub round: u32,
    pub selected: Vec<ClientId>,
    /// On-time successes this round.
    pub successes: usize,
    /// Invoked but missed (slow or crashed).
    pub failures: usize,
    /// Stale updates folded into this round's aggregation (FedLesScan).
    pub stale_applied: usize,
    /// Selected clients skipped because their previous invocation was
    /// still in flight (the scheduler never re-invokes mid-flight).
    pub in_flight_skipped: usize,
    /// Round duration: slowest on-time client or the round timeout.
    pub duration_s: f64,
    /// Central accuracy after this round's aggregation (if evaluated).
    pub accuracy: Option<f32>,
    pub eval_loss: Option<f32>,
    /// Mean client training loss over on-time updates.
    pub train_loss: Option<f32>,
    /// Cost incurred this round ($).
    pub cost: f64,
    /// Effective Update Ratio of this round (successes / invoked; the
    /// in-flight-skipped clients are not in the denominator because they
    /// were never invoked).
    pub eur: f64,
    /// Wall-clock seconds spent in this round's client selection
    /// (tier partitioning, behaviour clustering, cohort sampling) —
    /// real machine time, not virtual time, excluded from the
    /// determinism goldens. The fleet-scale acceptance metric: it must
    /// stay sub-second at 100k+ clients.
    pub select_wall_s: f64,
    /// Wall-clock seconds spent in this round's aggregation fold (real
    /// machine time, not virtual time — excluded from the determinism
    /// goldens).
    pub agg_wall_s: f64,
    /// Peak live parameter-plane bytes during this round: model-weight
    /// buffers only (global snapshot, per-update vectors, staleness
    /// buffer, and the aggregation fold's real holdings — O(P) for the
    /// native streaming accumulator, O(k × P) for a buffered batch
    /// fold), tracked by [`crate::params::PlaneGauge`].
    pub param_plane_peak_bytes: usize,
    /// Simulated network bytes sent server -> clients this round: every
    /// dispatched invocation downloads the full f32 global model.
    pub bytes_down: usize,
    /// Simulated network bytes sent clients -> server this round: raw
    /// f32 updates by default, or the quantized wire size (int8 codes +
    /// per-shard scales, plus indices for top-k) when
    /// `quantize_updates` is on.
    pub bytes_up: usize,
    /// Clients whose behaviour-cluster assignment was recomputed during
    /// this round's selection (affected cell-components only on the
    /// incremental path; the whole participant tier on a full rebuild).
    /// 0 for strategies without persistent cluster state.
    pub reclustered_clients: usize,
    /// Clustered participants whose standing assignment was reused
    /// as-is by this round's selection (the incremental-path cache).
    pub cluster_cache_hits: usize,
}

impl RoundRecord {
    /// Effective Update Ratio. A round that invoked nobody delivered no
    /// effective updates, so its EUR is 0 — not the vacuous 1.0 the seed
    /// reported, which inflated mean EUR whenever `adaptive_clients`
    /// clamping or a strategy produced an empty selection.
    pub fn compute_eur(successes: usize, invoked: usize) -> f64 {
        if invoked == 0 {
            return 0.0;
        }
        successes as f64 / invoked as f64
    }
}

/// Full experiment result: the §VI metrics plus the raw timeline.
#[derive(Debug, Clone)]
pub struct ExperimentResult {
    /// Identification
    pub dataset: String,
    pub strategy: String,
    pub scenario: String,
    pub seed: u64,
    /// Timeline
    pub rounds: Vec<RoundRecord>,
    /// client -> number of invocations across the experiment (Fig. 3c).
    pub invocations: HashMap<ClientId, u32>,
    /// Totals
    pub total_time_s: f64,
    pub total_cost: f64,
    pub final_accuracy: f32,
}

impl ExperimentResult {
    /// Mean EUR across rounds (Table II columns).
    pub fn mean_eur(&self) -> f64 {
        if self.rounds.is_empty() {
            return 1.0;
        }
        self.rounds.iter().map(|r| r.eur).sum::<f64>() / self.rounds.len() as f64
    }

    /// Bias (§VI-A5, from SAFA [26]): difference between the most- and
    /// least-invoked client's invocation counts, over all registered
    /// clients (clients never invoked count as 0).
    pub fn bias(&self, n_clients: usize) -> u32 {
        let max = self.invocations.values().copied().max().unwrap_or(0);
        let min = if self.invocations.len() < n_clients {
            0
        } else {
            self.invocations.values().copied().min().unwrap_or(0)
        };
        max - min
    }

    /// First round at which accuracy crossed `target`, if ever (Fig. 3a
    /// convergence-speed comparisons).
    pub fn rounds_to_accuracy(&self, target: f32) -> Option<u32> {
        self.rounds
            .iter()
            .find(|r| r.accuracy.map_or(false, |a| a >= target))
            .map(|r| r.round)
    }

    /// Invocation count distribution (the Fig. 3c violin input).
    pub fn invocation_distribution(&self, n_clients: usize) -> Vec<u32> {
        (0..n_clients)
            .map(|c| self.invocations.get(&c).copied().unwrap_or(0))
            .collect()
    }

    /// Write the per-round timeline as CSV (Fig. 3a/3b series).
    pub fn write_timeline_csv(&self, path: &Path) -> Result<()> {
        let mut out = String::from(
            "round,selected,successes,failures,stale_applied,in_flight_skipped,duration_s,accuracy,eval_loss,train_loss,cost,eur,select_wall_s,agg_wall_s,param_plane_peak_bytes,bytes_down,bytes_up,reclustered_clients,cluster_cache_hits\n",
        );
        for r in &self.rounds {
            out.push_str(&format!(
                "{},{},{},{},{},{},{:.3},{},{},{},{:.6},{:.4},{:.6},{:.6},{},{},{},{},{}\n",
                r.round,
                r.selected.len(),
                r.successes,
                r.failures,
                r.stale_applied,
                r.in_flight_skipped,
                r.duration_s,
                r.accuracy.map_or(String::new(), |v| format!("{v:.4}")),
                r.eval_loss.map_or(String::new(), |v| format!("{v:.4}")),
                r.train_loss.map_or(String::new(), |v| format!("{v:.4}")),
                r.cost,
                r.eur,
                r.select_wall_s,
                r.agg_wall_s,
                r.param_plane_peak_bytes,
                r.bytes_down,
                r.bytes_up,
                r.reclustered_clients,
                r.cluster_cache_hits,
            ));
        }
        std::fs::write(path, out)?;
        Ok(())
    }

    /// Serialize the full result (rounds + invocation counts) to JSON.
    pub fn to_json(&self) -> Json {
        let rounds: Vec<Json> = self
            .rounds
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("round", Json::num(r.round as f64)),
                    (
                        "selected",
                        Json::Arr(r.selected.iter().map(|&c| Json::num(c as f64)).collect()),
                    ),
                    ("successes", Json::num(r.successes as f64)),
                    ("failures", Json::num(r.failures as f64)),
                    ("stale_applied", Json::num(r.stale_applied as f64)),
                    ("in_flight_skipped", Json::num(r.in_flight_skipped as f64)),
                    ("duration_s", Json::num(r.duration_s)),
                    (
                        "accuracy",
                        r.accuracy.map_or(Json::Null, |v| Json::num(v as f64)),
                    ),
                    (
                        "eval_loss",
                        r.eval_loss.map_or(Json::Null, |v| Json::num(v as f64)),
                    ),
                    (
                        "train_loss",
                        r.train_loss.map_or(Json::Null, |v| Json::num(v as f64)),
                    ),
                    ("cost", Json::num(r.cost)),
                    ("eur", Json::num(r.eur)),
                    ("select_wall_s", Json::num(r.select_wall_s)),
                    ("agg_wall_s", Json::num(r.agg_wall_s)),
                    (
                        "param_plane_peak_bytes",
                        Json::num(r.param_plane_peak_bytes as f64),
                    ),
                    ("bytes_down", Json::num(r.bytes_down as f64)),
                    ("bytes_up", Json::num(r.bytes_up as f64)),
                    (
                        "reclustered_clients",
                        Json::num(r.reclustered_clients as f64),
                    ),
                    (
                        "cluster_cache_hits",
                        Json::num(r.cluster_cache_hits as f64),
                    ),
                ])
            })
            .collect();
        let mut invocations: Vec<(ClientId, u32)> =
            self.invocations.iter().map(|(&c, &n)| (c, n)).collect();
        invocations.sort_unstable();
        Json::obj(vec![
            ("dataset", Json::str(self.dataset.clone())),
            ("strategy", Json::str(self.strategy.clone())),
            ("scenario", Json::str(self.scenario.clone())),
            ("seed", Json::num(self.seed as f64)),
            ("total_time_s", Json::num(self.total_time_s)),
            ("total_cost", Json::num(self.total_cost)),
            ("final_accuracy", Json::num(self.final_accuracy as f64)),
            ("mean_eur", Json::num(self.mean_eur())),
            ("rounds", Json::Arr(rounds)),
            (
                "invocations",
                Json::Arr(
                    invocations
                        .iter()
                        .map(|&(c, n)| {
                            Json::arr(vec![Json::num(c as f64), Json::num(n as f64)])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn write_json(&self, path: &Path) -> Result<()> {
        self.to_json().write_file(path)
    }
}

/// Continuous-mode reporting slice: rounds don't exist, so progress is
/// bucketed into fixed-duration virtual-time windows (one round-timeout
/// each). Counts are attributed to the window in which the event
/// *completed*; `dispatched` is attributed to the window in which the
/// invocation departed.
#[derive(Debug, Clone)]
pub struct WindowRecord {
    pub window: u32,
    pub start_s: f64,
    pub end_s: f64,
    /// Invocations dispatched during this window.
    pub dispatched: usize,
    /// Invocations that completed (any outcome) during this window.
    pub completions: usize,
    /// Completions folded into the global model.
    pub folds: usize,
    /// Completions that crashed (transient failure or hard timeout).
    pub crashes: usize,
    /// Completions whose departed generation aged past tau: returned a
    /// model too stale to fold (Eq. 3 discard).
    pub expired: usize,
    /// Folds per virtual second within this window.
    pub updates_per_s: f64,
    /// folds / completions in this window (the continuous analogue of
    /// per-round EUR).
    pub effective_update_ratio: f64,
    /// Max concurrent in-flight invocations observed in this window.
    pub in_flight_peak: usize,
    /// Wall-clock seconds spent selecting replacement clients during
    /// this window (real machine time, excluded from determinism
    /// goldens) — the continuous analogue of the per-round
    /// `select_wall_s`.
    pub select_wall_s: f64,
    /// Clients whose cluster assignment was recomputed by selections in
    /// this window (incremental path; 0 for stateless strategies).
    pub reclustered_clients: usize,
    /// Clustered participants whose standing assignment was reused by
    /// selections in this window.
    pub cluster_cache_hits: usize,
}

/// Full continuous-mode experiment result (`--mode continuous`).
#[derive(Debug, Clone)]
pub struct ContinuousResult {
    /// Identification
    pub dataset: String,
    pub strategy: String,
    pub scenario: String,
    pub seed: u64,
    /// Timeline, bucketed into round-timeout-sized windows.
    pub windows: Vec<WindowRecord>,
    /// Virtual seconds from first dispatch to last completion.
    pub duration_s: f64,
    /// Totals over the whole run.
    pub dispatched: usize,
    pub completions: usize,
    pub folds: usize,
    pub crashes: usize,
    /// Completions discarded as too stale (Eq. 3 age >= tau).
    pub expired: usize,
    /// Completions that arrived after their dispatch deadline but still
    /// folded (staleness damping absorbs lateness; only age expires it).
    pub late: usize,
    /// Selected clients skipped because a previous invocation of theirs
    /// was still in flight.
    pub in_flight_skipped: usize,
    /// Global-model install count at the end of the run.
    pub final_generation: u32,
    pub final_accuracy: f32,
    pub total_cost: f64,
    /// Wall-clock seconds spent in aggregation folds (real machine time,
    /// excluded from determinism goldens).
    pub agg_wall_s: f64,
    /// Wall-clock seconds spent in replacement selection over the whole
    /// run (real machine time, excluded from determinism goldens).
    pub select_wall_s: f64,
    /// Total clients reclustered across the run's selection passes.
    pub reclustered_clients: usize,
    /// Total standing-assignment reuses across the run's selections.
    pub cluster_cache_hits: usize,
    /// Simulated network bytes server -> clients over the whole run
    /// (full f32 model per dispatched invocation).
    pub bytes_down: usize,
    /// Simulated network bytes clients -> server over the whole run
    /// (raw f32, or int8-quantized wire size when `quantize_updates`).
    pub bytes_up: usize,
    /// client -> invocation count across the run (bias input).
    pub invocations: HashMap<ClientId, u32>,
}

impl ContinuousResult {
    /// Folded updates per virtual second — the headline continuous-mode
    /// throughput metric.
    pub fn updates_per_s(&self) -> f64 {
        if self.duration_s <= 0.0 {
            return 0.0;
        }
        self.folds as f64 / self.duration_s
    }

    /// folds / completions over the whole run (continuous EUR).
    pub fn effective_update_ratio(&self) -> f64 {
        if self.completions == 0 {
            return 0.0;
        }
        self.folds as f64 / self.completions as f64
    }

    /// Serialize the full result (windows + invocation counts) to JSON.
    pub fn to_json(&self) -> Json {
        let windows: Vec<Json> = self
            .windows
            .iter()
            .map(|w| {
                Json::obj(vec![
                    ("window", Json::num(w.window as f64)),
                    ("start_s", Json::num(w.start_s)),
                    ("end_s", Json::num(w.end_s)),
                    ("dispatched", Json::num(w.dispatched as f64)),
                    ("completions", Json::num(w.completions as f64)),
                    ("folds", Json::num(w.folds as f64)),
                    ("crashes", Json::num(w.crashes as f64)),
                    ("expired", Json::num(w.expired as f64)),
                    ("updates_per_s", Json::num(w.updates_per_s)),
                    (
                        "effective_update_ratio",
                        Json::num(w.effective_update_ratio),
                    ),
                    ("in_flight_peak", Json::num(w.in_flight_peak as f64)),
                    ("select_wall_s", Json::num(w.select_wall_s)),
                    (
                        "reclustered_clients",
                        Json::num(w.reclustered_clients as f64),
                    ),
                    (
                        "cluster_cache_hits",
                        Json::num(w.cluster_cache_hits as f64),
                    ),
                ])
            })
            .collect();
        let mut invocations: Vec<(ClientId, u32)> =
            self.invocations.iter().map(|(&c, &n)| (c, n)).collect();
        invocations.sort_unstable();
        Json::obj(vec![
            ("dataset", Json::str(self.dataset.clone())),
            ("strategy", Json::str(self.strategy.clone())),
            ("scenario", Json::str(self.scenario.clone())),
            ("seed", Json::num(self.seed as f64)),
            ("mode", Json::str("continuous")),
            ("duration_s", Json::num(self.duration_s)),
            ("dispatched", Json::num(self.dispatched as f64)),
            ("completions", Json::num(self.completions as f64)),
            ("folds", Json::num(self.folds as f64)),
            ("crashes", Json::num(self.crashes as f64)),
            ("expired", Json::num(self.expired as f64)),
            ("late", Json::num(self.late as f64)),
            ("in_flight_skipped", Json::num(self.in_flight_skipped as f64)),
            ("final_generation", Json::num(self.final_generation as f64)),
            ("final_accuracy", Json::num(self.final_accuracy as f64)),
            ("total_cost", Json::num(self.total_cost)),
            ("updates_per_s", Json::num(self.updates_per_s())),
            (
                "effective_update_ratio",
                Json::num(self.effective_update_ratio()),
            ),
            ("agg_wall_s", Json::num(self.agg_wall_s)),
            ("select_wall_s", Json::num(self.select_wall_s)),
            (
                "reclustered_clients",
                Json::num(self.reclustered_clients as f64),
            ),
            (
                "cluster_cache_hits",
                Json::num(self.cluster_cache_hits as f64),
            ),
            ("bytes_down", Json::num(self.bytes_down as f64)),
            ("bytes_up", Json::num(self.bytes_up as f64)),
            ("windows", Json::Arr(windows)),
            (
                "invocations",
                Json::Arr(
                    invocations
                        .iter()
                        .map(|&(c, n)| {
                            Json::arr(vec![Json::num(c as f64), Json::num(n as f64)])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn write_json(&self, path: &Path) -> Result<()> {
        self.to_json().write_file(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(round: u32, succ: usize, sel: usize) -> RoundRecord {
        RoundRecord {
            round,
            selected: (0..sel).collect(),
            successes: succ,
            failures: sel - succ,
            stale_applied: 0,
            in_flight_skipped: 0,
            duration_s: 10.0,
            accuracy: Some(0.1 * round as f32),
            eval_loss: None,
            train_loss: None,
            cost: 0.01,
            eur: RoundRecord::compute_eur(succ, sel),
            select_wall_s: 0.0,
            agg_wall_s: 0.0,
            param_plane_peak_bytes: 0,
            bytes_down: 0,
            bytes_up: 0,
            reclustered_clients: 0,
            cluster_cache_hits: 0,
        }
    }

    fn exp(rounds: Vec<RoundRecord>) -> ExperimentResult {
        ExperimentResult {
            dataset: "mnist".into(),
            strategy: "fedavg".into(),
            scenario: "standard".into(),
            seed: 0,
            rounds,
            invocations: HashMap::new(),
            total_time_s: 0.0,
            total_cost: 0.0,
            final_accuracy: 0.0,
        }
    }

    #[test]
    fn eur_bounds() {
        assert_eq!(RoundRecord::compute_eur(0, 10), 0.0);
        assert_eq!(RoundRecord::compute_eur(10, 10), 1.0);
        // empty-round semantics: no invocations -> no effective updates
        assert_eq!(RoundRecord::compute_eur(0, 0), 0.0);
    }

    #[test]
    fn mean_eur_averages() {
        let e = exp(vec![rec(0, 5, 10), rec(1, 10, 10)]);
        assert!((e.mean_eur() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn bias_counts_uninvoked_clients_as_zero() {
        let mut e = exp(vec![]);
        e.invocations.insert(0, 5);
        e.invocations.insert(1, 3);
        // 4 registered clients, two never invoked -> min = 0
        assert_eq!(e.bias(4), 5);
        // only the two invoked registered -> min = 3
        assert_eq!(e.bias(2), 2);
    }

    #[test]
    fn rounds_to_accuracy_finds_crossing() {
        let e = exp(vec![rec(0, 1, 1), rec(1, 1, 1), rec(2, 1, 1)]);
        assert_eq!(e.rounds_to_accuracy(0.15), Some(2));
        assert_eq!(e.rounds_to_accuracy(0.9), None);
    }

    #[test]
    fn continuous_result_ratios_guard_zero() {
        let mut c = ContinuousResult {
            dataset: "mnist".into(),
            strategy: "fedlesscan".into(),
            scenario: "standard".into(),
            seed: 0,
            windows: vec![],
            duration_s: 0.0,
            dispatched: 0,
            completions: 0,
            folds: 0,
            crashes: 0,
            expired: 0,
            late: 0,
            in_flight_skipped: 0,
            final_generation: 0,
            final_accuracy: 0.0,
            total_cost: 0.0,
            agg_wall_s: 0.0,
            select_wall_s: 0.0,
            reclustered_clients: 0,
            cluster_cache_hits: 0,
            bytes_down: 0,
            bytes_up: 0,
            invocations: HashMap::new(),
        };
        assert_eq!(c.updates_per_s(), 0.0);
        assert_eq!(c.effective_update_ratio(), 0.0);
        c.duration_s = 50.0;
        c.completions = 20;
        c.folds = 15;
        assert!((c.updates_per_s() - 0.3).abs() < 1e-12);
        assert!((c.effective_update_ratio() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn continuous_result_json_has_windows_and_totals() {
        let c = ContinuousResult {
            dataset: "mnist".into(),
            strategy: "fedlesscan".into(),
            scenario: "straggler25".into(),
            seed: 42,
            windows: vec![WindowRecord {
                window: 0,
                start_s: 0.0,
                end_s: 60.0,
                dispatched: 6,
                completions: 4,
                folds: 3,
                crashes: 1,
                expired: 0,
                updates_per_s: 0.05,
                effective_update_ratio: 0.75,
                in_flight_peak: 6,
                select_wall_s: 0.0,
                reclustered_clients: 5,
                cluster_cache_hits: 11,
            }],
            duration_s: 55.0,
            dispatched: 6,
            completions: 4,
            folds: 3,
            crashes: 1,
            expired: 0,
            late: 1,
            in_flight_skipped: 0,
            final_generation: 3,
            final_accuracy: 0.5,
            total_cost: 0.01,
            agg_wall_s: 0.0,
            select_wall_s: 0.0,
            reclustered_clients: 5,
            cluster_cache_hits: 11,
            bytes_down: 24_000,
            bytes_up: 6_000,
            invocations: [(0, 2), (1, 4)].into_iter().collect(),
        };
        let p = std::env::temp_dir().join(format!("fedless-cont-{}.json", std::process::id()));
        c.write_json(&p).unwrap();
        let j = Json::parse_file(&p).unwrap();
        assert_eq!(j.get("mode").unwrap().as_str().unwrap(), "continuous");
        assert_eq!(j.get("folds").unwrap().as_usize().unwrap(), 3);
        assert_eq!(j.get("bytes_down").unwrap().as_usize().unwrap(), 24_000);
        assert_eq!(j.get("bytes_up").unwrap().as_usize().unwrap(), 6_000);
        assert_eq!(j.get("final_generation").unwrap().as_usize().unwrap(), 3);
        assert_eq!(
            j.get("reclustered_clients").unwrap().as_usize().unwrap(),
            5
        );
        assert_eq!(j.get("cluster_cache_hits").unwrap().as_usize().unwrap(), 11);
        match j.get("windows").unwrap() {
            Json::Arr(ws) => {
                assert_eq!(ws.len(), 1);
                assert_eq!(ws[0].get("folds").unwrap().as_usize().unwrap(), 3);
                assert_eq!(
                    ws[0].get("reclustered_clients").unwrap().as_usize().unwrap(),
                    5
                );
            }
            other => panic!("windows not an array: {other:?}"),
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn timeline_csv_has_header_and_rows() {
        let e = exp(vec![rec(0, 1, 2)]);
        let p = std::env::temp_dir().join(format!("fedless-tl-{}.csv", std::process::id()));
        e.write_timeline_csv(&p).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert!(s.starts_with("round,"));
        assert!(s
            .lines()
            .next()
            .unwrap()
            .ends_with(
                "select_wall_s,agg_wall_s,param_plane_peak_bytes,bytes_down,bytes_up,reclustered_clients,cluster_cache_hits"
            ));
        assert_eq!(s.lines().count(), 2);
        std::fs::remove_file(&p).ok();
    }
}
